"""End-to-end driver: serve a small model with batched requests.

    PYTHONPATH=src python examples/serve_batch.py [--arch qwen3-1.7b]

The paper is an inference paper, so the end-to-end example is serving:
batched prompts -> prefill -> greedy decode through the KV-cached
serve_step (the same function the decode_32k dry-run cells lower), now
via the handle/stream API (PR 8): ``submit`` returns a
``RequestHandle``, the first request's tokens are *streamed* (each
``next()`` steps the continuous scheduler), and ``drain`` finishes the
rest — mixed prompt lengths welcome (``--ragged``).  The run ends with
the engine's admission/degradation stats, scheduler occupancy and
health ledger.  Try a fault drill:

    REPRO_FAULT_PLAN="serve.decode_step:3:raise" \
        PYTHONPATH=src python examples/serve_batch.py

and watch the demotion + retry land in the report (see
docs/robustness.md).

Resume-after-kill drill: journal to a directory, SIGKILL the loop
mid-decode (the `kill` fault kind delivers a real SIGKILL), and rerun
with --resume — the restarted engine recovers every in-flight request
from the journal + newest snapshot and finishes with the exact greedy
tokens the uninterrupted run would have produced:

    REPRO_FAULT_PLAN="serve.decode_step:10:kill" \
        PYTHONPATH=src python examples/serve_batch.py \
        --journal-dir /tmp/serve-crash --snapshot-every 4 || true
    PYTHONPATH=src python examples/serve_batch.py \
        --journal-dir /tmp/serve-crash --resume
"""
import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serve.engine import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--ragged", action="store_true",
                    help="randomize prompt lengths (continuous "
                         "scheduler demo)")
    ap.add_argument("--journal-dir", default=None,
                    help="journal requests (WAL) + snapshots here; "
                         "enables --resume after a kill")
    ap.add_argument("--snapshot-every", type=int, default=None,
                    help="snapshot cadence in decode steps")
    ap.add_argument("--resume", action="store_true",
                    help="recover and finish journaled requests")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    print(f"serving {cfg.name} ({cfg.param_count()/1e6:.1f}M params, "
          f"reduced config)")
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params,
                    max_len=args.prompt_len + args.new_tokens + 8,
                    journal_dir=args.journal_dir,
                    snapshot_every=args.snapshot_every)

    t0 = time.time()
    if args.resume:
        reqs = engine.restore()
        print(f"restored {len(reqs)} journaled request(s), "
              f"{engine.stats()['recovered']} in flight")
        engine.serve(reqs)
    else:
        rng = np.random.default_rng(0)
        lens = (rng.integers(1, args.prompt_len + 1, args.batch)
                if args.ragged
                else np.full(args.batch, args.prompt_len))
        reqs = [engine.submit(
                    rng.integers(0, cfg.vocab_size, int(n)).astype(
                        np.int32),
                    args.new_tokens)
                for n in lens]
        # stream the first handle token by token (each next() steps
        # the scheduler), then drain the rest of the batch
        print(f"  req{reqs[0].rid} streaming:", end="", flush=True)
        for tok in reqs[0].tokens():
            print(f" {tok}", end="", flush=True)
        print()
        engine.drain()
    dt = time.time() - t0
    total_new = sum(len(r.out_tokens) for r in reqs)
    print(f"batch={len(reqs)} prompt<={args.prompt_len} "
          f"new={args.new_tokens}: {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s incl. prefill+compile)")
    for r in reqs:
        print(f"  req{r.rid} [{r.state.value}] prompt={len(r.prompt)}: "
              f"{r.out_tokens[:12]}...")
    stats = engine.stats()
    health = stats.pop("health")
    print(f"engine stats: {stats}")
    print(f"health: {health}")
    print(f"scheduler: {engine.scheduler_report()}")


if __name__ == "__main__":
    main()
