"""Train a reduced-config model end to end with fault-tolerant driver.

    PYTHONPATH=src python examples/train_smoke.py [--arch hymba-1.5b]

Demonstrates the full training substrate on CPU: synthetic sharded data,
AdamW + schedule, microbatched train step, async checkpoints, and a
mid-run simulated crash + bit-exact resume.
"""
import argparse
import os
import shutil

from repro import configs
from repro.runtime.driver import TrainDriver, TrainJobConfig
from repro.runtime.health import SimulatedFailure


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--crash-at", type=int, default=25)
    args = ap.parse_args()

    ckpt_dir = "/tmp/repro_example_ckpt"
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    cfg = configs.get_smoke(args.arch)
    job = TrainJobConfig(arch=cfg, steps=args.steps, global_batch=4,
                         seq_len=64, lr=3e-3, schedule="wsd",
                         ckpt_dir=ckpt_dir, ckpt_every=10)

    print(f"training {cfg.name} for {args.steps} steps "
          f"(crash injected at {args.crash_at})")
    os.environ["REPRO_FAIL_AT_STEP"] = str(args.crash_at)
    try:
        TrainDriver(job).run()
    except SimulatedFailure as e:
        print(f"!! {e} — restarting from checkpoint")
    finally:
        os.environ.pop("REPRO_FAIL_AT_STEP", None)

    state = TrainDriver(job).run(resume=True)
    print(f"done: step={state.step} final loss={state.last_loss:.4f}")


if __name__ == "__main__":
    main()
