"""Dataflow exploration across the paper's full layer grid + empirical check.

    PYTHONPATH=src python examples/explore_dataflows.py

For every layer in the paper's experiment grid (Sec. V) this ranks all
extended dataflows analytically, then empirically re-ranks the top
candidates in interpret mode on a reduced layer — reproducing the
paper's two-step methodology (heuristics first, measurement second).
"""
import numpy as np

from repro.core import explorer
from repro.core.dataflow import ConvProblem, OS

# the paper's experiment grid (Sec. V): (input hw, filter hw, stride, nf)
PAPER_LAYERS = [
    (56, 3, 1, 128), (56, 3, 1, 256), (56, 3, 1, 512),
    (56, 4, 1, 128), (56, 5, 1, 256),
    (112, 3, 1, 128), (112, 3, 1, 256), (112, 4, 1, 512),
    (56, 3, 2, 128), (56, 4, 2, 256),
    (112, 3, 2, 128), (112, 5, 2, 256),
]


def main() -> None:
    wins = {}
    for hw, f, s, nf in PAPER_LAYERS:
        conv = ConvProblem(ih=hw, iw=hw, fh=f, fw=f, s=s, cin=128, cout=nf)
        best = explorer.best_spec(conv.as_gemm())
        key = best.name
        wins[key] = wins.get(key, 0) + 1
        print(f"({f}x{f}, {hw}x{hw}, {nf}) s={s}: best = {best.name} "
              f"block={best.block}")
    print("\nwinning dataflows:", wins)
    assert all(name.startswith("OS") for name in wins), \
        "paper's conclusion: OS-anchored wins everywhere"

    # empirical re-rank of the analytic top-3 on a reduced layer
    conv = ConvProblem(ih=28, iw=28, fh=3, fw=3, s=1, cin=128, cout=128)
    g = conv.as_gemm()
    top3 = [c.spec for c in explorer.explore(g, top=3)]
    print("\nempirical re-rank (interpret mode, reduced layer):")
    for spec, seconds in explorer.empirical_rank(g, top3):
        print(f"  {spec.name:28s} {seconds*1e3:8.2f} ms/call")


if __name__ == "__main__":
    main()
