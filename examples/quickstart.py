"""Quickstart: explore dataflows for a layer, generate the kernel, run it.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's pipeline end to end:
  1. describe a conv layer (56x56, 3x3, stride 1, 128->256 channels, int8);
  2. enumerate + rank extended dataflows with the heuristics/cost model;
  3. emit the Pallas implementation for the winner (code generation);
  4. execute it (interpret mode on CPU) and check against the oracle.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import codegen, explorer
from repro.core.dataflow import ConvProblem, DataflowSpec
from repro.kernels import ops, ref


def main() -> None:
    conv = ConvProblem(ih=56, iw=56, fh=3, fw=3, s=1, cin=128, cout=256,
                       in_dtype="int8", out_dtype="int32")
    gemm = conv.as_gemm()
    print(f"layer: {conv}\nimplicit GEMM: M={gemm.m} K={gemm.k} N={gemm.n}\n")

    print("top dataflows (heuristic-pruned, ranked by est. time):")
    for cand in explorer.explore(gemm, top=5):
        print(f"  {cand.name:28s} est={cand.est_seconds*1e6:8.1f}us "
              f"traffic={cand.traffic_bytes/1e6:8.1f}MB "
              f"block={cand.spec.block}")

    best = explorer.best_spec(gemm)
    print(f"\nwinner: {best.name} (paper Alg. 8 predicts OS + weight aux)\n")
    print(codegen.describe_plan(gemm, best))

    print("\ngenerated source (first 20 lines):")
    src = codegen.generate_source(gemm, best)
    print("\n".join(src.splitlines()[:20]))

    # execute the winning dataflow on the actual conv (reduced spatial size
    # so interpret mode stays fast) and validate
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-10, 10, (1, 14, 14, 128)), jnp.int8)
    w = jnp.asarray(rng.integers(-10, 10, (3, 3, 128, 256)), jnp.int8)
    out = ops.conv2d(x, w, stride=1, spec=best.with_block((128, 128, 128)),
                     backend="interpret", b_oh=4)
    want = ref.conv2d_ref(x, w, 1)
    ok = bool(jnp.all(out == want))
    print(f"\nkernel vs oracle: {'MATCH' if ok else 'MISMATCH'} "
          f"(out {out.shape} {out.dtype})")


if __name__ == "__main__":
    main()
