"""Fused-epilogue kernels, single-dispatch WS/IS, and the autotune cache.

Oracle for every comparison is ``ref.matmul_fused_ref`` (jnp matmul +
bias + activation + dequant + residual), run in interpret mode.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import autotune
from repro.core.dataflow import (
    DataflowSpec, Epilogue, GemmProblem, Residency, IS, OS, WS,
)
from repro.kernels.matmul_df import matmul_df
from repro.kernels import ops, ref

BLOCK = (128, 128, 128)
SPECS = {
    "os_basic": DataflowSpec.basic(OS, block=BLOCK),
    "os_w_stripe": DataflowSpec(OS, {WS: Residency.STRIPE}, (WS,), BLOCK),
    "os_w_whole_i_stripe": DataflowSpec(
        OS, {WS: Residency.WHOLE, IS: Residency.STRIPE}, (WS, IS), BLOCK),
    "ws_basic": DataflowSpec.basic(WS, block=BLOCK),
    "ws_o_stripe": DataflowSpec(WS, {OS: Residency.STRIPE}, (OS,), BLOCK),
    "ws_i_stripe": DataflowSpec(WS, {IS: Residency.STRIPE}, (IS,), BLOCK),
    "is_basic": DataflowSpec.basic(IS, block=BLOCK),
    "is_o_stripe": DataflowSpec(IS, {OS: Residency.STRIPE}, (OS,), BLOCK),
    "is_b_whole": DataflowSpec(IS, {WS: Residency.WHOLE}, (WS,), BLOCK),
}
EPILOGUES = {
    "scale_bias_gelu_res": dict(scale=True, bias=True, activation="gelu",
                                residual=True),
    "bias_relu": dict(bias=True, activation="relu"),
    "silu": dict(activation="silu"),
    "scale": dict(scale=True),
}


def _operands(m, k, n, seed, in_dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    if jnp.issubdtype(in_dtype, jnp.integer):
        a = jnp.asarray(rng.integers(-127, 128, (m, k)), in_dtype)
        b = jnp.asarray(rng.integers(-127, 128, (k, n)), in_dtype)
    else:
        a = jnp.asarray(rng.normal(size=(m, k)), in_dtype)
        b = jnp.asarray(rng.normal(size=(k, n)), in_dtype)
    bias = jnp.asarray(rng.normal(size=(1, n)), jnp.float32)
    scale = jnp.asarray([[rng.uniform(0.01, 0.5)]], jnp.float32)
    residual = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    return a, b, bias, scale, residual


@pytest.mark.parametrize("epi_name", sorted(EPILOGUES))
@pytest.mark.parametrize("spec_name", sorted(SPECS))
def test_fused_epilogue_all_dataflows_f32(spec_name, epi_name):
    m, k, n = 256, 384, 512
    a, b, bias, scale, residual = _operands(
        m, k, n, hash((spec_name, epi_name)) % 2 ** 31)
    flags = EPILOGUES[epi_name]
    epi = Epilogue(
        bias=flags.get("bias", False),
        activation=flags.get("activation"),
        scale=flags.get("scale", False),
        residual=flags.get("residual", False),
    )
    out = matmul_df(
        a, b, SPECS[spec_name], interpret=True, epilogue=epi,
        scale=scale if epi.scale else None,
        bias=bias if epi.bias else None,
        residual=residual if epi.residual else None,
    )
    want = ref.matmul_fused_ref(
        a, b,
        bias=bias if epi.bias else None,
        scale=scale if epi.scale else None,
        residual=residual if epi.residual else None,
        activation=epi.activation,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("spec_name",
                         ["os_basic", "ws_basic", "ws_o_stripe",
                          "is_basic", "is_o_stripe"])
def test_int8_fused_dequant(spec_name):
    m, k, n = 256, 256, 384
    a, b, bias, _, residual = _operands(m, k, n, 11, jnp.int8)
    a_scale, b_scale = jnp.float32(0.013), jnp.float32(0.021)
    out = ops.int8_matmul_fused(
        a, b, a_scale, b_scale, bias=bias, residual=residual,
        activation="silu", spec=SPECS[spec_name], backend="interpret",
    )
    want = ref.matmul_fused_ref(
        a, b, scale=a_scale * b_scale, bias=bias, residual=residual,
        activation="silu",
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("spec_name", ["os_basic", "ws_basic", "is_basic"])
def test_bf16_fused(spec_name):
    m, k, n = 256, 256, 256
    a, b, bias, _, _ = _operands(m, k, n, 13, jnp.bfloat16)
    out = ops.matmul_fused(a, b, bias=bias, activation="gelu",
                           spec=SPECS[spec_name], backend="interpret")
    want = ref.matmul_fused_ref(a, b, bias=bias, activation="gelu")
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("spec_name", ["os_basic", "ws_basic", "is_basic"])
@pytest.mark.parametrize("shape", [(300, 200, 520), (100, 130, 70)])
def test_fused_pads_ragged_shapes(spec_name, shape):
    m, k, n = shape
    a, b, bias, scale, residual = _operands(m, k, n, m * n)
    out = ops.matmul_fused(
        a, b, bias=bias, scale=scale, residual=residual,
        activation="relu", spec=SPECS[spec_name], backend="interpret",
    )
    want = ref.matmul_fused_ref(a, b, bias=bias, scale=scale,
                                residual=residual, activation="relu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("spec_name",
                         ["os_basic", "os_w_stripe", "ws_basic",
                          "ws_o_stripe", "is_basic", "is_o_stripe"])
def test_fused_per_row_scale(spec_name):
    """Per-row (M, 1) dequant scales through every anchor family."""
    m, k, n = 256, 384, 512
    a, b, bias, _, _ = _operands(m, k, n, hash(spec_name) % 2 ** 31)
    rng = np.random.default_rng(21)
    scale = jnp.asarray(rng.uniform(0.01, 0.5, (m, 1)), jnp.float32)
    out = ops.matmul_fused(a, b, bias=bias, scale=scale, activation="relu",
                           spec=SPECS[spec_name], backend="interpret")
    want = ref.matmul_fused_ref(a, b, bias=bias, scale=scale,
                                activation="relu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


def test_int8_fused_per_row_scale_even_when_square():
    # m == n: a (M, 1) per-row scale must dispatch as per-row, not
    # per-column — the fused result must match the unfused oracle
    m = kdim = n = 128
    rng = np.random.default_rng(31)
    aq = jnp.asarray(rng.integers(-127, 128, (m, kdim)), jnp.int8)
    bq = jnp.asarray(rng.integers(-127, 128, (kdim, n)), jnp.int8)
    a_scale = jnp.asarray(rng.uniform(0.005, 0.02, (m, 1)), jnp.float32)
    b_scale = jnp.float32(0.013)
    out = ops.int8_matmul_fused(aq, bq, a_scale, b_scale,
                                backend="interpret")
    want = ref.int8_matmul_ref(aq, bq, a_scale, b_scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_int8_fused_per_row_scale_padded():
    m, kdim, n = 100, 130, 70   # ragged: every dim pads
    rng = np.random.default_rng(33)
    aq = jnp.asarray(rng.integers(-127, 128, (m, kdim)), jnp.int8)
    bq = jnp.asarray(rng.integers(-127, 128, (kdim, n)), jnp.int8)
    a_scale = jnp.asarray(rng.uniform(0.005, 0.02, (m, 1)), jnp.float32)
    b_scale = jnp.float32(0.02)
    out = ops.int8_matmul_fused(aq, bq, a_scale, b_scale, activation="silu",
                                backend="interpret")
    want = ref.matmul_fused_ref(aq, bq, scale=a_scale * b_scale,
                                activation="silu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_int8_fused_rejects_full_scale_grid():
    # per-row activations x per-column weights combine to (M, N): only
    # the unfused path can apply that
    aq = jnp.zeros((128, 128), jnp.int8)
    bq = jnp.zeros((128, 128), jnp.int8)
    with pytest.raises(ValueError, match="per-row"):
        ops.int8_matmul_fused(aq, bq, jnp.ones((128, 1)),
                              jnp.ones((1, 128)), backend="interpret")


def test_fused_per_column_scale():
    m, k, n = 256, 256, 384
    a, b, bias, _, _ = _operands(m, k, n, 17)
    rng = np.random.default_rng(18)
    scale = jnp.asarray(rng.uniform(0.01, 0.5, (1, n)), jnp.float32)
    out = ops.matmul_fused(a, b, bias=bias, scale=scale,
                           spec=SPECS["os_basic"], backend="interpret")
    want = ref.matmul_fused_ref(a, b, bias=bias, scale=scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


# ---------------------------------------------------------------------------
# Single-dispatch WS/IS regression.
# ---------------------------------------------------------------------------
from repro.core.jaxpr_utils import count_pallas_calls  # noqa: E402


@pytest.mark.parametrize("spec_name", ["ws_o_stripe", "is_o_stripe"])
def test_int8_fused_stripe_exact_for_deep_reductions(spec_name):
    """Integer-input fused epilogues through the output-stripe writers
    accumulate in an int32 scratch: running sums past 2**24 (where f32
    accumulation starts dropping low bits) must still match the oracle's
    single int32->f32 cast bit-for-bit."""
    k = 2048
    rng = np.random.default_rng(29)
    a = jnp.asarray(rng.integers(100, 128, (128, k)), jnp.int8)
    b = jnp.asarray(rng.integers(100, 128, (k, 128)), jnp.int8)
    one = jnp.float32(1.0)
    out = ops.int8_matmul_fused(a, b, one, one, spec=SPECS[spec_name],
                                backend="interpret")
    want = ref.int8_matmul_ref(a, b, one, one)
    assert float(jnp.max(jnp.abs(a.astype(jnp.int32) @ b.astype(jnp.int32))
                         )) > 2 ** 24  # the regression regime
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("spec_name", ["ws_basic", "is_basic"])
def test_rmw_accumulates_in_acc_dtype(spec_name):
    """Deep int8 reductions through the single-dispatch WS/IS path must
    stay bit-exact (int32 scratch accumulation, not output-dtype)."""
    rng = np.random.default_rng(23)
    k = 2048  # 16 reduction panels
    a = jnp.asarray(rng.integers(-127, 128, (128, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-127, 128, (k, 128)), jnp.int8)
    out = matmul_df(a, b, SPECS[spec_name], interpret=True)
    assert out.dtype == jnp.int32
    assert bool(jnp.all(out == ref.matmul_ref(a, b)))


@pytest.mark.parametrize("spec_name", ["ws_basic", "is_basic",
                                       "ws_i_stripe", "is_b_whole"])
@pytest.mark.parametrize("gk", [2, 4])
def test_ws_is_single_dispatch(spec_name, gk):
    """Basic WS/IS must issue exactly ONE pallas_call regardless of the
    reduction depth, and still match the oracle."""
    m, n = 256, 256
    k = 128 * gk
    a, b, _, _, _ = _operands(m, k, n, gk)
    spec = SPECS[spec_name]
    jaxpr = jax.make_jaxpr(
        lambda x, y: matmul_df(x, y, spec, interpret=True))(a, b)
    assert count_pallas_calls(jaxpr.jaxpr) == 1, jaxpr
    out = matmul_df(a, b, spec, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.matmul_ref(a, b)),
                               rtol=1e-5, atol=1e-3)


# ---------------------------------------------------------------------------
# Autotune cache.
# ---------------------------------------------------------------------------
def test_autotune_cache_hits_and_disk_roundtrip():
    autotune.clear()
    autotune.reset_stats()
    p = GemmProblem(256, 512, 1024, in_dtype="float32")
    s1 = autotune.best_spec(p, backend="interpret")
    s2 = autotune.best_spec(p, backend="interpret")
    st = autotune.stats()
    assert s1 == s2
    assert st["enumerations"] == 1 and st["hits"] == 1, st
    # drop the in-process cache: the JSON store must serve the spec
    autotune.clear()
    autotune.reset_stats()
    s3 = autotune.best_spec(p, backend="interpret")
    st = autotune.stats()
    assert s3 == s1 and st["enumerations"] == 0 and st["hits"] == 1, st


def test_repeated_ops_matmul_does_not_reenumerate():
    autotune.clear()
    autotune.reset_stats()
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(256, 128)), jnp.float32)
    o1 = ops.matmul(a, b, backend="interpret")
    o2 = ops.matmul(a, b, backend="interpret")
    st = autotune.stats()
    assert st["enumerations"] <= 1, st
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2))
    np.testing.assert_allclose(np.asarray(o1),
                               np.asarray(ref.matmul_ref(a, b)),
                               rtol=1e-5, atol=1e-3)


def test_autotune_key_distinguishes_dtype_and_backend():
    p32 = GemmProblem(128, 128, 128, in_dtype="float32")
    p8 = GemmProblem(128, 128, 128, in_dtype="int8")
    from repro.core.cost_model import V5E

    assert autotune._key(p32, V5E, "interpret") \
        != autotune._key(p8, V5E, "interpret")
    assert autotune._key(p32, V5E, "interpret") \
        != autotune._key(p32, V5E, "pallas")


# ---------------------------------------------------------------------------
# Epilogue spec validation + explorer satellite fixes.
# ---------------------------------------------------------------------------
def test_epilogue_validation():
    with pytest.raises(ValueError):
        Epilogue(activation="tanh")
    assert Epilogue().is_noop
    with pytest.raises(ValueError):
        a = jnp.zeros((128, 128), jnp.float32)
        matmul_df(a, a, SPECS["os_basic"], interpret=True,
                  epilogue=Epilogue(bias=True))  # bias array missing


def test_block_options_clamped_to_padded_dim():
    from repro.core import cost_model, explorer

    opts = explorer._block_options(300, cost_model.V5E)
    assert opts == [128, 256]          # 512 > padded 384 is pruned
    assert explorer._block_options(64, cost_model.V5E) == [128]
    for cand in explorer.enumerate_candidates(
            GemmProblem(300, 300, 300, in_dtype="float32")):
        assert all(blk <= 384 for blk in cand.spec.block)


def test_empirical_rank_honors_dtype():
    from repro.core import explorer

    p = GemmProblem(128, 128, 128, in_dtype="int8")
    ranked = explorer.empirical_rank(
        p, [SPECS["os_basic"], SPECS["ws_basic"]])
    assert len(ranked) == 2 and all(sec > 0 for _, sec in ranked)
