"""PR 8: continuous batching on a paged, per-row-banded KV cache.

Four layers under test, bottom-up:

  * kernels — per-row banding: ``kv_len`` as a (B,) vector bands both
    attention anchors per batch row (rows at 0 and at the full buffer
    included), and ``ops.paged_attention`` reads scattered pages
    through the block-table index map with contiguous-equivalent
    results;
  * cost model — a ragged decode step's modeled traffic follows each
    row's valid length, not the batch max;
  * ops API — ``SpecOverride`` as the one spec-shaped door into all
    four entry points, with the old per-op kwargs kept as aliases;
  * engine — reach-aware admission, mixed-length batches through the
    continuous scheduler with bit-identical greedy tokens vs
    per-request sequential decode, prefix-page reuse, chunked prefill,
    the handle/stream API, and the deprecated ``generate`` shim.
"""
import dataclasses
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core import cost_model
from repro.core.dataflow import (AttentionProblem, DataflowSpec,
                                 SpecOverride, OS, WS, IS)
from repro.kernels import ops, ref
from repro.models import lm
from repro.serve.engine import (AdmissionError, Engine, RequestState,
                                StepFailed)
from repro.serve.paged_cache import PagedKVCache, pages_for
from repro.serve.scheduler import SamplingParams, SchedulerConfig

CFG = configs.get_smoke("qwen3-1.7b")
MAX_LEN = 48


@pytest.fixture(scope="module")
def params():
    return lm.init_model(CFG, jax.random.PRNGKey(0))


def _qkv(b, hq, hkv, sq, skv, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, skv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, skv, d), jnp.float32)
    return q, k, v


# ---------------------------------------------------------------------------
# Kernels: per-row banding, both anchors.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("anchor", ["os", "ws"])
def test_ragged_rowwise_banding_parity(anchor):
    # rows at 0, mid-band, block-unaligned, and the full buffer
    skv = 32
    kv = jnp.asarray([0, 5, 17, skv], jnp.int32)
    q, k, v = _qkv(4, 2, 2, 1, skv, 16)
    got = ops.attention(q, k, v, causal=True, backend="interpret",
                        anchor=anchor, bq=8, bkv=8, kv_len=kv)
    want = ref.attention_ref(q, k, v, causal=True, kv_len=kv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    # a row with no valid KV attends to nothing
    assert np.all(np.asarray(got)[0] == 0.0)


@pytest.mark.parametrize("anchor", ["os", "ws"])
def test_ragged_banding_with_window(anchor):
    skv = 32
    kv = jnp.asarray([3, 12, 32], jnp.int32)
    q, k, v = _qkv(3, 2, 1, 1, skv, 16, seed=1)   # GQA group=2
    got = ops.attention(q, k, v, causal=True, window=8,
                        backend="interpret", anchor=anchor, bq=8,
                        bkv=8, kv_len=kv)
    want = ref.attention_ref(q, k, v, causal=True, window=8, kv_len=kv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_paged_vs_contiguous_equivalence():
    # one pool, three requests of ragged length; kernel reads pages
    # through the block table, oracle reads the contiguous originals
    page, d, hkv, hq = 8, 16, 2, 4
    kv = np.asarray([5, 17, 24], np.int32)
    q, k, v = _qkv(3, hq, hkv, 1, int(kv.max()), d, seed=2)
    n_pages = sum(pages_for(int(n), page) for n in kv) + 1
    pool_k = np.zeros((hkv, n_pages, page, d), np.float32)
    pool_v = np.zeros((hkv, n_pages, page, d), np.float32)
    tables = np.zeros((3, pages_for(int(kv.max()), page)), np.int32)
    nxt = 1                                 # page 0 stays as padding
    for r, n in enumerate(kv):
        for j in range(pages_for(int(n), page)):
            lo, hi = j * page, min((j + 1) * page, int(n))
            pool_k[:, nxt, :hi - lo] = np.asarray(k)[r, :, lo:hi]
            pool_v[:, nxt, :hi - lo] = np.asarray(v)[r, :, lo:hi]
            tables[r, j] = nxt
            nxt += 1
    for backend in ("interpret", "xla"):
        got = ops.paged_attention(
            q, jnp.asarray(pool_k), jnp.asarray(pool_v),
            jnp.asarray(tables), jnp.asarray(kv), backend=backend)
        want = ref.attention_ref(q, k, v, causal=True,
                                 kv_len=jnp.asarray(kv))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5,
                                   err_msg=f"backend={backend}")


def test_paged_attention_rejects_prefill_queries():
    q = jnp.zeros((1, 2, 4, 16))            # Sq=4: not a decode step
    pool = jnp.zeros((2, 4, 8, 16))
    with pytest.raises(ValueError, match="decode-only"):
        ops.paged_attention(q, pool, pool, jnp.zeros((1, 4), jnp.int32),
                            jnp.asarray([8], jnp.int32))


# ---------------------------------------------------------------------------
# Cost model: ragged decode traffic follows kv_valid, not batch max.
# ---------------------------------------------------------------------------
def test_ragged_decode_traffic_scales_per_row():
    p = AttentionProblem(bh=8, sq=1, skv=1024, d=64, group=1,
                        causal=True, dtype="float32", rows=4)
    spec = DataflowSpec.basic(OS, block=(8, 128, 64))
    short = cost_model.attention_rows_traffic(
        p, [64, 64, 64, 64], spec).total
    ragged = cost_model.attention_rows_traffic(
        p, [64, 256, 512, 1024], spec).total
    worst = cost_model.attention_rows_traffic(
        p, [1024, 1024, 1024, 1024], spec).total
    assert short < ragged < worst
    # a batch-max model would bill every row at the longest request
    assert ragged < 0.75 * worst


# ---------------------------------------------------------------------------
# Ops API: SpecOverride across all four entry points.
# ---------------------------------------------------------------------------
def test_spec_override_matmul():
    a = jnp.asarray(np.random.default_rng(0).normal(size=(32, 24)),
                    jnp.float32)
    b = jnp.asarray(np.random.default_rng(1).normal(size=(24, 16)),
                    jnp.float32)
    want = a @ b
    for ov in (SpecOverride(anchor=WS),
               SpecOverride(block=(16, 8, 8)),
               SpecOverride(anchor=OS, block=(16, 8, 8))):
        got = ops.matmul(a, b, spec=ov, backend="interpret")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-4)


def test_spec_override_attention_and_kwarg_aliases():
    q, k, v = _qkv(2, 2, 2, 8, 8, 16, seed=3)
    want = ref.attention_ref(q, k, v, causal=True)
    via_override = ops.attention(
        q, k, v, causal=True, backend="interpret",
        spec=SpecOverride(anchor=WS, block=(8, 8)))
    via_kwargs = ops.attention(q, k, v, causal=True,
                               backend="interpret", anchor="ws",
                               bq=8, bkv=8)
    np.testing.assert_allclose(np.asarray(via_override),
                               np.asarray(want), atol=2e-5, rtol=2e-5)
    # the override is sugar for the old kwargs: identical results
    np.testing.assert_array_equal(np.asarray(via_override),
                                  np.asarray(via_kwargs))
    with pytest.raises(ValueError, match="OS/WS"):
        ops.attention(q, k, v, backend="interpret",
                      spec=SpecOverride(anchor=IS))


def test_spec_override_conv2d():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(1, 12, 12, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 8, 16)), jnp.float32)
    want = ops.conv2d(x, w, backend="xla")
    got = ops.conv2d(x, w, backend="interpret",
                     spec=SpecOverride(anchor=OS))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_spec_override_merge_semantics():
    base = DataflowSpec.optimized()
    merged = SpecOverride(anchor=WS).merge(base)
    assert merged.anchor == WS
    assert merged.block == base.block
    full = SpecOverride(anchor=OS, block=(64, 32, 16))
    assert full.is_complete
    assert full.merge(base).block == (64, 32, 16)
    partial = SpecOverride(block=(None, 256, None)).merge(base)
    assert partial.block == (base.block[0], 256, base.block[2])


# ---------------------------------------------------------------------------
# Paged cache bookkeeping.
# ---------------------------------------------------------------------------
def test_paged_cache_refcounts_and_prefix_chain():
    cache = PagedKVCache(CFG, n_pages=8, page_size=4)
    toks = list(range(10))                 # 2 full pages + 1 partial
    pages = cache.alloc(pages_for(10, 4))
    assert pages is not None and len(pages) == 3
    L, H, D = CFG.n_layers, CFG.n_kv_heads, CFG.d_head
    kv = jnp.ones((L, H, 10, D))
    cache.store(toks, pages, 0, kv, kv)
    # a second prompt sharing the first 8 tokens reuses both full pages
    reuse, covered = cache.lookup_prefix(list(range(8)) + [99, 98, 97])
    assert covered == 8 and reuse == pages[:2]
    assert cache.refs[pages[0]] == 2
    # chain key includes the parent: same chunk at a different start
    # position (or after a different first page) must not match
    miss, cov0 = cache.lookup_prefix([4, 5, 6, 7, 0, 1, 2, 3, 42])
    assert cov0 == 0 and miss == []
    cache.release(reuse)
    cache.release(pages)
    assert cache.free_pages == 8
    # freed pages leave the prefix chain
    gone, _ = cache.lookup_prefix(toks)
    assert gone == []


def test_paged_cache_alloc_exhaustion_is_total():
    cache = PagedKVCache(CFG, n_pages=2, page_size=4)
    assert cache.alloc(3) is None          # no partial allocation
    assert cache.free_pages == 2
    assert cache.stats["oom_rejects"] == 1
    got = cache.alloc(2)
    assert len(got) == 2 and not cache.can_admit(1)


# ---------------------------------------------------------------------------
# Engine: reach-aware admission (the PR-8 bugfix).
# ---------------------------------------------------------------------------
def test_admission_probes_request_reach_not_max_len(params):
    # 64 KiB VMEM: the decode-step attention fits at the request's kv
    # reach (12) but not at max_len (2048).  The old probe billed every
    # request for max_len and over-rejected exactly this case.
    hw = dataclasses.replace(cost_model.V5E, vmem_bytes=65536,
                             name="tiny-vmem-64k")
    eng = Engine(CFG, params, max_len=2048, hw=hw)
    req = eng.submit(np.zeros(8, np.int32), 4)    # reach = 12: fits
    assert req.state == RequestState.QUEUED
    with pytest.raises(AdmissionError, match="kv reach"):
        eng.submit(np.zeros(8, np.int32), 4096)   # reach = 2048: doesn't


# ---------------------------------------------------------------------------
# Engine: continuous scheduler end to end.
# ---------------------------------------------------------------------------
def _sequential_tokens(params, prompts, new_tokens):
    out = []
    for p in prompts:
        eng = Engine(CFG, params, max_len=MAX_LEN)
        r = eng.submit(p, new_tokens)
        eng.serve([r])
        assert r.state == RequestState.DONE
        out.append(list(r.out_tokens))
    return out


def _ragged_prompts(lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, (n,)).astype(np.int32)
            for n in lens]


def test_mixed_length_batch_matches_sequential_decode(params):
    # the PR-8 acceptance property: a ragged batch through the
    # continuous scheduler produces the same greedy tokens as decoding
    # each request alone
    prompts = _ragged_prompts([7, 12, 2, 23])
    want = _sequential_tokens(params, prompts, 5)
    eng = Engine(CFG, params, max_len=MAX_LEN)
    reqs = [eng.submit(p, 5) for p in prompts]
    eng.serve(reqs)
    for r, exp in zip(reqs, want):
        assert r.state == RequestState.DONE, (r.rid, r.state, r.error)
        assert list(r.out_tokens) == exp
    rep = eng.scheduler_report()
    assert rep["steps"] > 0 and rep["active"] == 0


def test_continuous_slots_turn_over(params):
    # more requests than slots: short ones finish and free their slot
    # for the queue, and everyone still matches the sequential oracle
    prompts = _ragged_prompts([3, 9, 4, 6, 11], seed=1)
    want = _sequential_tokens(params, prompts, 3)
    eng = Engine(CFG, params, max_len=MAX_LEN,
                 scheduler_config=SchedulerConfig(max_batch=2))
    reqs = [eng.submit(p, 3) for p in prompts]
    eng.serve(reqs)
    for r, exp in zip(reqs, want):
        assert r.state == RequestState.DONE
        assert list(r.out_tokens) == exp


def test_chunked_prefill_matches_whole_prefill(params):
    prompts = _ragged_prompts([19, 7], seed=2)
    want = _sequential_tokens(params, prompts, 4)
    eng = Engine(CFG, params, max_len=MAX_LEN,
                 scheduler_config=SchedulerConfig(max_batch=2,
                                                  prefill_chunk=8))
    reqs = [eng.submit(p, 4) for p in prompts]
    eng.serve(reqs)
    for r, exp in zip(reqs, want):
        assert r.state == RequestState.DONE
        assert list(r.out_tokens) == exp


def test_prefix_page_reuse_shares_and_matches(params):
    rng = np.random.default_rng(3)
    shared = rng.integers(0, CFG.vocab_size, (19,)).astype(np.int32)
    p1 = shared
    p2 = np.concatenate(
        [shared[:16],
         rng.integers(0, CFG.vocab_size, (5,)).astype(np.int32)])
    want = _sequential_tokens(params, [p1, p2], 4)
    eng = Engine(CFG, params, max_len=MAX_LEN,
                 scheduler_config=SchedulerConfig(max_batch=2,
                                                  page_size=8))
    reqs = [eng.submit(p, 4) for p in (p1, p2)]
    eng.serve(reqs)
    for r, exp in zip(reqs, want):
        assert r.state == RequestState.DONE
        assert list(r.out_tokens) == exp
    pages = eng.scheduler_report()["pages"]
    assert pages["reuse_hits"] == 1
    assert pages["reuse_pages"] == 2       # 16 shared positions / 8


def test_handle_stream_and_result(params):
    prompts = _ragged_prompts([7, 12], seed=4)
    want = _sequential_tokens(params, prompts, 4)
    eng = Engine(CFG, params, max_len=MAX_LEN)
    h1 = eng.submit(prompts[0], 4)
    h2 = eng.submit(prompts[1], 4)
    # streaming h1 steps the scheduler; h2 decodes alongside it
    assert list(h1.tokens()) == want[0]
    assert list(h2.result()) == want[1]
    assert h1.state == RequestState.DONE
    assert h2.state == RequestState.DONE


def test_sampling_params_bundle(params):
    prompts = _ragged_prompts([6], seed=5)
    eng = Engine(CFG, params, max_len=MAX_LEN)
    h = eng.submit(prompts[0],
                   sampling=SamplingParams(max_new_tokens=3,
                                           greedy=False, seed=7))
    toks = h.result()
    assert len(toks) == 3
    # same per-request seed, fresh engine: the sampled stream replays
    eng2 = Engine(CFG, params, max_len=MAX_LEN)
    h2 = eng2.submit(prompts[0],
                     sampling=SamplingParams(max_new_tokens=3,
                                             greedy=False, seed=7))
    np.testing.assert_array_equal(toks, h2.result())


def test_generate_is_a_deprecated_shim(params):
    eng = Engine(CFG, params, max_len=MAX_LEN)
    prompts = np.stack(_ragged_prompts([8, 8], seed=6))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        out = eng.generate(prompts, max_new_tokens=3)
    assert any(issubclass(w.category, DeprecationWarning)
               for w in caught)
    assert out.shape == (2, 3)
    want = _sequential_tokens(params, list(prompts), 3)
    assert [list(row) for row in out] == want
