"""Dataflow matmul kernels vs the jnp oracle: shape/dtype/dataflow sweep."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.dataflow import DataflowSpec, Residency, IS, OS, WS
from repro.kernels.matmul_df import matmul_df
from repro.kernels import ops, ref

BLOCK = (128, 128, 128)
SPECS = {
    "os_basic": DataflowSpec.basic(OS, block=BLOCK),
    "os_w_stripe": DataflowSpec(OS, {WS: Residency.STRIPE}, (WS,), BLOCK),
    "os_w_whole": DataflowSpec(OS, {WS: Residency.WHOLE}, (WS,), BLOCK),
    "os_i_stripe": DataflowSpec(OS, {IS: Residency.STRIPE}, (IS,), BLOCK),
    "os_w_whole_i_stripe": DataflowSpec(
        OS, {WS: Residency.WHOLE, IS: Residency.STRIPE}, (WS, IS), BLOCK),
    "ws_basic": DataflowSpec.basic(WS, block=BLOCK),
    "ws_o_stripe": DataflowSpec(WS, {OS: Residency.STRIPE}, (OS,), BLOCK),
    "ws_i_stripe": DataflowSpec(WS, {IS: Residency.STRIPE}, (IS,), BLOCK),
    "is_basic": DataflowSpec.basic(IS, block=BLOCK),
    "is_o_stripe": DataflowSpec(IS, {OS: Residency.STRIPE}, (OS,), BLOCK),
    "is_b_whole": DataflowSpec(IS, {WS: Residency.WHOLE}, (WS,), BLOCK),
}
SHAPES = [(128, 128, 128), (256, 384, 512), (384, 128, 256)]


@pytest.mark.parametrize("spec_name", sorted(SPECS))
@pytest.mark.parametrize("shape", SHAPES)
def test_matmul_dataflows_f32(spec_name, shape):
    m, k, n = shape
    rng = np.random.default_rng(hash((spec_name, shape)) % 2**31)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    out = matmul_df(a, b, SPECS[spec_name], interpret=True)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("spec_name", ["os_basic", "ws_basic", "is_basic",
                                       "os_w_stripe", "is_o_stripe"])
def test_matmul_dataflows_int8(spec_name):
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.integers(-127, 127, (256, 256)), jnp.int8)
    b = jnp.asarray(rng.integers(-127, 127, (256, 384)), jnp.int8)
    out = matmul_df(a, b, SPECS[spec_name], interpret=True)
    want = ref.matmul_ref(a, b)
    assert out.dtype == jnp.int32
    assert bool(jnp.all(out == want))


@pytest.mark.parametrize("spec_name", ["os_basic", "ws_o_stripe"])
def test_matmul_dataflows_bf16(spec_name):
    rng = np.random.default_rng(8)
    a = jnp.asarray(rng.normal(size=(256, 256)), jnp.bfloat16)
    b = jnp.asarray(rng.normal(size=(256, 256)), jnp.bfloat16)
    out = matmul_df(a, b, SPECS[spec_name], interpret=True)
    want = ref.matmul_ref(a, b)
    rel = float(jnp.max(jnp.abs(out - want)) / jnp.max(jnp.abs(want)))
    assert rel < 1e-5, rel


def test_ops_matmul_pads_ragged_shapes():
    rng = np.random.default_rng(9)
    a = jnp.asarray(rng.normal(size=(300, 200)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(200, 520)), jnp.float32)
    out = ops.matmul(a, b, backend="interpret")
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.matmul_ref(a, b)),
                               rtol=1e-5, atol=1e-3)


def test_untileable_raises():
    a = jnp.zeros((100, 128), jnp.float32)
    b = jnp.zeros((128, 128), jnp.float32)
    with pytest.raises(ValueError):
        matmul_df(a, b, SPECS["os_basic"])
