"""Fused-epilogue conv kernels, single-dispatch WS/IS conv, and the conv
autotune keying path.

Oracle for every comparison is ``ref.conv2d_fused_ref`` /
``ref.conv2d_ref`` (jnp direct conv + epilogue), run in interpret mode.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import autotune, cost_model, explorer
from repro.core.dataflow import ConvProblem, DataflowSpec, GemmProblem, IS, OS, WS
from repro.core.jaxpr_utils import count_pallas_calls, count_primitive
from repro.kernels import ops, ref
from repro.kernels.conv2d_df import conv2d_df

ANCHORS = {"os": OS, "ws": WS, "is": IS}
CONV_CASES = [
    # (n, ih, iw, fh, fw, s, cin, cout)
    (2, 14, 14, 3, 3, 1, 128, 128),
    (1, 15, 13, 3, 3, 2, 64, 96),     # stride 2 + odd channels (padding)
    (1, 12, 12, 5, 5, 1, 60, 70),     # odd channels both sides
]
EPILOGUES = {
    "scale_bias_gelu_res": dict(scale=True, bias=True, activation="gelu",
                                residual=True),
    "bias_relu": dict(bias=True, activation="relu"),
    "silu": dict(activation="silu"),
    "scale": dict(scale=True),
}


def _operands(case, seed, in_dtype=jnp.float32):
    n, ih, iw, fh, fw, s, cin, cout = case
    oh = (ih - fh) // s + 1
    ow = (iw - fw) // s + 1
    rng = np.random.default_rng(seed)
    if jnp.issubdtype(in_dtype, jnp.integer):
        x = jnp.asarray(rng.integers(-20, 21, (n, ih, iw, cin)), in_dtype)
        w = jnp.asarray(rng.integers(-20, 21, (fh, fw, cin, cout)), in_dtype)
    else:
        x = jnp.asarray(rng.normal(size=(n, ih, iw, cin)), in_dtype)
        w = jnp.asarray(rng.normal(size=(fh, fw, cin, cout)), in_dtype)
    bias = jnp.asarray(rng.normal(size=(cout,)), jnp.float32)
    scale = jnp.asarray(rng.uniform(0.01, 0.5, (cout,)), jnp.float32)
    residual = jnp.asarray(rng.normal(size=(n, oh, ow, cout)), jnp.float32)
    return x, w, bias, scale, residual


@pytest.mark.parametrize("epi_name", sorted(EPILOGUES))
@pytest.mark.parametrize("case", CONV_CASES)
@pytest.mark.parametrize("anchor", sorted(ANCHORS))
def test_conv2d_fused_matches_oracle(anchor, case, epi_name):
    s = case[5]
    x, w, bias, scale, residual = _operands(
        case, hash((anchor, case, epi_name)) % 2**31)
    flags = EPILOGUES[epi_name]
    kw = dict(
        bias=bias if flags.get("bias") else None,
        scale=scale if flags.get("scale") else None,
        residual=residual if flags.get("residual") else None,
        activation=flags.get("activation"),
    )
    got = ops.conv2d_fused(
        x, w, stride=s, spec=DataflowSpec.basic(ANCHORS[anchor]),
        b_oh=4, backend="interpret", **kw,
    )
    want = ref.conv2d_fused_ref(
        x, w, s,
        bias=kw["bias"].reshape(1, -1) if kw["bias"] is not None else None,
        scale=kw["scale"].reshape(1, -1) if kw["scale"] is not None else None,
        residual=kw["residual"], activation=kw["activation"],
    )
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("anchor", sorted(ANCHORS))
def test_int8_conv2d_fused(anchor):
    case = (1, 14, 14, 3, 3, 1, 128, 128)
    x, w, bias, _, residual = _operands(case, 7, jnp.int8)
    x_scale, w_scale = jnp.float32(0.02), jnp.float32(0.01)
    got = ops.int8_conv2d_fused(
        x, w, x_scale, w_scale, bias=bias, residual=residual,
        activation="silu", spec=DataflowSpec.basic(ANCHORS[anchor]),
        backend="interpret",
    )
    want = ref.conv2d_fused_ref(
        x, w, 1, scale=(x_scale * w_scale).reshape(1, 1),
        bias=bias.reshape(1, -1), residual=residual, activation="silu",
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_int8_conv2d_fused_per_channel_scale():
    case = (1, 12, 12, 3, 3, 1, 64, 96)
    x, w, _, w_scale, _ = _operands(case, 9, jnp.int8)
    got = ops.int8_conv2d_fused(
        x, w, jnp.float32(0.05), w_scale, activation="relu",
        spec=DataflowSpec.basic(OS), backend="interpret",
    )
    want = ref.conv2d_fused_ref(
        x, w, 1, scale=(0.05 * w_scale).reshape(1, -1), activation="relu",
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_bf16_conv_fused():
    case = (1, 12, 12, 3, 3, 1, 128, 128)
    x, w, bias, _, _ = _operands(case, 11, jnp.bfloat16)
    got = ops.conv2d_fused(x, w, bias=bias, activation="gelu",
                           spec=DataflowSpec.basic(WS), b_oh=4,
                           backend="interpret")
    want = ref.conv2d_fused_ref(x, w, 1, bias=bias.reshape(1, -1),
                                activation="gelu")
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-1)


# ---------------------------------------------------------------------------
# Single-dispatch WS/IS conv regression.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("anchor", sorted(ANCHORS))
def test_conv_single_dispatch_no_zeros_init(anchor):
    """Every conv anchor must lower as exactly ONE pallas_call with no
    output zeros-init round trip, regardless of the reduction depth
    (here n_r = fh*fw*gc = 9)."""
    x = jnp.zeros((1, 14, 14, 128), jnp.float32)   # pre-padded: oh=ow=12
    w = jnp.zeros((3, 3, 128, 128), jnp.float32)
    spec = DataflowSpec.basic(ANCHORS[anchor])
    jx = jax.make_jaxpr(
        lambda a, b: conv2d_df(a, b, 1, spec, oh=12, ow=12, b_oh=4,
                               interpret=True))(x, w)
    assert count_pallas_calls(jx.jaxpr) == 1, jx
    # the old WS/IS lowering materialized jnp.zeros((n, oh, ow, k)) at
    # the top level; the in-kernel scratch init lives inside the
    # pallas_call, not the outer jaxpr
    assert all(eqn.primitive.name != "broadcast_in_dim"
               for eqn in jx.jaxpr.eqns), jx


def test_ws_is_conv_matches_os_bitwise_int32():
    """Single-dispatch WS/IS conv accumulates in an int32 scratch like
    OS: int8 convs must agree bitwise across all anchors and with the
    oracle."""
    case = (2, 15, 13, 3, 3, 2, 64, 96)
    x, w, _, _, _ = _operands(case, 13, jnp.int8)
    outs = {
        name: ops.conv2d(x, w, stride=2, spec=DataflowSpec.basic(a),
                         backend="interpret", b_oh=4)
        for name, a in ANCHORS.items()
    }
    want = ref.conv2d_ref(x, w, 2)
    for name, got in outs.items():
        assert got.dtype == jnp.int32, name
        assert bool(jnp.all(got == want)), name


# ---------------------------------------------------------------------------
# Conv autotune keying.
# ---------------------------------------------------------------------------
CONV_PROBLEM = ConvProblem(ih=14, iw=14, fh=3, fw=3, s=1, cin=128, cout=128,
                           n=2, in_dtype="float32", out_dtype="float32")


def test_conv_autotune_cache_hits():
    autotune.clear(disk=True)
    autotune.reset_stats()
    s1 = autotune.best_spec(CONV_PROBLEM, backend="interpret")
    s2 = autotune.best_spec(CONV_PROBLEM, backend="interpret")
    st = autotune.stats()
    assert s1 == s2
    assert st["enumerations"] == 1 and st["hits"] == 1, st


def test_ops_conv2d_resolves_through_conv_autotune():
    """ops.conv2d(spec=None) must key the cache on the ConvProblem: the
    trace-time lookup after a direct best_spec call is a cache hit."""
    autotune.clear(disk=True)
    autotune.reset_stats()
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 14, 14, 128)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 128, 128)), jnp.float32)
    spec = autotune.best_spec(CONV_PROBLEM, backend="interpret")
    assert autotune.stats()["misses"] == 1, autotune.stats()
    out = ops.conv2d(x, w, stride=1, backend="interpret")
    st = autotune.stats()
    assert st["hits"] >= 1 and st["enumerations"] == 1, st
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.conv2d_ref(x, w, 1)),
                               rtol=1e-4, atol=1e-2)
    # the chosen spec is conv-blocked and feasible for the realized kernel
    b_oh, bc, bk = spec.block
    assert b_oh in (1, 4, 8, 16) and bc % 128 == 0 and bk % 128 == 0
    assert cost_model.conv_vmem_footprint(CONV_PROBLEM, spec) \
        <= spec.vmem_budget


def test_conv_key_distinct_from_gemm_and_geometry():
    g = CONV_PROBLEM.as_gemm()
    gp = GemmProblem(m=g.m, k=g.k, n=g.n, in_dtype=g.in_dtype,
                     out_dtype=g.out_dtype)
    k_conv = autotune._key(CONV_PROBLEM, cost_model.V5E, "interpret")
    k_gemm = autotune._key(gp, cost_model.V5E, "interpret")
    assert k_conv != k_gemm
    # same implicit-GEMM view, different stride -> different key
    import dataclasses
    other = dataclasses.replace(CONV_PROBLEM, s=2)
    assert autotune._key(other, cost_model.V5E, "interpret") != k_conv


def test_conv2d_spec_fallback_when_image_exceeds_vmem():
    """A conv whose whole-resident image busts the analytic VMEM budget
    has no feasible conv candidate; ops.conv2d must fall back to the
    default dataflow + keyword blocking instead of raising (the seed
    behaviour for such shapes)."""
    big = ConvProblem(ih=224, iw=224, fh=3, fw=3, s=1, cin=128, cout=128,
                      in_dtype="float32", out_dtype="float32")
    assert explorer.enumerate_conv_candidates(big) == []
    x = jax.ShapeDtypeStruct((1, 224, 224, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((3, 3, 128, 128), jnp.float32)
    out = jax.eval_shape(
        lambda a, b: ops.conv2d(a, b, stride=1, backend="interpret"), x, w)
    assert out.shape == (1, 222, 222, 128)


def test_conv_explorer_prefers_os():
    """Paper headline: OS-anchored conv dataflows win the ranking."""
    ranked = explorer.explore_conv(CONV_PROBLEM, top=3)
    assert ranked and ranked[0].spec.anchor == OS
    assert all(c.feasible for c in ranked)


def test_hot_conv_problems_and_mixed_warm():
    from repro.configs.whisper_tiny import SMOKE
    from repro.models import lm

    probs = lm.hot_conv_problems(SMOKE, batch=2, seq=64)
    assert len(probs) == 2
    assert probs[0].cin == lm.AUDIO_N_MELS
    assert probs[1].s == 2 and probs[1].cout == SMOKE.d_model
    # dense configs have no conv frontend
    from repro.configs.qwen3_1_7b import CONFIG as QWEN
    assert lm.hot_conv_problems(QWEN, 2, 64) == []
    # gemm + conv problems warm through one call
    autotune.clear(disk=True)
    autotune.reset_stats()
    gemms = lm.hot_gemm_problems(SMOKE, 2, 64)
    specs = autotune.warm(gemms + probs, backend="interpret")
    assert len(specs) == len(gemms) + 2
    st = autotune.stats()
    assert st["misses"] == len(specs), st
