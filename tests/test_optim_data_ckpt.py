"""Optimizer, schedules, gradient compression, data pipeline, checkpointing."""
import os
import shutil

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.ckpt.checkpoint import Checkpointer
from repro.data.pipeline import SyntheticLMDataset
from repro.optim import AdamW, schedules
from repro.optim.compress import compressed_psum, quantize_grad


def test_adamw_converges_quadratic():
    opt = AdamW(lr_fn=lambda _: 0.1, weight_decay=0.0, clip_norm=None)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    loss = lambda p: jnp.sum((p["w"] - 1.0) ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0],
                               atol=1e-2)


def test_adamw_clips_gradients():
    opt = AdamW(lr_fn=lambda _: 0.1, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    g = {"w": jnp.asarray([100.0, 0.0, 0.0])}
    _, _, metrics = opt.update(g, state, params)
    assert metrics["grad_norm"] > 99.0


def test_bf16_moments_roundtrip():
    opt = AdamW(lr_fn=lambda _: 0.1, moment_dtype="bfloat16")
    params = {"w": jnp.ones(4)}
    state = opt.init(params)
    assert state.m["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones(4)}
    p2, s2, _ = opt.update(g, state, params)
    assert s2.m["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(p2["w"] < params["w"]))  # moved downhill


def test_wsd_schedule_phases():
    peak = 1.0
    lr_w = schedules.wsd(5, 10, 100, 20, peak)      # warmup
    lr_s = schedules.wsd(50, 10, 100, 20, peak)     # stable
    lr_d = schedules.wsd(125, 10, 100, 20, peak)    # decay
    assert float(lr_w) < peak
    assert float(lr_s) == pytest.approx(peak)
    assert float(lr_d) < peak


def test_cosine_schedule_monotone_decay():
    xs = [float(schedules.cosine(s, 10, 100, 1.0)) for s in range(10, 100, 5)]
    assert all(a >= b - 1e-6 for a, b in zip(xs, xs[1:]))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_quantize_grad_bounded_error(seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(64,)) * rng.uniform(0.1, 10))
    q, scale = quantize_grad(g)
    err = jnp.abs(q.astype(jnp.float32) * scale - g)
    assert float(err.max()) <= float(scale) / 2 + 1e-6


def test_compressed_psum_error_feedback_unbiased():
    """Over many steps, error feedback keeps the accumulated compressed
    sum close to the accumulated true sum (shard_map over 1 device)."""
    steps = 50
    rng = np.random.default_rng(0)
    grads = jnp.asarray(rng.normal(size=(steps, 32)), jnp.float32)

    def run(gs):
        def body(res, g):
            red, res = compressed_psum({"g": g}, "dp", {"g": res})
            return res["g"], red["g"]
        _, reds = jax.lax.scan(body, jnp.zeros((32,), jnp.float32), gs)
        return reds

    mesh = jax.make_mesh((1,), ("dp",))
    reds = jax.shard_map(
        run, mesh=mesh,
        in_specs=jax.sharding.PartitionSpec(),
        out_specs=jax.sharding.PartitionSpec(),
    )(grads)
    true_sum = np.asarray(grads.sum(0))
    comp_sum = np.asarray(reds.sum(0))
    # error feedback: cumulative bias stays within a few quantization steps
    scale = float(np.abs(np.asarray(grads)).max()) / 127.0
    assert np.abs(true_sum - comp_sum).max() < 4 * scale


def test_dataset_deterministic_and_stateless():
    ds1 = SyntheticLMDataset(vocab_size=100, seq_len=16, global_batch=4,
                             seed=7)
    ds2 = SyntheticLMDataset(vocab_size=100, seq_len=16, global_batch=4,
                             seed=7)
    b1, b2 = ds1.batch_np(12), ds2.batch_np(12)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds1.batch_np(13)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # targets are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])


def test_checkpoint_roundtrip_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    state = {
        "params": {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                   "nested": {"b": jnp.ones((4,), jnp.bfloat16)}},
    }
    for step in (1, 2, 3):
        ck.save(step, state, extras={"x": step}, blocking=True)
    assert ck.latest_step() == 3
    # keep=2 garbage collection
    dirs = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(dirs) == 2
    step, restored, extras = ck.restore(
        {"params": jax.eval_shape(lambda: state["params"])})
    assert step == 3 and extras["x"] == 3
    np.testing.assert_array_equal(np.asarray(restored["params"]["a"]),
                                  np.asarray(state["params"]["a"]))
    assert restored["params"]["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = {"params": {"w": jnp.ones((8, 8))}}
    ck.save(5, state, blocking=False)
    ck.wait()
    assert ck.latest_step() == 5


# -- checkpoint durability + failure surfacing (crash-drill satellites) ------
def _state():
    return {"params": {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}}


@pytest.fixture
def _arm(monkeypatch):
    """Arm a REPRO_FAULT_PLAN for the test and disarm after."""
    from repro.runtime import health

    def arm(plan):
        monkeypatch.setenv("REPRO_FAULT_PLAN", plan)
        health.reset_faults()
    yield arm
    monkeypatch.delenv("REPRO_FAULT_PLAN", raising=False)
    health.reset_faults()


def test_checkpoint_async_save_error_surfaced_on_wait(tmp_path, _arm):
    from repro.ckpt.checkpoint import CheckpointError
    _arm("ckpt.write:0:raise")
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(), blocking=False)   # daemon thread fails silently...
    with pytest.raises(CheckpointError):
        ck.wait()                          # ...until here
    st = ck.stats()
    assert st["save_errors"] == 1 and st["saves"] == 0
    # fault plan is hit 0 only: the retry lands and stats reflect it
    ck.save(2, _state(), blocking=True)
    assert ck.latest_step() == 2 and ck.stats()["saves"] == 1


def test_checkpoint_async_save_error_surfaced_on_next_save(tmp_path, _arm):
    from repro.ckpt.checkpoint import CheckpointError
    _arm("ckpt.write:0:raise")
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(), blocking=False)
    with pytest.raises(CheckpointError):
        ck.save(2, _state(), blocking=True)  # save() waits first -> raises
    ck.save(3, _state(), blocking=True)      # error consumed, not sticky
    assert ck.latest_step() == 3


def test_checkpoint_midwrite_fault_keeps_previous_step(tmp_path, _arm):
    """Kill/fault between payload-durable and publish: the previous step
    and LATEST stay intact, residue is swept by the next save."""
    from repro.ckpt.checkpoint import CheckpointError
    _arm("ckpt.write:1:raise")
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(), extras={"x": 1}, blocking=True)     # hit 0: clean
    with pytest.raises(CheckpointError):
        ck.save(2, _state(), extras={"x": 2}, blocking=True)  # hit 1: fault
    assert ck.latest_step() == 1
    step, _, extras = ck.restore(
        {"params": jax.eval_shape(lambda: _state()["params"])})
    assert step == 1 and extras["x"] == 1
    # the aborted write leaves step_*.tmp evidence; the next save's GC
    # sweeps it and publishing resumes normally
    assert any(d.endswith(".tmp") for d in os.listdir(tmp_path))
    ck.save(3, _state(), extras={"x": 3}, blocking=True)
    assert not any(d.endswith((".tmp", ".trash"))
                   for d in os.listdir(tmp_path))
    assert ck.latest_step() == 3


def test_checkpoint_resave_same_step_swaps_atomically(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(7, _state(), extras={"rev": 1}, blocking=True)
    ck.save(7, _state(), extras={"rev": 2}, blocking=True)
    assert ck.latest_step() == 7
    assert ck.manifest()["extras"]["rev"] == 2
    assert not any(d.endswith((".tmp", ".trash"))
                   for d in os.listdir(tmp_path))


def test_checkpoint_latest_fallback_when_pointer_dangles(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(), blocking=True)
    ck.save(2, _state(), blocking=True)
    with open(os.path.join(tmp_path, "LATEST"), "w") as f:
        f.write("step_00000099")        # kill inside the swap window
    assert ck.latest_step() == 2        # newest complete step wins
    os.remove(os.path.join(tmp_path, "step_00000002", "manifest.json"))
    assert ck.latest_step() == 1        # incomplete steps don't count
    assert ck.steps() == [1]
