"""Sub-byte packed weights (kernels/pack.py) and the shared quant util.

Pins the PR-9 contracts:

  * ``core.quant.symmetric_int8`` invariants (all-zero -> scale 1.0,
    round-trip bound) and the three former private copies delegating;
  * pack -> unpack losslessness on the int8 codes, outlier rows
    reconstructing exactly, traced fixed-capacity packing matching the
    concrete path;
  * ``ops.matmul_packed`` / ``ops.conv2d_packed`` BIT-exact against the
    dequantize-then-matmul oracles on every anchor, outliers exercised,
    one pallas_call per dispatch;
  * packed-byte cost accounting (wb4 <= 0.65x int8), ``wb`` autotune key
    segment + cache schema v6, explorer ranking packed problems through
    the generic registry;
  * int8-KV scale-shape validation in ``ops.attention``;
  * ``cfg.packed_weights`` model routing and Engine warm coverage.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from hypothesis import given, settings, strategies as st

from repro.core import autotune, cost_model, explorer, quant
from repro.core.dataflow import (
    ConvProblem, DataflowSpec, GemmProblem, IS, OS, WS,
)
from repro.kernels import ops, pack, ref

BITS = (4, 5)


def _mk_codes(rng, k, n, bits, n_outliers):
    """MSR-structured int8 codes: in-range rows + deliberate outliers."""
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    q = rng.integers(lo, hi + 1, size=(k, n)).astype(np.int8)
    rows = rng.choice(k, size=n_outliers, replace=False) if n_outliers else []
    for r in rows:
        q[r] = rng.integers(-120, 121, size=n).astype(np.int8)
    return jnp.asarray(q), np.asarray(rows)


def _mk_scale(rng, n):
    return jnp.asarray((rng.random((1, n)) + 0.5) / 127.0, jnp.float32)


# ---------------------------------------------------------------------------
# Shared symmetric int8 quant (core/quant.py).
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(min_value=-1e4, max_value=1e4), min_size=1,
                max_size=32))
def test_quant_roundtrip_bound(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, scale = quant.symmetric_int8(x)
    assert q.dtype == jnp.int8 and float(scale) > 0
    err = jnp.max(jnp.abs(x - q.astype(jnp.float32) * scale))
    assert float(err) <= float(scale) / 2 + 1e-6


def test_quant_zero_input_exact():
    for axis in (None, -1):
        q, scale = quant.symmetric_int8(jnp.zeros((3, 5)), axis=axis)
        assert not q.any()
        assert jnp.all(scale == 1.0)          # dequantization is exact
        assert jnp.all(quant.dequantize(q, scale) == 0.0)


def test_quant_single_source_of_truth():
    """The three former private copies all route through core.quant."""
    from repro.models import layers
    from repro.optim import compress

    x = jax.random.normal(jax.random.PRNGKey(0), (4, 4, 8), jnp.bfloat16)
    for got, want in (
        (ref.quantize_int8(x, axis=-1), quant.symmetric_int8(x, axis=-1)),
        (compress.quantize_grad(x), quant.symmetric_int8(x)),
        (layers._quantize_kv(x), quant.symmetric_int8(x, axis=-1)),
    ):
        assert jnp.array_equal(got[0], want[0])
        assert jnp.array_equal(got[1], want[1])


# ---------------------------------------------------------------------------
# Pack / unpack losslessness.
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=70),
       st.integers(min_value=1, max_value=12),
       st.sampled_from(BITS),
       st.integers(min_value=0, max_value=3))
def test_pack_unpack_lossless(k, n, bits, n_out):
    rng = np.random.default_rng(k * 1000 + n * 10 + bits + n_out)
    n_out = min(n_out, k)
    q, rows = _mk_codes(rng, k, n, bits, n_out)
    pw = pack.pack_int8(q, _mk_scale(rng, n), bits=bits)
    got, _ = pack.unpack_weights(pw)
    assert jnp.array_equal(got, q)            # exact, outliers included
    # the planes alone reconstruct the truncated codes
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    assert jnp.array_equal(pack.unpack_codes(pw),
                           jnp.clip(q, lo, hi).astype(jnp.int8))


def test_outlier_rows_reconstruct_exactly():
    rng = np.random.default_rng(7)
    q, rows = _mk_codes(rng, 64, 16, 4, 3)
    pw = pack.pack_int8(q, _mk_scale(rng, 16), bits=4)
    assert pw.outlier_idx.shape[0] >= len(rows)
    got, _ = pack.unpack_weights(pw)
    for r in rows:
        assert jnp.array_equal(got[r], q[r])
    # sentinel slots (idx == k_pad) never corrupt real rows
    assert jnp.all((pw.outlier_idx <= pw.k_pad))


def test_pack_roundtrip_quantization_bound():
    w = jax.random.normal(jax.random.PRNGKey(3), (40, 12))
    for bits in BITS:
        w_hat = ref.pack_roundtrip(w, bits=bits)
        pw = pack.pack_weights(w, bits=bits)
        err = jnp.abs(w - w_hat)
        assert float(jnp.max(err - pw.scale / 2)) <= 1e-6


def test_traced_fixed_capacity_matches_concrete():
    rng = np.random.default_rng(11)
    q, _ = _mk_codes(rng, 48, 8, 4, 2)
    scale = _mk_scale(rng, 8)
    cap = 4                                   # room beyond the 2 hot rows
    eager = pack.pack_int8(q, scale, bits=4, max_outliers=cap)
    traced = jax.jit(
        lambda qq, ss: pack.pack_int8(qq, ss, bits=4, max_outliers=cap)
    )(q, scale)
    assert jnp.array_equal(pack.unpack_weights(eager)[0],
                           pack.unpack_weights(traced)[0])
    # concrete overflow is a loud error, not silent truncation
    hot = jnp.full((48, 8), 100, jnp.int8)    # every row an outlier
    with pytest.raises(ValueError, match="outlier"):
        pack.pack_int8(hot, scale, bits=4, max_outliers=1)


def test_packed_weights_is_vmap_safe_pytree():
    def make(key):
        q = jax.random.randint(key, (32, 8), -8, 8, jnp.int32).astype(
            jnp.int8)
        return pack.pack_int8(q, jnp.full((1, 8), 0.01, jnp.float32),
                              bits=4, max_outliers=pack.outlier_capacity(32))

    stacked = jax.vmap(make)(jax.random.split(jax.random.PRNGKey(0), 3))
    assert stacked.codes.shape == (3, 4, 8)
    sliced = jax.tree.map(lambda a: a[1], stacked)
    assert sliced.codes.shape == (4, 8) and sliced.bits == 4


# ---------------------------------------------------------------------------
# Kernel bit-exactness vs the dequantize-then-matmul oracles.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("anchor", [OS, WS, IS])
def test_matmul_packed_bitexact(anchor, bits):
    rng = np.random.default_rng(42 + bits)
    m, k, n = 24, 96, 80
    q, _ = _mk_codes(rng, k, n, bits, 3)
    pw = pack.pack_int8(q, _mk_scale(rng, n), bits=bits)
    assert int(jnp.sum(pw.outlier_idx < pw.k_pad)) >= 3   # sidecar active
    aq = jnp.asarray(rng.integers(-127, 128, size=(m, k)), jnp.int8)
    a_scale = jnp.float32(0.013)
    want = ref.matmul_packed_ref(aq, pw, a_scale=a_scale)
    got = ops.matmul_packed(
        aq, pw, a_scale=a_scale,
        spec=DataflowSpec.basic(anchor, block=(32, 32, 32)),
        backend="interpret")
    assert jnp.array_equal(got, want)         # BIT-exact, not allclose


def test_matmul_packed_fused_epilogue():
    rng = np.random.default_rng(5)
    m, k, n = 16, 64, 48
    q, _ = _mk_codes(rng, k, n, 4, 2)
    pw = pack.pack_int8(q, _mk_scale(rng, n), bits=4)
    aq = jnp.asarray(rng.integers(-127, 128, size=(m, k)), jnp.int8)
    bias = jnp.asarray(rng.random(n), jnp.float32)
    resid = jnp.asarray(rng.random((m, n)), jnp.float32)
    kw = dict(a_scale=jnp.float32(0.02), bias=bias, residual=resid,
              activation="silu")
    want = ref.matmul_packed_ref(aq, pw, **kw)
    spec = DataflowSpec.basic(WS, block=(16, 32, 16))

    def call(x):
        return ops.matmul_packed_fused(x, pw, spec=spec,
                                       backend="interpret", **kw)

    assert jnp.allclose(call(aq), want, atol=1e-5)
    # one kernel dispatch: decompress + comp + epilogue all in-register
    from repro.core.jaxpr_utils import count_pallas_calls
    assert count_pallas_calls(jax.make_jaxpr(call)(aq).jaxpr) == 1


def test_matmul_packed_validation():
    rng = np.random.default_rng(6)
    q, _ = _mk_codes(rng, 32, 8, 4, 0)
    pw = pack.pack_int8(q, _mk_scale(rng, 8), bits=4)
    aq = jnp.zeros((4, 16), jnp.int8)         # K mismatch
    with pytest.raises(ValueError, match="K"):
        ops.matmul_packed(aq, pw, backend="interpret")
    from repro.kernels import matmul_df
    with pytest.raises(ValueError, match="fused epilogue"):
        matmul_df.matmul_df(
            jnp.zeros((32, 32), jnp.int8), pw.codes,
            DataflowSpec.basic(OS, block=(32, 32, 8)),
            weight_bits=4, comp=jnp.zeros((32, 8), jnp.int32))


@pytest.mark.parametrize("anchor,bits",
                         [(OS, 4), (WS, 4), (IS, 4), (WS, 5)])
def test_conv2d_packed_bitexact(anchor, bits):
    rng = np.random.default_rng(13 + bits)
    n_b, ih, iw, cin, cout, fh = 1, 6, 6, 32, 16, 2
    w = rng.normal(size=(fh, fh, cin, cout)).astype(np.float32)
    w[0, 1, 3, :] *= 30.0                     # force outlier rows
    pcw = pack.pack_conv_weights(jnp.asarray(w), bits=bits)
    assert int(jnp.sum(pcw.outlier_idx
                       < pcw.fh * pcw.fw * pcw.cin_pad)) >= 1
    xq = jnp.asarray(rng.integers(-127, 128, size=(n_b, ih, iw, cin)),
                     jnp.int8)
    x_scale = jnp.float32(0.02)
    want = ref.conv2d_packed_ref(xq, pcw, 1, x_scale=x_scale)
    got = ops.conv2d_packed(xq, pcw, stride=1, x_scale=x_scale,
                            spec=DataflowSpec.basic(anchor),
                            backend="interpret")
    assert jnp.array_equal(got, want)


# ---------------------------------------------------------------------------
# Cost model, explorer and autotune keys.
# ---------------------------------------------------------------------------
def test_packed_weight_bytes_formula():
    k, n = 2048, 2048
    nib = -(-k // 8) * n * 4
    hi = -(-k // 32) * n * 4
    side = -(-3 * k // 256) * (4 + n * 4)
    assert cost_model.packed_weight_bytes(k, n, 4) == nib + side
    assert cost_model.packed_weight_bytes(k, n, 5) == nib + hi + side
    assert cost_model.packed_outlier_capacity(k) == pack.outlier_capacity(k)


def test_packed_traffic_under_int8_cap():
    p8 = GemmProblem(m=256, k=2048, n=2048, in_dtype="int8",
                     out_dtype="int32")
    p4 = dataclasses.replace(p8, weight_bits=4)
    b8, b4 = cost_model.weight_stream_bytes(p8), \
        cost_model.weight_stream_bytes(p4)
    assert b8 == 2048 * 2048                  # plain: k * n * itemsize
    assert b4 / b8 <= 0.65                    # the CI-gated claim
    for anchor in (OS, WS, IS):
        spec = DataflowSpec.basic(anchor)
        t8 = cost_model.gemm_traffic(p8, spec)
        t4 = cost_model.gemm_traffic(p4, spec)
        assert t4.total < t8.total            # packed strictly cheaper
        assert t4.feasible


def test_conv_problem_carries_weight_bits():
    cv = ConvProblem(ih=14, iw=14, fh=3, fw=3, s=1, cin=128, cout=128,
                     weight_bits=5)
    g = cv.as_gemm()
    assert g.weight_bits == 5
    assert cost_model.weight_stream_bytes(g) \
        == cost_model.packed_weight_bytes(g.k, g.n, 5)
    with pytest.raises(ValueError, match="weight_bits"):
        GemmProblem(m=8, k=8, n=8, weight_bits=3)


def test_autotune_keys_versioned_with_packing_segment():
    assert autotune.CACHE_VERSION == 6
    p8 = GemmProblem(m=256, k=512, n=512, in_dtype="int8",
                     out_dtype="float32", acc_dtype="int32")
    p4 = dataclasses.replace(p8, weight_bits=4)
    hw = cost_model.V5E
    k8 = autotune._key(p8, hw, "interpret")
    k4 = autotune._key(p4, hw, "interpret")
    assert k8 != k4
    assert k8.startswith("v6|gemm|") and "|wb-|" in k8
    assert "|wb4|" in k4
    cv = ConvProblem(ih=8, iw=8, fh=3, fw=3, s=1, cin=128, cout=128,
                     weight_bits=4)
    assert "|wb4|" in autotune._key(cv, hw, "interpret")


def test_explorer_ranks_packed_through_generic_registry():
    """Packed problems flow through the same ProblemRegistration rows as
    plain ones — no per-kind branches — and the ranking reflects the
    packed weight stream (WS traffic strictly drops)."""
    p4 = GemmProblem(m=256, k=1024, n=1024, in_dtype="int8",
                     out_dtype="float32", acc_dtype="int32", weight_bits=4)
    spec = explorer.best_spec(p4)
    assert isinstance(spec, DataflowSpec)
    ranked = explorer.explore(p4, top=3)
    assert ranked and all(c.feasible for c in ranked)
    assert all(
        cost_model.gemm_traffic(p4, c.spec).feasible for c in ranked)


# ---------------------------------------------------------------------------
# int8-KV scale shape validation (ops.attention).
# ---------------------------------------------------------------------------
def test_attention_rejects_malformed_kv_scales():
    b, h, s, d = 1, 2, 8, 16
    q = jnp.zeros((b, h, s, d), jnp.float32)
    kq = jnp.zeros((b, h, s, d), jnp.int8)
    good = jnp.ones((b, h, s, 1), jnp.float32)
    with pytest.raises(ValueError, match="per-position"):
        ops.attention(q, kq, kq, k_scale=None, v_scale=None)
    for bad in (jnp.ones((b, h, s), jnp.float32),      # squeezed lane
                jnp.ones((), jnp.float32),             # per-tensor
                jnp.ones((b, h, 1, 1), jnp.float32)):  # per-head
        with pytest.raises(ValueError, match="trailing"):
            ops.attention(q, kq, kq, k_scale=bad, v_scale=good)
        with pytest.raises(ValueError, match="trailing"):
            ops.attention(q, kq, kq, k_scale=good, v_scale=bad)
    # well-shaped scales pass validation and run
    out = ops.attention(q, kq, kq, k_scale=good, v_scale=good,
                        backend="xla")
    assert out.shape == q.shape


# ---------------------------------------------------------------------------
# Model routing + Engine warm coverage.
# ---------------------------------------------------------------------------
def _packed_cfg(**kw):
    from repro.configs.base import ArchConfig

    return ArchConfig(name="packed-smoke", family="dense", n_layers=2,
                      d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                      vocab_size=256, d_head=32, packed_weights=True, **kw)


def test_packed_mlp_routes_through_model():
    from repro.models import layers, lm

    cfg = _packed_cfg()
    lp = lm._init_layer(jax.random.PRNGKey(0), cfg)
    assert isinstance(lp["mlp"]["w1"], pack.PackedWeights)
    assert lp["mlp"]["w2"].bits == 4
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 64), jnp.bfloat16)
    out = layers.mlp_apply(lp["mlp"], x, cfg)
    want = layers.packed_mlp_apply(lp["mlp"], x).astype(x.dtype)
    assert out.dtype == x.dtype
    assert jnp.array_equal(out, want)
    # stacked per-layer params survive vmap init + scan-style slicing
    params = lm.init_model(cfg, jax.random.PRNGKey(2))
    assert params["layers"]["mlp"]["w1"].codes.shape[0] == cfg.n_layers
    logits = lm.forward(params, jnp.zeros((1, 4), jnp.int32), cfg)
    logits = logits[0] if isinstance(logits, tuple) else logits
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_hot_gemm_problems_packed_rows():
    from repro.models import lm

    cfg = _packed_cfg()
    probs = lm.hot_gemm_problems(cfg, 2, 16)
    assert len(probs) == 2
    assert all(p.weight_bits == 4 and p.in_dtype == "int8"
               and p.acc_dtype == "int32" for p in probs)
    autotune.clear(disk=True)
    autotune.reset_stats()
    specs = autotune.warm(probs, backend="interpret")
    assert len(specs) == 2
    assert autotune.stats()["misses"] == 2


def test_engine_prewarms_packed_decode_shapes(monkeypatch):
    from repro.models import lm
    from repro.serve.engine import Engine

    cfg = _packed_cfg(use_pallas_kernels=True)
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=32)
    captured = []
    monkeypatch.setattr(autotune, "warm",
                        lambda probs, **kw: captured.extend(probs) or [])
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    eng._warm_autotune(2, 16)
    packed = [p for p in captured
              if getattr(p, "weight_bits", None) == cfg.packed_weight_bits]
    # prefill (t = 2*16) AND the decode step (t = 2*1) are both warmed
    ms = {p.m for p in packed}
    assert {32, 2} <= ms
    assert {(p.k, p.n) for p in packed} \
        == {(cfg.d_model, cfg.d_ff), (cfg.d_ff, cfg.d_model)}
