"""End-to-end network optimizer (paper §IV-B/C pipeline)."""
from repro.core import network
from repro.core.dataflow import OS


def test_resnet18_plan_all_os_anchored():
    plan = network.optimize_network(network.resnet18_int8())
    assert len(plan.layers) == 16
    assert plan.total_seconds > 0
    # paper Alg. 8: the explorer lands on OS-anchored everywhere
    for lp in plan.layers:
        assert lp.spec.anchor == OS, lp.spec.name


def test_mobilenet_and_shufflenet_blocks_plan():
    net = (network.mobilenet_block_int8(56, 64, 128)
           + network.shufflenet_stage_int8(28, 128, groups=4, rep=2))
    plan = network.optimize_network(net)
    assert len(plan.layers) == len(net)
    desc = plan.describe()
    assert "dw" in desc and "g4" in desc


def test_depthwise_grouping_changes_costs():
    dense = network.ConvLayerSpec(28, 28, 3, 3, 1, 128, 128, groups=1)
    dw = network.ConvLayerSpec(28, 28, 3, 3, 1, 128, 128, groups=128)
    c_dense = network.plan_layer(dense)[0][1]
    c_dw = network.plan_layer(dw)[0][1]
    assert c_dw < c_dense  # depthwise does ~1/128 of the MACs


def test_flexible_writes_never_worse():
    net = network.resnet18_int8()[:6]
    flex = network.optimize_network(net, flexible_writes=True)
    rigid = network.optimize_network(net, flexible_writes=False,
                                     layouts=("NCHWc128", "NHWC"))
    assert flex.total_seconds <= rigid.total_seconds + 1e-9
