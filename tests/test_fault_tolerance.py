"""Fault-drill integration suite: the tested invariant is that for
every registered injection site, a serve run with that site faulted
once completes all admitted requests DONE with greedy token outputs
bit-identical to the fault-free run — degradation, not failure — and
that the health ledger records exactly the injected demotions/retries.
Also: request lifecycle/admission, deadline eviction, retry
exhaustion, and autotune-cache corruption recovery.

CI runs this file as the ``fault-drill`` job."""
import dataclasses
import glob
import json
import os

import numpy as np
import pytest

import jax

from repro import configs
from repro.core import autotune, cost_model
from repro.core.dataflow import GemmProblem
from repro.models import lm
from repro.runtime import health
from repro.serve.engine import (AdmissionError, Engine, RequestState,
                                StepFailed)

CFG = configs.get_smoke("qwen3-1.7b")
MAX_LEN = 48
NEW_TOKENS = 4


@pytest.fixture(autouse=True)
def _clean_fault_env():
    keys = ("REPRO_FAULT_PLAN", "REPRO_FAIL_AT_STEP", "REPRO_FAULT_HANG_S")
    saved = {k: os.environ.get(k) for k in keys}
    for k in keys:
        os.environ.pop(k, None)
    health.reset_faults()
    yield
    for k, v in saved.items():
        if v is None:
            os.environ.pop(k, None)
        else:
            os.environ[k] = v
    health.reset_faults()


@pytest.fixture(scope="module")
def served():
    params = lm.init_model(CFG, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, CFG.vocab_size, (2, 8)).astype(np.int32)
    return params, prompts


def _serve(params, prompts, plan=None, policy=None, deadline_s=None,
           new_tokens=NEW_TOKENS):
    """Fresh engine (fresh jit traces + hit counters) under ``plan``."""
    if plan is None:
        os.environ.pop("REPRO_FAULT_PLAN", None)
    else:
        os.environ["REPRO_FAULT_PLAN"] = plan
    health.reset_faults()
    eng = Engine(CFG, params, max_len=MAX_LEN, policy=policy)
    reqs = [eng.submit(p, new_tokens, deadline_s=deadline_s)
            for p in prompts]
    eng.serve(reqs)
    toks = [list(r.out_tokens) for r in reqs]
    return eng, reqs, toks


# ---------------------------------------------------------------------------
# The fault-drill invariant, over every registered site.
# ---------------------------------------------------------------------------
def test_fault_drill_every_site_degrades_not_fails(served):
    params, prompts = served
    _, base_reqs, base = _serve(params, prompts)
    assert all(r.state == RequestState.DONE for r in base_reqs)

    failures = []
    for site in health.INJECTION_SITES:
        # nan faults only matter where float outputs flow through the
        # serve path; elsewhere one raise-kind drill per site suffices
        kinds = (("raise", "nan")
                 if site.startswith(("serve.", "layers.")) else ("raise",))
        for kind in kinds:
            plan = f"{site}:0:{kind}"
            eng, reqs, toks = _serve(params, prompts, plan=plan)
            states = [r.state.value for r in reqs]
            fired = [(f.site, f.kind) for f in health.fault_log()]
            # ledger records exactly the injected demotions/retries:
            # one demotion + one retry per fired fault that reached the
            # serve path, none otherwise
            ev = eng.monitor.report()["events"]
            expected = len(fired)
            if (toks != base
                    or any(s != "done" for s in states)
                    or ev.get("demotion", 0) != expected
                    or ev.get("retry", 0) != expected):
                failures.append((plan, states, toks, fired, ev))
    assert not failures, failures


def test_hang_fault_is_straggle_not_crash(served):
    params, prompts = served
    os.environ["REPRO_FAULT_HANG_S"] = "0.05"
    _, base_reqs, base = _serve(params, prompts, new_tokens=12)
    eng, reqs, toks = _serve(params, prompts,
                             plan="serve.decode_step:8:hang",
                             new_tokens=12)
    assert toks == base
    assert all(r.state == RequestState.DONE for r in reqs)
    assert [(f.site, f.kind) for f in health.fault_log()] == [
        ("serve.decode_step", "hang-timeout")]
    # no demotion, no retry — a hang is a straggler, not a failure
    assert eng.monitor.report()["events"].get("demotion", 0) == 0


def test_retries_exhausted_marks_requests_failed(served):
    params, prompts = served
    policy = health.DegradationPolicy(max_retries=2, backoff_base_s=0.001)
    eng, reqs, _ = _serve(params, prompts,
                          plan="serve.decode_step:*:raise", policy=policy)
    assert all(r.state == RequestState.FAILED for r in reqs)
    assert all("injected failure" in r.error for r in reqs)
    st = eng.stats()
    assert st["failed"] == 2 and st["retries"] == 2


def test_generate_raises_on_failed_batch(served):
    params, prompts = served
    os.environ["REPRO_FAULT_PLAN"] = "serve.prefill:*:raise"
    eng = Engine(CFG, params, max_len=MAX_LEN,
                 policy=health.DegradationPolicy(backoff_base_s=0.001))
    with pytest.raises(StepFailed):
        eng.generate(prompts, NEW_TOKENS)


def test_degradation_cooldown_reprobes_primary(served):
    params, prompts = served
    policy = health.DegradationPolicy(cooldown_steps=2,
                                      backoff_base_s=0.001)
    eng, reqs, toks = _serve(params, prompts,
                             plan="serve.decode_step:1:raise",
                             policy=policy, new_tokens=8)
    _, _, base = _serve(params, prompts, new_tokens=8)
    assert toks == base
    assert all(r.state == RequestState.DONE for r in reqs)
    # demoted at decode step 2, degraded through cooldown, then a
    # healthy re-probe promotes back to the primary path
    assert policy.probes >= 1 and not policy.demoted
    kinds = [e.kind for e in eng.monitor.events]
    assert "probe" in kinds
    assert eng.stats()["degraded_steps"] >= 1


# ---------------------------------------------------------------------------
# Request lifecycle: validation, admission, deadlines, budgets.
# ---------------------------------------------------------------------------
def test_submit_validation_errors(served):
    params, _ = served
    eng = Engine(CFG, params, max_len=MAX_LEN)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.array([], np.int32), 4)
    with pytest.raises(ValueError, match="leaves no decode room"):
        eng.submit(np.zeros(MAX_LEN, np.int32), 4)
    with pytest.raises(ValueError, match="dtype must be integer"):
        eng.submit(np.ones(8, np.float32), 4)
    with pytest.raises(ValueError, match="rank-1"):
        eng.submit(np.zeros((2, 8), np.int32), 4)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(np.zeros(8, np.int32), 0)
    st = eng.stats()
    assert st["rejected"] == 5 and st["admitted"] == 0
    assert st["health"]["events"]["admission-reject"] == 5


def test_vmem_admission_control(served):
    params, prompts = served
    tiny = dataclasses.replace(cost_model.V5E, vmem_bytes=1024,
                               name="tiny-vmem")
    eng = Engine(CFG, params, max_len=MAX_LEN, hw=tiny)
    with pytest.raises(AdmissionError, match="VMEM-feasible"):
        eng.submit(prompts[0], 4)
    # AdmissionError is a ValueError: callers can catch either
    assert issubclass(AdmissionError, ValueError)
    eng2 = Engine(CFG, params, max_len=MAX_LEN)
    req = eng2.submit(prompts[0], 4)
    assert req.state == RequestState.QUEUED


def test_budget_clamped_to_cache_capacity(served):
    params, prompts = served
    eng = Engine(CFG, params, max_len=MAX_LEN)
    req = eng.submit(prompts[0], 10_000)
    assert req.max_new_tokens == MAX_LEN - len(prompts[0])
    assert eng.stats()["budget_clamped"] == 1
    assert eng.monitor.events_of("backpressure")


def test_deadline_evicts_instead_of_stalling(served):
    params, prompts = served
    eng, reqs, _ = _serve(params, prompts, deadline_s=0.0)
    assert all(r.state == RequestState.EVICTED for r in reqs)
    assert all("deadline" in r.error for r in reqs)
    st = eng.stats()
    assert st["evicted"] == 2 and st["completed"] == 0
    assert eng.monitor.events_of("evicted")


def test_mixed_length_batch_served_continuously(served):
    # PR 8: mixed prompt lengths no longer raise — serve() routes the
    # ragged batch through the continuous scheduler (per-row banding)
    params, prompts = served
    eng = Engine(CFG, params, max_len=MAX_LEN)
    r1 = eng.submit(np.zeros(8, np.int32), 2)
    r2 = eng.submit(np.zeros(9, np.int32), 2)
    eng.serve([r1, r2])
    assert r1.state == RequestState.DONE and r2.state == RequestState.DONE
    assert len(r1.out_tokens) == 2 and len(r2.out_tokens) == 2
    assert eng.scheduler_report()["max_batch"] >= 1


# ---------------------------------------------------------------------------
# Autotune-cache corruption recovery.
# ---------------------------------------------------------------------------
@pytest.fixture()
def cache_file(tmp_path, monkeypatch):
    path = str(tmp_path / "autotune.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    autotune.clear()
    autotune.reset_stats()
    yield path
    autotune.clear()
    autotune.reset_stats()


def _seed_cache(n=2):
    probs = [GemmProblem(m=128 * (i + 1), k=128, n=128) for i in range(n)]
    specs = [autotune.best_spec(p) for p in probs]
    autotune._save_disk()
    return probs, specs


def test_garbage_cache_file_quarantined_not_fatal(cache_file):
    with open(cache_file, "w") as f:
        f.write("{ truncated garbage !!!")
    autotune._load_disk()           # must not raise
    st = autotune.stats()
    assert st["files_quarantined"] == 1
    assert glob.glob(cache_file + ".corrupt-*")
    assert not os.path.exists(cache_file)
    # serving-path lookups still work after quarantine
    assert autotune.best_spec(GemmProblem(m=128, k=128, n=128)) is not None


def test_partially_corrupt_cache_keeps_good_entries(cache_file):
    probs, specs = _seed_cache(2)
    with open(cache_file) as f:
        d = json.load(f)
    keys = sorted(d["entries"])
    d["entries"][keys[0]] = {"spec": "not-a-dict", "sum": 0}
    with open(cache_file, "w") as f:
        json.dump(d, f)
    autotune.clear()
    autotune.reset_stats()
    autotune._load_disk()
    st = autotune.stats()
    assert st["entries_loaded"] == 1
    assert st["entries_skipped"] == 1
    # the surviving entry round-trips to the same spec
    loaded = [autotune.best_spec(p) for p in probs]
    assert specs[0] in loaded or specs[1] in loaded


def test_checksum_mismatch_skipped(cache_file):
    _seed_cache(1)
    with open(cache_file) as f:
        d = json.load(f)
    (k0,) = d["entries"]
    d["entries"][k0]["sum"] = 123456789
    with open(cache_file, "w") as f:
        json.dump(d, f)
    autotune.clear()
    autotune.reset_stats()
    autotune._load_disk()
    st = autotune.stats()
    assert st["entries_loaded"] == 0 and st["entries_skipped"] == 1


def test_midwrite_kill_leaves_original_intact(cache_file):
    _seed_cache(1)
    before = open(cache_file).read()
    os.environ["REPRO_FAULT_PLAN"] = "autotune.save:0:raise"
    health.reset_faults()
    autotune._save_disk()           # injected kill; must not raise
    assert open(cache_file).read() == before
    assert autotune.stats()["save_errors"] == 1
    assert not glob.glob(os.path.join(os.path.dirname(cache_file), "*.tmp"))
    os.environ.pop("REPRO_FAULT_PLAN")
    # next save (fault disarmed) goes through atomically
    autotune.best_spec(GemmProblem(m=384, k=128, n=128))
    autotune._save_disk()
    with open(cache_file) as f:
        assert len(json.load(f)["entries"]) == 2


def test_load_fault_degrades_to_empty_cache(cache_file):
    _seed_cache(1)
    os.environ["REPRO_FAULT_PLAN"] = "autotune.load:0:raise"
    health.reset_faults()
    autotune.clear()
    autotune.reset_stats()
    autotune._load_disk()           # must not raise
    st = autotune.stats()
    assert st["load_errors"] == 1 and st["entries_loaded"] == 0
    # a failed load latches (no per-lookup retries against a broken
    # disk); the file is untouched, so clear() + reload recovers it
    os.environ.pop("REPRO_FAULT_PLAN")
    autotune.clear()
    autotune.reset_stats()
    autotune._load_disk()
    assert autotune.stats()["entries_loaded"] == 1
