"""Memory-pressure drills (PR 10): spill, preempt, backpressure.

The page pool is the continuous path's real decode datapath, so a pool
sized to roughly *half* the batch's aggregate KV working set forces the
pressure ladder — watermark admission deferral, host spill of cold
requests, preemption with deterministic recompute — and the contract
is that none of it changes a single emitted token: the constrained run
must match the unconstrained run bit-for-bit with zero FAILED requests.

The subprocess drill SIGKILLs the engine *mid-spill* (the
``pool.spill`` kill site) and asserts the PR-7 journal recovers every
request with nothing lost and nothing duplicated — spilling is
journal-invisible by design, so cold replay re-prefills and never needs
the half-written host buffers.

Run standalone (the pressure-drill CI job):

    PYTHONPATH=src python -m pytest -x -q tests/test_pressure.py
"""
import json
import os
import subprocess
import sys
import textwrap
import time
import types

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import configs
from repro.models import lm
from repro.runtime import health
from repro.serve.engine import Engine, RequestState
from repro.serve.journal import RequestJournal
from repro.serve.paged_cache import PagedKVCache, pages_for
from repro.serve.scheduler import ContinuousScheduler, SchedulerConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CFG = configs.get_smoke("qwen3-1.7b")
MAX_LEN = 48
NEW_TOKENS = 20                 # long decodes: rows grow while coresident
LENS = [7, 12, 2, 23]
PAGE = 8
# aggregate working set: pages_for(len + NEW_TOKENS, PAGE) per request
# = 4 + 4 + 3 + 6 = 17 pages; the constrained pool holds ~a third, so
# decode-time page growth must collide while requests are coresident —
# watermark deferral alone cannot serve it, the ladder has to fire.
# 6 pages = the largest single reach: every request is individually
# feasible (anything smaller is rejected rather than livelocked)
TINY_POOL = 6
BIG_POOL = 24                   # > the full working set: no pressure


@pytest.fixture(scope="module")
def eng():
    params = lm.init_model(CFG, jax.random.PRNGKey(0))
    return Engine(CFG, params, max_len=MAX_LEN)


def _prompts():
    rng = np.random.default_rng(0)
    return [rng.integers(0, CFG.vocab_size, (n,)).astype(np.int32)
            for n in LENS]


def _drain(eng, n_pages, prompts=None, new_tokens=NEW_TOKENS, **sckw):
    reqs = [eng.submit(p, new_tokens) for p in prompts or _prompts()]
    sched = ContinuousScheduler(eng, SchedulerConfig(
        max_batch=4, page_size=PAGE, n_pages=n_pages, **sckw))
    for r in reqs:
        sched.enqueue(r)
    sched.drain()
    eng._check_replay(reqs)
    return reqs, sched


# ---------------------------------------------------------------------------
# The in-process pressure drill: half the working set, identical tokens.
# ---------------------------------------------------------------------------
def test_pressure_drill_bit_identical_tokens(eng):
    base_reqs, base_sched = _drain(eng, BIG_POOL)
    assert all(r.state == RequestState.DONE for r in base_reqs)
    assert base_sched.use_paged           # the pool is the datapath

    before = dict(eng._counters)
    tiny_reqs, tiny_sched = _drain(eng, TINY_POOL)
    delta = {k: eng._counters[k] - before[k] for k in eng._counters}

    assert all(r.state == RequestState.DONE for r in tiny_reqs), [
        (r.rid, r.state, r.error) for r in tiny_reqs]
    assert delta["failed"] == 0
    for b, t in zip(base_reqs, tiny_reqs):
        assert t.out_tokens == b.out_tokens, (t.rid, t.out_tokens,
                                              b.out_tokens)
    # half the working set cannot be served without the ladder firing
    assert delta["spills"] + delta["preemptions"] > 0, delta
    assert delta["replay_divergence"] == 0, delta
    rep = tiny_sched.report()
    assert rep["paged_decode"] is True
    for key in ("occupancy", "above_high", "below_low", "spills"):
        assert key in rep["pages"], rep


def test_stats_surface_pressure_counters(eng):
    stats = eng.stats()
    for key in ("spills", "spilled_pages", "unspills", "preemptions",
                "backpressure"):
        assert key in stats, sorted(stats)


# ---------------------------------------------------------------------------
# Watermark backpressure: queued-with-reason, never silent.
# ---------------------------------------------------------------------------
def test_watermark_defers_admission_with_reason(eng):
    rng = np.random.default_rng(1)
    big = rng.integers(0, CFG.vocab_size, (30,)).astype(np.int32)
    small = rng.integers(0, CFG.vocab_size, (4,)).astype(np.int32)
    r1 = eng.submit(big, 10)              # reach 40 -> all 5 pages
    r2 = eng.submit(small, 2)
    before = eng._counters["backpressure"]
    sched = ContinuousScheduler(eng, SchedulerConfig(
        max_batch=4, page_size=PAGE, n_pages=5))
    sched.enqueue(r1)
    for _ in range(6):                    # decode until growth fills pool
        sched.step()
        if sched.paged.above_high():
            break
    assert sched.paged.above_high()
    assert r1.state == RequestState.DECODING
    sched.enqueue(r2)
    sched.step()
    assert r2.state == RequestState.QUEUED
    assert r2.queue_reason is not None
    assert "watermark" in r2.queue_reason
    assert eng._counters["backpressure"] == before + 1
    sched.drain()                         # r1 finishes -> pages free -> r2
    assert r1.state == RequestState.DONE
    assert r2.state == RequestState.DONE
    assert r2.queue_reason is None        # cleared at admission


def test_oversized_prompt_fails_loudly_when_pool_is_empty(eng):
    rng = np.random.default_rng(2)
    big = rng.integers(0, CFG.vocab_size, (40,)).astype(np.int32)
    req = eng.submit(big, 2)
    sched = ContinuousScheduler(eng, SchedulerConfig(
        max_batch=4, page_size=PAGE, n_pages=2))
    sched.enqueue(req)
    sched.drain()
    assert req.state == RequestState.FAILED
    assert "page pool cannot hold" in req.error


# ---------------------------------------------------------------------------
# Spill tier unit: bit-exact round trip, shared pages pinned.
# ---------------------------------------------------------------------------
def _mk_pool(n_pages=8, ps=4):
    cfg = types.SimpleNamespace(n_layers=2, n_kv_heads=2, d_head=4,
                                kv_cache_dtype="auto")
    return PagedKVCache(cfg, n_pages, ps, dtype="float32")


def test_spill_unspill_round_trip_bit_exact():
    pool = _mk_pool()
    pages = pool.alloc(3)
    rng = np.random.default_rng(3)
    payload_k = rng.standard_normal((2, 2, 3, 4, 4)).astype(np.float32)
    payload_v = rng.standard_normal((2, 2, 3, 4, 4)).astype(np.float32)
    import jax.numpy as jnp
    idx = jnp.asarray(pages, jnp.int32)
    pool.k_pages = pool.k_pages.at[:, :, idx].set(payload_k)
    pool.v_pages = pool.v_pages.at[:, :, idx].set(payload_v)
    pool.refs[pages[1]] += 1              # pages[1] shared with another req

    free_before = pool.free_pages
    entries = pool.spill(pages)
    assert [e[0] for e in entries] == ["host", "resident", "host"]
    assert entries[1][1] == pages[1]      # pinned in place
    assert pool.refs[pages[1]] == 2       # the spiller keeps its ref
    assert pool.free_pages == free_before + 2
    assert pool.stats["spilled_pages"] == 2

    back = pool.unspill(entries)
    assert back is not None and len(back) == 3
    assert back[1] == pages[1]
    got_k = np.asarray(pool.k_pages[:, :, jnp.asarray(back, jnp.int32)])
    got_v = np.asarray(pool.v_pages[:, :, jnp.asarray(back, jnp.int32)])
    np.testing.assert_array_equal(got_k[:, :, [0, 2]],
                                  payload_k[:, :, [0, 2]])
    np.testing.assert_array_equal(got_v[:, :, [0, 2]],
                                  payload_v[:, :, [0, 2]])


def test_unspill_returns_none_when_pool_full_entries_untouched():
    pool = _mk_pool(n_pages=4)
    pages = pool.alloc(2)
    entries = pool.spill(pages)
    pool.alloc(4)                         # exhaust the pool
    assert pool.unspill(entries) is None
    assert len(entries) == 2              # retryable later


# ---------------------------------------------------------------------------
# Refcount underflow: counted, fatal under REPRO_STRICT_POOL=1.
# ---------------------------------------------------------------------------
def test_release_underflow_counted_not_fatal_by_default(monkeypatch):
    monkeypatch.delenv("REPRO_STRICT_POOL", raising=False)
    pool = _mk_pool()
    pages = pool.alloc(1)
    pool.release(pages)
    pool.release(pages)                   # double free
    assert pool.stats["ref_underflows"] == 1
    assert pool.free_pages == pool.n_pages


def test_release_underflow_raises_under_strict_pool(monkeypatch):
    monkeypatch.setenv("REPRO_STRICT_POOL", "1")
    pool = _mk_pool()
    pages = pool.alloc(1)
    pool.release(pages)
    with pytest.raises(RuntimeError, match="double free"):
        pool.release(pages)


def test_pool_alloc_fault_site_is_a_simulated_oom(monkeypatch):
    monkeypatch.setenv("REPRO_FAULT_PLAN", "pool.alloc:0:raise")
    health.reset_faults()
    pool = _mk_pool()
    assert pool.alloc(1) is None          # injected OOM, absorbed
    assert pool.stats["oom_rejects"] == 1
    assert pool.alloc(1) is not None      # next hit is clean


# ---------------------------------------------------------------------------
# Property: the pool conserves pages under any op sequence.
# ---------------------------------------------------------------------------
@settings(max_examples=15)
@given(st.lists(st.integers(0, 3), min_size=1, max_size=50))
def test_pool_conserves_pages_under_any_op_sequence(ops):
    import jax.numpy as jnp
    pool = _mk_pool(n_pages=8, ps=4)
    ps = pool.page_size
    prompt = list(range(2 * ps + 1))      # 2 full pages + partial tail
    k_row = jnp.zeros((2, 2, len(prompt), 4))
    v_row = jnp.zeros((2, 2, len(prompt), 4))
    holders = []                          # page lists we own one ref on
    for op in ops:
        if op == 0:
            got = pool.alloc(1)
            if got is not None:
                holders.append(got)
        elif op == 1:
            if holders:
                pool.release(holders.pop(0))
        elif op == 2:
            reuse, covered = pool.lookup_prefix(prompt)
            new = pool.alloc(pages_for(len(prompt), ps) - len(reuse))
            if new is None:
                pool.release(reuse)
            else:
                pages = reuse + new
                pool.store(prompt, pages, covered, k_row, v_row)
                holders.append(pages)
        elif op == 3:
            reuse, _ = pool.lookup_prefix(prompt)
            if reuse:
                holders.append(reuse)
        # invariant: free + live == total, after every single op
        live = int(np.sum(pool.refs > 0))
        assert pool.free_pages + live == pool.n_pages
        # prefix chain only references live pages, bijectively
        for pid, key in pool._page_key.items():
            assert pool.refs[pid] > 0
            assert pool._prefix.get(key) == pid
        assert len(pool._prefix) == len(pool._page_key)
    assert pool.stats["ref_underflows"] == 0


# ---------------------------------------------------------------------------
# Satellites: chunked-prefill deadlines, drain stall.
# ---------------------------------------------------------------------------
def test_chunked_prefill_checks_deadline_at_chunk_boundary(eng):
    rng = np.random.default_rng(4)
    long = rng.integers(0, CFG.vocab_size, (23,)).astype(np.int32)
    req = eng.submit(long, 2, deadline_s=0.0)
    before = eng._counters["evicted"]
    sched = ContinuousScheduler(eng, SchedulerConfig(
        max_batch=2, page_size=PAGE, n_pages=8, prefill_chunk=4))
    sched.enqueue(req)
    time.sleep(0.01)
    sched.drain()
    assert req.state == RequestState.EVICTED
    assert "chunked prefill" in req.error
    assert eng._counters["evicted"] == before + 1
    # the reserved pages were returned — nothing leaked
    assert sched.paged.free_pages == sched.paged.n_pages


def test_drain_stall_fails_stranded_requests_loudly(eng):
    rng = np.random.default_rng(5)
    req = eng.submit(rng.integers(0, CFG.vocab_size, (6,)).astype(
        np.int32), 2)
    sched = ContinuousScheduler(eng, SchedulerConfig(
        max_batch=2, page_size=PAGE, n_pages=8))
    sched.enqueue(req)
    sched._admit = lambda: False          # wedge the scheduler
    sched._decode = lambda: False
    before = len(eng.monitor.events_of("scheduler.stall"))
    sched.drain()
    assert req.state == RequestState.FAILED
    assert "stalled" in req.error
    assert len(eng.monitor.events_of("scheduler.stall")) == before + 1
    assert not sched.has_work             # nothing silently stranded


# ---------------------------------------------------------------------------
# SIGKILL mid-spill: journal recovery, zero lost, zero duplicated.
# ---------------------------------------------------------------------------
DRIVER = textwrap.dedent("""
    import json, sys
    import numpy as np
    import jax
    from repro import configs
    from repro.models import lm
    from repro.serve.engine import Engine
    from repro.serve.scheduler import SchedulerConfig

    mode, jdir, out = sys.argv[1], sys.argv[2], sys.argv[3]
    cfg = configs.get_smoke("qwen3-1.7b")
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    sc = SchedulerConfig(max_batch=4, page_size=%(page)d,
                         n_pages=%(pool)d)
    eng = Engine(cfg, params, max_len=%(max_len)d, journal_dir=jdir,
                 scheduler_config=sc)
    if mode == "resume":
        reqs = eng.restore()
        eng.serve(reqs)
    else:
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
                   for n in %(lens)r]
        reqs = [eng.submit(p, %(new_tokens)d) for p in prompts]
        eng.serve(reqs)           # ragged: continuous scheduler, tiny pool
    stats = {k: v for k, v in eng.stats().items() if isinstance(v, int)}
    json.dump({"tokens": {str(r.rid): list(r.out_tokens) for r in reqs},
               "states": {str(r.rid): r.state.value for r in reqs},
               "stats": stats}, open(out, "w"))
""" % {"max_len": MAX_LEN, "new_tokens": NEW_TOKENS, "lens": LENS,
       "page": PAGE, "pool": TINY_POOL})


def _run_driver(script, mode, jdir, out, plan=None, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("REPRO_FAULT_PLAN", None)
    if plan is not None:
        env["REPRO_FAULT_PLAN"] = plan
    return subprocess.run(
        [sys.executable, script, mode, str(jdir), str(out)],
        env=env, timeout=timeout,
        stdout=subprocess.PIPE, stderr=subprocess.PIPE)


def test_sigkill_mid_spill_recovers_via_journal(tmp_path):
    script = tmp_path / "driver.py"
    script.write_text(DRIVER)

    # the clean constrained run: what recovery must reproduce
    out0 = tmp_path / "out0.json"
    proc = _run_driver(script, "run", tmp_path / "j0", out0)
    assert proc.returncode == 0, proc.stderr.decode()[-2000:]
    base = json.load(open(out0))
    assert all(s == "done" for s in base["states"].values()), base
    assert base["stats"]["spills"] + base["stats"]["preemptions"] > 0, \
        base["stats"]       # the tiny pool must actually exercise spill

    # SIGKILL at the first spill: no finally blocks, no flushes
    jdir = tmp_path / "journal"
    out1, out2 = tmp_path / "out1.json", tmp_path / "out2.json"
    proc = _run_driver(script, "run", jdir, out1, plan="pool.spill:0:kill")
    assert proc.returncode == -9, proc.stderr.decode()[-2000:]
    assert not out1.exists()

    recs = RequestJournal(str(jdir)).scan()
    owed = sorted(r["rid"] for r in recs if r["kind"] == "submit")
    assert owed == sorted(int(r) for r in base["tokens"])

    proc = _run_driver(script, "resume", jdir, out2)
    assert proc.returncode == 0, proc.stderr.decode()[-2000:]
    result = json.load(open(out2))
    got = {int(rid): toks for rid, toks in result["tokens"].items()}
    assert sorted(got) == owed, result    # zero lost, zero invented
    for rid in owed:
        assert result["states"][str(rid)] == "done", result
        assert got[rid] == base["tokens"][str(rid)], (rid, result)
    assert result["stats"]["failed"] == 0
    assert result["stats"]["replay_divergence"] == 0
