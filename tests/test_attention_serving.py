"""Pallas-resident decode: KV-cache & windowed attention (PR 5).

Covers the banded kernel path end to end: cached-decode / windowed
parity against the ref oracles (traced & static windows, GQA groups,
int8 KV with per-position scales, ``cache_index`` at 0 / mid /
``max_len - 1``), the banded cost model against a brute-force mask
(visited blocks == blocks with any unmasked lane), grid-work reduction
(skipped KV blocks leave the ``pallas_call`` grid, they are not masked
lanes), ``attention_apply`` dispatching ``ops.attention`` on every
cache/window branch with a single ``backend="xla"`` escape hatch, the
int8 fallback never materializing a float copy of the ``max_len``
cache, and the v5 autotune keys.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core import autotune, cost_model, explorer
from repro.core.dataflow import AttentionProblem, DataflowSpec, OS, WS
from repro.core.jaxpr_utils import (
    count_pallas_calls, count_primitive, pallas_grid_steps,
)
from repro.kernels import ops, ref
from repro.models import layers

D = 64


def _arrays(rng, b, hq, hkv, sq, skv, d=D):
    q = jnp.asarray(rng.normal(size=(b, hq, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, skv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, skv, d)), jnp.float32)
    return q, k, v


def _quant(x):
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    sc = jnp.where(amax == 0, 1.0, amax / 127.0)
    xq = jnp.clip(jnp.round(x / sc), -127, 127).astype(jnp.int8)
    return xq, sc


# ---------------------------------------------------------------------------
# Kernel parity: cached decode / windows / int8 KV.
# ---------------------------------------------------------------------------
CACHED_CASES = [
    # (b, hq, hkv, sq, max_len, kv_len, window)
    (2, 4, 2, 1, 384, 1, None),        # cache_index = 0 decode
    (2, 4, 2, 1, 384, 200, None),      # mid-cache decode
    (2, 4, 2, 1, 384, 384, None),      # cache_index = max_len - 1
    (1, 8, 2, 1, 512, 100, 64),        # windowed decode, group=4
    (1, 4, 4, 100, 512, 260, None),    # cached chunk prefill (sq > 1)
    (1, 4, 2, 100, 512, 260, 64),      # cached chunk prefill + window
]


@pytest.mark.parametrize("case", CACHED_CASES)
@pytest.mark.parametrize("anchor", ["os", "ws"])
def test_cached_kernel_parity(case, anchor):
    """Traced ``kv_len`` over a padded cache buffer == oracle on the
    valid slice, for both anchors."""
    b, hq, hkv, sq, max_len, kv_len, win = case
    rng = np.random.default_rng(hash(case) % 2 ** 31)
    q, k, v = _arrays(rng, b, hq, hkv, sq, max_len)
    got = ops.attention(q, k, v, causal=True, window=win,
                        backend="interpret", anchor=anchor,
                        kv_len=jnp.int32(kv_len))
    want = ref.attention_ref(q, k[:, :, :kv_len], v[:, :, :kv_len],
                             causal=True, window=win)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=2e-3)


@pytest.mark.parametrize("anchor", ["os", "ws"])
@pytest.mark.parametrize("kv_len", [5, 200, 384])
def test_int8_kv_kernel_parity(anchor, kv_len):
    """int8 K/V dequantized at the block load == oracle on the
    dequantized valid slice (exact: same scales, f32 math)."""
    rng = np.random.default_rng(kv_len)
    q, k, v = _arrays(rng, 2, 4, 2, 1, 384)
    kq, ks = _quant(k)
    vq, vs = _quant(v)
    got = ops.attention(q, kq, vq, causal=True, backend="interpret",
                        anchor=anchor, kv_len=jnp.int32(kv_len),
                        k_scale=ks, v_scale=vs)
    want = ref.attention_ref(
        q, (kq * ks)[:, :, :kv_len].astype(jnp.float32),
        (vq * vs)[:, :, :kv_len].astype(jnp.float32), causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=2e-3)
    # and the quantized result approximates the fp attention
    full = ref.attention_ref(q, k[:, :, :kv_len], v[:, :, :kv_len],
                             causal=True)
    assert float(jnp.max(jnp.abs(got - full))) < 0.15


@pytest.mark.parametrize("anchor", ["os", "ws"])
def test_traced_window_parity(anchor):
    """A traced window (``window_dyn`` — per-layer scanned windows)
    matches the static-window oracle, including the no-window
    sentinel."""
    rng = np.random.default_rng(11)
    q, k, v = _arrays(rng, 1, 4, 2, 256, 256)
    for w in (32, 100, 2 ** 30):
        got = ops.attention(q, k, v, causal=True, backend="interpret",
                            anchor=anchor, window_dyn=jnp.int32(w))
        want = ref.attention_ref(q, k, v, causal=True,
                                 window=None if w == 2 ** 30 else w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-3, atol=2e-3, err_msg=str(w))


@pytest.mark.parametrize("anchor", ["os", "ws"])
def test_noncausal_window_parity(anchor):
    """Without a causal mask a window only cuts the past — the static
    band must NOT shrink the flash KV grid (it would silently drop
    in-band blocks; review finding on static_band)."""
    rng = np.random.default_rng(21)
    q, k, v = _arrays(rng, 1, 4, 2, 512, 512)
    got = ops.attention(q, k, v, causal=False, window=128,
                        backend="interpret", anchor=anchor,
                        bq=128, bkv=128)
    want = ref.attention_ref(q, k, v, causal=False, window=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=2e-3)


def test_bf16_kv_cache_is_not_charged_dequant_scales():
    """A precision mismatch (f32 q over a bf16 cache) has no scale
    arrays — only int8 KV pays the per-position scale bytes."""
    spec = DataflowSpec.basic(OS, block=(1, 128, D))
    f32 = AttentionProblem(bh=8, sq=1, skv=1024, d=D)
    bf16 = dataclasses.replace(f32, kv_dtype="bfloat16")
    i8 = dataclasses.replace(f32, kv_dtype="int8")
    assert not bf16.kv_quantized and i8.kv_quantized
    t_f32 = cost_model.attention_traffic(f32, spec)
    t_bf16 = cost_model.attention_traffic(bf16, spec)
    t_i8 = cost_model.attention_traffic(i8, spec)
    # bf16 KV: exactly half the f32 KV stream, no phantom scale term
    assert t_bf16.reads[WS] == t_f32.reads[WS] // 2
    # int8 KV: quarter stream + two f32 scales per position
    assert t_i8.reads[WS] == t_f32.reads[WS] // 4 + t_f32.reads[WS] // D
    # VMEM: bf16 vs f32 differs only by the KV element halving (no
    # phantom scale buffers); int8 adds exactly the two scale blocks
    bkv = 128
    f_f32 = cost_model.attention_vmem_footprint(f32, spec)
    f_bf16 = cost_model.attention_vmem_footprint(bf16, spec)
    f_i8 = cost_model.attention_vmem_footprint(i8, spec)
    assert f_f32 - f_bf16 == 2 * 2 * bkv * D * 2
    assert f_i8 == f_bf16 - 2 * 2 * bkv * D + 2 * 2 * bkv * 4


def test_int8_without_scales_rejected():
    q = jnp.zeros((1, 4, 1, D), jnp.float32)
    k = jnp.zeros((1, 2, 128, D), jnp.int8)
    with pytest.raises(ValueError, match="k_scale"):
        ops.attention(q, k, k, backend="interpret")


# ---------------------------------------------------------------------------
# Banded cost model vs brute force.
# ---------------------------------------------------------------------------
def _brute_visited(p, bq, bkv):
    """Blocks with >= 1 unmasked lane, by materializing the mask."""
    bq, bkv = cost_model.attention_block_clamp(p.sq, p.skv, bq, bkv)
    gq = -(-p.sq // bq)
    gkv = -(-p.skv // bkv)
    off = p.kv_valid - p.sq
    qpos = np.arange(p.sq) + off
    kpos = np.arange(gkv * bkv)
    m = np.broadcast_to(kpos[None, :] < p.kv_valid,
                        (p.sq, gkv * bkv)).copy()
    if p.causal:
        m = m & (kpos[None, :] <= qpos[:, None])
    if p.window is not None:
        m = m & (kpos[None, :] > qpos[:, None] - p.window)
    pairs, blocks = 0, set()
    for i in range(gq):
        rows = m[i * bq: min((i + 1) * bq, p.sq)]
        for j in range(gkv):
            if rows[:, j * bkv: (j + 1) * bkv].any():
                pairs += 1
                blocks.add(j)
    return pairs, len(blocks)


BAND_PROBLEMS = [
    AttentionProblem(bh=4, sq=256, skv=256, d=D),
    AttentionProblem(bh=4, sq=512, skv=512, d=D, window=128),
    AttentionProblem(bh=4, sq=100, skv=512, d=D, kv_len=260),
    AttentionProblem(bh=4, sq=100, skv=512, d=D, kv_len=260, window=48),
    AttentionProblem(bh=4, sq=1, skv=1024, d=D, kv_len=129),
    AttentionProblem(bh=4, sq=1, skv=1024, d=D, kv_len=900, window=256),
    AttentionProblem(bh=4, sq=200, skv=200, d=D, causal=False),
    AttentionProblem(bh=4, sq=200, skv=200, d=D, causal=False, window=64),
]


@pytest.mark.parametrize("prob", BAND_PROBLEMS)
@pytest.mark.parametrize("bq,bkv", [(128, 128), (128, 64), (256, 128)])
def test_visited_blocks_match_brute_force(prob, bq, bkv):
    """The closed-form band (shared by kernels and cost model) counts
    exactly the blocks with at least one unmasked lane."""
    pairs, blocks, _, _ = cost_model.attention_visited_blocks(prob, bq, bkv)
    bpairs, bblocks = _brute_visited(prob, bq, bkv)
    assert (pairs, blocks) == (bpairs, bblocks)


def test_decode_traffic_scales_with_kv_len():
    """The acceptance invariant: modeled decode traffic grows with the
    valid KV length, not the max_len buffer, and int8 KV shrinks it."""
    spec = DataflowSpec.basic(OS, block=(1, 128, D))
    mk = lambda kl, kd=None: AttentionProblem(
        bh=8, sq=1, skv=2048, d=D, group=2, kv_len=kl, kv_dtype=kd)
    totals = [cost_model.attention_traffic(mk(kl), spec).total
              for kl in (128, 512, 2048)]
    assert totals[0] < totals[1] < totals[2]
    assert 4 * totals[0] < totals[2]
    t8 = cost_model.attention_traffic(mk(512, "int8"), spec).total
    assert t8 < cost_model.attention_traffic(mk(512), spec).total
    # full-length None == explicit skv
    assert (cost_model.attention_traffic(mk(None), spec).total
            == totals[-1])


def test_window_sparsity_reaches_the_ranking():
    """Banded accounting: mask sparsity no longer cancels out of the
    OS/WS comparison — the windowed WS one-shot KV fetch stays full
    while its per-pair state round-trips shrink with the band."""
    full = AttentionProblem(bh=8, sq=1024, skv=1024, d=D)
    win = dataclasses.replace(full, window=128)
    spec_os = DataflowSpec.basic(OS, block=(128, 128, D))
    spec_ws = DataflowSpec.basic(WS, block=(128, 128, D))
    for prob in (full, win):
        t_os = cost_model.attention_traffic(prob, spec_os)
        t_ws = cost_model.attention_traffic(prob, spec_ws)
        assert t_os.total < t_ws.total          # flash still wins
    # the window reduces both anchors' traffic...
    assert (cost_model.attention_traffic(win, spec_os).total
            < cost_model.attention_traffic(full, spec_os).total)
    # ...but by anchor-dependent amounts (the ratio moved: sparsity is
    # no longer a common factor that cancels)
    r_full = (cost_model.attention_traffic(full, spec_ws).total
              / cost_model.attention_traffic(full, spec_os).total)
    r_win = (cost_model.attention_traffic(win, spec_ws).total
             / cost_model.attention_traffic(win, spec_os).total)
    assert abs(r_full - r_win) > 0.1


def test_window_aware_candidates_and_versioned_keys():
    win_prob = AttentionProblem(bh=8, sq=512, skv=512, d=D, window=48)
    opts = explorer._attn_kv_block_options(win_prob)
    assert 48 in opts                     # window-snapped block offered
    dec = AttentionProblem(bh=8, sq=1, skv=2048, d=D, kv_len=100)
    assert 104 in explorer._attn_kv_block_options(dec)  # 8-aligned kv_len
    key = autotune._key(win_prob, cost_model.V5E, "interpret")
    assert key.startswith(
        f"v{autotune.CACHE_VERSION}|attn|"
        "8|512|512|64|1|c1|w48|float32|kl-|kd-|")
    k2 = autotune._key(dataclasses.replace(win_prob, kv_len=256),
                       cost_model.V5E, "interpret")
    k3 = autotune._key(dataclasses.replace(win_prob, kv_dtype="int8"),
                       cost_model.V5E, "interpret")
    assert len({key, k2, k3}) == 3        # new fields are keyed
    with pytest.raises(ValueError, match="kv_len"):
        AttentionProblem(bh=8, sq=1, skv=128, d=D, kv_len=256)


# ---------------------------------------------------------------------------
# Grid work: skipped KV blocks leave the lowering.
# ---------------------------------------------------------------------------
def test_static_window_shrinks_flash_grid():
    """A static window must shrink the pallas grid itself (trace-visible
    dispatch work), not just mask lanes in-kernel."""
    rng = np.random.default_rng(0)
    q, k, v = _arrays(rng, 1, 4, 2, 1024, 1024)

    def steps(win):
        jx = jax.make_jaxpr(
            lambda q, k, v: ops.attention(
                q, k, v, causal=True, window=win, backend="interpret",
                anchor="os", bq=128, bkv=128))(q, k, v)
        return pallas_grid_steps(jx.jaxpr), count_pallas_calls(jx.jaxpr)

    s_full, c_full = steps(None)
    s_win, c_win = steps(128)
    assert c_full == c_win == 1
    assert s_win < s_full
    # decode against a long cache: the window bounds the band statically
    qd, kd, vd = _arrays(rng, 1, 4, 2, 1, 4096)
    jx = jax.make_jaxpr(
        lambda q, k, v: ops.attention(
            q, k, v, causal=True, window=256, backend="interpret",
            anchor="os", bq=1, bkv=128, kv_len=jnp.int32(100)))(qd, kd, vd)
    assert pallas_grid_steps(jx.jaxpr) < 4 * 32   # << the 4*32 full sweep
    assert count_primitive(jx.jaxpr, "pad") == 0  # decode fast path kept


def test_ws_compiled_loop_skips_out_of_band_blocks():
    """The compiled WS per-block loop drops statically-invisible KV
    blocks — fewer ``pallas_call`` dispatches, zero work."""
    rng = np.random.default_rng(1)
    q, k, v = _arrays(rng, 1, 4, 2, 64, 512)

    def calls(win):
        jx = jax.make_jaxpr(
            lambda q, k, v: ops.attention(
                q, k, v, causal=True, window=win, backend="pallas",
                anchor="ws", bq=64, bkv=128))(q, k, v)
        return count_pallas_calls(jx.jaxpr)

    assert calls(None) == 4
    assert calls(64) < 4


# ---------------------------------------------------------------------------
# attention_apply: every branch on the kernel path, one escape hatch.
# ---------------------------------------------------------------------------
def _attn_setup(kv_dtype="auto", attn_window=None, qk_norm=False):
    cfg = configs.get_smoke("qwen3-1.7b")
    cfg = dataclasses.replace(cfg, kv_cache_dtype=kv_dtype,
                              attn_window=attn_window, qk_norm=qk_norm)
    p = layers.init_attention(jax.random.PRNGKey(0), cfg)
    return cfg, p


def _mk_cache(cfg, b, max_len, int8=False):
    shape = (b, cfg.n_kv_heads, max_len, cfg.d_head)
    if int8:
        return (jnp.zeros(shape, jnp.int8), jnp.zeros(shape, jnp.int8),
                jnp.ones(shape[:-1] + (1,), jnp.float32),
                jnp.ones(shape[:-1] + (1,), jnp.float32))
    return (jnp.zeros(shape, jnp.bfloat16), jnp.zeros(shape, jnp.bfloat16))


APPLY_CASES = [
    # (int8, window, s, cache_index, max_len)
    (False, None, 1, 0, 64),        # decode at cache_index = 0
    (False, None, 1, 31, 64),       # mid-cache decode
    (False, None, 1, 63, 64),       # cache_index = max_len - 1
    (False, 24, 1, 40, 64),         # windowed decode (static window)
    (True, None, 1, 40, 64),        # int8 KV decode
    (True, 24, 1, 63, 64),          # int8 + windowed, last slot
    (False, None, 8, 16, 64),       # cached multi-token chunk
]


@pytest.mark.parametrize("case", APPLY_CASES)
def test_attention_apply_kernel_vs_escape_hatch(case):
    """The Pallas route of attention_apply agrees with the XLA escape
    hatch on every cache/window/int8 branch (two independent
    implementations of the same masked semantics)."""
    int8, win, s, idx, max_len = case
    cfg, p = _attn_setup(kv_dtype="int8" if int8 else "auto")
    rng = np.random.default_rng(hash(case) % 2 ** 31)
    b = 2
    x = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)) * 0.3,
                    jnp.float32)
    cache = _mk_cache(cfg, b, max_len, int8=int8)
    # pre-fill the cache with idx entries so the decode attends history
    if idx:
        hist = jnp.asarray(rng.normal(size=(b, idx, cfg.d_model)) * 0.3,
                           jnp.float32)
        _, cache = layers.attention_apply(
            p, hist, cfg, positions=jnp.arange(idx)[None, :],
            kv_cache=cache, cache_index=jnp.int32(0), backend="xla")
    pos = (idx + jnp.arange(s))[None, :]
    kw = dict(positions=pos, window=win, kv_cache=cache,
              cache_index=jnp.int32(idx))
    out_k, cache_k = layers.attention_apply(p, x, cfg, backend="interpret",
                                            **kw)
    out_x, cache_x = layers.attention_apply(p, x, cfg, backend="xla", **kw)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_x),
                               rtol=2e-2, atol=2e-3)
    for got, want in zip(cache_k, cache_x):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32))


def test_attention_apply_traced_window_matches_static():
    cfg, p = _attn_setup()
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(1, 32, cfg.d_model)) * 0.3,
                    jnp.float32)
    stat, _ = layers.attention_apply(p, x, cfg, window=8,
                                     backend="interpret")
    dyn, _ = layers.attention_apply(p, x, cfg, window=jnp.int32(8),
                                    backend="interpret")
    np.testing.assert_allclose(np.asarray(stat), np.asarray(dyn),
                               rtol=1e-3, atol=2e-3)


@pytest.mark.parametrize("int8,win", [(False, None), (True, None),
                                      (False, 24), (True, 24)])
def test_attention_apply_cache_branches_dispatch_pallas(int8, win):
    """The acceptance claim: a KV cache and/or window still dispatches
    ONE ``ops.attention`` kernel (previously these branches fell back
    to masked einsums)."""
    cfg, p = _attn_setup(kv_dtype="int8" if int8 else "auto")
    b, s, max_len = 1, 1, 64
    x = jnp.zeros((b, s, cfg.d_model), jnp.float32)
    cache = _mk_cache(cfg, b, max_len, int8=int8)

    def run(x, cache_index, *cache):
        out, _ = layers.attention_apply(
            p, x, cfg, positions=jnp.full((b, 1), cache_index),
            window=win, kv_cache=cache, cache_index=cache_index,
            backend="interpret")
        return out

    jx = jax.make_jaxpr(run)(x, jnp.int32(3), *cache)
    assert count_pallas_calls(jx.jaxpr) == 1


def test_int8_fallback_never_materializes_float_cache():
    """Satellite: the XLA escape hatch folds the int8 dequant into the
    logits/probabilities — no eqn may produce a float image of the
    whole (B, Hkv, max_len, Dh) cache (the old path multiplied the
    full buffer by its scales every decode step)."""
    cfg, p = _attn_setup(kv_dtype="int8")
    b, max_len = 2, 128
    x = jnp.zeros((b, 1, cfg.d_model), jnp.float32)
    cache = _mk_cache(cfg, b, max_len, int8=True)
    cache_shape = (b, cfg.n_kv_heads, max_len, cfg.d_head)

    def run(x, cache_index, *cache):
        out, _ = layers.attention_apply(
            p, x, cfg, positions=jnp.full((b, 1), cache_index),
            kv_cache=cache, cache_index=cache_index, backend="xla")
        return out

    jx = jax.make_jaxpr(run)(x, jnp.int32(100), *cache)

    def visit(eqn):
        bad = 0
        if eqn.primitive.name in ("mul", "div", "add", "sub"):
            for v_ in eqn.outvars:
                aval = v_.aval
                if (getattr(aval, "shape", None) == cache_shape
                        and aval.dtype in (jnp.float32, jnp.bfloat16)):
                    bad += 1
        return bad

    from repro.core.jaxpr_utils import _walk
    assert _walk(jx.jaxpr, visit) == 0


def test_hot_attention_problems_windowed_and_int8():
    """Engine warming covers the windowed-prefill and int8 cached-decode
    shapes the model actually serves."""
    from repro.models import lm

    base = configs.get_smoke("qwen3-1.7b")
    cfg = dataclasses.replace(base, attn_window=64, kv_cache_dtype="int8")
    probs = lm.hot_attention_problems(cfg, 2, 128, max_len=256)
    assert len(probs) == 4
    wins = {p.window for p in probs}
    assert wins == {None, 64}
    decode = [p for p in probs if p.sq == 1]
    assert all(p.skv == 256 and p.kv_dtype == "int8" for p in decode)
    prefill = [p for p in probs if p.sq > 1]
    assert all(p.kv_dtype is None for p in prefill)   # attend_local
    for prob in probs:
        explorer.best_spec(prob)     # every warmed problem resolves
