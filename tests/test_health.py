"""Unit tests for runtime/health.py: the fault-injection harness,
the step-timing/straggler monitor, the event ledger, and the
kernel-degradation policy (no model required — serve-loop integration
lives in test_fault_tolerance.py)."""
import os

import pytest

from repro.runtime import health


@pytest.fixture(autouse=True)
def _clean_fault_env():
    keys = ("REPRO_FAULT_PLAN", "REPRO_FAIL_AT_STEP", "REPRO_FAULT_HANG_S")
    saved = {k: os.environ.get(k) for k in keys}
    health.reset_faults()
    yield
    for k, v in saved.items():
        if v is None:
            os.environ.pop(k, None)
        else:
            os.environ[k] = v
    health.reset_faults()


# ---------------------------------------------------------------------------
# Fault-plan parsing.
# ---------------------------------------------------------------------------
def test_parse_fault_plan():
    specs = health.parse_fault_plan(
        "serve.prefill:0:raise, kernel.matmul:*:nan,autotune.load:2:hang")
    assert [s.site for s in specs] == [
        "serve.prefill", "kernel.matmul", "autotune.load"]
    assert specs[0].step == 0 and specs[0].kind == "raise"
    assert specs[1].step is None and specs[1].kind == "nan"
    assert specs[2].kind == "hang-timeout"   # "hang" sugar


@pytest.mark.parametrize("bad", ["bogus", "site:kind", "a:b:c:d:e",
                                 "serve.prefill:0:explode",
                                 "serve.prefill:x:raise"])
def test_parse_fault_plan_rejects_malformed(bad):
    with pytest.raises(ValueError):
        health.parse_fault_plan(bad)


def test_register_site_idempotent():
    n = len(health.INJECTION_SITES)
    health.register_site("serve.prefill")
    assert len(health.INJECTION_SITES) == n
    health.register_site("test.site")
    try:
        assert "test.site" in health.INJECTION_SITES
    finally:
        health.INJECTION_SITES.remove("test.site")


# ---------------------------------------------------------------------------
# maybe_inject semantics.
# ---------------------------------------------------------------------------
def test_inject_raise_at_hit():
    os.environ["REPRO_FAULT_PLAN"] = "x.site:1:raise"
    assert health.maybe_inject("x.site") is None       # hit 0
    with pytest.raises(health.SimulatedFailure):
        health.maybe_inject("x.site")                  # hit 1 fires
    assert health.maybe_inject("x.site") is None       # hit 2
    log = health.fault_log()
    assert [(f.site, f.hit, f.kind) for f in log] == [("x.site", 1, "raise")]


def test_inject_every_hit_and_nan_kind():
    os.environ["REPRO_FAULT_PLAN"] = "x.site:*:nan"
    assert health.maybe_inject("x.site") == "nan"
    assert health.maybe_inject("x.site") == "nan"
    assert health.maybe_inject("other.site") is None
    assert len(health.fault_log()) == 2


def test_inject_hang_sleeps():
    import time
    os.environ["REPRO_FAULT_PLAN"] = "x.site:0:hang-timeout"
    os.environ["REPRO_FAULT_HANG_S"] = "0.05"
    t0 = time.monotonic()
    assert health.maybe_inject("x.site") == "hang-timeout"
    assert time.monotonic() - t0 >= 0.05


def test_step_override_and_fail_at_step_compat():
    os.environ["REPRO_FAIL_AT_STEP"] = "6"
    for s in range(1, 6):
        health.maybe_inject_failure(s)
    with pytest.raises(health.SimulatedFailure):
        health.maybe_inject_failure(6)
    # keyed on the passed step, not the hit counter: a "restart" that
    # replays from step 4 does not re-fire before step 6
    health.reset_faults()
    health.maybe_inject_failure(4)
    health.maybe_inject_failure(5)
    with pytest.raises(health.SimulatedFailure):
        health.maybe_inject_failure(6)


def test_reset_faults_zeroes_counters():
    health.maybe_inject("x.site")
    health.maybe_inject("x.site")
    health.reset_faults()
    os.environ["REPRO_FAULT_PLAN"] = "x.site:0:nan"
    assert health.maybe_inject("x.site") == "nan"


# ---------------------------------------------------------------------------
# HealthMonitor: stragglers, hook, ledger.
# ---------------------------------------------------------------------------
def test_straggler_threshold_boundary():
    mon = health.HealthMonitor(window=16, threshold=2.0)
    for s in range(8):
        assert not mon.record(s, 0.1)
    # exactly at threshold x median is NOT a straggler (strict >)
    assert not mon.record(8, 0.2)
    assert mon.record(9, 0.21)
    assert len(mon.stragglers) == 1
    assert mon.stragglers[0].step == 9


def test_straggler_needs_history():
    mon = health.HealthMonitor(window=16, threshold=2.0)
    for s in range(7):
        mon.record(s, 0.01)
    # only 7 records of history -> no straggler call yet
    assert not mon.record(7, 10.0)
    assert mon.stragglers == []


def test_on_straggler_hook_and_ledger():
    seen = []
    mon = health.HealthMonitor(window=16, threshold=3.0,
                               on_straggler=seen.append)
    for s in range(10):
        mon.record(s, 0.1)
    mon.record(10, 1.0)
    assert len(seen) == 1 and seen[0].seconds == 1.0
    evs = mon.events_of("straggler")
    assert len(evs) == 1 and evs[0].step == 10
    rep = mon.report()
    assert rep["stragglers"] == 1
    assert rep["events"]["straggler"] == 1
    assert rep["steps"] == 11


def test_note_and_report_rollup():
    mon = health.HealthMonitor()
    mon.note("demotion", site="kernel.attention", step=3, detail="boom")
    mon.note("retry", site="serve.decode_step", step=3)
    mon.note("retry", site="serve.decode_step", step=4)
    assert len(mon.events_of("retry")) == 2
    rep = mon.report()
    assert rep["events"] == {"demotion": 1, "retry": 2}
    assert rep["median_step_seconds"] == 0.0


# ---------------------------------------------------------------------------
# DegradationPolicy.
# ---------------------------------------------------------------------------
def test_degradation_demote_cooldown_reprobe():
    mon = health.HealthMonitor()
    pol = health.DegradationPolicy(cooldown_steps=3)
    assert pol.backend_for(0, mon) == "primary"
    pol.on_failure("kernel.attention", 0, RuntimeError("lowering"), mon)
    assert pol.demoted
    assert pol.backend_for(1, mon) == "degraded"
    assert pol.backend_for(2, mon) == "degraded"
    # cooldown elapsed -> optimistic re-probe
    assert pol.backend_for(3, mon) == "primary"
    assert pol.probes == 1 and not pol.demoted
    # failing probe re-demotes for another cooldown
    pol.on_failure("kernel.attention", 3, RuntimeError("still bad"), mon)
    assert pol.backend_for(4, mon) == "degraded"
    assert pol.demotions == [("kernel.attention", 0), ("kernel.attention", 3)]
    kinds = [e.kind for e in mon.events]
    assert kinds == ["demotion", "probe", "demotion"]


def test_degradation_backoff_is_exponential():
    pol = health.DegradationPolicy(backoff_base_s=0.01)
    assert pol.backoff_seconds(0) == pytest.approx(0.01)
    assert pol.backoff_seconds(1) == pytest.approx(0.02)
    assert pol.backoff_seconds(3) == pytest.approx(0.08)
