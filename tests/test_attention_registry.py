"""Unified dataflow-subsystem registry + attention anchor parity (PR 4).

Covers: the problem registry's four built-in registrations and the
generic ``explore``/``autotune`` dispatch; ``AttentionProblem`` keying
(``v4|attn|...``) and cache behavior; OS(flash)/WS(kv-stationary)
anchor parity against ``ref.attention_ref`` across GQA groups,
causal/windowed masks and ragged (right-aligned padding) shapes; the
decode ``Sq=1`` single-dispatch fast path; the WS compiled-backend loop
honoring the registry spec's ``(bq, bkv)``; and the per-problem
``measure`` hooks that extend the ``REPRO_AUTOTUNE_REFINE=1`` empirical
re-rank beyond GEMM to conv, binary and attention problems.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import autotune, cost_model, explorer
from repro.core.dataflow import (
    AttentionProblem,
    BinaryProblem,
    ConvProblem,
    DataflowSpec,
    GemmProblem,
    ProblemRegistration,
    register_problem,
    registered_kinds,
    registration_for,
    IS,
    OS,
    WS,
)
from repro.core.jaxpr_utils import count_pallas_calls, count_primitive
from repro.kernels import ops, ref

ATTN_PROBLEM = AttentionProblem(bh=8, sq=256, skv=256, d=64, group=2)
CONV_PROBLEM = ConvProblem(ih=10, iw=10, fh=3, fw=3, s=1, cin=32, cout=64,
                           n=1, in_dtype="float32", out_dtype="float32")
BIN_PROBLEM = BinaryProblem(m=64, kp=4, n=128, n_bits=128)
GEMM_PROBLEM = GemmProblem(m=128, k=128, n=128, in_dtype="float32",
                           out_dtype="float32")


# ---------------------------------------------------------------------------
# Registry mechanics.
# ---------------------------------------------------------------------------
def test_registry_covers_four_subsystems():
    kinds = registered_kinds()
    assert kinds == {
        "gemm": GemmProblem, "conv": ConvProblem, "bin": BinaryProblem,
        "attn": AttentionProblem,
    }
    for prob in (GEMM_PROBLEM, CONV_PROBLEM, BIN_PROBLEM, ATTN_PROBLEM):
        reg = registration_for(prob)
        assert reg.problem_cls is type(prob)
        assert callable(reg.enumerate) and callable(reg.time_estimate)
        assert callable(reg.vmem_footprint) and callable(reg.measure)
        # every registration's key head is pure strings
        assert all(isinstance(s, str) for s in reg.key_fields(prob))


def test_unregistered_problem_type_raises():
    with pytest.raises(TypeError, match="not a registered"):
        registration_for(object())


def test_generic_explore_dispatches_all_kinds():
    for prob in (GEMM_PROBLEM, CONV_PROBLEM, BIN_PROBLEM, ATTN_PROBLEM):
        ranked = explorer.explore(prob, top=3)
        assert ranked, prob
        assert ranked[0].est_seconds <= ranked[-1].est_seconds
        # the registration's footprint hook accepts the winning spec
        foot = registration_for(prob).vmem_footprint(prob, ranked[0].spec)
        assert foot > 0


def test_registering_new_subsystem_needs_no_autotune_edits(tmp_path,
                                                           monkeypatch):
    """The registry contract: a brand-new problem type resolves through
    best_spec with only a register_problem call (the PR-4 point)."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "cache.json"))
    autotune.clear()

    @dataclasses.dataclass(frozen=True)
    class ToyProblem:
        n: int

    toy_spec = DataflowSpec.basic(OS, block=(8, 8, 8))
    register_problem(ProblemRegistration(
        kind="toy", problem_cls=ToyProblem,
        key_fields=lambda p: (str(p.n),),
        enumerate=lambda p, hw, **kw: [
            explorer.Candidate(toy_spec, 1.0, p.n, True)],
        time_estimate=lambda p, spec, hw: 1.0,
        vmem_footprint=lambda p, spec: 8,
    ))
    try:
        got = autotune.best_spec(ToyProblem(n=4), backend="interpret")
        assert got == toy_spec
        key = autotune._key(ToyProblem(n=4), cost_model.V5E, "interpret")
        assert key.startswith(f"v{autotune.CACHE_VERSION}|toy|4|")
    finally:
        from repro.core import dataflow as df
        df._REGISTRY.pop(ToyProblem, None)
        autotune.clear()


# ---------------------------------------------------------------------------
# Attention cost model + explorer.
# ---------------------------------------------------------------------------
def test_attention_traffic_os_beats_ws():
    """Flash (OS) moves less HBM than kv-stationary (WS) — the WS state
    round-trips dominate — so the explorer must rank OS first."""
    spec_os = DataflowSpec.basic(OS, block=(128, 128, 64))
    spec_ws = DataflowSpec.basic(WS, block=(128, 128, 64))
    t_os = cost_model.attention_traffic(ATTN_PROBLEM, spec_os)
    t_ws = cost_model.attention_traffic(ATTN_PROBLEM, spec_ws)
    assert t_os.total < t_ws.total
    assert t_ws.reads[OS] > 0 and t_ws.writes[OS] > t_os.writes[OS]
    best = explorer.explore(ATTN_PROBLEM, top=1)[0]
    assert best.spec.anchor == OS


def test_attention_vmem_filter_and_is_anchor_rejected():
    tiny = dataclasses.replace(cost_model.V5E, vmem_bytes=1024)
    assert explorer.enumerate_attention_candidates(ATTN_PROBLEM, tiny) == []
    with pytest.raises(ValueError, match="no feasible dataflow"):
        explorer.best_spec(ATTN_PROBLEM, tiny)
    with pytest.raises(ValueError, match="OS/WS"):
        cost_model.attention_traffic(
            ATTN_PROBLEM, DataflowSpec.basic(IS, block=(128, 128, 64)))


def test_attention_decode_candidates_single_q_row():
    dec = AttentionProblem(bh=8, sq=1, skv=512, d=64, group=2)
    for cand in explorer.explore(dec, top=5):
        assert cand.spec.block[0] == 1   # no q blocking at Sq=1


# ---------------------------------------------------------------------------
# Autotune keying + resolution.
# ---------------------------------------------------------------------------
def test_attention_autotune_keys():
    key = autotune._key(ATTN_PROBLEM, cost_model.V5E, "interpret")
    assert key.startswith(f"v{autotune.CACHE_VERSION}|attn|8|256|256|64|2|")
    variants = [
        dataclasses.replace(ATTN_PROBLEM, causal=False),
        dataclasses.replace(ATTN_PROBLEM, window=128),
        dataclasses.replace(ATTN_PROBLEM, group=1),
        dataclasses.replace(ATTN_PROBLEM, sq=1),
        dataclasses.replace(ATTN_PROBLEM, dtype="bfloat16"),
    ]
    keys = {key} | {
        autotune._key(p, cost_model.V5E, "interpret") for p in variants
    }
    assert len(keys) == 1 + len(variants)   # every field is keyed


def test_gemm_keys_carry_registry_kind_tag():
    key = autotune._key(GEMM_PROBLEM, cost_model.V5E, "interpret")
    assert key.startswith(f"v{autotune.CACHE_VERSION}|gemm|128|128|128|")


def test_attention_autotune_cache_hits():
    autotune.clear(disk=True)
    autotune.reset_stats()
    s1 = autotune.best_spec(ATTN_PROBLEM, backend="interpret")
    s2 = autotune.best_spec(ATTN_PROBLEM, backend="interpret")
    st = autotune.stats()
    assert s1 == s2
    assert (st["lookups"], st["misses"], st["hits"]) == (2, 1, 1)
    # survives an in-process drop via the disk store
    autotune.clear(disk=False)
    s3 = autotune.best_spec(ATTN_PROBLEM, backend="interpret")
    assert s3 == s1 and autotune.stats()["enumerations"] == 1


def test_ops_attention_resolves_through_autotune():
    """ops.attention(spec=None) must consult the cache keyed on the
    AttentionProblem: the trace-time lookup after a direct best_spec
    call is a cache hit, not a fresh enumeration."""
    autotune.clear(disk=True)
    autotune.reset_stats()
    prob = AttentionProblem(bh=4, sq=128, skv=128, d=64, group=2,
                            causal=True, window=None, dtype="float32")
    autotune.best_spec(prob, backend="interpret")
    assert autotune.stats()["misses"] == 1
    q = jnp.zeros((1, 4, 128, 64), jnp.float32)
    k = jnp.zeros((1, 2, 128, 64), jnp.float32)
    ops.attention(q, k, k, causal=True, backend="interpret")
    st = autotune.stats()
    assert st["misses"] == 1 and st["hits"] >= 1


# ---------------------------------------------------------------------------
# Anchor parity: GQA, masks, ragged padding (satellite).
# ---------------------------------------------------------------------------
PARITY_CASES = [
    # (b, hq, hkv, sq, skv, causal, window)
    (2, 4, 2, 256, 256, True, None),     # GQA group=2
    (1, 8, 2, 128, 128, True, None),     # GQA group=4
    (1, 4, 1, 150, 200, True, None),     # ragged: sq/skv pad, group=4
    (1, 4, 2, 100, 260, True, 64),       # ragged + sliding window
    (1, 4, 2, 256, 256, True, 128),      # windowed causal
    (2, 2, 2, 200, 200, False, None),    # bidirectional
]


@pytest.mark.parametrize("case", PARITY_CASES)
@pytest.mark.parametrize("anchor", ["os", "ws"])
def test_attention_anchor_parity(case, anchor):
    b, hq, hkv, sq, skv, causal, win = case
    rng = np.random.default_rng(hash(case) % 2 ** 31)
    q = jnp.asarray(rng.normal(size=(b, hq, sq, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, skv, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, skv, 64)), jnp.float32)
    got = ops.attention(q, k, v, causal=causal, window=win,
                        backend="interpret", anchor=anchor)
    want = ref.attention_ref(q, k, v, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=2e-3)


@pytest.mark.parametrize("anchor", ["os", "ws"])
def test_attention_decode_parity(anchor):
    """The right-aligned Sq=1 decode row attends over the whole cache."""
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(2, 4, 1, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 2, 384, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 384, 64)), jnp.float32)
    got = ops.attention(q, k, v, causal=True, backend="interpret",
                        anchor=anchor)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Decode fast path + spec-honoring lowerings (satellites).
# ---------------------------------------------------------------------------
def test_decode_fast_path_single_dispatch_no_q_padding():
    """Sq=1 must lower as ONE kernel dispatch with NO pad ops (q is
    neither padded nor blocked; skv here is already block-aligned)."""
    q = jnp.zeros((1, 8, 1, 64), jnp.float32)
    k = jnp.zeros((1, 2, 256, 64), jnp.float32)
    spec = DataflowSpec.basic(OS, block=(1, 128, 64))
    jx = jax.make_jaxpr(
        lambda q, k, v: ops.attention(q, k, v, spec=spec,
                                      backend="interpret"))(q, k, k)
    assert count_pallas_calls(jx.jaxpr) == 1
    assert count_primitive(jx.jaxpr, "pad") == 0
    # the blocked prefill path DOES pad this ragged shape (contrast)
    qp = jnp.zeros((1, 8, 100, 64), jnp.float32)
    spec_p = DataflowSpec.basic(OS, block=(128, 128, 64))
    jx_p = jax.make_jaxpr(
        lambda q, k, v: ops.attention(q, k, v, spec=spec_p,
                                      backend="interpret"))(qp, k, k)
    assert count_primitive(jx_p.jaxpr, "pad") > 0


def test_kv_stationary_compiled_loop_honors_spec_block():
    """On compiled backends WS lowers as one aliased call per KV block —
    the loop must use the registry spec's bkv, not a built-in default."""
    q = jnp.zeros((1, 4, 256, 64), jnp.float32)
    k = jnp.zeros((1, 2, 512, 64), jnp.float32)
    for bkv, calls in ((128, 4), (256, 2)):
        spec = DataflowSpec.basic(WS, block=(128, bkv, 64))
        jx = jax.make_jaxpr(
            lambda q, k, v: ops.attention(q, k, v, spec=spec,
                                          backend="pallas"))(q, k, k)
        assert count_pallas_calls(jx.jaxpr) == calls, (bkv, calls)


def test_attention_spec_blocks_flow_to_both_kernels():
    """A non-default spec block must reach both kernel lowerings through
    ops.attention (clamped by cost_model.attention_block_clamp) and
    still match the oracle."""
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    want = ref.attention_ref(q, k, v, causal=True)
    for anchor_st, block in ((OS, (64, 64, 64)), (WS, (64, 64, 64)),
                             (OS, (512, 512, 64))):  # 512 clamps to 256
        spec = DataflowSpec.basic(anchor_st, block=block)
        got = ops.attention(q, k, v, causal=True, spec=spec,
                            backend="interpret")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Per-problem empirical refine hooks (satellite: conv/binary re-rank).
# ---------------------------------------------------------------------------
def test_refine_measure_hook_runs_for_conv_and_binary(monkeypatch):
    """REPRO_AUTOTUNE_REFINE=1 re-ranks conv and binary misses through
    the registration's measure hook (GEMM-only before PR 4)."""
    calls = []

    def spy(problem, specs, interpret=True):
        calls.append(type(problem).__name__)
        return [(s, float(i)) for i, s in enumerate(specs)]

    monkeypatch.setattr(explorer, "_measure_conv", spy)
    monkeypatch.setattr(explorer, "_measure_binary", spy)
    monkeypatch.setattr(explorer, "_measure_attention", spy)
    monkeypatch.setenv("REPRO_AUTOTUNE_REFINE", "1")
    autotune.clear(disk=True)
    autotune.best_spec(CONV_PROBLEM, backend="interpret")
    autotune.best_spec(BIN_PROBLEM, backend="interpret")
    autotune.best_spec(ATTN_PROBLEM, backend="interpret")
    assert calls == ["ConvProblem", "BinaryProblem", "AttentionProblem"]
    # cached: the hook does not rerun on hits
    autotune.best_spec(CONV_PROBLEM, backend="interpret")
    assert len(calls) == 3
    autotune.clear(disk=True)


def test_measure_hooks_execute_and_rank(monkeypatch):
    """The real hooks run the public ops in interpret mode and return a
    sorted (spec, seconds) ranking drawn from the candidate set."""
    monkeypatch.delenv("REPRO_AUTOTUNE_REFINE", raising=False)
    for prob in (BIN_PROBLEM,
                 AttentionProblem(bh=4, sq=128, skv=128, d=64, group=2),
                 CONV_PROBLEM):
        specs = [c.spec for c in explorer.explore(prob, top=2)]
        ranked = registration_for(prob).measure(prob, specs, interpret=True)
        assert sorted(s for _, s in ranked) == [s for _, s in ranked]
        assert {spec for spec, _ in ranked} == set(specs)


# ---------------------------------------------------------------------------
# Model/serving integration.
# ---------------------------------------------------------------------------
def test_hot_attention_problems_shapes():
    import dataclasses as dc

    from repro.configs.qwen3_1_7b import CONFIG as QWEN
    from repro.models import lm

    probs = lm.hot_attention_problems(QWEN, 2, 64, max_len=256)
    assert len(probs) == 2
    prefill, decode = probs
    assert (prefill.sq, prefill.skv) == (64, 64)
    assert (decode.sq, decode.skv) == (1, 256)
    for p in probs:
        assert p.bh == 2 * QWEN.n_heads
        assert p.group == QWEN.n_heads // QWEN.n_kv_heads
        assert p.d == QWEN.d_head
        # every warmed problem must actually resolve
        explorer.best_spec(p)
    ssm_cfg = dc.replace(QWEN, n_heads=0, n_kv_heads=0, family="ssm")
    assert lm.hot_attention_problems(ssm_cfg, 2, 64) == []
