"""Per-arch smoke tests: reduced config, one forward + one train step on CPU,
asserting output shapes + no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.optim import AdamW
from repro.train.step import make_train_step


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke(arch)
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, s = 2, 16
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                               jnp.int32),
    }
    if cfg.is_encoder_decoder:
        batch["enc_frames"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)), jnp.float32)

    logits, aux = lm.forward(params, batch["tokens"], cfg,
                             enc_frames=batch.get("enc_frames"),
                             remat="none")
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    opt = AdamW(lr_fn=lambda _: 1e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt, remat="none"))
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually changed
    changed = jax.tree.map(
        lambda a, b_: bool(jnp.any(a != b_)), params, new_params)
    assert any(jax.tree.leaves(changed))


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-780m", "hymba-1.5b",
                                  "whisper-tiny"])
def test_smoke_decode_consistency(arch):
    """Greedy decode logits match teacher-forced forward logits."""
    cfg = configs.get_smoke(arch)
    if cfg.n_experts:
        pytest.skip("capacity dropping makes MoE decode diverge by design")
    params = lm.init_model(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    b, s = 2, 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    enc = (jnp.asarray(rng.normal(size=(b, s, cfg.d_model)), jnp.float32)
           if cfg.is_encoder_decoder else None)
    logits, _ = lm.forward(params, tokens, cfg, enc_frames=enc, remat="none")
    _, cache = lm.prefill(params, tokens[:, : s - 1], cfg, max_len=s + 2,
                          enc_frames=enc)
    dec_logits, _ = lm.decode_step(params, cache, tokens[:, s - 1 : s], cfg)
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, : cfg.vocab_size]),
        np.asarray(logits[:, s - 1, : cfg.vocab_size]),
        rtol=1e-3, atol=1e-3,
    )


def test_all_cells_enumeration():
    cells = list(configs.all_cells(include_skipped=True))
    assert len(cells) == 40
    skipped = [c for c in cells if c[2]]
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s, _, _ in skipped)
    # ssm/hybrid run long_500k
    runs_long = {a for a, s, sk, _ in cells if s == "long_500k" and not sk}
    assert runs_long == {"mamba2-780m", "hymba-1.5b"}


def test_param_counts_match_published_sizes():
    expected = {
        "qwen3-moe-235b-a22b": (235e9, 0.03),
        "mistral-nemo-12b": (12.2e9, 0.05),
        "qwen3-1.7b": (1.7e9, 0.05),
        "hymba-1.5b": (1.6e9, 0.10),
        "mamba2-780m": (0.78e9, 0.10),
        "chameleon-34b": (34e9, 0.05),
    }
    for arch, (want, tol) in expected.items():
        got = configs.get(arch).param_count()
        assert abs(got - want) / want < tol, (arch, got, want)
    # MoE active params
    cfg = configs.get("qwen3-moe-235b-a22b")
    assert abs(cfg.active_param_count() - 22e9) / 22e9 < 0.05
