"""Binary (+-1, xnor-popcount) datapath: anchor parity, fused epilogue,
implicit-GEMM conv, autotune keying, and the refine env flag.

Oracles are ``ref.binary_matmul_ref`` / ``ref.binary_matmul_fused_ref``
/ ``ref.binary_conv2d_ref``.  Comparisons on the binary datapath proper
— raw int32 popcount dots and +-1 (re-)binarized outputs — are
*bitwise*; un-binarized float epilogue images are allowed exactly 1 ulp
because XLA may contract the kernel's ``scale * dot + bias`` into an
FMA in one lowering but not the other (the epilogue mirrors the oracle
operation-for-operation otherwise).
"""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import autotune, cost_model, explorer
from repro.core.dataflow import (
    BinaryEpilogue, BinaryProblem, DataflowSpec, GemmProblem, IS, OS, WS,
)
from repro.core.jaxpr_utils import count_primitive
from repro.kernels import ops, ref
from repro.kernels.binary_mm import binary_mm_df

ANCHORS = {"os": OS, "ws": WS, "is": IS}
# (m, k, n): tile-aligned and ragged (padding) shapes
SHAPES = [(128, 256, 128), (100, 96, 130), (64, 32, 256)]
EPILOGUES = {
    "scale_bias_sign": dict(scale=True, bias=True, binarize=True),
    "scale_bias": dict(scale=True, bias=True),
    "residual_sign": dict(residual=True, binarize=True),
    "sign": dict(binarize=True),
    "scalar_scale": dict(scale="scalar"),
}


def _packed_operands(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.choice([-1.0, 1.0], (m, k)), jnp.float32)
    b = jnp.asarray(rng.choice([-1.0, 1.0], (k, n)), jnp.float32)
    return a, b, ref.pack_binary(a, axis=1), ref.pack_binary(b, axis=0)


def _assert_bitwise(got, want, msg=""):
    assert got.dtype == want.dtype, (got.dtype, want.dtype, msg)
    assert got.shape == want.shape, (got.shape, want.shape, msg)
    assert bool(jnp.all(got == want)), msg


# ---------------------------------------------------------------------------
# Pack / unpack.
# ---------------------------------------------------------------------------
def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.choice([-1.0, 1.0], (5, 7, 64)), jnp.float32)
    for axis in (-1, 2):
        packed = ref.pack_binary(x, axis=axis)
        assert packed.dtype == jnp.uint32
        un = ref.unpack_binary(packed, axis=axis)
        _assert_bitwise(un, x, f"axis={axis}")
        # packing the unpacked image is idempotent
        _assert_bitwise(ref.pack_binary(un, axis=axis), packed)


def test_unpack_axis_moves():
    x = jnp.asarray(np.random.default_rng(2).choice([-1.0, 1.0], (32, 6)),
                    jnp.float32)
    packed = ref.pack_binary(x, axis=0)     # (1, 6)
    assert packed.shape == (1, 6)
    _assert_bitwise(ref.unpack_binary(packed, axis=0), x)


# ---------------------------------------------------------------------------
# Anchor parity: every anchor, tile-aligned and padded shapes.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("anchor", sorted(ANCHORS))
def test_binary_anchor_parity(anchor, shape):
    m, k, n = shape
    a, b, apk, bpk = _packed_operands(m, k, n, seed=hash(shape) % 2**31)
    spec = DataflowSpec.basic(ANCHORS[anchor], block=(64, 2, 128))
    got = ops.binary_matmul(apk, bpk, n_bits=k, spec=spec,
                            backend="interpret")
    want = ref.binary_matmul_ref(apk, bpk, k)
    _assert_bitwise(got, want, anchor)
    # and the packed dot equals the dense +-1 GEMM
    assert bool(jnp.all(got == (a @ b).astype(jnp.int32)))


@pytest.mark.parametrize("anchor", sorted(ANCHORS))
def test_binary_anchor_single_dispatch(anchor):
    """One pallas_call regardless of the reduction depth (gk panels)."""
    for kp_words in (2, 8, 16):
        k = 32 * kp_words
        apk = jnp.zeros((128, kp_words), jnp.uint32)
        bpk = jnp.zeros((kp_words, 128), jnp.uint32)
        spec = DataflowSpec.basic(ANCHORS[anchor], block=(128, 2, 128))
        jx = jax.make_jaxpr(
            lambda x, y: ops.binary_matmul(x, y, n_bits=k, spec=spec,
                                           backend="interpret"))(apk, bpk)
        assert count_primitive(jx.jaxpr, "pallas_call") == 1, \
            (anchor, kp_words)


# ---------------------------------------------------------------------------
# Fused epilogue.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("epi_name", sorted(EPILOGUES))
@pytest.mark.parametrize("anchor", sorted(ANCHORS))
def test_binary_fused_matches_oracle(anchor, epi_name):
    m, k, n = 100, 96, 130
    _, _, apk, bpk = _packed_operands(m, k, n,
                                      seed=hash((anchor, epi_name)) % 2**31)
    rng = np.random.default_rng(5)
    flags = EPILOGUES[epi_name]
    scale = None
    if flags.get("scale") == "scalar":
        scale = jnp.float32(0.37)
    elif flags.get("scale"):
        scale = jnp.asarray(rng.uniform(0.1, 2.0, (n,)), jnp.float32)
    bias = (jnp.asarray(rng.normal(size=(n,)), jnp.float32)
            if flags.get("bias") else None)
    residual = (jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
                if flags.get("residual") else None)
    binarize = flags.get("binarize", False)
    spec = DataflowSpec.basic(ANCHORS[anchor], block=(64, 2, 128))
    got = ops.binary_matmul_fused(
        apk, bpk, k, scale=scale, bias=bias, residual=residual,
        binarize=binarize, spec=spec, backend="interpret",
    )
    want = ref.binary_matmul_fused_ref(
        apk, bpk, k,
        scale=jnp.asarray(scale, jnp.float32).reshape(1, -1)
        if scale is not None else None,
        bias=bias.reshape(1, -1) if bias is not None else None,
        residual=residual, binarize=binarize,
    )
    assert got.dtype == (jnp.int8 if binarize else jnp.float32)
    if binarize:
        _assert_bitwise(got, want, (anchor, epi_name))
        assert set(np.unique(np.asarray(got))) <= {-1, 1}
    else:
        # pre-sign float image: identical op order, but XLA contracts
        # the kernel's scale/bias stage into FMA forms the oracle's
        # barrier-pinned lowering doesn't — a rounding deviation of a
        # few ulp of the largest intermediate, absolute, not relative
        dot = np.asarray(ref.binary_matmul_ref(apk, bpk, k), np.float32)
        s = (np.asarray(scale, np.float32).reshape(1, -1)
             if scale is not None else np.float32(1.0))
        b = (np.asarray(bias, np.float32) if bias is not None
             else np.float32(0.0))
        atol = 4 * np.spacing((np.abs(dot * s) + np.abs(b)).max())
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=0, atol=atol)


def test_binary_fused_single_dispatch():
    """The whole layer — dot + folded BN + sign — is ONE pallas_call."""
    m, k, n = 128, 256, 128
    _, _, apk, bpk = _packed_operands(m, k, n, seed=11)
    scale = jnp.ones((n,), jnp.float32)
    bias = jnp.zeros((n,), jnp.float32)
    jx = jax.make_jaxpr(
        lambda x, y: ops.binary_matmul_fused(
            x, y, k, scale=scale, bias=bias, binarize=True,
            spec=DataflowSpec.basic(OS, block=(128, 2, 128)),
            backend="interpret"))(apk, bpk)
    assert count_primitive(jx.jaxpr, "pallas_call") == 1


def test_binary_chain_streams_pm1():
    """Two chained binary layers: the re-binarized +-1 int8 output of
    layer 1 repacks into layer 2 with no accumulator round trip."""
    m, k1, k2, n = 64, 96, 128, 64
    rng = np.random.default_rng(7)
    x, w1, xpk, w1pk = _packed_operands(m, k1, k2, seed=7)
    w2 = jnp.asarray(rng.choice([-1.0, 1.0], (k2, n)), jnp.float32)
    w2pk = ref.pack_binary(w2, axis=0)
    spec = DataflowSpec.basic(WS, block=(64, 2, 64))
    h = ops.binary_matmul_fused(xpk, w1pk, k1, binarize=True, spec=spec,
                                backend="interpret")
    out = ops.binary_matmul_fused(ref.pack_binary(h, axis=1), w2pk, k2,
                                  spec=spec, backend="interpret")
    h_ref = jnp.where((x @ w1) >= 0, 1.0, -1.0)
    want = (h_ref @ w2).astype(jnp.float32)
    _assert_bitwise(out, want)


# ---------------------------------------------------------------------------
# Binary conv (implicit GEMM).
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("stride", [1, 2])
def test_binary_conv2d_matches_oracles(stride):
    rng = np.random.default_rng(stride)
    x = jnp.asarray(rng.choice([-1.0, 1.0], (2, 9, 8, 64)), jnp.float32)
    w = jnp.asarray(rng.choice([-1.0, 1.0], (3, 3, 64, 70)), jnp.float32)
    xp = ref.pack_binary(x, axis=-1)
    wp = ref.pack_binary(w, axis=2)
    got = ops.binary_conv2d(xp, wp, stride=stride, backend="interpret")
    want = ref.binary_conv2d_ref(xp, wp, stride)
    _assert_bitwise(got, want, f"s={stride}")
    # the packed conv equals the dense +-1 conv oracle exactly
    real = ref.conv2d_ref(x, w, stride)
    assert bool(jnp.all(got == real.astype(jnp.int32)))


def test_binary_conv2d_fused_and_single_dispatch():
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.choice([-1.0, 1.0], (1, 8, 8, 64)), jnp.float32)
    w = jnp.asarray(rng.choice([-1.0, 1.0], (3, 3, 64, 64)), jnp.float32)
    xp, wp = ref.pack_binary(x, axis=-1), ref.pack_binary(w, axis=2)
    scale = jnp.asarray(rng.uniform(0.1, 1.0, (64,)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    spec = DataflowSpec.basic(IS, block=(36, 2, 64))
    fn = lambda a, b: ops.binary_conv2d(
        a, b, scale=scale, bias=bias, binarize=True, spec=spec,
        backend="interpret")
    got = fn(xp, wp)
    want = ref.binary_conv2d_ref(
        xp, wp, 1, scale=scale.reshape(1, -1), bias=bias.reshape(1, -1),
        binarize=True)
    _assert_bitwise(got, want)
    jx = jax.make_jaxpr(fn)(xp, wp)
    assert count_primitive(jx.jaxpr, "pallas_call") == 1


# ---------------------------------------------------------------------------
# Error paths.
# ---------------------------------------------------------------------------
def test_binary_mm_df_untileable_raises():
    apk = jnp.zeros((100, 3), jnp.uint32)
    bpk = jnp.zeros((3, 130), jnp.uint32)
    with pytest.raises(ValueError, match="tile"):
        binary_mm_df(apk, bpk, 96, DataflowSpec.basic(OS, block=(64, 2, 128)))


def test_binary_mm_df_bad_shapes_raise():
    with pytest.raises(ValueError, match="bad shapes"):
        binary_mm_df(jnp.zeros((4, 2), jnp.uint32),
                     jnp.zeros((3, 4), jnp.uint32), 64,
                     DataflowSpec.basic(OS, block=(4, 1, 4)))


def test_binary_mm_df_missing_epilogue_operands_raise():
    apk = jnp.zeros((64, 2), jnp.uint32)
    bpk = jnp.zeros((2, 128), jnp.uint32)
    spec = DataflowSpec.basic(OS, block=(64, 2, 128))
    with pytest.raises(ValueError, match="scale"):
        binary_mm_df(apk, bpk, 64, spec,
                     epilogue=BinaryEpilogue(scale=True))
    with pytest.raises(ValueError, match="bias shape"):
        binary_mm_df(apk, bpk, 64, spec,
                     epilogue=BinaryEpilogue(bias=True),
                     bias=jnp.zeros((1, 64), jnp.float32))


def test_binary_problem_validates_depth():
    with pytest.raises(ValueError, match="n_bits"):
        BinaryProblem(m=8, kp=2, n=8, n_bits=65)


def test_binary_fused_bad_scale_raises():
    apk = jnp.zeros((64, 2), jnp.uint32)
    bpk = jnp.zeros((2, 128), jnp.uint32)
    with pytest.raises(ValueError, match="scale"):
        ops.binary_matmul_fused(
            apk, bpk, 64, scale=jnp.zeros((7,), jnp.float32),
            spec=DataflowSpec.basic(OS, block=(64, 2, 128)),
            backend="interpret")


# ---------------------------------------------------------------------------
# Autotune keying + exploration.
# ---------------------------------------------------------------------------
BIN_PROBLEM = BinaryProblem(m=128, kp=8, n=256, n_bits=256)


def test_binary_autotune_cache_hits():
    autotune.clear(disk=True)
    autotune.reset_stats()
    s1 = autotune.best_spec(BIN_PROBLEM, backend="interpret")
    s2 = autotune.best_spec(BIN_PROBLEM, backend="interpret")
    st = autotune.stats()
    assert s1 == s2
    assert st["enumerations"] == 1 and st["hits"] == 1, st
    # the pick is realizable: packed blocking, feasible traffic
    bm, bkp, bn = s1.block
    assert bkp in (1, 2, 4, 8, 16)
    assert cost_model.binary_traffic(BIN_PROBLEM, s1).feasible


def test_ops_binary_matmul_resolves_through_autotune():
    """ops.binary_matmul(spec=None) must key the cache on the
    BinaryProblem: the trace-time lookup after a direct best_spec call
    is a cache hit, and the result still matches the oracle bitwise."""
    autotune.clear(disk=True)
    autotune.reset_stats()
    m, k, n = BIN_PROBLEM.m, BIN_PROBLEM.n_bits, BIN_PROBLEM.n
    _, _, apk, bpk = _packed_operands(m, k, n, seed=21)
    autotune.best_spec(BIN_PROBLEM, backend="interpret")
    assert autotune.stats()["misses"] == 1
    got = ops.binary_matmul(apk, bpk, n_bits=k, backend="interpret")
    st = autotune.stats()
    assert st["hits"] >= 1 and st["enumerations"] == 1, st
    _assert_bitwise(got, ref.binary_matmul_ref(apk, bpk, k))


def test_binary_key_distinct_from_gemm_and_depth():
    g = BIN_PROBLEM.as_gemm()
    gp = GemmProblem(m=g.m, k=g.k, n=g.n, in_dtype=g.in_dtype,
                     out_dtype=g.out_dtype, acc_dtype=g.acc_dtype)
    k_bin = autotune._key(BIN_PROBLEM, cost_model.V5E, "interpret")
    k_gemm = autotune._key(gp, cost_model.V5E, "interpret")
    assert k_bin != k_gemm and "|bin|" in k_bin
    # same packed geometry, different true depth -> different key
    import dataclasses
    other = dataclasses.replace(BIN_PROBLEM, n_bits=224)
    assert autotune._key(other, cost_model.V5E, "interpret") != k_bin


def test_explore_binary_candidates_realizable():
    ranked = explorer.explore_binary(BIN_PROBLEM, top=5)
    assert ranked
    for c in ranked:
        assert c.feasible
        assert c.spec.anchor in (OS, WS, IS)
        bm, bkp, bn = c.spec.block
        assert bkp <= BIN_PROBLEM.kp


def test_hot_binary_problems_and_warm():
    import dataclasses as dc

    from repro.configs.qwen3_1_7b import CONFIG as QWEN
    from repro.models import lm

    assert lm.hot_binary_problems(QWEN, 2, 64) == []
    bcfg = dc.replace(QWEN, binary_mlp=True)
    probs = lm.hot_binary_problems(bcfg, 2, 64)
    assert len(probs) == 2
    assert probs[0].n_bits == bcfg.d_model
    assert probs[1].kp == bcfg.d_ff // 32
    autotune.clear(disk=True)
    autotune.reset_stats()
    specs = autotune.warm(probs, backend="interpret")
    assert len(specs) == 2
    assert autotune.stats()["misses"] == 2


def test_binary_mlp_layer_path():
    from repro.models import layers

    p = layers.init_binary_mlp(jax.random.PRNGKey(0), 64, 96)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 64))
    out_ref = layers.binary_mlp_apply(p, x, backend="xla")
    out_krn = layers.binary_mlp_apply(p, x, backend="interpret")
    assert out_ref.shape == (3, 4, 64)
    _assert_bitwise(out_krn, out_ref)


def test_binary_mlp_routes_through_model():
    """cfg.binary_mlp must actually change the model: _init_layer stores
    packed binary MLP params and layers.mlp_apply dispatches on them."""
    from repro.configs.base import ArchConfig
    from repro.models import layers, lm

    cfg = ArchConfig(name="bin-smoke", family="dense", n_layers=1,
                     d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                     vocab_size=256, d_head=32, binary_mlp=True)
    lp = lm._init_layer(jax.random.PRNGKey(0), cfg)
    assert "up" in lp["mlp"] and lp["mlp"]["up"]["w_packed"].dtype \
        == jnp.uint32
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 64), jnp.bfloat16)
    out = layers.mlp_apply(lp["mlp"], x, cfg)
    want = layers.binary_mlp_apply(lp["mlp"], x).astype(x.dtype)
    assert out.dtype == x.dtype
    _assert_bitwise(out, want)
    # the warmed problems describe exactly these projections
    probs = lm.hot_binary_problems(cfg, 2, 3)
    assert [(p.kp, p.n) for p in probs] == [(64 // 32, 128), (128 // 32, 64)]


# ---------------------------------------------------------------------------
# REPRO_AUTOTUNE_REFINE env flag.
# ---------------------------------------------------------------------------
GEMM_PROBLEM = GemmProblem(m=128, k=128, n=128, in_dtype="float32",
                           out_dtype="float32")


def test_refine_env_flag_changes_ranking_only(monkeypatch):
    """With REPRO_AUTOTUNE_REFINE=1 the empirical re-rank runs on cache
    misses and may pick a different (still-candidate) spec; numerics of
    the op that consumes the spec never change."""
    calls = []
    real_rank = explorer.empirical_rank

    def spy_rank(problem, specs, **kw):
        calls.append(len(specs))
        # deliberately invert the analytic order to prove the flag
        # changes the pick, not just re-measures it
        return [(s, float(i)) for i, s in enumerate(reversed(list(specs)))]

    monkeypatch.setattr(explorer, "empirical_rank", spy_rank)
    monkeypatch.delenv("REPRO_AUTOTUNE_REFINE", raising=False)
    autotune.clear(disk=True)
    assert not autotune.refine_enabled()
    analytic = autotune.best_spec(GEMM_PROBLEM, backend="interpret")
    assert calls == []   # flag off: no empirical pass

    monkeypatch.setenv("REPRO_AUTOTUNE_REFINE", "1")
    assert autotune.refine_enabled()
    autotune.clear(disk=True)
    refined = autotune.best_spec(GEMM_PROBLEM, backend="interpret")
    assert calls == [3]  # flag on: re-ranked the analytic top-k
    candidates = [c.spec for c in explorer.explore(GEMM_PROBLEM, top=3)]
    assert refined in candidates
    assert refined != analytic  # the inverted rank picked a different spec

    # correctness is spec-independent: both picks match the oracle
    monkeypatch.setattr(explorer, "empirical_rank", real_rank)
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.normal(size=(100, 100)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(100, 120)), jnp.float32)
    want = ref.matmul_ref(a, b)
    for spec in (analytic, refined):
        got = ops.matmul(a, b, spec=spec, backend="interpret")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_refine_flag_off_by_default(monkeypatch):
    monkeypatch.delenv("REPRO_AUTOTUNE_REFINE", raising=False)
    assert not autotune.refine_enabled()
    monkeypatch.setenv("REPRO_AUTOTUNE_REFINE", "0")
    assert not autotune.refine_enabled()
