"""Crash-drill suite: the tested invariant is that a process kill at
ANY point in the serve loop loses no journaled request — after restart,
``Engine.restore()`` + ``serve()`` produces greedy tokens bit-identical
to the uninterrupted run, with every in-flight request recovered (none
FAILED or lost, none duplicated) and journal/snapshot corruption
quarantined rather than fatal.

Three layers:
  * journal unit tests (CRC envelopes, torn tail, replay_table folding);
  * in-process recovery tests (warm resume from snapshot, cold replay,
    corrupt-snapshot fallback, replay-divergence detection, elastic
    restore onto a planned mesh);
  * subprocess SIGKILL drills — the ``kill`` fault kind delivers a real
    SIGKILL at randomized journaled steps (seeded by
    ``REPRO_CRASH_DRILL_SEED``, which CI randomizes per run), then a
    second process resumes and must reproduce the baseline bit-exactly.

CI runs this file as the ``crash-drill`` job.
"""
import json
import os
import random
import shutil
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro import configs
from repro.models import lm
from repro.runtime import health
from repro.serve.engine import Engine, RequestState
from repro.serve.journal import RequestJournal, replay_table

CFG = configs.get_smoke("qwen3-1.7b")
MAX_LEN = 48
NEW_TOKENS = 6
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _clean_fault_env():
    keys = ("REPRO_FAULT_PLAN", "REPRO_FAIL_AT_STEP", "REPRO_FAULT_HANG_S",
            "REPRO_JOURNAL_DIR", "REPRO_SNAPSHOT_EVERY")
    saved = {k: os.environ.get(k) for k in keys}
    for k in keys:
        os.environ.pop(k, None)
    health.reset_faults()
    yield
    for k, v in saved.items():
        if v is None:
            os.environ.pop(k, None)
        else:
            os.environ[k] = v
    health.reset_faults()


@pytest.fixture(scope="module")
def served():
    params = lm.init_model(CFG, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, CFG.vocab_size, (2, 8)).astype(np.int32)
    eng = Engine(CFG, params, max_len=MAX_LEN)
    reqs = [eng.submit(p, NEW_TOKENS) for p in prompts]
    eng.serve(reqs)
    assert all(r.state == RequestState.DONE for r in reqs)
    base = [list(r.out_tokens) for r in reqs]
    return params, prompts, base


def _engine(params, tmp, **kw):
    kw.setdefault("journal_dir", str(tmp))
    return Engine(CFG, params, max_len=MAX_LEN, **kw)


def _crash_journal(jdir, drop_terminals=True, drop_tokens=0):
    """Simulate the journal a kill leaves: strip terminal records and
    the last ``drop_tokens`` token records (never-flushed tail)."""
    path = os.path.join(str(jdir), "journal.jsonl")
    lines = open(path).readlines()
    keep = []
    for line in lines:
        kind = json.loads(line)["rec"]["kind"]
        if drop_terminals and kind in ("done", "failed", "evicted"):
            continue
        keep.append(line)
    if drop_tokens:
        tok_idx = [i for i, line in enumerate(keep)
                   if json.loads(line)["rec"]["kind"] == "token"]
        for i in tok_idx[-drop_tokens:]:
            keep[i] = None
        keep = [line for line in keep if line is not None]
    open(path, "w").writelines(keep)


# ---------------------------------------------------------------------------
# Journal: CRC envelopes, torn tail, replay folding.
# ---------------------------------------------------------------------------
def test_journal_roundtrip_and_stats(tmp_path):
    j = RequestJournal(str(tmp_path))
    j.append("submit", fsync=True, rid=0, prompt=[1, 2], max_new_tokens=3,
             deadline_s=None)
    j.append("token", rid=0, step=1, token=7)
    j.append("done", fsync=True, rid=0, step=1, error=None)
    j.close()
    j2 = RequestJournal(str(tmp_path))
    recs = j2.scan()
    assert [r["kind"] for r in recs] == ["submit", "token", "done"]
    st = j.stats()
    assert st["appends"] == 3 and st["fsyncs"] == 2
    assert j2.stats()["records_loaded"] == 3
    table = replay_table(recs)
    assert table[0]["state"] == "done" and table[0]["tokens"] == [7]


def test_journal_corrupt_record_skipped_not_fatal(tmp_path):
    j = RequestJournal(str(tmp_path))
    j.append("submit", rid=0, prompt=[1], max_new_tokens=2)
    j.append("token", rid=0, step=1, token=5)
    j.append("token", rid=0, step=2, token=6)
    j.close()
    lines = open(j.path).readlines()
    env = json.loads(lines[1])
    env["rec"]["token"] = 999          # bit-flip: CRC now mismatches
    lines[1] = json.dumps(env) + "\n"
    lines.insert(1, "not json at all\n")
    open(j.path, "w").writelines(lines)
    j2 = RequestJournal(str(tmp_path))
    recs = j2.scan()
    st = j2.stats()
    assert st["records_skipped"] == 2 and st["records_loaded"] == 2
    # the poisoned step-1 token is gone; the step-2 token is beyond the
    # contiguous prefix, so the fold refuses to resurrect it with a hole
    assert replay_table(recs)[0]["tokens"] == []


def test_journal_torn_tail_dropped(tmp_path):
    j = RequestJournal(str(tmp_path))
    j.append("submit", rid=0, prompt=[1], max_new_tokens=2)
    j.append("token", rid=0, step=1, token=5)
    j.close()
    with open(j.path, "a") as f:
        f.write('{"rec": {"kind": "token", "rid": 0, "st')  # kill mid-append
    j2 = RequestJournal(str(tmp_path))
    recs = j2.scan()
    assert j2.stats()["torn_tail"] == 1
    assert [r["kind"] for r in recs] == ["submit", "token"]


def test_journal_append_fault_degrades_not_raises(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_FAULT_PLAN", "journal.append:0:raise")
    health.reset_faults()
    j = RequestJournal(str(tmp_path))
    j.append("submit", rid=0, prompt=[1], max_new_tokens=1)  # must not raise
    j.append("token", rid=0, step=1, token=4)
    assert j.stats()["append_errors"] == 1
    assert j.stats()["appends"] == 1
    assert [r["kind"] for r in j.scan()] == ["token"]


def test_replay_table_position_addressed_tokens():
    """Replayed steps re-journal the same positions; the fold must
    overwrite, not duplicate."""
    j_recs = [
        {"kind": "submit", "rid": 3, "prompt": [1], "max_new_tokens": 4},
        {"kind": "token", "rid": 3, "step": 1, "token": 10},
        {"kind": "token", "rid": 3, "step": 2, "token": 11},
        {"kind": "token", "rid": 3, "step": 2, "token": 11},  # replayed
        {"kind": "token", "rid": 3, "step": 3, "token": 12},
        {"kind": "token", "rid": 9, "step": 1, "token": 99},  # no submit
        {"kind": "done", "rid": 3, "step": 3, "error": None},
    ]
    table = replay_table(j_recs)
    assert table[3]["tokens"] == [10, 11, 12]
    assert table[3]["state"] == "done"
    assert 9 not in table


# ---------------------------------------------------------------------------
# In-process recovery: warm resume, cold replay, fallbacks, divergence.
# ---------------------------------------------------------------------------
def test_restore_terminal_requests_intact(served, tmp_path):
    params, prompts, base = served
    eng = _engine(params, tmp_path)
    reqs = [eng.submit(p, NEW_TOKENS) for p in prompts]
    eng.serve(reqs)
    eng2 = _engine(params, tmp_path)
    rec = eng2.restore()
    assert [r.state for r in rec] == [RequestState.DONE] * 2
    assert [list(r.out_tokens) for r in rec] == base
    assert eng2.stats()["recovered"] == 0      # nothing was in flight
    # rid continuity: a post-restore submit does not collide
    assert eng2.submit(prompts[0], 2).rid == rec[-1].rid + 1


def test_warm_resume_from_snapshot_bit_exact(served, tmp_path):
    params, prompts, base = served
    eng = _engine(params, tmp_path, snapshot_every=2)
    reqs = [eng.submit(p, NEW_TOKENS) for p in prompts]
    eng.serve(reqs)
    assert eng.stats()["snapshots_saved"] >= 2
    _crash_journal(tmp_path, drop_tokens=2)    # crash after snapshot
    eng2 = _engine(params, tmp_path)
    rec = eng2.restore()
    assert eng2._pending_resume is not None
    assert eng2._pending_resume["cache"] is not None
    assert all(r.state == RequestState.DECODING for r in rec)
    eng2.serve(rec)
    assert [r.state for r in rec] == [RequestState.DONE] * 2
    assert [list(r.out_tokens) for r in rec] == base
    st = eng2.stats()
    assert st["recovered"] == 2 and st["replay_divergence"] == 0


def test_cold_replay_without_snapshot_bit_exact(served, tmp_path):
    params, prompts, base = served
    eng = _engine(params, tmp_path)            # no snapshots configured
    reqs = [eng.submit(p, NEW_TOKENS) for p in prompts]
    eng.serve(reqs)
    _crash_journal(tmp_path, drop_tokens=3)
    eng2 = _engine(params, tmp_path)
    rec = eng2.restore()
    assert eng2._pending_resume is not None
    assert eng2._pending_resume["cache"] is None   # journal-only replay
    eng2.serve(rec)
    assert [list(r.out_tokens) for r in rec] == base
    st = eng2.stats()
    assert st["recovered"] == 2 and st["replayed_steps"] > 0
    assert st["replay_divergence"] == 0


def test_corrupt_snapshots_fall_back_to_cold_replay(served, tmp_path):
    params, prompts, base = served
    eng = _engine(params, tmp_path, snapshot_every=2)
    reqs = [eng.submit(p, NEW_TOKENS) for p in prompts]
    eng.serve(reqs)
    snapdir = os.path.join(str(tmp_path), "snapshots")
    for d in os.listdir(snapdir):
        npz = os.path.join(snapdir, d, "arrays.npz")
        if os.path.exists(npz):
            with open(npz, "wb") as f:
                f.write(b"!torn npz!")
    _crash_journal(tmp_path, drop_tokens=1)
    eng2 = _engine(params, tmp_path)
    rec = eng2.restore()
    assert eng2.stats()["restore_fallbacks"] >= 1   # quarantined, not fatal
    eng2.serve(rec)
    assert [list(r.out_tokens) for r in rec] == base


def test_injected_restore_fault_falls_back(served, tmp_path, monkeypatch):
    params, prompts, base = served
    eng = _engine(params, tmp_path, snapshot_every=2)
    reqs = [eng.submit(p, NEW_TOKENS) for p in prompts]
    eng.serve(reqs)
    _crash_journal(tmp_path, drop_tokens=1)
    monkeypatch.setenv("REPRO_FAULT_PLAN", "engine.restore:*:raise")
    health.reset_faults()
    eng2 = _engine(params, tmp_path)
    rec = eng2.restore()                # every snapshot attempt faulted
    monkeypatch.delenv("REPRO_FAULT_PLAN")
    assert eng2.stats()["restore_fallbacks"] >= 1
    assert eng2._pending_resume["cache"] is None    # degraded to cold
    eng2.serve(rec)
    assert [list(r.out_tokens) for r in rec] == base


def test_replay_divergence_detected(served, tmp_path):
    params, prompts, base = served
    eng = _engine(params, tmp_path)
    reqs = [eng.submit(p, NEW_TOKENS) for p in prompts]
    eng.serve(reqs)
    _crash_journal(tmp_path)
    # forge a journaled token: replay must notice the journal "lied"
    path = os.path.join(str(tmp_path), "journal.jsonl")
    lines = open(path).readlines()
    for i, line in enumerate(lines):
        env = json.loads(line)
        if env["rec"]["kind"] == "token" and env["rec"]["step"] == 1:
            env["rec"]["token"] = (env["rec"]["token"] + 1) % CFG.vocab_size
            env["sum"] = __import__("zlib").crc32(json.dumps(
                env["rec"], sort_keys=True,
                separators=(",", ":")).encode()) & 0xFFFFFFFF
            lines[i] = json.dumps(env) + "\n"
            break
    open(path, "w").writelines(lines)
    eng2 = _engine(params, tmp_path)
    rec = eng2.restore()
    eng2.serve(rec)
    # recomputed tokens win (they come from the live model)...
    assert [list(r.out_tokens) for r in rec] == base
    # ...and the divergence is ledgered loudly
    assert eng2.stats()["replay_divergence"] == 1
    assert eng2.monitor.events_of("replay-divergence")


def test_snapshot_save_fault_degrades_serving(served, tmp_path, monkeypatch):
    params, prompts, base = served
    monkeypatch.setenv("REPRO_FAULT_PLAN", "snapshot.save:*:raise")
    health.reset_faults()
    eng = _engine(params, tmp_path, snapshot_every=2)
    reqs = [eng.submit(p, NEW_TOKENS) for p in prompts]
    eng.serve(reqs)                     # snapshot failures must not fail it
    assert [list(r.out_tokens) for r in reqs] == base
    st = eng.stats()
    assert st["snapshot_errors"] >= 1 and st["snapshots_saved"] == 0
    assert eng.monitor.events_of("snapshot-error")


def test_midwrite_ckpt_fault_keeps_previous_snapshot(served, tmp_path,
                                                     monkeypatch):
    params, prompts, base = served
    eng = _engine(params, tmp_path, snapshot_every=2)
    reqs = [eng.submit(p, NEW_TOKENS) for p in prompts]
    # first snapshot (step 2) lands, second (step 4) dies mid-write
    monkeypatch.setenv("REPRO_FAULT_PLAN", "ckpt.write:1:raise")
    health.reset_faults()
    eng.serve(reqs)
    st = eng.stats()
    assert st["snapshots_saved"] >= 1 and st["snapshot_errors"] == 1
    assert eng.snapshots.latest_step() == 2     # previous snapshot intact
    monkeypatch.delenv("REPRO_FAULT_PLAN")
    _crash_journal(tmp_path, drop_tokens=1)
    eng2 = _engine(params, tmp_path)
    rec = eng2.restore()
    eng2.serve(rec)
    assert [list(r.out_tokens) for r in rec] == base


def test_restore_without_journal_raises(served):
    params, _, _ = served
    eng = Engine(CFG, params, max_len=MAX_LEN)
    with pytest.raises(ValueError, match="journal"):
        eng.restore()


def test_restore_before_any_serve_requeues(served, tmp_path):
    params, prompts, _ = served
    eng = _engine(params, tmp_path)
    eng.submit(prompts[0], NEW_TOKENS)          # admitted, never served
    eng2 = _engine(params, tmp_path)
    rec = eng2.restore()
    assert [r.state for r in rec] == [RequestState.QUEUED]
    assert eng2._pending_resume is None         # plain serve() works
    eng2.serve(rec)
    assert rec[0].state == RequestState.DONE


def test_elastic_restore_onto_planned_mesh(served, tmp_path):
    """Snapshot restore through plan_remesh target shardings — the
    surviving-devices path, exercised on the local device set."""
    params, prompts, base = served
    eng = _engine(params, tmp_path, snapshot_every=2)
    reqs = [eng.submit(p, NEW_TOKENS) for p in prompts]
    eng.serve(reqs)
    _crash_journal(tmp_path, drop_tokens=1)
    eng2 = _engine(params, tmp_path)
    rec = eng2.restore(devices=jax.devices())
    assert eng2._pending_resume is not None
    assert eng2._pending_resume["cache"] is not None
    eng2.serve(rec)
    assert [list(r.out_tokens) for r in rec] == base


# ---------------------------------------------------------------------------
# Subprocess SIGKILL drills: a real kill, a real restart.
# ---------------------------------------------------------------------------
DRIVER = textwrap.dedent("""
    import json, sys
    import numpy as np
    import jax
    from repro import configs
    from repro.models import lm
    from repro.serve.engine import Engine

    mode, jdir, out = sys.argv[1], sys.argv[2], sys.argv[3]
    cfg = configs.get_smoke("qwen3-1.7b")
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=%(max_len)d, journal_dir=jdir,
                 snapshot_every=2)
    if mode == "resume":
        reqs = eng.restore()
        eng.serve(reqs)
    else:
        rng = np.random.default_rng(0)
        prompts = rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
        reqs = [eng.submit(p, %(new_tokens)d) for p in prompts]
        eng.serve(reqs)
    stats = {k: v for k, v in eng.stats().items() if isinstance(v, int)}
    json.dump({"tokens": {str(r.rid): list(r.out_tokens) for r in reqs},
               "states": {str(r.rid): r.state.value for r in reqs},
               "stats": stats}, open(out, "w"))
""" % {"max_len": MAX_LEN, "new_tokens": NEW_TOKENS})


def _run_driver(script, mode, jdir, out, plan=None, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("REPRO_FAULT_PLAN", None)
    if plan is not None:
        env["REPRO_FAULT_PLAN"] = plan
    return subprocess.run(
        [sys.executable, script, mode, str(jdir), str(out)],
        env=env, timeout=timeout,
        stdout=subprocess.PIPE, stderr=subprocess.PIPE)


def _drill_seed():
    return int(os.environ.get("REPRO_CRASH_DRILL_SEED", "0"))


# kill points: the journal append path, decode mid-step, and the
# snapshot mid-write window; hit ranges keep both submits durable
KILL_SITES = [
    ("journal.append", (4, 10)),
    ("serve.decode_step", (1, 4)),
    ("ckpt.write", (0, 1)),
]


@pytest.mark.parametrize("site,hit_range",
                         KILL_SITES, ids=[s for s, _ in KILL_SITES])
def test_sigkill_then_restart_bit_exact(served, tmp_path, site, hit_range):
    _, _, base = served
    rnd = random.Random(f"{_drill_seed()}|{site}")
    hit = rnd.randint(*hit_range)
    script = tmp_path / "driver.py"
    script.write_text(DRIVER)
    jdir = tmp_path / "journal"
    out1, out2 = tmp_path / "out1.json", tmp_path / "out2.json"

    proc = _run_driver(script, "run", jdir, out1,
                       plan=f"{site}:{hit}:kill")
    assert proc.returncode == -9, (site, hit, proc.stderr.decode()[-2000:])
    assert not out1.exists()            # SIGKILL: no output, no cleanup

    # which requests does the journal owe us? exactly the durable submits
    j = RequestJournal(str(jdir))
    owed = sorted(r["rid"] for r in j.scan() if r["kind"] == "submit")

    proc = _run_driver(script, "resume", jdir, out2)
    assert proc.returncode == 0, (site, hit, proc.stderr.decode()[-2000:])
    result = json.load(open(out2))
    got = {int(rid): toks for rid, toks in result["tokens"].items()}
    assert sorted(got) == owed, (site, hit, result)
    for rid in owed:
        # bit-identical to the uninterrupted run: nothing lost, nothing
        # duplicated, nothing FAILED
        assert result["states"][str(rid)] == "done", (site, hit, result)
        assert got[rid] == base[rid], (site, hit, result)
    assert result["stats"]["failed"] == 0
    assert result["stats"]["replay_divergence"] == 0


def test_sigkill_during_restore_is_survivable(served, tmp_path):
    """A second crash *during recovery* must leave a recoverable state:
    restore is read-only until serving resumes."""
    _, _, base = served
    script = tmp_path / "driver.py"
    script.write_text(DRIVER)
    jdir = tmp_path / "journal"
    out = tmp_path / "out.json"

    proc = _run_driver(script, "run", jdir, out,
                       plan="serve.decode_step:2:kill")
    assert proc.returncode == -9
    proc = _run_driver(script, "resume", jdir, out,
                       plan="engine.restore:0:kill")
    assert proc.returncode == -9
    proc = _run_driver(script, "resume", jdir, out)
    assert proc.returncode == 0, proc.stderr.decode()[-2000:]
    result = json.load(open(out))
    assert all(s == "done" for s in result["states"].values())
    assert [result["tokens"][str(i)] for i in sorted(
        int(k) for k in result["tokens"])] == base
