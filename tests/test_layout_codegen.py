"""Layout-chain DP (paper §IV-C) + code generator tests."""
import random

import hypothesis.strategies as st
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings

from repro.core import codegen, layout
from repro.core.dataflow import DataflowSpec, GemmProblem, Residency, OS, WS

options = st.builds(
    layout.LayerOption,
    layout=st.sampled_from(["NCHWc128", "NHWC", "CHWN"]),
    dataflow=st.sampled_from(["os", "ws", "is"]),
    cost=st.floats(0.0, 10.0, allow_nan=False),
    out_bytes=st.integers(0, 10**9),
)
chains = st.lists(st.lists(options, min_size=1, max_size=4),
                  min_size=1, max_size=6)


@given(chains, st.booleans())
@settings(max_examples=60, deadline=None)
def test_chain_dp_matches_brute_force(chain, flexible):
    got = layout.optimize_chain(chain, flexible)
    want = layout.brute_force_chain(chain, flexible)
    assert abs(got[0] - want[0]) < 1e-9
    # the chosen path realizes the claimed cost
    cost = sum(chain[i][j].cost for i, j in enumerate(got[1]))
    for i in range(1, len(got[1])):
        cost += layout.transition_cost(
            chain[i - 1][got[1][i - 1]], chain[i][got[1][i]], flexible)
    assert abs(cost - got[0]) < 1e-9


def test_flexible_writes_make_transitions_free():
    a = layout.LayerOption("NHWC", "os", 1.0, out_bytes=10**9)
    b = layout.LayerOption("NCHWc128", "os", 1.0, out_bytes=10**9)
    assert layout.transition_cost(a, b, flexible_writes=True) == 0.0
    assert layout.transition_cost(a, b, flexible_writes=False) > 0.0


def test_generated_source_executes_and_matches():
    p = GemmProblem(m=256, k=256, n=256, in_dtype="float32")
    spec = DataflowSpec(OS, {WS: Residency.STRIPE}, (WS,), (128, 128, 128))
    src = codegen.generate_source(p, spec)
    ns = {}
    exec(compile(src, "<generated>", "exec"), ns)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    out = ns["kernel"](a, b, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                               rtol=1e-5, atol=1e-3)
    assert ns["SPEC"] == spec


def test_describe_plan_mentions_residency():
    p = GemmProblem(m=1024, k=1024, n=1024)
    spec = DataflowSpec.optimized()
    text = codegen.describe_plan(p, spec)
    assert "anchor=output" in text
    assert "stripe" in text


def test_build_matmul_callable():
    p = GemmProblem(m=128, k=128, n=128, in_dtype="float32")
    fn = codegen.build_matmul(p, DataflowSpec.basic(OS), interpret=True)
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
    np.testing.assert_allclose(np.asarray(fn(a, b)), np.asarray(a @ b),
                               rtol=1e-5, atol=1e-3)
