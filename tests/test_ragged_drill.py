"""Ragged crash drill (PR 8): SIGKILL a continuous-batching drain
mid-step, restart, and demand bit-identical recovery.

The batch-synchronous crash drills (test_crash_recovery.py) pin the
equal-length path; this drill pins the continuous path: a
mixed-prompt-length batch routes through the scheduler (per-step
admission, per-row banded decode), the journal records the drain in
``mode="continuous"``, and a cold replay re-enqueues the same rids
through a fresh scheduler.  Admission order, slot assignment and the
fixed-shape ragged cache are deterministic, so the recovered greedy
streams must equal the uninterrupted run's exactly.

Run standalone (the crash-drill CI job's ragged-drill step):

    PYTHONPATH=src python -m pytest -x -q tests/test_ragged_drill.py
"""
import json
import os
import random
import subprocess
import sys
import textwrap

import pytest

from repro.serve.journal import RequestJournal

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
MAX_LEN = 48
NEW_TOKENS = 5
LENS = [7, 12, 2, 23]           # mixed: spans short/long, unaligned

DRIVER = textwrap.dedent("""
    import json, sys
    import numpy as np
    import jax
    from repro import configs
    from repro.models import lm
    from repro.serve.engine import Engine

    mode, jdir, out = sys.argv[1], sys.argv[2], sys.argv[3]
    cfg = configs.get_smoke("qwen3-1.7b")
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=%(max_len)d, journal_dir=jdir)
    if mode == "resume":
        reqs = eng.restore()
        eng.serve(reqs)
    else:
        rng = np.random.default_rng(0)
        lens = %(lens)r
        prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
                   for n in lens]
        reqs = [eng.submit(p, %(new_tokens)d) for p in prompts]
        eng.serve(reqs)           # ragged: continuous scheduler
    stats = {k: v for k, v in eng.stats().items() if isinstance(v, int)}
    json.dump({"tokens": {str(r.rid): list(r.out_tokens) for r in reqs},
               "states": {str(r.rid): r.state.value for r in reqs},
               "stats": stats}, open(out, "w"))
""" % {"max_len": MAX_LEN, "new_tokens": NEW_TOKENS, "lens": LENS})


def _run_driver(script, mode, jdir, out, plan=None, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("REPRO_FAULT_PLAN", None)
    if plan is not None:
        env["REPRO_FAULT_PLAN"] = plan
    return subprocess.run(
        [sys.executable, script, mode, str(jdir), str(out)],
        env=env, timeout=timeout,
        stdout=subprocess.PIPE, stderr=subprocess.PIPE)


def _drill_seed():
    return int(os.environ.get("REPRO_CRASH_DRILL_SEED", "0"))


@pytest.fixture(scope="module")
def base_tokens(tmp_path_factory):
    """The uninterrupted ragged run, in its own process (same
    environment as the drilled runs)."""
    tmp = tmp_path_factory.mktemp("ragged-base")
    script = tmp / "driver.py"
    script.write_text(DRIVER)
    out = tmp / "out.json"
    proc = _run_driver(script, "run", tmp / "journal", out)
    assert proc.returncode == 0, proc.stderr.decode()[-2000:]
    result = json.load(open(out))
    assert all(s == "done" for s in result["states"].values()), result
    return {int(rid): toks for rid, toks in result["tokens"].items()}


# decode mid-step and the journal-append window; hit ranges keep all
# four submits durable but land inside the continuous drain
RAGGED_KILL_SITES = [
    ("serve.decode_step", (2, 6)),
    ("journal.append", (10, 18)),
]


@pytest.mark.parametrize("site,hit_range", RAGGED_KILL_SITES,
                         ids=[s for s, _ in RAGGED_KILL_SITES])
def test_ragged_sigkill_then_restart_bit_exact(tmp_path, base_tokens,
                                               site, hit_range):
    rnd = random.Random(f"{_drill_seed()}|ragged|{site}")
    hit = rnd.randint(*hit_range)
    script = tmp_path / "driver.py"
    script.write_text(DRIVER)
    jdir = tmp_path / "journal"
    out1, out2 = tmp_path / "out1.json", tmp_path / "out2.json"

    proc = _run_driver(script, "run", jdir, out1,
                       plan=f"{site}:{hit}:kill")
    assert proc.returncode == -9, (site, hit, proc.stderr.decode()[-2000:])
    assert not out1.exists()

    j = RequestJournal(str(jdir))
    recs = j.scan()
    owed = sorted(r["rid"] for r in recs if r["kind"] == "submit")
    serves = [r for r in recs if r.get("kind") == "serve"]
    assert serves and serves[-1].get("mode") == "continuous", serves

    proc = _run_driver(script, "resume", jdir, out2)
    assert proc.returncode == 0, (site, hit, proc.stderr.decode()[-2000:])
    result = json.load(open(out2))
    got = {int(rid): toks for rid, toks in result["tokens"].items()}
    assert sorted(got) == owed, (site, hit, result)
    for rid in owed:
        assert result["states"][str(rid)] == "done", (site, hit, result)
        assert got[rid] == base_tokens[rid], (site, hit, result)
    assert result["stats"]["failed"] == 0
    assert result["stats"]["replay_divergence"] == 0
    assert result["stats"]["replayed_steps"] >= 0
