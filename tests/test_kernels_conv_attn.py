"""Conv2d / attention / binary kernels vs oracles (interpret mode)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.dataflow import DataflowSpec, OS, WS
from repro.kernels import ops, ref

CONV_CASES = [
    # (ih, iw, fh, fw, s, cin, cout)
    (14, 14, 3, 3, 1, 128, 128),
    (15, 13, 3, 3, 2, 64, 96),
    (12, 12, 5, 5, 1, 32, 128),
    (16, 16, 4, 4, 2, 128, 256),
    (10, 10, 1, 1, 1, 64, 64),
]


@pytest.mark.parametrize("case", CONV_CASES)
@pytest.mark.parametrize("anchor", [OS, WS])
def test_conv2d_dataflows(case, anchor):
    ih, iw, fh, fw, s, cin, cout = case
    rng = np.random.default_rng(hash(case) % 2**31)
    x = jnp.asarray(rng.normal(size=(2, ih, iw, cin)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(fh, fw, cin, cout)), jnp.float32)
    got = ops.conv2d(x, w, stride=s, spec=DataflowSpec.basic(anchor),
                     backend="interpret", b_oh=4)
    want = ref.conv2d_ref(x, w, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-2)


def test_conv2d_int8_exact():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-10, 10, (1, 14, 14, 128)), jnp.int8)
    w = jnp.asarray(rng.integers(-10, 10, (3, 3, 128, 128)), jnp.int8)
    got = ops.conv2d(x, w, stride=1, spec=DataflowSpec.basic(OS),
                     backend="interpret", b_oh=4)
    assert bool(jnp.all(got == ref.conv2d_ref(x, w, 1)))


ATTN_CASES = [
    # (b, hq, hkv, sq, skv, window)
    (2, 4, 2, 256, 256, None),
    (1, 8, 2, 200, 200, None),
    (2, 4, 4, 128, 384, None),   # decode-ish: kv longer than q
    (1, 4, 2, 256, 256, 128),    # sliding window
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("anchor", ["os", "ws"])
def test_attention_dataflows(case, anchor):
    b, hq, hkv, sq, skv, win = case
    rng = np.random.default_rng(hash(case) % 2**31)
    q = jnp.asarray(rng.normal(size=(b, hq, sq, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, skv, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, skv, 64)), jnp.float32)
    got = ops.attention(q, k, v, causal=True, window=win,
                        backend="interpret", anchor=anchor)
    want = ref.attention_ref(q, k, v, causal=True, window=win)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=2e-3)


def test_kv_stationary_single_dispatch():
    """WS attention must issue exactly ONE pallas_call regardless of the
    number of KV blocks (previously one aliased call per KV block)."""
    import jax
    from repro.core.jaxpr_utils import count_pallas_calls

    for skv in (256, 512):   # 2 and 4 KV blocks
        q = jnp.zeros((2, 4, 256, 64), jnp.float32)
        k = jnp.zeros((2, 2, skv, 64), jnp.float32)
        jx = jax.make_jaxpr(
            lambda q, k, v: ops.attention(q, k, v, backend="interpret",
                                          anchor="ws"))(q, k, k)
        assert count_pallas_calls(jx.jaxpr) == 1, (skv, jx)


def test_binary_matmul_exact():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.choice([-1.0, 1.0], (200, 256)), jnp.float32)
    w = jnp.asarray(rng.choice([-1.0, 1.0], (256, 300)), jnp.float32)
    apk = ref.pack_binary(a, axis=1)
    wpk = ref.pack_binary(w, axis=0)
    got = ops.binary_matmul(apk, wpk, n_bits=256, backend="interpret")
    want = (a @ w).astype(jnp.int32)
    assert bool(jnp.all(got == want))


def test_int8_matmul_dequant():
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.normal(size=(130, 256)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(256, 140)), jnp.float32)
    aq, asc = ref.quantize_int8(a, axis=1)
    bq, bsc = ref.quantize_int8(b, axis=0)
    got = ops.int8_matmul(aq, bq, asc, bsc, backend="interpret")
    want = ref.int8_matmul_ref(aq, bq, asc, bsc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
    # quantized result approximates the fp matmul
    rel = float(jnp.linalg.norm(got - a @ b) / jnp.linalg.norm(a @ b))
    assert rel < 0.05, rel


def test_grouped_conv_matches_per_group_dense():
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(1, 10, 10, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 8)), jnp.float32)  # 2 groups
    got = ref.grouped_conv2d_ref(x, w, stride=1, groups=2)
    # manual: group 0 = x[..., :4] conv w[..., :4]; group 1 likewise
    g0 = ref.conv2d_ref(x[..., :4], w[..., :4], 1)
    g1 = ref.conv2d_ref(x[..., 4:], w[..., 4:], 1)
    want = jnp.concatenate([g0, g1], axis=-1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_depthwise_conv_matches_grouped():
    rng = np.random.default_rng(12)
    c = 6
    x = jnp.asarray(rng.normal(size=(2, 9, 9, c)), jnp.float32)
    wd = jnp.asarray(rng.normal(size=(3, 3, c)), jnp.float32)
    got = ref.depthwise_conv2d_ref(x, wd, stride=2)
    # grouped equivalent: (fh, fw, 1, C) with identity group structure
    wg = wd[:, :, None, :] * np.eye(c)[None, None][..., :, :]  # (3,3,c,c)
    want = ref.conv2d_ref(x, jnp.asarray(wg, jnp.float32), 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
