"""Test-suite bootstrap.

Provides a minimal, deterministic stand-in for ``hypothesis`` when the
real package is not installed (the kernel container ships without it).
The stub replays a fixed number of pseudo-random examples per property
(seeded from the test name), supporting exactly the API surface this
suite uses: ``given``, ``settings``, ``assume`` and the strategies
``integers``, ``floats``, ``booleans``, ``sampled_from``, ``lists`` and
``builds``.  When the real hypothesis is importable it is used as-is.
"""
from __future__ import annotations

import random
import sys
import types


def _install_hypothesis_stub() -> None:
    class UnsatisfiedAssumption(Exception):
        pass

    class Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rnd: random.Random):
            return self._sample(rnd)

    def integers(min_value, max_value):
        return Strategy(lambda rnd: rnd.randint(min_value, max_value))

    def floats(min_value=0.0, max_value=1.0, allow_nan=False,
               allow_infinity=False, **_kw):
        return Strategy(lambda rnd: rnd.uniform(min_value, max_value))

    def booleans():
        return Strategy(lambda rnd: rnd.random() < 0.5)

    def sampled_from(elements):
        elements = list(elements)
        return Strategy(lambda rnd: rnd.choice(elements))

    def lists(elements, min_size=0, max_size=None):
        hi = max_size if max_size is not None else min_size + 5

        def sample(rnd):
            return [elements.sample(rnd)
                    for _ in range(rnd.randint(min_size, hi))]

        return Strategy(sample)

    def builds(target, *arg_strats, **kw_strats):
        def sample(rnd):
            args = [s.sample(rnd) for s in arg_strats]
            kwargs = {k: s.sample(rnd) for k, s in kw_strats.items()}
            return target(*args, **kwargs)

        return Strategy(sample)

    def assume(condition):
        if not condition:
            raise UnsatisfiedAssumption()
        return True

    def settings(**kw):
        def deco(fn):
            fn._stub_settings = kw
            return fn

        return deco

    _MAX_EXAMPLES_CAP = 20  # keep the deterministic replay fast

    def given(*strategies):
        def deco(fn):
            declared = getattr(fn, "_stub_settings", {})

            def wrapper():
                cfg = getattr(wrapper, "_stub_settings", None) or declared
                n = min(cfg.get("max_examples", 10), _MAX_EXAMPLES_CAP)
                rnd = random.Random(fn.__qualname__)
                ran = 0
                attempts = 0
                while ran < n and attempts < 10 * n:
                    attempts += 1
                    try:
                        fn(*[s.sample(rnd) for s in strategies])
                    except UnsatisfiedAssumption:
                        continue
                    ran += 1

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__module__ = fn.__module__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.UnsatisfiedAssumption = UnsatisfiedAssumption
    strat_mod = types.ModuleType("hypothesis.strategies")
    for name, obj in [
        ("integers", integers), ("floats", floats), ("booleans", booleans),
        ("sampled_from", sampled_from), ("lists", lists), ("builds", builds),
    ]:
        setattr(strat_mod, name, obj)
    mod.strategies = strat_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strat_mod


try:  # pragma: no cover - trivially environment-dependent
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_stub()


def pytest_configure(config):
    """Point the autotune spec cache at a throwaway path so test runs
    never touch (or depend on) the user's ~/.cache store."""
    import os
    import tempfile

    if "REPRO_AUTOTUNE_CACHE" not in os.environ:
        os.environ["REPRO_AUTOTUNE_CACHE"] = os.path.join(
            tempfile.mkdtemp(prefix="repro-autotune-"), "cache.json"
        )


def _install_shard_map_alias() -> None:
    """jax.shard_map graduated from jax.experimental in newer releases;
    alias it on older jax so tests run unmodified on both.  The old
    experimental replication checker has known false positives (e.g. on
    scan carries — its own error message suggests check_rep=False as the
    workaround), so the alias defaults it off."""
    import functools

    import jax

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map

        @functools.wraps(shard_map)
        def compat(f, **kw):
            kw.setdefault("check_rep", False)
            return shard_map(f, **kw)

        jax.shard_map = compat


_install_shard_map_alias()
