"""SSM (SSD) and MoE unit/property tests."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs.base import ArchConfig
from repro.models import lm, moe as moe_lib, ssm as ssm_lib


def _ssm_cfg(**kw):
    base = dict(name="t-ssm", family="ssm", n_layers=1, d_model=32,
                n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=64,
                ssm_state=8, ssm_headdim=8, ssm_chunk=4, ssm_expand=2,
                param_dtype="float32", act_dtype="float32")
    base.update(kw)
    return ArchConfig(**base)


@pytest.mark.parametrize("l", [4, 8, 12, 20])
def test_ssd_chunked_matches_recurrence(l):
    """Chunked SSD (prefill) == per-token recurrence (decode) run over the
    same sequence — the state-space duality itself."""
    cfg = _ssm_cfg()
    key = jax.random.PRNGKey(0)
    p = ssm_lib.init_mamba(key, cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, l, cfg.d_model)) * 0.3, jnp.float32)
    y_chunk, _ = ssm_lib.mamba_apply(p, x, cfg, state=None)
    state = ssm_lib.init_ssm_state(cfg, 2)
    y_rec, _ = ssm_lib.mamba_apply(p, x, cfg, state=state)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                               rtol=1e-4, atol=1e-4)


def test_ssd_state_carries_across_calls():
    cfg = _ssm_cfg()
    p = ssm_lib.init_mamba(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 8, cfg.d_model)) * 0.3, jnp.float32)
    # all at once (recurrent to track state)
    st0 = ssm_lib.init_ssm_state(cfg, 1)
    y_all, _ = ssm_lib.mamba_apply(p, x, cfg, state=st0)
    # split into two recurrent calls
    st1 = ssm_lib.init_ssm_state(cfg, 1)
    y1, st1 = ssm_lib.mamba_apply(p, x[:, :4], cfg, state=st1)
    y2, _ = ssm_lib.mamba_apply(p, x[:, 4:], cfg, state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_all), rtol=1e-4, atol=1e-4)


def _moe_cfg(**kw):
    base = dict(name="t-moe", family="moe", n_layers=1, d_model=32,
                n_heads=2, n_kv_heads=2, d_ff=48, vocab_size=64, d_head=16,
                n_experts=8, top_k=2, capacity_factor=8.0,
                param_dtype="float32", act_dtype="float32")
    base.update(kw)
    return ArchConfig(**base)


@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 2, 4]),
       st.sampled_from([4, 8]))
@settings(max_examples=20, deadline=None)
def test_moe_routing_properties(seed, top_k, n_experts):
    """Gates renormalize to 1; every kept token's output is a convex
    combination of expert outputs; aux loss >= 1 (balanced == 1)."""
    cfg = _moe_cfg(top_k=top_k, n_experts=n_experts)
    p = moe_lib.init_moe(jax.random.PRNGKey(seed % 2**31), cfg)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    top_g, top_e, aux = moe_lib._route(
        x.reshape(-1, cfg.d_model), p["router"], top_k)
    np.testing.assert_allclose(np.asarray(top_g.sum(-1)), 1.0, rtol=1e-5)
    assert bool(jnp.all((top_e >= 0) & (top_e < n_experts)))
    assert float(aux) >= 0.99  # E * sum f_e p_e >= 1 at balance


def test_moe_no_drop_equals_dense_expert_sum():
    """With capacity >= all assignments, the MoE output equals the explicit
    gate-weighted sum of expert FFNs."""
    cfg = _moe_cfg(capacity_factor=100.0)
    p = moe_lib.init_moe(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(1, 6, cfg.d_model)), jnp.float32)
    y, _ = moe_lib.moe_apply(p, x, cfg)
    # explicit dense computation
    xf = x.reshape(-1, cfg.d_model)
    top_g, top_e, _ = moe_lib._route(xf, p["router"], cfg.top_k)
    want = jnp.zeros_like(xf)
    for e in range(cfg.n_experts):
        gate = jnp.where(top_e == e, top_g, 0.0).sum(-1)   # (T,)
        h = jax.nn.silu(xf @ p["w1"][e]) * (xf @ p["w3"][e])
        want = want + gate[:, None] * (h @ p["w2"][e])
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    cfg = _moe_cfg(capacity_factor=0.1)   # tiny capacity forces drops
    p = moe_lib.init_moe(jax.random.PRNGKey(4), cfg)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    y, _ = moe_lib.moe_apply(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_shared_experts_added():
    cfg = _moe_cfg(n_shared_experts=1)
    p = moe_lib.init_moe(jax.random.PRNGKey(5), cfg)
    assert "shared" in p
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(1, 4, cfg.d_model)), jnp.float32)
    y, _ = moe_lib.moe_apply(p, x, cfg)
    assert y.shape == x.shape
