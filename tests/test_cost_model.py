"""Property tests on the cost model (Table-I analogue + TPU traffic)."""
import hypothesis.strategies as st
from hypothesis import assume, given, settings

from repro.core import cost_model, explorer
from repro.core.dataflow import (
    ConvProblem, DataflowSpec, GemmProblem, Residency, IS, OS, WS,
)

conv_problems = st.builds(
    ConvProblem,
    ih=st.integers(12, 128), iw=st.integers(12, 128),
    fh=st.integers(2, 5), fw=st.integers(2, 5),
    s=st.integers(1, 2),
    cin=st.sampled_from([32, 64, 128]), cout=st.sampled_from([64, 128]),
)

gemm_problems = st.builds(
    GemmProblem,
    m=st.sampled_from([256, 1024, 4096]),
    k=st.sampled_from([256, 1024, 4096]),
    n=st.sampled_from([256, 1024, 4096]),
)


@given(conv_problems)
@settings(max_examples=60, deadline=None)
def test_paper_observations_hold_for_all_layers(conv):
    # The paper's Observations 1, 3, 4, 5 re-derived from Table I.
    # Hypothesis found the regime boundary of Observation 1: when the
    # output tensor is barely larger than the filter (E < 2R — e.g.
    # 12x12 input, 5x5 filter, stride 2), WS's per-variable gain (R reads
    # + R writes) exceeds OS's (E reads), inverting the observation.  The
    # paper's layer grid (56/112 inputs, 3-5 filters) always has E >> R,
    # so we assert within that stated regime and record the boundary in
    # EXPERIMENTS.md SPaper-validation.
    assume(conv.E >= 2 * conv.R)
    obs = cost_model.paper_observations_hold(conv)
    assert all(obs.values()), obs


@given(gemm_problems)
@settings(max_examples=40, deadline=None)
def test_traffic_at_least_compulsory(p):
    # No dataflow moves fewer bytes than one read of each input + one
    # write of the output (compulsory traffic).
    compulsory = (p.m * p.k + p.k * p.n) * 2 + p.m * p.n * 4
    for anchor in (OS, WS, IS):
        t = cost_model.gemm_traffic(p, DataflowSpec.basic(anchor))
        assert t.total >= compulsory


@given(gemm_problems)
@settings(max_examples=40, deadline=None)
def test_basic_os_never_worse_than_ws_is(p):
    # Paper Fig. 2: among basic dataflows OS wins (no output RMW term).
    tos = cost_model.gemm_traffic(p, DataflowSpec.basic(OS)).total
    tws = cost_model.gemm_traffic(p, DataflowSpec.basic(WS)).total
    tis = cost_model.gemm_traffic(p, DataflowSpec.basic(IS)).total
    assert tos <= tws
    assert tos <= tis


@given(gemm_problems)
@settings(max_examples=30, deadline=None)
def test_aux_stationarity_never_increases_traffic(p):
    base = cost_model.gemm_traffic(p, DataflowSpec.basic(OS)).total
    ext = cost_model.gemm_traffic(
        p, DataflowSpec(OS, {WS: Residency.STRIPE}, (WS,))).total
    assert ext <= base


def test_explorer_picks_paper_optimized_dataflow():
    # Alg. 8: the best dataflow is OS-anchored with weight-aux first.
    p = GemmProblem(m=4096, k=4096, n=4096)
    best = explorer.best_spec(p)
    assert best.anchor == OS
    assert best.residency(WS) != Residency.STREAMED


def test_explorer_all_candidates_feasible():
    p = GemmProblem(m=2048, k=2048, n=2048)
    for c in explorer.enumerate_candidates(p):
        assert c.feasible
        assert c.traffic_bytes > 0


@given(conv_problems)
@settings(max_examples=30, deadline=None)
def test_conv_traffic_resident_input_bounded_by_unique_bytes(conv):
    spec = DataflowSpec(OS, {IS: Residency.WHOLE}, (IS,))
    t = cost_model.conv_traffic(conv, spec)
    unique = conv.n * conv.H * conv.cin
    assert t.reads[IS] == unique  # whole-resident: exactly one full read
    if conv.s == 1:
        # overlapping windows (s=1): residency can only reduce traffic.
        # (for s>1 a resident input may read unused pixels the streamed
        # form skips — the paper's sparse-reuse caveat, Fig. 5)
        streamed = cost_model.conv_traffic(conv, DataflowSpec.basic(OS))
        assert t.reads[IS] <= streamed.reads[IS]


def test_roofline_terms_and_dominance():
    r = cost_model.roofline(flops=1e15, hbm_bytes=1e12, collective_bytes=1e10,
                            chips=256)
    assert r.t_compute > 0 and r.t_memory > 0 and r.t_collective > 0
    assert r.dominant in ("compute", "memory", "collective")
    assert abs(r.t_compute - 1e15 / (256 * 197e12)) < 1e-12
    assert abs(r.t_memory - 1e12 / (256 * 819e9)) < 1e-12
    assert abs(r.t_collective - 1e10 / (256 * 50e9)) < 1e-12
    assert 0 <= r.compute_fraction <= 1


def test_model_flops():
    assert cost_model.model_flops(1e9, 1e6) == 6e15
    assert cost_model.model_flops(1e9, 1e6, training=False) == 2e15
