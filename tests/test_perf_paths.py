"""Tests for the §Perf optimization paths (banded SWA, segmented scan,
int8 all-to-all, bf16-projected collective accounting)."""
import dataclasses
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels import ref
from repro.launch import hlo_analysis
from repro.models import flags, layers, lm, moe as moe_lib


@pytest.mark.parametrize("case", [(2, 4, 2, 256, 32), (1, 5, 1, 300, 64),
                                  (2, 4, 4, 512, 128)])
def test_banded_swa_matches_oracle(case):
    """The banded-SWA form (demoted to a ref oracle in PR 5 — the
    runtime banding now lives in the Pallas kernel grid) still matches
    the masked oracle in both its scan and exact-cost lowerings."""
    b, hq, hkv, s, w = case
    d = 32
    rng = np.random.default_rng(hash(case) % 2**31)
    q = jnp.asarray(rng.normal(size=(b, hq, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    want = ref.attention_ref(q, k, v, causal=True, window=w)
    got = ref.banded_swa_attention_ref(q, k, v, w, d ** -0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=2e-3)
    with flags.exact_cost_mode():
        got_e = ref.banded_swa_attention_ref(q, k, v, w, d ** -0.5)
    np.testing.assert_allclose(np.asarray(got_e), np.asarray(want),
                               rtol=1e-4, atol=2e-3)


def test_window_segments_cover_stack():
    cfg = dataclasses.replace(configs.get("hymba-1.5b"))
    segs = lm._window_segments(cfg)
    assert segs[0] == (0, 1, None)             # first layer full attention
    assert segs[-1] == (cfg.n_layers - 1, cfg.n_layers, None)
    covered = []
    for s, e, _ in segs:
        covered.extend(range(s, e))
    assert covered == list(range(cfg.n_layers))
    full = [w for _, _, w in segs if w is None]
    assert len(full) == 3                      # first / middle / last


def test_segmented_forward_equals_traced_scan():
    cfg = dataclasses.replace(configs.get_smoke("hymba-1.5b"), n_layers=6)
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 48)), jnp.int32)
    x_seg, _ = lm.forward_hidden(params, tokens, cfg, remat="none")

    windows = lm.layer_windows(cfg)
    positions = jnp.arange(tokens.shape[1])[None, :]

    def body(x, scanned):
        x, _, aux = lm.layer_apply(
            scanned["lp"], x, cfg, window=scanned["window"],
            positions=positions, cache=None, cache_index=None,
            enc_out=None, dist=None)
        return x, aux

    x = layers.embed(params["embed"], tokens).astype(jnp.float32)
    x, _ = jax.lax.scan(body, x, {"lp": params["layers"],
                                  "window": windows})
    x_old = layers.rmsnorm({"scale": params["final_norm"]}, x, cfg.norm_eps)
    np.testing.assert_allclose(np.asarray(x_seg), np.asarray(x_old),
                               rtol=1e-4, atol=1e-4)


def test_int8_a2a_roundtrip_and_gradient():
    """Single-device axis: int8 a2a is identity up to quantization; the
    straight-through backward is the exact (unquantized) a2a."""
    mesh = jax.make_mesh((1,), ("ep",))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 4, 8, 16)), jnp.float32)

    spec = jax.sharding.PartitionSpec("ep")   # varying over the axis

    def f(x):
        return moe_lib._a2a(x, "ep", 0, 0).sum()

    g = jax.shard_map(
        jax.grad(f), mesh=mesh, in_specs=spec, out_specs=spec,
    )(x)
    np.testing.assert_allclose(np.asarray(g), 1.0)   # exact STE gradient

    def fwd(x):
        return moe_lib._a2a(x, "ep", 0, 0)

    y = jax.shard_map(
        fwd, mesh=mesh, in_specs=spec, out_specs=spec,
    )(x)
    # int8 quantization error bound: amax/127 per row
    err = np.abs(np.asarray(y) - np.asarray(x))
    bound = np.abs(np.asarray(x)).max(axis=-1, keepdims=True) / 127.0
    assert (err <= bound + 1e-6).all()


def test_bf16_projected_collective_bytes():
    hlo = """
  %ag = f32[1024]{0} all-gather(%x)
  %ar = bf16[1024]{0} all-reduce(%y)
"""
    stats = hlo_analysis.collective_stats(hlo)
    assert stats.total_bytes == 1024 * 4 + 1024 * 2
    assert stats.bf16_projected_bytes == 1024 * 2 + 1024 * 2


def test_mini_dryrun_on_fake_devices():
    """End-to-end dry-run lowering on 8 fake devices (subprocess so the
    XLA flag applies before jax init)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
import numpy as np
from repro import configs
from repro.configs.base import ShapeConfig, input_specs
from repro.launch import sharding
from repro.models import lm
from repro.optim import AdamW
from repro.train.step import make_train_step

cfg = configs.get_smoke("qwen3-moe-235b-a22b")
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
dist = lm.Dist(mesh=mesh, dp_axes=("data",), tp_axis="model")
shape = ShapeConfig("t", 32, 4, "train")
params_shape = jax.eval_shape(lambda: lm.init_model(cfg, jax.random.PRNGKey(0)))
p_sh = sharding.param_shardings(params_shape, mesh)
specs = input_specs(cfg, shape)
b_sh = sharding.batch_shardings(specs, mesh)
opt = AdamW(lr_fn=lambda s: 1e-3)
opt_shape = jax.eval_shape(opt.init, params_shape)
o_sh = sharding.opt_state_shardings(opt_shape, mesh)
step = make_train_step(cfg, opt, dist=dist, remat="full")
lowered = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh)).lower(
    params_shape, opt_shape, specs)
compiled = lowered.compile()
text = compiled.as_text()
assert "all-to-all" in text or "all-reduce" in text, "no collectives?!"
print("MINI_DRYRUN_OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        cwd="/root/repo", timeout=600,
    )
    assert "MINI_DRYRUN_OK" in out.stdout, out.stderr[-2000:]


def test_int8_kv_cache_decode_close_to_bf16():
    """Opt-in int8 KV cache: decode logits stay close to the bf16-cache
    run (per-position scales bound the quantization error)."""
    cfg = configs.get_smoke("qwen3-1.7b")
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 2, 20
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    _, c16 = lm.prefill(params, tokens[:, :S-1], cfg, max_len=S + 2)
    d16, _ = lm.decode_step(params, c16, tokens[:, S-1:S], cfg)
    _, c8 = lm.prefill(params, tokens[:, :S-1], cfg8, max_len=S + 2)
    assert c8["k"].dtype == jnp.int8 and "k_scale" in c8
    d8, _ = lm.decode_step(params, c8, tokens[:, S-1:S], cfg8)
    # int8 cache memory is ~half (+ small scales)
    bytes16 = c16["k"].size * 2
    bytes8 = c8["k"].size * 1 + c8["k_scale"].size * 4
    assert bytes8 < 0.6 * bytes16
    # logits close (quantization noise only)
    rel = float(jnp.max(jnp.abs(d8 - d16))
                / (jnp.max(jnp.abs(d16)) + 1e-9))
    assert rel < 0.05, rel
