"""Integration: training-loss-decreases, crash/resume bit-exactness,
straggler detection, elastic remesh planning, HLO collective parsing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import hlo_analysis
from repro.runtime import elastic, health
from repro.runtime.driver import TrainDriver, TrainJobConfig
from repro.runtime.health import SimulatedFailure


def _job(tmp, **kw):
    base = dict(arch=configs.get_smoke("qwen3-1.7b"), steps=10,
                global_batch=4, seq_len=32, ckpt_dir=str(tmp),
                ckpt_every=4, lr=1e-3)
    base.update(kw)
    return TrainJobConfig(**base)


def test_training_loss_decreases(tmp_path):
    job = _job(tmp_path, steps=30, seq_len=64, lr=3e-3)
    driver = TrainDriver(job)
    state = driver.init_state()
    losses = []
    for step in range(job.steps):
        batch = driver.dataset.batch(step)
        params, opt, metrics = driver._step_fn(
            state.params, state.opt_state, batch)
        losses.append(float(metrics["loss"]))
        state = type(state)(step + 1, params, opt, losses[-1])
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.2, (first, last)


def test_crash_resume_bit_exact(tmp_path):
    # uninterrupted run
    job = _job(tmp_path / "a")
    ref_state = TrainDriver(job).run()
    # crashed + resumed run
    job2 = _job(tmp_path / "b")
    os.environ["REPRO_FAIL_AT_STEP"] = "6"
    try:
        with pytest.raises(SimulatedFailure):
            TrainDriver(job2).run()
    finally:
        os.environ.pop("REPRO_FAIL_AT_STEP")
    resumed = TrainDriver(job2).run(resume=True)
    assert resumed.step == ref_state.step
    assert resumed.last_loss == pytest.approx(ref_state.last_loss, rel=1e-6)
    for a, b in zip(jax.tree.leaves(ref_state.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_detection():
    mon = health.HealthMonitor(window=16, threshold=2.0)
    flagged = []
    for i in range(20):
        flagged.append(mon.record(i, 0.1))
    assert not any(flagged)
    assert mon.record(20, 1.0)          # 10x median
    assert len(mon.stragglers) == 1


def test_elastic_largest_grid():
    assert elastic.largest_grid(256, 16, (16, 8, 4, 2, 1)) == (16, 16)
    assert elastic.largest_grid(240, 16, (16, 8, 4, 2, 1)) == (15, 16)
    assert elastic.largest_grid(7, 16, (16, 8, 4, 2, 1)) == (7, 1)
    assert elastic.largest_grid(12, 16, (16, 8, 4, 2, 1)) == (3, 4)
    assert elastic.largest_grid(1, 16, (16, 8, 4, 2, 1)) == (1, 1)


def test_elastic_plan_and_reshard_single_device(tmp_path):
    """Remesh planning + reshard on the (single) local device."""
    cfg = configs.get_smoke("qwen3-1.7b")
    from repro.models import lm
    from repro.optim import AdamW

    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    opt = AdamW(lr_fn=lambda _: 1e-3)
    opt_state = opt.init(params)
    params_shape = jax.eval_shape(lambda: params)
    opt_shape = jax.eval_shape(lambda: opt_state)
    plan = elastic.plan_remesh(jax.devices(), params_shape, opt_shape)
    assert plan.new_mesh.size == len(jax.devices())
    new_params = elastic.reshard(params, plan.param_shardings)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_collective_parser_on_synthetic_hlo():
    hlo = """
  %ag = bf16[16,256,4096]{2,1,0} all-gather(%x), replica_groups={}
  %ar = f32[1024]{0} all-reduce(%y), to_apply=%add
  %a2a = bf16[8,64,128]{2,1,0} all-to-all(%z)
  %rs = f32[128]{0} reduce-scatter(%w), dimensions={0}
  %cp = s32[4,4]{1,0} collective-permute(%v)
  %not_a_collective = f32[2,2]{1,0} add(%a, %b)
"""
    stats = hlo_analysis.collective_stats(hlo)
    assert stats.count_by_kind == {
        "all-gather": 1, "all-reduce": 1, "all-to-all": 1,
        "reduce-scatter": 1, "collective-permute": 1,
    }
    assert stats.bytes_by_kind["all-gather"] == 16 * 256 * 4096 * 2
    assert stats.bytes_by_kind["all-reduce"] == 1024 * 4
    assert stats.bytes_by_kind["all-to-all"] == 8 * 64 * 128 * 2
    assert stats.total_bytes > 0


def test_serve_engine_greedy_generation():
    from repro.serve.engine import Engine
    from repro.models import lm

    cfg = configs.get_smoke("qwen3-1.7b")
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, max_len=48)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    out = engine.generate(prompts, max_new_tokens=4)
    assert out.shape == (2, 4)
    assert out.dtype == np.int32
    assert (out >= 0).all() and (out < cfg.padded_vocab).all()
    # greedy decode is deterministic
    out2 = engine.generate(prompts, max_new_tokens=4)
    np.testing.assert_array_equal(out, out2)


def test_elastic_largest_grid_tie_breaking():
    # equal used-device counts break toward the larger model dim (less
    # activation memory per device, same throughput)
    assert elastic.largest_grid(8, 16, (8, 4, 2, 1)) == (1, 8)
    assert elastic.largest_grid(6, 4, (4, 2, 1)) == (3, 2)
    assert elastic.largest_grid(4, 2, (2, 1)) == (2, 2)
    # no divisor fits: fall back to pure data parallelism
    assert elastic.largest_grid(5, 16, (16, 8, 4, 2)) == (5, 1)


def test_elastic_degenerate_survivor_counts():
    with pytest.raises(ValueError):
        elastic.largest_grid(0, 16, (16, 8, 4, 2, 1))
    with pytest.raises(ValueError):
        elastic.largest_grid(-3, 16, (16, 8, 4, 2, 1))
    with pytest.raises(ValueError):
        elastic.plan_remesh([], params_shape=None)


def test_checkpoint_restore_onto_remesh_shardings(tmp_path):
    """Elastic restore: a snapshot written on one mesh loads bit-exactly
    through plan_remesh target shardings (the Engine.restore path)."""
    from repro.ckpt.checkpoint import Checkpointer
    from repro.models import lm

    cfg = configs.get_smoke("qwen3-1.7b")
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    cache = lm.init_cache(cfg, batch=2, max_len=16)
    ck = Checkpointer(str(tmp_path))
    ck.save(4, {"params": params, "cache": cache}, blocking=True)

    params_shape = jax.eval_shape(lambda: params)
    cache_shape = jax.eval_shape(lambda: cache)
    # inference restart: no optimizer state, but the KV cache reshards
    plan = elastic.plan_remesh(jax.devices(), params_shape,
                               cache_shape=cache_shape)
    assert plan.opt_shardings is None
    assert plan.cache_shardings is not None
    step, state, _ = ck.restore(
        {"params": params_shape, "cache": cache_shape},
        shardings={"params": plan.param_shardings,
                   "cache": plan.cache_shardings})
    assert step == 4
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(cache),
                    jax.tree.leaves(state["cache"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
