"""Roofline analysis per (arch x shape x mesh) from the dry-run artifacts.

Terms (task spec):
  compute    = HLO_FLOPs / (chips * 197 TF/s bf16)
  memory     = HBM_bytes / (chips * 819 GB/s)
  collective = collective_bytes / (chips * 50 GB/s/link)

Sources:
  * FLOPs + collective bytes: the dry-run's ``derived`` record (exact-mode
    L1/L2 extrapolation; per-device quantities — see dryrun.derive_costs).
  * HBM bytes: ``estimate_hbm_bytes`` below — an analytic per-device model
    (params / optimizer streams, activation carry, KV-cache reads, CE
    logit chunks).  The exact-mode HLO bytes are recorded as a
    cross-check but deliberately NOT used: exact mode materializes plain
    S x S attention, which the real (flash/chunked) pipeline never does.

Outputs benchmarks/results/roofline.md + CSV rows.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from benchmarks.common import emit
from repro import configs
from repro.configs.base import SHAPES
from repro.core.cost_model import V5E, model_flops, roofline

RESULTS = os.path.join(os.path.dirname(__file__), "results")
DRYRUN = os.path.join(RESULTS, "dryrun")


def estimate_hbm_bytes(rec: Dict, cfg, shape) -> float:
    """Per-device HBM bytes per step (documented approximation).

    train:  3 param reads per microbatch (fwd + remat recompute + bwd)
            + optimizer stream (grads f32 r+w, moments r+w, param write)
            + activation carry (save + 2 reads) + CE logit chunks (f32 r+w)
    prefill: 1 param read + activations + KV-cache write
    decode:  1 param read (active params for MoE) + full KV-cache read
             + SSM state r+w
    """
    chips = rec["chips"]
    mb = rec.get("microbatches", 1) or 1
    p_loc = cfg.param_count() * 2 / chips                     # bf16
    p_active_loc = cfg.active_param_count() * 2 / chips
    tokens_loc = shape.global_batch * shape.seq_len / chips * \
        (1 if shape.kind != "decode" else 0)
    d = cfg.d_model
    L = cfg.n_layers

    if shape.kind == "train":
        mdt = 2 if cfg.param_count() > 100e9 else 4
        opt_stream = p_loc / 2 * (4 + 4 + 2 * mdt + 2 * mdt) + p_loc
        param_stream = 3 * mb * p_active_loc
        act_carry = 3 * L * tokens_loc * d * 2
        # CE logit chunks: logits/chip = tokens_loc * V (sharded dp x tp);
        # ~4 f32 passes (fwd write+read, bwd recompute+grad)
        ce = 4 * tokens_loc * cfg.padded_vocab * 4
        return param_stream + opt_stream + act_carry + ce
    if shape.kind == "prefill":
        kv_write = (2 * cfg.kv_dim * tokens_loc * 2) * L if cfg.has_attention \
            else 0
        act = 2 * L * tokens_loc * d * 2
        return p_active_loc + act + kv_write
    # decode
    kv_read = 0.0
    if cfg.has_attention:
        # per layer: full valid KV history read once per step
        win_layers = 0
        full_layers = L
        if cfg.attn_window is not None:
            full = {0, L // 2, L - 1}
            win_layers = L - len(full)
            full_layers = len(full)
        skv_full = shape.seq_len
        skv_win = min(cfg.attn_window or 0, shape.seq_len)
        kv_read = (full_layers * skv_full + win_layers * skv_win) \
            * shape.global_batch * 2 * cfg.kv_dim * 2 / chips
    ssm = 0.0
    if cfg.has_ssm:
        ssm = 2 * L * shape.global_batch * cfg.ssm_heads * cfg.ssm_state \
            * cfg.ssm_headdim * 4 / chips
    return p_active_loc + kv_read + ssm


def load_records(mesh_tag: str = "16x16") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, f"*__{mesh_tag}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def analyse(rec: Dict) -> Optional[Dict]:
    cfg = configs.get(rec["arch"])
    shape = SHAPES[rec["shape"]]
    chips = rec["chips"]
    derived = rec.get("derived")
    if not derived or "flops" not in derived:
        return None
    flops_dev = derived["flops"]          # per-device (SPMD partition)
    # bf16-projected collective bytes (TPU toolchain projection; raw
    # XLA-CPU bytes carry a ~2x f32-emulation inflation — see hlo_analysis)
    coll_dev = derived.get("collective_bytes_bf16_projected",
                           derived["collective_bytes"])
    hbm_dev = estimate_hbm_bytes(rec, cfg, shape)
    # roofline() takes global quantities and divides by chips
    r = roofline(flops_dev * chips, hbm_dev * chips, coll_dev * chips,
                 chips=chips)
    tokens = shape.global_batch * shape.seq_len if shape.kind != "decode" \
        else shape.global_batch
    mf = model_flops(cfg.active_param_count(), tokens,
                     training=shape.kind == "train")
    mem = rec.get("memory", {})
    fits = (mem.get("argument_size_in_bytes", 0)
            + mem.get("temp_size_in_bytes", 0)) <= 16 * 2**30
    return {
        "arch": rec["arch"], "shape": rec["shape"], "chips": chips,
        "t_compute": r.t_compute, "t_memory": r.t_memory,
        "t_collective": r.t_collective, "dominant": r.dominant,
        "bound_time": r.bound_time,
        "compute_fraction": r.compute_fraction,
        "model_flops": mf, "hlo_flops": flops_dev * chips,
        "useful_ratio": mf / (flops_dev * chips) if flops_dev else 0.0,
        "fits_16g": fits,
        "mem_gib": (mem.get("argument_size_in_bytes", 0)
                    + mem.get("temp_size_in_bytes", 0)) / 2**30,
    }


IMPROVE_HINTS = {
    "compute": "reduce remat recompute (selective policies) / raise "
               "per-chip utilization via larger per-device batch",
    "memory": "decode: batch more requests per step so the param/KV "
              "stream amortizes; train: fuse optimizer+grad passes",
    "collective": "shrink FSDP all-gather volume (wider TP shards), "
                  "overlap MoE all-to-all with shared-expert compute",
}


def run() -> None:
    rows = []
    for rec in load_records("16x16"):
        a = analyse(rec)
        if a:
            rows.append(a)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    os.makedirs(RESULTS, exist_ok=True)
    md = ["| arch | shape | compute s | memory s | collective s | dominant "
          "| peak-frac | 6ND/HLO | mem GiB (fits16G) |",
          "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        md.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3e} | "
            f"{r['t_memory']:.3e} | {r['t_collective']:.3e} | "
            f"{r['dominant']} | {r['compute_fraction']:.2f} | "
            f"{r['useful_ratio']:.2f} | {r['mem_gib']:.1f} "
            f"({'y' if r['fits_16g'] else 'N'}) |"
        )
        emit(f"roofline/{r['arch']}__{r['shape']}", 0.0,
             f"{r['dominant']}:{r['compute_fraction']:.2f}")
    with open(os.path.join(RESULTS, "roofline.md"), "w") as f:
        f.write("\n".join(md) + "\n")
    with open(os.path.join(RESULTS, "roofline.json"), "w") as f:
        json.dump(rows, f, indent=2)
    print(f"# wrote {os.path.join(RESULTS, 'roofline.md')} "
          f"({len(rows)} cells)")
