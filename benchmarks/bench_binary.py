"""Paper Fig. 9: binary (1-bit) conv workloads.

The paper reports >12x over bitserial (CGO'20) and up to 4.8x over the
fp-optimized implementations of [20] on VGG conv layers.  On TPU the
binary path is xor+popcount on the VPU over 32x-packed channels; we report

  derived     — bytes-moved ratio (binary packed vs int8 vs bf16) for the
                VGG conv layers — the data-movement component of the
                paper's speedup (weights+inputs shrink 8x vs int8);
  us_per_call — interpret-mode wall-clock of the binary matmul kernel.

``run_smoke`` (the CI ``binary`` suite) additionally records the
backend-independent counters the regression gate tracks — one
``pallas_call`` per binary anchor (fused or not), the fused/unfused eqn
counts, and the analytic packed-byte traffic per anchor — and writes
them to ``BENCH_binary.json`` at the repo root (or ``out_path``).
"""
from __future__ import annotations

import json
import os
from typing import Dict

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import cost_model
from repro.core.dataflow import (
    BinaryProblem, ConvProblem, DataflowSpec, IS, OS, WS,
)
from repro.core.explorer import best_spec
from repro.core.jaxpr_utils import count_eqns, count_pallas_calls
from repro.kernels import ops, ref

SMOKE_CASE = dict(m=128, k=256, n=256)
CONV_CASE = dict(n=1, ih=10, iw=10, f=3, s=1, cin=64, cout=128)
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_binary.json")

VGG_LAYERS = [
    (56, 56, 3, 1, 256, 256),
    (56, 56, 3, 1, 256, 512),
    (28, 28, 3, 1, 512, 512),
    (14, 14, 3, 1, 512, 512),
]


def run() -> None:
    for ih, iw, f, s, cin, cout in VGG_LAYERS:
        tot = {}
        for dt, nm in (("binary_packed", "bin"), ("int8", "i8"),
                       ("bfloat16", "bf16")):
            cin_eff = cin // 32 if dt == "binary_packed" else cin
            conv = ConvProblem(ih=ih, iw=iw, fh=f, fw=f, s=s, cin=cin_eff,
                               cout=cout, in_dtype=dt, out_dtype="int32")
            g = conv.as_gemm()
            t = cost_model.gemm_traffic(g, best_spec(g))
            tot[nm] = t.total
        emit(f"fig9/vgg{ih}x{ih}c{cin}_bytes_i8_over_bin", 0.0,
             round(tot["i8"] / tot["bin"], 2))
        emit(f"fig9/vgg{ih}x{ih}c{cin}_bytes_bf16_over_bin", 0.0,
             round(tot["bf16"] / tot["bin"], 2))

    # kernel wall-clock: packed binary vs int8 matmul (reduced layer)
    rng = np.random.default_rng(0)
    m, k, n = 256, 512, 256
    a = jnp.asarray(rng.choice([-1.0, 1.0], (m, k)), jnp.float32)
    w = jnp.asarray(rng.choice([-1.0, 1.0], (k, n)), jnp.float32)
    apk, wpk = ref.pack_binary(a, axis=1), ref.pack_binary(w, axis=0)
    us_bin = time_fn(lambda x, y: ops.binary_matmul(
        x, y, n_bits=k, backend="interpret"), apk, wpk)
    ai = a.astype(jnp.int8)
    wi = w.astype(jnp.int8)
    us_i8 = time_fn(lambda x, y: ops.matmul(
        x, y, backend="interpret"), ai, wi)
    emit("fig9/binary_matmul_interpret", us_bin, 1.0)
    emit("fig9/int8_matmul_interpret", us_i8,
         round(us_i8 / max(us_bin, 1e-9), 2))


def run_smoke(out_path: str = OUT_PATH) -> Dict:
    """The CI ``binary`` suite: fused vs unfused binary GEMM per anchor
    plus the implicit-GEMM binary conv, with the dispatch/eqn/traffic
    counters the regression gate (``benchmarks/check_regression.py``)
    compares against the committed ``BENCH_binary.json``."""
    c = SMOKE_CASE
    m, k, n = c["m"], c["k"], c["n"]
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.choice([-1.0, 1.0], (m, k)), jnp.float32)
    w = jnp.asarray(rng.choice([-1.0, 1.0], (k, n)), jnp.float32)
    apk, wpk = ref.pack_binary(a, axis=1), ref.pack_binary(w, axis=0)
    scale = jnp.asarray(rng.uniform(0.1, 1.0, (n,)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=(n,)), jnp.float32)

    results = {
        "meta": {
            "backend": "interpret",
            "case": dict(SMOKE_CASE),
            "conv_case": dict(CONV_CASE),
            "epilogue": "scale+bias+sign",
            "note": "us_per_call is interpret-mode wall clock (CPU proxy); "
                    "dispatch/eqn counts and analytic traffic bytes are "
                    "backend-independent and are the tracked claim",
        },
        "rows": [],
    }

    anchors = [("os", DataflowSpec.basic(OS, block=(128, 8, 128))),
               ("ws", DataflowSpec.basic(WS, block=(128, 8, 128))),
               ("is", DataflowSpec.basic(IS, block=(128, 8, 128)))]
    prob = BinaryProblem(m=m, kp=k // 32, n=n, n_bits=k, out_dtype="int8")
    for name, spec in anchors:
        def unfused(x, y):
            dot = ops.binary_matmul(x, y, n_bits=k, spec=spec,
                                    backend="interpret")
            out = scale * dot.astype(jnp.float32) + bias
            return jnp.where(out >= 0, 1, -1).astype(jnp.int8)

        def fused(x, y):
            return ops.binary_matmul_fused(
                x, y, k, scale=scale, bias=bias, binarize=True, spec=spec,
                backend="interpret",
            )

        jx_u = jax.make_jaxpr(unfused)(apk, wpk)
        jx_f = jax.make_jaxpr(fused)(apk, wpk)
        row = {
            "name": name,
            "fused_pallas_calls": count_pallas_calls(jx_f.jaxpr),
            "unfused_pallas_calls": count_pallas_calls(jx_u.jaxpr),
            "fused_eqns": count_eqns(jx_f.jaxpr),
            "unfused_eqns": count_eqns(jx_u.jaxpr),
            "traffic_bytes": cost_model.binary_traffic(prob, spec).total,
            "fused_us": round(time_fn(fused, apk, wpk), 1),
            "unfused_us": round(time_fn(unfused, apk, wpk), 1),
        }
        assert row["fused_pallas_calls"] == 1, row
        assert row["unfused_pallas_calls"] == 1, row
        results["rows"].append(row)
        emit(
            f"binary/{name}", row["fused_us"],
            f"calls={row['fused_pallas_calls']}/{row['unfused_pallas_calls']}"
            f" eqns={row['fused_eqns']}/{row['unfused_eqns']}"
            f" bytes={row['traffic_bytes']}",
        )

    # implicit-GEMM binary conv: one pallas_call end to end
    cc = CONV_CASE
    x = jnp.asarray(
        rng.choice([-1.0, 1.0], (cc["n"], cc["ih"], cc["iw"], cc["cin"])),
        jnp.float32)
    wc = jnp.asarray(
        rng.choice([-1.0, 1.0], (cc["f"], cc["f"], cc["cin"], cc["cout"])),
        jnp.float32)
    xp = ref.pack_binary(x, axis=-1)
    wp = ref.pack_binary(wc, axis=2)
    conv_spec = DataflowSpec.basic(OS, block=(128, 2, 128))

    def conv(xx, ww):
        return ops.binary_conv2d(xx, ww, stride=cc["s"], scale=scale[:1],
                                 bias=bias[: cc["cout"]], binarize=True,
                                 spec=conv_spec, backend="interpret")

    jx_c = jax.make_jaxpr(conv)(xp, wp)
    results["conv"] = {
        "pallas_calls": count_pallas_calls(jx_c.jaxpr),
        "eqns": count_eqns(jx_c.jaxpr),
        "us": round(time_fn(conv, xp, wp), 1),
    }
    assert results["conv"]["pallas_calls"] == 1, results["conv"]
    emit("binary/conv_implicit_gemm", results["conv"]["us"],
         f"calls={results['conv']['pallas_calls']}")

    # the explored pick for the smoke problem (anchor + packed blocking)
    from repro.core import explorer

    best = explorer.explore_binary(prob, top=1)[0]
    results["explored_best"] = {
        "name": best.spec.name,
        "block": list(best.spec.block),
        "traffic_bytes": best.traffic_bytes,
    }
    emit("binary/explored_best", 0.0,
         f"{best.spec.name} block={best.spec.block}")

    try:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
            f.write("\n")
    except OSError as e:
        # keep running (local read-only checkouts), but say so — the CI
        # regression gate treats a missing fresh JSON as a failure
        print(f"# WARNING: could not write {out_path}: {e}")
    return results


if __name__ == "__main__":
    run()
    run_smoke()
