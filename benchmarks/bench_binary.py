"""Paper Fig. 9: binary (1-bit) conv workloads.

The paper reports >12x over bitserial (CGO'20) and up to 4.8x over the
fp-optimized implementations of [20] on VGG conv layers.  On TPU the
binary path is xor+popcount on the VPU over 32x-packed channels; we report

  derived     — bytes-moved ratio (binary packed vs int8 vs bf16) for the
                VGG conv layers — the data-movement component of the
                paper's speedup (weights+inputs shrink 8x vs int8);
  us_per_call — interpret-mode wall-clock of the binary matmul kernel.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import cost_model
from repro.core.dataflow import ConvProblem
from repro.core.explorer import best_spec
from repro.kernels import ops, ref

VGG_LAYERS = [
    (56, 56, 3, 1, 256, 256),
    (56, 56, 3, 1, 256, 512),
    (28, 28, 3, 1, 512, 512),
    (14, 14, 3, 1, 512, 512),
]


def run() -> None:
    for ih, iw, f, s, cin, cout in VGG_LAYERS:
        tot = {}
        for dt, nm in (("binary_packed", "bin"), ("int8", "i8"),
                       ("bfloat16", "bf16")):
            cin_eff = cin // 32 if dt == "binary_packed" else cin
            conv = ConvProblem(ih=ih, iw=iw, fh=f, fw=f, s=s, cin=cin_eff,
                               cout=cout, in_dtype=dt, out_dtype="int32")
            g = conv.as_gemm()
            t = cost_model.gemm_traffic(g, best_spec(g))
            tot[nm] = t.total
        emit(f"fig9/vgg{ih}x{ih}c{cin}_bytes_i8_over_bin", 0.0,
             round(tot["i8"] / tot["bin"], 2))
        emit(f"fig9/vgg{ih}x{ih}c{cin}_bytes_bf16_over_bin", 0.0,
             round(tot["bf16"] / tot["bin"], 2))

    # kernel wall-clock: packed binary vs int8 matmul (reduced layer)
    rng = np.random.default_rng(0)
    m, k, n = 256, 512, 256
    a = jnp.asarray(rng.choice([-1.0, 1.0], (m, k)), jnp.float32)
    w = jnp.asarray(rng.choice([-1.0, 1.0], (k, n)), jnp.float32)
    apk, wpk = ref.pack_binary(a, axis=1), ref.pack_binary(w, axis=0)
    us_bin = time_fn(lambda x, y: ops.binary_matmul(
        x, y, n_bits=k, backend="interpret"), apk, wpk)
    ai = a.astype(jnp.int8)
    wi = w.astype(jnp.int8)
    us_i8 = time_fn(lambda x, y: ops.matmul(
        x, y, backend="interpret"), ai, wi)
    emit("fig9/binary_matmul_interpret", us_bin, 1.0)
    emit("fig9/int8_matmul_interpret", us_i8,
         round(us_i8 / max(us_bin, 1e-9), 2))
