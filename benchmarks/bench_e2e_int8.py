"""Paper Fig. 8: end-to-end INT8 network speedup from dataflow optimization.

The paper compares its generated code against TVM on ResNet/VGG variants
(~3x tuned, up to ~14x untuned).  Off-TPU we report:

  derived    — traffic-model end-to-end speedup of the explored best
               dataflow (Alg. 8) over (a) the basic weight-stationary
               dataflow ("untuned" analogue) and (b) basic OS, summed
               over a ResNet-18-shaped conv stack at INT8;
  us_per_call— interpret-mode wall-clock of one reduced conv layer under
               the best dataflow (functional path check).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import cost_model, explorer
from repro.core.dataflow import ConvProblem, DataflowSpec, OS, WS
from repro.kernels import ops

# ResNet-18 conv body (ih, iw, fh, s, cin, cout) x repeat
RESNET18 = [
    (56, 56, 3, 1, 64, 64, 4),
    (56, 56, 3, 2, 64, 128, 1),
    (28, 28, 3, 1, 128, 128, 3),
    (28, 28, 3, 2, 128, 256, 1),
    (14, 14, 3, 1, 256, 256, 3),
    (14, 14, 3, 2, 256, 512, 1),
    (7, 7, 3, 1, 512, 512, 3),
]


def _stack_time(spec_fn) -> float:
    total = 0.0
    for ih, iw, f, s, cin, cout, rep in RESNET18:
        conv = ConvProblem(ih=ih, iw=iw, fh=f, fw=f, s=s, cin=cin,
                           cout=cout, in_dtype="int8", out_dtype="int32")
        g = conv.as_gemm()
        spec = spec_fn(g)
        total += rep * cost_model.gemm_time_estimate(g, spec)
    return total


def run() -> None:
    t_best = _stack_time(lambda g: explorer.best_spec(g))
    t_ws = _stack_time(lambda g: DataflowSpec.basic(WS))
    t_os = _stack_time(lambda g: DataflowSpec.basic(OS))
    emit("fig8/resnet18_int8_best_vs_ws_basic", 0.0,
         round(t_ws / t_best, 2))
    emit("fig8/resnet18_int8_best_vs_os_basic", 0.0,
         round(t_os / t_best, 2))

    # sub-byte packed twin of the same stack: modeled weight-stream bytes
    # (packed planes + outlier sidecar, kernels/pack.py) vs the int8 twin
    int8_w = packed_w = 0
    for ih, iw, f, s, cin, cout, rep in RESNET18:
        mk = lambda wb: ConvProblem(
            ih=ih, iw=iw, fh=f, fw=f, s=s, cin=cin, cout=cout,
            in_dtype="int8", out_dtype="int32", weight_bits=wb).as_gemm()
        int8_w += rep * cost_model.weight_stream_bytes(mk(None))
        packed_w += rep * cost_model.weight_stream_bytes(mk(4))
    emit("fig8/resnet18_weight_bytes_wb4_vs_int8", 0.0,
         round(packed_w / int8_w, 3))

    # end-to-end planner (paper SIV-B/C): per-layer exploration + chain DP,
    # including the depthwise / shuffled-grouped networks from the paper's scope
    from repro.core import network

    for name, net in (
        ("resnet18", network.resnet18_int8()),
        ("mobilenet_blocks", network.mobilenet_block_int8(56, 64, 128)
         + network.mobilenet_block_int8(28, 128, 256)),
        ("shufflenet_stage", network.shufflenet_stage_int8(28, 128, 4, 2)),
    ):
        plan = network.optimize_network(net)
        os_frac = sum(lp.spec.name.startswith("OS")
                      for lp in plan.layers) / len(plan.layers)
        emit(f"fig8/{name}_planned_us", 0.0,
             round(plan.total_seconds * 1e6, 1))
        emit(f"fig8/{name}_os_anchored_frac", 0.0, round(os_frac, 2))

    # functional INT8 conv through the optimized dataflow kernel
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-20, 20, (1, 14, 14, 128)), jnp.int8)
    w = jnp.asarray(rng.integers(-20, 20, (3, 3, 128, 128)), jnp.int8)
    us = time_fn(lambda a, b: ops.conv2d(
        a, b, stride=1, spec=DataflowSpec.optimized(), backend="interpret",
        b_oh=4), x, w)
    emit("fig8/int8_conv_os_aux_interpret", us, "-")
