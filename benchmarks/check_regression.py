"""CI benchmark regression gate.

Compares fresh interpret-mode benchmark runs against the committed
``BENCH_*.json`` baselines at the repo root and fails (exit 1) when a
tracked counter regresses:

  *pallas_calls*   kernel dispatches per trace — must not exceed the
                   baseline at all (a second dispatch means a fusion or
                   single-dispatch lowering broke);
  *grid_steps*     static pallas grid work per trace — must not exceed
                   the baseline at all (growth means banding stopped
                   pruning masked KV blocks from the lowering);
  *eqns*           total jaxpr equations — a trace-bloat proxy, allowed
                   ``--tolerance`` relative slack (jax version drift
                   moves it a little);
  *traffic_bytes*  analytic HBM byte counts from the cost model —
                   deterministic, allowed the same slack for cost-model
                   refinements (the ``decode_kv*`` rows of
                   ``BENCH_attention.json`` make "decode traffic scales
                   with the valid KV length, not max_len" a gated
                   invariant);
  *packed ratio*   the ``BENCH_fused.json`` packed row is additionally
                   gated as (wb4 packed weight bytes) / (int8 weight
                   bytes) <= 0.65 — the sub-byte format must keep
                   paying for itself against the int8 tier;
  *occupancy*      the ``decode_kv<N>`` rows are additionally gated
                   per request length as bytes-per-valid-KV-position:
                   each length's occupancy must stay within tolerance
                   of its baseline AND occupancy must not grow with N
                   (per-row banding means longer requests amortize the
                   fixed per-step overhead — a growing occupancy curve
                   means decode traffic picked up a term that scales
                   with the buffer instead of the request).

Wall-clock fields (``*_us``) and ``meta`` blocks are ignored: interpret
mode is a CPU proxy and CI machines are noisy; the tracked claims are
the backend-independent counters.

Fresh numbers come from ``--fresh-dir`` (a directory of BENCH_*.json
produced by ``benchmarks/run.py --out-dir``, the CI flow — the committed
baselines are never overwritten) or, when omitted, from re-running the
JSON-writing suites into a temp directory.

Exit codes: 0 = no regressions, 1 = regression or missing data.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
import tempfile
from typing import Dict, List, Tuple

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

# baseline file -> suite callable (rerun mode); each accepts out_path
def _suites():
    from benchmarks import (
        bench_attention, bench_binary, bench_conv, bench_fused,
    )

    return {
        "BENCH_fused.json": bench_fused.run,
        "BENCH_conv.json": bench_conv.run,
        "BENCH_binary.json": bench_binary.run_smoke,
        "BENCH_attention.json": bench_attention.run_smoke,
    }


def _walk(prefix: str, node) -> Dict[str, float]:
    """Flatten numeric leaves to {dotted.path: value}, skipping meta."""
    out: Dict[str, float] = {}
    if isinstance(node, dict):
        for key, val in node.items():
            if key == "meta":
                continue
            out.update(_walk(f"{prefix}.{key}" if prefix else key, val))
    elif isinstance(node, list):
        for i, val in enumerate(node):
            # index rows by their "name" field when present so a
            # reordering doesn't read as a regression
            tag = val.get("name", str(i)) if isinstance(val, dict) else str(i)
            out.update(_walk(f"{prefix}[{tag}]", val))
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        out[prefix] = float(node)
    return out


def _rule(path: str) -> Tuple[str, bool]:
    """(kind, tracked) for a flattened counter path."""
    leaf = path.rsplit(".", 1)[-1]
    if leaf.endswith("_us") or leaf == "us":
        return ("wallclock", False)
    if "pallas_calls" in leaf:
        return ("dispatch", True)
    if "grid_steps" in leaf:
        return ("dispatch", True)   # static grid work: no-exceed, like
    if "eqns" in leaf:              # dispatch counts (both are exact
        return ("eqns", True)       # trace-time quantities)
    if "traffic_bytes" in leaf:
        return ("traffic", True)
    return ("other", False)


def compare(baseline: dict, fresh: dict, tolerance: float,
            label: str) -> List[str]:
    """Regression messages (empty = clean) for one BENCH file pair."""
    base_flat = _walk("", baseline)
    fresh_flat = _walk("", fresh)
    problems: List[str] = []
    for path, base_val in sorted(base_flat.items()):
        kind, tracked = _rule(path)
        if not tracked:
            continue
        if path not in fresh_flat:
            problems.append(f"{label}:{path}: missing from fresh run")
            continue
        new = fresh_flat[path]
        limit = base_val if kind == "dispatch" \
            else base_val * (1.0 + tolerance)
        if new > limit:
            problems.append(
                f"{label}:{path}: {new:g} > baseline {base_val:g}"
                + ("" if kind == "dispatch" else f" (+{tolerance:.0%} tol)")
            )
    return problems


def _decode_occupancy(doc: dict) -> Dict[int, float]:
    """{kv_len: traffic bytes per valid KV position} from the
    ``decode_kv<N>`` rows of a BENCH_attention document."""
    rows = (doc.get("decode_cached") or {}).get("rows", [])
    out: Dict[int, float] = {}
    for row in rows:
        m = re.fullmatch(r"decode_kv(\d+)", str(row.get("name", "")))
        if m and "traffic_bytes" in row:
            kl = int(m.group(1))
            out[kl] = row["traffic_bytes"] / kl
    return out


def occupancy_gate(baseline: dict, fresh: dict, tolerance: float,
                   label: str) -> List[str]:
    """Per-request-length decode occupancy gates (PR 8).

    Continuous batching bills each request its own ``kv_valid`` band;
    these gates pin that per length: (1) every baseline ``decode_kv<N>``
    row's bytes/position stays within tolerance of its baseline, and
    (2) occupancy is non-increasing in N — growth with the request
    length means a buffer-sized (``max_len``) term leaked back into
    the decode stream.
    """
    base = _decode_occupancy(baseline)
    new = _decode_occupancy(fresh)
    problems: List[str] = []
    for kl, b_occ in sorted(base.items()):
        if kl not in new:
            problems.append(
                f"{label}:occupancy[decode_kv{kl}]: missing from fresh run")
            continue
        if new[kl] > b_occ * (1.0 + tolerance):
            problems.append(
                f"{label}:occupancy[decode_kv{kl}]: {new[kl]:.1f} "
                f"bytes/kv > baseline {b_occ:.1f} (+{tolerance:.0%} tol)")
    lens = sorted(new)
    for a, b in zip(lens, lens[1:]):
        if new[b] > new[a] * 1.01:       # 1% float slack
            problems.append(
                f"{label}:occupancy: grows with request length "
                f"(decode_kv{b} {new[b]:.1f} > decode_kv{a} "
                f"{new[a]:.1f} bytes/kv) — a max_len-sized term is "
                f"back in the decode stream")
    return problems


PACKED_RATIO_CAP = 0.65


def packed_gate(baseline: dict, fresh: dict, tolerance: float,
                label: str) -> List[str]:
    """Packed weight-traffic gates (PR 9).

    The sub-byte packed format must actually shrink the modeled weight
    stream: (1) the fresh ``wb4`` packed-plane + outlier-sidecar bytes
    must stay <= ``PACKED_RATIO_CAP`` x the int8 twin's bytes (hard cap
    — format bloat, e.g. an oversized sidecar, trips it immediately),
    and (2) the ratio must not regress past the committed baseline's by
    more than the tolerance.
    """
    def ratio(doc: dict) -> float:
        for row in (doc.get("packed") or {}).get("rows", []):
            if row.get("name") == "weight_traffic_model":
                return (row["wb4_weight_traffic_bytes"]
                        / row["int8_weight_traffic_bytes"])
        return float("nan")

    new = ratio(fresh)
    if new != new:  # NaN: row missing
        return [f"{label}:packed: weight_traffic_model row missing "
                f"from fresh run"]
    problems: List[str] = []
    if new > PACKED_RATIO_CAP:
        problems.append(
            f"{label}:packed: wb4/int8 weight-traffic ratio {new:.3f} "
            f"> cap {PACKED_RATIO_CAP}")
    base = ratio(baseline)
    if base == base and new > base * (1.0 + tolerance):
        problems.append(
            f"{label}:packed: wb4/int8 ratio {new:.3f} > baseline "
            f"{base:.3f} (+{tolerance:.0%} tol)")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--fresh-dir", default=None,
        help="directory of freshly-generated BENCH_*.json (from "
             "benchmarks/run.py --out-dir); omitted = rerun suites here",
    )
    ap.add_argument(
        "--tolerance", type=float, default=0.25,
        help="relative slack for eqn/traffic counters (default 0.25); "
             "dispatch counts get none",
    )
    args = ap.parse_args(argv)

    fresh_dir = args.fresh_dir
    if fresh_dir is None:
        fresh_dir = tempfile.mkdtemp(prefix="bench-fresh-")
        print(f"# re-running JSON suites into {fresh_dir}")
        for fname, fn in _suites().items():
            fn(out_path=os.path.join(fresh_dir, fname))

    problems: List[str] = []
    checked = 0
    for fname in sorted(_suites()):
        base_path = os.path.join(REPO_ROOT, fname)
        fresh_path = os.path.join(fresh_dir, fname)
        if not os.path.exists(base_path):
            problems.append(f"{fname}: committed baseline missing")
            continue
        if not os.path.exists(fresh_path):
            problems.append(f"{fname}: fresh run missing (suite failed?)")
            continue
        with open(base_path) as f:
            baseline = json.load(f)
        with open(fresh_path) as f:
            fresh = json.load(f)
        msgs = compare(baseline, fresh, args.tolerance, fname)
        if fname == "BENCH_attention.json":
            msgs += occupancy_gate(baseline, fresh, args.tolerance, fname)
        if fname == "BENCH_fused.json":
            msgs += packed_gate(baseline, fresh, args.tolerance, fname)
        problems.extend(msgs)
        checked += 1
        print(f"# {fname}: "
              + ("OK" if not msgs else f"{len(msgs)} regression(s)"))
    if problems:
        print("\nBENCHMARK REGRESSIONS:")
        for p in problems:
            print(f"  {p}")
        return 1
    print(f"# regression gate clean ({checked} baseline file(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
