"""Render EXPERIMENTS.md §Dry-run + §Roofline tables from the artifacts.

    PYTHONPATH=src python -m benchmarks.render_experiments

Prints markdown; the committed EXPERIMENTS.md embeds this output.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks import bench_roofline
from repro import configs
from repro.configs.base import SHAPES

DRYRUN = bench_roofline.DRYRUN


def dryrun_table(mesh_tag: str) -> str:
    rows = [
        "| arch | shape | kind | mb | lower s | compile s | args+temp "
        "GiB/dev | HLO flops/dev | collective B/dev | a2a | ag | ar |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for path in sorted(glob.glob(os.path.join(DRYRUN,
                                              f"*__{mesh_tag}.json"))):
        r = json.load(open(path))
        mem = r.get("memory", {})
        gib = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)) / 2**30
        d = r.get("derived", {})
        coll = r.get("collectives", {})
        kinds = coll.get("by_kind_count", {})
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{r.get('microbatches', 1)} | {r['lower_seconds']} | "
            f"{r.get('compile_seconds', '-')} | {gib:.2f} | "
            f"{d.get('flops', 0):.3e} | "
            f"{d.get('collective_bytes', 0):.3e} | "
            f"{kinds.get('all-to-all', 0)} | {kinds.get('all-gather', 0)} "
            f"| {kinds.get('all-reduce', 0)} |"
        )
    return "\n".join(rows)


def skip_table() -> str:
    rows = ["| arch | shape | reason |", "|---|---|---|"]
    for a, s, sk, reason in configs.all_cells(include_skipped=True):
        if sk:
            rows.append(f"| {a} | {s} | {reason} |")
    return "\n".join(rows)


def main() -> None:
    print("## Dry-run — single-pod mesh 16x16 (256 chips)\n")
    print(dryrun_table("16x16"))
    print("\n## Dry-run — multi-pod mesh 2x16x16 (512 chips)\n")
    print(dryrun_table("2x16x16"))
    print("\n## Skipped cells (per assignment rules)\n")
    print(skip_table())
    roof = os.path.join(bench_roofline.RESULTS, "roofline.md")
    if os.path.exists(roof):
        print("\n## Roofline (single-pod)\n")
        print(open(roof).read())


if __name__ == "__main__":
    main()
