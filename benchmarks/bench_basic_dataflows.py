"""Paper Fig. 2: relative latency of the basic dataflows (IS/WS/OS).

derived = traffic-model latency ratio vs OS at the paper's layer scale
(median over the layer grid reproduces the paper's 1.93x/3.41x s=1 and
5.39x/2.81x s=2 ordering qualitatively); us_per_call = interpret-mode
wall-clock of the matmul kernel on a reduced layer.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import PAPER_LAYERS, emit, time_fn
from repro.core import cost_model
from repro.core.dataflow import ConvProblem, DataflowSpec, IS, OS, WS
from repro.kernels import ops


def run() -> None:
    ratios = {IS: [], WS: []}
    for hw, f, s, nf in PAPER_LAYERS:
        conv = ConvProblem(ih=hw, iw=hw, fh=f, fw=f, s=s, cin=128, cout=nf)
        g = conv.as_gemm()
        t = {a: cost_model.gemm_time_estimate(g, DataflowSpec.basic(a))
             for a in (OS, WS, IS)}
        for a in (IS, WS):
            ratios[a].append(t[a] / t[OS])

    # reduced-layer interpret-mode wall clock per anchor
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    for anchor, nm in ((OS, "os"), (IS, "is"), (WS, "ws")):
        spec = DataflowSpec.basic(anchor, block=(128, 128, 128))
        us = time_fn(lambda x, y: ops.matmul(x, y, spec=spec,
                                             backend="interpret"), a, b)
        if anchor == OS:
            emit("fig2/basic_os", us, 1.0)
        else:
            med = float(np.median(ratios[anchor]))
            emit(f"fig2/basic_{nm}_vs_os", us, round(med, 2))

    s1 = [r for (hw, f, s, nf), r in zip(PAPER_LAYERS, ratios[IS]) if s == 1]
    s2 = [r for (hw, f, s, nf), r in zip(PAPER_LAYERS, ratios[IS]) if s == 2]
    emit("fig2/is_vs_os_median_s1", 0.0, round(float(np.median(s1)), 2))
    emit("fig2/is_vs_os_median_s2", 0.0, round(float(np.median(s2)), 2))
