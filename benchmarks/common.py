"""Shared benchmark utilities.

Every benchmark prints ``name,us_per_call,derived`` CSV rows:
  us_per_call — measured wall-clock of the interpret-mode kernel (CPU
                proxy; orders dataflows by data-movement/grid work, not
                MXU throughput), or of the XLA path where noted;
  derived     — the analytic quantity the paper's table reports
                (traffic-model speedup ratio, memory-op reduction, ...),
                computed for the paper-scale layer.
"""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax
import numpy as np

# The paper's conv layer grid (Sec. V): input sizes, filters, strides, nf.
PAPER_LAYERS: List[Tuple[int, int, int, int]] = [
    # (input hw, filter hw, stride, n_filters)
    (56, 3, 1, 128), (56, 3, 1, 256), (56, 3, 1, 512),
    (56, 4, 1, 128), (56, 5, 1, 256),
    (112, 3, 1, 128), (112, 3, 1, 256), (112, 4, 1, 512),
    (56, 3, 2, 128), (56, 4, 2, 256),
    (112, 3, 2, 128), (112, 5, 2, 256),
]


def time_fn(fn: Callable, *args, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
