"""Benchmark runner: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (see benchmarks/common.py for
column semantics).  The roofline table additionally requires dry-run
artifacts (python -m repro.launch.dryrun --all); it is skipped with a
note if they are absent.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_basic_dataflows,
        bench_binary,
        bench_e2e_int8,
        bench_extended_dataflows,
        bench_heuristics,
        bench_roofline,
    )

    print("name,us_per_call,derived")
    suites = [
        ("fig2_basic_dataflows", bench_basic_dataflows.run),
        ("fig7_extended_dataflows", bench_extended_dataflows.run),
        ("table1_heuristics", bench_heuristics.run),
        ("fig8_e2e_int8", bench_e2e_int8.run),
        ("fig9_binary", bench_binary.run),
        ("roofline", bench_roofline.run),
    ]
    failures = 0
    for name, fn in suites:
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception as e:
            failures += 1
            print(f"# {name} FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
