"""Benchmark runner: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (see benchmarks/common.py for
column semantics).  The roofline table additionally requires dry-run
artifacts (python -m repro.launch.dryrun --all); it is skipped with a
note if they are absent.

``--suites a,b`` runs a comma-separated subset (CI smoke uses
``--suites fig2_basic_dataflows,fused_epilogue,fused_conv,binary``).

``--out-dir DIR`` redirects the ``BENCH_*.json`` files the JSON-writing
suites (fused_epilogue, fused_conv, binary) produce into ``DIR`` instead
of overwriting the committed repo-root baselines — this is how CI
generates the fresh measurements ``benchmarks/check_regression.py``
gates on (and uploads as a workflow artifact).
"""
from __future__ import annotations

import argparse
import inspect
import os
import sys
import traceback


def main(argv=None) -> None:
    from benchmarks import (
        bench_attention,
        bench_basic_dataflows,
        bench_binary,
        bench_conv,
        bench_e2e_int8,
        bench_extended_dataflows,
        bench_fused,
        bench_heuristics,
        bench_roofline,
    )

    suites = [
        ("fig2_basic_dataflows", bench_basic_dataflows.run),
        ("fig7_extended_dataflows", bench_extended_dataflows.run),
        ("table1_heuristics", bench_heuristics.run),
        ("fig8_e2e_int8", bench_e2e_int8.run),
        ("fig9_binary", bench_binary.run),
        ("binary", bench_binary.run_smoke),
        ("attention", bench_attention.run_smoke),
        ("fused_epilogue", bench_fused.run),
        ("fused_conv", bench_conv.run),
        ("roofline", bench_roofline.run),
    ]
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--suites", default=None,
        help="comma-separated subset of: "
             + ",".join(name for name, _ in suites),
    )
    ap.add_argument(
        "--out-dir", default=None,
        help="write BENCH_*.json outputs here instead of the repo root "
             "(suites without a JSON artifact are unaffected)",
    )
    args = ap.parse_args(argv)
    if args.suites:
        wanted = set(args.suites.split(","))
        unknown = wanted - {name for name, _ in suites}
        if unknown:
            ap.error(f"unknown suites: {sorted(unknown)}")
        suites = [(n, f) for n, f in suites if n in wanted]
    if args.out_dir:
        os.makedirs(args.out_dir, exist_ok=True)

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        print(f"# --- {name} ---")
        kw = {}
        if args.out_dir and "out_path" in inspect.signature(fn).parameters:
            default = inspect.signature(fn).parameters["out_path"].default
            kw["out_path"] = os.path.join(args.out_dir,
                                          os.path.basename(default))
        try:
            fn(**kw)
        except Exception as e:
            failures += 1
            print(f"# {name} FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
