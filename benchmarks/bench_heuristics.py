"""Paper Table I: memory-op reductions per auxiliary vector variable,
and the Observations 1-5 derived from them (validated on the layer grid).

derived = predicted reduction (memory instructions per aux variable) for
the canonical 56x56 f3 s1 layer; plus 0/1 flags for each observation
holding across the whole grid.
"""
from __future__ import annotations

from benchmarks.common import PAPER_LAYERS, emit
from repro.core import cost_model
from repro.core.dataflow import ConvProblem, IS, OS, WS


def run() -> None:
    conv = ConvProblem(ih=56, iw=56, fh=3, fw=3, s=1, cin=128, cout=128)
    rows = [
        ("os_aux_input", OS, IS), ("os_aux_weight", OS, WS),
        ("ws_aux_input", WS, IS), ("ws_aux_output", WS, OS),
        ("is_aux_weight", IS, WS), ("is_aux_output", IS, OS),
    ]
    for name, anchor, aux in rows:
        r, w = cost_model.table1_reduction(anchor, aux, conv)
        emit(f"table1/{name}_reads", 0.0, int(r))
        emit(f"table1/{name}_writes", 0.0, int(w))

    # stride-2 IS rows (the nonlinear regime)
    conv2 = ConvProblem(ih=56, iw=56, fh=3, fw=3, s=2, cin=128, cout=128)
    for nv in (1, 2, 4):
        r, w = cost_model.table1_reduction(IS, OS, conv2, n_aux_vars=nv)
        emit(f"table1/is_aux_output_s2_var{nv}", 0.0, int(r))

    # observations across the full grid
    all_hold = {k: True for k in ("obs1_ws_gains_least",
                                  "obs3_os_aux_symmetric",
                                  "obs4_is_output_first",
                                  "obs5_ws_output_first")}
    for hw, f, s, nf in PAPER_LAYERS:
        c = ConvProblem(ih=hw, iw=hw, fh=f, fw=f, s=s, cin=128, cout=nf)
        for k, v in cost_model.paper_observations_hold(c).items():
            all_hold[k] &= v
    for k, v in all_hold.items():
        emit(f"table1/{k}", 0.0, int(v))
