"""Fused-epilogue vs unfused GEMM+epilogue: dispatch counts + wall clock.

For each dataflow anchor, compares

  unfused : ``ops.matmul`` followed by the epilogue (dequant scale, bias,
            silu, residual) as separate XLA ops — the raw accumulator
            round-trips HBM between the kernel and its epilogue;
  fused   : ``ops.matmul_fused`` — one kernel dispatch, epilogue applied
            in-register before the single output write.

Emits CSV rows (``us_per_call`` = interpret-mode wall clock, ``derived``
= "fused_calls/unfused_calls eqns=fused/unfused") and writes the full
results to ``BENCH_fused.json`` at the repo root.  Also records that the
single-dispatch WS lowering issues exactly one ``pallas_call`` per GEMM
regardless of the reduction depth.
"""
from __future__ import annotations

import json
import os
from typing import Dict

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import cost_model
from repro.core.dataflow import DataflowSpec, GemmProblem, IS, OS, WS
from repro.core.jaxpr_utils import count_eqns, count_pallas_calls
from repro.kernels import ops, pack
from repro.kernels.matmul_df import matmul_df

SHAPE = (256, 384, 512)
BLOCK = (128, 128, 128)
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_fused.json")


def run(out_path: str = OUT_PATH) -> Dict:
    m, k, n = SHAPE
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=(1, n)), jnp.float32)
    scale = jnp.float32(0.37)
    residual = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)

    results = {
        "meta": {
            "backend": "interpret",
            "shape": list(SHAPE),
            "epilogue": "scale+bias+silu+residual",
            "note": "us_per_call is interpret-mode wall clock (CPU proxy, "
                    "noisy at few iters — it does not measure the HBM "
                    "round trip fusion removes); dispatch/eqn counts are "
                    "backend-independent and are the tracked claim",
        },
        "rows": [],
    }

    anchors = [("os", DataflowSpec.basic(OS, block=BLOCK)),
               ("ws", DataflowSpec.basic(WS, block=BLOCK)),
               ("is", DataflowSpec.basic(IS, block=BLOCK))]
    for name, spec in anchors:
        def unfused(x, y):
            acc = ops.matmul(x, y, spec=spec, backend="interpret")
            return jax.nn.silu(scale * acc + bias) + residual

        def fused(x, y):
            return ops.matmul_fused(
                x, y, bias=bias, scale=scale, residual=residual,
                activation="silu", spec=spec, backend="interpret",
            )

        jx_u = jax.make_jaxpr(unfused)(a, b)
        jx_f = jax.make_jaxpr(fused)(a, b)
        row = {
            "name": name,
            "fused_pallas_calls": count_pallas_calls(jx_f.jaxpr),
            "unfused_pallas_calls": count_pallas_calls(jx_u.jaxpr),
            "fused_eqns": count_eqns(jx_f.jaxpr),
            "unfused_eqns": count_eqns(jx_u.jaxpr),
            "fused_us": round(time_fn(fused, a, b), 1),
            "unfused_us": round(time_fn(unfused, a, b), 1),
        }
        # the fusion must never add dispatches (eqn counts are reported
        # for reference — they drift with the jax tracing version and
        # don't measure the accumulator HBM round trip fusion removes)
        assert row["fused_pallas_calls"] <= row["unfused_pallas_calls"], row
        results["rows"].append(row)
        emit(
            f"fused/{name}", row["fused_us"],
            f"calls={row['fused_pallas_calls']}/{row['unfused_pallas_calls']}"
            f" eqns={row['fused_eqns']}/{row['unfused_eqns']}",
        )
        emit(f"fused/{name}_unfused", row["unfused_us"], "")

    # single-dispatch WS: one pallas_call regardless of reduction depth
    ws = DataflowSpec.basic(WS, block=BLOCK)
    by_gk = {}
    for gk in (1, 2, 4):
        aa = jnp.zeros((256, 128 * gk), jnp.float32)
        bb = jnp.zeros((128 * gk, 256), jnp.float32)
        jx = jax.make_jaxpr(
            lambda x, y: matmul_df(x, y, ws, interpret=True))(aa, bb)
        by_gk[str(gk)] = count_pallas_calls(jx.jaxpr)
    assert set(by_gk.values()) == {1}, by_gk
    results["ws_pallas_calls_by_gk"] = by_gk
    emit("fused/ws_single_dispatch", 0.0,
         "calls_by_gk=" + "/".join(f"{g}:{c}" for g, c in by_gk.items()))

    # --- sub-byte packed weights (kernels/pack.py) ---------------------------
    # Modeled weight-stream bytes for a decoder-MLP-shaped GEMM: the packed
    # planes + outlier sidecar vs the int8 twin.  Deterministic cost-model
    # output; check_regression.py gates the wb4/int8 ratio at <= 0.65.
    pm, pk_, pn = 256, 2048, 2048
    int8_twin = GemmProblem(m=pm, k=pk_, n=pn, in_dtype="int8",
                            out_dtype="float32", acc_dtype="int32")
    int8_bytes = cost_model.weight_stream_bytes(int8_twin)
    wb_bytes = {
        bits: cost_model.weight_stream_bytes(
            GemmProblem(m=pm, k=pk_, n=pn, in_dtype="int8",
                        out_dtype="float32", acc_dtype="int32",
                        weight_bits=bits))
        for bits in (4, 5)
    }
    traffic_row = {
        "name": "weight_traffic_model",
        "int8_weight_traffic_bytes": int8_bytes,
        "wb4_weight_traffic_bytes": wb_bytes[4],
        "wb5_weight_traffic_bytes": wb_bytes[5],
        "wb4_to_int8_ratio": round(wb_bytes[4] / int8_bytes, 4),
    }
    emit("packed/weight_traffic_wb4_vs_int8", 0.0,
         traffic_row["wb4_to_int8_ratio"])

    # functional packed dispatch: one pallas_call, decompress in-kernel
    q = jnp.asarray(rng.integers(-8, 8, size=(k, n)), jnp.int8)
    wscale = jnp.full((1, n), 0.01, jnp.float32)
    pw = pack.pack_int8(q, wscale, bits=4)
    aq = jnp.asarray(rng.integers(-127, 128, size=(m, k)), jnp.int8)
    ws_spec = DataflowSpec.basic(WS, block=BLOCK)

    def packed_call(x):
        return ops.matmul_packed(x, pw, a_scale=jnp.float32(0.02),
                                 spec=ws_spec, backend="interpret")

    jx_p = jax.make_jaxpr(packed_call)(aq)
    dispatch_row = {
        "name": "packed_ws_dispatch",
        "packed_pallas_calls": count_pallas_calls(jx_p.jaxpr),
        "packed_us": round(time_fn(packed_call, aq), 1),
    }
    assert dispatch_row["packed_pallas_calls"] == 1, dispatch_row
    results["packed"] = {"rows": [traffic_row, dispatch_row]}
    emit("packed/ws_dispatch", dispatch_row["packed_us"],
         f"calls={dispatch_row['packed_pallas_calls']}")

    try:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
            f.write("\n")
    except OSError:
        pass
    return results


if __name__ == "__main__":
    run()
