"""Fused-epilogue vs unfused conv + single-dispatch conv lowering.

For each dataflow anchor, compares

  unfused : ``ops.conv2d`` followed by the epilogue (dequant scale, bias,
            silu, residual) as separate XLA ops — the raw accumulator
            round-trips HBM between the kernel and its epilogue;
  fused   : ``ops.conv2d_fused`` — one kernel dispatch, epilogue applied
            in-register at the scratch flush.

Emits CSV rows (``us_per_call`` = interpret-mode wall clock, ``derived``
= "fused_calls/unfused_calls eqns=fused/unfused") and writes the full
results to ``BENCH_conv.json`` at the repo root.  Also records that
every conv anchor — including the previously panel-looped WS/IS — now
issues exactly one ``pallas_call`` regardless of the reduction depth
``n_r = fh*fw*ceil(cin/bc)``.
"""
from __future__ import annotations

import json
import os
from typing import Dict

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.dataflow import DataflowSpec, IS, OS, WS
from repro.core.jaxpr_utils import count_eqns, count_pallas_calls
from repro.kernels import ops
from repro.kernels.conv2d_df import conv2d_df

CASE = dict(n=1, ih=14, iw=14, f=3, s=1, cin=128, cout=128)
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_conv.json")


def run(out_path: str = OUT_PATH) -> Dict:
    c = CASE
    rng = np.random.default_rng(0)
    oh = (c["ih"] - c["f"]) // c["s"] + 1
    ow = (c["iw"] - c["f"]) // c["s"] + 1
    x = jnp.asarray(
        rng.normal(size=(c["n"], c["ih"], c["iw"], c["cin"])), jnp.float32)
    w = jnp.asarray(
        rng.normal(size=(c["f"], c["f"], c["cin"], c["cout"])), jnp.float32)
    bias = jnp.asarray(rng.normal(size=(c["cout"],)), jnp.float32)
    scale = jnp.float32(0.37)
    residual = jnp.asarray(
        rng.normal(size=(c["n"], oh, ow, c["cout"])), jnp.float32)

    results = {
        "meta": {
            "backend": "interpret",
            "case": dict(CASE),
            "epilogue": "scale+bias+silu+residual",
            "note": "us_per_call is interpret-mode wall clock (CPU proxy); "
                    "dispatch/eqn counts are backend-independent",
        },
        "rows": [],
    }

    anchors = [("os", DataflowSpec.basic(OS)),
               ("ws", DataflowSpec.basic(WS)),
               ("is", DataflowSpec.basic(IS))]
    for name, spec in anchors:
        def unfused(xx, ww):
            acc = ops.conv2d(xx, ww, stride=c["s"], spec=spec, b_oh=4,
                             backend="interpret")
            return jax.nn.silu(scale * acc + bias) + residual

        def fused(xx, ww):
            return ops.conv2d_fused(
                xx, ww, stride=c["s"], bias=bias, scale=scale,
                residual=residual, activation="silu", spec=spec, b_oh=4,
                backend="interpret",
            )

        jx_u = jax.make_jaxpr(unfused)(x, w)
        jx_f = jax.make_jaxpr(fused)(x, w)
        row = {
            "name": name,
            "fused_pallas_calls": count_pallas_calls(jx_f.jaxpr),
            "unfused_pallas_calls": count_pallas_calls(jx_u.jaxpr),
            "fused_eqns": count_eqns(jx_f.jaxpr),
            "unfused_eqns": count_eqns(jx_u.jaxpr),
            "fused_us": round(time_fn(fused, x, w), 1),
            "unfused_us": round(time_fn(unfused, x, w), 1),
        }
        # one dispatch per conv, fused or not (eqn counts are reported
        # for reference — the fused kernel's in-register epilogue and
        # operand padding trade a handful of trace eqns for removing the
        # accumulator's HBM round trip, which eqn counts don't measure)
        assert row["fused_pallas_calls"] == 1, row
        assert row["unfused_pallas_calls"] == 1, row
        results["rows"].append(row)
        emit(
            f"conv/{name}", row["fused_us"],
            f"calls={row['fused_pallas_calls']}/{row['unfused_pallas_calls']}"
            f" eqns={row['fused_eqns']}/{row['unfused_eqns']}",
        )
        emit(f"conv/{name}_unfused", row["unfused_us"], "")

    # single-dispatch WS/IS conv: one pallas_call regardless of the
    # reduction depth n_r (previously n_r aliased calls + zeros init)
    by_anchor = {}
    for name, spec in anchors[1:]:
        by_nr = {}
        for f in (1, 3, 5):
            oh_ = 12
            ihp = oh_ - 1 + f
            xx = jnp.zeros((1, ihp, ihp, 128), jnp.float32)
            ww = jnp.zeros((f, f, 128, 128), jnp.float32)
            jx = jax.make_jaxpr(
                lambda a, b: conv2d_df(a, b, 1, spec, oh=oh_, ow=oh_,
                                       b_oh=4, interpret=True))(xx, ww)
            by_nr[str(f * f)] = count_pallas_calls(jx.jaxpr)
        assert set(by_nr.values()) == {1}, (name, by_nr)
        by_anchor[name] = by_nr
        emit(f"conv/{name}_single_dispatch", 0.0,
             "calls_by_nr=" + "/".join(f"{k}:{v}" for k, v in by_nr.items()))
    results["pallas_calls_by_nr"] = by_anchor

    try:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
            f.write("\n")
    except OSError:
        pass
    return results


if __name__ == "__main__":
    run()
