"""Paper Fig. 7: extended (auxiliary-stationarity) dataflow speedups.

7a: best extended vs its own basic anchor (paper medians: OS x1.78,
    IS x1.96, WS x1.08 — WS gains least, Observation/Finding 1).
7b: fully-optimized IS/WS relative latency vs fully-optimized OS
    (paper: optimized WS ~7.41x slower; optimized OS beats IS ~90% of
    layers — Finding 2).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import PAPER_LAYERS, emit, time_fn
from repro.core import cost_model, explorer
from repro.core.dataflow import (
    ConvProblem, DataflowSpec, Residency, IS, OS, WS,
)
from repro.kernels import ops


def _best_for_anchor(g, anchor):
    cands = explorer.enumerate_candidates(
        g, anchors=(anchor,), prune_with_observations=False)
    if not cands:
        return None
    return min(cands, key=lambda c: (c.est_seconds, c.traffic_bytes))


def run() -> None:
    gains = {OS: [], WS: [], IS: []}
    opt_vs_os = {WS: [], IS: []}
    for hw, f, s, nf in PAPER_LAYERS:
        conv = ConvProblem(ih=hw, iw=hw, fh=f, fw=f, s=s, cin=128, cout=nf)
        g = conv.as_gemm()
        best = {}
        for anchor in (OS, WS, IS):
            basic = cost_model.gemm_time_estimate(
                g, DataflowSpec.basic(anchor))
            cand = _best_for_anchor(g, anchor)
            best[anchor] = cand.est_seconds if cand else basic
            gains[anchor].append(basic / best[anchor])
        for anchor in (WS, IS):
            opt_vs_os[anchor].append(best[anchor] / best[OS])

    for anchor, nm in ((OS, "os"), (IS, "is"), (WS, "ws")):
        emit(f"fig7a/aux_gain_{nm}", 0.0,
             round(float(np.median(gains[anchor])), 2))
    for anchor, nm in ((IS, "is"), (WS, "ws")):
        emit(f"fig7b/optimized_{nm}_vs_os", 0.0,
             round(float(np.median(opt_vs_os[anchor])), 2))

    # empirical interpret-mode check on one reduced layer: basic OS vs
    # extended OS (weight-stripe aux)
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(512, 256)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(256, 512)), jnp.float32)
    basic = DataflowSpec.basic(OS, block=(128, 128, 128))
    ext = DataflowSpec(OS, {WS: Residency.STRIPE}, (WS,), (128, 128, 128))
    us_basic = time_fn(lambda x, y: ops.matmul(x, y, spec=basic,
                                               backend="interpret"), a, b)
    us_ext = time_fn(lambda x, y: ops.matmul(x, y, spec=ext,
                                             backend="interpret"), a, b)
    emit("fig7a/empirical_os_basic", us_basic, 1.0)
    emit("fig7a/empirical_os_plus_weight_aux", us_ext,
         round(us_basic / max(us_ext, 1e-9), 2))
