"""Attention dataflow-anchor smoke suite (PR-4 parity + PR-5 banding).

The paper's OS-anchored, max-reuse dataflow *predicts* flash attention
when applied to the attention operator; the WS (kv-stationary) anchor
reproduces the paper's output-traffic pathology at attention scale.
``run_smoke`` (the CI ``attention`` suite) records the
backend-independent counters the regression gate tracks —

  * one ``pallas_call`` per anchor per layer (the single-dispatch
    lowering: flash's OS sweep and the interpret-mode WS form);
  * ONE dispatch and ZERO q-side pads for the decode (``Sq = 1``) fast
    path;
  * the analytic HBM traffic of each anchor from
    ``cost_model.attention_traffic`` (banded: only KV blocks the kernel
    actually visits are charged — the quantity the explorer ranks on);
  * ``swa_prefill``: the static sliding window shrinks the flash grid
    to the band (``grid_steps`` — trace-visible grid work, not masked
    lanes) and the banded traffic below the full-mask accounting;
  * ``decode_cached``: modeled decode traffic over a ``max_len`` cache
    buffer scales with the *valid* ``kv_len`` — the regression-tested
    serving invariant — and an int8 KV cache shrinks the stream
    further;

and writes them to ``BENCH_attention.json`` at the repo root (or
``out_path``) for ``benchmarks/check_regression.py``.
"""
from __future__ import annotations

import json
import os
from typing import Dict

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import cost_model, explorer
from repro.core.dataflow import AttentionProblem, DataflowSpec, OS, WS
from repro.core.jaxpr_utils import (
    count_eqns, count_pallas_calls, count_primitive, pallas_grid_steps,
)
from repro.kernels import ops, ref

SMOKE_CASE = dict(b=1, hq=4, hkv=2, sq=256, skv=256, d=64)
DECODE_CASE = dict(b=1, hq=4, hkv=2, sq=1, skv=256, d=64)
SWA_CASE = dict(b=1, hq=4, hkv=2, sq=512, skv=512, d=64, window=128)
DECODE_CACHED_CASE = dict(b=1, hq=4, hkv=2, d=64, max_len=1024,
                          kv_lens=(128, 256, 512, 1024))
OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_attention.json")


def _case_arrays(case, rng):
    q = jnp.asarray(rng.normal(
        size=(case["b"], case["hq"], case["sq"], case["d"])), jnp.float32)
    k = jnp.asarray(rng.normal(
        size=(case["b"], case["hkv"], case["skv"], case["d"])), jnp.float32)
    v = jnp.asarray(rng.normal(
        size=(case["b"], case["hkv"], case["skv"], case["d"])), jnp.float32)
    return q, k, v


def _problem(case) -> AttentionProblem:
    return AttentionProblem(
        bh=case["b"] * case["hq"], sq=case["sq"], skv=case["skv"],
        d=case["d"], group=case["hq"] // case["hkv"], causal=True,
        window=None, dtype="float32",
    )


def run_smoke(out_path: str = OUT_PATH) -> Dict:
    """The CI ``attention`` suite: OS(flash) vs WS(kv-stationary) anchors
    plus the decode fast path, with the dispatch/eqn/traffic counters the
    regression gate compares against the committed
    ``BENCH_attention.json``."""
    rng = np.random.default_rng(0)
    c = SMOKE_CASE
    q, k, v = _case_arrays(c, rng)
    prob = _problem(c)
    want = ref.attention_ref(q, k, v, causal=True)

    results = {
        "meta": {
            "backend": "interpret",
            "case": dict(SMOKE_CASE),
            "decode_case": dict(DECODE_CASE),
            "note": "us_per_call is interpret-mode wall clock (CPU proxy); "
                    "dispatch/eqn counts and analytic traffic bytes are "
                    "backend-independent and are the tracked claim",
        },
        "rows": [],
    }

    anchors = [
        ("os", DataflowSpec.basic(OS, block=(128, 128, c["d"]))),
        ("ws", DataflowSpec.basic(WS, block=(128, 128, c["d"]))),
    ]
    for name, spec in anchors:
        def attn(qq, kk, vv, s=spec):
            return ops.attention(qq, kk, vv, causal=True, spec=s,
                                 backend="interpret")

        jx = jax.make_jaxpr(attn)(q, k, v)
        got = attn(q, k, v)
        err = float(jnp.max(jnp.abs(got - want)))
        assert err < 3e-3, (name, err)
        row = {
            "name": name,
            "pallas_calls": count_pallas_calls(jx.jaxpr),
            "eqns": count_eqns(jx.jaxpr),
            "traffic_bytes": cost_model.attention_traffic(prob, spec).total,
            "us": round(time_fn(attn, q, k, v), 1),
        }
        assert row["pallas_calls"] == 1, row
        results["rows"].append(row)
        emit(f"attention/{name}", row["us"],
             f"calls={row['pallas_calls']} eqns={row['eqns']}"
             f" bytes={row['traffic_bytes']}")

    # decode fast path: Sq=1 -> single-q-row kernel, no q padding/blocking
    dc = DECODE_CASE
    qd, kd, vd = _case_arrays(dc, rng)
    dprob = _problem(dc)
    dspec = DataflowSpec.basic(OS, block=(1, 128, dc["d"]))

    def decode(qq, kk, vv):
        return ops.attention(qq, kk, vv, causal=True, spec=dspec,
                             backend="interpret")

    jx_d = jax.make_jaxpr(decode)(qd, kd, vd)
    derr = float(jnp.max(jnp.abs(
        decode(qd, kd, vd) - ref.attention_ref(qd, kd, vd, causal=True))))
    assert derr < 3e-3, derr
    results["decode"] = {
        "pallas_calls": count_pallas_calls(jx_d.jaxpr),
        "pad_eqns": count_primitive(jx_d.jaxpr, "pad"),
        "eqns": count_eqns(jx_d.jaxpr),
        "traffic_bytes": cost_model.attention_traffic(dprob, dspec).total,
        "us": round(time_fn(decode, qd, kd, vd), 1),
    }
    assert results["decode"]["pallas_calls"] == 1, results["decode"]
    assert results["decode"]["pad_eqns"] == 0, results["decode"]
    emit("attention/decode_sq1", results["decode"]["us"],
         f"calls={results['decode']['pallas_calls']}"
         f" pads={results['decode']['pad_eqns']}")

    # the explored pick for the smoke problem (anchor + (bq, bkv) block)
    best = explorer.explore(prob, top=1)[0]
    results["explored_best"] = {
        "name": best.spec.name,
        "block": list(best.spec.block),
        "traffic_bytes": best.traffic_bytes,
    }
    emit("attention/explored_best", 0.0,
         f"{best.spec.name} block={best.spec.block}")

    results["swa_prefill"] = _swa_prefill_suite(rng)
    results["decode_cached"] = _decode_cached_suite(rng)

    try:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
            f.write("\n")
    except OSError as e:
        _warn_unwritable(out_path, e)
    return results


def _warn_unwritable(out_path, e):
    # keep running (local read-only checkouts), but say so — the CI
    # regression gate treats a missing fresh JSON as a failure
    print(f"# WARNING: could not write {out_path}: {e}")


def _swa_prefill_suite(rng) -> Dict:
    """Static sliding-window prefill on the Pallas path.

    The window must reduce *grid work* (the static grid the
    ``pallas_call`` commits to — skipped KV blocks leave the lowering,
    they are not masked in-kernel) and the banded traffic accounting,
    while matching the windowed oracle.
    """
    c = SWA_CASE
    q, k, v = _case_arrays(c, rng)
    spec = DataflowSpec.basic(OS, block=(128, 128, c["d"]))
    prob_win = AttentionProblem(
        bh=c["b"] * c["hq"], sq=c["sq"], skv=c["skv"], d=c["d"],
        group=c["hq"] // c["hkv"], causal=True, window=c["window"],
        dtype="float32")
    prob_full = AttentionProblem(
        bh=prob_win.bh, sq=c["sq"], skv=c["skv"], d=c["d"],
        group=prob_win.group, causal=True, window=None, dtype="float32")

    def attn(qq, kk, vv, win=c["window"]):
        return ops.attention(qq, kk, vv, causal=True, window=win,
                             spec=spec, backend="interpret")

    def attn_full(qq, kk, vv):
        return ops.attention(qq, kk, vv, causal=True, spec=spec,
                             backend="interpret")

    jx_win = jax.make_jaxpr(attn)(q, k, v)
    jx_full = jax.make_jaxpr(attn_full)(q, k, v)
    got = attn(q, k, v)
    want = ref.attention_ref(q, k, v, causal=True, window=c["window"])
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 3e-3, err
    row = {
        "name": "swa_prefill",
        "pallas_calls": count_pallas_calls(jx_win.jaxpr),
        "grid_steps": pallas_grid_steps(jx_win.jaxpr),
        "grid_steps_full_mask": pallas_grid_steps(jx_full.jaxpr),
        "traffic_bytes":
            cost_model.attention_traffic(prob_win, spec).total,
        "traffic_bytes_full_mask":
            cost_model.attention_traffic(prob_full, spec).total,
        "us": round(time_fn(attn, q, k, v), 1),
    }
    assert row["pallas_calls"] == 1, row
    assert row["grid_steps"] < row["grid_steps_full_mask"], row
    assert row["traffic_bytes"] < row["traffic_bytes_full_mask"], row
    emit("attention/swa_prefill", row["us"],
         f"grid={row['grid_steps']}/{row['grid_steps_full_mask']}"
         f" bytes={row['traffic_bytes']}/{row['traffic_bytes_full_mask']}")
    return row


def _decode_cached_suite(rng) -> Dict:
    """Cached decode over a padded ``max_len`` KV buffer.

    The regression-tested serving invariant: modeled HBM traffic (and
    the kernel's visited blocks) scale with the *valid* ``kv_len``, not
    the buffer size, and an int8 KV cache shrinks the stream further.
    Parity runs the real kernel with a traced ``kv_len`` against the
    oracle on the valid slice.
    """
    c = DECODE_CACHED_CASE
    bh, group = c["b"] * c["hq"], c["hq"] // c["hkv"]
    dspec = DataflowSpec.basic(OS, block=(1, 128, c["d"]))
    case = dict(b=c["b"], hq=c["hq"], hkv=c["hkv"], sq=1,
                skv=c["max_len"], d=c["d"])
    q, k, v = _case_arrays(case, rng)

    def decode(qq, kk, vv, kl):
        return ops.attention(qq, kk, vv, causal=True, spec=dspec,
                             backend="interpret", kv_len=kl)

    rows = []
    for kl in c["kv_lens"]:
        prob = AttentionProblem(bh=bh, sq=1, skv=c["max_len"], d=c["d"],
                                group=group, causal=True, window=None,
                                dtype="float32", kv_len=kl)
        got = decode(q, k, v, jnp.int32(kl))
        want = ref.attention_ref(q, k[:, :, :kl], v[:, :, :kl], causal=True)
        err = float(jnp.max(jnp.abs(got - want)))
        assert err < 3e-3, (kl, err)
        jx = jax.make_jaxpr(decode)(q, k, v, jnp.int32(kl))
        row = {
            "name": f"decode_kv{kl}",
            "pallas_calls": count_pallas_calls(jx.jaxpr),
            "traffic_bytes": cost_model.attention_traffic(prob, dspec).total,
            "us": round(time_fn(decode, q, k, v, jnp.int32(kl)), 1),
        }
        assert row["pallas_calls"] == 1, row
        rows.append(row)
        emit(f"attention/decode_kv{kl}", row["us"],
             f"bytes={row['traffic_bytes']}")
    # traffic scales with the valid length, not max_len
    bytes_by_kl = [r["traffic_bytes"] for r in rows]
    assert all(a < b for a, b in zip(bytes_by_kl, bytes_by_kl[1:])), rows
    assert 2 * bytes_by_kl[0] < bytes_by_kl[-1], rows

    # int8 KV cache: smaller stream at the same valid length
    kl8 = c["kv_lens"][-2]
    prob8 = AttentionProblem(bh=bh, sq=1, skv=c["max_len"], d=c["d"],
                             group=group, causal=True, window=None,
                             dtype="float32", kv_len=kl8, kv_dtype="int8")
    k8 = jnp.clip(jnp.round(k * 16), -127, 127).astype(jnp.int8)
    v8 = jnp.clip(jnp.round(v * 16), -127, 127).astype(jnp.int8)
    sc = jnp.full((c["b"], c["hkv"], c["max_len"], 1), 1 / 16, jnp.float32)

    def decode8(qq, kk, vv, ks, vs, kl):
        return ops.attention(qq, kk, vv, causal=True, spec=dspec,
                             backend="interpret", kv_len=kl,
                             k_scale=ks, v_scale=vs)

    got8 = decode8(q, k8, v8, sc, sc, jnp.int32(kl8))
    want8 = ref.attention_ref(
        q, (k8 * sc)[:, :, :kl8].astype(jnp.float32),
        (v8 * sc)[:, :, :kl8].astype(jnp.float32), causal=True)
    err8 = float(jnp.max(jnp.abs(got8 - want8)))
    assert err8 < 3e-3, err8
    jx8 = jax.make_jaxpr(decode8)(q, k8, v8, sc, sc, jnp.int32(kl8))
    int8_row = {
        "name": f"decode_int8_kv{kl8}",
        "pallas_calls": count_pallas_calls(jx8.jaxpr),
        "traffic_bytes": cost_model.attention_traffic(prob8, dspec).total,
        "us": round(time_fn(decode8, q, k8, v8, sc, sc, jnp.int32(kl8)), 1),
    }
    f32_row = next(r for r in rows if r["name"] == f"decode_kv{kl8}")
    assert int8_row["pallas_calls"] == 1, int8_row
    assert int8_row["traffic_bytes"] < f32_row["traffic_bytes"], int8_row
    rows.append(int8_row)
    emit(f"attention/decode_int8_kv{kl8}", int8_row["us"],
         f"bytes={int8_row['traffic_bytes']}")

    # ragged continuous batch (PR 8): one decode step serves four
    # requests at different valid lengths — kv_len as a (B,) vector
    # bands per row, so the step's modeled traffic is the sum of each
    # request's own band, not rows x the batch max
    kvs = list(c["kv_lens"])
    nrows = len(kvs)
    rcase = dict(b=nrows, hq=c["hq"], hkv=c["hkv"], sq=1,
                 skv=c["max_len"], d=c["d"])
    qr, kr, vr = _case_arrays(rcase, rng)
    klv = jnp.asarray(kvs, jnp.int32)
    gotr = decode(qr, kr, vr, klv)
    wantr = ref.attention_ref(qr, kr, vr, causal=True, kv_len=klv)
    errr = float(jnp.max(jnp.abs(gotr - wantr)))
    assert errr < 3e-3, errr
    jxr = jax.make_jaxpr(decode)(qr, kr, vr, klv)
    rprob = AttentionProblem(bh=nrows * c["hq"], sq=1, skv=c["max_len"],
                             d=c["d"], group=group, causal=True,
                             window=None, dtype="float32", rows=nrows)
    ragged_bytes = cost_model.attention_rows_traffic(
        rprob, kvs, dspec).total
    batchmax_bytes = cost_model.attention_rows_traffic(
        rprob, [max(kvs)] * nrows, dspec).total
    ragged_row = {
        "name": "decode_ragged",
        "pallas_calls": count_pallas_calls(jxr.jaxpr),
        "traffic_bytes": ragged_bytes,
        "traffic_bytes_batchmax": batchmax_bytes,
        "us": round(time_fn(decode, qr, kr, vr, klv), 1),
    }
    assert ragged_row["pallas_calls"] == 1, ragged_row
    # the continuous-batching claim: per-row banding beats billing the
    # whole batch at the longest request's length
    assert ragged_bytes < 0.75 * batchmax_bytes, ragged_row
    rows.append(ragged_row)
    emit("attention/decode_ragged", ragged_row["us"],
         f"bytes={ragged_bytes} (batch-max model {batchmax_bytes})")
    return {"rows": rows}


if __name__ == "__main__":
    run_smoke()
