"""Attention dataflow-anchor smoke suite (the PR-4 parity claim).

The paper's OS-anchored, max-reuse dataflow *predicts* flash attention
when applied to the attention operator; the WS (kv-stationary) anchor
reproduces the paper's output-traffic pathology at attention scale.
``run_smoke`` (the CI ``attention`` suite) records the
backend-independent counters the regression gate tracks —

  * one ``pallas_call`` per anchor per layer (the single-dispatch
    lowering: flash's OS sweep and the interpret-mode WS form);
  * ONE dispatch and ZERO q-side pads for the decode (``Sq = 1``) fast
    path;
  * the analytic HBM traffic of each anchor from
    ``cost_model.attention_traffic`` (Q/KV/O bytes plus the WS state
    round-trips — the quantity the explorer ranks on);

and writes them to ``BENCH_attention.json`` at the repo root (or
``out_path``) for ``benchmarks/check_regression.py``.
"""
from __future__ import annotations

import json
import os
from typing import Dict

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import cost_model, explorer
from repro.core.dataflow import AttentionProblem, DataflowSpec, OS, WS
from repro.core.jaxpr_utils import (
    count_eqns, count_pallas_calls, count_primitive,
)
from repro.kernels import ops, ref

SMOKE_CASE = dict(b=1, hq=4, hkv=2, sq=256, skv=256, d=64)
DECODE_CASE = dict(b=1, hq=4, hkv=2, sq=1, skv=256, d=64)
OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_attention.json")


def _case_arrays(case, rng):
    q = jnp.asarray(rng.normal(
        size=(case["b"], case["hq"], case["sq"], case["d"])), jnp.float32)
    k = jnp.asarray(rng.normal(
        size=(case["b"], case["hkv"], case["skv"], case["d"])), jnp.float32)
    v = jnp.asarray(rng.normal(
        size=(case["b"], case["hkv"], case["skv"], case["d"])), jnp.float32)
    return q, k, v


def _problem(case) -> AttentionProblem:
    return AttentionProblem(
        bh=case["b"] * case["hq"], sq=case["sq"], skv=case["skv"],
        d=case["d"], group=case["hq"] // case["hkv"], causal=True,
        window=None, dtype="float32",
    )


def run_smoke(out_path: str = OUT_PATH) -> Dict:
    """The CI ``attention`` suite: OS(flash) vs WS(kv-stationary) anchors
    plus the decode fast path, with the dispatch/eqn/traffic counters the
    regression gate compares against the committed
    ``BENCH_attention.json``."""
    rng = np.random.default_rng(0)
    c = SMOKE_CASE
    q, k, v = _case_arrays(c, rng)
    prob = _problem(c)
    want = ref.attention_ref(q, k, v, causal=True)

    results = {
        "meta": {
            "backend": "interpret",
            "case": dict(SMOKE_CASE),
            "decode_case": dict(DECODE_CASE),
            "note": "us_per_call is interpret-mode wall clock (CPU proxy); "
                    "dispatch/eqn counts and analytic traffic bytes are "
                    "backend-independent and are the tracked claim",
        },
        "rows": [],
    }

    anchors = [
        ("os", DataflowSpec.basic(OS, block=(128, 128, c["d"]))),
        ("ws", DataflowSpec.basic(WS, block=(128, 128, c["d"]))),
    ]
    for name, spec in anchors:
        def attn(qq, kk, vv, s=spec):
            return ops.attention(qq, kk, vv, causal=True, spec=s,
                                 backend="interpret")

        jx = jax.make_jaxpr(attn)(q, k, v)
        got = attn(q, k, v)
        err = float(jnp.max(jnp.abs(got - want)))
        assert err < 3e-3, (name, err)
        row = {
            "name": name,
            "pallas_calls": count_pallas_calls(jx.jaxpr),
            "eqns": count_eqns(jx.jaxpr),
            "traffic_bytes": cost_model.attention_traffic(prob, spec).total,
            "us": round(time_fn(attn, q, k, v), 1),
        }
        assert row["pallas_calls"] == 1, row
        results["rows"].append(row)
        emit(f"attention/{name}", row["us"],
             f"calls={row['pallas_calls']} eqns={row['eqns']}"
             f" bytes={row['traffic_bytes']}")

    # decode fast path: Sq=1 -> single-q-row kernel, no q padding/blocking
    dc = DECODE_CASE
    qd, kd, vd = _case_arrays(dc, rng)
    dprob = _problem(dc)
    dspec = DataflowSpec.basic(OS, block=(1, 128, dc["d"]))

    def decode(qq, kk, vv):
        return ops.attention(qq, kk, vv, causal=True, spec=dspec,
                             backend="interpret")

    jx_d = jax.make_jaxpr(decode)(qd, kd, vd)
    derr = float(jnp.max(jnp.abs(
        decode(qd, kd, vd) - ref.attention_ref(qd, kd, vd, causal=True))))
    assert derr < 3e-3, derr
    results["decode"] = {
        "pallas_calls": count_pallas_calls(jx_d.jaxpr),
        "pad_eqns": count_primitive(jx_d.jaxpr, "pad"),
        "eqns": count_eqns(jx_d.jaxpr),
        "traffic_bytes": cost_model.attention_traffic(dprob, dspec).total,
        "us": round(time_fn(decode, qd, kd, vd), 1),
    }
    assert results["decode"]["pallas_calls"] == 1, results["decode"]
    assert results["decode"]["pad_eqns"] == 0, results["decode"]
    emit("attention/decode_sq1", results["decode"]["us"],
         f"calls={results['decode']['pallas_calls']}"
         f" pads={results['decode']['pad_eqns']}")

    # the explored pick for the smoke problem (anchor + (bq, bkv) block)
    best = explorer.explore(prob, top=1)[0]
    results["explored_best"] = {
        "name": best.spec.name,
        "block": list(best.spec.block),
        "traffic_bytes": best.traffic_bytes,
    }
    emit("attention/explored_best", 0.0,
         f"{best.spec.name} block={best.spec.block}")

    try:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
            f.write("\n")
    except OSError as e:
        # keep running (local read-only checkouts), but say so — the CI
        # regression gate treats a missing fresh JSON as a failure
        print(f"# WARNING: could not write {out_path}: {e}")
    return results


if __name__ == "__main__":
    run_smoke()
