"""Global model-lowering flags.

EXACT_COST_MODE: dry-run-only switch that removes every inner lax.scan
(plain attention instead of chunked, naive CE, unrolled SSD chunks) so
XLA ``cost_analysis`` counts all FLOPs exactly — XLA counts a while-loop
body ONCE regardless of trip count, so scan-based lowerings undercount.
Never enabled at execution time (the plain paths materialize S x S
buffers); see launch/dryrun.derive_costs.
"""
from __future__ import annotations

import contextlib

EXACT_COST_MODE = False


@contextlib.contextmanager
def exact_cost_mode():
    global EXACT_COST_MODE
    prev = EXACT_COST_MODE
    EXACT_COST_MODE = True
    try:
        yield
    finally:
        EXACT_COST_MODE = prev
