"""Mixture-of-Experts layer: sort-based capacity dispatch + EP all-to-all.

Dispatch is scatter/sort-based (no GShard one-hot (T, E, C) tensor — that
blows past HBM at 128 experts); tokens are sorted by expert id, placed into
an (E, C, D) capacity buffer, exchanged over the ``model`` mesh axis with
``jax.lax.all_to_all`` (expert parallelism), run through the local experts
as one batched GEMM, and returned.

Two modes:
  * ``moe_apply`` — local (single shard) path: used by smoke tests and as
    the shard_map body.
  * ``moe_apply_sharded`` — shard_map-wrapped EP path used by the
    distributed train/serve steps; the all-to-alls appear explicitly in
    the lowered HLO (they are the collective term of the MoE roofline).

Shared experts (moonshot-style) run as a plain dense MLP on every token —
data-independent of the dispatched path, so XLA overlaps them with the
all-to-all (documented in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

try:  # jax >= 0.5 exports shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def _shard_map(f, **kw):
        # the experimental replication checker has known false positives
        # (e.g. on scan carries); newer jax removed the knob entirely
        kw.setdefault("check_rep", False)
        return _exp_shard_map(f, **kw)


def _axis_size(axis_name: str) -> int:
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)  # pre-0.5 spelling

from repro.models import layers

Params = Dict[str, jax.Array]


def init_moe(key, cfg) -> Params:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)
    scale = (2.0 / (d + f)) ** 0.5
    dt = jnp.dtype(cfg.param_dtype)
    p: Params = {
        "router": (jax.random.normal(ks[0], (d, e), jnp.float32)
                   * d ** -0.5).astype(jnp.float32),
        "w1": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale
               ).astype(dt),
        "w3": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale
               ).astype(dt),
        "w2": (jax.random.normal(ks[3], (e, f, d), jnp.float32) * scale
               ).astype(dt),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared"] = layers.init_mlp(ks[4], d, fs, cfg.param_dtype)
    return p


def _route(x_flat: jax.Array, router: jax.Array, top_k: int):
    """Top-k routing with renormalized gates. x_flat: (T, D)."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(probs, top_k)              # (T, k)
    top_g = top_g / jnp.sum(top_g, axis=-1, keepdims=True)
    # load-balancing aux loss (Switch-style): E * sum_e f_e * p_e
    e = router.shape[1]
    f_e = jnp.mean(
        jax.nn.one_hot(top_e, e, dtype=jnp.float32).sum(axis=1), axis=0
    )
    p_e = jnp.mean(probs, axis=0)
    aux_loss = e * jnp.sum(f_e * p_e) / top_k
    return top_g, top_e, aux_loss


def _dispatch_indices(top_e: jax.Array, top_k: int, n_experts: int,
                      capacity: int):
    """Sort token->expert assignments; compute per-expert slot positions."""
    t = top_e.shape[0]
    flat_e = top_e.reshape(-1)                              # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]                                      # sorted expert id
    st = order // top_k                                     # source token
    starts = jnp.searchsorted(se, jnp.arange(n_experts), side="left")
    pos = jnp.arange(t * top_k) - starts[se]
    keep = pos < capacity
    pos_c = jnp.minimum(pos, capacity - 1)
    return order, se, st, pos_c, keep


def _expert_ffn(p: Params, xs: jax.Array) -> jax.Array:
    """Batched SwiGLU over experts: xs (E_loc, C*, D)."""
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, p["w1"]))
    up = jnp.einsum("ecd,edf->ecf", xs, p["w3"])
    return jnp.einsum("ecf,efd->ecd", gate * up, p["w2"])


# §Perf iteration 4: int8-compressed dispatch all-to-all.  Forward sends
# int8 payload + per-slot scales (~2x fewer ICI bytes); backward routes the
# cotangent through a plain bf16 all-to-all (straight-through estimator —
# the quantization error is treated as identity, the standard MoE-a2a
# compression arrangement).
A2A_INT8 = True


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def _a2a(x, axis_name: str, split_axis: int, concat_axis: int):
    return jax.lax.all_to_all(x, axis_name, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=False)


def _a2a_fwd(x, axis_name, split_axis, concat_axis):
    if not A2A_INT8:
        return _a2a(x, axis_name, split_axis, concat_axis), None
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.where(amax == 0, 1.0, amax / 127.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -127, 127).astype(jnp.int8)
    q = jax.lax.all_to_all(q, axis_name, split_axis=split_axis,
                           concat_axis=concat_axis, tiled=False)
    scale = jax.lax.all_to_all(scale, axis_name, split_axis=split_axis,
                               concat_axis=concat_axis, tiled=False)
    return (q.astype(jnp.float32) * scale).astype(x.dtype), None


def _a2a_bwd(axis_name, split_axis, concat_axis, _, g):
    # all_to_all is its own inverse with swapped axes
    return (jax.lax.all_to_all(g, axis_name, split_axis=concat_axis,
                               concat_axis=split_axis, tiled=False),)


_a2a.defvjp(_a2a_fwd, _a2a_bwd)


def moe_apply(
    p: Params, x: jax.Array, cfg, ep_axis: Optional[str] = None
) -> Tuple[jax.Array, jax.Array]:
    """MoE block. x: (B, S, D). Returns (y, aux_loss).

    With ``ep_axis`` set this function is running inside shard_map: experts
    in ``p`` are the local shard (E_loc = E / axis_size) and capacity
    buffers are exchanged with all_to_all over that axis.
    """
    b, s, d = x.shape
    x_flat = x.reshape(-1, d)
    t = x_flat.shape[0]
    e, k = cfg.n_experts, cfg.top_k
    ep = _axis_size(ep_axis) if ep_axis else 1
    e_loc = e // ep

    top_g, top_e, aux = _route(x_flat, p["router"], k)
    capacity = max(8, int(cfg.capacity_factor * t * k / e))
    order, se, st, pos_c, keep = _dispatch_indices(top_e, k, e, capacity)

    buf = jnp.zeros((e, capacity, d), x.dtype)
    vals = x_flat[st] * keep[:, None].astype(x.dtype)
    buf = buf.at[se, pos_c].add(vals)

    if ep_axis:
        # (E, C, D) -> (ep, E_loc, C, D) -> exchange -> local experts hold
        # one (C) slab from every peer: (ep, E_loc, C, D) -> (E_loc, ep*C, D)
        buf = buf.reshape(ep, e_loc, capacity, d)
        buf = _a2a(buf, ep_axis, 0, 0)
        buf = buf.transpose(1, 0, 2, 3).reshape(e_loc, ep * capacity, d)

    out_buf = _expert_ffn(p, buf)

    if ep_axis:
        out_buf = out_buf.reshape(e_loc, ep, capacity, d).transpose(1, 0, 2, 3)
        out_buf = _a2a(out_buf, ep_axis, 0, 0)
        out_buf = out_buf.reshape(e, capacity, d)

    gathered = out_buf[se, pos_c] * keep[:, None].astype(out_buf.dtype)
    y_sorted = jnp.zeros((t * k, d), x.dtype)
    y_flat = y_sorted.at[order].set(gathered.astype(x.dtype))
    y = (y_flat.reshape(t, k, d)
         * top_g[..., None].astype(x.dtype)).sum(axis=1)

    if "shared" in p:
        y = y + layers.mlp_apply(p["shared"], x_flat, cfg)

    return y.reshape(b, s, d), aux


def moe_apply_psum_local(
    p: Params, x: jax.Array, cfg, ep_axis: str
) -> Tuple[jax.Array, jax.Array]:
    """EP without all-to-all: every shard routes all its tokens, runs only
    its local experts, and the outputs are psum-combined over the EP axis.

    Used for decode (seq=1 cannot shard over the model axis) where the
    token count is tiny and the psum of (T, D) is cheaper than an a2a.
    """
    b, s, d = x.shape
    x_flat = x.reshape(-1, d)
    t = x_flat.shape[0]
    e, k = cfg.n_experts, cfg.top_k
    ep = _axis_size(ep_axis)
    e_loc = e // ep
    rank = jax.lax.axis_index(ep_axis)

    top_g, top_e, aux = _route(x_flat, p["router"], k)
    capacity = max(8, int(cfg.capacity_factor * t * k / e))
    order, se, st, pos_c, keep = _dispatch_indices(top_e, k, e, capacity)

    buf = jnp.zeros((e, capacity, d), x.dtype)
    vals = x_flat[st] * keep[:, None].astype(x.dtype)
    buf = buf.at[se, pos_c].add(vals)
    # local experts only: slice [rank*e_loc, (rank+1)*e_loc)
    buf_loc = jax.lax.dynamic_slice_in_dim(buf, rank * e_loc, e_loc, axis=0)
    out_loc = _expert_ffn(p, buf_loc)
    out_buf = jnp.zeros((e, capacity, d), out_loc.dtype)
    out_buf = jax.lax.dynamic_update_slice_in_dim(
        out_buf, out_loc, rank * e_loc, axis=0
    )

    gathered = out_buf[se, pos_c] * keep[:, None].astype(out_buf.dtype)
    y_flat = jnp.zeros((t * k, d), x.dtype).at[order].set(
        gathered.astype(x.dtype))
    y = (y_flat.reshape(t, k, d)
         * top_g[..., None].astype(x.dtype)).sum(axis=1)
    y = jax.lax.psum(y, ep_axis)
    if "shared" in p:
        y = y + layers.mlp_apply(p["shared"], x_flat, cfg)
    return y.reshape(b, s, d), aux


def moe_apply_sharded(
    p: Params, x: jax.Array, cfg, mesh: jax.sharding.Mesh,
    dp_axes: Tuple[str, ...], tp_axis: str,
) -> Tuple[jax.Array, jax.Array]:
    """shard_map-wrapped EP MoE.

    Training/prefill: x sharded (batch over dp_axes, seq over tp_axis);
    experts over tp_axis (EP == TP, n_experts % tp == 0); capacity
    buffers exchanged by all_to_all.  Decode (seq < tp): psum-local mode.
    """
    from jax.sharding import PartitionSpec as P

    tp = mesh.shape[tp_axis]
    s = x.shape[1]
    a2a_mode = s % tp == 0 and s >= tp

    pspec_x = P(dp_axes, tp_axis if a2a_mode else None, None)
    pspec_experts = P(tp_axis, None, None)
    in_specs = (
        {
            **{kk: pspec_experts for kk in ("w1", "w2", "w3")},
            "router": P(),
            **({"shared": {kk: P() for kk in ("w1", "w2", "w3")}}
               if "shared" in p else {}),
        },
        pspec_x,
    )

    def body(p_loc, x_loc):
        if a2a_mode:
            y, aux = moe_apply(p_loc, x_loc, cfg, ep_axis=tp_axis)
        else:
            y, aux = moe_apply_psum_local(p_loc, x_loc, cfg, ep_axis=tp_axis)
        aux = jax.lax.pmean(jax.lax.pmean(aux, tp_axis), dp_axes)
        return y, aux

    fn = _shard_map(
        body, mesh=mesh, in_specs=in_specs,
        out_specs=(pspec_x, P()),
    )
    return fn(p, x)
