"""Foundational model layers — pure JAX, explicit param pytrees.

Every layer is an ``init_*(key, ...) -> params`` plus a pure apply
function.  The attention apply dispatches between the plain XLA oracle,
a chunked online-softmax path (memory-safe for 32k+ contexts), and the
Pallas flash kernel (on TPU runtimes).

Graceful degradation: every kernel dispatch site in this module
(``attention_apply``, ``mlp_apply``, ``binary_dense``) consults a
process-wide *backend override* before resolving its backend.  The
serving engine's ``DegradationPolicy`` (runtime/health.py) traces its
degraded step functions under ``forced_backend("xla")``, which pins
every site onto the existing XLA escape hatches (``_attention_xla``,
the einsum MLP, the binary reference path) without threading a backend
argument through the model scan.  The same sites carry named
fault-injection points (``layers.attention`` / ``layers.mlp`` /
``kernel.binary_matmul`` via ops) so a drill can fail any one dispatch
and watch the stack degrade instead of crash.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import flags

Params = Dict[str, jax.Array]

# Process-wide kernel-backend override ("xla" pins every dispatch site
# onto its escape hatch; None = per-site resolution).  Consulted at
# trace time, so a jitted function built under ``forced_backend`` bakes
# the override into its trace.
_BACKEND_OVERRIDE: Optional[str] = None


@contextlib.contextmanager
def forced_backend(backend: Optional[str]):
    """Pin every kernel dispatch site in this module to ``backend``
    for the duration (used by the serving engine's degraded step
    functions; active during tracing is sufficient)."""
    global _BACKEND_OVERRIDE
    prev = _BACKEND_OVERRIDE
    _BACKEND_OVERRIDE = backend
    try:
        yield
    finally:
        _BACKEND_OVERRIDE = prev


def backend_override() -> Optional[str]:
    return _BACKEND_OVERRIDE


def _dtype(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------
def init_rmsnorm(dim: int, dtype="float32") -> Params:
    return {"scale": jnp.ones((dim,), _dtype(dtype))}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    orig = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(orig)


# ---------------------------------------------------------------------------
# Rotary position embedding.
# ---------------------------------------------------------------------------
def rope_frequencies(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, D) with positions (..., S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                     # (D/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)            # (..., S, D/2)
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    orig = x.dtype
    x1, x2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(orig)


# ---------------------------------------------------------------------------
# Dense / embedding.
# ---------------------------------------------------------------------------
def init_dense(key, d_in: int, d_out: int, dtype="bfloat16") -> Params:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32)
                  * scale).astype(_dtype(dtype))}


def dense(params: Params, x: jax.Array) -> jax.Array:
    return jnp.einsum("...d,df->...f", x, params["w"])


def init_embedding(key, vocab: int, dim: int, dtype="bfloat16") -> Params:
    emb = jax.random.normal(key, (vocab, dim), jnp.float32) * dim ** -0.5
    return {"table": emb.astype(_dtype(dtype))}


def embed(params: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params: Params, x: jax.Array) -> jax.Array:
    return jnp.einsum("...d,vd->...v", x, params["table"])


# ---------------------------------------------------------------------------
# Attention (GQA, optional qk-norm / sliding window / chunked softmax).
# ---------------------------------------------------------------------------
def init_attention(key, cfg) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {
        "wq": init_dense(ks[0], cfg.d_model, cfg.q_dim, cfg.param_dtype)["w"],
        "wk": init_dense(ks[1], cfg.d_model, cfg.kv_dim, cfg.param_dtype)["w"],
        "wv": init_dense(ks[2], cfg.d_model, cfg.kv_dim, cfg.param_dtype)["w"],
        "wo": init_dense(ks[3], cfg.q_dim, cfg.d_model, cfg.param_dtype)["w"],
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(cfg.d_head)["scale"]
        p["k_norm"] = init_rmsnorm(cfg.d_head)["scale"]
    return p


def _plain_attention(q, k, v, mask_fn, scale, k_scale=None, v_scale=None):
    # q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D); optional per-position
    # (B, Hkv, Skv, 1) int8-KV dequant scales, folded into the logits /
    # probabilities so the int8 cache never materializes a float copy.
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, sq, d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if k_scale is not None:
        logits = logits * k_scale[..., 0][:, :, None, None, :]
    mask = mask_fn(jnp.arange(sq), jnp.arange(skv))
    # ragged mask_fns return (B, Sq, Skv) — one band per batch row
    mask = mask[:, None, None] if mask.ndim == 3 else mask[None, None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)   # fully-masked row (kv_valid == 0)
    if v_scale is not None:
        p = p * v_scale[..., 0][:, :, None, None, :]
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def _chunked_attention(q, k, v, mask_fn, scale, q_chunk: int = 512,
                       kv_chunk: int = 1024, k_scale=None, v_scale=None):
    """Double-chunked online-softmax attention.

    Outer scan over q chunks, inner scan over kv chunks: live memory is
    O(B * H * q_chunk * kv_chunk) regardless of sequence length — this is
    the OS-anchored dataflow expressed in XLA (the Pallas flash kernel is
    its TPU-native realization).  int8 K/V dequantize per chunk via the
    optional per-position scales (folded into logits/probabilities), so
    live memory stays chunk-sized for quantized caches too.
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    quant = k_scale is not None

    nq = -(-sq // q_chunk)
    qpad = nq * q_chunk - sq
    nk = -(-skv // kv_chunk)
    kpad = nk * kv_chunk - skv
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, qpad), (0, 0))) if qpad else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, kpad), (0, 0))) if kpad else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, kpad), (0, 0))) if kpad else v

    qg = qp.reshape(b, hkv, g, nq, q_chunk, d).transpose(3, 0, 1, 2, 4, 5)
    kc = kp.reshape(b, hkv, nk, kv_chunk, d).transpose(2, 0, 1, 3, 4)
    vc = vp.reshape(b, hkv, nk, kv_chunk, d).transpose(2, 0, 1, 3, 4)
    xs = (kc, vc)
    if quant:
        def chunk_scales(s):
            sp = (jnp.pad(s, ((0, 0), (0, 0), (0, kpad), (0, 0)))
                  if kpad else s)
            return sp.reshape(b, hkv, nk, kv_chunk, 1).transpose(
                2, 0, 1, 3, 4)
        xs = xs + (chunk_scales(k_scale), chunk_scales(v_scale))

    def q_step(iq, q_i):
        q_i = q_i.astype(jnp.float32)                    # (b,hkv,g,qc,d)
        qpos = iq * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inp):
            acc, m, l, j = carry
            if quant:
                kj, vj, ksj, vsj = inp
            else:
                kj, vj = inp
            logits = jnp.einsum("bhgqd,bhkd->bhgqk", q_i,
                                kj.astype(jnp.float32)) * scale
            if quant:
                logits = logits * ksj[..., 0][:, :, None, None, :]
            kpos = j * kv_chunk + jnp.arange(kv_chunk)
            mask = mask_fn(qpos, kpos) & (kpos < skv)[None, :] \
                & (qpos < sq)[:, None]
            mask = (mask[:, None, None] if mask.ndim == 3
                    else mask[None, None, None])
            logits = jnp.where(mask, logits, -jnp.inf)
            m_new = jnp.maximum(m, logits.max(axis=-1, keepdims=True))
            m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p = jnp.exp(logits - m_safe)
            p = jnp.where(jnp.isneginf(logits), 0.0, p)
            alpha = jnp.exp(jnp.where(jnp.isneginf(m), 0.0, m - m_safe))
            alpha = jnp.where(jnp.isneginf(m), 0.0, alpha)
            l_new = alpha * l + p.sum(axis=-1, keepdims=True)
            if quant:
                p = p * vsj[..., 0][:, :, None, None, :]
            acc = acc * alpha[..., 0][..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vj.astype(jnp.float32)
            )
            return (acc, m_new, l_new, j + 1), None

        acc0 = jnp.zeros((b, hkv, g, q_chunk, d), jnp.float32)
        m0 = jnp.full((b, hkv, g, q_chunk, 1), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk, 1), jnp.float32)
        (acc, m, l, _), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (acc0, m0, l0, 0), xs
        )
        l = jnp.where(l == 0.0, 1.0, l)
        return (acc / l).astype(q.dtype), iq + 1

    def q_scan(carry, q_i):
        iq = carry
        out_i, iq = q_step(iq, q_i)
        return iq, out_i

    # flash-style recompute: neither scan saves its probability matrices
    _, outs = jax.lax.scan(jax.checkpoint(q_scan), 0, qg)  # (nq,b,hkv,g,qc,d)
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, nq * q_chunk, d)
    return out[:, :, :sq]


def bidir_attention(q, k, v, scale, chunked_threshold: int = 2048):
    """Non-causal attention (encoder / cross) with chunked dispatch."""
    mask_fn = lambda qp, kp: jnp.ones((qp.shape[0], kp.shape[0]), bool)
    if k.shape[2] > chunked_threshold and not flags.EXACT_COST_MODE:
        return _chunked_attention(q, k, v, mask_fn, scale)
    return _plain_attention(q, k, v, mask_fn, scale)


def _attention_xla(q, k, v, scale, *, window=None, kv_len=None,
                   k_scale=None, v_scale=None, chunked_threshold=2048):
    """The single XLA escape hatch behind ``attention_apply``.

    One masked-einsum implementation (``ref.attention_ref`` semantics:
    causal, static/traced window, valid-KV-prefix mask, folded int8-KV
    dequant), realized chunked beyond ``chunked_threshold`` so live
    memory stays O(chunk) for long caches.  The banded-SWA oracle
    (``ref.banded_swa_attention_ref``) is substituted only under
    ``flags.EXACT_COST_MODE`` — a dry-run-only analysis mode where
    XLA's cost_analysis must see the banded einsums to count windowed
    attention FLOPs honestly; at execution time windowed banding lives
    in the Pallas kernel grid, not here.
    """
    from repro.kernels import ref as kref

    sq, skv = q.shape[2], k.shape[2]
    if (flags.EXACT_COST_MODE and isinstance(window, int)
            and kv_len is None and sq == skv and sq > 2 * window):
        return kref.banded_swa_attention_ref(q, k, v, int(window), scale)
    kv_valid = skv if kv_len is None else kv_len
    off = kv_valid - sq                       # right-align q rows
    ragged = getattr(kv_len, "ndim", 0) == 1  # (B,) per-row valid length

    def mask_fn(qpos, kpos):
        if ragged:
            qp = qpos[None, :, None] + off[:, None, None]   # (B, Sq, 1)
            kp = kpos[None, None, :]
            m = (kp <= qp) & (kp < kv_valid[:, None, None])
            if window is not None:
                m &= kp > qp - window
            return m                                        # (B, Sq, Skv)
        qp = (qpos + off)[:, None]
        kp = kpos[None, :]
        m = kp <= qp
        if kv_len is not None:
            m &= kp < kv_valid
        if window is not None:
            m &= kp > qp - window
        return m

    if skv > chunked_threshold and not flags.EXACT_COST_MODE:
        return _chunked_attention(q, k, v, mask_fn, scale,
                                  k_scale=k_scale, v_scale=v_scale)
    return _plain_attention(q, k, v, mask_fn, scale,
                            k_scale=k_scale, v_scale=v_scale)


def _cache_update(buf, val, idx):
    """Write ``val`` into the position axis (2) of a KV-cache buffer.

    A scalar ``idx`` writes every batch row at the same offset (the
    batch-synchronous path); a ``(B,)`` vector writes each row at its
    own offset — the ragged continuous-batching path, realized as a
    per-row ``dynamic_update_slice`` under ``vmap``.
    """
    if getattr(idx, "ndim", 0) == 1:
        return jax.vmap(
            lambda b_, v_, i_: jax.lax.dynamic_update_slice_in_dim(
                b_, v_, i_, axis=1)
        )(buf, val, idx)
    return jax.lax.dynamic_update_slice_in_dim(buf, val, idx, axis=2)


def _quantize_kv(x):
    """Symmetric per-(batch, head, position) int8 quantization of K/V."""
    from repro.core import quant

    return quant.symmetric_int8(x, axis=-1)


def attention_apply(
    p: Params,
    x: jax.Array,                 # (B, S, D_model)
    cfg,
    positions: Optional[jax.Array] = None,
    window: Optional[jax.Array] = None,   # traced or static window length
    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    cache_index: Optional[jax.Array] = None,
    chunked_threshold: int = 2048,
    attend_local: bool = False,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """GQA self-attention. Returns (out, new_kv_cache).

    Every masked branch — plain prefill, KV-cache decode (traced
    ``cache_index`` / valid length), static and traced sliding windows,
    int8 KV with per-position scales — dispatches ONE
    ``kernels.ops.attention`` call on kernel backends: the kernel grid
    skips KV blocks beyond the valid cache length and outside the
    window band, and dequantizes int8 K/V at the block load, so decode
    cost scales with the filled cache, not ``max_len``.
    ``backend="xla"`` is the single escape hatch (``_attention_xla``:
    masked einsum, chunked beyond ``chunked_threshold``, dequant folded
    into logits/probabilities — never a float copy of the cache);
    ``backend=None`` picks the Pallas kernel on TPU runtimes with
    ``cfg.use_pallas_kernels``, the escape hatch otherwise.

    ``attend_local``: update the cache but attend over the freshly
    projected K/V (prefill-from-zero: identical math, and it keeps the
    attended KV length at ``S`` instead of the padded cache buffer).
    """
    from repro.runtime import health

    fault = health.maybe_inject("layers.attention")
    b, s, _ = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = jnp.einsum("bsd,df->bsf", x, p["wq"]).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,df->bsf", x, p["wk"]).reshape(b, s, hkv, dh)
    v = jnp.einsum("bsd,df->bsf", x, p["wv"]).reshape(b, s, hkv, dh)
    if cfg.qk_norm:
        q = rmsnorm({"scale": p["q_norm"]}, q, cfg.norm_eps)
        k = rmsnorm({"scale": p["k_norm"]}, k, cfg.norm_eps)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q = apply_rope(q.transpose(0, 2, 1, 3), positions[:, None], cfg.rope_theta)
    k = apply_rope(k.transpose(0, 2, 1, 3), positions[:, None], cfg.rope_theta)
    v = v.transpose(0, 2, 1, 3)

    new_cache = None
    kv_len = None                    # valid attended-KV prefix (traced)
    k_att, v_att = k, v              # operands actually attended over
    k_sc = v_sc = None               # int8-KV per-position scales
    if kv_cache is not None:
        ck, cv = kv_cache[0], kv_cache[1]   # (B, Hkv, S_max, Dh) [+ scales]
        int8_kv = ck.dtype == jnp.int8
        if int8_kv:
            k_store, k_scale = _quantize_kv(k)
            v_store, v_scale = _quantize_kv(v)
        else:
            k_store, v_store = k.astype(ck.dtype), v.astype(cv.dtype)
        ck = _cache_update(ck, k_store, cache_index)
        cv = _cache_update(cv, v_store, cache_index)
        if int8_kv:
            cks, cvs = kv_cache[2], kv_cache[3]
            cks = _cache_update(cks, k_scale, cache_index)
            cvs = _cache_update(cvs, v_scale, cache_index)
            new_cache = (ck, cv, cks, cvs)
        else:
            new_cache = (ck, cv)
        if not attend_local:
            k_att, v_att = ck, cv    # int8 stays int8: dequant happens
            if int8_kv:              # at the kernel block load / folded
                k_sc, v_sc = cks, cvs
            kv_len = cache_index + s     # traced valid length
    scale = dh ** -0.5
    if backend is None:
        backend = _BACKEND_OVERRIDE or (
            "pallas" if cfg.use_pallas_kernels
            and jax.default_backend() == "tpu" else "xla")
    if backend == "xla":
        out = _attention_xla(
            q, k_att, v_att, scale, window=window, kv_len=kv_len,
            k_scale=k_sc, v_scale=v_sc,
            chunked_threshold=chunked_threshold,
        )
    else:
        from repro.kernels import ops as kops

        static_window = window if isinstance(window, int) else None
        out = kops.attention(
            q, k_att, v_att, causal=True, scale=scale,
            window=static_window,
            window_dyn=None if static_window is not None else window,
            kv_len=kv_len, k_scale=k_sc, v_scale=v_sc, backend=backend,
        )

    if fault == "nan":
        out = out * jnp.asarray(jnp.nan, out.dtype)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    return jnp.einsum("bsf,fd->bsd", out, p["wo"]), new_cache


def paged_attention_apply(
    p: Params,
    x: jax.Array,                 # (B, 1, D_model) decode activations
    cfg,
    *,
    positions: jax.Array,         # (B, 1) absolute position of this token
    window: Optional[int],        # static-only (kernel grid parameter)
    k_pages: jax.Array,           # (Hkv, n_pages, page, Dh) one layer's pool
    v_pages: jax.Array,
    block_tables: jax.Array,      # (B, max_pages) int32 page ids
    kv_lens: jax.Array,           # (B,) int32 filled KV length (pre-write)
    write_pids: jax.Array,        # (B,) int32 page receiving this step's KV
    write_offs: jax.Array,        # (B,) int32 offset within that page
    backend: Optional[str] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """GQA decode attention straight off a paged KV pool (PR-10 tentpole).

    The decode twin of ``attention_apply``'s cached branch with the
    contiguous ``(B, max_len)`` cache strip replaced by block-table
    indirection into the shared page pool: the fresh K/V is scattered to
    ``(write_pids, write_offs)`` (idle batch rows point at the pool's
    scratch page) and attention runs through ``kernels.ops.
    paged_attention`` over each row's ``block_tables`` row with a
    ``kv_lens + 1`` band.  Projections, qk-norm, RoPE and the output
    projection are byte-for-byte the same graph as ``attention_apply``,
    and the gathered XLA fallback reproduces the contiguous decode
    band exactly — so paged and slot decode emit bit-identical logits.

    Returns ``(out, (k_pages, v_pages))`` with the updated pools.
    """
    from repro.runtime import health

    fault = health.maybe_inject("layers.attention")
    b, s, _ = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = jnp.einsum("bsd,df->bsf", x, p["wq"]).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,df->bsf", x, p["wk"]).reshape(b, s, hkv, dh)
    v = jnp.einsum("bsd,df->bsf", x, p["wv"]).reshape(b, s, hkv, dh)
    if cfg.qk_norm:
        q = rmsnorm({"scale": p["q_norm"]}, q, cfg.norm_eps)
        k = rmsnorm({"scale": p["k_norm"]}, k, cfg.norm_eps)
    q = apply_rope(q.transpose(0, 2, 1, 3), positions[:, None], cfg.rope_theta)
    k = apply_rope(k.transpose(0, 2, 1, 3), positions[:, None], cfg.rope_theta)
    v = v.transpose(0, 2, 1, 3)

    # scatter this step's K/V into each row's (page, offset) slot
    k_new = k[:, :, 0].astype(k_pages.dtype).transpose(1, 0, 2)  # (Hkv, B, Dh)
    v_new = v[:, :, 0].astype(v_pages.dtype).transpose(1, 0, 2)
    k_pages = k_pages.at[:, write_pids, write_offs].set(k_new)
    v_pages = v_pages.at[:, write_pids, write_offs].set(v_new)

    scale = dh ** -0.5
    if backend is None:
        backend = _BACKEND_OVERRIDE or (
            "pallas" if cfg.use_pallas_kernels
            and jax.default_backend() == "tpu" else "xla")
    from repro.kernels import ops as kops

    out = kops.paged_attention(
        q, k_pages, v_pages, block_tables, kv_lens + 1,
        scale=scale, window=window, backend=backend,
    )
    if fault == "nan":
        out = out * jnp.asarray(jnp.nan, out.dtype)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    return jnp.einsum("bsf,fd->bsd", out, p["wo"]), (k_pages, v_pages)


# ---------------------------------------------------------------------------
# SwiGLU MLP.
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, dtype="bfloat16") -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": init_dense(k1, d_model, d_ff, dtype)["w"],   # gate
        "w3": init_dense(k2, d_model, d_ff, dtype)["w"],   # up
        "w2": init_dense(k3, d_ff, d_model, dtype)["w"],   # down
    }


def fused_dense(
    x: jax.Array,                    # (..., d_in)
    w: jax.Array,                    # (d_in, d_out)
    bias: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    residual: Optional[jax.Array] = None,
) -> jax.Array:
    """Projection through the fused-epilogue Pallas GEMM.

    Collapses leading dims, runs ``ops.matmul_fused`` (one kernel
    dispatch: GEMM + bias/activation/residual applied in-register before
    the output write, autotuned dataflow spec), and restores the shape.
    """
    from repro.kernels import ops as kops

    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    r2 = (residual.reshape(-1, residual.shape[-1])
          if residual is not None else None)
    out = kops.matmul_fused(x2, w, bias=bias, residual=r2,
                            activation=activation)
    return out.reshape(*lead, w.shape[-1]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Binary (+-1, xnor-popcount) layers — the paper's Fig. 9 workload class.
# ---------------------------------------------------------------------------
def init_binary_dense(key, d_in: int, d_out: int) -> Params:
    """A +-1 projection with a folded batchnorm tail.

    Weights are stored bit-packed along the reduction axis
    (``(d_in/32, d_out)`` uint32 — 32x smaller than an fp32 image);
    ``scale``/``bias`` hold the folded BN (gamma/sigma,
    beta - gamma*mu/sigma) applied in the fused kernel epilogue.
    ``d_in`` must be a multiple of 32 (the packing word width).
    """
    from repro.kernels import ref as kref

    if d_in % 32:
        raise ValueError(f"binary d_in {d_in} must be a multiple of 32")
    w = jnp.where(jax.random.normal(key, (d_in, d_out)) >= 0, 1.0, -1.0)
    return {
        "w_packed": kref.pack_binary(w, axis=0),
        "scale": jnp.full((d_out,), 1.0 / d_in ** 0.5, jnp.float32),
        "bias": jnp.zeros((d_out,), jnp.float32),
    }


def binary_dense(
    p: Params,
    x: jax.Array,                 # (..., d_in) real-valued or +-1
    binarize: bool = True,
    backend: Optional[str] = None,
) -> jax.Array:
    """Binarize ``x``, project through the fused binary GEMM, and apply
    the folded BN (+ sign when ``binarize``) in-register.

    One ``pallas_call`` per layer on kernel backends: activations are
    bit-packed (an XLA shuffle, 32x smaller HBM image), the
    xnor-popcount dot, BN scale/bias and re-binarization all happen at
    the accumulator flush, so chained binary layers stream +-1 int8
    activations instead of round-tripping int32 accumulators.
    """
    from repro.kernels import ops as kops, ref as kref

    if backend is None:
        backend = _BACKEND_OVERRIDE
    d_in = x.shape[-1]
    lead = x.shape[:-1]
    xp = kref.pack_binary(x.reshape(-1, d_in), axis=1)
    out = kops.binary_matmul_fused(
        xp, p["w_packed"], d_in, scale=p["scale"], bias=p["bias"],
        binarize=binarize, backend=backend,
    )
    return out.reshape(*lead, out.shape[-1])


def binary_mlp_apply(p: Params, x: jax.Array,
                     backend: Optional[str] = None) -> jax.Array:
    """Two chained binary projections (hidden layer re-binarized
    in-register, output left real-valued for the residual stream)."""
    h = binary_dense(p["up"], x, binarize=True, backend=backend)
    return binary_dense(p["down"], h, binarize=False, backend=backend)


def init_binary_mlp(key, d_model: int, d_ff: int) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "up": init_binary_dense(k1, d_model, d_ff),
        "down": init_binary_dense(k2, d_ff, d_model),
    }


# ---------------------------------------------------------------------------
# Sub-byte packed-weight MLP (kernels/pack.py datapath).
# ---------------------------------------------------------------------------
def init_packed_mlp(key, d_model: int, d_ff: int, bits: int = 4) -> Params:
    """SwiGLU MLP with sub-byte packed weights.

    Weights are generated directly as MSR-structured int8 codes — almost
    every reduction row fits the ``bits``-wide code range, plus a couple
    of deliberate outlier rows per projection exercising the sidecar —
    then packed at the fixed ``pack.outlier_capacity`` so the init is
    traceable under the per-layer ``jax.vmap`` in ``lm.init_model``
    (PackedWeights is a pytree; its leaves stack across layers).
    """
    from repro.kernels import pack

    def one(k, d_in, d_out):
        k1, k2, k3 = jax.random.split(k, 3)
        lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
        q = jax.random.randint(k1, (d_in, d_out), lo, hi + 1, jnp.int32)
        cap = pack.outlier_capacity(d_in)
        n_out = min(2, cap)
        rows = jax.random.choice(k2, d_in, (n_out,), replace=False)
        spikes = jax.random.randint(k3, (n_out, d_out), -100, 101, jnp.int32)
        q = q.at[rows].set(spikes)
        scale = jnp.full((1, d_out), 1.0 / (127.0 * d_in ** 0.5), jnp.float32)
        return pack.pack_int8(q.astype(jnp.int8), scale, bits=bits,
                              max_outliers=cap)

    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": one(k1, d_model, d_ff),   # gate
        "w3": one(k2, d_model, d_ff),   # up
        "w2": one(k3, d_ff, d_model),   # down
    }


def packed_mlp_apply(p: Params, x: jax.Array,
                     backend: Optional[str] = None) -> jax.Array:
    """SwiGLU through the packed-weight GEMMs.

    Activations quantize per-tensor int8 at each projection boundary;
    the packed kernel fuses the combined (activation x per-column
    weight) dequant scale — and the gate's silu — into the accumulator
    flush, so each projection stays one dispatch and the weight only
    ever streams as packed planes.
    """
    from repro.core import quant
    from repro.kernels import ops as kops

    if backend is None:
        backend = _BACKEND_OVERRIDE
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    xq, xs = quant.symmetric_int8(x2)
    gate = kops.matmul_packed_fused(xq, p["w1"], a_scale=xs,
                                    activation="silu", backend=backend)
    up = kops.matmul_packed(xq, p["w3"], a_scale=xs, backend=backend)
    hq, hs = quant.symmetric_int8(gate * up)
    out = kops.matmul_packed(hq, p["w2"], a_scale=hs, backend=backend)
    return out.reshape(*lead, out.shape[-1])


def mlp_apply(p: Params, x: jax.Array, cfg=None) -> jax.Array:
    """SwiGLU MLP.  With ``cfg.use_pallas_kernels`` on a TPU runtime the
    three projections run through the fused-epilogue kernel path (the
    gate's silu is fused into its GEMM's output write).  Binary-MLP
    params (``cfg.binary_mlp`` -> ``init_binary_mlp``) and packed-weight
    params (``cfg.packed_weights`` -> ``init_packed_mlp``) are
    dispatched on their param types to the xnor-popcount / sub-byte
    decompress paths."""
    from repro.kernels import pack
    from repro.runtime import health

    fault = health.maybe_inject("layers.mlp")
    if "up" in p:   # binary MLP params (lm._init_layer under binary_mlp)
        out = binary_mlp_apply(p, x).astype(x.dtype)
    elif isinstance(p.get("w1"), pack.PackedWeights):
        out = packed_mlp_apply(p, x).astype(x.dtype)
    elif (cfg is not None and getattr(cfg, "use_pallas_kernels", False)
            and jax.default_backend() == "tpu"
            and _BACKEND_OVERRIDE is None):
        gate = fused_dense(x, p["w1"], activation="silu")
        up = fused_dense(x, p["w3"])
        out = fused_dense((gate * up).astype(x.dtype), p["w2"])
    else:
        gate = jax.nn.silu(jnp.einsum("...d,df->...f", x, p["w1"]))
        up = jnp.einsum("...d,df->...f", x, p["w3"])
        out = jnp.einsum("...f,fd->...d", gate * up, p["w2"])
    if fault == "nan":
        out = out * jnp.asarray(jnp.nan, out.dtype)
    return out
