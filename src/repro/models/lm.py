"""Generic LM assembly: one model function covering every assigned family.

Families (configs/base.ArchConfig.family):
  dense / vlm : pre-norm attention + SwiGLU           (llama-like; chameleon
                is early-fusion so VQ image tokens are ordinary vocab ids)
  moe         : attention + routed MoE (+ shared experts)
  ssm         : mamba2 SSD blocks only (attention-free)
  hybrid      : parallel attention + SSM heads per layer (hymba-style),
                SWA except a few full-attention layers
  audio       : whisper-style encoder-decoder; conv frontend stubbed —
                inputs are precomputed frame embeddings

Implementation notes:
  * params are stacked per-layer (vmap init) and consumed by lax.scan —
    compile time stays flat in depth (94-layer configs lower in seconds);
  * remat policy wraps the scan body (configurable);
  * decode carries a KV cache / SSM state pytree through the same scan;
  * MoE uses the shard_map EP path when a ``Dist`` is provided.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import flags, layers, moe as moe_lib, ssm as ssm_lib

Params = Dict[str, Any]
FULL_WINDOW = jnp.int32(2 ** 30)   # sentinel: sliding window covering all


@dataclasses.dataclass(frozen=True)
class Dist:
    """Distribution context threaded into the model (None = single device)."""

    mesh: Any
    dp_axes: Tuple[str, ...]   # batch axes, e.g. ("pod", "data")
    tp_axis: str               # tensor/expert-parallel axis


# ---------------------------------------------------------------------------
# Init.
# ---------------------------------------------------------------------------
def _init_layer(key, cfg) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {"ln1": layers.init_rmsnorm(cfg.d_model)["scale"]}
    if cfg.has_attention:
        p["attn"] = layers.init_attention(ks[0], cfg)
    if cfg.has_ssm:
        p["mamba"] = ssm_lib.init_mamba(ks[1], cfg)
    if cfg.n_experts:
        p["ln2"] = layers.init_rmsnorm(cfg.d_model)["scale"]
        p["moe"] = moe_lib.init_moe(ks[2], cfg)
    elif cfg.d_ff and cfg.family != "ssm":
        p["ln2"] = layers.init_rmsnorm(cfg.d_model)["scale"]
        if getattr(cfg, "binary_mlp", False):
            p["mlp"] = layers.init_binary_mlp(ks[3], cfg.d_model, cfg.d_ff)
        elif getattr(cfg, "packed_weights", False):
            p["mlp"] = layers.init_packed_mlp(
                ks[3], cfg.d_model, cfg.d_ff,
                bits=getattr(cfg, "packed_weight_bits", 4))
        else:
            p["mlp"] = layers.init_mlp(ks[3], cfg.d_model, cfg.d_ff,
                                       cfg.param_dtype)
    if cfg.is_encoder_decoder:
        p["ln_cross"] = layers.init_rmsnorm(cfg.d_model)["scale"]
        p["cross"] = layers.init_attention(ks[4], cfg)
    return p


def _init_enc_layer(key, cfg) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "ln1": layers.init_rmsnorm(cfg.d_model)["scale"],
        "attn": layers.init_attention(ks[0], cfg),
        "ln2": layers.init_rmsnorm(cfg.d_model)["scale"],
        "mlp": layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.param_dtype),
    }


def init_model(cfg, key) -> Params:
    k_emb, k_layers, k_enc, k_head = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params: Params = {
        "embed": layers.init_embedding(k_emb, cfg.padded_vocab, cfg.d_model,
                                       cfg.param_dtype),
        "layers": jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys),
        "final_norm": layers.init_rmsnorm(cfg.d_model)["scale"],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.init_embedding(
            k_head, cfg.padded_vocab, cfg.d_model, cfg.param_dtype
        )
    if cfg.is_encoder_decoder:
        enc_keys = jax.random.split(k_enc, cfg.n_enc_layers)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
            "final_norm": layers.init_rmsnorm(cfg.d_model)["scale"],
        }
    return params


def hot_gemm_problems(cfg, batch: int, seq: int):
    """The GEMM workloads that actually route through the autotuned
    fused-kernel path, as ``GemmProblem`` rows.

    Used to pre-populate the ``core.autotune`` spec cache (e.g. by
    ``serve.engine.Engine``) so the fused path never enumerates the
    dataflow candidate space at trace time.  Today that is the MLP's
    three projections (``layers.mlp_apply`` -> ``fused_dense``); extend
    this list as more matmuls (attention projections, LM head) are
    moved onto ``ops.matmul_fused``.

    ``cfg.packed_weights`` configs route the MLP through
    ``ops.matmul_packed`` instead, so their rows are the int8-activation
    / ``weight_bits``-tagged problems the packed kernels key the
    autotune cache on (``v6|gemm|...|wb4|...``).
    """
    from repro.core.dataflow import GemmProblem

    t = batch * seq
    dt = str(jnp.dtype(cfg.param_dtype))
    shapes = set()
    if cfg.d_ff and cfg.family != "ssm":
        shapes.add((t, cfg.d_model, cfg.d_ff))
        shapes.add((t, cfg.d_ff, cfg.d_model))
    if getattr(cfg, "packed_weights", False):
        wb = getattr(cfg, "packed_weight_bits", 4)
        return [
            GemmProblem(m, k, n, in_dtype="int8", out_dtype="float32",
                        acc_dtype="int32", weight_bits=wb)
            for m, k, n in sorted(shapes)
        ]
    return [GemmProblem(m, k, n, in_dtype=dt) for m, k, n in sorted(shapes)]


# whisper-style audio frontends: n_mels mel bins, two k=3 1-D convs
# (stride 1 then stride 2) over 2x the encoder frame count
AUDIO_N_MELS = 80
AUDIO_CONV_KERNEL = 3


def hot_conv_problems(cfg, batch: int, seq: int):
    """The conv workloads of ``cfg``'s modality frontend, as
    ``ConvProblem`` rows for the ``core.autotune`` conv spec cache.

    Audio (whisper-family) configs front the encoder with two 1-D convs
    over the mel spectrogram — k=3 stride-1 (n_mels -> d_model) then k=3
    stride-2 (d_model -> d_model) halving the frame count to the encoder
    sequence length.  Represented as height-1 2-D ``ConvProblem``s (the
    form ``ops.conv2d`` keys on).  Other families have no conv frontend
    and return an empty list.
    """
    from repro.core.dataflow import ConvProblem

    if cfg.family != "audio":
        return []
    dt = str(jnp.dtype(cfg.param_dtype))
    enc_seq = max(1, int(seq * cfg.enc_seq_ratio))
    frames = 2 * enc_seq
    k = AUDIO_CONV_KERNEL
    return [
        ConvProblem(ih=1, iw=frames + k - 1, fh=1, fw=k, s=1,
                    cin=AUDIO_N_MELS, cout=cfg.d_model, n=batch,
                    in_dtype=dt, out_dtype="float32"),
        ConvProblem(ih=1, iw=2 * enc_seq + k - 1, fh=1, fw=k, s=2,
                    cin=cfg.d_model, cout=cfg.d_model, n=batch,
                    in_dtype=dt, out_dtype="float32"),
    ]


def hot_binary_problems(cfg, batch: int, seq: int):
    """The binary (xnor-popcount) workloads of a ``binary_mlp`` config,
    as ``BinaryProblem`` rows for the ``core.autotune`` spec cache.

    Configs with ``binary_mlp`` route their decoder-layer MLPs through
    ``layers.binary_mlp_apply`` (``_init_layer`` stores binary params,
    ``layers.mlp_apply`` dispatches on them) — packed reduction depth
    ``d/32`` words, true depth ``d`` bits.  Other configs return an
    empty list.
    """
    from repro.core.dataflow import BinaryProblem

    if not getattr(cfg, "binary_mlp", False) or not cfg.d_ff:
        return []
    t = batch * seq
    return [
        BinaryProblem(m=t, kp=cfg.d_model // 32, n=cfg.d_ff,
                      n_bits=cfg.d_model, out_dtype="int8"),
        BinaryProblem(m=t, kp=cfg.d_ff // 32, n=cfg.d_model,
                      n_bits=cfg.d_ff, out_dtype="float32"),
    ]


def hot_attention_problems(cfg, batch: int, seq: int,
                           max_len: Optional[int] = None):
    """The attention workloads of ``cfg``'s decoder layers, as
    ``AttentionProblem`` rows for the ``core.autotune`` spec cache.

    Per request geometry: the prefill square (``sq = skv = seq``) and
    the cached decode step (``sq = 1``, ``skv = max_len or seq`` — the
    padded KV-cache buffer, whose traced valid length keys as the
    ``kl-`` worst case) that ``layers.attention_apply`` routes through
    ``ops.attention`` on TPU.  Sliding-window configs add the windowed
    variants of both (static windows reach the kernel, so the banded
    ranking must be warmed for them too), and an int8 KV cache
    (``cfg.kv_cache_dtype``) keys the decode rows with
    ``kv_dtype="int8"``.  Attention-free families (ssm) return an
    empty list.
    """
    from repro.core.dataflow import AttentionProblem

    if not cfg.has_attention:
        return []
    dt = str(jnp.dtype(cfg.act_dtype))
    kv_dt = "int8" if cfg.kv_cache_dtype == "int8" else None
    group = max(1, cfg.n_heads // cfg.n_kv_heads)
    bh = batch * cfg.n_heads
    skv_dec = max_len or seq
    windows = [None]
    if cfg.attn_window is not None:
        windows.append(int(cfg.attn_window))
    probs = []
    for win in windows:
        probs.append(AttentionProblem(bh=bh, sq=seq, skv=seq, d=cfg.d_head,
                                      group=group, causal=True, window=win,
                                      dtype=dt))
        probs.append(AttentionProblem(bh=bh, sq=1, skv=skv_dec,
                                      d=cfg.d_head, group=group,
                                      causal=True, window=win, dtype=dt,
                                      kv_dtype=kv_dt))
    return probs


def layer_windows(cfg) -> Optional[jax.Array]:
    """Per-layer sliding windows as a scannable array (hybrid archs)."""
    if cfg.attn_window is None:
        return None
    ws = [cfg.layer_window(i) for i in range(cfg.n_layers)]
    return jnp.asarray(
        [FULL_WINDOW if w is None else w for w in ws], jnp.int32
    )


# ---------------------------------------------------------------------------
# Layer application.
# ---------------------------------------------------------------------------
def _cross_attention(p, x, enc_out, cfg):
    b, s, _ = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = jnp.einsum("bsd,df->bsf", x, p["wq"]).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,df->bsf", enc_out, p["wk"]).reshape(
        b, enc_out.shape[1], hkv, dh)
    v = jnp.einsum("bsd,df->bsf", enc_out, p["wv"]).reshape(
        b, enc_out.shape[1], hkv, dh)
    if cfg.qk_norm:
        q = layers.rmsnorm({"scale": p["q_norm"]}, q, cfg.norm_eps)
        k = layers.rmsnorm({"scale": p["k_norm"]}, k, cfg.norm_eps)
    out = layers.bidir_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), scale=dh ** -0.5,
    )
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    return jnp.einsum("bsf,fd->bsd", out, p["wo"])


def layer_apply(
    lp: Params, x: jax.Array, cfg, *,
    window: Optional[jax.Array],
    positions: Optional[jax.Array],
    cache: Optional[Params],
    cache_index: Optional[jax.Array],
    enc_out: Optional[jax.Array],
    dist: Optional[Dist],
    attend_local: bool = False,
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """One decoder layer. Returns (x, new_cache_slice, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    if dist is not None:
        # re-pin batch sharding: SPMD propagation can drop it through the
        # SSD reshapes/transposes (observed: replicated mamba activations).
        # §Perf iteration 3: for attention-only archs the residual stream
        # is sequence-sharded over the TP axis (Megatron-SP): RMSNorm is
        # per-token (no comm), the MoE boundary gather disappears, and
        # row-parallel all-reduces lower to half-cost reduce-scatters.
        from jax.sharding import NamedSharding, PartitionSpec as P

        tp = dist.mesh.shape[dist.tp_axis]
        seq_ok = (not cfg.has_ssm) and x.shape[1] % tp == 0             and x.shape[1] >= tp
        spec = P(dist.dp_axes, dist.tp_axis if seq_ok else None, None)
        x = jax.lax.with_sharding_constraint(
            x, NamedSharding(dist.mesh, spec))
    h = layers.rmsnorm({"scale": lp["ln1"]}, x, cfg.norm_eps)

    mix = jnp.zeros_like(x)
    n_paths = 0
    if cfg.has_attention:
        kv = None
        if cache is not None:
            kv = (cache["k"], cache["v"])
            if "k_scale" in cache:
                kv = kv + (cache["k_scale"], cache["v_scale"])
        attn_out, new_kv = layers.attention_apply(
            lp["attn"], h, cfg, positions=positions, window=window,
            kv_cache=kv, cache_index=cache_index, attend_local=attend_local,
        )
        mix = mix + attn_out
        n_paths += 1
        if new_kv is not None:
            new_cache["k"], new_cache["v"] = new_kv[:2]
            if len(new_kv) == 4:
                new_cache["k_scale"], new_cache["v_scale"] = new_kv[2:]
    if cfg.has_ssm:
        state = (cache["ssm"], cache["conv"]) if cache is not None else None
        ssm_out, new_state = ssm_lib.mamba_apply(lp["mamba"], h, cfg, state)
        mix = mix + ssm_out
        n_paths += 1
        if new_state is not None:
            new_cache["ssm"], new_cache["conv"] = new_state
    x = x + mix / max(n_paths, 1)

    if cfg.is_encoder_decoder and enc_out is not None:
        hc = layers.rmsnorm({"scale": lp["ln_cross"]}, x, cfg.norm_eps)
        x = x + _cross_attention(lp["cross"], hc, enc_out, cfg)
        if cache is not None:
            # store per-layer cross KV for cached decode
            b, se, _ = enc_out.shape
            hkv, dh = cfg.n_kv_heads, cfg.d_head
            ck = jnp.einsum("bsd,df->bsf", enc_out, lp["cross"]["wk"])
            cv = jnp.einsum("bsd,df->bsf", enc_out, lp["cross"]["wv"])
            new_cache["cross_k"] = ck.reshape(b, se, hkv, dh).transpose(
                0, 2, 1, 3).astype(cache["cross_k"].dtype)
            new_cache["cross_v"] = cv.reshape(b, se, hkv, dh).transpose(
                0, 2, 1, 3).astype(cache["cross_v"].dtype)
    elif cfg.is_encoder_decoder and cache is not None and "cross_k" in cache:
        # decode: cross-attend to the cached encoder projections
        hc = layers.rmsnorm({"scale": lp["ln_cross"]}, x, cfg.norm_eps)
        b, s, _ = hc.shape
        h_, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
        q = jnp.einsum("bsd,df->bsf", hc, lp["cross"]["wq"]).reshape(
            b, s, h_, dh).transpose(0, 2, 1, 3)
        out = layers.bidir_attention(
            q, cache["cross_k"], cache["cross_v"], scale=dh ** -0.5,
        ).transpose(0, 2, 1, 3).reshape(b, s, h_ * dh)
        x = x + jnp.einsum("bsf,fd->bsd", out, lp["cross"]["wo"])
        new_cache["cross_k"] = cache["cross_k"]
        new_cache["cross_v"] = cache["cross_v"]

    if cfg.n_experts:
        h2 = layers.rmsnorm({"scale": lp["ln2"]}, x, cfg.norm_eps)
        if dist is not None:
            y, aux = moe_lib.moe_apply_sharded(
                lp["moe"], h2, cfg, dist.mesh, dist.dp_axes, dist.tp_axis
            )
        else:
            y, aux = moe_lib.moe_apply(lp["moe"], h2, cfg)
        x = x + y
    elif "mlp" in lp:
        h2 = layers.rmsnorm({"scale": lp["ln2"]}, x, cfg.norm_eps)
        x = x + layers.mlp_apply(lp["mlp"], h2, cfg)

    return x, (new_cache or None), aux


# ---------------------------------------------------------------------------
# Encoder (whisper).
# ---------------------------------------------------------------------------
def encode(params: Params, frames: jax.Array, cfg) -> jax.Array:
    """Bidirectional encoder over precomputed frame embeddings (stub
    frontend per the assignment)."""
    enc = params["encoder"]

    def body(x, lp):
        h = layers.rmsnorm({"scale": lp["ln1"]}, x, cfg.norm_eps)
        # bidirectional self-attention (no causal mask)
        b, s, _ = h.shape
        hh, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
        q = jnp.einsum("bsd,df->bsf", h, lp["attn"]["wq"]).reshape(
            b, s, hh, dh).transpose(0, 2, 1, 3)
        k = jnp.einsum("bsd,df->bsf", h, lp["attn"]["wk"]).reshape(
            b, s, hkv, dh).transpose(0, 2, 1, 3)
        v = jnp.einsum("bsd,df->bsf", h, lp["attn"]["wv"]).reshape(
            b, s, hkv, dh).transpose(0, 2, 1, 3)
        pos = jnp.arange(s)[None, :]
        q = layers.apply_rope(q, pos[:, None], cfg.rope_theta)
        k = layers.apply_rope(k, pos[:, None], cfg.rope_theta)
        out = layers.bidir_attention(
            q, k, v, scale=dh ** -0.5,
        ).transpose(0, 2, 1, 3).reshape(b, s, hh * dh)
        x = x + jnp.einsum("bsf,fd->bsd", out, lp["attn"]["wo"])
        h2 = layers.rmsnorm({"scale": lp["ln2"]}, x, cfg.norm_eps)
        x = x + layers.mlp_apply(lp["mlp"], h2, cfg)
        return x, None

    x, _ = jax.lax.scan(
        body if flags.EXACT_COST_MODE else jax.checkpoint(body),
        frames.astype(jnp.dtype(cfg.act_dtype)), enc["layers"],
        unroll=cfg.n_enc_layers if flags.EXACT_COST_MODE else 1,
    )
    return layers.rmsnorm({"scale": enc["final_norm"]}, x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Forward (train / prefill).
# ---------------------------------------------------------------------------
def forward(
    params: Params,
    tokens: jax.Array,                 # (B, S)
    cfg,
    enc_frames: Optional[jax.Array] = None,
    dist: Optional[Dist] = None,
    remat: str = "dots",               # "none" | "dots" | "full"
    unroll: int = 1,                   # scan unroll (dry-run FLOP accounting)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B, S, V), total moe aux loss)."""
    x, aux = forward_hidden(params, tokens, cfg, enc_frames=enc_frames,
                            dist=dist, remat=remat, unroll=unroll)
    head = params.get("lm_head", params["embed"])
    logits = layers.unembed(head, x)
    if dist is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P

        logits = jax.lax.with_sharding_constraint(
            logits, NamedSharding(dist.mesh, P(dist.dp_axes, None,
                                               dist.tp_axis))
        )
    return logits, aux


def forward_hidden(
    params: Params,
    tokens: jax.Array,                 # (B, S)
    cfg,
    enc_frames: Optional[jax.Array] = None,
    dist: Optional[Dist] = None,
    remat: str = "dots",
    unroll: int = 1,
) -> Tuple[jax.Array, jax.Array]:
    """forward() minus the unembedding: (final hidden (B,S,D), aux loss)."""
    x = layers.embed(params["embed"], tokens).astype(jnp.dtype(cfg.act_dtype))
    if dist is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P

        x = jax.lax.with_sharding_constraint(
            x, NamedSharding(dist.mesh, P(dist.dp_axes, None, None))
        )
    enc_out = None
    if cfg.is_encoder_decoder:
        if enc_frames is None:
            raise ValueError("enc-dec arch requires enc_frames")
        enc_out = encode(params, enc_frames, cfg)

    windows = layer_windows(cfg)
    # uniform window: pass it statically — every layer shares one value,
    # and a static window lets the Pallas kernel shrink its KV grid to
    # the band (and exact-cost mode count banded-SWA FLOPs honestly)
    static_window = None
    if cfg.attn_window is not None and cfg.full_attn_every == 0:
        windows = None
        static_window = int(cfg.attn_window)
    positions = jnp.arange(tokens.shape[1])[None, :]

    def make_body(window_static):
        def body(x, scanned):
            lp = scanned["lp"]
            window = scanned.get("window", window_static)
            x, _, aux = layer_apply(
                lp, x, cfg, window=window, positions=positions, cache=None,
                cache_index=None, enc_out=enc_out, dist=dist,
            )
            return x, aux
        if remat == "dots":
            return jax.checkpoint(
                body, policy=jax.checkpoint_policies.checkpoint_dots)
        if remat == "full":
            return jax.checkpoint(body)
        return body

    if windows is not None and cfg.full_attn_every \
            and not flags.EXACT_COST_MODE:
        # §Perf iteration 1 (hymba): segment the stack into runs of
        # same-window layers so SWA layers take the STATIC banded path —
        # O(S*2w) attention instead of masked O(S^2) under a traced window.
        aux_total = jnp.zeros((), jnp.float32)
        for start, end, win in _window_segments(cfg):
            seg = jax.tree.map(lambda a: a[start:end], params["layers"])
            body = make_body(win)
            x, auxes = jax.lax.scan(body, x, {"lp": seg}, unroll=unroll)
            aux_total = aux_total + jnp.sum(auxes)
        x = layers.rmsnorm({"scale": params["final_norm"]}, x, cfg.norm_eps)
        return x, aux_total

    body = make_body(static_window)
    scanned = {"lp": params["layers"]}
    if windows is not None:
        scanned["window"] = windows
    x, auxes = jax.lax.scan(body, x, scanned, unroll=unroll)

    x = layers.rmsnorm({"scale": params["final_norm"]}, x, cfg.norm_eps)
    return x, jnp.sum(auxes)


def _window_segments(cfg):
    """Contiguous (start, end, static_window) runs of same-window layers."""
    segs = []
    cur_win = cfg.layer_window(0)
    start = 0
    for i in range(1, cfg.n_layers):
        w = cfg.layer_window(i)
        if w != cur_win:
            segs.append((start, i, cur_win))
            start, cur_win = i, w
    segs.append((start, cfg.n_layers, cur_win))
    return segs


def chunked_cross_entropy(
    x: jax.Array,            # (B, S, D) final hidden states
    table: jax.Array,        # (Vp, D) unembedding
    targets: jax.Array,      # (B, S)
    cfg,
    chunk: int = 1024,
) -> jax.Array:
    """Sequence-chunked CE: logits are computed per chunk and never
    materialized at (B, S, V) f32 — the naive loss's logit copies cost
    ~10 GB/device at (1M tokens x 150k vocab); this keeps live memory at
    O(B * chunk * V_shard) and recomputes chunk logits in backward."""
    b, s, d = x.shape
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
    xc = x.reshape(b, nc, -1, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, nc, -1).transpose(1, 0, 2)
    valid_tok = (jnp.arange(nc * xc.shape[2]) < s).reshape(nc, -1)
    vmask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size

    def body(total, inp):
        x_i, t_i, ok = inp
        logits = jnp.einsum("bcd,vd->bcv", x_i, table).astype(jnp.float32)
        if cfg.padded_vocab != cfg.vocab_size:
            logits = jnp.where(vmask, logits, -jnp.inf)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t_i[..., None], axis=-1)[..., 0]
        nll = jnp.where(ok[None, :], logz - gold, 0.0)
        return total + nll.sum(), None

    total, _ = jax.lax.scan(
        jax.checkpoint(body), jnp.zeros((), jnp.float32),
        (xc, tc, valid_tok),
    )
    return total / (b * s)


def loss_fn(
    params: Params, batch: Dict[str, jax.Array], cfg,
    dist: Optional[Dist] = None, remat: str = "dots",
    aux_weight: float = 0.01, unroll: int = 1,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x, aux = forward_hidden(
        params, batch["tokens"], cfg,
        enc_frames=batch.get("enc_frames"), dist=dist, remat=remat,
        unroll=unroll,
    )
    head = params.get("lm_head", params["embed"])
    if flags.EXACT_COST_MODE:
        logits = layers.unembed(head, x).astype(jnp.float32)
        if cfg.padded_vocab != cfg.vocab_size:
            valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
            logits = jnp.where(valid, logits, -jnp.inf)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, batch["targets"][..., None], axis=-1)[..., 0]
        nll = jnp.mean(logz - gold)
    else:
        nll = chunked_cross_entropy(x, head["table"], batch["targets"], cfg)
    total = nll + aux_weight * aux
    return total, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode.
# ---------------------------------------------------------------------------
def init_cache(cfg, batch: int, max_len: int, dtype="bfloat16",
               enc_len: int = None) -> Params:
    cache: Params = {"index": jnp.zeros((), jnp.int32)}
    if cfg.has_attention:
        shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_len, cfg.d_head)
        kv_dt = jnp.dtype(dtype if cfg.kv_cache_dtype == "auto"
                          else cfg.kv_cache_dtype)
        cache["k"] = jnp.zeros(shape, kv_dt)
        cache["v"] = jnp.zeros(shape, kv_dt)
        if kv_dt == jnp.int8:
            sshape = shape[:-1] + (1,)
            cache["k_scale"] = jnp.ones(sshape, jnp.float32)
            cache["v_scale"] = jnp.ones(sshape, jnp.float32)
    if cfg.has_ssm:
        s, tail = ssm_lib.init_ssm_state(cfg, batch)
        cache["ssm"] = jnp.zeros((cfg.n_layers,) + s.shape, s.dtype)
        cache["conv"] = jnp.zeros((cfg.n_layers,) + tail.shape, tail.dtype)
    if cfg.is_encoder_decoder:
        # cross-attention KV computed at prefill from encoder output
        el = enc_len if enc_len is not None else max_len
        shape = (cfg.n_layers, batch, cfg.n_kv_heads, el, cfg.d_head)
        cache["cross_k"] = jnp.zeros(shape, jnp.dtype(dtype))
        cache["cross_v"] = jnp.zeros(shape, jnp.dtype(dtype))
    return cache


def decode_step(
    params: Params,
    cache: Params,
    tokens: jax.Array,            # (B, 1)
    cfg,
    dist: Optional[Dist] = None,
    unroll: int = 1,
) -> Tuple[jax.Array, Params]:
    """One decode step with the KV/SSM cache. Returns (logits (B, V), cache).

    ``cache['index']`` may be a scalar (uniform-position batch — every
    sequence at the same depth) or a ``(B,)`` vector (ragged continuous
    batch — each row decodes at its own depth; PR 8): positions, the
    per-row cache writes, and the per-row attention bands all follow it.
    """
    x = layers.embed(params["embed"], tokens).astype(jnp.dtype(cfg.act_dtype))
    idx = cache["index"]
    if getattr(idx, "ndim", 0) == 1:
        positions = idx[:, None]                           # (B, 1) ragged
    else:
        positions = jnp.full((tokens.shape[0], 1), idx, jnp.int32)
    windows = layer_windows(cfg)
    static_window = None
    if cfg.attn_window is not None and cfg.full_attn_every == 0:
        # uniform window: static (see forward_hidden) — the decode step's
        # kernel band then spans ceil(window/bkv)+1 KV blocks, not the
        # whole max_len cache buffer
        windows = None
        static_window = int(cfg.attn_window)

    def body(x, scanned):
        lp = scanned["lp"]
        layer_cache = scanned["cache"]
        window = scanned.get("window", static_window)
        x, new_cache, _ = layer_apply(
            lp, x, cfg, window=window, positions=positions,
            cache=layer_cache, cache_index=idx,
            enc_out=None, dist=dist,
        )
        return x, new_cache

    scanned = {"lp": params["layers"],
               "cache": {k: cache[k] for k in
                         ("k", "v", "k_scale", "v_scale", "ssm", "conv",
                          "cross_k", "cross_v")
                         if k in cache}}
    if windows is not None:
        scanned["window"] = windows
    x, new_layer_caches = jax.lax.scan(body, x, scanned, unroll=unroll)

    for k, v in new_layer_caches.items():
        cache[k] = v
    cache["index"] = idx + 1

    x = layers.rmsnorm({"scale": params["final_norm"]}, x, cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    logits = layers.unembed(head, x[:, -1])
    if cfg.padded_vocab != cfg.vocab_size:
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(valid, logits, -jnp.inf)
    return logits, cache


def supports_paged_decode(cfg) -> bool:
    """Can ``paged_decode_step`` drive this config's decode?

    The paged datapath covers the pure-attention decoder stack (dense
    or MoE FFN, uniform static or no sliding window).  SSM state,
    encoder-decoder cross caches, int8 KV scales, and per-layer traced
    window schedules still decode through the contiguous slot cache.
    """
    return bool(
        cfg.has_attention
        and not cfg.has_ssm
        and not cfg.is_encoder_decoder
        and cfg.kv_cache_dtype in ("auto", None)
        and (cfg.attn_window is None or cfg.full_attn_every == 0)
    )


def paged_decode_step(
    params: Params,
    k_pages: jax.Array,           # (L, Hkv, n_pages, page, Dh) page pools
    v_pages: jax.Array,
    tokens: jax.Array,            # (B, 1)
    block_tables: jax.Array,      # (B, max_pages) int32
    kv_lens: jax.Array,           # (B,) int32 filled length per row
    write_pids: jax.Array,        # (B,) int32 destination page per row
    write_offs: jax.Array,        # (B,) int32 offset within that page
    cfg,
    unroll: int = 1,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One decode step straight off the paged KV pool (PR-10 tentpole).

    The paged twin of ``decode_step``: the same embed → scan(layer) →
    final-norm → unembed graph, with each layer's attention routed
    through ``layers.paged_attention_apply`` over that layer's slice of
    the shared page pools instead of the per-slot cache strip.  Idle
    batch rows scatter their writes to the pool's scratch page (the
    scheduler points ``write_pids`` there) and their logits are
    discarded host-side.  Returns ``(logits (B, V), (k_pages,
    v_pages))`` — the scheduler commits the pools on step success,
    mirroring the slot path's reject-on-failure cache contract.
    """
    x = layers.embed(params["embed"], tokens).astype(jnp.dtype(cfg.act_dtype))
    positions = kv_lens[:, None]                           # (B, 1) ragged
    static_window = None
    if cfg.attn_window is not None and cfg.full_attn_every == 0:
        static_window = int(cfg.attn_window)

    def body(x, scanned):
        lp = scanned["lp"]
        h = layers.rmsnorm({"scale": lp["ln1"]}, x, cfg.norm_eps)
        mix = jnp.zeros_like(x)
        n_paths = 0
        attn_out, (kp, vp) = layers.paged_attention_apply(
            lp["attn"], h, cfg, positions=positions, window=static_window,
            k_pages=scanned["k"], v_pages=scanned["v"],
            block_tables=block_tables, kv_lens=kv_lens,
            write_pids=write_pids, write_offs=write_offs,
        )
        mix = mix + attn_out
        n_paths += 1
        x = x + mix / max(n_paths, 1)
        if cfg.n_experts:
            h2 = layers.rmsnorm({"scale": lp["ln2"]}, x, cfg.norm_eps)
            y, _ = moe_lib.moe_apply(lp["moe"], h2, cfg)
            x = x + y
        elif "mlp" in lp:
            h2 = layers.rmsnorm({"scale": lp["ln2"]}, x, cfg.norm_eps)
            x = x + layers.mlp_apply(lp["mlp"], h2, cfg)
        return x, {"k": kp, "v": vp}

    scanned = {"lp": params["layers"], "k": k_pages, "v": v_pages}
    x, new_pools = jax.lax.scan(body, x, scanned, unroll=unroll)

    x = layers.rmsnorm({"scale": params["final_norm"]}, x, cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    logits = layers.unembed(head, x[:, -1])
    if cfg.padded_vocab != cfg.vocab_size:
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(valid, logits, -jnp.inf)
    return logits, (new_pools["k"], new_pools["v"])


def prefill(
    params: Params, tokens: jax.Array, cfg,
    max_len: Optional[int] = None,
    enc_frames: Optional[jax.Array] = None,
    dist: Optional[Dist] = None,
    unroll: int = 1,
) -> Tuple[jax.Array, Params]:
    """Run the prompt through the model, filling the cache.

    Attention during prefill runs over the *local* K/V projections
    (``attend_local``) while writing the cache — identical math to
    attending over the just-filled cache, but it keeps the static
    banded-SWA path available and avoids touching the padded cache
    buffer (max_len) in the attention einsums.
    """
    b, s = tokens.shape
    max_len = max_len or s
    enc_out = None
    if cfg.is_encoder_decoder:
        if enc_frames is None:
            raise ValueError("enc-dec arch requires enc_frames")
        enc_out = encode(params, enc_frames, cfg)
    cache = init_cache(cfg, b, max_len, cfg.act_dtype,
                       enc_len=(enc_out.shape[1] if enc_out is not None
                                else None))
    x = layers.embed(params["embed"], tokens).astype(jnp.dtype(cfg.act_dtype))
    if dist is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P

        x = jax.lax.with_sharding_constraint(
            x, NamedSharding(dist.mesh, P(dist.dp_axes, None, None)))
    windows = layer_windows(cfg)
    static_window = None
    if cfg.attn_window is not None and cfg.full_attn_every == 0:
        # uniform window: static (see forward_hidden) — kernel-grid banding
        windows = None
        static_window = int(cfg.attn_window)
    positions = jnp.arange(s)[None, :]
    idx0 = jnp.zeros((), jnp.int32)

    def make_body(window_static):
        def body(x, scanned):
            lp = scanned["lp"]
            layer_cache = scanned["cache"]
            window = scanned.get("window", window_static)
            x, new_cache, _ = layer_apply(
                lp, x, cfg, window=window, positions=positions,
                cache=layer_cache, cache_index=idx0, enc_out=enc_out,
                dist=dist, attend_local=True,
            )
            return x, new_cache
        return body

    cache_keys = [k for k in ("k", "v", "k_scale", "v_scale", "ssm",
                              "conv", "cross_k", "cross_v") if k in cache]
    if windows is not None and cfg.full_attn_every \
            and not flags.EXACT_COST_MODE:
        # segmented SWA prefill (see forward_hidden §Perf iteration 1)
        new_caches = {k: [] for k in cache_keys}
        for start, end, win in _window_segments(cfg):
            seg = {
                "lp": jax.tree.map(lambda a: a[start:end], params["layers"]),
                "cache": {k: cache[k][start:end] for k in cache_keys},
            }
            x, seg_caches = jax.lax.scan(make_body(win), x, seg,
                                         unroll=unroll)
            for k in cache_keys:
                new_caches[k].append(seg_caches[k])
        for k in cache_keys:
            cache[k] = jnp.concatenate(new_caches[k], axis=0)
    else:
        scanned = {"lp": params["layers"],
                   "cache": {k: cache[k] for k in cache_keys}}
        if windows is not None:
            scanned["window"] = windows
        x, new_layer_caches = jax.lax.scan(make_body(static_window), x,
                                           scanned, unroll=unroll)
        for k, v in new_layer_caches.items():
            cache[k] = v
    cache["index"] = jnp.asarray(s, jnp.int32)
    x = layers.rmsnorm({"scale": params["final_norm"]}, x, cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    logits = layers.unembed(head, x[:, -1])
    return logits, cache


def prefill_chunk(
    params: Params,
    cache: Params,
    tokens: jax.Array,            # (B, S_chunk)
    cfg,
    start: jax.Array,             # scalar or (B,) filled-prefix offset
    unroll: int = 1,
) -> Tuple[jax.Array, Params]:
    """Prefill one prompt chunk into an existing cache at ``start``.

    The continuous scheduler's chunked-prefill step (PR 8): a long
    prompt streams through in fixed-size chunks interleaved with decode
    steps, bounding per-step latency for already-running requests.
    Unlike ``prefill`` this attends over the *filled cache* (not the
    local projections), so chunk N sees chunks 0..N-1; the attention
    band follows ``kv_len = start + S_chunk`` per row.  Returns
    (last-token logits (B, V), cache) — the logits are meaningful only
    on the final chunk of a prompt.
    """
    b, s = tokens.shape
    x = layers.embed(params["embed"], tokens).astype(jnp.dtype(cfg.act_dtype))
    start = jnp.asarray(start, jnp.int32)
    if start.ndim == 1:
        positions = start[:, None] + jnp.arange(s)[None, :]   # (B, S)
    else:
        positions = start + jnp.arange(s)[None, :]
    windows = layer_windows(cfg)
    static_window = None
    if cfg.attn_window is not None and cfg.full_attn_every == 0:
        windows = None
        static_window = int(cfg.attn_window)

    def body(x, scanned):
        lp = scanned["lp"]
        layer_cache = scanned["cache"]
        window = scanned.get("window", static_window)
        x, new_cache, _ = layer_apply(
            lp, x, cfg, window=window, positions=positions,
            cache=layer_cache, cache_index=start, enc_out=None, dist=None,
        )
        return x, new_cache

    cache_keys = [k for k in ("k", "v", "k_scale", "v_scale", "ssm",
                              "conv", "cross_k", "cross_v") if k in cache]
    scanned = {"lp": params["layers"],
               "cache": {k: cache[k] for k in cache_keys}}
    if windows is not None:
        scanned["window"] = windows
    x, new_layer_caches = jax.lax.scan(body, x, scanned, unroll=unroll)
    for k, v in new_layer_caches.items():
        cache[k] = v
    cache["index"] = start + s
    x = layers.rmsnorm({"scale": params["final_norm"]}, x, cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    logits = layers.unembed(head, x[:, -1])
    return logits, cache
