"""Mamba2 (SSD — state-space duality) block in pure JAX.

Chunked SSD: intra-chunk attention-like matmuls + inter-chunk state
recurrence (lax.scan).  The chunk GEMMs are the MXU-friendly face of the
SSM — they are where the paper's OS-anchored dataflow applies (see
DESIGN.md §4: for attention-free archs the dataflow technique lands on
the SSD chunk GEMMs instead of attention).

Projections are kept SEPARATE per role (z/x/BC/dt) rather than fused —
§Perf iteration 2: separate tensors let the x/z projections shard over
the TP axis (column-parallel on d_inner, row-parallel out_proj), so SSD
compute spreads over the ``model`` axis instead of replicating.  The
headdim axis P (= d_inner per head) stays outer in every SSD einsum, so
a d_inner sharding is consistent end-to-end.

Decode maintains an O(1) recurrent state (B, H, N, P) + conv tails.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import flags, layers

Params = Dict[str, jax.Array]


def init_mamba(key, cfg) -> Params:
    d, di = cfg.d_model, cfg.d_inner
    n, h = cfg.ssm_state, cfg.ssm_heads
    g = 1  # single B/C group
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    p: Params = {
        "z_proj": layers.init_dense(ks[0], d, di, cfg.param_dtype)["w"],
        "x_proj": layers.init_dense(ks[1], d, di, cfg.param_dtype)["w"],
        "bc_proj": layers.init_dense(ks[2], d, 2 * g * n,
                                     cfg.param_dtype)["w"],
        "dt_proj": layers.init_dense(ks[3], d, h, cfg.param_dtype)["w"],
        "conv_x_w": (jax.random.normal(ks[4], (cfg.ssm_conv, di),
                                       jnp.float32) * 0.1).astype(dt),
        "conv_x_b": jnp.zeros((di,), dt),
        "conv_bc_w": (jax.random.normal(ks[5], (cfg.ssm_conv, 2 * g * n),
                                        jnp.float32) * 0.1).astype(dt),
        "conv_bc_b": jnp.zeros((2 * g * n,), dt),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": layers.init_rmsnorm(di)["scale"],
        "out_proj": layers.init_dense(ks[0], di, d, cfg.param_dtype)["w"],
    }
    return p


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: Optional[jax.Array] = None):
    """Depthwise causal conv1d. x: (B, L, C); w: (K, C). Returns (y, tail)."""
    k = w.shape[0]
    if tail is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    new_tail = xp[:, -(k - 1):, :] if k > 1 else None
    return y + b[None, None, :], new_tail


def _ssd_chunked(xh, dt, a, bmat, cmat, chunk: int):
    """Chunked SSD: one lax.scan over chunks carrying the (B,H,N,P) state.

    Per-chunk work is matmul-rich (the SSD duality): an intra-chunk
    attention-like (Q x Q) einsum + state update, with live memory
    O(B*Q*Q*H) per step instead of O(B*L/Q*Q*Q*H) for the fully
    vectorized form (which is ~GBs/device at 32k prefill).

    xh: (B, L, H, P); dt: (B, L, H); a: (H,) negative;
    bmat/cmat: (B, L, N). Returns (y (B,L,H,P), final_state (B,H,N,P)).
    """
    bsz, l, h, pdim = xh.shape
    n = bmat.shape[-1]
    nc = l // chunk
    q = chunk
    f32 = jnp.float32

    xh_c = xh.reshape(bsz, nc, q, h, pdim).transpose(1, 0, 2, 3, 4)
    dt_c = dt.reshape(bsz, nc, q, h).transpose(1, 0, 2, 3).astype(f32)
    b_c = bmat.reshape(bsz, nc, q, n).transpose(1, 0, 2, 3).astype(f32)
    c_c = cmat.reshape(bsz, nc, q, n).transpose(1, 0, 2, 3).astype(f32)
    mask = jnp.tril(jnp.ones((q, q), bool))

    def step(s_prev, inp):
        x_k, dt_k, b_k, c_k = inp          # (B,Q,H,P),(B,Q,H),(B,Q,N)x2
        x_k = x_k.astype(f32)
        da = dt_k * a[None, None, :]       # (B,Q,H) negative
        cum = jnp.cumsum(da, axis=1)       # inclusive
        seg = cum[:, -1, :]                # (B,H)

        # intra-chunk: y_i = sum_{j<=i} (C_i . B_j) exp(cum_i - cum_j) dt_j x_j
        diff = cum[:, :, None, :] - cum[:, None, :, :]      # (B,Qi,Qj,H)
        # zero masked entries BEFORE exp: j>i gives diff>0 which can
        # overflow to inf, and inf*0 in the VJP poisons grads with NaN
        diff = jnp.where(mask[None, :, :, None], diff, 0.0)
        lmat = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bin,bjn->bij", c_k, b_k)       # (B,Qi,Qj)
        w = scores[..., None] * lmat * dt_k[:, None, :, :]  # (B,Qi,Qj,H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, x_k)

        # inter-chunk: y_i += C_i exp(cum_i) @ S_prev
        decay_from_start = jnp.exp(cum)                     # (B,Q,H)
        y_inter = jnp.einsum(
            "bqn,bqh,bhnp->bqhp", c_k, decay_from_start, s_prev
        )

        # state update: S = exp(seg) S_prev + sum_j exp(seg - cum_j) dt_j B_j x_j
        decay_to_end = jnp.exp(seg[:, None, :] - cum)       # (B,Q,H)
        s_local = jnp.einsum(
            "bqn,bqh,bqhp->bhnp", b_k, dt_k * decay_to_end, x_k
        )
        s_new = s_prev * jnp.exp(seg)[:, :, None, None] + s_local
        return s_new, (y_intra + y_inter).astype(xh.dtype)

    s0 = jnp.zeros((bsz, h, n, pdim), f32)
    s_final, y_c = jax.lax.scan(step, s0, (xh_c, dt_c, b_c, c_c),
                                unroll=nc if flags.EXACT_COST_MODE else 1)
    y = y_c.transpose(1, 0, 2, 3, 4).reshape(bsz, l, h, pdim).astype(f32)
    return y, s_final


def mamba_apply(
    p: Params, x: jax.Array, cfg,
    state: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Mamba2 block. x: (B, L, D).

    ``state`` = (ssm_state (B,H,N,P), conv_tail (B, K-1, di + 2n)) enables
    recurrent decode (L small, typically 1).
    """
    bsz, l, d = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    pdim = cfg.ssm_headdim
    g = 1

    z = jnp.einsum("bld,de->ble", x, p["z_proj"])
    xin = jnp.einsum("bld,de->ble", x, p["x_proj"])
    bc = jnp.einsum("bld,de->ble", x, p["bc_proj"])
    dt = jnp.einsum("bld,de->ble", x, p["dt_proj"])

    tail = state[1] if state is not None else None
    tail_x = tail[:, :, :di] if tail is not None else None
    tail_bc = tail[:, :, di:] if tail is not None else None
    xin, new_tail_x = _causal_conv(xin, p["conv_x_w"], p["conv_x_b"], tail_x)
    bc, new_tail_bc = _causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"],
                                   tail_bc)
    xin = jax.nn.silu(xin)
    bc = jax.nn.silu(bc)
    bmat, cmat = jnp.split(bc, [g * n], axis=-1)
    new_tail = (jnp.concatenate([new_tail_x, new_tail_bc], axis=-1)
                if new_tail_x is not None else None)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])     # (B,L,H)
    a = -jnp.exp(p["a_log"])                                # (H,) < 0
    # p-major head layout: d_inner column j feeds (p=j//H, h=j%H), so a
    # TP sharding of d_inner maps to whole P-rows and propagates through
    # the reshape (headdim-sharded SSD; §Perf iteration 2)
    xh = xin.reshape(bsz, l, pdim, h).transpose(0, 1, 3, 2)

    if state is None:
        pad = (-l) % cfg.ssm_chunk
        if pad:
            xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b_p = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
            c_p = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        else:
            xh_p, dt_p, b_p, c_p = xh, dt, bmat, cmat
        y, s_final = _ssd_chunked(xh_p, dt_p, a, b_p, c_p, cfg.ssm_chunk)
        y = y[:, :l]
        new_state = None if state is None else (s_final, new_tail)
    else:
        # recurrent decode: per-token state update
        s = state[0].astype(jnp.float32)                    # (B,H,N,P)

        def step(s_prev, inp):
            x_t, dt_t, b_t, c_t = inp                       # (B,H,P),(B,H),(B,N),(B,N)
            da = jnp.exp(dt_t * a[None, :])                 # (B,H)
            s_new = s_prev * da[:, :, None, None] + jnp.einsum(
                "bn,bh,bhp->bhnp", b_t, dt_t, x_t
            )
            y_t = jnp.einsum("bn,bhnp->bhp", c_t, s_new)
            return s_new, y_t

        s_final, ys = jax.lax.scan(
            step, s,
            (xh.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
             bmat.astype(jnp.float32).transpose(1, 0, 2),
             cmat.astype(jnp.float32).transpose(1, 0, 2)),
        )
        y = ys.transpose(1, 0, 2, 3)                        # (B,L,H,P)
        new_state = (s_final, new_tail)

    y = y + p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.transpose(0, 1, 3, 2).reshape(bsz, l, di).astype(x.dtype)
    y = layers.rmsnorm({"scale": p["norm"]}, y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, p["out_proj"])
    return out, new_state


def init_ssm_state(cfg, batch: int):
    h, n, pdim = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return (
        jnp.zeros((batch, h, n, pdim), jnp.float32),
        jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), jnp.float32),
    )
