"""Cost models: the paper's Table-I heuristics, adapted to TPU, + rooflines.

Two clearly-separated models (DESIGN.md §5.2):

1. ``table1_reduction``   — the paper's CPU/SIMD memory-instruction-reduction
   closed forms, reproduced *literally* (per additional vector variable).
   Used to validate Observations 1-5 and by ``benchmarks/bench_heuristics``.

2. ``gemm_traffic`` / ``conv_traffic`` — the TPU adaptation: HBM<->VMEM bytes
   moved by a tiled Pallas kernel under a given ``DataflowSpec`` (grid order
   + VMEM residency).  This is what the explorer ranks on.

Plus the roofline terms used by EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

from repro.core.dataflow import (
    ANCHOR_GRID_ORDER,
    AttentionProblem,
    BinaryProblem,
    ConvProblem,
    DataflowSpec,
    GemmProblem,
    Residency,
    Stationarity,
    IS,
    OS,
    WS,
)

_DTYPE_BYTES = {
    "float64": 8,
    "float32": 4,
    "bfloat16": 2,
    "float16": 2,
    "int32": 4,
    "uint32": 4,
    "int8": 1,
    "uint8": 1,
    "bool": 1,
    "binary_packed": 4,  # 32 binary channels per uint32 lane
}


def dtype_bytes(dtype: str) -> int:
    key = str(dtype)
    if key not in _DTYPE_BYTES:
        raise KeyError(f"unknown dtype {dtype!r}")
    return _DTYPE_BYTES[key]


# ---------------------------------------------------------------------------
# Hardware description (TPU v5e class; see task spec for the constants).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_bw: float = 50e9                # bytes/s per ICI link
    vmem_bytes: int = 16 * 1024 * 1024  # software-managed fast memory
    lane: int = 128                     # minor-dim tiling
    sublane: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"float32": 8, "bfloat16": 16, "int8": 32}
    )

    def peak_flops_for(self, dtype: str) -> float:
        # int8 runs at 2x bf16 on the MXU; fp32 at ~1/4 (v5e has no fp32 MXU,
        # fp32 matmuls decompose); binary uses the VPU xor+popcount path.
        scale = {
            "bfloat16": 1.0,
            "float16": 1.0,
            "int8": 2.0,
            "float32": 0.25,
            "binary_packed": 0.5,
        }.get(str(dtype), 1.0)
        return self.peak_flops * scale


V5E = HardwareSpec()


# ---------------------------------------------------------------------------
# 1. Paper Table I, literal CPU/SIMD form.
# ---------------------------------------------------------------------------
def table1_reduction(
    anchor: Stationarity,
    aux: Stationarity,
    conv: ConvProblem,
    n_aux_vars: int = 1,
) -> Tuple[float, float]:
    """(reads_saved, writes_saved) **per additional aux vector variable**.

    Literal transcription of the paper's Table I (simplified forms, as in
    the paper).  Units: memory instructions of one vector variable each.
    """
    H, R, E, s, fw, fh, ih = (
        conv.H, conv.R, conv.E, conv.s, conv.fw, conv.fh, conv.ih,
    )
    if anchor == OS:
        # "Both" aux rows: every stashed input or weight variable saves E reads.
        if aux in (IS, WS):
            return (float(E), 0.0)
    elif anchor == WS:
        if aux == IS:
            return (float(R), 0.0)
        if aux == OS:
            return (float(R), float(R))
    elif anchor == IS:
        if s == 1:
            if aux == WS:
                return (float(H), 0.0)
            if aux == OS:
                return (float(H), float(H))
        else:
            if aux == WS:
                if n_aux_vars <= fw:
                    return (H / s, 0.0)
                return (H / ((fw - s) * s), 0.0)
            if aux == OS:
                if n_aux_vars == 1:
                    g = H + H / fw
                    return (g, g)
                if n_aux_vars == 2:
                    g = (ih / max(fw - s, 1)) * (H + H / fw) + (ih / s) * max(
                        fw - s - 1, 0
                    )
                    return (g, g)
                g = (fh - s) * (fw - s) * H / R
                return (g, g)
    raise ValueError(f"no Table-I row for anchor={anchor} aux={aux} s={s}")


def paper_observations_hold(conv: ConvProblem) -> Dict[str, bool]:
    """Re-derive Observations 1-5 from Table I for a given layer (tested)."""
    obs = {}
    # Obs 1: WS gains least per aux variable.
    ws_gain = max(sum(table1_reduction(WS, a, conv)) for a in (IS, OS))
    os_gain = sum(table1_reduction(OS, WS, conv))
    is_gain = sum(table1_reduction(IS, OS, conv, n_aux_vars=1))
    obs["obs1_ws_gains_least"] = ws_gain <= min(os_gain, is_gain)
    # Obs 3: under OS, input-aux == weight-aux.
    obs["obs3_os_aux_symmetric"] = table1_reduction(
        OS, IS, conv
    ) == table1_reduction(OS, WS, conv)
    # Obs 4: under IS, output-aux >= weight-aux.
    obs["obs4_is_output_first"] = sum(
        table1_reduction(IS, OS, conv, 1)
    ) >= sum(table1_reduction(IS, WS, conv, 1))
    # Obs 5: under WS, output-aux >= input-aux.
    obs["obs5_ws_output_first"] = sum(
        table1_reduction(WS, OS, conv)
    ) >= sum(table1_reduction(WS, IS, conv))
    return obs


# ---------------------------------------------------------------------------
# 2. TPU HBM<->VMEM traffic model for tiled kernels.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Traffic:
    """Bytes moved between HBM and VMEM, per operand class."""

    reads: Dict[Stationarity, int]
    writes: Dict[Stationarity, int]
    vmem_peak: int
    feasible: bool  # fits in the VMEM budget

    @property
    def total(self) -> int:
        return sum(self.reads.values()) + sum(self.writes.values())


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# Sub-byte packed weights (kernels/pack.py)
#
# Byte accounting mirrors the storage format exactly: a nibble plane of
# ceil(k/8) int32 words per column, a 1-bit high plane (bits == 5 only) of
# ceil(k/32) words per column, and an outlier sidecar of at most
# ceil(3k/256) rows (MSR coding bounds the out-of-range rows; pack.py uses
# the same capacity formula), each row one int32 index + n int32 deltas.
# ---------------------------------------------------------------------------


def packed_outlier_capacity(k: int) -> int:
    """Worst-case outlier sidecar rows for a K-dim of ``k`` (matches pack.py)."""
    return max(1, _ceil(3 * k, 256))


def packed_slab_bytes(rows: int, cols: int, weight_bits: int) -> int:
    """Bytes of the packed planes covering a ``rows x cols`` weight slab."""
    bytes_ = _ceil(rows, 8) * cols * 4  # nibble plane, int32 words
    if weight_bits == 5:
        bytes_ += _ceil(rows, 32) * cols * 4  # high-bit plane
    return bytes_


def packed_weight_bytes(k: int, n: int, weight_bits: int) -> int:
    """Total HBM bytes of a packed (k, n) weight: planes + outlier sidecar."""
    cap = packed_outlier_capacity(k)
    return packed_slab_bytes(k, n, weight_bits) + cap * (4 + n * 4)


def weight_stream_bytes(p: GemmProblem) -> int:
    """HBM bytes of one full fetch of the weight operand (packed-aware)."""
    if p.weight_bits is None:
        return p.k * p.n * dtype_bytes(p.in_dtype)
    return packed_weight_bytes(p.k, p.n, p.weight_bits)


def gemm_vmem_footprint(p: GemmProblem, spec: DataflowSpec) -> int:
    """Peak VMEM bytes claimed by the dataflow (double-buffered streams)."""
    bm, bk, bn = spec.block
    ib, ob = dtype_bytes(p.in_dtype), dtype_bytes(p.out_dtype)
    ab = dtype_bytes(p.acc_dtype)
    foot = 0
    # streamed blocks are double-buffered by the Pallas pipeline
    res_a = spec.residency(IS)
    res_b = spec.residency(WS)
    res_o = spec.residency(OS)
    foot += {
        Residency.STREAMED: 2 * bm * bk,
        Residency.STRIPE: bm * p.k,
        Residency.WHOLE: p.m * p.k,
    }[res_a] * ib
    if p.weight_bits is None:
        foot += {
            Residency.STREAMED: 2 * bk * bn,
            Residency.STRIPE: p.k * bn,
            Residency.WHOLE: p.k * p.n,
        }[res_b] * ib
    else:
        # packed planes resident per the dataflow, plus the transient
        # decompressed int8 block materialized at the stripe load
        foot += {
            Residency.STREAMED: 2 * packed_slab_bytes(bk, bn, p.weight_bits),
            Residency.STRIPE: packed_slab_bytes(p.k, bn, p.weight_bits),
            Residency.WHOLE: packed_slab_bytes(p.k, p.n, p.weight_bits),
        }[res_b]
        foot += bk * bn  # int8 decompress scratch
    foot += {
        Residency.STREAMED: 2 * bm * bn,
        Residency.STRIPE: bm * p.n if spec.anchor == IS else p.m * bn,
        Residency.WHOLE: p.m * p.n,
    }[res_o] * ob
    # scratch accumulator: OS always; basic (streamed-output) WS/IS since
    # the single-dispatch lowering accumulates in a VMEM scratch too
    if spec.anchor == OS or res_o == Residency.STREAMED:
        foot += bm * bn * ab
    elif p.in_dtype in ("int8", "uint8", "int32", "uint32", "bool"):
        # integer-input fused epilogues make the output-stripe WS/IS
        # writers accumulate in an int32 scratch of the stripe's shape
        # (kernels.matmul_df); charge it conservatively
        foot += {
            Residency.STRIPE: bm * p.n if spec.anchor == IS else p.m * bn,
            Residency.WHOLE: p.m * p.n,
        }[res_o] * ab
    return foot


def gemm_traffic(p: GemmProblem, spec: DataflowSpec) -> Traffic:
    """HBM bytes moved by the tiled kernel realizing ``spec`` on ``p``.

    Derivation (DESIGN.md §2): an operand whose block index is constant
    across consecutive grid steps is fetched once per distinct index; a
    streamed operand is re-fetched on every sweep of the grid dims its
    index does not depend on.
    """
    bm, bk, bn = spec.block
    gm, gk, gn = _ceil(p.m, bm), _ceil(p.k, bk), _ceil(p.n, bn)
    ib, ob = dtype_bytes(p.in_dtype), dtype_bytes(p.out_dtype)
    A, B, O = p.m * p.k * ib, weight_stream_bytes(p), p.m * p.n * ob

    res_a, res_b, res_o = (
        spec.residency(IS), spec.residency(WS), spec.residency(OS)
    )
    reads: Dict[Stationarity, int] = {}
    writes: Dict[Stationarity, int] = {IS: 0, WS: 0, OS: 0}

    if spec.anchor == OS:
        writes[OS] = O  # flushed once from the scratch accumulator
        reads[OS] = 0
        # Only one streamed-aux operand can own the outer grid position; the
        # aux_priority decides (paper Alg. 8: weight first).  WHOLE residency
        # removes the conflict.
        a_once = res_a == Residency.WHOLE
        b_once = res_b == Residency.WHOLE
        stripes = [
            st
            for st in spec.aux_priority
            if spec.residency(st) == Residency.STRIPE and st in (IS, WS)
        ]
        if not stripes:
            stripes = [
                st for st in (WS, IS) if spec.residency(st) == Residency.STRIPE
            ]
        if stripes:
            first = stripes[0]
            a_once = a_once or (first == IS)
            b_once = b_once or (first == WS)
            # a second stripe also sticks iff the first is WHOLE-resident
            for st in stripes[1:]:
                if (st == IS and b_once and res_b == Residency.WHOLE) or (
                    st == WS and a_once and res_a == Residency.WHOLE
                ):
                    a_once = a_once or st == IS
                    b_once = b_once or st == WS
        reads[IS] = A if a_once else gn * A
        reads[WS] = B if b_once else gm * B
    elif spec.anchor == WS:
        reads[WS] = B  # anchored: fetched exactly once
        a_once = res_a in (Residency.STRIPE, Residency.WHOLE)
        reads[IS] = A if a_once else gn * A
        if res_o in (Residency.STRIPE, Residency.WHOLE):
            reads[OS] = 0
            writes[OS] = O
        else:  # read-modify-write per reduction visit
            reads[OS] = gk * O
            writes[OS] = gk * O
    elif spec.anchor == IS:
        reads[IS] = A
        b_once = res_b == Residency.WHOLE  # stripes don't survive the m sweep
        reads[WS] = B if b_once else gm * B
        if res_o in (Residency.STRIPE, Residency.WHOLE):
            reads[OS] = 0
            writes[OS] = O
        else:
            reads[OS] = gk * O
            writes[OS] = gk * O
    else:
        raise ValueError(spec.anchor)

    foot = gemm_vmem_footprint(p, spec)
    return Traffic(
        reads=reads,
        writes=writes,
        vmem_peak=foot,
        feasible=foot <= spec.vmem_budget,
    )


def conv_traffic(p: ConvProblem, spec: DataflowSpec) -> Traffic:
    """Conv traffic via the implicit-GEMM view + window-overlap correction.

    A streamed conv input is read through overlapping windows (R/s^2 reuse
    forfeited); STRIPE/WHOLE residency recovers the unique-bytes bound —
    this is exactly the paper's input-reuse argument (Fig. 4) in bytes.
    """
    g = p.as_gemm()
    t = gemm_traffic(g, spec)
    unique_in = p.n * p.H * p.cin * dtype_bytes(p.in_dtype)
    reads = dict(t.reads)
    if spec.residency(IS) in (Residency.STRIPE, Residency.WHOLE):
        # resident input: halo rows are fetched once -> unique bytes
        refetch = reads[IS] // max(g.m * g.k * dtype_bytes(g.in_dtype), 1)
        reads[IS] = max(1, refetch) * unique_in if spec.anchor != IS else unique_in
        if spec.residency(IS) == Residency.WHOLE or spec.anchor == IS:
            reads[IS] = unique_in
    return Traffic(reads, dict(t.writes), t.vmem_peak, t.feasible)


def conv_gemm_view(p: ConvProblem, spec: DataflowSpec) -> DataflowSpec:
    """Map a conv-blocked spec to its implicit-GEMM blocking.

    A *conv-blocked* spec stores ``block = (b_oh, bc, bk)`` — the output
    row-tile height, the cin reduction panel, and the cout tile realized
    by ``kernels.conv2d_df``.  One output tile covers ``b_oh * ow`` GEMM
    rows, one reduction panel ``bc`` of the ``R * cin`` reduction, and
    one cout tile ``bk`` GEMM columns.
    """
    b_oh, bc, bk = spec.block
    return spec.with_block((max(1, b_oh) * p.ow, bc, bk))


def conv_vmem_footprint(p: ConvProblem, spec: DataflowSpec) -> int:
    """Peak VMEM bytes claimed by the realized conv kernel.

    Mirrors ``gemm_vmem_footprint`` for ``kernels.conv2d_df``'s actual
    lowering (``spec.block`` is conv-blocked, see ``conv_gemm_view``):
    the padded input image is whole-resident, one (fh, fw, C, bk) weight
    block and one (b_oh, ow, bk) output block are double-buffered, and
    the scratch accumulator holds one output tile in the acc dtype.
    """
    b_oh, bc, bk = spec.block
    ib, ob = dtype_bytes(p.in_dtype), dtype_bytes(p.out_dtype)
    ab = 4  # int32 / float32 accumulator
    cpad = _ceil(p.cin, bc) * bc
    kpad = _ceil(p.cout, bk) * bk
    b_oh = min(b_oh, p.oh)
    oh_pad = _ceil(p.oh, b_oh) * b_oh
    ih_pad = (oh_pad - 1) * p.s + p.fh + (p.s - 1)
    iw_pad = (p.ow - 1) * p.s + p.fw + (p.s - 1)
    foot = ih_pad * iw_pad * cpad * ib                # whole-resident image
    foot += 2 * p.fh * p.fw * cpad * min(bk, kpad) * ib
    foot += 2 * b_oh * p.ow * min(bk, kpad) * ob
    foot += b_oh * p.ow * min(bk, kpad) * ab
    return foot


def binary_traffic(p: BinaryProblem, spec: DataflowSpec) -> Traffic:
    """HBM bytes moved by the binary kernel realizing ``spec`` on ``p``.

    Bit-traffic accounting runs on the packed-word GEMM view
    (``BinaryProblem.as_gemm``): operands are uint32 words carrying 32
    binary channels each, so A is ``m * kp * 4`` bytes — 8x smaller than
    the int8 image of the same layer, which is the data-movement
    component of the paper's Fig. 9 speedup.  ``spec.block`` is
    ``(bm, bkp, bn)`` with the reduction blocked in packed words.
    """
    return gemm_traffic(p.as_gemm(), spec)


def binary_time_estimate(
    p: BinaryProblem, spec: DataflowSpec, hw: HardwareSpec = V5E
) -> float:
    """max(compute, memory) estimate for ranking binary dataflows.

    Compute charges ``bit_ops`` (xnor + popcount-accumulate pairs over
    the *true* reduction depth) at the VPU's ``binary_packed`` rate;
    memory comes from ``binary_traffic`` on the packed view.
    """
    t = binary_traffic(p, spec)
    tc = p.bit_ops / hw.peak_flops_for("binary_packed")
    tm = t.total / hw.hbm_bw
    penalty = 0.0 if t.feasible else float("inf")
    return max(tc, tm) + penalty


def conv_time_estimate(
    p: ConvProblem, spec: DataflowSpec, hw: HardwareSpec = V5E
) -> float:
    """max(compute, memory) estimate for ranking *conv-blocked* specs.

    Traffic comes from ``conv_traffic`` on the implicit-GEMM view of the
    blocking; feasibility from ``conv_vmem_footprint`` (the realized
    kernel's residency, not the GEMM tiling's).
    """
    t = conv_traffic(p, conv_gemm_view(p, spec))
    tc = p.flops / hw.peak_flops_for(p.in_dtype)
    tm = t.total / hw.hbm_bw
    feasible = conv_vmem_footprint(p, spec) <= spec.vmem_budget
    return max(tc, tm) + (0.0 if feasible else float("inf"))


# Attention: online-softmax statistics ride in (bq, 128)-shaped f32 lanes
# next to the (bq, d) f32 accumulator (see kernels/attention_df).
ATTN_STAT_LANES = 256   # m + l, 128 lanes each
_F32 = 4


def attention_block_clamp(sq: int, skv: int, bq: int,
                          bkv: int) -> Tuple[int, int]:
    """The ``(bq, bkv)`` the attention kernels actually realize for true
    lengths ``(sq, skv)``: blocks clamp to the 8-padded sequence, and
    ``sq == 1`` forces the single-q-row decode fast path (no q blocking).

    The single source of this rule — ``ops.attention`` applies it before
    padding and the cost model mirrors it here, so ranking and realized
    kernel can never drift apart.
    """
    bq = 1 if sq <= 1 else max(1, min(bq, -(-sq // 8) * 8))
    bkv = max(1, min(bkv, -(-max(skv, 1) // 8) * 8))
    return bq, bkv


def _attn_padded(p: AttentionProblem, spec: DataflowSpec):
    bq, bkv = attention_block_clamp(p.sq, p.skv, spec.block[0],
                                    spec.block[1])
    sqp = _ceil(p.sq, bq) * bq
    skvp = _ceil(p.skv, bkv) * bkv
    return bq, bkv, sqp, skvp


def attention_band(p: AttentionProblem, i: int, bq: int,
                   bkv: int) -> Tuple[int, int]:
    """[lo, hi] inclusive KV-block band visible to q tile ``i``.

    The single source of the banding rule: ``kernels.attention_df``
    mirrors these bounds in its index maps (with traced ``kv_len`` /
    ``window`` scalars), so the blocks the cost model charges are
    exactly the blocks the kernel fetches.  ``hi < lo`` means the tile
    sees nothing (can only happen for all-padding q tiles).

    A KV block ``j`` (positions ``[j*bkv, (j+1)*bkv)``) is visible iff
      * it starts inside the valid prefix: ``j*bkv < kv_valid``;
      * (causal) it starts at or before the tile's last q position;
      * (window) it ends after the tile's first q position minus the
        window.
    q rows are right-aligned against the valid KV length
    (``off = kv_valid - sq``), matching the kernels and the decode
    convention.
    """
    kv_valid = p.kv_valid
    off = kv_valid - p.sq
    hi = max(0, _ceil(kv_valid, bkv) - 1)          # last valid block
    if p.causal:
        qmax = min((i + 1) * bq, p.sq) - 1 + off   # tile's last true row
        hi = min(hi, max(0, qmax) // bkv)
    lo = 0
    if p.window is not None:
        qmin = i * bq + off
        lo = max(0, (qmin - p.window + 1) // bkv)
    return min(lo, hi), hi


def attention_visited_blocks(
    p: AttentionProblem, bq: int, bkv: int
) -> Tuple[int, int, int, int]:
    """(visited (q tile, KV block) pairs, distinct visited KV blocks,
    gq, gkv) under banded execution with blocks ``(bq, bkv)``.

    ``pairs`` is the number of grid steps that do DMA + compute work
    (OS re-streams one KV block per pair; WS round-trips one state
    block per pair); ``kv_blocks`` is how many distinct KV blocks are
    touched at all (WS fetches each exactly once).  With no window, a
    full valid prefix and no causal mask this degenerates to the old
    full-mask accounting (``pairs = gq * gkv``).
    """
    bq, bkv = attention_block_clamp(p.sq, p.skv, bq, bkv)
    gq = _ceil(p.sq, bq)
    gkv = _ceil(p.skv, bkv)
    pairs = 0
    seen = set()
    for i in range(gq):
        lo, hi = attention_band(p, i, bq, bkv)
        if hi < lo:
            continue
        pairs += hi - lo + 1
        seen.update(range(lo, hi + 1))
    return pairs, len(seen), gq, gkv


def attention_banded_ops(p: AttentionProblem, bq: int,
                         bkv: int) -> Tuple[int, int]:
    """(dot_flops, softmax_ops) over the *visited* score blocks only.

    Block skipping makes mask sparsity a first-class ranking term: a
    windowed prefill's compute scales with ``sq * window``-ish visited
    area, and a cached decode's with the valid KV length — the full-
    mask ``AttentionProblem.dot_flops`` stays available for rooflines.
    """
    pairs, _, _, _ = attention_visited_blocks(p, bq, bkv)
    bq, bkv = attention_block_clamp(p.sq, p.skv, bq, bkv)
    scores = pairs * bq * bkv
    return 4 * p.bh * scores * p.d, 6 * p.bh * scores


def attention_vmem_footprint(p: AttentionProblem,
                             spec: DataflowSpec) -> int:
    """Peak VMEM bytes claimed by the realized attention kernel.

    Both anchors double-buffer the streamed q and KV blocks; the
    anchor-dependent term is where the running (acc, m, l) state lives —
    VMEM scratch for the whole KV sweep under OS, a double-buffered
    revisited block under WS.
    """
    bq, bkv, _, _ = _attn_padded(p, spec)
    ib = dtype_bytes(p.dtype)
    kvib = dtype_bytes(p.kv_elem_dtype)
    state = bq * (p.d + ATTN_STAT_LANES) * _F32
    foot = 2 * bq * p.d * ib              # q block
    foot += 2 * 2 * bkv * p.d * kvib      # k and v blocks
    if p.kv_quantized:                    # int8 KV: per-position scales
        foot += 2 * 2 * bkv * _F32
    if spec.anchor == OS:
        foot += 2 * bq * p.d * ib         # output block
        foot += state                     # scratch acc + stats
    else:                                 # WS: state revisited through HBM
        foot += 2 * state
    return foot


def attention_traffic(p: AttentionProblem, spec: DataflowSpec) -> Traffic:
    """HBM bytes moved by the attention kernel realizing ``spec``.

    Operand classes: IS = Q, WS = K+V (+ per-position dequant scales
    for an int8 KV cache), OS = output / running state.

      OS (flash)          — Q and O move once; KV blocks stream once
                            per *visited* (q tile, KV block) pair.
      WS (kv-stationary)  — each *visited* KV block moves exactly once,
                            but the sweep is rectangular: for every
                            swept block ALL ``gq`` q tiles re-read
                            their q block and round-trip the (acc, m,
                            l) state (an invisible pair skips compute
                            yet still carries its state through the
                            aliased buffers — per-pair banding cannot
                            remove WS's state traffic, only whole
                            blocks leave the sweep).

    Banded accounting (PR 5): the kernels skip KV blocks beyond the
    valid ``kv_len`` and fully out-of-band causal/window blocks
    (``attention_visited_blocks``), so mask sparsity no longer cancels
    out of the OS-vs-WS ranking — OS's KV re-streaming shrinks with
    the visited *pairs* while WS shrinks only with the distinct
    visited *blocks*.  A cached decode therefore moves bytes
    proportional to the valid KV length, not the ``skv`` buffer size.
    """
    bq, bkv, sqp, skvp = _attn_padded(p, spec)
    pairs, kv_blocks, gq, gkv = attention_visited_blocks(p, bq, bkv)
    qib = dtype_bytes(p.dtype)
    kvib = dtype_bytes(p.kv_elem_dtype)
    # bytes of one KV position (K + V rows, + two f32 dequant scales
    # when the cache is int8-quantized), charged per q-head row (GQA
    # re-use is a VMEM property, not an HBM one, matching the kernels).
    kv_pos = 2 * p.d * kvib + (2 * _F32 if p.kv_quantized else 0)
    Q = p.bh * sqp * p.d * qib
    O = p.bh * sqp * p.d * qib
    reads: Dict[Stationarity, int] = {}
    writes: Dict[Stationarity, int] = {IS: 0, WS: 0, OS: 0}
    if spec.anchor == OS:
        reads[IS] = Q
        reads[WS] = p.bh * pairs * bkv * kv_pos
        reads[OS] = 0
        writes[OS] = O
    elif spec.anchor == WS:
        reads[WS] = p.bh * kv_blocks * bkv * kv_pos
        steps = kv_blocks * gq          # rectangular sweep (see above)
        reads[IS] = p.bh * steps * bq * p.d * qib
        state = p.bh * steps * bq * (p.d + ATTN_STAT_LANES) * _F32
        reads[OS] = state
        writes[OS] = state
    else:
        raise ValueError(f"attention admits OS/WS anchors, not {spec.anchor}")
    foot = attention_vmem_footprint(p, spec)
    return Traffic(reads=reads, writes=writes, vmem_peak=foot,
                   feasible=foot <= spec.vmem_budget)


def attention_rows_traffic(p: AttentionProblem, kv_lens,
                           spec: DataflowSpec) -> Traffic:
    """Per-row banded traffic for a ragged decode step (PR 8).

    ``kv_lens`` holds one valid KV length per batch row of ``p``
    (``len(kv_lens)`` rows sharing ``p.bh`` head-rows equally); each
    row is charged the banded traffic of ITS OWN valid length — the
    sum a continuous-batching step realizes — instead of charging
    every row at the batch max.  A row at 0 moves nothing (its kernel
    steps clamp onto the edge block and skip all compute).  The
    per-row problems reuse :func:`attention_traffic`, so this stays a
    pure aggregation of the one banding rule.
    """
    kv_lens = [int(kv) for kv in kv_lens]
    rows = max(len(kv_lens), 1)
    if p.bh % rows:
        raise ValueError(f"bh={p.bh} not divisible by {rows} kv_lens rows")
    heads = p.bh // rows
    reads: Dict[Stationarity, int] = {IS: 0, WS: 0, OS: 0}
    writes: Dict[Stationarity, int] = {IS: 0, WS: 0, OS: 0}
    vmem_peak, feasible = 0, True
    for kv in kv_lens:
        if kv <= 0:
            continue                       # empty row: no visited blocks
        rp = dataclasses.replace(p, bh=heads, rows=1,
                                 kv_len=min(kv, p.skv))
        t = attention_traffic(rp, spec)
        for st in (IS, WS, OS):
            reads[st] += t.reads.get(st, 0)
            writes[st] += t.writes.get(st, 0)
        vmem_peak = max(vmem_peak, t.vmem_peak)
        feasible &= t.feasible
    return Traffic(reads=reads, writes=writes, vmem_peak=vmem_peak,
                   feasible=feasible)


def attention_time_estimate(
    p: AttentionProblem, spec: DataflowSpec, hw: HardwareSpec = V5E
) -> float:
    """max(compute, memory) estimate for ranking attention dataflows.

    Compute charges the QK^T/PV dots at the MXU rate of ``p.dtype``
    plus the online-softmax per-score ops at the VPU (float32) rate,
    both over the *visited* score blocks only
    (``attention_banded_ops``); memory comes from ``attention_traffic``
    (banded, anchor-dependent KV re-streaming and state round-trips).
    """
    t = attention_traffic(p, spec)
    dot, soft = attention_banded_ops(p, spec.block[0], spec.block[1])
    tc = (dot / hw.peak_flops_for(p.dtype)
          + soft / hw.peak_flops_for("float32"))
    tm = t.total / hw.hbm_bw
    return max(tc, tm) + (0.0 if t.feasible else float("inf"))


# ---------------------------------------------------------------------------
# 3. Roofline terms (EXPERIMENTS.md §Roofline).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    t_compute: float
    t_memory: float
    t_collective: float
    chips: int
    flops: float
    hbm_bytes: float
    collective_bytes: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def compute_fraction(self) -> float:
        """Fraction of the roofline-bound time spent at peak compute."""
        if self.bound_time == 0:
            return 0.0
        return self.t_compute / self.bound_time


def roofline(
    flops: float,
    hbm_bytes: float,
    collective_bytes: float,
    chips: int = 1,
    hw: HardwareSpec = V5E,
    dtype: str = "bfloat16",
) -> RooflineTerms:
    """The three-term roofline from the task spec.

    compute    = HLO_FLOPs / (chips * peak)
    memory     = HLO_bytes / (chips * hbm_bw)
    collective = collective_bytes / (chips * link_bw)

    ``flops``/``hbm_bytes``/``collective_bytes`` are *global* (whole-step)
    quantities; per-chip values are obtained by the division.
    """
    return RooflineTerms(
        t_compute=flops / (chips * hw.peak_flops_for(dtype)),
        t_memory=hbm_bytes / (chips * hw.hbm_bw),
        t_collective=collective_bytes / (chips * hw.ici_bw),
        chips=chips,
        flops=flops,
        hbm_bytes=hbm_bytes,
        collective_bytes=collective_bytes,
    )


def model_flops(n_params: int, tokens: int, training: bool = True) -> float:
    """6*N*D for training (fwd+bwd), 2*N*D for inference forward."""
    return (6.0 if training else 2.0) * n_params * tokens


def traffic_seconds(t: Traffic, hw: HardwareSpec = V5E) -> float:
    return t.total / hw.hbm_bw


def gemm_time_estimate(
    p: GemmProblem, spec: DataflowSpec, hw: HardwareSpec = V5E
) -> float:
    """max(compute, memory) single-chip estimate used for ranking dataflows."""
    t = gemm_traffic(p, spec)
    tc = p.flops / hw.peak_flops_for(p.in_dtype)
    tm = t.total / hw.hbm_bw
    penalty = 0.0 if t.feasible else float("inf")
    return max(tc, tm) + penalty
