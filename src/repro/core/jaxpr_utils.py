"""Small jaxpr-inspection helpers shared by tests and benchmarks."""
from __future__ import annotations


def _subjaxprs(v):
    from jax.core import ClosedJaxpr, Jaxpr

    if isinstance(v, ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        # e.g. lax.cond/switch store their branches as a tuple of jaxprs
        for item in v:
            yield from _subjaxprs(item)


def _walk(jaxpr, visit) -> int:
    count = 0
    for eqn in jaxpr.eqns:
        count += visit(eqn)
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                count += _walk(sub, visit)
    return count


def count_primitive(jaxpr, name: str) -> int:
    """Occurrences of primitive ``name`` anywhere in ``jaxpr``
    (recursing into sub-jaxprs)."""
    return _walk(jaxpr, lambda eqn: eqn.primitive.name == name)


def count_pallas_calls(jaxpr) -> int:
    """Number of ``pallas_call`` primitives anywhere in ``jaxpr``
    (recursing into sub-jaxprs) — i.e. kernel dispatches per trace."""
    return count_primitive(jaxpr, "pallas_call")


def count_eqns(jaxpr) -> int:
    """Total equation count including sub-jaxprs — a dispatch/step-count
    proxy for comparing fused vs unfused lowerings."""
    return _walk(jaxpr, lambda eqn: 1)


def pallas_grid_steps(jaxpr) -> int:
    """Total static grid steps across every ``pallas_call`` in
    ``jaxpr`` (recursing into sub-jaxprs): the sum over dispatches of
    the product of their grid dims.

    This is the "grid work" a lowering commits to at trace time — the
    banded attention kernels shrink it when a static window (or static
    valid length) proves KV blocks masked, so benchmarks/tests can
    assert skipped blocks really left the grid rather than being
    masked in-kernel.
    """
    def visit(eqn):
        if eqn.primitive.name != "pallas_call":
            return 0
        steps = 1
        for dim in eqn.params["grid_mapping"].grid:
            steps *= int(dim)
        return steps

    return _walk(jaxpr, visit)
