"""Small jaxpr-inspection helpers shared by tests and benchmarks."""
from __future__ import annotations


def _subjaxprs(v):
    from jax.core import ClosedJaxpr, Jaxpr

    if isinstance(v, ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        # e.g. lax.cond/switch store their branches as a tuple of jaxprs
        for item in v:
            yield from _subjaxprs(item)


def _walk(jaxpr, visit) -> int:
    count = 0
    for eqn in jaxpr.eqns:
        count += visit(eqn)
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                count += _walk(sub, visit)
    return count


def count_primitive(jaxpr, name: str) -> int:
    """Occurrences of primitive ``name`` anywhere in ``jaxpr``
    (recursing into sub-jaxprs)."""
    return _walk(jaxpr, lambda eqn: eqn.primitive.name == name)


def count_pallas_calls(jaxpr) -> int:
    """Number of ``pallas_call`` primitives anywhere in ``jaxpr``
    (recursing into sub-jaxprs) — i.e. kernel dispatches per trace."""
    return count_primitive(jaxpr, "pallas_call")


def count_eqns(jaxpr) -> int:
    """Total equation count including sub-jaxprs — a dispatch/step-count
    proxy for comparing fused vs unfused lowerings."""
    return _walk(jaxpr, lambda eqn: 1)
