"""Shared symmetric int8 quantization — single source of truth.

Every int8 tier in the codebase uses the same symmetric (zero-point
free) scheme:

    amax  = max(|x|)  over the reduction axes
    scale = amax / 127        (1.0 where amax == 0, so dequant is exact)
    q     = clip(round(x / scale), -127, 127)  as int8

Until PR 9 three private copies of this lived in ``kernels/ref.py``
(per-axis weight/activation quant), ``optim/compress.py`` (per-tensor
gradient compression) and ``models/layers.py`` (per-position KV-cache
quant); they are all thin wrappers over :func:`symmetric_int8` now.
The sub-byte packed-weight tier (``kernels/pack.py``) builds on the
same helper for its int8 pre-quantization.

Numerical note: amax and the division are computed in float32.  For
bfloat16/float16 inputs this matches the historical per-copy behaviour
exactly — ``x / scale`` promoted to float32 anyway, and the low-to-high
widening cast is value-preserving.
"""
from __future__ import annotations

from typing import Tuple, Union

import jax
import jax.numpy as jnp

Axis = Union[None, int, Tuple[int, ...]]


def symmetric_int8(
    x: jax.Array, axis: Axis = None, keepdims: bool = True
) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization of ``x`` -> ``(q, scale)``.

    ``axis=None`` quantizes per-tensor (scalar float32 scale); an int or
    tuple axis reduces amax over those dims, keeping them as size-1 dims
    when ``keepdims`` so the scale broadcasts back against ``q``.

    Invariants (property-tested in tests/test_packed.py):
      * all-zero reductions quantize to q == 0 with scale == 1.0 (no
        divide-by-zero; dequantization is exact);
      * ``|x - q * scale| <= scale / 2`` elementwise (round-trip bound),
        since amax / scale == 127 never clips.
    """
    x32 = x.astype(jnp.float32)
    if axis is None:
        amax = jnp.max(jnp.abs(x32))
    else:
        amax = jnp.max(jnp.abs(x32), axis=axis, keepdims=keepdims)
    scale = jnp.where(amax == 0, 1.0, amax / 127.0)
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`symmetric_int8` up to the round-trip bound."""
    return (q.astype(jnp.float32) * scale).astype(dtype)
