"""End-to-end network optimization (paper §IV-B/C complete pipeline).

Given a whole conv network (list of layer specs, including the
depthwise / grouped variants the paper targets), this:

  1. explores extended dataflows per layer (heuristics + cost model),
  2. runs the §IV-C layout/dataflow chain DP over per-layer options with
     transition costs,
  3. emits an executable plan: per-layer DataflowSpec + predicted
     traffic/time, realizable through kernels/ops.conv2d.

This is the analogue of the paper's end-to-end code generation flow that
produced the Fig. 8 networks.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core import cost_model, explorer, layout
from repro.core.dataflow import ConvProblem, DataflowSpec, GemmProblem


@dataclasses.dataclass(frozen=True)
class ConvLayerSpec:
    """One conv layer in a network, with grouping (paper §IV scope:
    simple / depthwise / grouped / shuffled-grouped convolutions)."""

    ih: int
    iw: int
    fh: int
    fw: int
    s: int
    cin: int
    cout: int
    groups: int = 1          # cin == cout == groups -> depthwise
    in_dtype: str = "int8"

    def problems(self) -> ConvProblem:
        """Per-group conv problem (groups share the dataflow choice)."""
        if self.cin % self.groups or self.cout % self.groups:
            raise ValueError(f"groups {self.groups} must divide channels")
        return ConvProblem(
            ih=self.ih, iw=self.iw, fh=self.fh, fw=self.fw, s=self.s,
            cin=self.cin // self.groups, cout=self.cout // self.groups,
            in_dtype=self.in_dtype,
        )

    @property
    def is_depthwise(self) -> bool:
        return self.groups == self.cin == self.cout


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    layer: ConvLayerSpec
    spec: DataflowSpec
    layout: str
    est_seconds: float
    traffic_bytes: int


@dataclasses.dataclass(frozen=True)
class NetworkPlan:
    layers: List[LayerPlan]
    total_seconds: float

    def describe(self) -> str:
        lines = []
        for i, lp in enumerate(self.layers):
            tag = "dw" if lp.layer.is_depthwise else (
                f"g{lp.layer.groups}" if lp.layer.groups > 1 else "conv")
            lines.append(
                f"  L{i:02d} {tag:5s} {lp.layer.ih}x{lp.layer.iw} "
                f"f{lp.layer.fh} s{lp.layer.s} "
                f"{lp.layer.cin}->{lp.layer.cout}: {lp.spec.name:22s} "
                f"{lp.est_seconds*1e6:9.1f}us {lp.layout}"
            )
        lines.append(f"  total: {self.total_seconds*1e6:.1f}us (est.)")
        return "\n".join(lines)


def plan_layer(
    layer: ConvLayerSpec,
    hw: cost_model.HardwareSpec = cost_model.V5E,
    top: int = 3,
) -> List[Tuple[DataflowSpec, float, int]]:
    """Top dataflow options for one layer: (spec, est_s, traffic_bytes).

    Grouped convs explore the per-group GEMM and scale costs by the
    group count (groups run the same dataflow back-to-back — this is
    exactly the paper's treatment: grouping shrinks K and N, shifting
    which auxiliary stationarity fits in the register/VMEM budget).
    """
    conv = layer.problems()
    g = conv.as_gemm()
    cands = explorer.explore(g, hw, top=top)
    out = []
    for c in cands:
        out.append((c.spec, c.est_seconds * layer.groups,
                    c.traffic_bytes * layer.groups))
    return out


def optimize_network(
    net: Sequence[ConvLayerSpec],
    hw: cost_model.HardwareSpec = cost_model.V5E,
    flexible_writes: bool = True,
    layouts: Sequence[str] = ("NCHWc128",),
) -> NetworkPlan:
    """Explore per-layer dataflows, then chain-DP over (layout, dataflow).

    With ``flexible_writes`` (the paper's finding) layout transitions are
    free and the DP reduces to per-layer argmin; with it disabled the DP
    balances relayout cost against per-layer gains.
    """
    per_layer_options: List[List[layout.LayerOption]] = []
    per_layer_specs: List[List[DataflowSpec]] = []
    for lyr in net:
        opts = []
        specs = []
        conv = lyr.problems()
        out_bytes = conv.E * lyr.cout * cost_model.dtype_bytes(
            conv.out_dtype)
        for spec, est_s, traffic in plan_layer(lyr, hw):
            for lo in layouts:
                opts.append(layout.LayerOption(
                    layout=lo, dataflow=spec.name, cost=est_s,
                    out_bytes=out_bytes,
                ))
                specs.append(spec)
        per_layer_options.append(opts)
        per_layer_specs.append(specs)

    total, choice = layout.optimize_chain(per_layer_options,
                                          flexible_writes)
    plans = []
    for lyr, opts, specs, j in zip(net, per_layer_options, per_layer_specs,
                                   choice):
        plans.append(LayerPlan(
            layer=lyr, spec=specs[j], layout=opts[j].layout,
            est_seconds=opts[j].cost, traffic_bytes=0,
        ))
    return NetworkPlan(layers=plans, total_seconds=total)


# The paper's Fig. 8 network bodies, with the depthwise/grouped variants
# from its §IV scope (mobilenet-style blocks for the depthwise rows).
def resnet18_int8() -> List[ConvLayerSpec]:
    spec = []
    body = [
        (56, 3, 1, 64, 64, 1, 4),
        (56, 3, 2, 64, 128, 1, 1),
        (28, 3, 1, 128, 128, 1, 3),
        (28, 3, 2, 128, 256, 1, 1),
        (14, 3, 1, 256, 256, 1, 3),
        (14, 3, 2, 256, 512, 1, 1),
        (7, 3, 1, 512, 512, 1, 3),
    ]
    for hw_, f, s, cin, cout, g, rep in body:
        spec.extend([ConvLayerSpec(hw_, hw_, f, f, s, cin, cout, g)] * rep)
    return spec


def mobilenet_block_int8(hw_: int, cin: int, cout: int,
                         s: int = 1) -> List[ConvLayerSpec]:
    """Depthwise-separable block: depthwise 3x3 + pointwise 1x1."""
    return [
        ConvLayerSpec(hw_, hw_, 3, 3, s, cin, cin, groups=cin),
        ConvLayerSpec((hw_ - 3) // s + 1, (hw_ - 3) // s + 1, 1, 1, 1,
                      cin, cout, groups=1),
    ]


def shufflenet_stage_int8(hw_: int, c: int, groups: int = 4,
                          rep: int = 3) -> List[ConvLayerSpec]:
    """Shuffled grouped convolutions (paper §IV: 'shuffled grouped')."""
    out = []
    for _ in range(rep):
        out.append(ConvLayerSpec(hw_, hw_, 1, 1, 1, c, c, groups=groups))
        out.append(ConvLayerSpec(hw_, hw_, 3, 3, 1, c, c, groups=c))
        out.append(ConvLayerSpec(hw_ - 2, hw_ - 2, 1, 1, 1, c, c,
                                 groups=groups))
        hw_ -= 2
    return out
