"""Persistent autotuned dataflow-spec cache (PolyDL-style memoization).

``best_spec`` memoizes ``explorer.best_spec`` so the candidate space is
enumerated and ranked at most once per distinct workload, per process —
and, via a small on-disk JSON store, at most once per machine.

Key schema (``_key``): a flat string over every field that changes the
ranking, built generically from the problem registry
(``core.dataflow.register_problem``) —

    v<CACHE_VERSION>|<kind>|<key_fields...>
                    |hw=<name>|vmem=<bytes>|backend=<pallas/interpret/xla>

where ``kind`` tags the subsystem and ``key_fields`` come from its
registration:

    gemm — m|k|n|in_dtype|out_dtype|acc_dtype
    conv — full conv geometry n|ih|iw|fh|fw|s|cin|cout|dtypes (two convs
           with the same implicit-GEMM view but different filter/stride
           have different window reuse and VMEM needs); specs are
           conv-blocked ``(b_oh, bc, bk)`` (see
           ``cost_model.conv_gemm_view``)
    bin  — packed geometry m|kp|n plus the true reduction depth n_bits
           (two packings of different-K layers can share a ``kp`` but
           differ in bit-ops); ``block`` = ``(bm, bkp, bn)`` in words
    attn — bh|sq|skv|d|group|causal|window|dtype|kv_len|kv_dtype;
           ``block`` = ``(bq, bkv, d)`` over the OS(flash)/
           WS(kv-stationary) anchors; ``kv_len`` (the valid KV prefix
           of a padded cache buffer — traced lengths key as the
           ``kl-`` worst case) and ``kv_dtype`` (int8 KV cache) both
           move the banded traffic ranking

Disk location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro/autotune.json``.  Invalidation: entries embed the key
schema version, so bumping ``CACHE_VERSION`` (e.g. when the cost model
or kernel lowering changes materially) orphans every stale entry;
deleting the file forces a full re-tune.  Disk I/O is best-effort — a
read-only filesystem degrades to the in-process cache.

Corruption recovery: a cache file that fails to parse is *quarantined*
(renamed to ``autotune.json.corrupt-<n>``) so the evidence survives for
a post-mortem instead of being silently ignored or — worse — crashing
serving.  Within a parseable file every entry is validated
independently: each carries a CRC32 checksum of its spec payload, and
a malformed or checksum-mismatched entry is skipped (counted in
``stats()['entries_skipped']``) while the good entries load normally.
Saves are atomic (temp file + ``os.replace``) so a mid-write kill can
never leave a torn store — the ``autotune.save`` fault-injection site
drills exactly that (see runtime/health.py).

``CACHE_VERSION`` history: 1 = GEMM-only keys (PR 1); 2 = conv keys
added alongside the single-dispatch conv lowering (PR 2) — the conv
kernel change shifts realized traffic, so v1 entries are orphaned;
3 = binary keys added alongside the explored binary anchors (PR 3) —
the binary kernel's blocking became spec-driven, so v2 entries are
orphaned; 4 = registry-generic keys (every kind is tagged, GEMM keys
gained the ``gemm`` segment) + attention keys (PR 4); 5 = attention
keys gained the ``kv_len``/``kv_dtype`` segments alongside the banded
(block-skipping) cost model and kernel lowerings (PR 5) — v4 attention
rankings were computed under full-mask accounting, so every v4 entry
is orphaned; 6 = GEMM/conv keys gained the ``wb<bits>`` packing segment
alongside the sub-byte packed-weight datapath (PR 9) — the cost model
now charges packed-plane + outlier-sidecar bytes for weight traffic,
so v5 GEMM/conv rankings are stale and every v5 entry is orphaned.

An optional *empirical refinement* pass (``refine=True``) re-ranks the
analytical top-k by interpret-mode wall clock before caching, trading
one-off tuning time for a measured winner — the PolyDL observation that
autotuned selection over a pruned space beats a purely analytical pick.
The re-rank runs through the registration's ``measure`` hook, so every
registered subsystem (GEMM, conv, binary, attention) refines the same
way.  With ``refine=None`` (the default) the pass is enabled by setting
``REPRO_AUTOTUNE_REFINE=1`` in the environment; it changes only which
feasible spec is picked, never the numerics of the op that consumes it.
"""
from __future__ import annotations

import json
import os
import tempfile
import zlib
from typing import Any, Dict, Iterable, List, Optional

from repro.core import cost_model, explorer
from repro.core.dataflow import (
    DataflowSpec,
    Residency,
    Stationarity,
    registration_for,
)

CACHE_VERSION = 6

# Any problem type carrying a ``core.dataflow`` registration resolves
# here — deliberately not a closed Union, so onboarding a subsystem
# never edits this module.
Problem = Any

_memory: Dict[str, DataflowSpec] = {}
_disk_loaded = False
_defer_save = False  # warm() batches misses into one disk write
_stats = {
    "lookups": 0,       # best_spec calls
    "hits": 0,          # served from memory or disk
    "misses": 0,        # required an enumeration
    "enumerations": 0,  # explorer.explore invocations (incl. refinement)
    "entries_loaded": 0,        # disk entries accepted by validation
    "entries_skipped": 0,       # malformed / checksum-failed entries
    "files_quarantined": 0,     # unparseable stores moved aside
    "load_errors": 0,           # I/O or injected faults during load
    "save_errors": 0,           # I/O or injected faults during save
}


def _key(problem: Problem, hw: cost_model.HardwareSpec,
         backend: str) -> str:
    reg = registration_for(problem)
    return "|".join([
        f"v{CACHE_VERSION}", reg.kind, *reg.key_fields(problem),
        f"hw={hw.name}", f"vmem={hw.vmem_bytes}", f"backend={backend}",
    ])


def cache_path() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "autotune.json"
    )


def _spec_to_json(spec: DataflowSpec) -> dict:
    return {
        "anchor": spec.anchor.value,
        "aux": [[st.value, res.value] for st, res in spec.aux],
        "aux_priority": [st.value for st in spec.aux_priority],
        "block": list(spec.block),
        "vmem_budget": spec.vmem_budget,
    }


def _spec_from_json(d: dict) -> DataflowSpec:
    return DataflowSpec(
        anchor=Stationarity(d["anchor"]),
        aux={Stationarity(s): Residency(r) for s, r in d["aux"]},
        aux_priority=tuple(Stationarity(s) for s in d["aux_priority"]),
        block=tuple(d["block"]),
        vmem_budget=d["vmem_budget"],
    )


def _checksum(spec_json: dict) -> int:
    """CRC32 of the canonical JSON encoding of a spec payload."""
    blob = json.dumps(spec_json, sort_keys=True,
                      separators=(",", ":")).encode()
    return zlib.crc32(blob) & 0xFFFFFFFF


def _entry_to_json(spec: DataflowSpec) -> dict:
    payload = _spec_to_json(spec)
    return {"spec": payload, "sum": _checksum(payload)}


def _entry_from_json(entry: dict) -> Optional[DataflowSpec]:
    """Validate ONE disk entry; None means skip (never raise).

    Accepts only the checksummed ``{"spec": ..., "sum": ...}`` envelope
    whose CRC matches; anything else — a truncated object, a bit-flipped
    payload, a pre-checksum legacy entry — is rejected individually so
    one bad record cannot poison its neighbors.
    """
    if not isinstance(entry, dict):
        return None
    payload = entry.get("spec")
    if not isinstance(payload, dict) or "sum" not in entry:
        return None
    try:
        if int(entry["sum"]) != _checksum(payload):
            return None
        return _spec_from_json(payload)
    except (KeyError, ValueError, TypeError):
        return None


def _quarantine(path: str) -> Optional[str]:
    """Move an unreadable cache file to ``<path>.corrupt-<n>``.

    Keeps the evidence for debugging and guarantees the next save starts
    from a clean slate; returns the quarantine path (None if the rename
    itself failed, e.g. on a read-only filesystem)."""
    for n in range(100):
        target = f"{path}.corrupt-{n}"
        if not os.path.exists(target):
            break
    else:
        target = f"{path}.corrupt-overflow"
    try:
        os.replace(path, target)
    except OSError:
        return None
    _stats["files_quarantined"] += 1
    return target


def _load_disk() -> None:
    """Best-effort disk load with per-entry validation.

    Failure containment, from coarse to fine: an I/O error or injected
    ``autotune.load`` fault degrades to the in-process cache (counted,
    never raised past here); an unparseable file is quarantined to
    ``autotune.json.corrupt-<n>``; a parseable file with some malformed
    or checksum-failed entries keeps every good entry and counts the
    skips in ``stats()``.  A version mismatch is not corruption — the
    orphaned store is left in place and simply ignored.
    """
    from repro.runtime import health

    global _disk_loaded
    if _disk_loaded:
        return
    _disk_loaded = True
    path = cache_path()
    try:
        health.maybe_inject("autotune.load")
        with open(path) as f:
            raw = f.read()
    except FileNotFoundError:
        return
    except (OSError, health.SimulatedFailure):
        _stats["load_errors"] += 1
        return
    try:
        data = json.loads(raw)
        if not isinstance(data, dict):
            raise ValueError("cache root is not an object")
    except ValueError:
        _quarantine(path)
        return
    if data.get("version") != CACHE_VERSION:
        return
    entries = data.get("entries")
    if not isinstance(entries, dict):
        _quarantine(path)
        return
    for key, entry in entries.items():
        if key in _memory:
            continue
        spec = _entry_from_json(entry)
        if spec is None:
            _stats["entries_skipped"] += 1
            continue
        _memory[key] = spec
        _stats["entries_loaded"] += 1


def _save_disk() -> None:
    """Atomic, best-effort rewrite of the whole store.

    The payload is fully serialized into a temp file in the target
    directory and moved into place with ``os.replace``, so a reader can
    never observe a torn store and a mid-write kill (drilled via the
    ``autotune.save`` fault site) leaves the previous file intact.
    """
    from repro.runtime import health

    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        payload = {
            "version": CACHE_VERSION,
            "entries": {k: _entry_to_json(s) for k, s in _memory.items()},
        }
        fd, tmp = tempfile.mkstemp(
            dir=os.path.dirname(path), suffix=".tmp"
        )
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
                # the injected mid-write kill lands here: after bytes hit
                # the temp file but before the atomic rename
                health.maybe_inject("autotune.save")
            os.replace(tmp, path)
        except BaseException:
            os.unlink(tmp)
            raise
    except (OSError, health.SimulatedFailure):
        _stats["save_errors"] += 1


def refine_enabled() -> bool:
    """The ``REPRO_AUTOTUNE_REFINE=1`` env flag (ROADMAP PR-1 open item):
    opt-in empirical re-ranking of the analytical top-k on cache misses."""
    return os.environ.get("REPRO_AUTOTUNE_REFINE", "") == "1"


def best_spec(
    problem: Problem,
    hw: cost_model.HardwareSpec = cost_model.V5E,
    backend: str = "pallas",
    refine: Optional[bool] = None,
    refine_top: int = 3,
) -> DataflowSpec:
    """Cached explorer pick for ``problem`` on ``hw``/``backend``.

    Fully registry-driven: any problem type registered via
    ``core.dataflow.register_problem`` resolves here — the cache key,
    the candidate enumeration (through the generic ``explorer.explore``)
    and the optional empirical refinement all come from the problem's
    registration.  Block semantics are per-subsystem (GEMM
    ``(bm, bk, bn)``, conv ``(b_oh, bc, bk)``, binary ``(bm, bkp, bn)``
    in packed words, attention ``(bq, bkv, d)``).  ``refine=None``
    defers to the ``REPRO_AUTOTUNE_REFINE=1`` env flag (default off);
    the re-rank runs the registration's ``measure`` hook on the
    analytical top-k.
    """
    if refine is None:
        refine = refine_enabled()
    _load_disk()
    reg = registration_for(problem)
    key = _key(problem, hw, backend)
    _stats["lookups"] += 1
    spec = _memory.get(key)
    if spec is not None:
        _stats["hits"] += 1
        return spec
    _stats["misses"] += 1
    _stats["enumerations"] += 1
    ranked = explorer.explore(problem, hw, top=max(1, refine_top))
    if not ranked:
        raise ValueError(f"no feasible dataflow for {problem}")
    spec = ranked[0].spec
    if refine and reg.measure is not None and len(ranked) > 1:
        measured = reg.measure(problem, [c.spec for c in ranked],
                               interpret=True)
        spec = measured[0][0]
    _memory[key] = spec
    if not _defer_save:
        _save_disk()
    return spec


def warm(
    problems: Iterable[Problem],
    hw: cost_model.HardwareSpec = cost_model.V5E,
    backend: str = "pallas",
) -> List[DataflowSpec]:
    """Pre-populate the cache for a known set of hot workloads (any
    registered problem types — GEMM, conv, binary, attention — mix
    freely).

    Misses are batched into a single disk write at the end instead of
    one full-store rewrite per problem.  Problems with no feasible
    dataflow (e.g. a conv whose image exceeds VMEM) are skipped rather
    than aborting the warm-up — the op will raise at call time instead.
    """
    global _defer_save
    before = _stats["misses"]
    _defer_save = True
    specs = []
    try:
        for p in problems:
            try:
                specs.append(best_spec(p, hw, backend))
            except ValueError:
                continue
    finally:
        _defer_save = False
    if _stats["misses"] > before:
        _save_disk()
    return specs


def stats() -> Dict[str, int]:
    return dict(_stats)


def reset_stats() -> None:
    for k in _stats:
        _stats[k] = 0


def clear(disk: bool = False) -> None:
    """Drop the in-process cache; with ``disk=True`` also the JSON store."""
    global _disk_loaded
    _memory.clear()
    _disk_loaded = False
    if disk:
        try:
            os.unlink(cache_path())
        except OSError:
            pass
