"""Persistent autotuned dataflow-spec cache (PolyDL-style memoization).

``best_spec`` memoizes ``explorer.best_spec`` so the candidate space is
enumerated and ranked at most once per distinct workload, per process —
and, via a small on-disk JSON store, at most once per machine.

Key schema (``_key``): a flat string over every field that changes the
ranking.  GEMM problems —

    v<CACHE_VERSION>|m|k|n|in_dtype|out_dtype|acc_dtype
                    |hw=<name>|vmem=<bytes>|backend=<pallas/interpret/xla>

Conv problems (``ConvProblem``) key on the full conv geometry instead of
the implicit-GEMM collapse (two convs with the same GEMM view but
different filter/stride have different window reuse and VMEM needs) —

    v<CACHE_VERSION>|conv|n|ih|iw|fh|fw|s|cin|cout|in_dtype|out_dtype
                    |hw=<name>|vmem=<bytes>|backend=<...>

and resolve through ``explorer.explore_conv`` (conv-blocked specs whose
``block`` is ``(b_oh, bc, bk)``; see ``cost_model.conv_gemm_view``).

Binary problems (``BinaryProblem``) key on the packed geometry plus the
true reduction depth (two packings of different-K layers can share a
``kp`` but differ in bit-ops) —

    v<CACHE_VERSION>|bin|m|kp|n|n_bits|out_dtype
                    |hw=<name>|vmem=<bytes>|backend=<...>

and resolve through ``explorer.explore_binary`` (``block`` =
``(bm, bkp, bn)`` with the reduction blocked in packed uint32 words).

Disk location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro/autotune.json``.  Invalidation: entries embed the key
schema version, so bumping ``CACHE_VERSION`` (e.g. when the cost model
or kernel lowering changes materially) orphans every stale entry;
deleting the file forces a full re-tune.  Disk I/O is best-effort — a
read-only filesystem degrades to the in-process cache.

``CACHE_VERSION`` history: 1 = GEMM-only keys (PR 1); 2 = conv keys
added alongside the single-dispatch conv lowering (PR 2) — the conv
kernel change shifts realized traffic, so v1 entries are orphaned;
3 = binary keys added alongside the explored binary anchors (PR 3) —
the binary kernel's blocking became spec-driven, so v2 entries are
orphaned.

An optional *empirical refinement* pass (``refine=True``) re-ranks the
analytical top-k by interpret-mode wall clock (``explorer.empirical_rank``)
before caching, trading one-off tuning time for a measured winner — the
PolyDL observation that autotuned selection over a pruned space beats a
purely analytical pick.  With ``refine=None`` (the default) the pass is
enabled by setting ``REPRO_AUTOTUNE_REFINE=1`` in the environment; it
changes only which feasible spec is picked, never the numerics of the
op that consumes it.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Dict, Iterable, List, Optional, Union

from repro.core import cost_model, explorer
from repro.core.dataflow import (
    BinaryProblem,
    ConvProblem,
    DataflowSpec,
    GemmProblem,
    Residency,
    Stationarity,
)

CACHE_VERSION = 3

Problem = Union[GemmProblem, ConvProblem, BinaryProblem]

_memory: Dict[str, DataflowSpec] = {}
_disk_loaded = False
_defer_save = False  # warm() batches misses into one disk write
_stats = {
    "lookups": 0,       # best_spec calls
    "hits": 0,          # served from memory or disk
    "misses": 0,        # required an enumeration
    "enumerations": 0,  # explorer.explore invocations (incl. refinement)
}


def _key(problem: Problem, hw: cost_model.HardwareSpec,
         backend: str) -> str:
    if isinstance(problem, ConvProblem):
        head = [
            "conv", str(problem.n), str(problem.ih), str(problem.iw),
            str(problem.fh), str(problem.fw), str(problem.s),
            str(problem.cin), str(problem.cout),
            problem.in_dtype, problem.out_dtype,
        ]
    elif isinstance(problem, BinaryProblem):
        head = [
            "bin", str(problem.m), str(problem.kp), str(problem.n),
            str(problem.n_bits), problem.out_dtype,
        ]
    else:
        head = [
            str(problem.m), str(problem.k), str(problem.n),
            problem.in_dtype, problem.out_dtype, problem.acc_dtype,
        ]
    return "|".join([
        f"v{CACHE_VERSION}", *head,
        f"hw={hw.name}", f"vmem={hw.vmem_bytes}", f"backend={backend}",
    ])


def cache_path() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "autotune.json"
    )


def _spec_to_json(spec: DataflowSpec) -> dict:
    return {
        "anchor": spec.anchor.value,
        "aux": [[st.value, res.value] for st, res in spec.aux],
        "aux_priority": [st.value for st in spec.aux_priority],
        "block": list(spec.block),
        "vmem_budget": spec.vmem_budget,
    }


def _spec_from_json(d: dict) -> DataflowSpec:
    return DataflowSpec(
        anchor=Stationarity(d["anchor"]),
        aux={Stationarity(s): Residency(r) for s, r in d["aux"]},
        aux_priority=tuple(Stationarity(s) for s in d["aux_priority"]),
        block=tuple(d["block"]),
        vmem_budget=d["vmem_budget"],
    )


def _load_disk() -> None:
    global _disk_loaded
    if _disk_loaded:
        return
    _disk_loaded = True
    try:
        with open(cache_path()) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return
    if data.get("version") != CACHE_VERSION:
        return
    for key, entry in data.get("entries", {}).items():
        if key not in _memory:
            try:
                _memory[key] = _spec_from_json(entry)
            except (KeyError, ValueError, TypeError):
                continue


def _save_disk() -> None:
    """Atomic, best-effort rewrite of the whole store."""
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        payload = {
            "version": CACHE_VERSION,
            "entries": {k: _spec_to_json(s) for k, s in _memory.items()},
        }
        fd, tmp = tempfile.mkstemp(
            dir=os.path.dirname(path), suffix=".tmp"
        )
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            os.unlink(tmp)
            raise
    except OSError:
        pass


def refine_enabled() -> bool:
    """The ``REPRO_AUTOTUNE_REFINE=1`` env flag (ROADMAP PR-1 open item):
    opt-in empirical re-ranking of the analytical top-k on cache misses."""
    return os.environ.get("REPRO_AUTOTUNE_REFINE", "") == "1"


def best_spec(
    problem: Problem,
    hw: cost_model.HardwareSpec = cost_model.V5E,
    backend: str = "pallas",
    refine: Optional[bool] = None,
    refine_top: int = 3,
) -> DataflowSpec:
    """Cached explorer pick for ``problem`` on ``hw``/``backend``.

    ``GemmProblem``s rank via ``explorer.explore``; ``ConvProblem``s via
    ``explorer.explore_conv`` and return *conv-blocked* specs (``block``
    = ``(b_oh, bc, bk)``); ``BinaryProblem``s via
    ``explorer.explore_binary`` (``block`` = ``(bm, bkp, bn)`` in packed
    words).  Empirical refinement applies to GEMM problems only (the
    interpret-mode re-rank runs ``ops.matmul``); ``refine=None`` defers
    to the ``REPRO_AUTOTUNE_REFINE=1`` env flag (default off).
    """
    if refine is None:
        refine = refine_enabled()
    _load_disk()
    key = _key(problem, hw, backend)
    _stats["lookups"] += 1
    spec = _memory.get(key)
    if spec is not None:
        _stats["hits"] += 1
        return spec
    _stats["misses"] += 1
    _stats["enumerations"] += 1
    is_conv = isinstance(problem, ConvProblem)
    is_binary = isinstance(problem, BinaryProblem)
    explore_fn = (explorer.explore_conv if is_conv
                  else explorer.explore_binary if is_binary
                  else explorer.explore)
    ranked = explore_fn(problem, hw, top=max(1, refine_top))
    if not ranked:
        raise ValueError(f"no feasible dataflow for {problem}")
    spec = ranked[0].spec
    if refine and not (is_conv or is_binary) and len(ranked) > 1:
        measured = explorer.empirical_rank(
            problem, [c.spec for c in ranked], interpret=True
        )
        spec = measured[0][0]
    _memory[key] = spec
    if not _defer_save:
        _save_disk()
    return spec


def warm(
    problems: Iterable[Problem],
    hw: cost_model.HardwareSpec = cost_model.V5E,
    backend: str = "pallas",
) -> List[DataflowSpec]:
    """Pre-populate the cache for a known set of hot workloads (GEMM,
    conv and binary problems mix freely).

    Misses are batched into a single disk write at the end instead of
    one full-store rewrite per problem.  Problems with no feasible
    dataflow (e.g. a conv whose image exceeds VMEM) are skipped rather
    than aborting the warm-up — the op will raise at call time instead.
    """
    global _defer_save
    before = _stats["misses"]
    _defer_save = True
    specs = []
    try:
        for p in problems:
            try:
                specs.append(best_spec(p, hw, backend))
            except ValueError:
                continue
    finally:
        _defer_save = False
    if _stats["misses"] > before:
        _save_disk()
    return specs


def stats() -> Dict[str, int]:
    return dict(_stats)


def reset_stats() -> None:
    for k in _stats:
        _stats[k] = 0


def clear(disk: bool = False) -> None:
    """Drop the in-process cache; with ``disk=True`` also the JSON store."""
    global _disk_loaded
    _memory.clear()
    _disk_loaded = False
    if disk:
        try:
            os.unlink(cache_path())
        except OSError:
            pass
