"""Heuristic-guided dataflow exploration (paper §IV).

Enumerates (anchor, auxiliary residency, block shape) candidates for a
workload, prunes with the Table-I-derived observations, ranks with the
TPU traffic model, and optionally validates empirically (interpret-mode
execution or wall-clock on real hardware).

``explore`` is generic: it dispatches through the problem registry
(``core.dataflow.register_problem``) to the per-subsystem candidate
enumerator, so GEMM, conv, binary and attention problems all rank
through one pipeline.  This module registers the four built-in
subsystems at import time — onboarding a new one is a single
``register_problem`` call (enumerator + cost hooks), no edits to
``explore`` or ``core.autotune``.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import cost_model
from repro.core.dataflow import (
    AttentionProblem,
    BinaryProblem,
    ConvProblem,
    DataflowSpec,
    GemmProblem,
    ProblemRegistration,
    Residency,
    Stationarity,
    register_problem,
    registration_for,
    IS,
    OS,
    WS,
)


@dataclasses.dataclass(frozen=True)
class Candidate:
    spec: DataflowSpec
    est_seconds: float
    traffic_bytes: int
    feasible: bool

    @property
    def name(self) -> str:
        return self.spec.name


def _block_options(dim: int, hw: cost_model.HardwareSpec) -> List[int]:
    """Tile-aligned candidate block sizes for one GEMM dimension.

    Blocks are multiples of the hardware lane width, clamped to the
    lane-padded dimension so a candidate can never exceed the (padded)
    extent it tiles — e.g. dim=300 pads to 384 and admits {128, 256} but
    not 512, which would fail ``matmul_df``'s tiling check after the
    caller pads the operand to a block multiple.
    """
    lane = hw.lane
    padded = -(-max(dim, 1) // lane) * lane
    opts = [b for b in (lane, 2 * lane, 4 * lane) if b <= padded]
    return opts or [lane]


def enumerate_candidates(
    problem: GemmProblem,
    hw: cost_model.HardwareSpec = cost_model.V5E,
    anchors: Sequence[Stationarity] = (OS, WS, IS),
    prune_with_observations: bool = True,
) -> List[Candidate]:
    """All realizable extended dataflows for ``problem``.

    With ``prune_with_observations`` the paper's heuristics cut the space:
      Obs 1: drop WS-anchored extended variants (gain least).
      Obs 4/5: under IS/WS, only output-aux variants are kept.
    """
    out: List[Candidate] = []
    aux_opts = {
        OS: [  # anchor OS: aux over inputs/weights
            {},
            {WS: Residency.STRIPE},
            {WS: Residency.WHOLE},
            {IS: Residency.STRIPE},
            {WS: Residency.WHOLE, IS: Residency.STRIPE},
        ],
        WS: [{}, {OS: Residency.STRIPE}, {IS: Residency.STRIPE}],
        IS: [{}, {OS: Residency.STRIPE}, {WS: Residency.WHOLE}],
    }
    for anchor in anchors:
        variants = aux_opts[anchor]
        if prune_with_observations:
            if anchor == WS:
                variants = [{}, {OS: Residency.STRIPE}]  # Obs 1 + Obs 5
            if anchor == IS:
                variants = [{}, {OS: Residency.STRIPE}]  # Obs 4
        for aux in variants:
            pri = tuple(aux.keys())
            for bm, bk, bn in itertools.product(
                _block_options(problem.m, hw),
                _block_options(problem.k, hw),
                _block_options(problem.n, hw),
            ):
                spec = DataflowSpec(
                    anchor=anchor, aux=aux, aux_priority=pri,
                    block=(bm, bk, bn), vmem_budget=hw.vmem_bytes,
                )
                t = cost_model.gemm_traffic(problem, spec)
                if not t.feasible:
                    continue
                est = cost_model.gemm_time_estimate(problem, spec, hw)
                out.append(Candidate(spec, est, t.total, t.feasible))
    return out


def explore(
    problem,
    hw: cost_model.HardwareSpec = cost_model.V5E,
    top: int = 5,
    **kw,
) -> List[Candidate]:
    """Ranked candidates (best first) for ANY registered problem type.

    Dispatches through the problem registry to the subsystem's candidate
    enumerator (``enumerate_candidates`` for GEMM,
    ``enumerate_conv_candidates`` for conv, ...); extra keywords are
    forwarded to it (e.g. ``anchors=...``,
    ``prune_with_observations=...``).
    """
    cands = registration_for(problem).enumerate(problem, hw, **kw)
    return sorted(cands, key=lambda c: (c.est_seconds, c.traffic_bytes))[:top]


def best_spec(
    problem, hw: cost_model.HardwareSpec = cost_model.V5E
) -> DataflowSpec:
    ranked = explore(problem, hw, top=1)
    if not ranked:
        raise ValueError(f"no feasible dataflow for {problem}")
    return ranked[0].spec


# ---------------------------------------------------------------------------
# Conv candidate space (the shapes kernels/conv2d_df actually realizes).
# ---------------------------------------------------------------------------
def _b_oh_options(oh: int) -> List[int]:
    """Output row-tile heights, clamped to the output height."""
    return [b for b in (4, 8, 16) if b <= oh] or [max(1, oh)]


def enumerate_conv_candidates(
    problem: ConvProblem,
    hw: cost_model.HardwareSpec = cost_model.V5E,
    anchors: Sequence[Stationarity] = (OS, WS, IS),
) -> List[Candidate]:
    """All conv dataflows realizable by ``kernels.conv2d_df``.

    Per anchor the kernel admits exactly one residency shape — the input
    image is whole-resident under OS (fetched once per batch element),
    anchored under IS, and re-streamed per cout tile under WS — so the
    space is anchors x conv block choices ``(b_oh, bc, bk)`` clamped to
    the (lane-padded) problem dims.  Specs are *conv-blocked*; ranking
    uses ``cost_model.conv_time_estimate`` (implicit-GEMM traffic +
    realized-kernel VMEM feasibility).
    """
    aux_for = {
        OS: {IS: Residency.WHOLE},
        WS: {},
        IS: {},
    }
    out: List[Candidate] = []
    for anchor in anchors:
        aux = aux_for[anchor]
        pri = tuple(aux.keys())
        for b_oh, bc, bk in itertools.product(
            _b_oh_options(problem.oh),
            _block_options(problem.cin, hw),
            _block_options(problem.cout, hw),
        ):
            spec = DataflowSpec(
                anchor=anchor, aux=aux, aux_priority=pri,
                block=(b_oh, bc, bk), vmem_budget=hw.vmem_bytes,
            )
            if cost_model.conv_vmem_footprint(problem, spec) > hw.vmem_bytes:
                continue
            t = cost_model.conv_traffic(
                problem, cost_model.conv_gemm_view(problem, spec))
            est = max(problem.flops / hw.peak_flops_for(problem.in_dtype),
                      t.total / hw.hbm_bw)  # feasible: no infinity penalty
            out.append(Candidate(spec, est, t.total, True))
    return out


def explore_conv(
    problem: ConvProblem,
    hw: cost_model.HardwareSpec = cost_model.V5E,
    top: int = 5,
    **kw,
) -> List[Candidate]:
    """Ranked conv-blocked candidates (alias of the generic ``explore``)."""
    return explore(problem, hw, top, **kw)


# ---------------------------------------------------------------------------
# Binary candidate space (the shapes kernels/binary_mm actually realizes).
# ---------------------------------------------------------------------------
def _bkp_options(kp: int) -> List[int]:
    """Packed-word reduction-panel widths, clamped to the packed depth."""
    return [w for w in (2, 4, 8, 16) if w <= max(kp, 1)] or [1]


def enumerate_binary_candidates(
    problem: BinaryProblem,
    hw: cost_model.HardwareSpec = cost_model.V5E,
    anchors: Sequence[Stationarity] = (OS, WS, IS),
) -> List[Candidate]:
    """All binary dataflows realizable by ``kernels.binary_mm``.

    The kernel lowers the three basic anchors as one ``pallas_call`` each
    with the packed-word reduction innermost, so the space is anchors x
    ``(bm, bkp, bn)`` blocks — ``bkp`` counts uint32 words, ``bm``/``bn``
    are lane-aligned like the GEMM explorer.  Ranking uses
    ``cost_model.binary_time_estimate`` (bit-op compute at the VPU
    xor+popcount rate, packed-word byte traffic).
    """
    out: List[Candidate] = []
    for anchor in anchors:
        for bm, bkp, bn in itertools.product(
            _block_options(problem.m, hw),
            _bkp_options(problem.kp),
            _block_options(problem.n, hw),
        ):
            spec = DataflowSpec.basic(
                anchor, block=(bm, bkp, bn), vmem_budget=hw.vmem_bytes,
            )
            t = cost_model.binary_traffic(problem, spec)
            if not t.feasible:
                continue
            est = cost_model.binary_time_estimate(problem, spec, hw)
            out.append(Candidate(spec, est, t.total, True))
    return out


def explore_binary(
    problem: BinaryProblem,
    hw: cost_model.HardwareSpec = cost_model.V5E,
    top: int = 5,
    **kw,
) -> List[Candidate]:
    """Ranked binary candidates (alias of the generic ``explore``)."""
    return explore(problem, hw, top, **kw)


# ---------------------------------------------------------------------------
# Attention candidate space (kernels/attention_df's realizable anchors).
# ---------------------------------------------------------------------------
def _attn_block_options(s: int) -> List[int]:
    """q/kv block-length candidates clamped to the (8-padded) sequence.

    ``s == 1`` (the decode q side) admits only the single-row block —
    the ``ops.attention`` fast path skips q blocking entirely there.
    """
    if s <= 1:
        return [1]
    padded = -(-s // 8) * 8
    opts = [b for b in (128, 256, 512) if b <= padded]
    return opts or [padded]


def _attn_kv_block_options(problem: AttentionProblem) -> List[int]:
    """KV block-length candidates, window- and valid-length-aware.

    Beyond the generic lane-friendly sizes this adds (a) blocks snapped
    to the sliding window (a ``bkv`` near ``window`` minimizes the
    partially-masked fraction of each visited band) and (b) blocks
    snapped to the valid KV prefix when attending over a mostly-empty
    cache buffer (``kv_len << skv``).  All candidates stay 8-aligned
    and within the padded sequence; the banded cost model ranks them.
    """
    opts = set(_attn_block_options(problem.skv))
    padded = -(-max(problem.skv, 1) // 8) * 8
    if problem.window is not None:
        opts.add(min(padded, max(8, -(-problem.window // 8) * 8)))
    if problem.kv_len is not None and problem.kv_len < problem.skv:
        opts.update(_attn_block_options(problem.kv_len))
        opts.add(min(padded, max(8, -(-problem.kv_len // 8) * 8)))
    return sorted(opts)


def enumerate_attention_candidates(
    problem: AttentionProblem,
    hw: cost_model.HardwareSpec = cost_model.V5E,
    anchors: Sequence[Stationarity] = (OS, WS),
) -> List[Candidate]:
    """All attention dataflows realizable by ``kernels.attention_df``.

    The kernel admits two anchors — OS (flash: q-row output tile
    anchored, online-softmax state in VMEM scratch) and WS
    (kv-stationary: each KV block fetched once, state round-tripping
    HBM) — so the space is anchors x ``(bq, bkv)`` blocks with a
    VMEM-fit filter; KV block options are window- and valid-length-
    aware (``_attn_kv_block_options``) and ranking runs the *banded*
    cost model, so KV blocks the kernel skips are never charged.
    Specs carry ``block = (bq, bkv, d)``.
    """
    out: List[Candidate] = []
    for anchor in anchors:
        for bq, bkv in itertools.product(
            _attn_block_options(problem.sq),
            _attn_kv_block_options(problem),
        ):
            spec = DataflowSpec.basic(
                anchor, block=(bq, bkv, problem.d),
                vmem_budget=hw.vmem_bytes,
            )
            t = cost_model.attention_traffic(problem, spec)
            if not t.feasible:
                continue
            est = cost_model.attention_time_estimate(problem, spec, hw)
            out.append(Candidate(spec, est, t.total, True))
    return out


def measure(
    fn: Callable, args: Tuple, iters: int = 5, warmup: int = 2
) -> float:
    """Empirical wall-clock per call (seconds) — used by benchmarks."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def empirical_rank(
    problem: GemmProblem,
    specs: Sequence[DataflowSpec],
    interpret: bool = True,
    seed: int = 0,
) -> List[Tuple[DataflowSpec, float]]:
    """Execute each spec (interpret mode) and rank by wall-clock.

    Interpret-mode timing is a *correctness-preserving proxy* — it orders
    dataflows by grid-step and data-movement counts, not MXU throughput;
    the analytical model remains the primary ranking signal off-TPU.

    Operands are drawn in ``problem.in_dtype`` so int8/bf16 rankings
    measure the dtype they claim to.
    """
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    dtype = jnp.dtype(problem.in_dtype)
    if jnp.issubdtype(dtype, jnp.integer):
        a = jnp.asarray(
            rng.integers(-127, 128, size=(problem.m, problem.k)), dtype)
        b = jnp.asarray(
            rng.integers(-127, 128, size=(problem.k, problem.n)), dtype)
    else:
        a = jnp.asarray(rng.normal(size=(problem.m, problem.k)), dtype)
        b = jnp.asarray(rng.normal(size=(problem.k, problem.n)), dtype)
    from repro.kernels import ops

    results = []
    for spec in specs:
        fn = lambda x, y, s=spec: ops.matmul(
            x, y, spec=s, backend="interpret" if interpret else None
        )
        results.append((spec, measure(fn, (a, b), iters=3, warmup=1)))
    return sorted(results, key=lambda sr: sr[1])


# ---------------------------------------------------------------------------
# Per-subsystem empirical measure hooks (autotune's refine=True re-rank).
# All four draw deterministic operands in the problem's dtype, execute
# each candidate spec through the public op in interpret mode, and
# return [(spec, seconds)] sorted fastest-first — ranking-only, never
# touching the numerics of the op that consumes the winning spec.
# ---------------------------------------------------------------------------
def _late_bound(name: str) -> Callable:
    """A measure hook resolving ``name`` through module globals at call
    time, so tests can monkeypatch ``empirical_rank``/``_measure_*`` and
    have the registrations (captured at import) honor the patch."""
    def hook(problem, specs, interpret: bool = True):
        return globals()[name](problem, specs, interpret=interpret)
    return hook


def _measure_conv(problem: ConvProblem, specs: Sequence[DataflowSpec],
                  interpret: bool = True) -> List[Tuple[DataflowSpec, float]]:
    import jax.numpy as jnp
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    dtype = jnp.dtype(problem.in_dtype)
    xs = (problem.n, problem.ih, problem.iw, problem.cin)
    ws = (problem.fh, problem.fw, problem.cin, problem.cout)
    if jnp.issubdtype(dtype, jnp.integer):
        x = jnp.asarray(rng.integers(-8, 9, size=xs), dtype)
        w = jnp.asarray(rng.integers(-8, 9, size=ws), dtype)
    else:
        x = jnp.asarray(rng.normal(size=xs), dtype)
        w = jnp.asarray(rng.normal(size=ws), dtype)
    backend = "interpret" if interpret else None
    results = []
    for spec in specs:
        b_oh, bc, bk = spec.block   # conv-blocked (see conv_gemm_view)
        fn = lambda a, b, s=spec, t=(b_oh, bc, bk): ops.conv2d(
            a, b, stride=problem.s, spec=s, b_oh=t[0], bc=t[1], bk=t[2],
            backend=backend,
        )
        results.append((spec, measure(fn, (x, w), iters=3, warmup=1)))
    return sorted(results, key=lambda sr: sr[1])


def _measure_binary(problem: BinaryProblem, specs: Sequence[DataflowSpec],
                    interpret: bool = True
                    ) -> List[Tuple[DataflowSpec, float]]:
    import jax.numpy as jnp
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    a = jnp.asarray(
        rng.integers(0, 2 ** 32, size=(problem.m, problem.kp),
                     dtype=np.uint32))
    b = jnp.asarray(
        rng.integers(0, 2 ** 32, size=(problem.kp, problem.n),
                     dtype=np.uint32))
    backend = "interpret" if interpret else None
    results = []
    for spec in specs:
        fn = lambda x, y, s=spec: ops.binary_matmul(
            x, y, n_bits=problem.n_bits, spec=s, backend=backend)
        results.append((spec, measure(fn, (a, b), iters=3, warmup=1)))
    return sorted(results, key=lambda sr: sr[1])


def _measure_attention(problem: AttentionProblem,
                       specs: Sequence[DataflowSpec],
                       interpret: bool = True
                       ) -> List[Tuple[DataflowSpec, float]]:
    import jax.numpy as jnp
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    dtype = jnp.dtype(problem.dtype)
    q = jnp.asarray(
        rng.normal(size=(1, problem.bh, problem.sq, problem.d)), dtype)
    kv_shape = (1, problem.bh_kv, problem.skv, problem.d)
    kw = {}
    if problem.kv_elem_dtype == "int8":
        k = jnp.asarray(rng.integers(-127, 128, size=kv_shape), jnp.int8)
        v = jnp.asarray(rng.integers(-127, 128, size=kv_shape), jnp.int8)
        sc_shape = kv_shape[:-1] + (1,)
        kw["k_scale"] = jnp.full(sc_shape, 1 / 127, jnp.float32)
        kw["v_scale"] = jnp.full(sc_shape, 1 / 127, jnp.float32)
    else:
        k = jnp.asarray(rng.normal(size=kv_shape),
                        jnp.dtype(problem.kv_elem_dtype))
        v = jnp.asarray(rng.normal(size=kv_shape),
                        jnp.dtype(problem.kv_elem_dtype))
    if problem.kv_len is not None:
        kw["kv_len"] = jnp.asarray(problem.kv_len, jnp.int32)
    backend = "interpret" if interpret else None
    results = []
    for spec in specs:
        fn = lambda qq, kk, vv, s=spec: ops.attention(
            qq, kk, vv, causal=problem.causal, window=problem.window,
            spec=s, group=problem.group, backend=backend, **kw)
        results.append((spec, measure(fn, (q, k, v), iters=3, warmup=1)))
    return sorted(results, key=lambda sr: sr[1])


# ---------------------------------------------------------------------------
# Built-in subsystem registrations.  Everything ``autotune.best_spec``,
# ``warm`` and the generic ``explore`` need to serve a problem type lives
# in its registration row — adding a subsystem never edits their code.
# ---------------------------------------------------------------------------
register_problem(ProblemRegistration(
    kind="gemm",
    problem_cls=GemmProblem,
    key_fields=lambda p: (str(p.m), str(p.k), str(p.n),
                          p.in_dtype, p.out_dtype, p.acc_dtype,
                          "wb-" if p.weight_bits is None
                          else f"wb{p.weight_bits}"),
    enumerate=enumerate_candidates,
    time_estimate=cost_model.gemm_time_estimate,
    vmem_footprint=cost_model.gemm_vmem_footprint,
    measure=_late_bound("empirical_rank"),
))

register_problem(ProblemRegistration(
    kind="conv",
    problem_cls=ConvProblem,
    key_fields=lambda p: (str(p.n), str(p.ih), str(p.iw), str(p.fh),
                          str(p.fw), str(p.s), str(p.cin), str(p.cout),
                          p.in_dtype, p.out_dtype,
                          "wb-" if p.weight_bits is None
                          else f"wb{p.weight_bits}"),
    enumerate=enumerate_conv_candidates,
    time_estimate=cost_model.conv_time_estimate,
    vmem_footprint=cost_model.conv_vmem_footprint,
    measure=_late_bound("_measure_conv"),
))

register_problem(ProblemRegistration(
    kind="bin",
    problem_cls=BinaryProblem,
    key_fields=lambda p: (str(p.m), str(p.kp), str(p.n), str(p.n_bits),
                          p.out_dtype),
    enumerate=enumerate_binary_candidates,
    time_estimate=cost_model.binary_time_estimate,
    vmem_footprint=lambda p, spec:
        cost_model.gemm_vmem_footprint(p.as_gemm(), spec),
    measure=_late_bound("_measure_binary"),
))

register_problem(ProblemRegistration(
    kind="attn",
    problem_cls=AttentionProblem,
    # v5 appended the valid-KV-prefix (kl*) and KV-cache-dtype (kd*)
    # segments: both move the banded traffic ranking (kl bounds the
    # visited blocks, kd the KV byte stream + scale reads).  PR 8
    # appends the ragged-rows segment (r*): a per-row-banded decode
    # step (each batch row carrying its own traced kv_len) lowers
    # with per-row index-map clamps, so its spec must not share a
    # cache row with the uniform batch of the same folded shape.
    key_fields=lambda p: (str(p.bh), str(p.sq), str(p.skv), str(p.d),
                          str(p.group), f"c{int(p.causal)}",
                          "w-" if p.window is None else f"w{p.window}",
                          p.dtype,
                          "kl-" if p.kv_len is None else f"kl{p.kv_len}",
                          "kd-" if p.kv_dtype is None
                          else f"kd{p.kv_dtype}",
                          f"r{p.rows}"),
    enumerate=enumerate_attention_candidates,
    time_estimate=cost_model.attention_time_estimate,
    vmem_footprint=cost_model.attention_vmem_footprint,
    measure=_late_bound("_measure_attention"),
))
