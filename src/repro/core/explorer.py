"""Heuristic-guided dataflow exploration (paper §IV).

Enumerates (anchor, auxiliary residency, block shape) candidates for a
workload, prunes with the Table-I-derived observations, ranks with the
TPU traffic model, and optionally validates empirically (interpret-mode
execution or wall-clock on real hardware).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import cost_model
from repro.core.dataflow import (
    ConvProblem,
    DataflowSpec,
    GemmProblem,
    Residency,
    Stationarity,
    IS,
    OS,
    WS,
)


@dataclasses.dataclass(frozen=True)
class Candidate:
    spec: DataflowSpec
    est_seconds: float
    traffic_bytes: int
    feasible: bool

    @property
    def name(self) -> str:
        return self.spec.name


def _block_options(dim: int, hw: cost_model.HardwareSpec) -> List[int]:
    opts = [b for b in (128, 256, 512) if b <= max(dim, 128)]
    return opts or [128]


def enumerate_candidates(
    problem: GemmProblem,
    hw: cost_model.HardwareSpec = cost_model.V5E,
    anchors: Sequence[Stationarity] = (OS, WS, IS),
    prune_with_observations: bool = True,
) -> List[Candidate]:
    """All realizable extended dataflows for ``problem``.

    With ``prune_with_observations`` the paper's heuristics cut the space:
      Obs 1: drop WS-anchored extended variants (gain least).
      Obs 4/5: under IS/WS, only output-aux variants are kept.
    """
    out: List[Candidate] = []
    aux_opts = {
        OS: [  # anchor OS: aux over inputs/weights
            {},
            {WS: Residency.STRIPE},
            {WS: Residency.WHOLE},
            {IS: Residency.STRIPE},
            {WS: Residency.WHOLE, IS: Residency.STRIPE},
        ],
        WS: [{}, {OS: Residency.STRIPE}, {IS: Residency.STRIPE}],
        IS: [{}, {OS: Residency.STRIPE}, {WS: Residency.WHOLE}],
    }
    for anchor in anchors:
        variants = aux_opts[anchor]
        if prune_with_observations:
            if anchor == WS:
                variants = [{}, {OS: Residency.STRIPE}]  # Obs 1 + Obs 5
            if anchor == IS:
                variants = [{}, {OS: Residency.STRIPE}]  # Obs 4
        for aux in variants:
            pri = tuple(aux.keys())
            for bm, bk, bn in itertools.product(
                _block_options(problem.m, hw),
                _block_options(problem.k, hw),
                _block_options(problem.n, hw),
            ):
                spec = DataflowSpec(
                    anchor=anchor, aux=aux, aux_priority=pri,
                    block=(bm, bk, bn), vmem_budget=hw.vmem_bytes,
                )
                t = cost_model.gemm_traffic(problem, spec)
                if not t.feasible:
                    continue
                est = cost_model.gemm_time_estimate(problem, spec, hw)
                out.append(Candidate(spec, est, t.total, t.feasible))
    return out


def explore(
    problem: GemmProblem,
    hw: cost_model.HardwareSpec = cost_model.V5E,
    top: int = 5,
    **kw,
) -> List[Candidate]:
    """Ranked candidates (best first)."""
    cands = enumerate_candidates(problem, hw, **kw)
    return sorted(cands, key=lambda c: (c.est_seconds, c.traffic_bytes))[:top]


def best_spec(
    problem: GemmProblem, hw: cost_model.HardwareSpec = cost_model.V5E
) -> DataflowSpec:
    ranked = explore(problem, hw, top=1)
    if not ranked:
        raise ValueError(f"no feasible dataflow for {problem}")
    return ranked[0].spec


def measure(
    fn: Callable, args: Tuple, iters: int = 5, warmup: int = 2
) -> float:
    """Empirical wall-clock per call (seconds) — used by benchmarks."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def empirical_rank(
    problem: GemmProblem,
    specs: Sequence[DataflowSpec],
    interpret: bool = True,
    seed: int = 0,
) -> List[Tuple[DataflowSpec, float]]:
    """Execute each spec (interpret mode) and rank by wall-clock.

    Interpret-mode timing is a *correctness-preserving proxy* — it orders
    dataflows by grid-step and data-movement counts, not MXU throughput;
    the analytical model remains the primary ranking signal off-TPU.
    """
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(problem.m, problem.k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(problem.k, problem.n)), jnp.float32)
    from repro.kernels import ops

    results = []
    for spec in specs:
        fn = lambda x, y, s=spec: ops.matmul(
            x, y, spec=s, backend="interpret" if interpret else None
        )
        results.append((spec, measure(fn, (a, b), iters=3, warmup=1)))
    return sorted(results, key=lambda sr: sr[1])
