"""Heuristic-guided dataflow exploration (paper §IV).

Enumerates (anchor, auxiliary residency, block shape) candidates for a
workload, prunes with the Table-I-derived observations, ranks with the
TPU traffic model, and optionally validates empirically (interpret-mode
execution or wall-clock on real hardware).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import cost_model
from repro.core.dataflow import (
    BinaryProblem,
    ConvProblem,
    DataflowSpec,
    GemmProblem,
    Residency,
    Stationarity,
    IS,
    OS,
    WS,
)


@dataclasses.dataclass(frozen=True)
class Candidate:
    spec: DataflowSpec
    est_seconds: float
    traffic_bytes: int
    feasible: bool

    @property
    def name(self) -> str:
        return self.spec.name


def _block_options(dim: int, hw: cost_model.HardwareSpec) -> List[int]:
    """Tile-aligned candidate block sizes for one GEMM dimension.

    Blocks are multiples of the hardware lane width, clamped to the
    lane-padded dimension so a candidate can never exceed the (padded)
    extent it tiles — e.g. dim=300 pads to 384 and admits {128, 256} but
    not 512, which would fail ``matmul_df``'s tiling check after the
    caller pads the operand to a block multiple.
    """
    lane = hw.lane
    padded = -(-max(dim, 1) // lane) * lane
    opts = [b for b in (lane, 2 * lane, 4 * lane) if b <= padded]
    return opts or [lane]


def enumerate_candidates(
    problem: GemmProblem,
    hw: cost_model.HardwareSpec = cost_model.V5E,
    anchors: Sequence[Stationarity] = (OS, WS, IS),
    prune_with_observations: bool = True,
) -> List[Candidate]:
    """All realizable extended dataflows for ``problem``.

    With ``prune_with_observations`` the paper's heuristics cut the space:
      Obs 1: drop WS-anchored extended variants (gain least).
      Obs 4/5: under IS/WS, only output-aux variants are kept.
    """
    out: List[Candidate] = []
    aux_opts = {
        OS: [  # anchor OS: aux over inputs/weights
            {},
            {WS: Residency.STRIPE},
            {WS: Residency.WHOLE},
            {IS: Residency.STRIPE},
            {WS: Residency.WHOLE, IS: Residency.STRIPE},
        ],
        WS: [{}, {OS: Residency.STRIPE}, {IS: Residency.STRIPE}],
        IS: [{}, {OS: Residency.STRIPE}, {WS: Residency.WHOLE}],
    }
    for anchor in anchors:
        variants = aux_opts[anchor]
        if prune_with_observations:
            if anchor == WS:
                variants = [{}, {OS: Residency.STRIPE}]  # Obs 1 + Obs 5
            if anchor == IS:
                variants = [{}, {OS: Residency.STRIPE}]  # Obs 4
        for aux in variants:
            pri = tuple(aux.keys())
            for bm, bk, bn in itertools.product(
                _block_options(problem.m, hw),
                _block_options(problem.k, hw),
                _block_options(problem.n, hw),
            ):
                spec = DataflowSpec(
                    anchor=anchor, aux=aux, aux_priority=pri,
                    block=(bm, bk, bn), vmem_budget=hw.vmem_bytes,
                )
                t = cost_model.gemm_traffic(problem, spec)
                if not t.feasible:
                    continue
                est = cost_model.gemm_time_estimate(problem, spec, hw)
                out.append(Candidate(spec, est, t.total, t.feasible))
    return out


def explore(
    problem: GemmProblem,
    hw: cost_model.HardwareSpec = cost_model.V5E,
    top: int = 5,
    **kw,
) -> List[Candidate]:
    """Ranked candidates (best first)."""
    cands = enumerate_candidates(problem, hw, **kw)
    return sorted(cands, key=lambda c: (c.est_seconds, c.traffic_bytes))[:top]


def best_spec(
    problem: GemmProblem, hw: cost_model.HardwareSpec = cost_model.V5E
) -> DataflowSpec:
    ranked = explore(problem, hw, top=1)
    if not ranked:
        raise ValueError(f"no feasible dataflow for {problem}")
    return ranked[0].spec


# ---------------------------------------------------------------------------
# Conv candidate space (the shapes kernels/conv2d_df actually realizes).
# ---------------------------------------------------------------------------
def _b_oh_options(oh: int) -> List[int]:
    """Output row-tile heights, clamped to the output height."""
    return [b for b in (4, 8, 16) if b <= oh] or [max(1, oh)]


def enumerate_conv_candidates(
    problem: ConvProblem,
    hw: cost_model.HardwareSpec = cost_model.V5E,
    anchors: Sequence[Stationarity] = (OS, WS, IS),
) -> List[Candidate]:
    """All conv dataflows realizable by ``kernels.conv2d_df``.

    Per anchor the kernel admits exactly one residency shape — the input
    image is whole-resident under OS (fetched once per batch element),
    anchored under IS, and re-streamed per cout tile under WS — so the
    space is anchors x conv block choices ``(b_oh, bc, bk)`` clamped to
    the (lane-padded) problem dims.  Specs are *conv-blocked*; ranking
    uses ``cost_model.conv_time_estimate`` (implicit-GEMM traffic +
    realized-kernel VMEM feasibility).
    """
    aux_for = {
        OS: {IS: Residency.WHOLE},
        WS: {},
        IS: {},
    }
    out: List[Candidate] = []
    for anchor in anchors:
        aux = aux_for[anchor]
        pri = tuple(aux.keys())
        for b_oh, bc, bk in itertools.product(
            _b_oh_options(problem.oh),
            _block_options(problem.cin, hw),
            _block_options(problem.cout, hw),
        ):
            spec = DataflowSpec(
                anchor=anchor, aux=aux, aux_priority=pri,
                block=(b_oh, bc, bk), vmem_budget=hw.vmem_bytes,
            )
            if cost_model.conv_vmem_footprint(problem, spec) > hw.vmem_bytes:
                continue
            t = cost_model.conv_traffic(
                problem, cost_model.conv_gemm_view(problem, spec))
            est = max(problem.flops / hw.peak_flops_for(problem.in_dtype),
                      t.total / hw.hbm_bw)  # feasible: no infinity penalty
            out.append(Candidate(spec, est, t.total, True))
    return out


def explore_conv(
    problem: ConvProblem,
    hw: cost_model.HardwareSpec = cost_model.V5E,
    top: int = 5,
    **kw,
) -> List[Candidate]:
    """Ranked conv-blocked candidates (best first)."""
    cands = enumerate_conv_candidates(problem, hw, **kw)
    return sorted(cands, key=lambda c: (c.est_seconds, c.traffic_bytes))[:top]


# ---------------------------------------------------------------------------
# Binary candidate space (the shapes kernels/binary_mm actually realizes).
# ---------------------------------------------------------------------------
def _bkp_options(kp: int) -> List[int]:
    """Packed-word reduction-panel widths, clamped to the packed depth."""
    return [w for w in (2, 4, 8, 16) if w <= max(kp, 1)] or [1]


def enumerate_binary_candidates(
    problem: BinaryProblem,
    hw: cost_model.HardwareSpec = cost_model.V5E,
    anchors: Sequence[Stationarity] = (OS, WS, IS),
) -> List[Candidate]:
    """All binary dataflows realizable by ``kernels.binary_mm``.

    The kernel lowers the three basic anchors as one ``pallas_call`` each
    with the packed-word reduction innermost, so the space is anchors x
    ``(bm, bkp, bn)`` blocks — ``bkp`` counts uint32 words, ``bm``/``bn``
    are lane-aligned like the GEMM explorer.  Ranking uses
    ``cost_model.binary_time_estimate`` (bit-op compute at the VPU
    xor+popcount rate, packed-word byte traffic).
    """
    out: List[Candidate] = []
    for anchor in anchors:
        for bm, bkp, bn in itertools.product(
            _block_options(problem.m, hw),
            _bkp_options(problem.kp),
            _block_options(problem.n, hw),
        ):
            spec = DataflowSpec.basic(
                anchor, block=(bm, bkp, bn), vmem_budget=hw.vmem_bytes,
            )
            t = cost_model.binary_traffic(problem, spec)
            if not t.feasible:
                continue
            est = cost_model.binary_time_estimate(problem, spec, hw)
            out.append(Candidate(spec, est, t.total, True))
    return out


def explore_binary(
    problem: BinaryProblem,
    hw: cost_model.HardwareSpec = cost_model.V5E,
    top: int = 5,
    **kw,
) -> List[Candidate]:
    """Ranked binary candidates (best first)."""
    cands = enumerate_binary_candidates(problem, hw, **kw)
    return sorted(cands, key=lambda c: (c.est_seconds, c.traffic_bytes))[:top]


def measure(
    fn: Callable, args: Tuple, iters: int = 5, warmup: int = 2
) -> float:
    """Empirical wall-clock per call (seconds) — used by benchmarks."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def empirical_rank(
    problem: GemmProblem,
    specs: Sequence[DataflowSpec],
    interpret: bool = True,
    seed: int = 0,
) -> List[Tuple[DataflowSpec, float]]:
    """Execute each spec (interpret mode) and rank by wall-clock.

    Interpret-mode timing is a *correctness-preserving proxy* — it orders
    dataflows by grid-step and data-movement counts, not MXU throughput;
    the analytical model remains the primary ranking signal off-TPU.

    Operands are drawn in ``problem.in_dtype`` so int8/bf16 rankings
    measure the dtype they claim to.
    """
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    dtype = jnp.dtype(problem.in_dtype)
    if jnp.issubdtype(dtype, jnp.integer):
        a = jnp.asarray(
            rng.integers(-127, 128, size=(problem.m, problem.k)), dtype)
        b = jnp.asarray(
            rng.integers(-127, 128, size=(problem.k, problem.n)), dtype)
    else:
        a = jnp.asarray(rng.normal(size=(problem.m, problem.k)), dtype)
        b = jnp.asarray(rng.normal(size=(problem.k, problem.n)), dtype)
    from repro.kernels import ops

    results = []
    for spec in specs:
        fn = lambda x, y, s=spec: ops.matmul(
            x, y, spec=s, backend="interpret" if interpret else None
        )
        results.append((spec, measure(fn, (a, b), iters=3, warmup=1)))
    return sorted(results, key=lambda sr: sr[1])
