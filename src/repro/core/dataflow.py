"""Dataflow specifications — the paper's central abstraction, adapted to TPU.

A *dataflow* is (1) an **anchoring stationarity** that fixes the grid
iteration order of a tiled kernel, and (2) an ordered set of **auxiliary
stationarities** that allocate leftover VMEM capacity to stash non-anchored
operands (the TPU analogue of stashing in spare SIMD registers).

Paper mapping (DESIGN.md §2):
  anchoring stationarity  -> which operand's block index is held constant in
                             the innermost grid dimensions
  auxiliary stationarity  -> VMEM residency of a non-anchored operand
                             (stripe-resident or whole-resident)
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple


class Stationarity(str, enum.Enum):
    """Operand classes whose reuse a dataflow can exploit (paper §II/§III)."""

    INPUT = "input"
    WEIGHT = "weight"
    OUTPUT = "output"

    def __repr__(self) -> str:  # terse repr for benchmark tables
        return self.value


class Residency(str, enum.Enum):
    """How an auxiliary operand is held in VMEM.

    STREAMED : re-fetched per grid step that needs it (no aux stationarity).
    STRIPE   : one block-stripe along the anchored axis stays resident while
               the inner grid dims iterate (a few "vector variables").
    WHOLE    : the entire operand is pinned in VMEM for the kernel's lifetime
               (the paper's "all spare registers" limit case).
    """

    STREAMED = "streamed"
    STRIPE = "stripe"
    WHOLE = "whole"

    def __repr__(self) -> str:
        return self.value


IS = Stationarity.INPUT
WS = Stationarity.WEIGHT
OS = Stationarity.OUTPUT


EPILOGUE_ACTIVATIONS = ("relu", "gelu", "silu")


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """Element-wise tail fused into a dataflow kernel's output write.

    The fused computation, applied in-register to the accumulator before
    the single HBM write each anchor performs, is

        y = act(scale * acc + bias) + residual

    where every stage is optional (identity when its flag is off) and the
    arithmetic runs in float32 regardless of the accumulator dtype.

    Attributes:
      bias: add a per-output-column bias vector of shape (1, N).
      activation: one of ``EPILOGUE_ACTIVATIONS`` or None.
      scale: multiply by a dequantization scale — shape (1, 1)
        (per-tensor), (1, N) (per-column) or, for the matmul kernels,
        (M, 1) (per-row), e.g. ``a_scale * b_scale`` of an int8 GEMM.
      residual: add a residual tensor of the full output shape (M, N).

    The spec is hashable (a jit static argument); the actual operand
    arrays travel separately (see ``kernels.matmul_df.matmul_df``).
    """

    bias: bool = False
    activation: Optional[str] = None
    scale: bool = False
    residual: bool = False

    def __post_init__(self) -> None:
        if (self.activation is not None
                and self.activation not in EPILOGUE_ACTIVATIONS):
            raise ValueError(
                f"activation {self.activation!r} not in "
                f"{EPILOGUE_ACTIVATIONS}"
            )

    @property
    def is_noop(self) -> bool:
        return not (self.bias or self.activation or self.scale
                    or self.residual)


@dataclasses.dataclass(frozen=True)
class DataflowSpec:
    """A fully-specified extended dataflow for a GEMM-like tiled kernel.

    Attributes:
      anchor: the anchoring stationarity (decides grid iteration order).
      aux: mapping from non-anchored operand class to its VMEM residency.
        Operands absent from the map are ``STREAMED``.
      aux_priority: allocation order used by the explorer when the VMEM
        budget cannot hold every requested residency (paper Alg. 8 uses
        ``(WEIGHT, INPUT)`` under an OS anchor).
      block: (bm, bk, bn) tile shape for the underlying GEMM view.
      vmem_budget: bytes of VMEM this dataflow may claim.
    """

    anchor: Stationarity
    # stored as a sorted tuple of (operand, residency) pairs so the spec is
    # hashable (jit static arg); constructors accept a Mapping too.
    aux: Tuple[Tuple[Stationarity, Residency], ...] = ()
    aux_priority: Tuple[Stationarity, ...] = ()
    block: Tuple[int, int, int] = (128, 128, 128)
    vmem_budget: int = 16 * 1024 * 1024

    def __post_init__(self) -> None:
        aux = dict(self.aux) if not isinstance(self.aux, dict) else self.aux
        if self.anchor in aux:
            raise ValueError(
                f"anchor {self.anchor!r} cannot also be auxiliary"
            )
        for st, res in aux.items():
            if not isinstance(st, Stationarity) or not isinstance(res, Residency):
                raise TypeError(f"bad aux entry {st!r}: {res!r}")
        object.__setattr__(
            self,
            "aux",
            tuple(sorted(aux.items(), key=lambda kv: kv[0].value)),
        )
        bm, bk, bn = self.block
        if min(bm, bk, bn) <= 0:
            raise ValueError(f"non-positive block {self.block}")

    @property
    def aux_map(self) -> Mapping[Stationarity, Residency]:
        return dict(self.aux)

    # -- convenience ------------------------------------------------------
    def residency(self, operand: Stationarity) -> Residency:
        if operand == self.anchor:
            # The anchored operand is by construction held across the inner
            # grid dims; report WHOLE-like stickiness via STRIPE semantics.
            return Residency.STRIPE
        return self.aux_map.get(operand, Residency.STREAMED)

    @property
    def name(self) -> str:
        parts = [f"{self.anchor.value[0].upper()}S"]
        for st, res in self.aux:
            if res != Residency.STREAMED:
                parts.append(f"{st.value[0]}:{res.value}")
        return "+".join(parts)

    def with_block(self, block: Tuple[int, int, int]) -> "DataflowSpec":
        return dataclasses.replace(self, block=block)

    # -- canonical dataflows ----------------------------------------------
    @classmethod
    def basic(cls, anchor: Stationarity, **kw) -> "DataflowSpec":
        """A basic dataflow: anchoring stationarity only (paper §II)."""
        return cls(anchor=anchor, aux={}, aux_priority=(), **kw)

    @classmethod
    def optimized(cls, **kw) -> "DataflowSpec":
        """Paper Alg. 8: OS anchor, aux priority weight-then-input."""
        return cls(
            anchor=OS,
            aux={WS: Residency.STRIPE, IS: Residency.STREAMED},
            aux_priority=(WS, IS),
            **kw,
        )


_ANCHOR_ALIASES = {
    "os": Stationarity.OUTPUT, "output": Stationarity.OUTPUT,
    "ws": Stationarity.WEIGHT, "weight": Stationarity.WEIGHT,
    "is": Stationarity.INPUT, "input": Stationarity.INPUT,
}


@dataclasses.dataclass(frozen=True)
class SpecOverride:
    """A partial per-call dataflow override for ``ops.*(spec=...)``.

    One surface for all four subsystems (gemm / conv / binary /
    attention): fields left ``None`` inherit from the autotuned spec
    for the call's problem key, so ``SpecOverride(anchor=WS)`` forces
    the anchor while keeping the autotuned blocking, and
    ``SpecOverride(block=(None, 256))`` overrides one block dim only.
    ``anchor`` accepts a :class:`Stationarity` or its short name
    (``"os"`` / ``"ws"`` / ``"is"``).  For attention ``block`` is
    ``(bq, bkv)``; the legacy per-field ``anchor``/``bq``/``bkv``
    kwargs on ``ops.attention`` remain as aliases for one release.
    Hashable (jit static arg), like :class:`DataflowSpec`.
    """

    anchor: Optional[Stationarity] = None
    block: Optional[Tuple[Optional[int], ...]] = None

    def __post_init__(self) -> None:
        a = self.anchor
        if isinstance(a, str) and not isinstance(a, Stationarity):
            try:
                a = _ANCHOR_ALIASES[a.lower()]
            except KeyError:
                raise ValueError(
                    f"unknown anchor {self.anchor!r}; use one of "
                    f"{sorted(_ANCHOR_ALIASES)} or a Stationarity"
                ) from None
            object.__setattr__(self, "anchor", a)
        if self.block is not None:
            object.__setattr__(self, "block", tuple(self.block))

    @property
    def anchor_name(self) -> Optional[str]:
        if self.anchor is None:
            return None
        return {Stationarity.OUTPUT: "os", Stationarity.WEIGHT: "ws",
                Stationarity.INPUT: "is"}[self.anchor]

    def block_dim(self, idx: int) -> Optional[int]:
        if self.block is None or idx >= len(self.block):
            return None
        return self.block[idx]

    @property
    def is_complete(self) -> bool:
        """Every field pinned — the merge needs no autotuned base."""
        return (self.anchor is not None and self.block is not None
                and len(self.block) > 0
                and all(b is not None for b in self.block))

    def merge(self, base: "DataflowSpec") -> "DataflowSpec":
        """The full spec this override realizes over ``base``.

        An anchor change drops ``base``'s aux residencies (they were
        chosen for the old anchor and may name the new one); a pure
        block override keeps them.
        """
        anchor = self.anchor if self.anchor is not None else base.anchor
        block = list(base.block)
        if self.block is not None:
            for i, bv in enumerate(self.block):
                if bv is not None and i < len(block):
                    block[i] = bv
        if anchor == base.anchor:
            return dataclasses.replace(base, block=tuple(block))
        return DataflowSpec.basic(anchor, block=tuple(block),
                                  vmem_budget=base.vmem_budget)


@dataclasses.dataclass(frozen=True)
class GemmProblem:
    """Shape/dtype description of a GEMM-like workload: (M,K)x(K,N)->(M,N).

    ``weight_bits`` (None | 4 | 5) marks the B operand as sub-byte
    packed (``kernels/pack.py`` planes + outlier sidecar): the cost
    model then charges packed-byte weight traffic/footprints, and the
    autotune key gains the packing segment so compressed and plain
    variants of the same shape rank independently.
    """

    m: int
    k: int
    n: int
    in_dtype: str = "bfloat16"
    out_dtype: str = "float32"
    acc_dtype: str = "float32"
    weight_bits: Optional[int] = None

    def __post_init__(self) -> None:
        if self.weight_bits not in (None, 4, 5):
            raise ValueError(
                f"weight_bits must be None, 4 or 5, got {self.weight_bits}")

    @property
    def flops(self) -> int:
        return 2 * self.m * self.k * self.n

    def operand_bytes(self) -> Mapping[Stationarity, int]:
        from repro.core.cost_model import dtype_bytes, weight_stream_bytes

        ib = dtype_bytes(self.in_dtype)
        ob = dtype_bytes(self.out_dtype)
        return {
            IS: self.m * self.k * ib,
            WS: weight_stream_bytes(self),
            OS: self.m * self.n * ob,
        }


@dataclasses.dataclass(frozen=True)
class BinaryProblem:
    """A binary (+-1, xnor-popcount) GEMM on bit-packed operands.

    ``(M, Kp) x (Kp, N)`` over packed uint32 words, where ``Kp`` is the
    packed reduction depth (32 binary channels per word) and ``n_bits``
    the *true* pre-packing reduction depth K (``n_bits <= 32 * kp``;
    slack words/bits are zero-padding that cancels out of the
    ``K - 2*popcount(xor)`` identity).
    """

    m: int
    kp: int
    n: int
    n_bits: int
    out_dtype: str = "int32"

    def __post_init__(self) -> None:
        if self.n_bits > 32 * self.kp:
            raise ValueError(
                f"n_bits={self.n_bits} exceeds packed depth 32*{self.kp}"
            )

    @property
    def bit_ops(self) -> int:
        """xnor + popcount-accumulate pairs, in scalar-bit-op units."""
        return 2 * self.m * self.n_bits * self.n

    def as_gemm(self) -> GemmProblem:
        """Packed-word GEMM view used for traffic/footprint accounting."""
        return GemmProblem(
            m=self.m, k=self.kp, n=self.n,
            in_dtype="binary_packed", out_dtype=self.out_dtype,
            acc_dtype="int32",
        )


@dataclasses.dataclass(frozen=True)
class BinaryEpilogue:
    """Element-wise tail fused into a binary kernel's accumulator flush.

    Applied in-register to the xnor-popcount dot product before the one
    HBM output write:

        y = scale * dot + bias + residual
        out = sign(y) if binarize else y            (sign: y >= 0 -> +1)

    ``scale``/``bias`` cover a folded batchnorm (gamma/sigma and
    beta - gamma*mu/sigma, per output column); ``binarize`` re-binarizes
    in-register so chained binary layers never round-trip the int32
    accumulator (or its float image) through HBM.  All arithmetic before
    the sign runs in float32.

    The spec is hashable (a jit static argument); operand arrays travel
    separately (see ``kernels.binary_mm.binary_mm_df``).
    """

    scale: bool = False
    bias: bool = False
    residual: bool = False
    binarize: bool = False

    @property
    def is_noop(self) -> bool:
        return not (self.scale or self.bias or self.residual
                    or self.binarize)


@dataclasses.dataclass(frozen=True)
class ConvProblem:
    """Direct-convolution workload in the paper's notation (Fig. 3).

    ih/iw: input spatial; fh/fw: filter; s: stride; cin/cout: channels;
    n: batch. H = ih*iw, R = fh*fw, E = oh*ow as in the paper.
    """

    ih: int
    iw: int
    fh: int
    fw: int
    s: int
    cin: int
    cout: int
    n: int = 1
    in_dtype: str = "int8"
    out_dtype: str = "int32"
    weight_bits: Optional[int] = None

    def __post_init__(self) -> None:
        if self.weight_bits not in (None, 4, 5):
            raise ValueError(
                f"weight_bits must be None, 4 or 5, got {self.weight_bits}")

    @property
    def oh(self) -> int:
        return (self.ih - self.fh) // self.s + 1

    @property
    def ow(self) -> int:
        return (self.iw - self.fw) // self.s + 1

    # Paper notation -------------------------------------------------------
    @property
    def H(self) -> int:
        return self.ih * self.iw

    @property
    def R(self) -> int:
        return self.fh * self.fw

    @property
    def E(self) -> int:
        return self.oh * self.ow

    @property
    def flops(self) -> int:
        return 2 * self.n * self.E * self.R * self.cin * self.cout

    def as_gemm(self) -> GemmProblem:
        """Implicit-GEMM view: M = n*oh*ow, K = fh*fw*cin, N = cout."""
        return GemmProblem(
            m=self.n * self.E,
            k=self.R * self.cin,
            n=self.cout,
            in_dtype=self.in_dtype,
            out_dtype=self.out_dtype,
            weight_bits=self.weight_bits,
        )


@dataclasses.dataclass(frozen=True)
class AttentionProblem:
    """Shape/mask description of a (GQA) attention workload.

    ``bh`` is the folded batch * q-heads leading dim the kernels run
    over; ``group`` q heads share each KV head (``bh // group`` KV
    rows).  ``sq``/``skv`` are the *true* (pre-padding) sequence
    lengths; the kernels right-align the q rows against the KV length,
    so the decode step is simply ``sq=1, skv=<cache length>``.

    Valid-length / window / KV-dtype terms (PR 5):
      kv_len   — the valid KV prefix length when attending over a
                 padded KV-cache buffer of ``skv`` slots (``None`` =
                 all of ``skv`` is valid).  The kernels skip KV blocks
                 beyond it and the cost model's *banded* accounting
                 charges only the visited blocks, so decode traffic
                 scales with the valid length, not the buffer size.
                 Traced cache lengths key as ``None`` (worst case).
      window   — causal sliding window; fully-out-of-band KV blocks are
                 skipped in the kernel grid and dropped from the traffic
                 accounting (mask sparsity no longer cancels out of the
                 OS/WS ranking once blocks are skipped).
      kv_dtype — the K/V element dtype when it differs from the q/out
                 ``dtype`` (``"int8"`` for a quantized KV cache, which
                 adds per-position f32 scale reads and shrinks the KV
                 stream 2-4x).
      rows     — per-row banding (PR 8): the number of batch rows the
                 folded ``bh`` dim spans when each row carries its OWN
                 traced valid KV length (a ragged continuous-batching
                 decode step; ``kv_len`` stays ``None`` — the worst
                 case keys the cache — and ``cost_model.
                 attention_rows_traffic`` charges the realized per-row
                 lengths).  ``rows == 1`` is the uniform batch.

    The anchor choice maps the paper's dataflows onto attention:
      OS — the output tile (a block of q rows) is anchored; online-
           softmax statistics live in VMEM scratch across the KV sweep
           (flash attention); KV blocks stream per q tile.
      WS — the KV block is anchored (fetched exactly once) while the
           (acc, m, l) running partials round-trip HBM once per KV
           block — the paper's WS output-traffic pathology at
           attention scale.
    ``DataflowSpec.block`` for attention is ``(bq, bkv, d)``.
    """

    bh: int
    sq: int
    skv: int
    d: int
    group: int = 1
    causal: bool = True
    window: Optional[int] = None
    dtype: str = "float32"
    kv_len: Optional[int] = None
    kv_dtype: Optional[str] = None
    rows: int = 1

    def __post_init__(self) -> None:
        if self.bh % max(self.group, 1):
            raise ValueError(
                f"bh={self.bh} not divisible by group={self.group}"
            )
        if self.kv_len is not None and not 0 < self.kv_len <= self.skv:
            raise ValueError(
                f"kv_len={self.kv_len} outside (0, skv={self.skv}]"
            )
        if self.rows < 1 or self.bh % self.rows:
            raise ValueError(
                f"bh={self.bh} not divisible by rows={self.rows}"
            )

    @property
    def bh_kv(self) -> int:
        return self.bh // max(self.group, 1)

    @property
    def kv_valid(self) -> int:
        """The valid KV prefix length (``kv_len`` defaulting to skv)."""
        return self.kv_len if self.kv_len is not None else self.skv

    @property
    def kv_elem_dtype(self) -> str:
        return self.kv_dtype if self.kv_dtype is not None else self.dtype

    @property
    def kv_quantized(self) -> bool:
        """True when the K/V cache carries per-position dequant scales
        (int8 quantization) — a mere precision mismatch (e.g. f32
        activations over a bf16 cache) has no scale arrays."""
        return self.kv_elem_dtype in ("int8", "uint8")

    @property
    def dot_flops(self) -> int:
        """QK^T + PV MXU flops over the full (unbanded) score grid.
        The ranking estimate uses the banded per-block counts from
        ``cost_model.attention_banded_ops`` instead."""
        return 4 * self.bh * self.sq * self.skv * self.d

    @property
    def softmax_ops(self) -> int:
        """Per-score VPU work: max, sub, exp, sum, rescale-mul, fma."""
        return 6 * self.bh * self.sq * self.skv

    @property
    def flops(self) -> int:
        return self.dot_flops


# Grid iteration orders per anchor (innermost dim last). The anchored
# operand's block index is constant across the innermost dim(s); see
# kernels/matmul_df for the realization.
ANCHOR_GRID_ORDER = {
    OS: ("m", "n", "k"),  # out tile (m,n) fixed while k reduces -> scratch acc
    WS: ("k", "n", "m"),  # weight tile (k,n) fixed while m sweeps -> out RMW
    IS: ("m", "k", "n"),  # input tile (m,k) fixed while n sweeps -> out RMW
}


# ---------------------------------------------------------------------------
# Problem registry: one generic pipeline for every dataflow subsystem.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ProblemRegistration:
    """How a problem type plugs into the generic explore/autotune pipeline.

    Onboarding a new dataflow subsystem (depthwise conv, SSM scan, ...)
    is one ``register_problem`` call supplying:

      kind            — short cache-key tag (``gemm``/``conv``/``bin``/
                        ``attn``); becomes the second key segment after
                        the schema version.
      problem_cls     — the frozen dataclass describing the workload.
      key_fields      — problem -> tuple of strings covering every field
                        that changes the ranking (the cache key head).
      enumerate       — (problem, hw, **kw) -> List[explorer.Candidate]
                        of realizable specs.  This hook OWNS the
                        candidate space: it must itself apply the
                        VMEM-fit filter and attach the cost estimate to
                        each candidate (using the two hooks below), so
                        the generic pipeline only sorts what it returns.
      time_estimate   — (problem, spec, hw) -> est. seconds; the cost
                        function ``enumerate`` ranks with, re-exposed
                        here so callers can score a spec for any
                        registered problem without per-type imports.
      vmem_footprint  — (problem, spec) -> peak VMEM bytes claimed by
                        the realized kernel; the feasibility check
                        ``enumerate`` filters with, re-exposed likewise.
      measure         — optional (problem, specs, interpret=True) ->
                        sorted [(spec, seconds)] empirical re-rank hook
                        used by ``autotune.best_spec(refine=True)``.

    ``core.explorer`` registers the four built-in subsystems at import;
    ``core.autotune`` and ``explorer.explore`` dispatch through this
    table and contain no per-problem-type branches.
    """

    kind: str
    problem_cls: type
    key_fields: Callable[[Any], Tuple[str, ...]]
    enumerate: Callable[..., Any]
    time_estimate: Callable[..., float]
    vmem_footprint: Callable[[Any, "DataflowSpec"], int]
    measure: Optional[Callable[..., Any]] = None


_REGISTRY: Dict[type, ProblemRegistration] = {}


def register_problem(reg: ProblemRegistration) -> ProblemRegistration:
    """Register (or re-register) a problem type's subsystem hooks.

    ``kind`` tags must be unique across problem types — two subsystems
    sharing one would mint colliding ``autotune`` cache keys, silently
    serving one type's cached spec (whose block semantics differ) to
    the other.
    """
    for cls, existing in _REGISTRY.items():
        if existing.kind == reg.kind and cls is not reg.problem_cls:
            raise ValueError(
                f"kind {reg.kind!r} is already registered for "
                f"{cls.__name__}; cache keys would collide"
            )
    _REGISTRY[reg.problem_cls] = reg
    return reg


def registration_for(problem_or_cls) -> ProblemRegistration:
    """The registration for a problem instance or class (KeyError-free:
    raises TypeError naming the unregistered type)."""
    cls = (problem_or_cls if isinstance(problem_or_cls, type)
           else type(problem_or_cls))
    reg = _REGISTRY.get(cls)
    if reg is None:
        raise TypeError(
            f"{cls.__name__} is not a registered dataflow problem type; "
            f"known: {sorted(r.kind for r in _REGISTRY.values())} "
            f"(see core.dataflow.register_problem)"
        )
    return reg


def registered_kinds() -> Dict[str, type]:
    """kind tag -> problem class for every registered subsystem."""
    return {reg.kind: cls for cls, reg in _REGISTRY.items()}
