"""End-to-end layout/dataflow chain optimization (paper §IV-C).

Given a chain of layers, each with several (layout, dataflow) options of
known per-layer cost, pick one option per layer minimizing total cost
including layout-transformation costs between successive layers — the
paper's dynamic-programming approach.

The paper also observes that reducing along fw/fh/ic lets outputs be
written flexibly, making most transitions free; ``transition_cost``
models that with a ``flexible_writes`` flag.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class LayerOption:
    """One (memory layout, dataflow) implementation choice for a layer."""

    layout: str          # e.g. "NCHWc128", "NHWC"
    dataflow: str        # DataflowSpec.name
    cost: float          # per-layer execution cost (seconds or bytes)
    out_bytes: int = 0   # activation size (drives relayout cost)


def transition_cost(
    prev: LayerOption, nxt: LayerOption, flexible_writes: bool = True,
    hbm_bw: float = 819e9,
) -> float:
    """Cost of converting ``prev``'s output layout to ``nxt``'s input layout.

    flexible_writes=True is the paper's finding: the producing layer can
    emit any layout for free because reduction order decouples from write
    order. Otherwise a relayout pass reads+writes the activation once.
    """
    if prev.layout == nxt.layout or flexible_writes:
        return 0.0
    return 2.0 * prev.out_bytes / hbm_bw


def optimize_chain(
    layers: Sequence[Sequence[LayerOption]],
    flexible_writes: bool = True,
) -> Tuple[float, List[int]]:
    """DP over the chain. Returns (total cost, option index per layer)."""
    if not layers:
        return 0.0, []
    # dp[j] = best cost ending with option j of current layer
    dp = [opt.cost for opt in layers[0]]
    back: List[List[int]] = []
    for li in range(1, len(layers)):
        ndp = []
        nback = []
        for opt in layers[li]:
            best_j, best_c = 0, float("inf")
            for j, prev_opt in enumerate(layers[li - 1]):
                c = dp[j] + transition_cost(prev_opt, opt, flexible_writes)
                if c < best_c:
                    best_c, best_j = c, j
            ndp.append(best_c + opt.cost)
            nback.append(best_j)
        dp, _ = ndp, back.append(nback)
    # backtrack
    idx = int(min(range(len(dp)), key=dp.__getitem__))
    total = dp[idx]
    choice = [idx]
    for nback in reversed(back):
        idx = nback[idx]
        choice.append(idx)
    choice.reverse()
    return total, choice


def brute_force_chain(
    layers: Sequence[Sequence[LayerOption]],
    flexible_writes: bool = True,
) -> Tuple[float, List[int]]:
    """Exponential reference for property tests."""
    import itertools

    best = (float("inf"), [])
    for combo in itertools.product(*[range(len(l)) for l in layers]):
        cost = sum(layers[i][j].cost for i, j in enumerate(combo))
        for i in range(1, len(combo)):
            cost += transition_cost(
                layers[i - 1][combo[i - 1]], layers[i][combo[i]],
                flexible_writes,
            )
        if cost < best[0]:
            best = (cost, list(combo))
    return best
