"""mamba2-780m [ssm] — 48L d1536 attn-free, vocab 50280, ssm_state=128,
SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_chunk=256,
    ssm_expand=2,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=512,
    ssm_state=16,
    ssm_headdim=16,
    ssm_chunk=8,
    ssm_expand=2,
    tie_embeddings=True,
    param_dtype="float32",
    act_dtype="float32",
)
