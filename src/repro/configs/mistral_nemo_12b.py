"""mistral-nemo-12b [dense] — 40L d5120 32H (GQA kv=8) d_ff 14336,
vocab 131072, 128k ctx. [hf:mistralai/Mistral-Nemo-Base-2407; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab_size=131_072,
    d_head=128,
    rope_theta=1_000_000.0,
)

SMOKE = ArchConfig(
    name="mistral-nemo-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    d_head=32,
    param_dtype="float32",
    act_dtype="float32",
)
