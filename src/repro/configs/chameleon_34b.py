"""chameleon-34b [vlm] — 48L d8192 64H (GQA kv=8) d_ff 22016, vocab 65536.
Early fusion: VQ image tokens live in the vocab, so the frontend stub is
the tokenizer itself; the backbone is a dense LM with qk-norm.
[arXiv:2405.09818]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22_016,
    vocab_size=65_536,
    d_head=128,
    qk_norm=True,             # chameleon stabilizes with qk-norm
    rope_theta=10_000.0,
)

SMOKE = ArchConfig(
    name="chameleon-smoke",
    family="vlm",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    d_head=32,
    qk_norm=True,
    param_dtype="float32",
    act_dtype="float32",
)
