"""minicpm-2b [dense] — 40L d2304 36H (MHA kv=36) d_ff 5760, vocab 122753.
WSD schedule (see optim.schedules.wsd). [arXiv:2404.06395; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122_753,
    d_head=64,
    tie_embeddings=True,      # minicpm ties embeddings
    rope_theta=10_000.0,
)

SMOKE = ArchConfig(
    name="minicpm-smoke",
    family="dense",
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=6,
    d_ff=192,
    vocab_size=512,
    d_head=16,
    tie_embeddings=True,
    param_dtype="float32",
    act_dtype="float32",
)
