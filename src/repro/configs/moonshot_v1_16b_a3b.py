"""moonshot-v1-16b-a3b [moe] — 48L d2048 16H (MHA kv=16) expert d_ff=1408,
vocab 163840, MoE 64 experts top-6 + shared experts (moonlight-style).
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163_840,
    d_head=128,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,       # moonlight shared experts
    rope_theta=50_000.0,
)

SMOKE = ArchConfig(
    name="moonshot-smoke",
    family="moe",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=96,
    vocab_size=512,
    d_head=32,
    n_experts=8,
    top_k=2,
    n_shared_experts=1,
    capacity_factor=2.0,
    param_dtype="float32",
    act_dtype="float32",
)
