"""Architecture registry: the 10 assigned archs (+ reduced smoke variants).

``get(name)`` / ``get_smoke(name)`` resolve configs; ``SKIP`` records the
(arch, shape) cells excluded per the assignment rules (quadratic-attention
archs skip long_500k — see DESIGN.md §4).
"""
from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, input_specs

_MODULES = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "minicpm-2b": "minicpm_2b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "qwen3-1.7b": "qwen3_1_7b",
    "minitron-8b": "minitron_8b",
    "hymba-1.5b": "hymba_1_5b",
    "mamba2-780m": "mamba2_780m",
    "whisper-tiny": "whisper_tiny",
    "chameleon-34b": "chameleon_34b",
}

ARCH_NAMES: List[str] = list(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ArchConfig:
    return _module(name).SMOKE


def cell_skipped(arch: str, shape: str) -> Tuple[bool, str]:
    """(skipped?, reason) for an (arch x shape) dry-run cell."""
    cfg = get(arch)
    if shape == "long_500k" and not cfg.subquadratic:
        return True, ("full quadratic attention at 512k context "
                      "(per assignment: run only SSM/hybrid/linear-attn)")
    return False, ""


def all_cells(include_skipped: bool = False):
    """Every (arch, shape) pair in the assignment (40 cells)."""
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            skipped, reason = cell_skipped(arch, shape)
            if skipped and not include_skipped:
                continue
            yield arch, shape, skipped, reason
