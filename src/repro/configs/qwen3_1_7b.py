"""qwen3-1.7b [dense] — 28L d2048 16H (GQA kv=8) d_ff 6144, vocab 151936,
qk_norm. [hf:Qwen/Qwen3 family; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=6144,
    vocab_size=151_936,
    d_head=128,
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)

SMOKE = ArchConfig(
    name="qwen3-1.7b-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    d_head=32,
    qk_norm=True,
    tie_embeddings=True,
    param_dtype="float32",
    act_dtype="float32",
)
