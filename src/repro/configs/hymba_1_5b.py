"""hymba-1.5b [hybrid] — 32L d1600 25H (GQA kv=5) d_ff 5504, vocab 32001,
ssm_state=16; parallel attn+mamba heads; SWA except 3 full-attention
layers (first/middle/last). [arXiv:2411.13676; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32_001,
    d_head=64,
    ssm_state=16,
    ssm_headdim=64,
    ssm_chunk=256,
    ssm_expand=2,
    attn_window=1024,
    full_attn_every=1,        # keep {first, middle, last} full-attention
    rope_theta=10_000.0,
)

SMOKE = ArchConfig(
    name="hymba-smoke",
    family="hybrid",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    d_head=16,
    ssm_state=8,
    ssm_headdim=16,
    ssm_chunk=8,
    ssm_expand=2,
    attn_window=8,
    full_attn_every=1,
    param_dtype="float32",
    act_dtype="float32",
)
