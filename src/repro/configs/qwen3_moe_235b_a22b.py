"""qwen3-moe-235b-a22b [moe] — 94L d4096 64H (GQA kv=4) expert d_ff=1536,
vocab 151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B family; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,                # per-expert FFN width
    vocab_size=151_936,
    d_head=128,
    qk_norm=True,             # qwen3 family
    n_experts=128,
    top_k=8,
    n_shared_experts=0,
    rope_theta=1_000_000.0,
)

SMOKE = ArchConfig(
    name="qwen3-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=512,
    d_head=32,
    qk_norm=True,
    n_experts=8,
    top_k=2,
    capacity_factor=2.0,
    param_dtype="float32",
    act_dtype="float32",
)
