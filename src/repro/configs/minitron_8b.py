"""minitron-8b [dense] — 32L d4096 32H (GQA kv=8) d_ff 16384, vocab 256000.
Pruned nemotron. [arXiv:2407.14679; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16_384,
    vocab_size=256_000,
    d_head=128,
    rope_theta=10_000.0,
)

SMOKE = ArchConfig(
    name="minitron-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=768,
    d_head=32,
    param_dtype="float32",
    act_dtype="float32",
)
