"""Architecture configuration schema + input specs for the assigned shapes."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One LM-family architecture (exact dims from the assignment table)."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                     # 0 for attn-free
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 128
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25

    # --- SSM (mamba2 SSD) ----------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_expand: int = 2
    ssm_conv: int = 4

    # --- hybrid (hymba) ------------------------------------------------------
    attn_window: Optional[int] = None      # sliding window for SWA layers
    full_attn_every: int = 0               # 0 = all full attention

    # --- encoder-decoder (whisper) -------------------------------------------
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_seq_ratio: float = 1.0             # encoder frames per decoder token

    # --- numerics ------------------------------------------------------------
    param_dtype: str = "bfloat16"
    act_dtype: str = "bfloat16"
    # "auto" follows act_dtype; "int8" halves the decode memory term
    # (per-(pos,head) scales in f32); opt-in — decode cells are
    # memory-bound on the KV+param stream
    kv_cache_dtype: str = "auto"

    # --- paper technique -----------------------------------------------------
    use_pallas_kernels: bool = False       # True on real TPU runtime
    # decoder-layer MLPs use the binary (xnor-popcount) datapath — +-1
    # packed weights + folded-BN fused epilogue (paper Fig. 9 workload
    # class, layers.binary_mlp_apply); requires d_model/d_ff % 32 == 0
    binary_mlp: bool = False
    # decoder-layer MLPs store weights sub-byte packed (kernels/pack.py:
    # int4/int5 nibble planes + MSR outlier sidecar) and run through
    # ``ops.matmul_packed`` with in-register decompress at the stripe
    # load (layers.packed_mlp_apply); mutually exclusive with binary_mlp
    packed_weights: bool = False
    packed_weight_bits: int = 4            # 4 or 5

    def __post_init__(self):
        if self.n_heads and self.n_kv_heads and self.n_heads % self.n_kv_heads:
            raise ValueError(f"{self.name}: heads {self.n_heads} % kv "
                             f"{self.n_kv_heads} != 0")

    # -- derived --------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so embeddings shard over 16-way TP
        (logits beyond vocab_size are masked in loss/decode)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_state else 0

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid-with-SWA)."""
        return self.has_ssm and (
            self.family == "ssm"
            or (self.family == "hybrid" and self.attn_window is not None)
        )

    def layer_window(self, layer: int) -> Optional[int]:
        """Sliding window for a layer (hymba keeps a few full-attn layers)."""
        if self.attn_window is None:
            return None
        if self.full_attn_every:
            full = {0, self.n_layers // 2, self.n_layers - 1}
            if layer in full:
                return None
        return self.attn_window

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers + head)."""
        d, v = self.d_model, self.vocab_size
        total = v * d                                    # embed
        if not self.tie_embeddings:
            total += v * d                               # lm head
        per_layer = 0
        if self.has_attention:
            per_layer += d * self.q_dim + 2 * d * self.kv_dim \
                + self.q_dim * d
            per_layer += 2 * d                           # norms
            if self.qk_norm:
                per_layer += 2 * self.d_head
        if self.has_ssm:
            di, n, h = self.d_inner, self.ssm_state, self.ssm_heads
            per_layer += d * (2 * di + 2 * n + h)        # in_proj(z,x,B,C,dt)
            per_layer += di * self.ssm_conv + di         # conv + D
            per_layer += h                               # A_log
            per_layer += di * d                          # out_proj
        if self.n_experts:
            per_layer += d * self.n_experts              # router
            per_layer += self.n_experts * 3 * d * self.d_ff
            per_layer += self.n_shared_experts * 3 * d * self.d_ff
        elif self.d_ff:
            per_layer += 3 * d * self.d_ff               # SwiGLU
        per_layer += d                                   # final/extra norm
        total += self.n_layers * per_layer
        if self.is_encoder_decoder:
            enc_layer = 4 * d * d + 3 * d * self.d_ff + 2 * d
            total += self.n_enc_layers * enc_layer
            total += self.n_layers * (4 * d * d + d)     # cross attention
        return total

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top_k + shared experts only)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        expert_params = self.n_layers * self.n_experts * 3 * self.d_model \
            * self.d_ff
        active_expert = self.n_layers * (self.top_k + self.n_shared_experts) \
            * 3 * self.d_model * self.d_ff
        return full - expert_params + active_expert


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def input_specs(cfg: ArchConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train/prefill: token ids (B, S) (+ encoder frames for enc-dec — the
    modality frontend is stubbed per the assignment: precomputed frame
    embeddings).  decode: one new token per sequence + cache position.
    """
    i32 = jnp.int32
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "targets": jax.ShapeDtypeStruct((b, s), i32),
        }
        if cfg.is_encoder_decoder:
            enc_s = int(s * cfg.enc_seq_ratio)
            specs["enc_frames"] = jax.ShapeDtypeStruct(
                (b, enc_s, cfg.d_model), jnp.dtype(cfg.act_dtype)
            )
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.is_encoder_decoder:
            enc_s = int(s * cfg.enc_seq_ratio)
            specs["enc_frames"] = jax.ShapeDtypeStruct(
                (b, enc_s, cfg.d_model), jnp.dtype(cfg.act_dtype)
            )
        return specs
    if shape.kind == "decode":
        return {
            "tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "positions": jax.ShapeDtypeStruct((b,), i32),
        }
    raise ValueError(shape.kind)
