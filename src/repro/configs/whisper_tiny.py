"""whisper-tiny [audio] — 4L enc + 4L dec, d384 6H d_ff 1536, vocab 51865.
Enc-dec; conv frontend STUBBED: input_specs provides precomputed frame
embeddings (B, S_enc, d_model). [arXiv:2212.04356]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51_865,
    d_head=64,
    is_encoder_decoder=True,
    n_enc_layers=4,
    enc_seq_ratio=1.0,
    rope_theta=10_000.0,
)

SMOKE = ArchConfig(
    name="whisper-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    d_head=16,
    is_encoder_decoder=True,
    n_enc_layers=2,
    param_dtype="float32",
    act_dtype="float32",
)
