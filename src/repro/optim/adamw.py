"""AdamW with decoupled weight decay, fp32 moments, global-norm clipping.

Pure-JAX (no optax).  Moments are kept in fp32 regardless of the param
dtype; the update is computed in fp32 and cast back — the standard
mixed-precision arrangement for bf16 params.  The optimizer state inherits
the parameter sharding (ZeRO-1 falls out of the sharding rules in
launch/sharding.py, which shard moments over the data axis too).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # ()
    m: Any                   # pytree like params (fp32)
    v: Any                   # pytree like params (fp32)


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr_fn: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    # bf16 moments halve optimizer HBM — the standard lever for >100B
    # models on 16 GB/chip parts (update math stays fp32).
    moment_dtype: str = "float32"

    def init(self, params) -> AdamWState:
        mdt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jnp.zeros(p.shape, mdt)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def update(
        self, grads, state: AdamWState, params
    ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
        gnorm = global_norm(grads)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self.lr_fn(state.step)

        mdt = jnp.dtype(self.moment_dtype)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            mh, vh = m / bc1, v / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return new_p.astype(p.dtype), m.astype(mdt), v.astype(mdt)

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        new = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([n[0] for n in new])
        new_m = treedef.unflatten([n[1] for n in new])
        new_v = treedef.unflatten([n[2] for n in new])
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_p, AdamWState(step, new_m, new_v), metrics


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))
