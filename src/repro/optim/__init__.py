from repro.optim.adamw import AdamW, AdamWState, global_norm
from repro.optim import schedules, compress
