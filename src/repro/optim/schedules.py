"""Learning-rate schedules (pure functions of the step).

Includes WSD (warmup-stable-decay) — the schedule MiniCPM trains with —
plus cosine and linear-warmup helpers.
"""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup_steps: int, peak: float):
    step = jnp.asarray(step, jnp.float32)
    return peak * jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))


def wsd(step, warmup_steps: int, stable_steps: int, decay_steps: int,
        peak: float, floor: float = 0.0):
    """Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395 §4)."""
    step = jnp.asarray(step, jnp.float32)
    warm = peak * (step + 1) / max(warmup_steps, 1)
    decay_frac = (step - warmup_steps - stable_steps) / max(decay_steps, 1)
    decay = peak * jnp.exp(-decay_frac * 5.0)  # fast exponential anneal
    lr = jnp.where(
        step < warmup_steps, warm,
        jnp.where(step < warmup_steps + stable_steps, peak,
                  jnp.maximum(decay, floor)),
    )
    return lr


def cosine(step, warmup_steps: int, total_steps: int, peak: float,
           floor_ratio: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak * (step + 1) / max(warmup_steps, 1)
    t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                 0.0, 1.0)
    cos = peak * (floor_ratio + (1 - floor_ratio) * 0.5 *
                  (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup_steps, warm, cos)
