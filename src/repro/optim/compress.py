"""Gradient compression: int8 quantized all-reduce with error feedback.

Distributed-optimization trick for bandwidth-bound data parallelism: each
shard quantizes its gradient to int8 (per-tensor symmetric scale), the
all-reduce moves 4x fewer bytes, and the quantization residual is carried
into the next step (error feedback keeps the scheme unbiased over time).

Used by the shard_map DP training variant (train/step.py with
``compress_grads=True``); the property test checks the error-feedback
invariant (accumulated compensation keeps long-run bias ~0).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant


def quantize_grad(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization -> (q, scale)."""
    return quant.symmetric_int8(g)


def dequantize_grad(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(
    grads: Any, axis_name: str, residuals: Optional[Any] = None
) -> Tuple[Any, Any]:
    """int8-compressed gradient all-reduce with error feedback.

    Inside shard_map/pmap: quantize (grad + residual), psum the int8
    payload (as int32 accumulate to avoid overflow), dequantize with the
    max scale, and carry the local quantization error to the next step.

    Returns (reduced_grads, new_residuals).
    """
    if residuals is None:
        residuals = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads
        )

    def one(g, r):
        target = g.astype(jnp.float32) + r
        q, scale = quantize_grad(target)
        # share one conservative scale so dequantization is exact w.r.t.
        # the summed int payload
        scale = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
        sent = q.astype(jnp.float32) * scale
        new_r = target - sent                      # local error feedback
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
        reduced = summed.astype(jnp.float32) * scale / n.astype(jnp.float32)
        return reduced.astype(g.dtype), new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        treedef.unflatten([p[0] for p in pairs]),
        treedef.unflatten([p[1] for p in pairs]),
    )
