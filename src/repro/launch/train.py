"""Training launcher: end-to-end distributed training driver.

Runs real training on whatever devices exist (the production meshes need
real hardware; smoke-scale runs use --smoke and the local device), with
checkpoint-restart fault tolerance via repro.runtime.driver.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.runtime.driver import TrainDriver, TrainJobConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine",
                    choices=("cosine", "wsd", "const"))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(
        args.arch)
    job = TrainJobConfig(
        arch=cfg, steps=args.steps, global_batch=args.batch,
        seq_len=args.seq, lr=args.lr, schedule=args.schedule,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        microbatches=args.microbatches, remat=args.remat, seed=args.seed,
    )
    driver = TrainDriver(job)
    state = driver.run(resume=args.resume)
    print(f"final step={state.step} loss={state.last_loss:.4f}")


if __name__ == "__main__":
    main()
