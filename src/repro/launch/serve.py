"""Serving launcher: continuous batched generation with the Engine.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt-len 32 --new-tokens 16

Requests go through the handle/stream API: ``submit()`` returns a
``RequestHandle`` per prompt and ``drain()`` runs the continuous
scheduler — mixed prompt lengths are fine (``--ragged`` randomizes
them), short requests finish and free their slot while long ones keep
decoding.

Crash-safe serving: give it a journal directory and a snapshot cadence
and every admission/token/terminal transition is journaled, with
periodic engine snapshots; after a kill, ``--resume`` replays the
journal (and newest snapshot) and finishes the interrupted batch with
bit-identical greedy tokens:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --journal-dir /tmp/serve-journal --snapshot-every 4
  # ... SIGKILL mid-decode, then:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --journal-dir /tmp/serve-journal --resume
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serve.engine import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--ragged", action="store_true",
                    help="randomize prompt lengths in [1, prompt-len] "
                         "(exercises the continuous scheduler)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--journal-dir", default=None,
                    help="enable the durable request journal (WAL) + "
                         "snapshots under this directory")
    ap.add_argument("--snapshot-every", type=int, default=None,
                    help="engine snapshot cadence in decode steps "
                         "(default: REPRO_SNAPSHOT_EVERY)")
    ap.add_argument("--resume", action="store_true",
                    help="recover journaled requests after a crash and "
                         "finish serving them")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(
        args.arch)
    params = lm.init_model(cfg, jax.random.PRNGKey(args.seed))
    engine = Engine(cfg, params,
                    max_len=args.prompt_len + args.new_tokens + 8,
                    journal_dir=args.journal_dir,
                    snapshot_every=args.snapshot_every)
    if args.resume:
        reqs = engine.restore()
        engine.serve(reqs)
        print(f"resumed {len(reqs)} journaled request(s):")
    else:
        rng = np.random.default_rng(args.seed)
        lens = (rng.integers(1, args.prompt_len + 1, args.batch)
                if args.ragged
                else np.full(args.batch, args.prompt_len))
        reqs = [engine.submit(
                    rng.integers(0, cfg.vocab_size, int(n)).astype(
                        np.int32),
                    args.new_tokens)
                for n in lens]
        engine.drain()
    for r in reqs:
        print(f"  req{r.rid} [{r.state.value}] "
              f"prompt={len(r.prompt)}: {r.out_tokens}")
    stats = engine.stats()
    print(f"engine: admitted={stats['admitted']} "
          f"completed={stats['completed']} retries={stats['retries']} "
          f"demotions={stats['demotions']} "
          f"degraded_steps={stats['degraded_steps']} "
          f"snapshots={stats['snapshots_saved']} "
          f"recovered={stats['recovered']} "
          f"replayed_steps={stats['replayed_steps']}")


if __name__ == "__main__":
    main()
