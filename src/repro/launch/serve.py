"""Serving launcher: batched greedy generation with the Engine.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serve.engine import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(
        args.arch)
    params = lm.init_model(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)
    ).astype(np.int32)
    engine = Engine(cfg, params,
                    max_len=args.prompt_len + args.new_tokens + 8)
    out = engine.generate(prompts, args.new_tokens)
    print(f"generated {out.shape} tokens:")
    for row in out:
        print("  ", row.tolist())
    stats = engine.stats()
    print(f"engine: admitted={stats['admitted']} "
          f"completed={stats['completed']} retries={stats['retries']} "
          f"demotions={stats['demotions']} "
          f"degraded_steps={stats['degraded_steps']}")


if __name__ == "__main__":
    main()
