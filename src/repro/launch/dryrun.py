import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real step function (train_step for train
shapes, prefill for prefill shapes, serve_step for decode shapes) with
ShapeDtypeStruct inputs (zero allocation), compiles it against the
production mesh, and records:

  * memory_analysis()  — proves the cell fits per-device HBM,
  * cost_analysis()    — HLO FLOPs / bytes for §Roofline,
  * collective bytes   — parsed from the compiled HLO text,

into benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import SHAPES, input_specs
from repro.launch import hlo_analysis, sharding
from repro.launch.mesh import make_production_mesh, mesh_axes
from repro.models import flags, lm
from repro.optim import AdamW, schedules
from repro.serve.engine import make_serve_step
from repro.train.step import make_train_step

RESULTS_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "benchmarks", "results",
    "dryrun",
)


def _dist_for(cfg, mesh) -> Optional[lm.Dist]:
    dp, tp = mesh_axes(mesh)
    return lm.Dist(mesh=mesh, dp_axes=dp, tp_axis=tp)


def _lower(cfg, shape, mesh, dist, remat: str, unroll: int,
           microbatches: int):
    """Build + lower the step function for one (cfg, shape) on a mesh."""
    params_shape = jax.eval_shape(
        lambda: lm.init_model(cfg, jax.random.PRNGKey(0))
    )
    p_sh = sharding.param_shardings(params_shape, mesh)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        # >100B models: bf16 moments (halves optimizer HBM; DESIGN.md §5)
        mdt = "bfloat16" if cfg.param_count() > 100e9 else "float32"
        opt = AdamW(lr_fn=lambda s: schedules.cosine(s, 100, 10_000, 3e-4),
                    moment_dtype=mdt)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        o_sh = sharding.opt_state_shardings(opt_shape, mesh)
        b_sh = sharding.batch_shardings(specs, mesh)
        step_fn = make_train_step(cfg, opt, dist=dist, remat=remat,
                                  unroll=unroll, microbatches=microbatches)
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_sh, o_sh, b_sh),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(params_shape, opt_shape, specs)
    elif shape.kind == "prefill":
        b_sh = sharding.batch_shardings(specs, mesh)

        def prefill_fn(params, batch):
            return lm.prefill(params, batch["tokens"], cfg,
                              enc_frames=batch.get("enc_frames"), dist=dist,
                              unroll=unroll)

        jitted = jax.jit(prefill_fn, in_shardings=(p_sh, b_sh))
        lowered = jitted.lower(params_shape, specs)
    elif shape.kind == "decode":
        cache_shape = jax.eval_shape(
            lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len,
                                  cfg.act_dtype,
                                  enc_len=(shape.seq_len
                                           if cfg.is_encoder_decoder
                                           else None))
        )
        c_sh = sharding.cache_shardings(cache_shape, mesh)
        tok_shape = {"tokens": specs["tokens"]}
        t_sh = sharding.batch_shardings(tok_shape, mesh)
        serve_step = make_serve_step(cfg, dist=dist, unroll=unroll)

        def step_fn(params, cache, batch):
            return serve_step(params, cache, batch["tokens"])

        jitted = jax.jit(
            step_fn, in_shardings=(p_sh, c_sh, t_sh), donate_argnums=(1,)
        )
        lowered = jitted.lower(params_shape, cache_shape, tok_shape)
    else:
        raise ValueError(shape.kind)
    return lowered


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False,
               remat: str = "full", compile_: bool = True,
               unroll: int = 1, microbatches: int = 1,
               derive: bool = True) -> Dict:
    """Lower+compile one cell; return the analysis record."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    dist = _dist_for(cfg, mesh)
    chips = mesh.size
    t0 = time.time()
    lowered = _lower(cfg, shape, mesh, dist, remat, unroll, microbatches)
    t_lower = time.time() - t0
    record: Dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": list(mesh.devices.shape),
        "mesh_axes": list(mesh.axis_names),
        "chips": chips,
        "kind": shape.kind,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "lower_seconds": round(t_lower, 2),
        "unroll": unroll,
        "remat": remat,
        "microbatches": microbatches,
    }
    if not compile_:
        return record

    t1 = time.time()
    compiled = lowered.compile()
    record["compile_seconds"] = round(time.time() - t1, 2)
    record["memory"] = hlo_analysis.memory_summary(compiled)
    record["cost"] = hlo_analysis.cost_summary(compiled)
    text = compiled.as_text()
    coll = hlo_analysis.collective_stats(text)
    record["collectives"] = {
        "total_bytes": coll.total_bytes,
        "by_kind_bytes": coll.bytes_by_kind,
        "by_kind_count": coll.count_by_kind,
    }
    if derive:
        try:
            record["derived"] = derive_costs(arch, shape_name, multi_pod,
                                             remat=remat)
        except Exception as e:  # derivation is best-effort
            record["derived_error"] = f"{type(e).__name__}: {e}"
    return record


def _exact_cost_record(cfg, shape, mesh, dist, remat: str) -> Dict:
    """cost_analysis + collective bytes with every inner scan removed."""
    with flags.exact_cost_mode():
        lowered = _lower(cfg, shape, mesh, dist, remat=remat,
                         unroll=max(cfg.n_layers, 1), microbatches=1)
        compiled = lowered.compile()
    cost = hlo_analysis.cost_summary(compiled)
    coll = hlo_analysis.collective_stats(compiled.as_text())
    return {
        "flops": cost.get("flops", 0.0),
        "transcendentals": cost.get("transcendentals", 0.0),
        "bytes_accessed": cost.get("bytes_accessed", 0.0),
        "collective_bytes": float(coll.total_bytes),
        "collective_bytes_bf16_projected": float(coll.bf16_projected_bytes),
        "collective_by_kind": coll.bytes_by_kind,
    }


def derive_costs(arch: str, shape_name: str, multi_pod: bool = False,
                 remat: str = "full") -> Dict:
    """Exact per-cell cost via 1-layer/2-layer exact-mode compiles.

    XLA counts while-loop bodies once, so scan-mode cost_analysis
    undercounts by ~n_layers (and by the inner attention/CE/SSD chunk
    counts).  In exact mode every scan is unrolled/bypassed; costs of the
    homogeneous layer stack extrapolate exactly:
        total(L) = cost(L=1) + (L - 1) * [cost(L=2) - cost(L=1)].
    """
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    dist = _dist_for(cfg, mesh)
    keys = ("flops", "transcendentals", "bytes_accessed",
            "collective_bytes")
    # (two-class path also extrapolates the bf16-projected metric)

    def derive_homogeneous(cfg_h):
        recs = {}
        for l in (1, 2):
            over = {"n_layers": l}
            if cfg_h.is_encoder_decoder:
                over["n_enc_layers"] = l
            cfg_l = dataclasses.replace(cfg_h, **over)
            recs[l] = _exact_cost_record(cfg_l, shape, mesh, dist, remat)
        return recs

    big_l = cfg.n_layers
    out: Dict = {}
    if cfg.attn_window is not None and cfg.full_attn_every:
        # heterogeneous stack (hymba): derive per-layer costs separately
        # for the full-attention and banded-SWA layer classes
        out["method"] = "exact_mode_two_class_extrapolation"
        full_cfg = dataclasses.replace(cfg, attn_window=None)
        swa_cfg = dataclasses.replace(cfg, full_attn_every=0)
        rf = derive_homogeneous(full_cfg)
        rs = derive_homogeneous(swa_cfg)
        n_full = len({0, big_l // 2, big_l - 1})
        n_swa = big_l - n_full
        for key in keys + ("collective_bytes_bf16_projected",):
            d_full = max(rf[2][key] - rf[1][key], 0.0)
            d_swa = max(rs[2][key] - rs[1][key], 0.0)
            base = rf[1][key] - d_full   # non-layer (embed/CE) part
            out[key] = base + n_full * d_full + n_swa * d_swa
            out[f"{key}_per_layer"] = d_swa
        out["collective_by_kind_L2"] = rf[2]["collective_by_kind"]
        return out

    out["method"] = "exact_mode_L1_L2_extrapolation"
    recs = derive_homogeneous(cfg)
    for key in keys + ("collective_bytes_bf16_projected",):
        delta = recs[2][key] - recs[1][key]
        if delta < 0:
            # SPMD made different global resharding choices at L=1 vs 2;
            # fall back to the L=2 measurement scaled (lower bound).
            out[f"{key}_unstable"] = True
            out[key] = recs[2][key] * big_l / 2.0
            out[f"{key}_per_layer"] = recs[2][key] / 2.0
        else:
            out[key] = recs[1][key] + (big_l - 1) * delta
            out[f"{key}_per_layer"] = delta
    out["collective_by_kind_L2"] = recs[2]["collective_by_kind"]
    return out


def save_record(record: Dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    mesh_tag = "x".join(str(d) for d in record["mesh"])
    fname = f"{record['arch']}__{record['shape']}__{mesh_tag}.json"
    path = os.path.abspath(os.path.join(RESULTS_DIR, fname))
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="0 = auto (8 for train shapes, 1 otherwise)")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--unroll", type=int, default=1,
                    help="layer-scan unroll (full unroll = exact HLO flops)")
    ap.add_argument("--no-derive", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        cells = [(a, s) for a, s, _, _ in configs.all_cells()]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape (or --all) required")
        skipped, reason = configs.cell_skipped(args.arch, args.shape)
        if skipped:
            print(f"SKIP {args.arch} x {args.shape}: {reason}")
            return
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        tag = f"{arch} x {shape} x {'2x16x16' if args.multi_pod else '16x16'}"
        mb = args.microbatches or (8 if SHAPES[shape].kind == "train" else 1)
        try:
            rec = lower_cell(arch, shape, multi_pod=args.multi_pod,
                             remat=args.remat, compile_=not args.no_compile,
                             unroll=args.unroll,
                             microbatches=mb,
                             derive=not args.no_derive)
            path = save_record(rec)
            mem = rec.get("memory", {})
            per_dev = (mem.get("argument_size_in_bytes", 0)
                       + mem.get("temp_size_in_bytes", 0)) / 2**30
            flops = rec.get("derived", {}).get(
                "flops", rec.get("cost", {}).get("flops", 0))
            coll = rec.get("derived", {}).get(
                "collective_bytes",
                rec.get("collectives", {}).get("total_bytes", 0))
            print(f"OK   {tag}: lower={rec['lower_seconds']}s "
                  f"compile={rec.get('compile_seconds', '-')}s "
                  f"mem/dev={per_dev:.2f}GiB flops={flops:.3e} "
                  f"coll={coll:.3e}B -> {os.path.basename(path)}")
        except Exception as e:
            failures += 1
            print(f"FAIL {tag}: {type(e).__name__}: {e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
