"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else sees the real (single) device.
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (possibly fake) devices exist."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"need {data * model} devices, have {n}")
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axes(mesh: jax.sharding.Mesh) -> Tuple[Tuple[str, ...], str]:
    """(dp_axes, tp_axis) for any of our meshes."""
    names = mesh.axis_names
    tp = "model"
    dp = tuple(n for n in names if n != tp)
    return dp, tp
