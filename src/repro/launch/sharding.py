"""Sharding rules: parameter/optimizer/batch/cache PartitionSpecs.

Megatron-pattern TP over the ``model`` axis + FSDP (ZeRO-3) over the data
axes, by parameter-path pattern matching:

  embed/lm_head (V, D)         -> (model, dp)     vocab-TP
  wq/wk/wv/w1/w3 (D, F)        -> (dp, model)     column-parallel
  wo/w2 (F, D)                 -> (model, dp)     row-parallel
  moe w1/w3 (E, D, F)          -> (model, dp, -)  expert-parallel + FSDP
  moe w2 (E, F, D)             -> (model, -, dp)
  router / norms / mamba small -> replicated
  stacked layer leading dim L  -> never sharded

Optimizer moments inherit the parameter specs (ZeRO-1+3).  KV caches
shard batch over dp and sequence over model (decode shapes can't shard
heads: kv_heads < 16 for several archs).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _dims_divisible(shape, spec, mesh: Mesh) -> bool:
    for dim, names in zip(shape, spec):
        if names is None:
            continue
        ns = names if isinstance(names, tuple) else (names,)
        size = int(np.prod([mesh.shape[n] for n in ns]))
        if dim % size:
            return False
    return True


def _maybe(spec: P, shape, mesh: Mesh) -> P:
    """Fall back to replication for any axis that doesn't divide."""
    out = []
    for dim, names in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if names is None:
            out.append(None)
            continue
        ns = names if isinstance(names, tuple) else (names,)
        size = int(np.prod([mesh.shape[n] for n in ns]))
        out.append(names if dim % size == 0 else None)
    return P(*out)


def param_pspec(path: str, shape, mesh: Mesh, dp, tp) -> P:
    """PartitionSpec for one parameter, by path substring matching."""
    nd = len(shape)
    lead = 1 if "layers" in path and nd >= 2 else 0  # stacked layer dim

    def with_lead(*spec):
        return _maybe(P(*([None] * lead), *spec), shape, mesh)

    if "embed" in path or "lm_head" in path:
        return _maybe(P(tp, dp), shape, mesh)
    if "moe" in path:
        if "router" in path:
            return P(*([None] * nd))
        if path.endswith("w2"):
            return with_lead(tp, None, dp)
        if "shared" in path:
            return with_lead(dp, tp) if path.endswith(("w1", "w3")) \
                else with_lead(tp, dp)
        return with_lead(tp, dp, None)          # moe w1/w3 (E, D, F)
    if "mamba" in path:
        if "x_proj" in path or "z_proj" in path:
            return with_lead(dp, tp)       # column-parallel on d_inner
        if "out_proj" in path:
            return with_lead(tp, dp)       # row-parallel (psum on exit)
        if "bc_proj" in path or "dt_proj" in path:
            return with_lead(dp, None)
        if "conv_x" in path:
            return with_lead(None, tp) if nd >= 2 + lead else \
                with_lead(tp)
        return P(*([None] * nd))
    if path.endswith(("wq", "wk", "wv", "w1", "w3")):
        return with_lead(dp, tp)
    if path.endswith(("wo", "w2")):
        return with_lead(tp, dp)
    return P(*([None] * nd))                    # norms, scalars, biases


def _path_str(path) -> str:
    return "/".join(
        str(getattr(pp, "key", getattr(pp, "idx", pp))) for pp in path
    )


def param_shardings(params_shape, mesh: Mesh):
    """NamedSharding pytree matching a params (shape) pytree."""
    from repro.launch.mesh import mesh_axes

    dp, tp = mesh_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0] if dp else None

    def one(path, leaf):
        spec = param_pspec(_path_str(path), leaf.shape, mesh, dp, tp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_state_shardings(opt_state_shape, mesh: Mesh):
    """Optimizer state: moments inherit param sharding; step replicated."""
    from repro.launch.mesh import mesh_axes

    dp, tp = mesh_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0] if dp else None

    def one(path, leaf):
        ps = _path_str(path)
        if leaf.ndim == 0 or "step" in ps:
            return NamedSharding(mesh, P())
        # moments live under .m / .v with the same sub-path as the param
        spec = param_pspec(ps, leaf.shape, mesh, dp, tp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, opt_state_shape)


def batch_shardings(batch_shape, mesh: Mesh):
    """tokens/targets (B, S) -> batch over dp axes; frames likewise."""
    from repro.launch.mesh import mesh_axes

    dp, tp = mesh_axes(mesh)
    dp_t = dp if len(dp) > 1 else (dp[0] if dp else None)

    def one(path, leaf):
        spec = _maybe(P(dp_t), leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, batch_shape)


def cache_shardings(cache_shape, mesh: Mesh):
    """KV cache (L, B, H, S, D): batch over dp, sequence over model.
    SSM state (L, B, H, N, P): batch over dp only."""
    from repro.launch.mesh import mesh_axes

    dp, tp = mesh_axes(mesh)
    dp_t = dp if len(dp) > 1 else (dp[0] if dp else None)

    def one(path, leaf):
        ps = _path_str(path)
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if ps.startswith(("k", "v", "cross")) and leaf.ndim == 5:
            spec = _maybe(P(None, dp_t, None, tp, None), leaf.shape, mesh)
        elif ps.startswith(("ssm", "conv")):
            spec = _maybe(P(None, dp_t), leaf.shape, mesh)
        else:
            spec = P(*([None] * leaf.ndim))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def logits_sharding(mesh: Mesh):
    from repro.launch.mesh import mesh_axes

    dp, tp = mesh_axes(mesh)
    dp_t = dp if len(dp) > 1 else (dp[0] if dp else None)
    return NamedSharding(mesh, P(dp_t, None, tp))
