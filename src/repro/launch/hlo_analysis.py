"""Compiled-HLO analysis: collective bytes, FLOPs, memory — roofline inputs.

``cost_analysis()`` gives HLO FLOPs and bytes-accessed; collective bytes
are NOT in it, so we parse the (stable)HLO text and sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (per the task spec).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.  %x = bf16[16,256,4096]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*\(?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVES) + r")[\(-]"
)
# tuple-result collectives: (bf16[...], bf16[...]) all-reduce(
_TUPLE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]
    # f32 payloads re-counted at 2 B/elem: XLA-CPU emulates bf16 GEMMs in
    # f32 and hoists the convert above the gather, inflating measured
    # collective bytes ~2x vs a TPU toolchain (where weights/activations
    # move as bf16).  The truth lies between total_bytes (raw, upper
    # bound) and bf16_projected_bytes (lower bound).
    bf16_projected_by_kind: Dict[str, int] = dataclasses.field(
        default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def bf16_projected_bytes(self) -> int:
        return sum(self.bf16_projected_by_kind.values()) or self.total_bytes

    def summary(self) -> str:
        parts = [
            f"{k}: n={self.count_by_kind[k]} bytes={v:,}"
            for k, v in sorted(self.bytes_by_kind.items())
        ]
        return "; ".join(parts) or "none"


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective op in the HLO text.

    (Result shape ~ moved payload for AG/AR/A2A; for reduce-scatter the
    *operand* is larger, but result-bytes is the per-chip traffic which
    is what the roofline term divides by link bandwidth.)
    """
    by_kind: Dict[str, int] = {}
    count: Dict[str, int] = {}
    proj: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        hit = None
        for c in _COLLECTIVES:
            if f" {c}(" in line or f" {c}-start(" in line:
                hit = c
                break
        if hit is None:
            continue
        # sum every shape on the lhs (covers tuple results)
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split(hit)[0]
        nbytes = 0
        pbytes = 0
        for dt, dims in _TUPLE_RE.findall(lhs):
            b = _shape_bytes(dt, dims)
            nbytes += b
            pbytes += b // 2 if dt == "f32" else b
        by_kind[hit] = by_kind.get(hit, 0) + nbytes
        count[hit] = count.get(hit, 0) + 1
        proj[hit] = proj.get(hit, 0) + pbytes
    return CollectiveStats(by_kind, count, proj)


def cost_summary(compiled) -> Dict[str, float]:
    """flops / bytes from compiled.cost_analysis() (robust to key variants)."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    out = {}
    for k in ("flops", "bytes accessed", "transcendentals",
              "optimal_seconds"):
        if k in ca:
            out[k.replace(" ", "_")] = float(ca[k])
    # per-space bytes accessed keys like 'bytes accessed0{}'
    for k, v in ca.items():
        if k.startswith("bytes accessed"):
            out.setdefault("bytes_accessed", float(ca.get("bytes accessed",
                                                          0.0)))
    return out


def memory_summary(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for attr in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes", "peak_memory_in_bytes",
    ):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = float(v)
    return out
