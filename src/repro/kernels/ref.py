"""Pure-jnp oracles for every kernel in this package.

These are the ground truth for all kernel tests (interpret-mode allclose)
and double as the XLA execution path used by the models on non-TPU backends.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.dataflow import EPILOGUE_ACTIVATIONS

# the single name->fn table for epilogue activations; the in-kernel
# fusion (kernels.matmul_df) uses this same mapping
ACTIVATION_FNS = {
    "relu": lambda x: jnp.maximum(x, 0.0),
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}
assert set(ACTIVATION_FNS) == set(EPILOGUE_ACTIVATIONS)


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    acc = jnp.int32 if jnp.issubdtype(a.dtype, jnp.integer) else jnp.float32
    out = jnp.dot(a, b, preferred_element_type=acc)
    return out.astype(out_dtype or acc)


def conv2d_ref(
    x: jax.Array,          # (N, H, W, Cin)
    w: jax.Array,          # (fh, fw, Cin, Cout)
    stride: int = 1,
    out_dtype=None,
) -> jax.Array:
    """Direct NHWC convolution, VALID padding, via dot_general (pure jnp)."""
    n, ih, iw, cin = x.shape
    fh, fw, _, cout = w.shape
    oh = (ih - fh) // stride + 1
    ow = (iw - fw) // stride + 1
    acc = jnp.int32 if jnp.issubdtype(x.dtype, jnp.integer) else jnp.float32
    out = jnp.zeros((n, oh, ow, cout), acc)
    for ky in range(fh):
        for kx in range(fw):
            xs = x[:, ky : ky + (oh - 1) * stride + 1 : stride,
                   kx : kx + (ow - 1) * stride + 1 : stride, :]
            out = out + jnp.einsum(
                "nhwc,co->nhwo", xs.astype(acc), w[ky, kx].astype(acc),
                preferred_element_type=acc,
            )
    return out.astype(out_dtype or acc)


def conv2d_fused_ref(
    x: jax.Array,          # (N, H, W, Cin)
    w: jax.Array,          # (fh, fw, Cin, Cout)
    stride: int = 1,
    bias: Optional[jax.Array] = None,       # (1, Cout)
    scale: Optional[jax.Array] = None,      # (1, 1) or (1, Cout)
    residual: Optional[jax.Array] = None,   # (N, oh, ow, Cout)
    activation: Optional[str] = None,
    out_dtype=None,
) -> jax.Array:
    """Fused-epilogue conv oracle: act(scale * conv + bias) + residual.

    Epilogue arithmetic runs in float32 (matching the in-kernel fusion);
    ``bias``/``scale``/``residual`` may be any broadcastable shape.
    """
    out = conv2d_ref(x, w, stride).astype(jnp.float32)
    if scale is not None:
        out = out * scale.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    if activation is not None:
        out = ACTIVATION_FNS[activation](out)
    if residual is not None:
        out = out + residual.astype(jnp.float32)
    return out.astype(out_dtype or jnp.float32)


def grouped_conv2d_ref(
    x: jax.Array,          # (N, H, W, Cin)
    w: jax.Array,          # (fh, fw, Cin//groups, Cout)
    stride: int = 1,
    groups: int = 1,
    out_dtype=None,
) -> jax.Array:
    """Grouped conv oracle: per-group direct conv, concatenated.

    groups == Cin == Cout is depthwise (use depthwise_conv2d_ref for the
    fast path)."""
    n, ih, iw, cin = x.shape
    fh, fw, cg, cout = w.shape
    assert cin % groups == 0 and cout % groups == 0 and cg == cin // groups
    outs = []
    og = cout // groups
    for g in range(groups):
        xg = x[..., g * cg : (g + 1) * cg]
        wg = w[..., g * og : (g + 1) * og]
        outs.append(conv2d_ref(xg, wg, stride, out_dtype))
    return jnp.concatenate(outs, axis=-1)


def depthwise_conv2d_ref(
    x: jax.Array,          # (N, H, W, C)
    w: jax.Array,          # (fh, fw, C)
    stride: int = 1,
    out_dtype=None,
) -> jax.Array:
    """Depthwise conv oracle (one filter per channel), VALID padding."""
    n, ih, iw, c = x.shape
    fh, fw, _ = w.shape
    oh = (ih - fh) // stride + 1
    ow = (iw - fw) // stride + 1
    acc = jnp.int32 if jnp.issubdtype(x.dtype, jnp.integer) else jnp.float32
    out = jnp.zeros((n, oh, ow, c), acc)
    for ky in range(fh):
        for kx in range(fw):
            xs = x[:, ky : ky + (oh - 1) * stride + 1 : stride,
                   kx : kx + (ow - 1) * stride + 1 : stride, :]
            out = out + xs.astype(acc) * w[ky, kx].astype(acc)
    return out.astype(out_dtype or acc)


def attention_ref(
    q: jax.Array,              # (B, Hq, Sq, D)
    k: jax.Array,              # (B, Hkv, Skv, D)  float, or int8 w/ scales
    v: jax.Array,              # (B, Hkv, Skv, D)
    causal: bool = True,
    window: Optional[jax.Array] = None,   # static int or traced scalar
    scale: Optional[float] = None,
    kv_len: Optional[jax.Array] = None,   # valid KV prefix (traced ok)
    k_scale: Optional[jax.Array] = None,  # (B, Hkv, Skv, 1) f32 dequant
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """GQA attention oracle: causal mask, sliding window (static or
    traced), valid-KV-prefix masking for padded cache buffers, and
    int8-KV dequantization via per-position scales.

    q rows right-align against the valid KV length (``kv_len``
    defaulting to ``Skv``), so a cached decode step is ``sq=1`` over the
    padded cache with ``kv_len = cache_index + 1``.  The dequant is
    *folded* — ``k_scale`` multiplies the logits and ``v_scale`` the
    probabilities (exactly equal to scaling K/V rows, since scales are
    per position) — so no full-precision copy of the cache is ever
    materialized; the models' XLA escape hatch relies on this shape.
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qg = q.reshape(b, hkv, group, sq, d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if k_scale is not None:
        logits = logits * k_scale[..., 0][:, :, None, None, :]
    skv = k.shape[2]
    kv_valid = skv if kv_len is None else kv_len
    if getattr(kv_valid, "ndim", 0) == 1:
        # per-batch-row valid lengths (ragged decode): (B, sq, skv) mask
        kv_col = kv_valid[:, None, None]
        qpos = jnp.arange(sq)[None, :, None] + (kv_col - sq)
        kpos = jnp.arange(skv)[None, None, :]
        mask = kpos < kv_col
    else:
        qpos = jnp.arange(sq)[:, None] + (kv_valid - sq)  # right-aligned
        kpos = jnp.arange(skv)[None, :]
        mask = kpos < kv_valid
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    mask = (mask[:, None, None] if mask.ndim == 3
            else mask[None, None, None])
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)        # fully-masked rows
    if v_scale is not None:
        p = p * v_scale[..., 0][:, :, None, None, :]
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def banded_swa_attention_ref(q, k, v, window: int, scale) -> jax.Array:
    """Causal sliding-window attention via static banding (oracle).

    Keys are blocked at the window size; each q block attends to its own
    and the previous key block (2w keys) — O(S * 2w * d) compute instead
    of the O(S^2 * d) a masked full attention spends.  Requires a STATIC
    window, self-attention (q/kv same positions), no cache.

    Demoted from ``models.layers._banded_swa_attention`` (PR 5): the
    runtime banding now happens inside the Pallas kernel grid
    (``kernels.attention_df``); this form survives as the test oracle
    and as the exact-cost-mode FLOP-accounting path (dry-run only —
    XLA's cost analysis needs the banded einsums materialized to count
    windowed attention honestly).
    """
    from repro.models import flags

    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    w = int(window)
    nb = -(-s // w)
    pad = nb * w - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    qb = q.reshape(b, hkv, g, nb, w, d)
    kb = k.reshape(b, hkv, nb, w, d)
    vb = v.reshape(b, hkv, nb, w, d)
    k_prev = jnp.roll(kb, 1, axis=2)
    v_prev = jnp.roll(vb, 1, axis=2)
    kband = jnp.concatenate([k_prev, kb], axis=3)        # (b,hkv,nb,2w,d)
    vband = jnp.concatenate([v_prev, vb], axis=3)
    qpos = jnp.arange(w)[:, None]
    kpos = jnp.arange(2 * w)[None, :] - w                 # relative
    band_mask = (kpos <= qpos) & (kpos > qpos - w)        # (w, 2w)
    first_mask = band_mask & (kpos >= 0)                  # block 0: no wrap

    def one_block(q_i, k_i, v_i, m_i):
        # q_i (b,hkv,g,w,d); k_i/v_i (b,hkv,2w,d); m_i (w,2w)
        lg = jnp.einsum("bhgqd,bhkd->bhgqk", q_i.astype(jnp.float32),
                        k_i.astype(jnp.float32)) * scale
        lg = jnp.where(m_i[None, None, None], lg, -jnp.inf)
        p = jax.nn.softmax(lg, axis=-1)
        return jnp.einsum("bhgqk,bhkd->bhgqd", p, v_i.astype(jnp.float32))

    if flags.EXACT_COST_MODE:
        # vectorized over blocks (exact flop accounting; memory unused)
        is_first = (jnp.arange(nb) == 0)[:, None, None]
        mask = jnp.where(is_first, first_mask[None], band_mask[None])
        out = jax.vmap(one_block, in_axes=(3, 2, 2, 0), out_axes=3)(
            qb, kband, vband, mask)
        out = out.reshape(b, hq, nb * w, d)[:, :, :s]
        return out.astype(q.dtype)

    # scan over blocks — live memory O(b*h*w*2w)
    masks = jnp.where((jnp.arange(nb) == 0)[:, None, None],
                      first_mask[None], band_mask[None])

    def step(_, inp):
        q_i, k_i, v_i, m_i = inp
        return None, one_block(q_i, k_i, v_i, m_i)

    _, outs = jax.lax.scan(
        jax.checkpoint(step), None,
        (qb.transpose(3, 0, 1, 2, 4, 5),
         kband.transpose(2, 0, 1, 3, 4),
         vband.transpose(2, 0, 1, 3, 4), masks),
    )                                                     # (nb,b,hkv,g,w,d)
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, nb * w, d)
    return out[:, :, :s].astype(q.dtype)


def binary_matmul_ref(a_packed: jax.Array, b_packed: jax.Array,
                      n_bits: int) -> jax.Array:
    """+-1 GEMM on bit-packed operands: dot = n_bits - 2*popcount(xor).

    a_packed: (M, Kp) uint32, b_packed: (Kp, N) uint32 where Kp = K/32 and
    ``n_bits`` = K (the true, pre-packing reduction depth).
    """
    x = jnp.bitwise_xor(a_packed[:, :, None], b_packed[None, :, :])
    pops = jax.lax.population_count(x).astype(jnp.int32).sum(axis=1)
    return n_bits - 2 * pops


def pack_binary(x: jax.Array, axis: int = -1) -> jax.Array:
    """Pack a +-1 (or {0,1}) tensor into uint32 along ``axis`` (len % 32 == 0)."""
    bits = (x > 0).astype(jnp.uint32)
    bits = jnp.moveaxis(bits, axis, -1)
    *lead, kdim = bits.shape
    assert kdim % 32 == 0, kdim
    bits = bits.reshape(*lead, kdim // 32, 32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    packed = (bits * weights).sum(axis=-1).astype(jnp.uint32)
    return jnp.moveaxis(packed, -1, axis)


def unpack_binary(packed: jax.Array, axis: int = -1,
                  dtype=jnp.float32) -> jax.Array:
    """Inverse of ``pack_binary``: uint32 words -> a +-1 tensor whose
    ``axis`` is 32x longer (bit 1 -> +1, bit 0 -> -1)."""
    p = jnp.moveaxis(packed, axis, -1)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (p[..., None] >> shifts) & jnp.uint32(1)        # (..., kp, 32)
    *lead, kp, _ = bits.shape
    pm1 = (2 * bits.astype(jnp.int32) - 1).reshape(*lead, kp * 32)
    return jnp.moveaxis(pm1.astype(dtype), -1, axis)


def binary_epilogue_ref(
    dot: jax.Array,                          # (M, N) int32 xnor-popcount dot
    scale: Optional[jax.Array] = None,       # (1, 1) or (1, N) float32
    bias: Optional[jax.Array] = None,        # (1, N) float32
    residual: Optional[jax.Array] = None,    # (M, N)
    binarize: bool = False,
    out_dtype=None,
) -> jax.Array:
    """The fused binary tail: ``y = scale * dot + bias + residual`` then
    ``sign(y)`` (y >= 0 -> +1) when ``binarize``.  Float32 arithmetic in
    exactly the in-kernel order, with an optimization barrier after each
    stage pinning this oracle to separate per-stage rounding.  Binarized
    (+-1) outputs match the kernel bitwise; pre-sign float images may
    differ by 1 ulp where XLA contracts the kernel's scale/bias stage
    into an FMA (tests/test_binary)."""
    x = dot.astype(jnp.float32)
    if scale is not None:
        x = jax.lax.optimization_barrier(x * scale.astype(jnp.float32))
    if bias is not None:
        x = jax.lax.optimization_barrier(x + bias.astype(jnp.float32))
    if residual is not None:
        x = jax.lax.optimization_barrier(x + residual.astype(jnp.float32))
    if binarize:
        out = jnp.where(x >= 0, 1, -1)
        return out.astype(out_dtype or jnp.int8)
    return x.astype(out_dtype or jnp.float32)


def binary_matmul_fused_ref(
    a_packed: jax.Array, b_packed: jax.Array, n_bits: int,
    scale: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    binarize: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Fused binary GEMM oracle: the xnor-popcount dot through
    ``binary_epilogue_ref``."""
    return binary_epilogue_ref(
        binary_matmul_ref(a_packed, b_packed, n_bits),
        scale=scale, bias=bias, residual=residual, binarize=binarize,
        out_dtype=out_dtype,
    )


def binary_im2col(x_packed: jax.Array, fh: int, fw: int,
                  stride: int = 1) -> jax.Array:
    """Patch-extract a packed NHWC image for the implicit-GEMM view.

    x_packed: (N, H, W, Cp) uint32 -> (N, oh, ow, fh*fw*Cp) uint32, tap
    order (ky, kx, cp) matching a (fh, fw, Cp, Cout) filter reshaped to
    (fh*fw*Cp, Cout).
    """
    n, ih, iw, cp = x_packed.shape
    oh = (ih - fh) // stride + 1
    ow = (iw - fw) // stride + 1
    taps = []
    for ky in range(fh):
        for kx in range(fw):
            taps.append(
                x_packed[:, ky : ky + (oh - 1) * stride + 1 : stride,
                         kx : kx + (ow - 1) * stride + 1 : stride, :]
            )
    return jnp.concatenate(taps, axis=-1)


def binary_conv2d_ref(
    x_packed: jax.Array,   # (N, H, W, Cp) uint32
    w_packed: jax.Array,   # (fh, fw, Cp, Cout) uint32
    stride: int = 1,
    n_bits: Optional[int] = None,   # true reduction depth fh*fw*cin
    scale: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,   # (N, oh, ow, Cout)
    binarize: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Binary conv oracle via explicit im2col + the packed GEMM oracle.

    ``n_bits`` defaults to every packed bit (fh*fw*32*Cp); pass
    ``fh*fw*cin`` when the true channel count doesn't fill the last word.
    """
    n, ih, iw, cp = x_packed.shape
    fh, fw, _, cout = w_packed.shape
    oh = (ih - fh) // stride + 1
    ow = (iw - fw) // stride + 1
    if n_bits is None:
        n_bits = fh * fw * 32 * cp
    cols = binary_im2col(x_packed, fh, fw, stride)
    a = cols.reshape(n * oh * ow, fh * fw * cp)
    b = w_packed.reshape(fh * fw * cp, cout)
    res2 = (residual.reshape(n * oh * ow, cout)
            if residual is not None else None)
    if scale is None and bias is None and res2 is None and not binarize:
        out = binary_matmul_ref(a, b, n_bits)   # raw int32 dots
        if out_dtype is not None:
            out = out.astype(out_dtype)
    else:
        out = binary_matmul_fused_ref(
            a, b, n_bits, scale=scale, bias=bias, residual=res2,
            binarize=binarize, out_dtype=out_dtype,
        )
    return out.reshape(n, oh, ow, cout)


def quantize_int8(x: jax.Array, axis: int = -1):
    """Symmetric per-axis int8 quantization -> (q, scale)."""
    return quant.symmetric_int8(x, axis=axis)


def int8_matmul_ref(aq, bq, a_scale, b_scale) -> jax.Array:
    """Dequantized int8 GEMM oracle -> float32."""
    acc = jnp.dot(aq, bq, preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * a_scale * b_scale


def matmul_fused_ref(
    a: jax.Array,
    b: jax.Array,
    bias: Optional[jax.Array] = None,
    scale: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    out_dtype=None,
) -> jax.Array:
    """Fused-epilogue GEMM oracle: act(scale * (a @ b) + bias) + residual.

    Epilogue arithmetic runs in float32 (matching the in-kernel fusion);
    ``bias``/``scale``/``residual`` may be any broadcastable shape.
    """
    acc = jnp.int32 if jnp.issubdtype(a.dtype, jnp.integer) else jnp.float32
    x = jnp.dot(a, b, preferred_element_type=acc).astype(jnp.float32)
    if scale is not None:
        x = x * scale.astype(jnp.float32)
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    if activation is not None:
        x = ACTIVATION_FNS[activation](x)
    if residual is not None:
        x = x + residual.astype(jnp.float32)
    return x.astype(out_dtype or jnp.float32)


# ---------------------------------------------------------------------------
# Sub-byte packed-weight oracles (kernels/pack.py).  The kernel contract is
# *bit-exactness* against dequantize-then-matmul: int8 x int8 -> int32
# accumulation is exact regardless of blocking, the outlier compensation
# restores the exact unclipped codes, and the scale epilogue is one f32
# multiply — so these oracles pin the packed kernels bitwise, not allclose.
# ---------------------------------------------------------------------------


def pack_roundtrip(w: jax.Array, bits: int = 4, group_size: int = 1,
                   max_outliers: Optional[int] = None) -> jax.Array:
    """Pack ``w`` then dequantize back -> float32 reconstruction.

    The pack -> unpack leg is lossless on the int8 codes (outlier rows
    included); the only error left is the int8 quantization itself, so
    ``|w - pack_roundtrip(w)| <= scale / 2`` elementwise.
    """
    from repro.kernels import pack

    return pack.dequantize(
        pack.pack_weights(w, bits=bits, group_size=group_size,
                          max_outliers=max_outliers))


def matmul_packed_ref(
    aq: jax.Array,                    # (M, K) int8 activations
    pw,                               # pack.PackedWeights
    a_scale: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    out_dtype=None,
) -> jax.Array:
    """Dequantize-then-matmul oracle for ``ops.matmul_packed``.

    Unpacks the exact int8 codes (outlier deltas scattered back), runs
    the int32 GEMM, and applies the same f32 epilogue as the fused
    kernel: ``act((a_scale * w_scale) * acc + bias) + residual``.
    """
    from repro.kernels import pack

    q, w_scale = pack.unpack_weights(pw)  # exact (k, n) int8
    scale = w_scale if a_scale is None else (
        jnp.asarray(a_scale, jnp.float32) * w_scale)
    return matmul_fused_ref(
        aq, q, bias=bias, scale=scale, residual=residual,
        activation=activation, out_dtype=out_dtype)


def conv2d_packed_ref(
    xq: jax.Array,                    # (N, H, W, Cin) int8
    pcw,                              # pack.PackedConvWeights
    stride: int = 1,
    x_scale: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    out_dtype=None,
) -> jax.Array:
    """Dequantize-then-conv oracle for ``ops.conv2d_packed``."""
    from repro.kernels import pack

    q, w_scale = pack.unpack_conv_weights(pcw)  # exact (fh, fw, cin, K)
    scale = w_scale if x_scale is None else (
        jnp.asarray(x_scale, jnp.float32) * w_scale)
    return conv2d_fused_ref(
        xq, q, stride, bias=bias, scale=scale,
        residual=residual, activation=activation, out_dtype=out_dtype)
