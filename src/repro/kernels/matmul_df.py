"""Dataflow-parameterized tiled matmul Pallas kernels (TPU target).

Each ``DataflowSpec`` lowers to a distinct ``pl.pallas_call``:

  anchor=OS : grid (gm, gn, gk), k innermost; fp32/int32 VMEM scratch
              accumulator, output flushed to HBM once per tile.
  anchor=WS : grid (gk, gn, gm), weight tile constant while m sweeps;
              outputs read-modify-written via input_output_aliasing
              (reproducing the paper's WS output traffic).
  anchor=IS : grid (gm, gk, gn), input tile constant while n sweeps;
              outputs RMW like WS.

Auxiliary stationarities change BlockSpecs (and sometimes the grid order):
  input  STRIPE -> A block (bm, K), index (i, 0)   [resident per m-stripe]
  weight STRIPE -> B block (K, bn), index (0, j) with n outermost
  weight WHOLE  -> B block (K, N), index (0, 0)    [pinned for the call]
  output STRIPE -> O block (., .) held across the reduction sweep
                   (WS: (M, bn) per n; IS: (bm, N) per m), written once.

Validated against ``ref.matmul_ref`` in interpret mode (tests/test_matmul_df).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.dataflow import DataflowSpec, Residency, Stationarity, IS, OS, WS


def _acc_dtype(in_dtype) -> jnp.dtype:
    return jnp.int32 if jnp.issubdtype(in_dtype, jnp.integer) else jnp.float32


# ---------------------------------------------------------------------------
# OS-anchored kernels.
# ---------------------------------------------------------------------------
def _os_kernel(a_ref, b_ref, o_ref, acc_ref, *, gk: int, bk: int,
               a_stripe: bool, b_res: Residency, n_first: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    if a_stripe:  # A block is (bm, K): slice the active k panel
        a = a_ref[:, pl.dslice(k * bk, bk)]
    b = b_ref[...]
    if b_res == Residency.STRIPE:  # B block is (K, bn)
        b = b_ref[pl.dslice(k * bk, bk), :]
    elif b_res == Residency.WHOLE:  # B block is (K, N)
        j = pl.program_id(0) if n_first else pl.program_id(1)
        bn = acc_ref.shape[1]
        b = b_ref[pl.dslice(k * bk, bk), pl.dslice(j * bn, bn)]
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=acc_ref.dtype)

    @pl.when(k == gk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _build_os(a, b, out_dtype, spec: DataflowSpec, interpret: bool):
    (m, kdim), (_, n) = a.shape, b.shape
    bm, bk, bn = spec.block
    gm, gk, gn = m // bm, kdim // bk, n // bn
    res_a, res_b = spec.residency(IS), spec.residency(WS)
    a_stripe = res_a in (Residency.STRIPE, Residency.WHOLE)
    # weight-stripe residency needs n outermost so the stripe survives m
    n_first = res_b == Residency.STRIPE

    if n_first:
        grid = (gn, gm, gk)
        ij = lambda g0, g1: (g1, g0)  # (i, j) from (n-major grid)
    else:
        grid = (gm, gn, gk)
        ij = lambda g0, g1: (g0, g1)

    def a_map(g0, g1, k):
        i, _ = ij(g0, g1)
        return (i, 0) if a_stripe else (i, k)

    def b_map(g0, g1, k):
        _, j = ij(g0, g1)
        if res_b == Residency.WHOLE:
            return (0, 0)
        if res_b == Residency.STRIPE:
            return (0, j)
        return (k, j)

    def o_map(g0, g1, k):
        i, j = ij(g0, g1)
        return (i, j)

    a_block = (bm, kdim) if a_stripe else (bm, bk)
    b_block = {
        Residency.WHOLE: (kdim, n),
        Residency.STRIPE: (kdim, bn),
        Residency.STREAMED: (bk, bn),
    }[res_b]

    kernel = functools.partial(
        _os_kernel, gk=gk, bk=bk, a_stripe=a_stripe, b_res=res_b,
        n_first=n_first,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(a_block, a_map),
            pl.BlockSpec(b_block, b_map),
        ],
        out_specs=pl.BlockSpec((bm, bn), o_map),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), _acc_dtype(a.dtype))],
        interpret=interpret,
    )(a, b)


# ---------------------------------------------------------------------------
# WS/IS-anchored kernels.
#
# Pallas TPU requires revisited output blocks to be *consecutive* in the
# grid, so the basic (streamed-output) WS/IS dataflows — whose defining
# property is that outputs are read-modify-written once per reduction step —
# are lowered as one aliased pallas_call per reduction panel.  This is the
# paper's WS/IS memory behaviour verbatim: partial sums round-trip HBM.
# ---------------------------------------------------------------------------
def _rmw_panel_kernel(a_ref, b_ref, o_in_ref, o_ref, *, b_whole: bool,
                      k_panel: int, bk: int, bn: int, a_whole: bool,
                      m_minor: bool):
    """out(i,j) += A(i, k_panel) @ B(k_panel, j) for one reduction panel."""
    i = pl.program_id(1) if m_minor else pl.program_id(0)
    j = pl.program_id(0) if m_minor else pl.program_id(1)
    a = a_ref[...]
    if a_whole:  # A panel (M, bk) resident: slice the m rows
        bm = o_ref.shape[0]
        a = a_ref[pl.dslice(i * bm, bm), :]
    b = b_ref[...]
    if b_whole:  # B (K, N) resident: slice the active panel/tile
        b = b_ref[pl.dslice(k_panel * bk, bk), pl.dslice(j * bn, bn)]
    part = jnp.dot(a, b, preferred_element_type=o_ref.dtype)
    o_ref[...] = o_in_ref[...] + part


def _build_rmw(a, b, out_dtype, spec: DataflowSpec, interpret: bool,
               m_minor: bool):
    """Basic WS (m_minor=True) / IS (m_minor=False) with streamed outputs."""
    (m, kdim), (_, n) = a.shape, b.shape
    bm, bk, bn = spec.block
    gm, gk, gn = m // bm, kdim // bk, n // bn
    res_a = spec.residency(IS)
    res_b = spec.residency(WS)
    a_whole = m_minor and res_a in (Residency.STRIPE, Residency.WHOLE)
    b_whole = (not m_minor) and res_b == Residency.WHOLE

    a_block = (m, bk) if a_whole else (bm, bk)
    b_block = (kdim, n) if b_whole else (bk, bn)
    grid = (gn, gm) if m_minor else (gm, gn)

    out = jnp.zeros((m, n), out_dtype)
    for k in range(gk):
        if m_minor:  # WS: weight tile constant while m sweeps (inner)
            a_map = (lambda j, i, kk=k: (0, kk)) if a_whole else (
                lambda j, i, kk=k: (i, kk))
            b_map = (lambda j, i, kk=k: (kk, j))
            o_map = lambda j, i: (i, j)
        else:        # IS: input tile constant while n sweeps (inner)
            a_map = lambda i, j, kk=k: (i, kk)
            b_map = (lambda i, j: (0, 0)) if b_whole else (
                lambda i, j, kk=k: (kk, j))
            o_map = lambda i, j: (i, j)
        kernel = functools.partial(
            _rmw_panel_kernel, b_whole=b_whole, k_panel=k, bk=bk, bn=bn,
            a_whole=a_whole, m_minor=m_minor,
        )
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec(a_block, a_map),
                pl.BlockSpec(b_block, b_map),
                pl.BlockSpec((bm, bn), o_map),
            ],
            out_specs=pl.BlockSpec((bm, bn), o_map),
            out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
            input_output_aliases={2: 0},
            interpret=interpret,
        )(a, b, out)
    return out


def _ws_stripe_kernel(a_ref, b_ref, o_ref, *, bm: int):
    k, i = pl.program_id(1), pl.program_id(2)
    part = jnp.dot(a_ref[...], b_ref[...],
                   preferred_element_type=o_ref.dtype)
    sl = pl.dslice(i * bm, bm)

    @pl.when(k == 0)
    def _init():
        o_ref[sl, :] = part

    @pl.when(k != 0)
    def _acc():
        o_ref[sl, :] += part


def _build_ws(a, b, out_dtype, spec: DataflowSpec, interpret: bool):
    (m, kdim), (_, n) = a.shape, b.shape
    bm, bk, bn = spec.block
    gm, gk, gn = m // bm, kdim // bk, n // bn
    res_a, res_o = spec.residency(IS), spec.residency(OS)
    a_stripe = res_a in (Residency.STRIPE, Residency.WHOLE)

    if res_o in (Residency.STRIPE, Residency.WHOLE):
        # grid (gn, gk, gm): weight blocks each fetched once; output stripe
        # (M, bn) resident per n, written once — no RMW.
        kernel = functools.partial(_ws_stripe_kernel, bm=bm)
        return pl.pallas_call(
            kernel,
            grid=(gn, gk, gm),
            in_specs=[
                pl.BlockSpec((bm, bk), lambda j, k, i: (i, k)),
                pl.BlockSpec((bk, bn), lambda j, k, i: (k, j)),
            ],
            out_specs=pl.BlockSpec((m, bn), lambda j, k, i: (0, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
            interpret=interpret,
        )(a, b)

    # streamed outputs: RMW per reduction panel (the paper's WS traffic)
    return _build_rmw(a, b, out_dtype, spec, interpret, m_minor=True)


# ---------------------------------------------------------------------------
# IS-anchored kernels.
# ---------------------------------------------------------------------------
def _is_stripe_kernel(a_ref, b_ref, o_ref, *, b_whole: bool, bk: int, bn: int):
    k, j = pl.program_id(1), pl.program_id(2)
    b = b_ref[...]
    if b_whole:
        b = b_ref[pl.dslice(k * bk, bk), pl.dslice(j * bn, bn)]
    part = jnp.dot(a_ref[...], b, preferred_element_type=o_ref.dtype)
    sl = pl.dslice(j * bn, bn)

    @pl.when(k == 0)
    def _init():
        o_ref[:, sl] = part

    @pl.when(k != 0)
    def _acc():
        o_ref[:, sl] += part


def _build_is(a, b, out_dtype, spec: DataflowSpec, interpret: bool):
    (m, kdim), (_, n) = a.shape, b.shape
    bm, bk, bn = spec.block
    gm, gk, gn = m // bm, kdim // bk, n // bn
    res_b, res_o = spec.residency(WS), spec.residency(OS)
    b_whole = res_b == Residency.WHOLE
    b_block = (kdim, n) if b_whole else (bk, bn)
    b_map = (lambda i, k, j: (0, 0)) if b_whole else (lambda i, k, j: (k, j))

    if res_o in (Residency.STRIPE, Residency.WHOLE):
        kernel = functools.partial(
            _is_stripe_kernel, b_whole=b_whole, bk=bk, bn=bn
        )
        return pl.pallas_call(
            kernel,
            grid=(gm, gk, gn),
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, k, j: (i, k)),
                pl.BlockSpec(b_block, b_map),
            ],
            out_specs=pl.BlockSpec((bm, n), lambda i, k, j: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
            interpret=interpret,
        )(a, b)

    # streamed outputs: RMW per reduction panel (the paper's IS traffic)
    return _build_rmw(a, b, out_dtype, spec, interpret, m_minor=False)


# ---------------------------------------------------------------------------
# Entry point.
# ---------------------------------------------------------------------------
def matmul_df(
    a: jax.Array,
    b: jax.Array,
    spec: DataflowSpec,
    out_dtype: Optional[jnp.dtype] = None,
    interpret: bool = False,
) -> jax.Array:
    """(M, K) @ (K, N) under the given dataflow. Shapes must tile evenly
    by ``spec.block`` (use ``ops.matmul`` for automatic padding)."""
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad shapes {a.shape} @ {b.shape}")
    m, kdim = a.shape
    n = b.shape[1]
    bm, bk, bn = spec.block
    if m % bm or kdim % bk or n % bn:
        raise ValueError(
            f"shapes ({m},{kdim},{n}) must tile by block {spec.block}"
        )
    if out_dtype is None:
        out_dtype = _acc_dtype(a.dtype)
    build = {OS: _build_os, WS: _build_ws, IS: _build_is}[spec.anchor]
    return build(a, b, out_dtype, spec, interpret)
