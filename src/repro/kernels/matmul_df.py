"""Dataflow-parameterized tiled matmul Pallas kernels (TPU target).

Each ``DataflowSpec`` lowers to a distinct ``pl.pallas_call``:

  anchor=OS : grid (gm, gn, gk), k innermost; fp32/int32 VMEM scratch
              accumulator, output flushed to HBM once per tile.
  anchor=WS : grid (gn, gm, gk), n outermost so each weight column-panel
              is swept before moving on; output tile revisited across the
              in-grid reduction (consecutive revisits -> one HBM write).
  anchor=IS : grid (gm, gn, gk), m outermost so each input row-stripe is
              swept before moving on; outputs revisited like WS.

Auxiliary stationarities change BlockSpecs (and sometimes the grid order):
  input  STRIPE -> A block (bm, K), index (i, 0)   [resident per m-stripe]
  weight STRIPE -> B block (K, bn), index (0, j) with n outermost
  weight WHOLE  -> B block (K, N), index (0, 0)    [pinned for the call]
  output STRIPE -> O block (., .) held across the reduction sweep
                   (WS: (M, bn) per n; IS: (bm, N) per m), written once.

Single-dispatch WS/IS lowering: the basic (streamed-output) WS/IS
dataflows — whose defining property in the paper is that outputs are
read-modify-written once per reduction step — are lowered as ONE
``pallas_call`` with the reduction innermost in the grid: partial sums
accumulate exactly in a VMEM scratch of the accumulator dtype and only
the final, post-epilogue value reaches HBM.  This removes the
per-reduction-panel dispatch and the zeros-initialization round trip of
the previous lowering (one aliased call per k panel); the paper's
per-step partial-sum round trips move from HBM into VMEM.  The anchored
operand keeps its stationarity as a resident stripe — WS holds the
(K, bn) weight column-stripe per j, IS the (bm, K) input row-stripe per
i — so HBM traffic matches what ``cost_model.gemm_traffic`` charges the
anchor's reads; the model intentionally keeps the paper's RMW *output*
accounting for basic WS/IS so the explorer's ranking stays comparable
with the paper's tables.

Precision note: the OS and basic-WS/IS paths always accumulate in a
VMEM scratch of the accumulator dtype (exact for int8->int32), and the
output-stripe WS/IS writers do the same whenever an integer-input fused
epilogue is active — so every int8 path is bit-exact regardless of
reduction depth.  Float output-stripe variants accumulate in the output
dtype inside the revisited output block (the seed behaviour; exact at
the default float32 out_dtype).

Fused epilogues: every anchor can apply an ``Epilogue`` (dequant scale,
bias, activation, residual — ``core.dataflow.Epilogue``) in-register at
the point the accumulator is flushed: the OS scratch flush, the WS/IS
stripe writers' final reduction visit, and the single-dispatch RMW
path's last k step.  The raw accumulator never touches HBM; the one
output write carries the post-epilogue values.  Dequant scales may be
per-tensor (1, 1), per-output-column (1, N), or per-row (M, 1) — the
per-row form covers int8 per-activation-row quantization without
falling back to the unfused path.

Validated against ``ref.matmul_ref`` / ``ref.matmul_fused_ref`` in
interpret mode (tests/test_kernels_matmul, tests/test_fused_epilogue).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.dataflow import (
    DataflowSpec,
    Epilogue,
    Residency,
    Stationarity,
    IS,
    OS,
    WS,
)
from repro.kernels.pack import (
    WORD_BITS as _PLANE_K,
    WORD_NIBBLES as _PACK_K,
    unpack_block as _unpack_block,
)
from repro.kernels.ref import ACTIVATION_FNS as _ACT_FNS


def _acc_dtype(in_dtype) -> jnp.dtype:
    return jnp.int32 if jnp.issubdtype(in_dtype, jnp.integer) else jnp.float32


# ---------------------------------------------------------------------------
# Packed sub-byte weights (kernels/pack.py planes).
#
# When ``weight_bits`` is set, the B operand is the packed nibble plane
# (K/8, N) int32 — plus, at 5 bits, a (K/32, N) bit plane — and every
# anchor decompresses the active block to int8 lanes in VMEM at the
# load (``pack.unpack_block``) before the exact int8 dot.  The sparse
# outlier sidecar arrives as a precomputed rank-R compensation term
# ``comp = A[:, idx] @ delta`` (an (M, N) int32 operand blocked like the
# output) added to the accumulator at the epilogue flush, so the raw
# accumulator still never round-trips HBM.
# ---------------------------------------------------------------------------
class _Packed(NamedTuple):
    bits: int                       # code width: 4 or 5
    hi: Optional[jax.Array]         # (K/32, N) int32 bit plane (bits == 5)
    comp: Optional[jax.Array]       # (M, N) int32 outlier compensation


def _pop_packed(refs, wb: Optional[int], has_comp: bool):
    """Peel the bit-plane / compensation refs off the kernel's varargs."""
    bhi_ref = comp_ref = None
    if wb == 5:
        bhi_ref, refs = refs[0], refs[1:]
    if has_comp:
        comp_ref, refs = refs[0], refs[1:]
    return bhi_ref, comp_ref, refs


def _load_b(b_ref, bhi_ref, wb: Optional[int], b_res: Residency,
            k=None, bk: Optional[int] = None, j=None,
            bn: Optional[int] = None):
    """Read the active B panel under any residency, decompressing packed
    int32 words to int8 lanes in-register when ``wb`` is set."""
    if wb is None:
        b = b_ref[...]
        if b_res == Residency.STRIPE:    # B block is (K, bn)
            b = b_ref[pl.dslice(k * bk, bk), :]
        elif b_res == Residency.WHOLE:   # B block is (K, N)
            b = b_ref[pl.dslice(k * bk, bk), pl.dslice(j * bn, bn)]
        return b
    if b_res == Residency.STRIPE:
        rn, rh = bk // _PACK_K, bk // _PLANE_K
        w = b_ref[pl.dslice(k * rn, rn), :]
        h = bhi_ref[pl.dslice(k * rh, rh), :] if bhi_ref is not None else None
        rows = bk
    elif b_res == Residency.WHOLE:
        rn, rh = bk // _PACK_K, bk // _PLANE_K
        w = b_ref[pl.dslice(k * rn, rn), pl.dslice(j * bn, bn)]
        h = (bhi_ref[pl.dslice(k * rh, rh), pl.dslice(j * bn, bn)]
             if bhi_ref is not None else None)
        rows = bk
    else:
        w = b_ref[...]
        h = bhi_ref[...] if bhi_ref is not None else None
        rows = w.shape[0] * _PACK_K
    return _unpack_block(w, h, wb, rows)


def _packed_operands(pk: Optional[_Packed], b_block, b_map,
                     bm: int, bn: int, comp_map):
    """Extra pallas operands + BlockSpecs for the packed planes.

    The bit plane tiles exactly like the nibble plane with K rows
    divided by the per-word code count; the compensation term is blocked
    like the output."""
    if pk is None:
        return (), []
    arrs, specs = [], []
    if pk.hi is not None:
        arrs.append(pk.hi)
        specs.append(
            pl.BlockSpec((b_block[0] // _PLANE_K, b_block[1]), b_map))
    if pk.comp is not None:
        arrs.append(pk.comp)
        specs.append(pl.BlockSpec((bm, bn), comp_map))
    return tuple(arrs), specs


def _codes_block(pk: Optional[_Packed], b_block):
    if pk is None:
        return b_block
    return (b_block[0] // _PACK_K, b_block[1])


# ---------------------------------------------------------------------------
# Epilogue plumbing shared by all anchors.
#
# Operand order is canonical — (scale, bias, residual), each present iff
# its Epilogue flag is set — appended to the pallas_call inputs after A/B.
# ---------------------------------------------------------------------------
def _apply_epilogue(epi: Optional[Epilogue], acc, scale, bias, residual,
                    out_dtype):
    """y = act(scale * acc + bias) + residual, computed in float32."""
    if epi is None:
        return acc.astype(out_dtype)
    x = acc.astype(jnp.float32)
    if epi.scale:
        x = x * scale
    if epi.bias:
        x = x + bias
    if epi.activation is not None:
        x = _ACT_FNS[epi.activation](x)
    if epi.residual:
        x = x + residual.astype(jnp.float32)
    return x.astype(out_dtype)


def _read_epi(epi: Optional[Epilogue], refs: Sequence,
              res_rows=None, res_cols=None):
    """Read (scale, bias, residual) values from the kernel's epilogue refs.

    ``res_rows``/``res_cols`` slice the residual block for the stripe
    writers whose output block spans a full stripe.
    """
    if epi is None:
        return None, None, None
    it = iter(refs)
    scale = next(it)[...] if epi.scale else None
    bias = next(it)[...] if epi.bias else None
    residual = None
    if epi.residual:
        r = next(it)
        if res_rows is not None:
            residual = r[res_rows, :]
        elif res_cols is not None:
            residual = r[:, res_cols]
        else:
            residual = r[...]
    return scale, bias, residual


def _epi_operands(epi: Optional[Epilogue], scale, bias, residual):
    if epi is None:
        return ()
    ops = []
    if epi.scale:
        ops.append(scale)
    if epi.bias:
        ops.append(bias)
    if epi.residual:
        ops.append(residual)
    return tuple(ops)


def _epi_specs(epi: Optional[Epilogue], scale, bm: int, bn: int,
               scale_i, scale_j, bias_j, res_block, res_map):
    """BlockSpecs for the epilogue operands.

    ``scale_i``/``scale_j``/``bias_j``: index maps returning the output
    row-block index i (per-row scales) or column-block index j from the
    grid ids; ``res_block``/``res_map`` describe the residual block
    (matching the builder's output blocking).
    """
    if epi is None:
        return []
    specs = []
    if epi.scale:
        if scale.shape == (1, 1):        # per-tensor
            specs.append(pl.BlockSpec((1, 1), lambda *g: (0, 0)))
        elif scale.shape[1] == 1:        # per-row (M, 1)
            specs.append(pl.BlockSpec((bm, 1), scale_i))
        else:                            # per-column (1, N)
            specs.append(pl.BlockSpec((1, bn), scale_j))
    if epi.bias:
        specs.append(pl.BlockSpec((1, bn), bias_j))
    if epi.residual:
        specs.append(pl.BlockSpec(res_block, res_map))
    return specs


# ---------------------------------------------------------------------------
# OS-anchored kernels.
# ---------------------------------------------------------------------------
def _os_kernel(a_ref, b_ref, *refs, gk: int, bk: int, a_stripe: bool,
               b_res: Residency, n_first: bool, epi: Optional[Epilogue],
               wb: Optional[int] = None, has_comp: bool = False):
    bhi_ref, comp_ref, refs = _pop_packed(refs, wb, has_comp)
    o_ref, acc_ref = refs[-2], refs[-1]
    epi_refs = refs[:-2]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    if a_stripe:  # A block is (bm, K): slice the active k panel
        a = a_ref[:, pl.dslice(k * bk, bk)]
    j = pl.program_id(0) if n_first else pl.program_id(1)
    b = _load_b(b_ref, bhi_ref, wb, b_res, k, bk, j, acc_ref.shape[1])
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=acc_ref.dtype)

    @pl.when(k == gk - 1)
    def _flush():
        acc = acc_ref[...]
        if comp_ref is not None:   # outlier rows land at the flush
            acc = acc + comp_ref[...]
        scale, bias, residual = _read_epi(epi, epi_refs)
        o_ref[...] = _apply_epilogue(
            epi, acc, scale, bias, residual, o_ref.dtype
        )


def _build_os(a, b, out_dtype, spec: DataflowSpec, interpret: bool,
              epi, epi_args, pk: Optional[_Packed] = None):
    (m, kdim), (_, n) = a.shape, b.shape
    bm, bk, bn = spec.block
    gm, gk, gn = m // bm, kdim // bk, n // bn
    res_a, res_b = spec.residency(IS), spec.residency(WS)
    a_stripe = res_a in (Residency.STRIPE, Residency.WHOLE)
    # weight-stripe residency needs n outermost so the stripe survives m
    n_first = res_b == Residency.STRIPE

    if n_first:
        grid = (gn, gm, gk)
        ij = lambda g0, g1: (g1, g0)  # (i, j) from (n-major grid)
    else:
        grid = (gm, gn, gk)
        ij = lambda g0, g1: (g0, g1)

    def a_map(g0, g1, k):
        i, _ = ij(g0, g1)
        return (i, 0) if a_stripe else (i, k)

    def b_map(g0, g1, k):
        _, j = ij(g0, g1)
        if res_b == Residency.WHOLE:
            return (0, 0)
        if res_b == Residency.STRIPE:
            return (0, j)
        return (k, j)

    def o_map(g0, g1, k):
        i, j = ij(g0, g1)
        return (i, j)

    def j_map(g0, g1, k):
        _, j = ij(g0, g1)
        return (0, j)

    def i_map(g0, g1, k):
        i, _ = ij(g0, g1)
        return (i, 0)

    a_block = (bm, kdim) if a_stripe else (bm, bk)
    b_block = {
        Residency.WHOLE: (kdim, n),
        Residency.STRIPE: (kdim, bn),
        Residency.STREAMED: (bk, bn),
    }[res_b]

    packed, packed_specs = _packed_operands(pk, b_block, b_map, bm, bn, o_map)
    kernel = functools.partial(
        _os_kernel, gk=gk, bk=bk, a_stripe=a_stripe, b_res=res_b,
        n_first=n_first, epi=epi,
        wb=None if pk is None else pk.bits,
        has_comp=pk is not None and pk.comp is not None,
    )
    scale = epi_args[0] if (epi is not None and epi.scale) else None
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(a_block, a_map),
            pl.BlockSpec(_codes_block(pk, b_block), b_map),
            *packed_specs,
            *_epi_specs(epi, scale, bm, bn, i_map, j_map, j_map,
                        (bm, bn), o_map),
        ],
        out_specs=pl.BlockSpec((bm, bn), o_map),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), _acc_dtype(a.dtype))],
        interpret=interpret,
    )(a, b, *packed, *epi_args)


# ---------------------------------------------------------------------------
# WS/IS-anchored kernels, streamed outputs (single dispatch).
# ---------------------------------------------------------------------------
def _rmw_kernel(a_ref, b_ref, *refs, gk: int, bk: int, a_stripe: bool,
                b_res: Residency, m_minor: bool,
                epi: Optional[Epilogue], wb: Optional[int] = None,
                has_comp: bool = False):
    """Accumulate A(i,:) @ B(:,j) across the in-grid reduction.

    Grid is (outer, inner, gk) with the reduction innermost; the output
    block index (i, j) is constant across the k sweep, so its revisits
    are consecutive and only the final visit — accumulated exactly in
    the VMEM scratch, post-epilogue — reaches HBM.
    """
    bhi_ref, comp_ref, refs = _pop_packed(refs, wb, has_comp)
    o_ref, acc_ref = refs[-2], refs[-1]
    epi_refs = refs[:-2]
    k = pl.program_id(2)
    if m_minor:   # WS: j outermost, i sweeps before the next weight stripe
        j = pl.program_id(0)
    else:         # IS: i outermost, j sweeps before the next input stripe
        j = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    if a_stripe:  # A block is (bm, K): slice the active k panel
        a = a_ref[:, pl.dslice(k * bk, bk)]
    b = _load_b(b_ref, bhi_ref, wb, b_res, k, bk, j, acc_ref.shape[1])
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=acc_ref.dtype)

    @pl.when(k == gk - 1)
    def _flush():
        acc = acc_ref[...]
        if comp_ref is not None:   # outlier rows land at the flush
            acc = acc + comp_ref[...]
        scale, bias, residual = _read_epi(epi, epi_refs)
        o_ref[...] = _apply_epilogue(
            epi, acc, scale, bias, residual, o_ref.dtype
        )


def _build_rmw(a, b, out_dtype, spec: DataflowSpec, interpret: bool,
               m_minor: bool, epi, epi_args, pk: Optional[_Packed] = None):
    """Basic WS (m_minor=True) / IS (m_minor=False) with streamed outputs.

    One ``pallas_call`` regardless of the reduction depth: the k loop is
    the innermost grid dimension and the output block is revisited in
    place (no per-panel dispatch, no zeros-init round trip, no aliasing).
    The anchored operand stays stripe-resident — the WS weight
    column-stripe (K, bn) is fetched once per j, the IS input row-stripe
    (bm, K) once per i — matching the traffic ``cost_model.gemm_traffic``
    charges the anchor.
    """
    (m, kdim), (_, n) = a.shape, b.shape
    bm, bk, bn = spec.block
    gm, gk, gn = m // bm, kdim // bk, n // bn
    res_a = spec.residency(IS)
    res_b = spec.residency(WS)
    # the anchored operand is stripe-resident by construction
    a_stripe = (not m_minor) or res_a in (Residency.STRIPE, Residency.WHOLE)
    b_res = Residency.STRIPE if m_minor else res_b
    if b_res == Residency.STRIPE and not m_minor:
        b_res = Residency.STREAMED  # IS aux stripe on B cannot survive m

    a_block = (bm, kdim) if a_stripe else (bm, bk)
    b_block = {
        Residency.WHOLE: (kdim, n),
        Residency.STRIPE: (kdim, bn),
        Residency.STREAMED: (bk, bn),
    }[b_res]
    grid = (gn, gm, gk) if m_minor else (gm, gn, gk)

    if m_minor:   # grid ids (j, i, k)
        idx = lambda j, i, k: (i, j, k)
    else:         # grid ids (i, j, k)
        idx = lambda i, j, k: (i, j, k)

    def a_map(g0, g1, g2):
        i, _, k = idx(g0, g1, g2)
        return (i, 0) if a_stripe else (i, k)

    def b_map(g0, g1, g2):
        _, j, k = idx(g0, g1, g2)
        if b_res == Residency.WHOLE:
            return (0, 0)
        if b_res == Residency.STRIPE:
            return (0, j)
        return (k, j)

    def o_map(g0, g1, g2):
        i, j, _ = idx(g0, g1, g2)
        return (i, j)

    def j_map(g0, g1, g2):
        _, j, _ = idx(g0, g1, g2)
        return (0, j)

    def i_map(g0, g1, g2):
        i, _, _ = idx(g0, g1, g2)
        return (i, 0)

    packed, packed_specs = _packed_operands(pk, b_block, b_map, bm, bn, o_map)
    kernel = functools.partial(
        _rmw_kernel, gk=gk, bk=bk, a_stripe=a_stripe, b_res=b_res,
        m_minor=m_minor, epi=epi,
        wb=None if pk is None else pk.bits,
        has_comp=pk is not None and pk.comp is not None,
    )
    scale = epi_args[0] if (epi is not None and epi.scale) else None
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(a_block, a_map),
            pl.BlockSpec(_codes_block(pk, b_block), b_map),
            *packed_specs,
            *_epi_specs(epi, scale, bm, bn, i_map, j_map, j_map,
                        (bm, bn), o_map),
        ],
        out_specs=pl.BlockSpec((bm, bn), o_map),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), _acc_dtype(a.dtype))],
        interpret=interpret,
    )(a, b, *packed, *epi_args)


# ---------------------------------------------------------------------------
# WS-anchored, output-stripe kernels.
# ---------------------------------------------------------------------------
def _ws_stripe_kernel(a_ref, b_ref, *refs, bm: int, gk: int,
                      epi: Optional[Epilogue], use_acc: bool,
                      wb: Optional[int] = None, has_comp: bool = False):
    bhi_ref, comp_ref, refs = _pop_packed(refs, wb, has_comp)
    if use_acc:   # exact accumulation in a scratch of the acc dtype
        o_ref, acc_ref = refs[-2], refs[-1]
        epi_refs = refs[:-2]
    else:
        o_ref, acc_ref = refs[-1], None
        epi_refs = refs[:-1]
    buf = acc_ref if use_acc else o_ref
    k, i = pl.program_id(1), pl.program_id(2)
    part = jnp.dot(a_ref[...],
                   _load_b(b_ref, bhi_ref, wb, Residency.STREAMED),
                   preferred_element_type=buf.dtype)
    sl = pl.dslice(i * bm, bm)

    @pl.when(k == 0)
    def _init():
        buf[sl, :] = part

    @pl.when(k != 0)
    def _acc():
        buf[sl, :] += part

    if epi is not None:
        @pl.when(k == gk - 1)
        def _epilogue():
            acc = buf[sl, :]
            if comp_ref is not None:   # outlier rows land at the flush
                acc = acc + comp_ref[...]
            scale, bias, residual = _read_epi(epi, epi_refs, res_rows=sl)
            o_ref[sl, :] = _apply_epilogue(
                epi, acc, scale, bias, residual, o_ref.dtype
            )


def _build_ws(a, b, out_dtype, spec: DataflowSpec, interpret: bool,
              epi, epi_args, pk: Optional[_Packed] = None):
    (m, kdim), (_, n) = a.shape, b.shape
    bm, bk, bn = spec.block
    gm, gk, gn = m // bm, kdim // bk, n // bn
    res_a, res_o = spec.residency(IS), spec.residency(OS)

    if res_o in (Residency.STRIPE, Residency.WHOLE):
        # grid (gn, gk, gm): weight blocks each fetched once; output stripe
        # (M, bn) resident per n, written once — no RMW.  Integer-input
        # fused epilogues accumulate exactly in an int32 scratch stripe.
        use_acc = epi is not None and jnp.issubdtype(a.dtype, jnp.integer)
        kernel = functools.partial(_ws_stripe_kernel, bm=bm, gk=gk, epi=epi,
                                   use_acc=use_acc,
                                   wb=None if pk is None else pk.bits,
                                   has_comp=pk is not None
                                   and pk.comp is not None)
        b_map = lambda j, k, i: (k, j)
        j_map = lambda j, k, i: (0, j)
        i_map = lambda j, k, i: (i, 0)
        packed, packed_specs = _packed_operands(
            pk, (bk, bn), b_map, bm, bn, lambda j, k, i: (i, j))
        scale = epi_args[0] if (epi is not None and epi.scale) else None
        return pl.pallas_call(
            kernel,
            grid=(gn, gk, gm),
            in_specs=[
                pl.BlockSpec((bm, bk), lambda j, k, i: (i, k)),
                pl.BlockSpec(_codes_block(pk, (bk, bn)), b_map),
                *packed_specs,
                *_epi_specs(epi, scale, bm, bn, i_map, j_map, j_map,
                            (m, bn), j_map),
            ],
            out_specs=pl.BlockSpec((m, bn), lambda j, k, i: (0, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
            scratch_shapes=(
                [pltpu.VMEM((m, bn), _acc_dtype(a.dtype))] if use_acc
                else []),
            interpret=interpret,
        )(a, b, *packed, *epi_args)

    # streamed outputs: single-dispatch revisited accumulation
    return _build_rmw(a, b, out_dtype, spec, interpret, m_minor=True,
                      epi=epi, epi_args=epi_args, pk=pk)


# ---------------------------------------------------------------------------
# IS-anchored kernels.
# ---------------------------------------------------------------------------
def _is_stripe_kernel(a_ref, b_ref, *refs, b_whole: bool, bk: int, bn: int,
                      gk: int, epi: Optional[Epilogue], use_acc: bool,
                      wb: Optional[int] = None, has_comp: bool = False):
    bhi_ref, comp_ref, refs = _pop_packed(refs, wb, has_comp)
    if use_acc:   # exact accumulation in a scratch of the acc dtype
        o_ref, acc_ref = refs[-2], refs[-1]
        epi_refs = refs[:-2]
    else:
        o_ref, acc_ref = refs[-1], None
        epi_refs = refs[:-1]
    buf = acc_ref if use_acc else o_ref
    k, j = pl.program_id(1), pl.program_id(2)
    b = _load_b(b_ref, bhi_ref, wb,
                Residency.WHOLE if b_whole else Residency.STREAMED,
                k, bk, j, bn)
    part = jnp.dot(a_ref[...], b, preferred_element_type=buf.dtype)
    sl = pl.dslice(j * bn, bn)

    @pl.when(k == 0)
    def _init():
        buf[:, sl] = part

    @pl.when(k != 0)
    def _acc():
        buf[:, sl] += part

    if epi is not None:
        @pl.when(k == gk - 1)
        def _epilogue():
            acc = buf[:, sl]
            if comp_ref is not None:   # outlier rows land at the flush
                acc = acc + comp_ref[...]
            scale, bias, residual = _read_epi(epi, epi_refs, res_cols=sl)
            o_ref[:, sl] = _apply_epilogue(
                epi, acc, scale, bias, residual, o_ref.dtype
            )


def _build_is(a, b, out_dtype, spec: DataflowSpec, interpret: bool,
              epi, epi_args, pk: Optional[_Packed] = None):
    (m, kdim), (_, n) = a.shape, b.shape
    bm, bk, bn = spec.block
    gm, gk, gn = m // bm, kdim // bk, n // bn
    res_b, res_o = spec.residency(WS), spec.residency(OS)
    b_whole = res_b == Residency.WHOLE
    b_block = (kdim, n) if b_whole else (bk, bn)
    b_map = (lambda i, k, j: (0, 0)) if b_whole else (lambda i, k, j: (k, j))

    if res_o in (Residency.STRIPE, Residency.WHOLE):
        use_acc = epi is not None and jnp.issubdtype(a.dtype, jnp.integer)
        kernel = functools.partial(
            _is_stripe_kernel, b_whole=b_whole, bk=bk, bn=bn, gk=gk, epi=epi,
            use_acc=use_acc,
            wb=None if pk is None else pk.bits,
            has_comp=pk is not None and pk.comp is not None,
        )
        j_map = lambda i, k, j: (0, j)
        i_map = lambda i, k, j: (i, 0)
        packed, packed_specs = _packed_operands(
            pk, b_block, b_map, bm, bn, lambda i, k, j: (i, j))
        scale = epi_args[0] if (epi is not None and epi.scale) else None
        return pl.pallas_call(
            kernel,
            grid=(gm, gk, gn),
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, k, j: (i, k)),
                pl.BlockSpec(_codes_block(pk, b_block), b_map),
                *packed_specs,
                *_epi_specs(epi, scale, bm, bn, i_map, j_map, j_map,
                            (bm, n), i_map),
            ],
            out_specs=pl.BlockSpec((bm, n), lambda i, k, j: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
            scratch_shapes=(
                [pltpu.VMEM((bm, n), _acc_dtype(a.dtype))] if use_acc
                else []),
            interpret=interpret,
        )(a, b, *packed, *epi_args)

    # streamed outputs: single-dispatch revisited accumulation
    return _build_rmw(a, b, out_dtype, spec, interpret, m_minor=False,
                      epi=epi, epi_args=epi_args, pk=pk)


# ---------------------------------------------------------------------------
# Entry point.
# ---------------------------------------------------------------------------
def matmul_df(
    a: jax.Array,
    b: jax.Array,
    spec: DataflowSpec,
    out_dtype: Optional[jnp.dtype] = None,
    interpret: bool = False,
    epilogue: Optional[Epilogue] = None,
    scale: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    weight_bits: Optional[int] = None,
    b_hi: Optional[jax.Array] = None,
    comp: Optional[jax.Array] = None,
) -> jax.Array:
    """(M, K) @ (K, N) under the given dataflow. Shapes must tile evenly
    by ``spec.block`` (use ``ops.matmul`` / ``ops.matmul_fused`` for
    automatic padding).

    With ``epilogue`` set, ``y = act(scale * acc + bias) + residual`` is
    applied in-register before the output write: ``scale`` is (1, 1)
    (per-tensor), (1, N) (per-column) or (M, 1) (per-row — e.g. int8
    per-activation-row dequant) float32, ``bias`` is (1, N) float32,
    ``residual`` is (M, N).

    With ``weight_bits`` set (4 or 5), ``b`` is the packed sub-byte
    nibble plane (K/8, N) int32 from ``kernels/pack.py`` (``b_hi`` the
    (K/32, N) bit plane at 5 bits); each anchor decompresses the active
    block to int8 lanes in VMEM at the load.  ``comp`` is the optional
    (M, N) int32 outlier compensation term (``A[:, idx] @ delta``) added
    to the accumulator at the epilogue flush — it requires a fused
    epilogue so the corrected accumulator never round-trips HBM raw.
    """
    if weight_bits is None:
        if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
            raise ValueError(f"bad shapes {a.shape} @ {b.shape}")
    else:
        if weight_bits not in (4, 5):
            raise ValueError(f"weight_bits must be 4 or 5, got {weight_bits}")
        if not jnp.issubdtype(a.dtype, jnp.integer):
            raise ValueError(
                f"packed weights need integer activations, got {a.dtype}")
        if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0] * 8:
            raise ValueError(
                f"bad packed shapes: a {a.shape} vs nibble plane {b.shape}")
        if weight_bits == 5:
            if b_hi is None:
                raise ValueError("weight_bits=5 needs the b_hi bit plane")
            if b_hi.shape != (a.shape[1] // 32, b.shape[1]):
                raise ValueError(
                    f"bit plane shape {b_hi.shape} != "
                    f"({a.shape[1] // 32}, {b.shape[1]})")
    m, kdim = a.shape
    n = b.shape[1]
    bm, bk, bn = spec.block
    if m % bm or kdim % bk or n % bn:
        raise ValueError(
            f"shapes ({m},{kdim},{n}) must tile by block {spec.block}"
        )
    if weight_bits is not None and bk % (32 if weight_bits == 5 else 8):
        raise ValueError(
            f"packed weight_bits={weight_bits} needs bk divisible by "
            f"{32 if weight_bits == 5 else 8}, got {bk}")
    if comp is not None:
        if weight_bits is None:
            raise ValueError("comp is only meaningful with packed weights")
        if comp.shape != (m, n):
            raise ValueError(f"comp shape {comp.shape} != ({m}, {n})")
    epi = epilogue if (epilogue is not None and not epilogue.is_noop) else None
    if comp is not None and epi is None:
        raise ValueError(
            "outlier compensation requires a fused epilogue flush")
    if epi is not None:
        if epi.scale:
            if scale is None:
                raise ValueError("epilogue.scale set but no scale array")
            if scale.shape not in ((1, 1), (1, n), (m, 1)):
                raise ValueError(
                    f"scale shape {scale.shape} != (1,1)/(1,{n})/({m},1)"
                )
        if epi.bias:
            if bias is None:
                raise ValueError("epilogue.bias set but no bias array")
            if bias.shape != (1, n):
                raise ValueError(f"bias shape {bias.shape} != (1, {n})")
        if epi.residual:
            if residual is None:
                raise ValueError("epilogue.residual set but no residual array")
            if residual.shape != (m, n):
                raise ValueError(
                    f"residual shape {residual.shape} != ({m}, {n})"
                )
    if out_dtype is None:
        out_dtype = jnp.float32 if epi is not None else _acc_dtype(a.dtype)
    epi_args = _epi_operands(epi, scale, bias, residual)
    pk = None if weight_bits is None else _Packed(weight_bits, b_hi, comp)
    build = {OS: _build_os, WS: _build_ws, IS: _build_is}[spec.anchor]
    return build(a, b, out_dtype, spec, interpret, epi, epi_args, pk=pk)
