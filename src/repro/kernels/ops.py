"""Public jit'd wrappers around the Pallas kernels.

These handle padding to tile boundaries, dataflow selection (via the
``core.autotune`` spec cache when no spec is given), backend dispatch
(Pallas on TPU, interpret-mode Pallas or the jnp oracle elsewhere), and
quantization plumbing.

``matmul_fused`` / ``int8_matmul_fused`` and ``conv2d_fused`` /
``int8_conv2d_fused`` execute the whole layer — GEMM/conv plus its
epilogue (dequant scale, bias, activation, residual) — in one kernel
dispatch: the epilogue is applied in-register before the single HBM
output write instead of as separate XLA ops re-reading the raw
accumulator from HBM.

Fault injection (runtime/health.py): each public op carries a named
site — ``kernel.matmul`` / ``kernel.conv2d`` / ``kernel.binary_matmul``
/ ``kernel.attention`` — checked at dispatch.  Since these wrappers are
jitted, an armed fault fires at trace/lowering time (once per distinct
compiled shape), which is where real lowering and interpret failures
surface; a ``nan``-kind fault bakes a NaN multiply into the trace for
float outputs (integer outputs ignore it — there is no int NaN), so
the non-finite sentinel downstream sees exactly what a numerically
broken kernel would produce.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import autotune, cost_model
from repro.core.dataflow import (
    AttentionProblem, BinaryEpilogue, BinaryProblem, ConvProblem,
    DataflowSpec, Epilogue, GemmProblem, Residency, SpecOverride,
    IS, OS, WS,
)
from repro.kernels import (
    attention_df, binary_mm, conv2d_df, matmul_df, pack, ref,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _inject(site: str) -> Optional[str]:
    from repro.runtime import health

    return health.maybe_inject(site)


def _poison(out: jax.Array, fault: Optional[str]) -> jax.Array:
    """Realize a ``nan``-kind injected fault on a float result."""
    if fault == "nan" and jnp.issubdtype(out.dtype, jnp.floating):
        return out * jnp.asarray(jnp.nan, out.dtype)
    return out


def _pad_to(x: jax.Array, mults, value=0):
    pads = []
    needs = False
    for dim, mult in zip(x.shape, mults):
        pad = (-dim) % mult
        pads.append((0, pad))
        needs |= pad > 0
    return jnp.pad(x, pads, constant_values=value) if needs else x


def _resolve_spec(spec, problem, backend: str) -> DataflowSpec:
    """Resolve a public op's ``spec`` argument to a full DataflowSpec.

    ``None`` -> the autotuned spec for ``problem``.  A ``SpecOverride``
    merges onto that autotuned base (a *complete* override — anchor and
    every block dim pinned — skips the cache lookup and realizes over
    the paper-default dataflow).  A full ``DataflowSpec`` passes
    through untouched.
    """
    if isinstance(spec, SpecOverride):
        if spec.is_complete:
            return spec.merge(DataflowSpec.optimized())
        return spec.merge(autotune.best_spec(problem, backend=backend))
    if spec is None:
        return autotune.best_spec(problem, backend=backend)
    return spec


def _gemm_problem(m: int, k: int, n: int, in_dtype, out_dtype,
                  weight_bits: Optional[int] = None) -> GemmProblem:
    integer = jnp.issubdtype(jnp.dtype(in_dtype), jnp.integer)
    if out_dtype is None:
        out = "int32" if integer else "float32"
    else:
        out = str(jnp.dtype(out_dtype))
    return GemmProblem(
        m=m, k=k, n=n, in_dtype=str(jnp.dtype(in_dtype)), out_dtype=out,
        acc_dtype="int32" if integer else "float32",
        weight_bits=weight_bits,
    )


def _conv_problem(n: int, ih: int, iw: int, fh: int, fw: int, stride: int,
                  cin: int, cout: int, in_dtype, out_dtype,
                  weight_bits: Optional[int] = None) -> ConvProblem:
    integer = jnp.issubdtype(jnp.dtype(in_dtype), jnp.integer)
    if out_dtype is None:
        out = "int32" if integer else "float32"
    else:
        out = str(jnp.dtype(out_dtype))
    return ConvProblem(
        ih=ih, iw=iw, fh=fh, fw=fw, s=stride, cin=cin, cout=cout, n=n,
        in_dtype=str(jnp.dtype(in_dtype)), out_dtype=out,
        weight_bits=weight_bits,
    )


def _conv_pad(x, w, stride: int, oh: int, ow: int, b_oh: int, bc: int,
              bk: int):
    """Lane-align channels and halo-pad the image for the window loads."""
    n, ih, iw, cin = x.shape
    fh, fw, _, cout = w.shape
    bc_ = min(bc, -(-cin // 128) * 128)
    bk_ = min(bk, -(-cout // 128) * 128)
    b_oh_ = min(b_oh, oh)
    oh_pad = -(-oh // b_oh_) * b_oh_
    # halo padding so every (t, ky) window load is in bounds
    ih_need = (oh_pad - 1) * stride + fh + (stride - 1)
    iw_need = (ow - 1) * stride + fw + (stride - 1)
    xp = _pad_to(x, (1, 1, 1, bc_))
    xp = jnp.pad(
        xp,
        ((0, 0), (0, max(0, ih_need - ih)), (0, max(0, iw_need - iw)), (0, 0)),
    )
    wp = _pad_to(w, (1, 1, bc_, bk_))
    return xp, wp, oh_pad, b_oh_, bc_, bk_


def default_matmul_spec(m: int, k: int, n: int, in_dtype="bfloat16",
                        vmem_budget: int = 16 * 2 ** 20) -> DataflowSpec:
    """Paper Alg. 8: OS anchor, aux to weights first (WHOLE if it fits,
    else STRIPE), then inputs."""
    from repro.core.cost_model import dtype_bytes

    ib = dtype_bytes(str(in_dtype))
    bm = min(512, max(128, m))
    bn = min(512, max(128, n))
    bk = min(512, max(128, k))
    aux = {}
    base = 2 * bm * bk * ib + 2 * bm * bn * 4 + bm * bn * 4
    if k * n * ib + base <= vmem_budget:
        aux[WS] = Residency.WHOLE
        if bm * k * ib + k * n * ib + base <= vmem_budget:
            aux[IS] = Residency.STRIPE
    elif k * bn * ib + base <= vmem_budget:
        aux[WS] = Residency.STRIPE
    return DataflowSpec(anchor=OS, aux=aux, aux_priority=(WS, IS),
                        block=(bm, bk, bn), vmem_budget=vmem_budget)


@functools.partial(jax.jit, static_argnames=("spec", "out_dtype", "backend"))
def matmul(
    a: jax.Array,
    b: jax.Array,
    spec: Optional[DataflowSpec] = None,
    out_dtype=None,
    backend: Optional[str] = None,   # "pallas" | "interpret" | "xla"
) -> jax.Array:
    """(M, K) @ (K, N) with automatic padding under a dataflow spec.

    With ``spec=None`` the dataflow comes from the ``core.autotune``
    cache — the explorer's candidate space is enumerated once per
    distinct (shape, dtype, hardware, backend) and memoized in-process
    and on disk.
    """
    fault = _inject("kernel.matmul")
    m, k = a.shape
    n = b.shape[1]
    backend = backend or ("pallas" if _on_tpu() else "xla")
    if backend == "xla":
        return _poison(ref.matmul_ref(a, b, out_dtype), fault)
    spec = _resolve_spec(
        spec, _gemm_problem(m, k, n, a.dtype, out_dtype), backend)
    bm, bk, bn = spec.block
    ap = _pad_to(a, (bm, bk))
    bp = _pad_to(b, (bk, bn))
    spec = spec.with_block((min(bm, ap.shape[0]), min(bk, ap.shape[1]),
                            min(bn, bp.shape[1])))
    out = matmul_df.matmul_df(ap, bp, spec, out_dtype=out_dtype,
                              interpret=backend == "interpret")
    return _poison(out[:m, :n], fault)


@functools.partial(
    jax.jit,
    static_argnames=("stride", "spec", "b_oh", "bc", "bk", "out_dtype",
                     "backend"),
)
def conv2d(
    x: jax.Array,      # (N, H, W, Cin)
    w: jax.Array,      # (fh, fw, Cin, Cout)
    stride: int = 1,
    spec: Optional[DataflowSpec] = None,
    b_oh: int = 8,
    bc: int = 128,
    bk: int = 128,
    out_dtype=None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Direct NHWC conv (VALID padding) under a dataflow spec.

    With ``spec=None`` the dataflow (anchor AND conv blocking ``(b_oh,
    bc, bk)``) comes from the ``core.autotune`` cache keyed on the
    ``ConvProblem`` — the conv candidate space is ranked once per
    distinct (geometry, dtype, hardware, backend) and memoized.  An
    explicitly-passed ``spec`` keeps the ``b_oh``/``bc``/``bk`` keyword
    blocking (its ``block`` field is GEMM-shaped).
    """
    fault = _inject("kernel.conv2d")
    n, ih, iw, cin = x.shape
    fh, fw, _, cout = w.shape
    oh = (ih - fh) // stride + 1
    ow = (iw - fw) // stride + 1
    backend = backend or ("pallas" if _on_tpu() else "xla")
    if backend == "xla":
        return _poison(ref.conv2d_ref(x, w, stride, out_dtype), fault)
    override = spec if isinstance(spec, SpecOverride) else None
    if spec is None or override is not None:
        try:
            spec = autotune.best_spec(
                _conv_problem(n, ih, iw, fh, fw, stride, cin, cout, x.dtype,
                              out_dtype),
                backend=backend,
            )
            b_oh, bc, bk = spec.block  # conv-blocked, from the conv explorer
        except ValueError:
            # no candidate fits the analytic VMEM budget (e.g. a very
            # large whole-resident image): fall back to the paper's
            # default dataflow under the keyword blocking
            spec = DataflowSpec.optimized()
        if override is not None:
            spec = override.merge(spec.with_block((b_oh, bc, bk)))
            b_oh, bc, bk = spec.block

    xp, wp, oh_pad, b_oh_, bc_, bk_ = _conv_pad(
        x, w, stride, oh, ow, b_oh, bc, bk)
    out = conv2d_df.conv2d_df(
        xp, wp, stride, spec, oh=oh_pad, ow=ow, b_oh=b_oh_, bc=bc_, bk=bk_,
        out_dtype=out_dtype, interpret=backend == "interpret",
    )
    return _poison(out[:, :oh, :, :cout], fault)


@functools.partial(
    jax.jit,
    static_argnames=("stride", "activation", "spec", "b_oh", "bc", "bk",
                     "out_dtype", "backend"),
)
def conv2d_fused(
    x: jax.Array,      # (N, H, W, Cin)
    w: jax.Array,      # (fh, fw, Cin, Cout)
    stride: int = 1,
    bias: Optional[jax.Array] = None,       # (Cout,) or (1, Cout) float
    scale: Optional[jax.Array] = None,      # scalar or (Cout,) dequant scale
    residual: Optional[jax.Array] = None,   # (N, oh, ow, Cout)
    activation: Optional[str] = None,       # relu | gelu | silu
    spec: Optional[DataflowSpec] = None,
    b_oh: int = 8,
    bc: int = 128,
    bk: int = 128,
    out_dtype=None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Fused-epilogue conv: ``act(scale * conv(x, w) + bias) + residual``.

    One kernel dispatch per layer: the epilogue runs in-register on the
    scratch accumulator at the flush, so the raw conv result never
    round-trips HBM.  Shapes pad automatically like ``conv2d``; epilogue
    math is float32 and the default output dtype is float32.
    """
    fault = _inject("kernel.conv2d")
    n, ih, iw, cin = x.shape
    fh, fw, _, cout = w.shape
    oh = (ih - fh) // stride + 1
    ow = (iw - fw) // stride + 1
    backend = backend or ("pallas" if _on_tpu() else "xla")
    if bias is not None:
        bias = jnp.asarray(bias, jnp.float32).reshape(1, cout)
    if scale is not None:
        scale = jnp.asarray(scale, jnp.float32)
        if scale.size == 1:
            scale = scale.reshape(1, 1)
        elif scale.size == cout:
            scale = scale.reshape(1, cout)
        else:
            raise ValueError(
                f"scale must be scalar or per-output-channel (Cout={cout}), "
                f"got {scale.shape}"
            )
    if backend == "xla":
        return _poison(ref.conv2d_fused_ref(
            x, w, stride, bias=bias, scale=scale, residual=residual,
            activation=activation, out_dtype=out_dtype,
        ), fault)
    epi = Epilogue(
        bias=bias is not None,
        activation=activation,
        scale=scale is not None,
        residual=residual is not None,
    )
    override = spec if isinstance(spec, SpecOverride) else None
    if spec is None or override is not None:
        try:
            spec = autotune.best_spec(
                _conv_problem(n, ih, iw, fh, fw, stride, cin, cout, x.dtype,
                              out_dtype or jnp.float32),
                backend=backend,
            )
            b_oh, bc, bk = spec.block
        except ValueError:
            spec = DataflowSpec.optimized()  # see conv2d's fallback note
        if override is not None:
            spec = override.merge(spec.with_block((b_oh, bc, bk)))
            b_oh, bc, bk = spec.block
    xp, wp, oh_pad, b_oh_, bc_, bk_ = _conv_pad(
        x, w, stride, oh, ow, b_oh, bc, bk)
    kpad = wp.shape[3]
    if bias is not None:
        bias = _pad_to(bias, (1, bk_))
    if scale is not None and scale.shape != (1, 1):
        scale = _pad_to(scale, (1, bk_))
    if residual is not None:
        residual = jnp.pad(
            residual,
            ((0, 0), (0, oh_pad - oh), (0, 0), (0, kpad - cout)),
        )
    out = conv2d_df.conv2d_df(
        xp, wp, stride, spec, oh=oh_pad, ow=ow, b_oh=b_oh_, bc=bc_, bk=bk_,
        out_dtype=out_dtype or jnp.float32,
        interpret=backend == "interpret",
        epilogue=epi, scale=scale, bias=bias, residual=residual,
    )
    return _poison(out[:, :oh, :, :cout], fault)


@functools.partial(
    jax.jit, static_argnames=("stride", "activation", "spec", "backend")
)
def int8_conv2d_fused(
    xq: jax.Array, wq: jax.Array, x_scale: jax.Array, w_scale: jax.Array,
    stride: int = 1,
    bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    spec: Optional[DataflowSpec] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Quantized conv with the dequant + epilogue fused into the kernel:
    ``act((x_scale * w_scale) * conv(xq, wq) + bias) + residual`` -> f32.

    Scales must be per-tensor (scalar) or combine to per-output-channel;
    spatially-varying activation scales need the unfused path.
    """
    scale = (jnp.asarray(x_scale, jnp.float32)
             * jnp.asarray(w_scale, jnp.float32))
    cout = wq.shape[3]
    if scale.size not in (1, cout):
        raise ValueError(
            f"fused conv dequant needs scalar or per-output-channel scales, "
            f"got combined shape {scale.shape}"
        )
    return conv2d_fused(
        xq, wq, stride=stride, bias=bias, scale=scale.reshape(1, -1),
        residual=residual, activation=activation, spec=spec, backend=backend,
    )


def _attention_problem(bh: int, sq: int, skv: int, d: int, group: int,
                       causal: bool, window: Optional[int],
                       dtype, kv_dtype=None, rows: int = 1) -> AttentionProblem:
    dt = str(jnp.dtype(dtype))
    kdt = None if kv_dtype is None else str(jnp.dtype(kv_dtype))
    return AttentionProblem(
        bh=bh, sq=sq, skv=skv, d=d, group=group, causal=causal,
        window=window, dtype=dt, kv_dtype=None if kdt == dt else kdt,
        rows=rows,
    )


@functools.partial(
    jax.jit,
    static_argnames=("group", "causal", "window", "scale", "spec", "bq",
                     "bkv", "backend", "anchor"),
)
def attention(
    q: jax.Array,            # (B, Hq, Sq, D)
    k: jax.Array,            # (B, Hkv, Skv, D)  float, or int8 w/ scales
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,         # static sliding window
    scale: Optional[float] = None,
    spec: Optional[DataflowSpec] = None,
    bq: Optional[int] = None,
    bkv: Optional[int] = None,
    backend: Optional[str] = None,
    anchor: Optional[str] = None,  # "os" (flash) | "ws" (kv-stationary)
    group: Optional[int] = None,
    kv_len: Optional[jax.Array] = None,   # valid KV prefix (traced ok)
    window_dyn: Optional[jax.Array] = None,   # traced sliding window
    k_scale: Optional[jax.Array] = None,  # (B, Hkv, Skv, 1) int8-KV scales
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """GQA attention under a dataflow anchor. Returns (B, Hq, Sq, D).

    With ``spec=None`` the dataflow — the anchor AND the ``(bq, bkv)``
    blocking — comes from the ``core.autotune`` cache keyed on the
    ``AttentionProblem`` (keys ``v5|attn|...``): the candidate space
    {OS/flash, WS/kv-stationary} x blocks is ranked once per distinct
    (shape, mask, dtype, hardware, backend) and memoized.  An explicit
    ``anchor``/``bq``/``bkv`` overrides only that field of the resolved
    spec, so e.g. the benchmark's forced-WS variant still honors the
    autotuned block.

    Serving terms (PR 5), all handled inside the kernel grid:
      * ``kv_len`` — the filled prefix of a padded KV-cache buffer
        (traced; q rows right-align against it).  KV blocks beyond it
        are skipped — clamped index maps issue no DMA and ``pl.when``
        skips their compute — so a decode step's traffic scales with
        the *valid* cache length, not ``Skv``.  Traced lengths key the
        autotune lookup as the full-``Skv`` worst case.  A ``(B,)``
        vector bands *per batch row* (PR 8): each row's grid steps
        clamp onto its own band edge, so a ragged continuous batch
        pays each request's true cache length.
      * ``spec`` also accepts a partial :class:`SpecOverride`; its
        anchor/block fields fill whichever of ``anchor``/``bq``/``bkv``
        were not explicitly passed.
      * ``window`` (static) / ``window_dyn`` (traced) — causal sliding
        window; a static window additionally shrinks the KV grid
        dimension to the band width.
      * ``k_scale``/``v_scale`` — per-position f32 scales of an int8
        K/V cache, dequantized at the block load; the cache never
        round-trips HBM as a float copy.

    Decode (``Sq == 1``) takes a single-q-row fast path: the q side is
    neither padded nor blocked (``bq = 1``, one q tile), keeping the
    per-step cost at one kernel dispatch over the KV stream.
    """
    fault = _inject("kernel.attention")
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = group or hq // hkv
    backend = backend or ("pallas" if _on_tpu() else "xla")
    quant = k.dtype == jnp.int8
    if quant:
        if k_scale is None or v_scale is None:
            raise ValueError("int8 K/V need per-position k_scale/v_scale")
        # catch wrong scale layouts (e.g. a squeezed (B, H, S) vector or a
        # per-tensor scalar) before they broadcast silently in the kernel
        want_k, want_v = k.shape[:-1] + (1,), v.shape[:-1] + (1,)
        if k_scale.shape != want_k or v_scale.shape != want_v:
            raise ValueError(
                f"int8 K/V scales must be per-position with a trailing "
                f"singleton lane: expected k_scale {want_k} and v_scale "
                f"{want_v}, got {k_scale.shape} and {v_scale.shape}"
            )
    win_eff = window if window is not None else window_dyn
    if backend == "xla":
        return _poison(
            ref.attention_ref(q, k, v, causal=causal, window=win_eff,
                              scale=scale, kv_len=kv_len,
                              k_scale=k_scale, v_scale=v_scale), fault)
    if isinstance(spec, SpecOverride):
        # one-surface override (PR 8): unpack into the legacy per-field
        # aliases; an explicitly-passed alias kwarg wins over the
        # override's field
        if spec.anchor not in (None, OS, WS):
            raise ValueError(
                f"attention admits OS/WS anchors, not {spec.anchor!r}"
            )
        anchor = anchor if anchor is not None else spec.anchor_name
        bq = bq if bq is not None else spec.block_dim(0)
        bkv = bkv if bkv is not None else spec.block_dim(1)
        spec = None
    ragged = getattr(kv_len, "ndim", 0) == 1
    if ragged and kv_len.shape[0] != b:
        raise ValueError(
            f"per-row kv_len needs one entry per batch row "
            f"({b}), got shape {kv_len.shape}"
        )
    if spec is None and (anchor is None or bq is None or bkv is None):
        spec = autotune.best_spec(
            _attention_problem(b * hq, sq, skv, d, group, causal, window,
                               q.dtype, k.dtype, rows=b if ragged else 1),
            backend=backend,
        )
    if spec is not None:
        if spec.anchor not in (OS, WS):
            raise ValueError(
                f"attention admits OS/WS anchors, not {spec.anchor!r}"
            )
        if anchor is None:
            anchor = "os" if spec.anchor == OS else "ws"
        bq = bq if bq is not None else spec.block[0]
        bkv = bkv if bkv is not None else spec.block[1]
    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)
    bq_, bkv_ = cost_model.attention_block_clamp(sq, skv, bq, bkv)
    if sq == 1:
        qp = qf                 # decode fast path: no q padding/blocking
    else:
        qp = _pad_to(qf, (1, bq_, 1))
    kp = _pad_to(kf, (1, bkv_, 1))
    vp = _pad_to(vf, (1, bkv_, 1))
    ksp = vsp = None
    if quant:
        ksp = _pad_to(k_scale.reshape(b * hkv, skv, 1), (1, bkv_, 1))
        vsp = _pad_to(v_scale.reshape(b * hkv, skv, 1), (1, bkv_, 1))
    fn = (attention_df.flash_attention if anchor == "os"
          else attention_df.kv_stationary_attention)
    out = fn(
        qp, kp, vp, group=group, causal=causal, window=window, scale=scale,
        skv_valid=skv, sq_valid=sq, bq=bq_, bkv=bkv_,
        interpret=backend == "interpret",
        kv_len=kv_len, window_dyn=window_dyn, k_scale=ksp, v_scale=vsp,
    )
    return _poison(out[:, :sq].reshape(b, hq, sq, d), fault)


@functools.partial(
    jax.jit, static_argnames=("group", "scale", "window", "backend"),
)
def paged_attention(
    q: jax.Array,             # (B, Hq, 1, D) decode queries
    k_pages: jax.Array,       # (Hkv, n_pages, page, D) device page pool
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, max_pages) int32 page ids (pad with 0)
    kv_lens: jax.Array,       # (B,) int32 valid KV length per row
    scale: Optional[float] = None,
    window: Optional[int] = None,
    group: Optional[int] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Decode attention straight off a paged KV cache. Returns (B, Hq, 1, D).

    The block-table indirection rides the kernel's scalar-prefetch index
    map — a page table *is* an index map (see docs/serving.md) — so each
    row's KV stream gathers its own pages with no contiguous copy, and
    out-of-band grid steps clamp onto the band edge (no DMA, no
    compute).  The xla/oracle path gathers pages into a contiguous
    per-row cache and defers to ``ref.attention_ref`` with ragged
    ``kv_len``.  Decode-only: ``Sq == 1``.
    """
    fault = _inject("kernel.attention")
    b, hq, sq, d = q.shape
    if sq != 1:
        raise ValueError(f"paged_attention is decode-only (Sq == 1), got {sq}")
    hkv, _, page, _ = k_pages.shape
    group = group or hq // hkv
    backend = backend or ("pallas" if _on_tpu() else "xla")
    if backend == "xla":
        kg = jnp.moveaxis(k_pages[:, block_tables], 1, 0).reshape(
            b, hkv, -1, d)
        vg = jnp.moveaxis(v_pages[:, block_tables], 1, 0).reshape(
            b, hkv, -1, d)
        return _poison(
            ref.attention_ref(q, kg, vg, causal=True, window=window,
                              scale=scale, kv_len=kv_lens), fault)
    out = attention_df.paged_flash_attention(
        q.reshape(b * hq, 1, d), k_pages, v_pages, block_tables, kv_lens,
        group=group, scale=scale, window=window,
        interpret=backend == "interpret",
    )
    return _poison(out.reshape(b, hq, 1, d), fault)


def _binary_problem(m: int, kp: int, n: int, n_bits: int,
                    out_dtype="int32") -> BinaryProblem:
    return BinaryProblem(m=m, kp=kp, n=n, n_bits=n_bits,
                         out_dtype=str(jnp.dtype(out_dtype)))


@functools.partial(jax.jit, static_argnames=("n_bits", "spec", "backend"))
def binary_matmul(
    a_packed: jax.Array, b_packed: jax.Array, n_bits: int,
    spec: Optional[DataflowSpec] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Packed +-1 GEMM: (M, Kp) x (Kp, N) uint32 -> (M, N) int32 dots.

    ``n_bits`` is the true pre-packing reduction depth K.  With
    ``spec=None`` the dataflow (anchor AND ``(bm, bkp, bn)`` blocking)
    comes from the ``core.autotune`` cache keyed on the
    ``BinaryProblem``.  Zero-padded packed words xor to 0 on both sides
    and drop out of the popcount, so the ``K - 2*popcount`` identity
    absorbs the tile padding with no post-correction.
    """
    fault = _inject("kernel.binary_matmul")
    backend = backend or ("pallas" if _on_tpu() else "xla")
    if backend == "xla":
        return _poison(ref.binary_matmul_ref(a_packed, b_packed, n_bits),
                       fault)
    m, kp = a_packed.shape
    n = b_packed.shape[1]
    spec = _resolve_spec(spec, _binary_problem(m, kp, n, n_bits), backend)
    bm, bkp, bn = spec.block
    ap = _pad_to(a_packed, (bm, bkp))
    bp = _pad_to(b_packed, (bkp, bn))
    spec = spec.with_block((min(bm, ap.shape[0]), min(bkp, ap.shape[1]),
                            min(bn, bp.shape[1])))
    out = binary_mm.binary_mm_df(
        ap, bp, n_bits, spec, out_dtype=jnp.int32,
        interpret=backend == "interpret",
    )
    return _poison(out[:m, :n], fault)


@functools.partial(
    jax.jit, static_argnames=("n_bits", "binarize", "spec", "out_dtype",
                              "backend"),
)
def binary_matmul_fused(
    a_packed: jax.Array, b_packed: jax.Array, n_bits: int,
    scale: Optional[jax.Array] = None,      # scalar or (N,) folded-BN gamma
    bias: Optional[jax.Array] = None,       # (N,) folded-BN beta
    residual: Optional[jax.Array] = None,   # (M, N)
    binarize: bool = False,
    spec: Optional[DataflowSpec] = None,
    out_dtype=None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Fused-epilogue binary GEMM: ``y = scale * dot + bias + residual``
    then ``sign(y)`` when ``binarize``.

    One kernel dispatch per layer: the folded batchnorm and the
    re-binarization run in-register at the accumulator flush, so chained
    binary layers emit +-1 int8 activations directly and the int32
    accumulator never round-trips HBM.  Output dtype defaults to int8
    (+-1) when ``binarize`` else float32.
    """
    fault = _inject("kernel.binary_matmul")
    m, kp = a_packed.shape
    n = b_packed.shape[1]
    if scale is not None:
        scale = jnp.asarray(scale, jnp.float32)
        if scale.size == 1:
            scale = scale.reshape(1, 1)
        elif scale.size == n:
            scale = scale.reshape(1, n)
        else:
            raise ValueError(
                f"scale must be scalar or per-output-column (N={n}), "
                f"got {scale.shape}"
            )
    if bias is not None:
        bias = jnp.asarray(bias, jnp.float32).reshape(1, n)
    backend = backend or ("pallas" if _on_tpu() else "xla")
    if backend == "xla":
        return _poison(ref.binary_matmul_fused_ref(
            a_packed, b_packed, n_bits, scale=scale, bias=bias,
            residual=residual, binarize=binarize, out_dtype=out_dtype,
        ), fault)
    epi = BinaryEpilogue(
        scale=scale is not None, bias=bias is not None,
        residual=residual is not None, binarize=binarize,
    )
    out_dt = out_dtype or (jnp.int8 if binarize else jnp.float32)
    spec = _resolve_spec(
        spec, _binary_problem(m, kp, n, n_bits, out_dt), backend)
    bm, bkp, bn = spec.block
    ap = _pad_to(a_packed, (bm, bkp))
    bp = _pad_to(b_packed, (bkp, bn))
    if scale is not None and scale.shape != (1, 1):
        scale = _pad_to(scale, (1, bn))
    if bias is not None:
        bias = _pad_to(bias, (1, bn))
    if residual is not None:
        residual = _pad_to(residual, (bm, bn))
    spec = spec.with_block((min(bm, ap.shape[0]), min(bkp, ap.shape[1]),
                            min(bn, bp.shape[1])))
    out = binary_mm.binary_mm_df(
        ap, bp, n_bits, spec, out_dtype=out_dt,
        interpret=backend == "interpret",
        epilogue=epi, scale=scale, bias=bias, residual=residual,
    )
    return _poison(out[:m, :n], fault)


@functools.partial(
    jax.jit, static_argnames=("stride", "n_bits", "binarize", "spec",
                              "out_dtype", "backend"),
)
def binary_conv2d(
    x_packed: jax.Array,   # (N, H, W, Cp) uint32 channel-packed image
    w_packed: jax.Array,   # (fh, fw, Cp, Cout) uint32
    stride: int = 1,
    n_bits: Optional[int] = None,   # true reduction depth fh*fw*cin
    scale: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,   # (N, oh, ow, Cout)
    binarize: bool = False,
    spec: Optional[DataflowSpec] = None,
    out_dtype=None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Binary NHWC conv (VALID padding) via the implicit-GEMM view.

    The channel-packed image is patch-extracted to the (N*oh*ow,
    fh*fw*Cp) GEMM view (XLA slices — not a kernel dispatch) and runs
    through the single-dispatch binary GEMM, optionally with the fused
    folded-BN/sign epilogue, so a binary convnet layer is ONE
    ``pallas_call`` end to end.  ``n_bits`` defaults to every packed bit
    (fh*fw*32*Cp); pass ``fh*fw*cin`` when cin doesn't fill the last
    word.  With ``spec=None`` the dataflow resolves through the
    ``core.autotune`` cache keyed on the implicit-GEMM
    ``BinaryProblem``.
    """
    nb, ih, iw, cp = x_packed.shape
    fh, fw, _, cout = w_packed.shape
    oh = (ih - fh) // stride + 1
    ow = (iw - fw) // stride + 1
    if n_bits is None:
        n_bits = fh * fw * 32 * cp
    if scale is not None:
        scale = jnp.asarray(scale, jnp.float32).reshape(1, -1)
    if bias is not None:
        bias = jnp.asarray(bias, jnp.float32).reshape(1, -1)
    backend = backend or ("pallas" if _on_tpu() else "xla")
    if backend == "xla":
        return ref.binary_conv2d_ref(
            x_packed, w_packed, stride, n_bits=n_bits,
            scale=scale, bias=bias,
            residual=residual, binarize=binarize, out_dtype=out_dtype,
        )
    cols = ref.binary_im2col(x_packed, fh, fw, stride)
    a = cols.reshape(nb * oh * ow, fh * fw * cp)
    b = w_packed.reshape(fh * fw * cp, cout)
    res2 = (residual.reshape(nb * oh * ow, cout)
            if residual is not None else None)
    if scale is None and bias is None and res2 is None and not binarize:
        out = binary_matmul(a, b, n_bits, spec=spec, backend=backend)
        if out_dtype is not None:
            out = out.astype(out_dtype)
    else:
        out = binary_matmul_fused(
            a, b, n_bits, scale=scale, bias=bias, residual=res2,
            binarize=binarize, spec=spec, out_dtype=out_dtype,
            backend=backend,
        )
    return out.reshape(nb, oh, ow, cout)


@functools.partial(jax.jit, static_argnames=("spec", "backend"))
def int8_matmul(
    aq: jax.Array, bq: jax.Array, a_scale: jax.Array, b_scale: jax.Array,
    spec: Optional[DataflowSpec] = None, backend: Optional[str] = None,
) -> jax.Array:
    """Quantized GEMM: int8 x int8 -> int32 (MXU) -> dequantized f32."""
    acc = matmul(aq, bq, spec=spec, out_dtype=jnp.int32, backend=backend)
    return acc.astype(jnp.float32) * a_scale * b_scale


@functools.partial(
    jax.jit, static_argnames=("activation", "spec", "out_dtype", "backend")
)
def matmul_fused(
    a: jax.Array,
    b: jax.Array,
    bias: Optional[jax.Array] = None,       # (N,) or (1, N) float
    scale: Optional[jax.Array] = None,      # scalar, (N,) or (M, 1) scale
    residual: Optional[jax.Array] = None,   # (M, N)
    activation: Optional[str] = None,       # relu | gelu | silu
    spec: Optional[DataflowSpec] = None,
    out_dtype=None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Fused-epilogue GEMM: ``act(scale * (a @ b) + bias) + residual``.

    One kernel dispatch per layer: the epilogue runs in-register on the
    accumulator, so the raw GEMM result never round-trips HBM.  Shapes
    pad automatically like ``matmul``; epilogue math is float32 and the
    default output dtype is float32.

    ``scale`` may be per-tensor (scalar), per-column ((N,) / (1, N)) or
    per-row ((M, 1) — e.g. int8 per-activation-row dequant).  When
    M == N an explicit 2-D shape disambiguates; a 1-D vector defaults to
    per-column.
    """
    fault = _inject("kernel.matmul")
    m, k = a.shape
    n = b.shape[1]
    backend = backend or ("pallas" if _on_tpu() else "xla")
    if bias is not None:
        bias = jnp.asarray(bias, jnp.float32).reshape(1, n)
    if scale is not None:
        scale = jnp.asarray(scale, jnp.float32)
        if scale.size == 1:
            scale = scale.reshape(1, 1)
        elif scale.ndim == 2 and scale.shape == (m, 1):
            pass  # per-row, explicitly shaped
        elif scale.size == n and not (scale.ndim == 2
                                      and scale.shape[1] == 1):
            scale = scale.reshape(1, n)
        elif scale.size == m and (scale.ndim == 1
                                  or scale.shape[1] == 1):
            scale = scale.reshape(m, 1)
        else:
            raise ValueError(
                f"scale must be scalar, per-column (N={n}) or per-row "
                f"(M={m}, 1), got {scale.shape}"
            )
    if backend == "xla":
        return _poison(ref.matmul_fused_ref(
            a, b, bias=bias, scale=scale, residual=residual,
            activation=activation, out_dtype=out_dtype,
        ), fault)
    epi = Epilogue(
        bias=bias is not None,
        activation=activation,
        scale=scale is not None,
        residual=residual is not None,
    )
    spec = _resolve_spec(
        spec, _gemm_problem(m, k, n, a.dtype, out_dtype or jnp.float32),
        backend)
    bm, bk, bn = spec.block
    ap = _pad_to(a, (bm, bk))
    bp = _pad_to(b, (bk, bn))
    mp, np_ = ap.shape[0], bp.shape[1]
    if bias is not None:
        bias = _pad_to(bias, (1, bn))
    if scale is not None and scale.shape[1] != 1:
        scale = _pad_to(scale, (1, bn))
    elif scale is not None and scale.shape[0] != 1:
        scale = _pad_to(scale, (bm, 1))  # per-row rides the M padding
    if residual is not None:
        residual = _pad_to(residual, (bm, bn))
    spec = spec.with_block((min(bm, mp), min(bk, ap.shape[1]),
                            min(bn, np_)))
    out = matmul_df.matmul_df(
        ap, bp, spec, out_dtype=out_dtype or jnp.float32,
        interpret=backend == "interpret",
        epilogue=epi, scale=scale, bias=bias, residual=residual,
    )
    return _poison(out[:m, :n], fault)


@functools.partial(jax.jit, static_argnames=("activation", "spec", "backend"))
def int8_matmul_fused(
    aq: jax.Array, bq: jax.Array, a_scale: jax.Array, b_scale: jax.Array,
    bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    spec: Optional[DataflowSpec] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Quantized GEMM with the dequant + epilogue fused into the kernel:
    ``act((a_scale * b_scale) * (aq @ bq) + bias) + residual`` -> f32.

    Scales must be per-tensor (scalar), combine to per-output-column
    (1, N), or combine to per-activation-row (M, 1); a full (M, N) scale
    grid (per-row activations x per-column weights) needs the unfused
    ``int8_matmul``.
    """
    scale = (jnp.asarray(a_scale, jnp.float32)
             * jnp.asarray(b_scale, jnp.float32))
    m, n = aq.shape[0], bq.shape[1]
    # shape-based dispatch: a per-row (M, 1) scale must not be mistaken
    # for a per-column vector even when M == N
    per_tensor = scale.size == 1
    per_column = (scale.shape == (n,)
                  or (scale.ndim == 2 and scale.shape[0] == 1
                      and scale.shape[1] == n))
    per_row = scale.ndim == 2 and scale.shape == (m, 1)
    if not (per_tensor or per_column or per_row):
        raise ValueError(
            f"fused dequant needs scalar, per-column or per-row scales, "
            f"got combined shape {scale.shape}; use int8_matmul instead"
        )
    return matmul_fused(
        aq, bq, bias=bias,
        scale=scale if per_row else scale.reshape(1, -1),
        residual=residual,
        activation=activation, spec=spec, backend=backend,
    )


# ---------------------------------------------------------------------------
# Sub-byte packed-weight GEMM / conv (kernels/pack.py).
#
# The weight never exists densely in HBM: the kernel streams the packed
# nibble/bit planes and decompresses each (bk, bn) slab to int8 lanes in
# VMEM at the stripe load.  Outlier rows (MSR sidecar) are compensated by
# a precomputed ``A[:, idx] @ delta`` term added to the accumulator at the
# epilogue flush, so the corrected int32 accumulator never round-trips
# HBM raw.  Both ops are *bit-exact* against the dequantize-then-matmul
# oracles (``ref.matmul_packed_ref`` / ``ref.conv2d_packed_ref``) when the
# epilogue is scale-only.
# ---------------------------------------------------------------------------


def _packed_gran(bits: int) -> int:
    return 32 if bits == 5 else 8


@functools.partial(jax.jit, static_argnames=("activation", "spec", "backend"))
def matmul_packed_fused(
    aq: jax.Array,                    # (M, K) int8 activations
    pw: pack.PackedWeights,
    a_scale: Optional[jax.Array] = None,    # per-tensor activation scale
    bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    spec: Optional[DataflowSpec] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Packed-weight GEMM with in-register decompress and fused epilogue:
    ``act((a_scale * w_scale) * (aq @ W) + bias) + residual`` -> f32,
    where ``W`` is the exact int8 image of the packed weight.

    The spec resolves through the autotune cache keyed on the
    ``weight_bits``-tagged :class:`GemmProblem`, so packed and plain
    layouts rank (and cache) independently.
    """
    fault = _inject("kernel.matmul")
    m, k = aq.shape
    if k != pw.k:
        raise ValueError(f"activation K={k} != packed weight k={pw.k}")
    n = pw.n
    backend = backend or ("pallas" if _on_tpu() else "xla")
    if a_scale is not None:
        a_scale = jnp.asarray(a_scale, jnp.float32)
        if a_scale.size != 1:
            raise ValueError(
                f"a_scale must be per-tensor (scalar), got {a_scale.shape}")
        a_scale = a_scale.reshape(1, 1)
    if backend == "xla":
        return _poison(ref.matmul_packed_ref(
            aq, pw, a_scale=a_scale, bias=bias, residual=residual,
            activation=activation,
        ), fault)
    scale = pw.scale if a_scale is None else a_scale * pw.scale  # (1, N)
    if bias is not None:
        bias = jnp.asarray(bias, jnp.float32).reshape(1, n)
    epi = Epilogue(
        scale=True, bias=bias is not None, activation=activation,
        residual=residual is not None,
    )
    spec = _resolve_spec(
        spec,
        _gemm_problem(m, k, n, aq.dtype, jnp.float32, weight_bits=pw.bits),
        backend)
    bm, bk, bn = spec.block
    gran = _packed_gran(pw.bits)
    if bk % gran:  # packed slabs decode in whole int32 words
        bk = max(gran, bk - bk % gran)
    # activations pad to the pack-time K (mult of 32), then to the block;
    # packed planes zero-pad along K/N — pad rows decode against zero
    # activation columns, pad columns are sliced off the output
    ap = _pad_to(jnp.pad(aq, ((0, 0), (0, pw.k_pad - k))), (bm, bk))
    codes = _pad_to(pw.codes, (bk // 8, bn))
    hi = (_pad_to(pw.highbits, (bk // 32, bn))
          if pw.highbits is not None else None)
    mp, kp, np_ = ap.shape[0], ap.shape[1], codes.shape[1]
    scale_p = _pad_to(scale, (1, bn))
    if bias is not None:
        bias = _pad_to(bias, (1, bn))
    if residual is not None:
        residual = _pad_to(residual, (bm, bn))
    comp = None
    if pw.outlier_idx.shape[0]:
        gathered = jnp.take(ap, pw.outlier_idx, axis=1, mode="fill",
                            fill_value=0).astype(jnp.int32)
        comp = _pad_to(
            jnp.dot(gathered, pw.outlier_delta,
                    preferred_element_type=jnp.int32),
            (bm, bn))
    spec = spec.with_block((min(bm, mp), min(bk, kp), min(bn, np_)))
    out = matmul_df.matmul_df(
        ap, codes, spec, out_dtype=jnp.float32,
        interpret=backend == "interpret",
        epilogue=epi, scale=scale_p, bias=bias, residual=residual,
        weight_bits=pw.bits, b_hi=hi, comp=comp,
    )
    return _poison(out[:m, :n], fault)


def matmul_packed(
    aq: jax.Array,
    pw: pack.PackedWeights,
    a_scale: Optional[jax.Array] = None,
    spec: Optional[DataflowSpec] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Packed-weight GEMM, dequant-only epilogue:
    ``(a_scale * w_scale) * (aq @ W)`` -> f32 (bit-exact vs the oracle)."""
    return matmul_packed_fused(aq, pw, a_scale=a_scale, spec=spec,
                               backend=backend)


@functools.partial(
    jax.jit,
    static_argnames=("stride", "activation", "spec", "b_oh", "bc", "bk",
                     "backend"),
)
def conv2d_packed_fused(
    xq: jax.Array,                    # (N, H, W, Cin) int8
    pcw: pack.PackedConvWeights,
    stride: int = 1,
    x_scale: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    spec: Optional[DataflowSpec] = None,
    b_oh: int = 8,
    bc: int = 128,
    bk: int = 128,
    backend: Optional[str] = None,
) -> jax.Array:
    """Packed-weight conv with in-register decompress and fused epilogue.

    Outlier compensation is materialized op-side: each sidecar slot is a
    (tap, channel) row whose activation window patch is sliced out of the
    padded image and rank-1-multiplied with the delta row; the summed
    (N, oh, ow, K) int32 term joins the accumulator at the kernel flush.
    """
    fault = _inject("kernel.conv2d")
    n, ih, iw, cin = xq.shape
    if cin != pcw.cin:
        raise ValueError(f"input channels {cin} != packed cin {pcw.cin}")
    fh, fw, kout, cp = pcw.fh, pcw.fw, pcw.kout, pcw.cin_pad
    oh = (ih - fh) // stride + 1
    ow = (iw - fw) // stride + 1
    backend = backend or ("pallas" if _on_tpu() else "xla")
    if x_scale is not None:
        x_scale = jnp.asarray(x_scale, jnp.float32)
        if x_scale.size != 1:
            raise ValueError(
                f"x_scale must be per-tensor (scalar), got {x_scale.shape}")
        x_scale = x_scale.reshape(1, 1)
    if backend == "xla":
        return _poison(ref.conv2d_packed_ref(
            xq, pcw, stride, x_scale=x_scale, bias=bias, residual=residual,
            activation=activation,
        ), fault)
    scale = pcw.scale if x_scale is None else x_scale * pcw.scale  # (1, K)
    if bias is not None:
        bias = jnp.asarray(bias, jnp.float32).reshape(1, kout)
    epi = Epilogue(
        scale=True, bias=bias is not None, activation=activation,
        residual=residual is not None,
    )
    override = spec if isinstance(spec, SpecOverride) else None
    if spec is None or override is not None:
        try:
            spec = autotune.best_spec(
                _conv_problem(n, ih, iw, fh, fw, stride, cin, kout,
                              xq.dtype, jnp.float32, weight_bits=pcw.bits),
                backend=backend,
            )
            b_oh, bc, bk = spec.block
        except ValueError:
            spec = DataflowSpec.optimized()  # see conv2d's fallback note
        if override is not None:
            spec = override.merge(spec.with_block((b_oh, bc, bk)))
            b_oh, bc, bk = spec.block
    gran = _packed_gran(pcw.bits)
    bc_ = min(bc, -(-cp // 128) * 128)
    if bc_ % gran:
        raise ValueError(
            f"packed conv needs a channel block divisible by {gran}, "
            f"got bc={bc_}")
    bk_ = min(bk, -(-kout // 128) * 128)
    b_oh_ = min(b_oh, oh)
    oh_pad = -(-oh // b_oh_) * b_oh_
    ih_need = (oh_pad - 1) * stride + fh + (stride - 1)
    iw_need = (ow - 1) * stride + fw + (stride - 1)
    # channels pad to the pack-time cin_pad (per-tap mult of 32) first so
    # the image and the planes agree on the lane layout, then to bc_
    xp = _pad_to(jnp.pad(xq, ((0, 0), (0, 0), (0, 0), (0, cp - cin))),
                 (1, 1, 1, bc_))
    xp = jnp.pad(
        xp,
        ((0, 0), (0, max(0, ih_need - ih)), (0, max(0, iw_need - iw)),
         (0, 0)),
    )
    codes = _pad_to(pcw.codes, (1, 1, bc_ // 8, bk_))
    hi = (_pad_to(pcw.highbits, (1, 1, bc_ // 32, bk_))
          if pcw.highbits is not None else None)
    kpad = codes.shape[3]
    scale_p = _pad_to(scale, (1, bk_))
    if bias is not None:
        bias = _pad_to(bias, (1, bk_))
    if residual is not None:
        residual = jnp.pad(
            residual,
            ((0, 0), (0, oh_pad - oh), (0, 0), (0, kpad - kout)),
        )
    comp = None
    cap = pcw.outlier_idx.shape[0]
    if cap:
        hslice = (oh_pad - 1) * stride + 1
        wslice = (ow - 1) * stride + 1
        delta_p = _pad_to(pcw.outlier_delta, (1, bk_))
        comp = jnp.zeros((n, oh_pad, ow, kpad), jnp.int32)
        for r in range(cap):
            f = pcw.outlier_idx[r]          # flat (ky*fw + kx)*cp + c
            ky = f // (fw * cp)
            kx = (f // cp) % fw
            c = f % cp
            # dynamic_slice clamps the sentinel row (ky == fh) in bounds;
            # its zero delta nullifies the garbage patch
            patch = jax.lax.dynamic_slice(
                xp, (0, ky, kx, c), (n, hslice, wslice, 1))
            patch = patch[:, ::stride, ::stride, 0].astype(jnp.int32)
            comp = comp + patch[..., None] * delta_p[r][None, None, None, :]
    out = conv2d_df.conv2d_df(
        xp, codes, stride, spec, oh=oh_pad, ow=ow, b_oh=b_oh_, bc=bc_,
        bk=bk_, out_dtype=jnp.float32, interpret=backend == "interpret",
        epilogue=epi, scale=scale_p, bias=bias, residual=residual,
        weight_bits=pcw.bits, w_hi=hi, comp=comp,
    )
    return _poison(out[:, :oh, :, :kout], fault)


def conv2d_packed(
    xq: jax.Array,
    pcw: pack.PackedConvWeights,
    stride: int = 1,
    x_scale: Optional[jax.Array] = None,
    spec: Optional[DataflowSpec] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Packed-weight conv, dequant-only epilogue (bit-exact vs oracle)."""
    return conv2d_packed_fused(xq, pcw, stride=stride, x_scale=x_scale,
                               spec=spec, backend=backend)
