"""Dataflow-parameterized direct convolution Pallas kernels (NHWC, TPU).

TPU adaptation of the paper's conv dataflows (DESIGN.md §2):
  * channel-last tiling = the paper's NCHWc with c = 128 lanes;
  * the input image is held **whole-resident** in VMEM (input auxiliary
    stationarity — conv inputs at the paper's scales fit comfortably);
  * weights are stripe-resident per output-channel tile.

Every anchor lowers as ONE ``pl.pallas_call`` with the ``(ky, kx,
cin-block)`` reduction innermost in the grid and a VMEM scratch
accumulator in the accumulator dtype; only the final, post-epilogue
value reaches HBM.  The anchors differ solely in the order of the outer
grid dimensions, which decides which operand's block index is held
constant (= fetched once) across the sweep:

  anchor=OS : grid (n, goh, gk, n_r) — output tile fixed while the
              reduction runs; the input image is fetched once per batch
              element (whole-resident auxiliary input stationarity).
  anchor=WS : grid (gk, n, goh, n_r) — the (fh, fw, C, bk) weight block
              is anchored outermost and fetched exactly once; the input
              image re-streams per output-channel tile.
  anchor=IS : grid (n, gk, goh, n_r) — the input image is anchored and
              fetched exactly once per batch element; weight blocks
              re-stream per image.

The previous lowering realized WS/IS as one aliased ``pallas_call`` per
reduction panel — ``n_r`` dispatches plus a ``jnp.zeros`` output init,
each round-tripping the full output through HBM.  The single-dispatch
form keeps those partial-sum round trips in VMEM; the analytic cost
model (``cost_model.conv_traffic``) intentionally keeps the paper's RMW
output accounting for basic WS/IS so the explorer's ranking stays
comparable with the paper's tables (same treatment as ``matmul_df``).

Fused epilogues: an ``Epilogue`` (dequant scale, bias, activation,
residual — ``core.dataflow.Epilogue``) is applied in-register at the
scratch flush of every anchor, so the raw accumulator never touches HBM
and the one output write carries the post-epilogue values.

Shapes must be pre-padded by ``ops.conv2d`` / ``ops.conv2d_fused``
(lane-aligned channels, halo rows/cols for the strided window loads).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.dataflow import DataflowSpec, Epilogue, OS, WS, IS
from repro.kernels.matmul_df import (
    _apply_epilogue, _epi_operands, _pop_packed, _read_epi,
)
from repro.kernels.pack import (
    WORD_BITS as _PLANE_K,
    WORD_NIBBLES as _PACK_K,
    unpack_block as _unpack_block,
)


def _acc_dtype(in_dtype) -> jnp.dtype:
    return jnp.int32 if jnp.issubdtype(in_dtype, jnp.integer) else jnp.float32


def _strided_window(x, b_oh: int, ow: int, s: int):
    """Select every s-th row/col from a contiguous (b_oh*s, ow*s, c) load."""
    if s == 1:
        return x
    c = x.shape[-1]
    x = x.reshape(b_oh, s, ow * s, c)[:, 0]
    x = x.reshape(b_oh, ow, s, c)[:, :, 0]
    return x


def _conv_kernel(x_ref, w_ref, *refs, fw: int, gc: int, bc: int, b_oh: int,
                 ow: int, s: int, n_r: int, tid: int,
                 epi: Optional[Epilogue], wb: Optional[int] = None,
                 has_comp: bool = False):
    whi_ref, comp_ref, refs = _pop_packed(refs, wb, has_comp)
    o_ref, acc_ref = refs[-2], refs[-1]
    epi_refs = refs[:-2]
    t = pl.program_id(tid)
    r = pl.program_id(3)
    ky = r // (fw * gc)
    kx = (r // gc) % fw
    cb = r % gc

    @pl.when(r == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    row0 = t * b_oh * s + ky
    xs = x_ref[0, pl.dslice(row0, b_oh * s), pl.dslice(kx, ow * s),
               pl.dslice(cb * bc, bc)]
    xs = _strided_window(xs, b_oh, ow, s)                      # (b_oh, ow, bc)
    if wb is None:
        wv = w_ref[ky, kx, pl.dslice(cb * bc, bc), :]          # (bc, bk)
    else:  # packed planes: decompress the (bc, bk) slab in-register
        rn = bc // _PACK_K
        wp = w_ref[ky, kx, pl.dslice(cb * rn, rn), :]
        hp = None
        if whi_ref is not None:
            rh = bc // _PLANE_K
            hp = whi_ref[ky, kx, pl.dslice(cb * rh, rh), :]
        wv = _unpack_block(wp, hp, wb, bc)
    part = jnp.dot(
        xs.reshape(b_oh * ow, bc), wv,
        preferred_element_type=acc_ref.dtype,
    ).reshape(b_oh, ow, -1)
    acc_ref[...] += part

    @pl.when(r == n_r - 1)
    def _flush():
        # scale/bias blocks ((1, 1) / (1, bk)) broadcast over the
        # (b_oh, ow, bk) accumulator; the residual block matches the
        # output block and drops its leading batch dim
        acc = acc_ref[...]
        if comp_ref is not None:   # outlier taps land at the flush
            acc = acc + comp_ref[0]
        scale, bias, residual = _read_epi(epi, epi_refs)
        if residual is not None:
            residual = residual[0]
        o_ref[0] = _apply_epilogue(
            epi, acc, scale, bias, residual, o_ref.dtype
        )


def conv2d_df(
    x: jax.Array,     # (N, ih_pad, iw_pad, C)   pre-padded
    w: jax.Array,     # (fh, fw, C, K)
    stride: int,
    spec: DataflowSpec,
    oh: int,
    ow: int,
    b_oh: int = 8,
    bc: int = 128,
    bk: int = 128,
    out_dtype=None,
    interpret: bool = False,
    epilogue: Optional[Epilogue] = None,
    scale: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    weight_bits: Optional[int] = None,
    w_hi: Optional[jax.Array] = None,
    comp: Optional[jax.Array] = None,
) -> jax.Array:
    """Direct conv under the given dataflow. Returns (N, oh, ow, K).

    With ``epilogue`` set, ``y = act(scale * acc + bias) + residual`` is
    applied in-register before the output write: ``scale`` is (1, 1)
    (per-tensor) or (1, K) (per-output-channel) float32, ``bias`` is
    (1, K) float32, ``residual`` is (N, oh, ow, K).

    With ``weight_bits`` set (4 or 5), ``w`` is the packed per-tap
    nibble plane (fh, fw, C/8, K) int32 from ``kernels/pack.py``
    (``w_hi`` the (fh, fw, C/32, K) bit plane at 5 bits); the kernel
    decompresses each (bc, bk) weight slab in-register at the reduction
    step.  ``comp`` is the optional (N, oh, ow, K) int32 outlier
    compensation added to the accumulator at the epilogue flush.
    """
    n, ih_pad, iw_pad, c = x.shape
    if weight_bits is None:
        fh, fw, _, kout = w.shape
    else:
        if weight_bits not in (4, 5):
            raise ValueError(f"weight_bits must be 4 or 5, got {weight_bits}")
        if not jnp.issubdtype(x.dtype, jnp.integer):
            raise ValueError(
                f"packed weights need integer activations, got {x.dtype}")
        fh, fw, cw, kout = w.shape
        if cw * 8 != c:
            raise ValueError(
                f"nibble plane channels {cw}*8 != input channels {c}")
        if bc % (32 if weight_bits == 5 else 8):
            raise ValueError(
                f"packed weight_bits={weight_bits} needs bc divisible by "
                f"{32 if weight_bits == 5 else 8}, got {bc}")
        if weight_bits == 5 and w_hi is None:
            raise ValueError("weight_bits=5 needs the w_hi bit plane")
    if c % bc or kout % bk or oh % b_oh:
        raise ValueError(f"untileable: C={c} bc={bc} K={kout} bk={bk} "
                         f"oh={oh} b_oh={b_oh}")
    gc, gk, goh = c // bc, kout // bk, oh // b_oh
    n_r = fh * fw * gc

    epi = epilogue if (epilogue is not None and not epilogue.is_noop) else None
    if comp is not None:
        if weight_bits is None:
            raise ValueError("comp is only meaningful with packed weights")
        if epi is None:
            raise ValueError(
                "outlier compensation requires a fused epilogue flush")
        if comp.shape != (n, oh, ow, kout):
            raise ValueError(
                f"comp shape {comp.shape} != ({n}, {oh}, {ow}, {kout})")
    if epi is not None:
        if epi.scale:
            if scale is None:
                raise ValueError("epilogue.scale set but no scale array")
            if scale.shape not in ((1, 1), (1, kout)):
                raise ValueError(
                    f"scale shape {scale.shape} != (1,1)/(1,{kout})"
                )
        if epi.bias:
            if bias is None:
                raise ValueError("epilogue.bias set but no bias array")
            if bias.shape != (1, kout):
                raise ValueError(f"bias shape {bias.shape} != (1, {kout})")
        if epi.residual:
            if residual is None:
                raise ValueError("epilogue.residual set but no residual array")
            if residual.shape != (n, oh, ow, kout):
                raise ValueError(
                    f"residual shape {residual.shape} != "
                    f"({n}, {oh}, {ow}, {kout})"
                )
    if out_dtype is None:
        out_dtype = jnp.float32 if epi is not None else _acc_dtype(x.dtype)

    # Grid order per anchor; the reduction r = (ky, kx, cin-block) is
    # always innermost so the output tile's revisits are consecutive.
    if spec.anchor == OS:
        grid = (n, goh, gk, n_r)
        bsel, tsel, jsel = (lambda g: g[0]), (lambda g: g[1]), (lambda g: g[2])
        tid = 1
    elif spec.anchor == WS:
        grid = (gk, n, goh, n_r)
        bsel, tsel, jsel = (lambda g: g[1]), (lambda g: g[2]), (lambda g: g[0])
        tid = 2
    elif spec.anchor == IS:
        grid = (n, gk, goh, n_r)
        bsel, tsel, jsel = (lambda g: g[0]), (lambda g: g[2]), (lambda g: g[1])
        tid = 2
    else:
        raise ValueError(spec.anchor)

    x_spec = pl.BlockSpec((1, ih_pad, iw_pad, c),
                          lambda *g: (bsel(g), 0, 0, 0))
    w_rows = c if weight_bits is None else c // _PACK_K
    w_spec = pl.BlockSpec((fh, fw, w_rows, bk), lambda *g: (0, 0, 0, jsel(g)))
    o_spec = pl.BlockSpec((1, b_oh, ow, bk),
                          lambda *g: (bsel(g), tsel(g), 0, jsel(g)))
    packed_args, packed_specs = [], []
    if w_hi is not None:
        packed_args.append(w_hi)
        packed_specs.append(pl.BlockSpec(
            (fh, fw, c // _PLANE_K, bk), lambda *g: (0, 0, 0, jsel(g))))
    if comp is not None:
        packed_args.append(comp)
        packed_specs.append(pl.BlockSpec(
            (1, b_oh, ow, bk), lambda *g: (bsel(g), tsel(g), 0, jsel(g))))

    epi_specs = []
    if epi is not None:
        if epi.scale:
            if scale.shape == (1, 1):
                epi_specs.append(pl.BlockSpec((1, 1), lambda *g: (0, 0)))
            else:
                epi_specs.append(
                    pl.BlockSpec((1, bk), lambda *g: (0, jsel(g))))
        if epi.bias:
            epi_specs.append(pl.BlockSpec((1, bk), lambda *g: (0, jsel(g))))
        if epi.residual:
            epi_specs.append(pl.BlockSpec(
                (1, b_oh, ow, bk), lambda *g: (bsel(g), tsel(g), 0, jsel(g))))
    epi_args = _epi_operands(epi, scale, bias, residual)

    kernel = functools.partial(
        _conv_kernel, fw=fw, gc=gc, bc=bc, b_oh=b_oh, ow=ow, s=stride,
        n_r=n_r, tid=tid, epi=epi, wb=weight_bits,
        has_comp=comp is not None,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec, w_spec, *packed_specs, *epi_specs],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, kout), out_dtype),
        scratch_shapes=[pltpu.VMEM((b_oh, ow, bk), _acc_dtype(x.dtype))],
        interpret=interpret,
    )(x, w, *packed_args, *epi_args)
