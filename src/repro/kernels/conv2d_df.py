"""Dataflow-parameterized direct convolution Pallas kernels (NHWC, TPU).

TPU adaptation of the paper's conv dataflows (DESIGN.md §2):
  * channel-last tiling = the paper's NCHWc with c = 128 lanes;
  * the input image is held **whole-resident** in VMEM (input auxiliary
    stationarity — conv inputs at the paper's scales fit comfortably);
  * weights are stripe-resident per output-channel tile;
  * anchor OS: reduction (ky, kx, cin-block) innermost, fp32/int32 scratch
    accumulator, output written once;
  * anchor WS: one aliased pallas_call per (ky, kx, cin-block) reduction
    panel — outputs round-trip HBM each step (the paper's WS traffic).

Shapes must be pre-padded by ``ops.conv2d`` (lane-aligned channels, halo
rows/cols for the strided window loads).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.dataflow import DataflowSpec, Stationarity, OS, WS, IS


def _acc_dtype(in_dtype) -> jnp.dtype:
    return jnp.int32 if jnp.issubdtype(in_dtype, jnp.integer) else jnp.float32


def _strided_window(x, b_oh: int, ow: int, s: int):
    """Select every s-th row/col from a contiguous (b_oh*s, ow*s, c) load."""
    if s == 1:
        return x
    c = x.shape[-1]
    x = x.reshape(b_oh, s, ow * s, c)[:, 0]
    x = x.reshape(b_oh, ow, s, c)[:, :, 0]
    return x


def _os_conv_kernel(x_ref, w_ref, o_ref, acc_ref, *, fh, fw, gc, bc, b_oh,
                    ow, s, n_r):
    r = pl.program_id(3)
    ky = r // (fw * gc)
    kx = (r // gc) % fw
    cb = r % gc

    @pl.when(r == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    t = pl.program_id(1)
    row0 = t * b_oh * s + ky
    xs = x_ref[0, pl.dslice(row0, b_oh * s), pl.dslice(kx, ow * s),
               pl.dslice(cb * bc, bc)]
    xs = _strided_window(xs, b_oh, ow, s)                      # (b_oh, ow, bc)
    wv = w_ref[ky, kx, pl.dslice(cb * bc, bc), :]              # (bc, bk)
    part = jnp.dot(
        xs.reshape(b_oh * ow, bc), wv,
        preferred_element_type=acc_ref.dtype,
    ).reshape(b_oh, ow, -1)
    acc_ref[...] += part

    @pl.when(r == n_r - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _ws_conv_panel_kernel(x_ref, w_ref, o_in_ref, o_ref, *, ky, kx, cb, bc,
                          b_oh, ow, s):
    t = pl.program_id(1)
    row0 = t * b_oh * s + ky
    xs = x_ref[0, pl.dslice(row0, b_oh * s), pl.dslice(kx, ow * s),
               pl.dslice(cb * bc, bc)]
    xs = _strided_window(xs, b_oh, ow, s)
    wv = w_ref[ky, kx, pl.dslice(cb * bc, bc), :]
    part = jnp.dot(
        xs.reshape(b_oh * ow, bc), wv, preferred_element_type=o_ref.dtype
    ).reshape(1, b_oh, ow, -1)
    o_ref[...] = o_in_ref[...] + part


def conv2d_df(
    x: jax.Array,     # (N, ih_pad, iw_pad, C)   pre-padded
    w: jax.Array,     # (fh, fw, C, K)
    stride: int,
    spec: DataflowSpec,
    oh: int,
    ow: int,
    b_oh: int = 8,
    bc: int = 128,
    bk: int = 128,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Direct conv under the given dataflow. Returns (N, oh, ow, K)."""
    n, ih_pad, iw_pad, c = x.shape
    fh, fw, _, kout = w.shape
    if c % bc or kout % bk or oh % b_oh:
        raise ValueError(f"untileable: C={c} bc={bc} K={kout} bk={bk} "
                         f"oh={oh} b_oh={b_oh}")
    gc, gk, goh = c // bc, kout // bk, oh // b_oh
    n_r = fh * fw * gc
    out_dtype = out_dtype or _acc_dtype(x.dtype)

    x_spec = pl.BlockSpec((1, ih_pad, iw_pad, c),
                          lambda b, t, j, *r: (b, 0, 0, 0))
    w_spec = pl.BlockSpec((fh, fw, c, bk), lambda b, t, j, *r: (0, 0, 0, j))
    o_spec = pl.BlockSpec((1, b_oh, ow, bk), lambda b, t, j, *r: (b, t, 0, j))

    if spec.anchor == OS:
        kernel = functools.partial(
            _os_conv_kernel, fh=fh, fw=fw, gc=gc, bc=bc, b_oh=b_oh, ow=ow,
            s=stride, n_r=n_r,
        )
        return pl.pallas_call(
            kernel,
            grid=(n, goh, gk, n_r),
            in_specs=[x_spec, w_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((n, oh, ow, kout), out_dtype),
            scratch_shapes=[pltpu.VMEM((b_oh, ow, bk), _acc_dtype(x.dtype))],
            interpret=interpret,
        )(x, w)

    if spec.anchor in (WS, IS):
        # WS: anchored weight panel (ky, kx, cb) re-fetched never; outputs
        # RMW HBM once per panel. (IS over conv degenerates to the same
        # panel loop with the input resident — the paper notes IS conv
        # becomes irregular for s>1; we realize it identically but keep the
        # traffic distinction in the cost model.)
        out = jnp.zeros((n, oh, ow, kout), out_dtype)
        for r in range(n_r):
            ky, kx, cb = r // (fw * gc), (r // gc) % fw, r % gc
            kernel = functools.partial(
                _ws_conv_panel_kernel, ky=ky, kx=kx, cb=cb, bc=bc, b_oh=b_oh,
                ow=ow, s=stride,
            )
            out = pl.pallas_call(
                kernel,
                grid=(n, goh, gk),
                in_specs=[x_spec, w_spec, o_spec],
                out_specs=o_spec,
                out_shape=jax.ShapeDtypeStruct((n, oh, ow, kout), out_dtype),
                input_output_aliases={2: 0},
                interpret=interpret,
            )(x, w, out)
        return out

    raise ValueError(spec.anchor)
