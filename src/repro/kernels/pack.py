"""Sub-byte (MSR-coded) weight packing with a sparse outlier sidecar.

The third quantization tier, between int8 and binary: trained int8
weights overwhelmingly carry a run of identical most-significant bits
(the MSR analysis of the Low-Cost-AI-Accelerator line of work — >=99%
of rows fit 4-5 bits), so the int8 weight matrix is stored as dense
sub-byte *codes* plus a tiny exact-correction sidecar:

  * per-column(-group) symmetric int8 pre-quantization
    (``core.quant.symmetric_int8``) -> ``q`` (K, N) int8, ``scale``
    (1, N) float32;
  * offset-binary codes ``u = clip(q, lo, hi) + 2**(bits-1)`` with
    ``[lo, hi] = [-2**(bits-1), 2**(bits-1)-1]``, bits in {4, 5};
  * **nibble plane** ``codes``: (K/8, N) int32 — the low 4 code bits of
    8 consecutive K rows per 32-bit word (row ``r*8 + t`` lives in bits
    ``[4t, 4t+4)``);
  * **bit plane** ``highbits`` (bits == 5 only): (K/32, N) int32 — code
    bit 4 of 32 consecutive K rows per word;
  * **outlier sidecar**: K rows where ``q`` falls outside ``[lo, hi]``
    (no MSR run) are stored exactly as ``delta = q_row - clip(q_row)``
    under ``(outlier_idx (R,) int32, outlier_delta (R, N) int32)``.
    Unused capacity slots carry ``idx == k_pad`` and zero deltas, so
    fixed-capacity packing is traceable under jit/vmap (stacked
    per-layer params).

K is padded to a multiple of 32 at pack time; the pad rows encode the
value 0 exactly, so any block-padded GEMM over the planes is exact.

The planes are what the Pallas kernels stream: ``matmul_df`` /
``conv2d_df`` load packed int32 words per block and decompress to int8
lanes in VMEM via :func:`unpack_block` (shift/mask/reshape — no HBM
round trip of the decompressed matrix), then run the usual exact
int8 x int8 -> int32 dot.  The outlier correction is the rank-R term
``A[:, idx] @ delta``; ``ops.matmul_packed`` feeds it to the kernel as
a precomputed compensation operand added to the accumulator at the
epilogue-side flush.

Byte accounting for the explorer lives in
``core.cost_model.packed_weight_bytes`` (charged when a problem's
``weight_bits`` is set).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import symmetric_int8

WORD_NIBBLES = 8       # 4-bit codes per int32 word (nibble plane)
WORD_BITS = 32         # bit-plane entries per int32 word
PACK_BITS = (4, 5)     # supported code widths


def outlier_capacity(k: int) -> int:
    """Worst-case MSR outlier rows for a K-deep weight: <=3 per 256."""
    return max(1, -(-(3 * k) // 256))


def _code_range(bits: int) -> Tuple[int, int]:
    return -(1 << (bits - 1)), (1 << (bits - 1)) - 1


def _bitcast_i32(words: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(words.astype(jnp.uint32), jnp.int32)


def _pack_nibbles(u: jax.Array) -> jax.Array:
    """(K, N) codes in [0, 16) -> (K/8, N) int32 words (K % 8 == 0)."""
    kp, n = u.shape
    w = u.astype(jnp.uint32).reshape(kp // WORD_NIBBLES, WORD_NIBBLES, n)
    shifts = (jnp.arange(WORD_NIBBLES, dtype=jnp.uint32) * 4)[None, :, None]
    return _bitcast_i32(jnp.sum(w << shifts, axis=1, dtype=jnp.uint32))


def _pack_bits(b: jax.Array) -> jax.Array:
    """(K, N) bits in {0, 1} -> (K/32, N) int32 words (K % 32 == 0)."""
    kp, n = b.shape
    w = b.astype(jnp.uint32).reshape(kp // WORD_BITS, WORD_BITS, n)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)[None, :, None]
    return _bitcast_i32(jnp.sum(w << shifts, axis=1, dtype=jnp.uint32))


def unpack_block(words: jax.Array, hi_words: Optional[jax.Array],
                 bits: int, rows: int) -> jax.Array:
    """Decode packed int32 words to int8 lanes — the in-register decompress.

    ``words`` is a (rows/8, cols) nibble-plane block, ``hi_words`` the
    matching (rows/32, cols) bit-plane block when ``bits == 5``.  Pure
    shift/mask/reshape on values already in VMEM, so it lowers inside a
    Pallas kernel body at block-load time.  (The arithmetic right shift
    on int32 drags sign bits through the top nibble; the ``& 0xF`` mask
    discards them.)
    """
    cols = words.shape[-1]
    shifts = (jnp.arange(WORD_NIBBLES, dtype=jnp.int32) * 4)[None, :, None]
    u = (words[:, None, :] >> shifts) & 0xF
    u = u.reshape(rows, cols)
    if bits == 5:
        hs = jnp.arange(WORD_BITS, dtype=jnp.int32)[None, :, None]
        hb = (hi_words[:, None, :] >> hs) & 0x1
        u = u + (hb.reshape(rows, cols) << 4)
    return (u - (1 << (bits - 1))).astype(jnp.int8)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PackedWeights:
    """Packed sub-byte weight planes + per-group scales + outlier sidecar.

    A pytree (planes/scale/sidecar are leaves; ``bits``/``k``/``n`` are
    static aux data), so stacked per-layer packed params vmap/scan like
    any other parameter subtree.
    """

    codes: jax.Array                 # (k_pad/8, n) int32 nibble plane
    highbits: Optional[jax.Array]    # (k_pad/32, n) int32, bits == 5 only
    scale: jax.Array                 # (1, n) float32 per-column(-group)
    outlier_idx: jax.Array           # (r,) int32; k_pad marks empty slots
    outlier_delta: jax.Array         # (r, n) int32 exact row corrections
    bits: int                        # 4 or 5
    k: int                           # true reduction length
    n: int

    @property
    def k_pad(self) -> int:
        return self.codes.shape[-2] * WORD_NIBBLES

    def tree_flatten(self):
        leaves = (self.codes, self.highbits, self.scale,
                  self.outlier_idx, self.outlier_delta)
        return leaves, (self.bits, self.k, self.n)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)


def _pack_core(qp: jax.Array, bits: int, max_outliers: Optional[int]):
    """Shared plane/sidecar construction for a row-padded (Kp, N) int32
    matrix (Kp % 32 == 0) -> (codes, highbits, idx, delta)."""
    kp = qp.shape[0]
    lo, hi = _code_range(bits)
    trunc = jnp.clip(qp, lo, hi)
    u = trunc + (1 << (bits - 1))              # offset-binary, >= 0
    codes = _pack_nibbles(u & 0xF)
    highbits = _pack_bits((u >> 4) & 0x1) if bits == 5 else None

    is_out = jnp.any(qp != trunc, axis=1)      # (kp,) rows with no MSR run
    if max_outliers is None:
        mask = np.asarray(jax.device_get(is_out))
        idx = jnp.asarray(np.nonzero(mask)[0], jnp.int32)
    else:
        cap = int(max_outliers)
        if not isinstance(is_out, jax.core.Tracer):
            r_true = int(jnp.sum(is_out))
            if r_true > cap:
                raise ValueError(
                    f"{r_true} outlier rows exceed max_outliers={cap}")
        idx = jnp.nonzero(is_out, size=cap, fill_value=kp)[0].astype(jnp.int32)
    delta = (jnp.take(qp, idx, axis=0, mode="fill", fill_value=0)
             - jnp.take(trunc, idx, axis=0, mode="fill", fill_value=0))
    return codes, highbits, idx, delta.astype(jnp.int32)


def pack_int8(q: jax.Array, scale: jax.Array, bits: int = 4,
              max_outliers: Optional[int] = None) -> PackedWeights:
    """Pack an int8 weight matrix (K, N) into sub-byte planes + sidecar.

    ``max_outliers=None`` (concrete arrays only) sizes the sidecar to the
    actual outlier count; an int gives a fixed capacity so packing is
    traceable under jit/vmap — the caller guarantees the data fits (a
    concrete overflow raises, a traced one cannot be checked).
    """
    if bits not in PACK_BITS:
        raise ValueError(f"weight_bits must be one of {PACK_BITS}, got {bits}")
    if q.ndim != 2:
        raise ValueError(f"expected a (K, N) weight matrix, got {q.shape}")
    k, n = q.shape
    qp = jnp.asarray(q, jnp.int32)
    pad = (-k) % WORD_BITS
    if pad:
        qp = jnp.pad(qp, ((0, pad), (0, 0)))   # value 0 encodes exactly
    codes, highbits, idx, delta = _pack_core(qp, bits, max_outliers)
    scale = jnp.broadcast_to(
        jnp.asarray(scale, jnp.float32).reshape(1, -1), (1, n))
    return PackedWeights(codes, highbits, scale, idx, delta, bits, k, n)


def pack_weights(w: jax.Array, bits: int = 4, group_size: int = 1,
                 max_outliers: Optional[int] = None) -> PackedWeights:
    """Quantize a float weight matrix (K, N) to int8 and pack it.

    The symmetric int8 scale is shared per group of ``group_size``
    adjacent output columns (group 1 = per-column).  Groups run along N,
    not K: the kernel decompresses to exact int8 *codes* at the block
    load and applies the scale once at the epilogue flush, which demands
    a scale constant along the reduction.
    """
    k, n = w.shape
    if group_size <= 0 or n % group_size:
        raise ValueError(f"group_size {group_size} must divide n={n}")
    wg = w.reshape(k, n // group_size, group_size)
    qg, sg = symmetric_int8(wg, axis=(0, 2))          # (1, G, 1) scales
    scale = jnp.broadcast_to(sg, (1, n // group_size, group_size))
    return pack_int8(qg.reshape(k, n), scale.reshape(1, n), bits=bits,
                     max_outliers=max_outliers)


def unpack_codes(pw: PackedWeights) -> jax.Array:
    """Dense int8 matrix (k, n) from the planes alone (outliers still
    truncated — this is exactly what the kernel's in-register decompress
    reconstructs before compensation)."""
    q = unpack_block(pw.codes, pw.highbits, pw.bits, pw.k_pad)
    return q[: pw.k]


def unpack_weights(pw: PackedWeights) -> Tuple[jax.Array, jax.Array]:
    """Exact int8 reconstruction -> (q (k, n) int8, scale (1, n) f32).

    Scatters the outlier deltas back over the truncated codes; empty
    sidecar slots (idx == k_pad) drop out of bounds.
    """
    qp = jnp.pad(unpack_codes(pw).astype(jnp.int32),
                 ((0, pw.k_pad - pw.k), (0, 0)))
    qp = qp.at[pw.outlier_idx].add(pw.outlier_delta, mode="drop")
    return qp[: pw.k].astype(jnp.int8), pw.scale


def dequantize(pw: PackedWeights, dtype=jnp.float32) -> jax.Array:
    """Float reconstruction (k, n): exact int8 image times the scale."""
    q, scale = unpack_weights(pw)
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Conv weights: the same planes, laid out per filter tap.
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PackedConvWeights:
    """Packed (fh, fw, C, K) conv weights.

    Channels are padded to a multiple of 32 *per tap* so the planes tile
    along the input-channel axis exactly like the dense weight does in
    ``conv2d_df`` (the kernel slices a (bc, bk) slab per reduction step
    and decompresses it in-register).  Outlier rows live in the
    flattened ``(ky * fw + kx) * cin_pad + c`` index space; empty slots
    carry ``idx == fh * fw * cin_pad`` and zero deltas.
    """

    codes: jax.Array                 # (fh, fw, cin_pad/8, kout) int32
    highbits: Optional[jax.Array]    # (fh, fw, cin_pad/32, kout) int32
    scale: jax.Array                 # (1, kout) float32 per output channel
    outlier_idx: jax.Array           # (r,) int32 flat tap-channel rows
    outlier_delta: jax.Array         # (r, kout) int32
    bits: int
    fh: int
    fw: int
    cin: int                         # true input channels
    cin_pad: int                     # per-tap padded channels (mult of 32)
    kout: int

    def tree_flatten(self):
        leaves = (self.codes, self.highbits, self.scale,
                  self.outlier_idx, self.outlier_delta)
        aux = (self.bits, self.fh, self.fw, self.cin, self.cin_pad,
               self.kout)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)


def pack_conv_weights(w: jax.Array, bits: int = 4,
                      max_outliers: Optional[int] = None
                      ) -> PackedConvWeights:
    """Quantize (fh, fw, C, K) conv weights per output channel and pack."""
    if bits not in PACK_BITS:
        raise ValueError(f"weight_bits must be one of {PACK_BITS}, got {bits}")
    fh, fw, c, kout = w.shape
    q, scale = symmetric_int8(w, axis=(0, 1, 2))      # scale (1, 1, 1, K)
    cp = c + ((-c) % WORD_BITS)
    qp = jnp.pad(q.astype(jnp.int32), ((0, 0), (0, 0), (0, cp - c), (0, 0)))
    codes, highbits, idx, delta = _pack_core(
        qp.reshape(fh * fw * cp, kout), bits, max_outliers)
    codes = codes.reshape(fh, fw, cp // WORD_NIBBLES, kout)
    if highbits is not None:
        highbits = highbits.reshape(fh, fw, cp // WORD_BITS, kout)
    return PackedConvWeights(codes, highbits, scale.reshape(1, kout),
                             idx, delta, bits, fh, fw, c, cp, kout)


def unpack_conv_weights(pcw: PackedConvWeights
                        ) -> Tuple[jax.Array, jax.Array]:
    """Exact int8 reconstruction -> (q (fh, fw, cin, K) int8, scale)."""
    flat_rows = pcw.fh * pcw.fw * pcw.cin_pad
    codes = pcw.codes.reshape(flat_rows // WORD_NIBBLES, pcw.kout)
    hi = (pcw.highbits.reshape(flat_rows // WORD_BITS, pcw.kout)
          if pcw.highbits is not None else None)
    q = unpack_block(codes, hi, pcw.bits, flat_rows).astype(jnp.int32)
    q = q.at[pcw.outlier_idx].add(pcw.outlier_delta, mode="drop")
    q = q.reshape(pcw.fh, pcw.fw, pcw.cin_pad, pcw.kout)[:, :, : pcw.cin]
    return q.astype(jnp.int8), pcw.scale
