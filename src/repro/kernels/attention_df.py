"""Dataflow-parameterized attention Pallas kernels (TPU target).

The paper's central result — OS-anchored dataflows with auxiliary weight
stationarity win — *predicts* flash attention when applied to the attention
operator:

  * OS anchor: the output tile (one block of query rows) is the anchored
    operand; the online-softmax statistics and the output accumulator live
    in VMEM scratch across the whole KV sweep; outputs are written to HBM
    exactly once.  KV blocks stream (they are the "weights").
  * WS anchor (comparison variant): KV blocks are anchored — each is
    fetched exactly once — while the running (acc, m, l) partials are
    read-modify-written through HBM once per KV block.  This reproduces the
    paper's WS output-traffic pathology at attention scale and is used by
    the benchmarks, not the models.

GQA is handled by an index-map head mapping (q head -> kv head).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bkv: int, gkv: int, scale: float, causal: bool,
                  window: Optional[int], sq: int, skv: int, skv_valid: int):
    iq, jk = pl.program_id(1), pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                      # (bq, d)
    k = k_ref[0].astype(jnp.float32)                      # (bkv, d)
    v = v_ref[0].astype(jnp.float32)                      # (bkv, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) \
        + (skv_valid - sq)                                # right-aligned
    kpos = jk * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = kpos < skv_valid                               # padding
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, :1]                                 # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)            # (bq, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                                # (bq, bkv)
    alpha = jnp.exp(m_prev - m_new)                       # (bq, 1)
    l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(jk == gkv - 1)
    def _flush():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)                   # fully-masked rows
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,            # (BH, Sq, D)   batch*q_heads folded
    k: jax.Array,            # (BHkv, Skv, D)
    v: jax.Array,
    group: int = 1,          # q_heads per kv head (GQA)
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    skv_valid: Optional[int] = None,
    sq_valid: Optional[int] = None,
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """OS-anchored attention. Sq % bq == 0 and Skv % bkv == 0 (pre-padded).

    ``sq_valid``/``skv_valid`` are the true (pre-padding) lengths; the
    causal mask right-aligns the true q rows against the true kv length.
    ``bq``/``bkv`` come from the caller — ``ops.attention`` resolves
    them from the autotuned registry spec and clamps them to the padded
    sequence (``cost_model.attention_block_clamp``) before calling in.
    """
    bh, sq, d = q.shape
    skv = k.shape[1]
    gq, gkv = sq // bq, skv // bkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    skv_valid = skv if skv_valid is None else skv_valid
    sq_valid = sq if sq_valid is None else sq_valid

    kernel = functools.partial(
        _flash_kernel, bq=bq, bkv=bkv, gkv=gkv, scale=scale, causal=causal,
        window=window, sq=sq_valid, skv=skv, skv_valid=skv_valid,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, gq, gkv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# WS-anchored (KV-stationary) attention: benchmark variant.
# ---------------------------------------------------------------------------
def _kv_stationary_kernel(q_ref, k_ref, v_ref, acc_in, m_in, l_in,
                          acc_out, m_out, l_out, *, jk: Optional[int],
                          bq: int, bkv: int, scale: float, causal: bool,
                          window: Optional[int], sq: int, skv_valid: int):
    """One KV block's online-softmax update.

    ``jk=None``: single-dispatch form — the KV sweep is grid dim 1, the
    state refs are the revisited output buffers (in == out), initialized
    in-kernel at the first KV block.  ``jk=int``: per-block form — one
    call per KV block, state carried through aliased input/output pairs.
    """
    if jk is None:
        jk_idx, iq = pl.program_id(1), pl.program_id(2)

        @pl.when(jk_idx == 0)
        def _init():
            acc_in[...] = jnp.zeros_like(acc_in)
            m_in[...] = jnp.full_like(m_in, NEG_INF)
            l_in[...] = jnp.zeros_like(l_in)
    else:
        jk_idx, iq = jk, pl.program_id(1)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) \
        + (skv_valid - sq)
    kpos = jk_idx * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = kpos < skv_valid
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_in[0][:, :1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_in[0][:, :1] + jnp.sum(p, axis=-1, keepdims=True)
    acc_out[0] = acc_in[0] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_out[0] = jnp.broadcast_to(m_new, m_out.shape[1:])
    l_out[0] = jnp.broadcast_to(l_new, l_out.shape[1:])


def _kv_single_kernel(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref, **kw):
    _kv_stationary_kernel(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
                          acc_ref, m_ref, l_ref, **kw)


def kv_stationary_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    group: int = 1, causal: bool = True, window: Optional[int] = None,
    scale: Optional[float] = None, skv_valid: Optional[int] = None,
    sq_valid: Optional[int] = None,
    bq: int = 128, bkv: int = 128, interpret: bool = False,
) -> jax.Array:
    """WS-anchored attention: each KV block fetched exactly once, the
    (acc, m, l) running partials round-tripping HBM once per KV block
    (the paper's WS output traffic).

    ``bq``/``bkv`` come from the caller on BOTH lowerings — the
    interpret-mode single dispatch and the compiled per-KV-block
    aliased-call loop — so when ``ops.attention`` resolves them from
    the autotuned registry spec, both anchors honor the autotuned block
    (previously the compiled loop only ever saw these keyword
    defaults).

    In interpret mode — where this benchmark variant runs and is
    compared against flash attention — it lowers as ONE ``pallas_call``
    with grid (bh, gkv, gq): the state blocks, indexed by the *inner*
    q-tile dim, are revisited once per KV block and carry the partials
    between non-consecutive visits through their HBM buffers
    (initialized in-kernel at the first KV block, no zeros-init arrays),
    so per-block dispatch overhead no longer pollutes the OS/WS
    comparison.  Persisting output blocks across non-consecutive
    revisits relies on sequential grid execution — an interpret-mode
    property, not a documented Pallas TPU guarantee — so on compiled
    backends the realized lowering stays the well-defined per-KV-block
    aliased-call loop (same traffic, gkv dispatches).
    """
    bh, sq, d = q.shape
    skv = k.shape[1]
    gq, gkv = sq // bq, skv // bkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    skv_valid = skv if skv_valid is None else skv_valid
    sq_valid = sq if sq_valid is None else sq_valid
    kw = dict(bq=bq, bkv=bkv, scale=scale, causal=causal, window=window,
              sq=sq_valid, skv_valid=skv_valid)
    out_shape = [
        jax.ShapeDtypeStruct((bh, sq, d), jnp.float32),
        jax.ShapeDtypeStruct((bh, sq, 128), jnp.float32),
        jax.ShapeDtypeStruct((bh, sq, 128), jnp.float32),
    ]

    if interpret:
        state_spec = pl.BlockSpec((1, bq, d), lambda b, j, i: (b, i, 0))
        stat_spec = pl.BlockSpec((1, bq, 128), lambda b, j, i: (b, i, 0))
        acc, m, l = pl.pallas_call(
            functools.partial(_kv_single_kernel, jk=None, **kw),
            grid=(bh, gkv, gq),
            in_specs=[
                pl.BlockSpec((1, bq, d), lambda b, j, i: (b, i, 0)),
                pl.BlockSpec((1, bkv, d),
                             lambda b, j, i, g=group: (b // g, j, 0)),
                pl.BlockSpec((1, bkv, d),
                             lambda b, j, i, g=group: (b // g, j, 0)),
            ],
            out_specs=[state_spec, stat_spec, stat_spec],
            out_shape=out_shape,
            interpret=True,
        )(q, k, v)
    else:
        acc = jnp.zeros((bh, sq, d), jnp.float32)
        m = jnp.full((bh, sq, 128), NEG_INF, jnp.float32)
        l = jnp.zeros((bh, sq, 128), jnp.float32)
        state_spec = pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0))
        stat_spec = pl.BlockSpec((1, bq, 128), lambda b, i: (b, i, 0))
        for jk in range(gkv):
            acc, m, l = pl.pallas_call(
                functools.partial(_kv_stationary_kernel, jk=jk, **kw),
                grid=(bh, gq),
                in_specs=[
                    pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
                    pl.BlockSpec((1, bkv, d),
                                 lambda b, i, j=jk, g=group: (b // g, j, 0)),
                    pl.BlockSpec((1, bkv, d),
                                 lambda b, i, j=jk, g=group: (b // g, j, 0)),
                    state_spec, stat_spec, stat_spec,
                ],
                out_specs=[state_spec, stat_spec, stat_spec],
                out_shape=out_shape,
                input_output_aliases={3: 0, 4: 1, 5: 2},
            )(q, k, v, acc, m, l)
    lsafe = jnp.where(l[:, :, :1] == 0.0, 1.0, l[:, :, :1])
    return (acc / lsafe).astype(q.dtype)
