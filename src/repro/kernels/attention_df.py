"""Dataflow-parameterized attention Pallas kernels (TPU target).

The paper's central result — OS-anchored dataflows with auxiliary weight
stationarity win — *predicts* flash attention when applied to the attention
operator:

  * OS anchor: the output tile (one block of query rows) is the anchored
    operand; the online-softmax statistics and the output accumulator live
    in VMEM scratch across the whole KV sweep; outputs are written to HBM
    exactly once.  KV blocks stream (they are the "weights").
  * WS anchor (comparison variant): KV blocks are anchored — each is
    fetched exactly once — while the running (acc, m, l) partials are
    read-modify-written through HBM once per KV block.  This reproduces the
    paper's WS output-traffic pathology at attention scale and is used by
    the benchmarks, not the models.

Banded execution (PR 5): both lowerings take a *traced* valid KV length
(``kv_len`` — the filled prefix of a padded KV-cache buffer) and a
static or traced sliding ``window``, and skip KV blocks the mask fully
excludes — in the *grid*, not just in the lanes:

  * the banding scalars ride in a ``PrefetchScalarGridSpec`` info array,
    so the KV *index maps* clamp out-of-band grid steps onto the band's
    edge block (a revisited index — no new DMA is issued) and
    ``pl.when`` skips their compute entirely;
  * with a static window the flash grid's KV dimension itself shrinks to
    the band width ``ceil((bq + window - 1) / bkv) + 1`` and the WS
    compiled per-block loop drops statically-invisible blocks, so the
    skipped work disappears from the lowering (visible in the
    ``pallas_call`` grid / dispatch counts);
  * decode traffic therefore scales with the *valid* cache length, not
    ``max_len`` — the "prune work the dataflow can prove is masked"
    discipline the banded cost model (``cost_model.attention_band``)
    charges for.  The cost model and these index maps share one banding
    rule; keep them in sync.

Per-row banding (PR 8): ``kv_len`` may be a per-batch-row ``(R,)``
array — ``make_band_info`` then builds an ``(R, 2)`` info array and
every index map / mask derives its row as ``b // (bh // R)``, so a
ragged continuous-batching decode step bands each request at its own
valid length in ONE dispatch.  ``paged_flash_attention`` extends the
same scalar-prefetch trick to a paged KV cache: the per-row block
table is part of the prefetch array and the KV index maps dereference
it to translate logical blocks into physical page ids (a page table
*is* an index map).

int8 KV caches dequantize at the block load: K/V stream as int8 with
per-position f32 scales (``k_scale``/``v_scale``, shape (BHkv, Skv, 1)),
multiplied in-register after the VMEM fetch — the cache never
round-trips HBM as a float copy.  (The (…, 1) scale lane is
interpret-mode friendly; a compiled TPU lowering would lane-pad it.)

GQA is handled by an index-map head mapping (q head -> kv head).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
# "no sliding window" sentinel inside the banding info array (matches
# models/lm.FULL_WINDOW so traced per-layer windows pass through).
HUGE_WINDOW = 2 ** 30


# ---------------------------------------------------------------------------
# Banding: the one rule deciding which KV blocks a q tile visits.
# ---------------------------------------------------------------------------
def make_band_info(kv_len, window, window_dyn, skv_valid: int) -> jax.Array:
    """The int32 scalar-prefetch array: ``[valid KV length, window]``.

    ``kv_len`` (traced or int) overrides the static true length
    ``skv_valid``; ``window_dyn`` (traced) overrides the static
    ``window``; no window at all encodes as ``HUGE_WINDOW``.

    Shape contract (PR 8): a scalar ``kv_len`` yields the legacy
    ``(2,)`` array; a *per-batch-row* ``kv_len`` of shape ``(R,)``
    yields ``(R, 2)`` — one ``[kv_valid, window]`` pair per row — and
    the kernels derive each grid step's row as ``b // (bh // R)`` to
    band per row.  ``window``/``window_dyn`` broadcast across rows.
    """
    kv_valid = skv_valid if kv_len is None else kv_len
    if window_dyn is not None:
        w = window_dyn
    elif window is not None:
        w = window
    else:
        w = HUGE_WINDOW
    kv_valid = jnp.asarray(kv_valid, jnp.int32)
    if kv_valid.ndim == 1:                  # ragged: one band per row
        w = jnp.broadcast_to(jnp.asarray(w, jnp.int32).reshape(-1),
                             kv_valid.shape)
        return jnp.stack([kv_valid, w], axis=-1)
    return jnp.stack([
        kv_valid.reshape(()),
        jnp.asarray(w, jnp.int32).reshape(()),
    ])


def _info_pair(info, row):
    """``(kv_valid, window)`` for batch row ``row`` of an info array in
    either the legacy ``(2,)`` or the per-row ``(R, 2)`` shape."""
    if len(info.shape) == 2:
        return info[row, 0], info[row, 1]
    return info[0], info[1]


def _heads_per_row(bh: int, info: jax.Array) -> int:
    """Head-rows per batch row for a per-row ``(R, 2)`` info array; 0
    (the "no row mapping" sentinel) for the legacy ``(2,)`` shape."""
    if info.ndim != 2:
        return 0
    rows = info.shape[0]
    if bh % rows:
        raise ValueError(
            f"folded bh={bh} not divisible by {rows} per-row kv_len rows"
        )
    return bh // rows


def _band_lo_hi(i, info, *, bq: int, bkv: int, sq: int, causal: bool,
                windowed: bool, row=0):
    """Traced [lo, hi] inclusive KV-block band for q tile ``i`` of batch
    row ``row``.

    Mirrors ``cost_model.attention_band`` exactly (the cost model is the
    documented source of the rule): q rows right-align against the valid
    KV length, ``hi`` is clamped by the valid prefix and the causal
    diagonal, ``lo`` by the sliding window.  ``row`` indexes a per-row
    ``(R, 2)`` info array (ignored for the legacy ``(2,)`` shape).
    """
    kv_valid, win = _info_pair(info, row)
    off = kv_valid - sq
    hi = jnp.maximum(0, (kv_valid + bkv - 1) // bkv - 1)
    if causal:
        qmax = (i + 1) * bq - 1 + off
        hi = jnp.minimum(hi, jnp.maximum(qmax, 0) // bkv)
    if windowed:
        qmin = i * bq + off
        lo = jnp.maximum(0, (qmin - win + 1) // bkv)
        lo = jnp.minimum(lo, hi)
    else:
        lo = jnp.zeros_like(hi)
    return lo, hi


def static_band(gkv: int, skv_valid: int, bq: int, bkv: int,
                window: Optional[int], causal: bool = True) -> int:
    """The static KV grid extent per q tile (the flash grid's dim 2).

    The valid true length bounds it at ``ceil(skv_valid / bkv)``; a
    *static* window under a *causal* mask tightens it to the band
    width — each q tile's visible blocks then span at most
    ``bq + window - 1`` positions.  Without the causal upper bound the
    window only cuts the past (the band still reaches the last valid
    block), so no static shrink applies.  Traced lengths/windows can
    only shrink the band further at run time (the index-map clamp +
    ``pl.when`` skip handle those steps).
    """
    band = -(-skv_valid // bkv)
    if window is not None and causal:
        band = min(band, -(-(bq + window - 1) // bkv) + 1)
    return max(1, min(band, gkv))


def _score_mask(i, jblk, info, *, bq: int, bkv: int, sq: int, causal: bool,
                windowed: bool, row=0):
    """(bq, bkv) lane mask for q tile ``i`` against KV block ``jblk``."""
    kv_valid, win = _info_pair(info, row)
    off = kv_valid - sq
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) + off
    kpos = jblk * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = kpos < kv_valid
    if causal:
        mask &= kpos <= qpos
    if windowed:
        mask &= kpos > qpos - win
    return mask


def _load_kv(k_ref, v_ref, ks_ref, vs_ref):
    """Fetch one KV block, dequantizing int8 at the load when scales
    are present — the float image exists only in registers/VMEM."""
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    if ks_ref is not None:
        k = k * ks_ref[0]                 # (bkv, 1) per-position scales
        v = v * vs_ref[0]
    return k, v


# ---------------------------------------------------------------------------
# OS-anchored (flash) attention.
# ---------------------------------------------------------------------------
def _flash_kernel(info_ref, *refs, bq: int, bkv: int, band: int,
                  scale: float, causal: bool, windowed: bool, sq: int,
                  quant: bool, heads: int = 0):
    if quant:
        q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref \
            = refs
    else:
        q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = refs
        ks_ref = vs_ref = None
    i, jr = pl.program_id(1), pl.program_id(2)
    # per-row banding: grid dim 0 walks batch*heads; ``heads`` head-rows
    # share each batch row's [kv_valid, window] pair (0 = legacy scalar)
    row = pl.program_id(0) // heads if heads else 0
    lo, hi = _band_lo_hi(i, info_ref, bq=bq, bkv=bkv, sq=sq, causal=causal,
                         windowed=windowed, row=row)
    jblk = jnp.minimum(lo + jr, hi)       # == the index-map fetch

    @pl.when(jr == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(lo + jr <= hi)               # out-of-band step: zero work
    def _update():
        q = q_ref[0].astype(jnp.float32)              # (bq, d)
        k, v = _load_kv(k_ref, v_ref, ks_ref, vs_ref)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        mask = _score_mask(i, jblk, info_ref, bq=bq, bkv=bkv, sq=sq,
                           causal=causal, windowed=windowed, row=row)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, :1]                         # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # explicit lane zeroing: a fully-masked block must contribute
        # nothing even while m is still NEG_INF (exp(s - m_new) = 1.0)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(jr == band - 1)
    def _flush():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)               # fully-masked rows
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,            # (BH, Sq, D)   batch*q_heads folded
    k: jax.Array,            # (BHkv, Skv, D)  float, or int8 with scales
    v: jax.Array,
    group: int = 1,          # q_heads per kv head (GQA)
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    skv_valid: Optional[int] = None,
    sq_valid: Optional[int] = None,
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = False,
    kv_len: Optional[jax.Array] = None,
    window_dyn: Optional[jax.Array] = None,
    k_scale: Optional[jax.Array] = None,   # (BHkv, Skv, 1) f32
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """OS-anchored attention. Sq % bq == 0 and Skv % bkv == 0 (pre-padded).

    ``sq_valid``/``skv_valid`` are the true (pre-padding) lengths;
    ``kv_len`` (traced) restricts further to the filled prefix of a
    KV-cache buffer and the causal mask right-aligns the true q rows
    against it.  The KV grid dimension is the static band
    (``static_band``); out-of-band steps are clamped onto the band edge
    by the index maps (no DMA) and skipped by ``pl.when`` (no compute),
    so realized traffic scales with the *visited* blocks the banded
    cost model charges.  ``bq``/``bkv`` come from the caller —
    ``ops.attention`` resolves them from the autotuned registry spec
    and clamps them (``cost_model.attention_block_clamp``) before
    calling in.  int8 K/V dequantize at the block load via the
    per-position ``k_scale``/``v_scale``.
    """
    bh, sq, d = q.shape
    skv = k.shape[1]
    gq, gkv = sq // bq, skv // bkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    skv_valid = skv if skv_valid is None else skv_valid
    sq_valid = sq if sq_valid is None else sq_valid
    windowed = window is not None or window_dyn is not None
    quant = k_scale is not None
    band = static_band(gkv, skv_valid, bq, bkv, window, causal)
    info = make_band_info(kv_len, window, window_dyn, skv_valid)
    heads = _heads_per_row(bh, info)
    bounds = dict(bq=bq, bkv=bkv, sq=sq_valid, causal=causal,
                  windowed=windowed)

    def kv_block(b, i, jr, info_ref):
        lo, hi = _band_lo_hi(i, info_ref,
                             row=b // heads if heads else 0, **bounds)
        return jnp.minimum(lo + jr, hi)

    kernel = functools.partial(
        _flash_kernel, band=band, scale=scale, quant=quant, heads=heads,
        **bounds,
    )
    in_specs = [
        pl.BlockSpec((1, bq, d), lambda b, i, jr, info: (b, i, 0)),
        pl.BlockSpec((1, bkv, d),
                     lambda b, i, jr, info, g=group:
                     (b // g, kv_block(b, i, jr, info), 0)),
        pl.BlockSpec((1, bkv, d),
                     lambda b, i, jr, info, g=group:
                     (b // g, kv_block(b, i, jr, info), 0)),
    ]
    args = [q, k, v]
    if quant:
        in_specs += [
            pl.BlockSpec((1, bkv, 1),
                         lambda b, i, jr, info, g=group:
                         (b // g, kv_block(b, i, jr, info), 0)),
            pl.BlockSpec((1, bkv, 1),
                         lambda b, i, jr, info, g=group:
                         (b // g, kv_block(b, i, jr, info), 0)),
        ]
        args += [k_scale, v_scale]
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(bh, gq, band),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, bq, d),
                                   lambda b, i, jr, info: (b, i, 0)),
            scratch_shapes=[
                pltpu.VMEM((bq, d), jnp.float32),
                pltpu.VMEM((bq, 128), jnp.float32),
                pltpu.VMEM((bq, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=interpret,
    )(info, *args)


# ---------------------------------------------------------------------------
# WS-anchored (KV-stationary) attention: benchmark variant.
# ---------------------------------------------------------------------------
def _kv_stationary_kernel(info_ref, *refs, jk: Optional[int], bq: int,
                          bkv: int, scale: float, causal: bool,
                          windowed: bool, sq: int, quant: bool,
                          heads: int = 0):
    """One KV block's online-softmax update.

    ``jk=None``: single-dispatch form — the KV sweep is grid dim 1, the
    state refs are the revisited output buffers (in == out), initialized
    in-kernel at the first KV block.  ``jk=int``: per-block form — one
    call per KV block, state carried through aliased input/output pairs.
    Banding: a (KV block, q tile) pair outside the visible band updates
    nothing (the state passes through); beyond-valid KV blocks are
    additionally clamped in the index maps so they issue no DMA.
    """
    if quant:
        (q_ref, k_ref, v_ref, ks_ref, vs_ref,
         acc_in, m_in, l_in, acc_out, m_out, l_out) = refs
    else:
        q_ref, k_ref, v_ref, acc_in, m_in, l_in, acc_out, m_out, l_out = refs
        ks_ref = vs_ref = None
    if jk is None:
        jk_idx, iq = pl.program_id(1), pl.program_id(2)

        @pl.when(jk_idx == 0)
        def _init():
            acc_in[...] = jnp.zeros_like(acc_in)
            m_in[...] = jnp.full_like(m_in, NEG_INF)
            l_in[...] = jnp.zeros_like(l_in)
    else:
        jk_idx, iq = jk, pl.program_id(1)

    row = pl.program_id(0) // heads if heads else 0
    bounds = dict(bq=bq, bkv=bkv, sq=sq, causal=causal, windowed=windowed)
    lo, hi = _band_lo_hi(iq, info_ref, row=row, **bounds)
    visible = (jk_idx >= lo) & (jk_idx <= hi)

    @pl.when(visible)
    def _update():
        q = q_ref[0].astype(jnp.float32)
        k, v = _load_kv(k_ref, v_ref, ks_ref, vs_ref)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        mask = _score_mask(iq, jk_idx, info_ref, row=row, **bounds)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_in[0][:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_in[0][:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_out[0] = acc_in[0] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_out[0] = jnp.broadcast_to(m_new, m_out.shape[1:])
        l_out[0] = jnp.broadcast_to(l_new, l_out.shape[1:])

    if jk is not None:
        # per-block form: an invisible pair must still carry the state
        # through its aliased output buffers
        @pl.when(~visible)
        def _carry():
            acc_out[0] = acc_in[0]
            m_out[0] = m_in[0]
            l_out[0] = l_in[0]


def _kv_single_kernel(info_ref, q_ref, k_ref, v_ref, *rest, **kw):
    if kw["quant"]:
        ks_ref, vs_ref, acc_ref, m_ref, l_ref = rest
        refs = (q_ref, k_ref, v_ref, ks_ref, vs_ref,
                acc_ref, m_ref, l_ref, acc_ref, m_ref, l_ref)
    else:
        acc_ref, m_ref, l_ref = rest
        refs = (q_ref, k_ref, v_ref,
                acc_ref, m_ref, l_ref, acc_ref, m_ref, l_ref)
    _kv_stationary_kernel(info_ref, *refs, **kw)


def _ws_block_statically_invisible(jk: int, bkv: int, sq_valid: int,
                                   skv_valid: int,
                                   window: Optional[int],
                                   traced_bounds: bool) -> bool:
    """Can the compiled per-block WS loop drop KV block ``jk`` outright?

    Only static knowledge prunes the dispatch list: with a static
    window and no traced valid length, a block whose end precedes every
    q row's window start is invisible to the whole tile range.  Traced
    bounds fall back to the in-kernel skip (the call still lowers).
    """
    if traced_bounds or window is None:
        return False
    qmin_global = skv_valid - sq_valid      # first true q row, aligned
    return (jk + 1) * bkv - 1 <= qmin_global - window


def kv_stationary_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    group: int = 1, causal: bool = True, window: Optional[int] = None,
    scale: Optional[float] = None, skv_valid: Optional[int] = None,
    sq_valid: Optional[int] = None,
    bq: int = 128, bkv: int = 128, interpret: bool = False,
    kv_len: Optional[jax.Array] = None,
    window_dyn: Optional[jax.Array] = None,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """WS-anchored attention: each KV block fetched exactly once, the
    (acc, m, l) running partials round-tripping HBM once per KV block
    (the paper's WS output traffic).

    ``bq``/``bkv`` come from the caller on BOTH lowerings — the
    interpret-mode single dispatch and the compiled per-KV-block
    aliased-call loop — so when ``ops.attention`` resolves them from
    the autotuned registry spec, both anchors honor the autotuned
    block.

    Banding: the KV dimension only spans the statically-valid blocks
    (``ceil(skv_valid / bkv)``), a static window drops statically-
    invisible blocks from the compiled dispatch loop, and traced
    ``kv_len``/``window_dyn`` clamp the KV index maps (no DMA) and skip
    per-pair compute in-kernel.  int8 K/V dequantize at the block load
    via the per-position scales.

    In interpret mode — where this benchmark variant runs and is
    compared against flash attention — it lowers as ONE ``pallas_call``
    with grid (bh, gkv, gq): the state blocks, indexed by the *inner*
    q-tile dim, are revisited once per KV block and carry the partials
    between non-consecutive visits through their HBM buffers
    (initialized in-kernel at the first KV block, no zeros-init arrays),
    so per-block dispatch overhead no longer pollutes the OS/WS
    comparison.  Persisting output blocks across non-consecutive
    revisits relies on sequential grid execution — an interpret-mode
    property, not a documented Pallas TPU guarantee — so on compiled
    backends the realized lowering stays the well-defined per-KV-block
    aliased-call loop (same traffic, one dispatch per visited block).
    """
    bh, sq, d = q.shape
    skv = k.shape[1]
    gq, gkv = sq // bq, skv // bkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    skv_valid = skv if skv_valid is None else skv_valid
    sq_valid = sq if sq_valid is None else sq_valid
    windowed = window is not None or window_dyn is not None
    quant = k_scale is not None
    gkv_v = max(1, min(gkv, -(-skv_valid // bkv)))  # statically-valid blocks
    info = make_band_info(kv_len, window, window_dyn, skv_valid)
    heads = _heads_per_row(bh, info)
    kw = dict(bq=bq, bkv=bkv, scale=scale, causal=causal, windowed=windowed,
              sq=sq_valid, quant=quant, heads=heads)
    out_shape = [
        jax.ShapeDtypeStruct((bh, sq, d), jnp.float32),
        jax.ShapeDtypeStruct((bh, sq, 128), jnp.float32),
        jax.ShapeDtypeStruct((bh, sq, 128), jnp.float32),
    ]

    def kv_clamp(b, j, info_ref):
        """Fetchable block for grid step ``j``: out-of-band steps alias
        the band's edge blocks — above the valid prefix AND below the
        global window start (tile 0's band) — so they re-use an
        adjacent step's index and issue no new DMA."""
        kv_valid, win = _info_pair(info_ref, b // heads if heads else 0)
        hi = jnp.maximum(0, (kv_valid + bkv - 1) // bkv - 1)
        lo = jnp.zeros_like(hi)
        if windowed:
            off = kv_valid - sq_valid
            lo = jnp.minimum(jnp.maximum(0, (off - win + 1) // bkv), hi)
        return jnp.clip(j, lo, hi)

    if interpret:
        state_spec = pl.BlockSpec((1, bq, d),
                                  lambda b, j, i, info: (b, i, 0))
        stat_spec = pl.BlockSpec((1, bq, 128),
                                 lambda b, j, i, info: (b, i, 0))
        kv_spec = pl.BlockSpec(
            (1, bkv, d),
            lambda b, j, i, info, g=group:
            (b // g, kv_clamp(b, j, info), 0))
        in_specs = [
            pl.BlockSpec((1, bq, d), lambda b, j, i, info: (b, i, 0)),
            kv_spec, kv_spec,
        ]
        args = [q, k, v]
        if quant:
            sc_spec = pl.BlockSpec(
                (1, bkv, 1),
                lambda b, j, i, info, g=group:
                (b // g, kv_clamp(b, j, info), 0))
            in_specs += [sc_spec, sc_spec]
            args += [k_scale, v_scale]
        acc, m, l = pl.pallas_call(
            functools.partial(_kv_single_kernel, jk=None, **kw),
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=(bh, gkv_v, gq),
                in_specs=in_specs,
                out_specs=[state_spec, stat_spec, stat_spec],
            ),
            out_shape=out_shape,
            interpret=True,
        )(info, *args)
    else:
        acc = jnp.zeros((bh, sq, d), jnp.float32)
        m = jnp.full((bh, sq, 128), NEG_INF, jnp.float32)
        l = jnp.zeros((bh, sq, 128), jnp.float32)
        state_spec = pl.BlockSpec((1, bq, d), lambda b, i, info: (b, i, 0))
        stat_spec = pl.BlockSpec((1, bq, 128), lambda b, i, info: (b, i, 0))
        traced_bounds = kv_len is not None or window_dyn is not None
        for jk in range(gkv_v):
            if _ws_block_statically_invisible(jk, bkv, sq_valid, skv_valid,
                                              window, traced_bounds):
                continue                    # zero dispatch work
            kv_spec = pl.BlockSpec(
                (1, bkv, d),
                lambda b, i, info, j=jk, g=group:
                (b // g, kv_clamp(b, j, info), 0))
            in_specs = [
                pl.BlockSpec((1, bq, d), lambda b, i, info: (b, i, 0)),
                kv_spec, kv_spec,
            ]
            args = [q, k, v]
            n_in = 3
            if quant:
                sc_spec = pl.BlockSpec(
                    (1, bkv, 1),
                    lambda b, i, info, j=jk, g=group:
                    (b // g, kv_clamp(b, j, info), 0))
                in_specs += [sc_spec, sc_spec]
                args += [k_scale, v_scale]
                n_in = 5
            acc, m, l = pl.pallas_call(
                functools.partial(_kv_stationary_kernel, jk=jk, **kw),
                grid_spec=pltpu.PrefetchScalarGridSpec(
                    num_scalar_prefetch=1,
                    grid=(bh, gq),
                    in_specs=in_specs + [state_spec, stat_spec, stat_spec],
                    out_specs=[state_spec, stat_spec, stat_spec],
                ),
                out_shape=out_shape,
                input_output_aliases={n_in + 1: 0, n_in + 2: 1,
                                      n_in + 3: 2},
            )(info, *args, acc, m, l)
    lsafe = jnp.where(l[:, :, :1] == 0.0, 1.0, l[:, :, :1])
    return (acc / lsafe).astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged (block-table) decode attention: a page table IS an index map.
# ---------------------------------------------------------------------------
def _paged_kernel(info_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                  l_ref, *, page: int, band: int, scale: float, heads: int,
                  window: Optional[int]):
    b, jr = pl.program_id(0), pl.program_id(1)
    row = b // heads
    kv_valid = info_ref[row, 0]
    hi = jnp.maximum(0, (kv_valid + page - 1) // page - 1)
    if window is not None:
        lo = jnp.minimum(jnp.maximum(0, (kv_valid - window) // page), hi)
    else:
        lo = jnp.zeros_like(hi)
    jblk = jnp.minimum(lo + jr, hi)

    @pl.when(jr == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when((lo + jr <= hi) & (kv_valid > 0))
    def _update():
        q = q_ref[0].astype(jnp.float32)                    # (1, d)
        k = k_ref[0, 0].astype(jnp.float32)                 # (page, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kpos = jblk * page + jax.lax.broadcasted_iota(
            jnp.int32, (1, page), 1)
        mask = kpos < kv_valid          # decode q row == position kv_valid-1
        if window is not None:
            mask &= kpos > kv_valid - 1 - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(jr == band - 1)
    def _flush():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_flash_attention(
    q: jax.Array,             # (BH, 1, D)  folded rows*q_heads, decode
    k_pages: jax.Array,       # (HKV, P, page, D) shared page pool
    v_pages: jax.Array,
    block_tables: jax.Array,  # (R, max_pages) int32 page ids per row
    kv_lens: jax.Array,       # (R,) int32 valid lengths per row
    group: int = 1,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """OS-anchored decode attention over a paged KV cache.

    The block indirection rides the same ``PrefetchScalarGridSpec``
    machinery as the banded kernels — the scalar-prefetch array is
    ``concat([kv_lens[:, None], block_tables], axis=1)`` and the KV
    index maps dereference it twice: batch row ``b // heads`` selects
    the row's band (exactly the per-row ``[kv_valid, window]`` clamp of
    :func:`flash_attention`), then ``info[row, 1 + jblk]`` translates
    the row's logical KV block into a physical page id.  A page table
    *is* an index map: no gather materializes a contiguous cache, the
    DMA engine walks the pool directly.

    Each logical block spans exactly one page (``bkv == page``).  Steps
    beyond a row's last valid page clamp onto it (a revisited page id —
    no new DMA) and skip compute; a row at ``kv_len == 0`` dereferences
    table slot 0 (tables must default to a valid id, 0 by convention)
    and writes zeros.  Float pools only — the int8-KV scale sidecar
    stays on the contiguous path.
    """
    bh, sq, d = q.shape
    if sq != 1:
        raise ValueError(f"paged attention is decode-only (sq=1), got {sq}")
    hkv, n_pages, page, _ = k_pages.shape
    rows, max_pages = block_tables.shape
    if bh % rows:
        raise ValueError(f"bh={bh} not divisible by rows={rows}")
    heads = bh // rows
    if heads != hkv * group:
        raise ValueError(
            f"{heads} q heads per row != pool heads {hkv} * group {group}"
        )
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    band = max(1, max_pages)
    info = jnp.concatenate([
        jnp.asarray(kv_lens, jnp.int32).reshape(rows, 1),
        jnp.asarray(block_tables, jnp.int32).reshape(rows, max_pages),
    ], axis=1)

    def page_block(b, jr, info_ref):
        row = b // heads
        kv_valid = info_ref[row, 0]
        hi = jnp.maximum(0, (kv_valid + page - 1) // page - 1)
        if window is not None:
            lo = jnp.minimum(jnp.maximum(0, (kv_valid - window) // page),
                             hi)
        else:
            lo = jnp.zeros_like(hi)
        jblk = jnp.minimum(lo + jr, hi)
        return info_ref[row, 1 + jblk]          # page table -> index map

    kv_spec = pl.BlockSpec(
        (1, 1, page, d),
        lambda b, jr, info, g=group:
        ((b % heads) // g, page_block(b, jr, info), 0, 0))
    kernel = functools.partial(
        _paged_kernel, page=page, band=band, scale=scale, heads=heads,
        window=window,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(bh, band),
            in_specs=[
                pl.BlockSpec((1, 1, d), lambda b, jr, info: (b, 0, 0)),
                kv_spec, kv_spec,
            ],
            out_specs=pl.BlockSpec((1, 1, d), lambda b, jr, info: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, d), jnp.float32),
                pltpu.VMEM((1, 128), jnp.float32),
                pltpu.VMEM((1, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bh, 1, d), q.dtype),
        interpret=interpret,
    )(info, q, k_pages, v_pages)
