"""Dataflow-parameterized attention Pallas kernels (TPU target).

The paper's central result — OS-anchored dataflows with auxiliary weight
stationarity win — *predicts* flash attention when applied to the attention
operator:

  * OS anchor: the output tile (one block of query rows) is the anchored
    operand; the online-softmax statistics and the output accumulator live
    in VMEM scratch across the whole KV sweep; outputs are written to HBM
    exactly once.  KV blocks stream (they are the "weights").
  * WS anchor (comparison variant): KV blocks are anchored — each is
    fetched exactly once — while the running (acc, m, l) partials are
    read-modify-written through HBM once per KV block.  This reproduces the
    paper's WS output-traffic pathology at attention scale and is used by
    the benchmarks, not the models.

Banded execution (PR 5): both lowerings take a *traced* valid KV length
(``kv_len`` — the filled prefix of a padded KV-cache buffer) and a
static or traced sliding ``window``, and skip KV blocks the mask fully
excludes — in the *grid*, not just in the lanes:

  * the banding scalars ride in a ``PrefetchScalarGridSpec`` info array,
    so the KV *index maps* clamp out-of-band grid steps onto the band's
    edge block (a revisited index — no new DMA is issued) and
    ``pl.when`` skips their compute entirely;
  * with a static window the flash grid's KV dimension itself shrinks to
    the band width ``ceil((bq + window - 1) / bkv) + 1`` and the WS
    compiled per-block loop drops statically-invisible blocks, so the
    skipped work disappears from the lowering (visible in the
    ``pallas_call`` grid / dispatch counts);
  * decode traffic therefore scales with the *valid* cache length, not
    ``max_len`` — the "prune work the dataflow can prove is masked"
    discipline the banded cost model (``cost_model.attention_band``)
    charges for.  The cost model and these index maps share one banding
    rule; keep them in sync.

int8 KV caches dequantize at the block load: K/V stream as int8 with
per-position f32 scales (``k_scale``/``v_scale``, shape (BHkv, Skv, 1)),
multiplied in-register after the VMEM fetch — the cache never
round-trips HBM as a float copy.  (The (…, 1) scale lane is
interpret-mode friendly; a compiled TPU lowering would lane-pad it.)

GQA is handled by an index-map head mapping (q head -> kv head).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
# "no sliding window" sentinel inside the banding info array (matches
# models/lm.FULL_WINDOW so traced per-layer windows pass through).
HUGE_WINDOW = 2 ** 30


# ---------------------------------------------------------------------------
# Banding: the one rule deciding which KV blocks a q tile visits.
# ---------------------------------------------------------------------------
def make_band_info(kv_len, window, window_dyn, skv_valid: int) -> jax.Array:
    """The (2,) int32 scalar-prefetch array: [valid KV length, window].

    ``kv_len`` (traced or int) overrides the static true length
    ``skv_valid``; ``window_dyn`` (traced) overrides the static
    ``window``; no window at all encodes as ``HUGE_WINDOW``.
    """
    kv_valid = skv_valid if kv_len is None else kv_len
    if window_dyn is not None:
        w = window_dyn
    elif window is not None:
        w = window
    else:
        w = HUGE_WINDOW
    return jnp.stack([
        jnp.asarray(kv_valid, jnp.int32).reshape(()),
        jnp.asarray(w, jnp.int32).reshape(()),
    ])


def _band_lo_hi(i, info, *, bq: int, bkv: int, sq: int, causal: bool,
                windowed: bool):
    """Traced [lo, hi] inclusive KV-block band for q tile ``i``.

    Mirrors ``cost_model.attention_band`` exactly (the cost model is the
    documented source of the rule): q rows right-align against the valid
    KV length, ``hi`` is clamped by the valid prefix and the causal
    diagonal, ``lo`` by the sliding window.
    """
    kv_valid = info[0]
    off = kv_valid - sq
    hi = jnp.maximum(0, (kv_valid + bkv - 1) // bkv - 1)
    if causal:
        qmax = (i + 1) * bq - 1 + off
        hi = jnp.minimum(hi, jnp.maximum(qmax, 0) // bkv)
    if windowed:
        qmin = i * bq + off
        lo = jnp.maximum(0, (qmin - info[1] + 1) // bkv)
        lo = jnp.minimum(lo, hi)
    else:
        lo = jnp.zeros_like(hi)
    return lo, hi


def static_band(gkv: int, skv_valid: int, bq: int, bkv: int,
                window: Optional[int], causal: bool = True) -> int:
    """The static KV grid extent per q tile (the flash grid's dim 2).

    The valid true length bounds it at ``ceil(skv_valid / bkv)``; a
    *static* window under a *causal* mask tightens it to the band
    width — each q tile's visible blocks then span at most
    ``bq + window - 1`` positions.  Without the causal upper bound the
    window only cuts the past (the band still reaches the last valid
    block), so no static shrink applies.  Traced lengths/windows can
    only shrink the band further at run time (the index-map clamp +
    ``pl.when`` skip handle those steps).
    """
    band = -(-skv_valid // bkv)
    if window is not None and causal:
        band = min(band, -(-(bq + window - 1) // bkv) + 1)
    return max(1, min(band, gkv))


def _score_mask(i, jblk, info, *, bq: int, bkv: int, sq: int, causal: bool,
                windowed: bool):
    """(bq, bkv) lane mask for q tile ``i`` against KV block ``jblk``."""
    kv_valid = info[0]
    off = kv_valid - sq
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) + off
    kpos = jblk * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = kpos < kv_valid
    if causal:
        mask &= kpos <= qpos
    if windowed:
        mask &= kpos > qpos - info[1]
    return mask


def _load_kv(k_ref, v_ref, ks_ref, vs_ref):
    """Fetch one KV block, dequantizing int8 at the load when scales
    are present — the float image exists only in registers/VMEM."""
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    if ks_ref is not None:
        k = k * ks_ref[0]                 # (bkv, 1) per-position scales
        v = v * vs_ref[0]
    return k, v


# ---------------------------------------------------------------------------
# OS-anchored (flash) attention.
# ---------------------------------------------------------------------------
def _flash_kernel(info_ref, *refs, bq: int, bkv: int, band: int,
                  scale: float, causal: bool, windowed: bool, sq: int,
                  quant: bool):
    if quant:
        q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref \
            = refs
    else:
        q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = refs
        ks_ref = vs_ref = None
    i, jr = pl.program_id(1), pl.program_id(2)
    lo, hi = _band_lo_hi(i, info_ref, bq=bq, bkv=bkv, sq=sq, causal=causal,
                         windowed=windowed)
    jblk = jnp.minimum(lo + jr, hi)       # == the index-map fetch

    @pl.when(jr == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(lo + jr <= hi)               # out-of-band step: zero work
    def _update():
        q = q_ref[0].astype(jnp.float32)              # (bq, d)
        k, v = _load_kv(k_ref, v_ref, ks_ref, vs_ref)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        mask = _score_mask(i, jblk, info_ref, bq=bq, bkv=bkv, sq=sq,
                           causal=causal, windowed=windowed)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, :1]                         # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # explicit lane zeroing: a fully-masked block must contribute
        # nothing even while m is still NEG_INF (exp(s - m_new) = 1.0)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(jr == band - 1)
    def _flush():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)               # fully-masked rows
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,            # (BH, Sq, D)   batch*q_heads folded
    k: jax.Array,            # (BHkv, Skv, D)  float, or int8 with scales
    v: jax.Array,
    group: int = 1,          # q_heads per kv head (GQA)
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    skv_valid: Optional[int] = None,
    sq_valid: Optional[int] = None,
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = False,
    kv_len: Optional[jax.Array] = None,
    window_dyn: Optional[jax.Array] = None,
    k_scale: Optional[jax.Array] = None,   # (BHkv, Skv, 1) f32
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """OS-anchored attention. Sq % bq == 0 and Skv % bkv == 0 (pre-padded).

    ``sq_valid``/``skv_valid`` are the true (pre-padding) lengths;
    ``kv_len`` (traced) restricts further to the filled prefix of a
    KV-cache buffer and the causal mask right-aligns the true q rows
    against it.  The KV grid dimension is the static band
    (``static_band``); out-of-band steps are clamped onto the band edge
    by the index maps (no DMA) and skipped by ``pl.when`` (no compute),
    so realized traffic scales with the *visited* blocks the banded
    cost model charges.  ``bq``/``bkv`` come from the caller —
    ``ops.attention`` resolves them from the autotuned registry spec
    and clamps them (``cost_model.attention_block_clamp``) before
    calling in.  int8 K/V dequantize at the block load via the
    per-position ``k_scale``/``v_scale``.
    """
    bh, sq, d = q.shape
    skv = k.shape[1]
    gq, gkv = sq // bq, skv // bkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    skv_valid = skv if skv_valid is None else skv_valid
    sq_valid = sq if sq_valid is None else sq_valid
    windowed = window is not None or window_dyn is not None
    quant = k_scale is not None
    band = static_band(gkv, skv_valid, bq, bkv, window, causal)
    info = make_band_info(kv_len, window, window_dyn, skv_valid)
    bounds = dict(bq=bq, bkv=bkv, sq=sq_valid, causal=causal,
                  windowed=windowed)

    def kv_block(i, jr, info_ref):
        lo, hi = _band_lo_hi(i, info_ref, **bounds)
        return jnp.minimum(lo + jr, hi)

    kernel = functools.partial(
        _flash_kernel, band=band, scale=scale, quant=quant, **bounds,
    )
    in_specs = [
        pl.BlockSpec((1, bq, d), lambda b, i, jr, info: (b, i, 0)),
        pl.BlockSpec((1, bkv, d),
                     lambda b, i, jr, info, g=group:
                     (b // g, kv_block(i, jr, info), 0)),
        pl.BlockSpec((1, bkv, d),
                     lambda b, i, jr, info, g=group:
                     (b // g, kv_block(i, jr, info), 0)),
    ]
    args = [q, k, v]
    if quant:
        in_specs += [
            pl.BlockSpec((1, bkv, 1),
                         lambda b, i, jr, info, g=group:
                         (b // g, kv_block(i, jr, info), 0)),
            pl.BlockSpec((1, bkv, 1),
                         lambda b, i, jr, info, g=group:
                         (b // g, kv_block(i, jr, info), 0)),
        ]
        args += [k_scale, v_scale]
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(bh, gq, band),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, bq, d),
                                   lambda b, i, jr, info: (b, i, 0)),
            scratch_shapes=[
                pltpu.VMEM((bq, d), jnp.float32),
                pltpu.VMEM((bq, 128), jnp.float32),
                pltpu.VMEM((bq, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=interpret,
    )(info, *args)


# ---------------------------------------------------------------------------
# WS-anchored (KV-stationary) attention: benchmark variant.
# ---------------------------------------------------------------------------
def _kv_stationary_kernel(info_ref, *refs, jk: Optional[int], bq: int,
                          bkv: int, scale: float, causal: bool,
                          windowed: bool, sq: int, quant: bool):
    """One KV block's online-softmax update.

    ``jk=None``: single-dispatch form — the KV sweep is grid dim 1, the
    state refs are the revisited output buffers (in == out), initialized
    in-kernel at the first KV block.  ``jk=int``: per-block form — one
    call per KV block, state carried through aliased input/output pairs.
    Banding: a (KV block, q tile) pair outside the visible band updates
    nothing (the state passes through); beyond-valid KV blocks are
    additionally clamped in the index maps so they issue no DMA.
    """
    if quant:
        (q_ref, k_ref, v_ref, ks_ref, vs_ref,
         acc_in, m_in, l_in, acc_out, m_out, l_out) = refs
    else:
        q_ref, k_ref, v_ref, acc_in, m_in, l_in, acc_out, m_out, l_out = refs
        ks_ref = vs_ref = None
    if jk is None:
        jk_idx, iq = pl.program_id(1), pl.program_id(2)

        @pl.when(jk_idx == 0)
        def _init():
            acc_in[...] = jnp.zeros_like(acc_in)
            m_in[...] = jnp.full_like(m_in, NEG_INF)
            l_in[...] = jnp.zeros_like(l_in)
    else:
        jk_idx, iq = jk, pl.program_id(1)

    bounds = dict(bq=bq, bkv=bkv, sq=sq, causal=causal, windowed=windowed)
    lo, hi = _band_lo_hi(iq, info_ref, **bounds)
    visible = (jk_idx >= lo) & (jk_idx <= hi)

    @pl.when(visible)
    def _update():
        q = q_ref[0].astype(jnp.float32)
        k, v = _load_kv(k_ref, v_ref, ks_ref, vs_ref)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        mask = _score_mask(iq, jk_idx, info_ref, **bounds)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_in[0][:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_in[0][:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_out[0] = acc_in[0] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_out[0] = jnp.broadcast_to(m_new, m_out.shape[1:])
        l_out[0] = jnp.broadcast_to(l_new, l_out.shape[1:])

    if jk is not None:
        # per-block form: an invisible pair must still carry the state
        # through its aliased output buffers
        @pl.when(~visible)
        def _carry():
            acc_out[0] = acc_in[0]
            m_out[0] = m_in[0]
            l_out[0] = l_in[0]


def _kv_single_kernel(info_ref, q_ref, k_ref, v_ref, *rest, **kw):
    if kw["quant"]:
        ks_ref, vs_ref, acc_ref, m_ref, l_ref = rest
        refs = (q_ref, k_ref, v_ref, ks_ref, vs_ref,
                acc_ref, m_ref, l_ref, acc_ref, m_ref, l_ref)
    else:
        acc_ref, m_ref, l_ref = rest
        refs = (q_ref, k_ref, v_ref,
                acc_ref, m_ref, l_ref, acc_ref, m_ref, l_ref)
    _kv_stationary_kernel(info_ref, *refs, **kw)


def _ws_block_statically_invisible(jk: int, bkv: int, sq_valid: int,
                                   skv_valid: int,
                                   window: Optional[int],
                                   traced_bounds: bool) -> bool:
    """Can the compiled per-block WS loop drop KV block ``jk`` outright?

    Only static knowledge prunes the dispatch list: with a static
    window and no traced valid length, a block whose end precedes every
    q row's window start is invisible to the whole tile range.  Traced
    bounds fall back to the in-kernel skip (the call still lowers).
    """
    if traced_bounds or window is None:
        return False
    qmin_global = skv_valid - sq_valid      # first true q row, aligned
    return (jk + 1) * bkv - 1 <= qmin_global - window


def kv_stationary_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    group: int = 1, causal: bool = True, window: Optional[int] = None,
    scale: Optional[float] = None, skv_valid: Optional[int] = None,
    sq_valid: Optional[int] = None,
    bq: int = 128, bkv: int = 128, interpret: bool = False,
    kv_len: Optional[jax.Array] = None,
    window_dyn: Optional[jax.Array] = None,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """WS-anchored attention: each KV block fetched exactly once, the
    (acc, m, l) running partials round-tripping HBM once per KV block
    (the paper's WS output traffic).

    ``bq``/``bkv`` come from the caller on BOTH lowerings — the
    interpret-mode single dispatch and the compiled per-KV-block
    aliased-call loop — so when ``ops.attention`` resolves them from
    the autotuned registry spec, both anchors honor the autotuned
    block.

    Banding: the KV dimension only spans the statically-valid blocks
    (``ceil(skv_valid / bkv)``), a static window drops statically-
    invisible blocks from the compiled dispatch loop, and traced
    ``kv_len``/``window_dyn`` clamp the KV index maps (no DMA) and skip
    per-pair compute in-kernel.  int8 K/V dequantize at the block load
    via the per-position scales.

    In interpret mode — where this benchmark variant runs and is
    compared against flash attention — it lowers as ONE ``pallas_call``
    with grid (bh, gkv, gq): the state blocks, indexed by the *inner*
    q-tile dim, are revisited once per KV block and carry the partials
    between non-consecutive visits through their HBM buffers
    (initialized in-kernel at the first KV block, no zeros-init arrays),
    so per-block dispatch overhead no longer pollutes the OS/WS
    comparison.  Persisting output blocks across non-consecutive
    revisits relies on sequential grid execution — an interpret-mode
    property, not a documented Pallas TPU guarantee — so on compiled
    backends the realized lowering stays the well-defined per-KV-block
    aliased-call loop (same traffic, one dispatch per visited block).
    """
    bh, sq, d = q.shape
    skv = k.shape[1]
    gq, gkv = sq // bq, skv // bkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    skv_valid = skv if skv_valid is None else skv_valid
    sq_valid = sq if sq_valid is None else sq_valid
    windowed = window is not None or window_dyn is not None
    quant = k_scale is not None
    gkv_v = max(1, min(gkv, -(-skv_valid // bkv)))  # statically-valid blocks
    info = make_band_info(kv_len, window, window_dyn, skv_valid)
    kw = dict(bq=bq, bkv=bkv, scale=scale, causal=causal, windowed=windowed,
              sq=sq_valid, quant=quant)
    out_shape = [
        jax.ShapeDtypeStruct((bh, sq, d), jnp.float32),
        jax.ShapeDtypeStruct((bh, sq, 128), jnp.float32),
        jax.ShapeDtypeStruct((bh, sq, 128), jnp.float32),
    ]

    def kv_clamp(j, info_ref):
        """Fetchable block for grid step ``j``: out-of-band steps alias
        the band's edge blocks — above the valid prefix AND below the
        global window start (tile 0's band) — so they re-use an
        adjacent step's index and issue no new DMA."""
        hi = jnp.maximum(0, (info_ref[0] + bkv - 1) // bkv - 1)
        lo = jnp.zeros_like(hi)
        if windowed:
            off = info_ref[0] - sq_valid
            lo = jnp.minimum(jnp.maximum(0, (off - info_ref[1] + 1) // bkv),
                             hi)
        return jnp.clip(j, lo, hi)

    if interpret:
        state_spec = pl.BlockSpec((1, bq, d),
                                  lambda b, j, i, info: (b, i, 0))
        stat_spec = pl.BlockSpec((1, bq, 128),
                                 lambda b, j, i, info: (b, i, 0))
        kv_spec = pl.BlockSpec(
            (1, bkv, d),
            lambda b, j, i, info, g=group:
            (b // g, kv_clamp(j, info), 0))
        in_specs = [
            pl.BlockSpec((1, bq, d), lambda b, j, i, info: (b, i, 0)),
            kv_spec, kv_spec,
        ]
        args = [q, k, v]
        if quant:
            sc_spec = pl.BlockSpec(
                (1, bkv, 1),
                lambda b, j, i, info, g=group:
                (b // g, kv_clamp(j, info), 0))
            in_specs += [sc_spec, sc_spec]
            args += [k_scale, v_scale]
        acc, m, l = pl.pallas_call(
            functools.partial(_kv_single_kernel, jk=None, **kw),
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=(bh, gkv_v, gq),
                in_specs=in_specs,
                out_specs=[state_spec, stat_spec, stat_spec],
            ),
            out_shape=out_shape,
            interpret=True,
        )(info, *args)
    else:
        acc = jnp.zeros((bh, sq, d), jnp.float32)
        m = jnp.full((bh, sq, 128), NEG_INF, jnp.float32)
        l = jnp.zeros((bh, sq, 128), jnp.float32)
        state_spec = pl.BlockSpec((1, bq, d), lambda b, i, info: (b, i, 0))
        stat_spec = pl.BlockSpec((1, bq, 128), lambda b, i, info: (b, i, 0))
        traced_bounds = kv_len is not None or window_dyn is not None
        for jk in range(gkv_v):
            if _ws_block_statically_invisible(jk, bkv, sq_valid, skv_valid,
                                              window, traced_bounds):
                continue                    # zero dispatch work
            kv_spec = pl.BlockSpec(
                (1, bkv, d),
                lambda b, i, info, j=jk, g=group:
                (b // g, kv_clamp(j, info), 0))
            in_specs = [
                pl.BlockSpec((1, bq, d), lambda b, i, info: (b, i, 0)),
                kv_spec, kv_spec,
            ]
            args = [q, k, v]
            n_in = 3
            if quant:
                sc_spec = pl.BlockSpec(
                    (1, bkv, 1),
                    lambda b, i, info, j=jk, g=group:
                    (b // g, kv_clamp(j, info), 0))
                in_specs += [sc_spec, sc_spec]
                args += [k_scale, v_scale]
                n_in = 5
            acc, m, l = pl.pallas_call(
                functools.partial(_kv_stationary_kernel, jk=jk, **kw),
                grid_spec=pltpu.PrefetchScalarGridSpec(
                    num_scalar_prefetch=1,
                    grid=(bh, gq),
                    in_specs=in_specs + [state_spec, stat_spec, stat_spec],
                    out_specs=[state_spec, stat_spec, stat_spec],
                ),
                out_shape=out_shape,
                input_output_aliases={n_in + 1: 0, n_in + 2: 1,
                                      n_in + 3: 2},
            )(info, *args, acc, m, l)
    lsafe = jnp.where(l[:, :, :1] == 0.0, 1.0, l[:, :, :1])
    return (acc / lsafe).astype(q.dtype)
