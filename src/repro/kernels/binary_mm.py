"""Binary (+-1) matmul Pallas kernel: XOR + popcount on packed uint32.

TPU adaptation of the paper's binary-NN workloads (Fig. 9): the CPU
bit-serial path has no MXU analogue, so binary GEMMs run on the VPU as
xor + ``lax.population_count`` with the same OS-anchored dataflow the
paper found optimal (output tile accumulates in VMEM scratch; packed
weights stripe-resident).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _binary_os_kernel(a_ref, b_ref, o_ref, acc_ref, *, gk: int, n_bits: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]                                     # (bm, bkp) uint32
    b = b_ref[...]                                     # (bkp, bn) uint32
    x = jnp.bitwise_xor(a[:, :, None], b[None, :, :])  # (bm, bkp, bn)
    pops = jax.lax.population_count(x).astype(jnp.int32).sum(axis=1)
    acc_ref[...] += pops

    @pl.when(k == gk - 1)
    def _flush():
        # dot = K - 2 * popcount(xor)
        o_ref[...] = (n_bits - 2 * acc_ref[...]).astype(o_ref.dtype)


def binary_matmul(
    a_packed: jax.Array,   # (M, Kp) uint32
    b_packed: jax.Array,   # (Kp, N) uint32
    n_bits: int,           # true reduction depth K = 32 * Kp
    bm: int = 128,
    bkp: int = 8,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    m, kp = a_packed.shape
    n = b_packed.shape[1]
    if m % bm or kp % bkp or n % bn:
        raise ValueError(f"untileable ({m},{kp},{n}) by ({bm},{bkp},{bn})")
    gm, gk, gn = m // bm, kp // bkp, n // bn
    kernel = functools.partial(_binary_os_kernel, gk=gk, n_bits=n_bits)
    return pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bkp), lambda i, j, k: (i, k)),
            pl.BlockSpec((bkp, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a_packed, b_packed)
