"""Binary (+-1) matmul Pallas kernels: XOR + popcount on packed uint32.

TPU adaptation of the paper's binary-NN workloads (Fig. 9): the CPU
bit-serial path has no MXU analogue, so binary GEMMs run on the VPU as
xor + ``lax.population_count`` over 32x-packed uint32 words.  PR 3
brings the binary datapath to parity with the matmul/conv subsystems:
every ``DataflowSpec`` anchor lowers as ONE ``pl.pallas_call`` with the
packed-word reduction innermost in the grid and a VMEM int32 scratch
accumulator — anchors differ only in outer grid order and operand
residency, exactly like ``matmul_df``:

  anchor=OS : grid (gm, gn, gk) — the output tile is fixed while the
              packed reduction runs; A/B word-blocks stream per k step.
  anchor=WS : grid (gn, gm, gk) — the packed weight column-stripe
              (Kp, bn) is resident per j and fetched once; A streams.
  anchor=IS : grid (gm, gn, gk) with the packed input row-stripe
              (bm, Kp) resident per i and fetched once; B streams.

``spec.block`` is ``(bm, bkp, bn)``: ``bkp`` counts uint32 words (32
binary channels each), enumerated by ``explorer.explore_binary`` and
ranked by ``cost_model.binary_time_estimate``.

Fused binary epilogue (``core.dataflow.BinaryEpilogue``): the folded
batchnorm ``scale * dot + bias`` (per output column), an optional
residual, and sign/threshold re-binarization are applied in-register at
the scratch flush — so a chain of binary layers emits +-1 int8
activations directly and the int32 accumulator (or its float image)
never round-trips HBM between layers.

The +-1 dot product falls out of the popcount identity
``dot = K - 2 * popcount(a xor b)`` with K = ``n_bits``, the *true*
pre-packing reduction depth: zero-padded packed words xor to zero on
both sides and drop out of the popcount, so padding needs no
post-correction (see ``ops.binary_matmul``).

Validated against ``ref.binary_matmul_ref`` /
``ref.binary_matmul_fused_ref`` in interpret mode (tests/test_binary):
bitwise on the binary datapath proper (int32 dots, +-1 binarized
outputs); un-binarized float epilogue images may differ by 1 ulp where
XLA contracts the scale/bias stage into an FMA in one lowering only.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.dataflow import BinaryEpilogue, DataflowSpec, IS, OS, WS


def _apply_binary_epilogue(epi: Optional[BinaryEpilogue], dot, scale, bias,
                           residual, out_dtype):
    """out = sign?(scale * dot + bias + residual), float32 arithmetic.

    Mirrors ``ref.binary_epilogue_ref`` operation-for-operation, with
    the same per-stage optimization barriers (best-effort: XLA may
    still contract scale/bias into an FMA under this lowering, a 1-ulp
    effect on the pre-sign float image only).
    """
    if epi is None:
        return dot.astype(out_dtype)
    x = dot.astype(jnp.float32)
    if epi.scale:
        x = jax.lax.optimization_barrier(x * scale)
    if epi.bias:
        x = jax.lax.optimization_barrier(x + bias)
    if epi.residual:
        x = jax.lax.optimization_barrier(x + residual.astype(jnp.float32))
    if epi.binarize:
        return jnp.where(x >= 0, 1, -1).astype(out_dtype)
    return x.astype(out_dtype)


def _read_binary_epi(epi: Optional[BinaryEpilogue], refs: Sequence):
    if epi is None:
        return None, None, None
    it = iter(refs)
    scale = next(it)[...] if epi.scale else None
    bias = next(it)[...] if epi.bias else None
    residual = next(it)[...] if epi.residual else None
    return scale, bias, residual


def _binary_kernel(a_ref, b_ref, *refs, gk: int, bkp: int, n_bits: int,
                   a_stripe: bool, b_stripe: bool,
                   epi: Optional[BinaryEpilogue]):
    """Shared single-dispatch kernel body for every anchor.

    The reduction over packed-word panels is the innermost grid dim;
    popcounts accumulate exactly in the int32 scratch and only the
    final, post-epilogue value reaches HBM.
    """
    o_ref, acc_ref = refs[-2], refs[-1]
    epi_refs = refs[:-2]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # stripe-resident operands slice the active word panel; streamed
    # blocks arrive panel-sized already
    a = a_ref[:, pl.dslice(k * bkp, bkp)] if a_stripe else a_ref[...]
    b = b_ref[pl.dslice(k * bkp, bkp), :] if b_stripe else b_ref[...]
    x = jnp.bitwise_xor(a[:, :, None], b[None, :, :])  # (bm, bkp, bn)
    pops = jax.lax.population_count(x).astype(jnp.int32).sum(axis=1)
    acc_ref[...] += pops

    @pl.when(k == gk - 1)
    def _flush():
        dot = n_bits - 2 * acc_ref[...]
        scale, bias, residual = _read_binary_epi(epi, epi_refs)
        o_ref[...] = _apply_binary_epilogue(
            epi, dot, scale, bias, residual, o_ref.dtype
        )


def binary_mm_df(
    a_packed: jax.Array,   # (M, Kp) uint32
    b_packed: jax.Array,   # (Kp, N) uint32
    n_bits: int,           # true reduction depth K <= 32 * Kp
    spec: DataflowSpec,
    out_dtype=None,
    interpret: bool = False,
    epilogue: Optional[BinaryEpilogue] = None,
    scale: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
) -> jax.Array:
    """Packed +-1 GEMM under the given dataflow.  Shapes must tile evenly
    by ``spec.block`` = (bm, bkp, bn) (use ``ops.binary_matmul`` /
    ``ops.binary_matmul_fused`` for automatic padding).

    With ``epilogue`` set, ``y = scale * dot + bias + residual`` (then
    ``sign(y)`` when ``epilogue.binarize``) is applied in-register before
    the output write: ``scale`` is (1, 1) (per-tensor) or (1, N)
    (per-output-column, e.g. a folded batchnorm gamma/sigma) float32,
    ``bias`` is (1, N) float32, ``residual`` is (M, N).
    """
    if a_packed.ndim != 2 or b_packed.ndim != 2 \
            or a_packed.shape[1] != b_packed.shape[0]:
        raise ValueError(f"bad shapes {a_packed.shape} @ {b_packed.shape}")
    m, kp = a_packed.shape
    n = b_packed.shape[1]
    bm, bkp, bn = spec.block
    if m % bm or kp % bkp or n % bn:
        raise ValueError(
            f"shapes ({m},{kp},{n}) must tile by block {spec.block}"
        )
    epi = epilogue if (epilogue is not None and not epilogue.is_noop) else None
    if epi is not None:
        if epi.scale:
            if scale is None:
                raise ValueError("epilogue.scale set but no scale array")
            if scale.shape not in ((1, 1), (1, n)):
                raise ValueError(f"scale shape {scale.shape} != (1,1)/(1,{n})")
        if epi.bias:
            if bias is None:
                raise ValueError("epilogue.bias set but no bias array")
            if bias.shape != (1, n):
                raise ValueError(f"bias shape {bias.shape} != (1, {n})")
        if epi.residual:
            if residual is None:
                raise ValueError("epilogue.residual set but no residual array")
            if residual.shape != (m, n):
                raise ValueError(
                    f"residual shape {residual.shape} != ({m}, {n})"
                )
    if out_dtype is None:
        out_dtype = (jnp.int8 if (epi is not None and epi.binarize)
                     else jnp.float32 if epi is not None
                     else jnp.int32)

    gm, gk, gn = m // bm, kp // bkp, n // bn
    # Anchor -> outer grid order + resident stripes (see module docstring).
    if spec.anchor == OS:
        grid = (gm, gn, gk)
        a_stripe = b_stripe = False
        ij = lambda g0, g1: (g0, g1)
    elif spec.anchor == WS:
        grid = (gn, gm, gk)
        a_stripe, b_stripe = False, True
        ij = lambda g0, g1: (g1, g0)
    elif spec.anchor == IS:
        grid = (gm, gn, gk)
        a_stripe, b_stripe = True, False
        ij = lambda g0, g1: (g0, g1)
    else:
        raise ValueError(spec.anchor)

    def a_map(g0, g1, k):
        i, _ = ij(g0, g1)
        return (i, 0) if a_stripe else (i, k)

    def b_map(g0, g1, k):
        _, j = ij(g0, g1)
        return (0, j) if b_stripe else (k, j)

    def o_map(g0, g1, k):
        i, j = ij(g0, g1)
        return (i, j)

    def j_map(g0, g1, k):
        _, j = ij(g0, g1)
        return (0, j)

    a_block = (bm, kp) if a_stripe else (bm, bkp)
    b_block = (kp, bn) if b_stripe else (bkp, bn)

    epi_specs = []
    if epi is not None:
        if epi.scale:
            if scale.shape == (1, 1):
                epi_specs.append(pl.BlockSpec((1, 1), lambda *g: (0, 0)))
            else:
                epi_specs.append(pl.BlockSpec((1, bn), j_map))
        if epi.bias:
            epi_specs.append(pl.BlockSpec((1, bn), j_map))
        if epi.residual:
            epi_specs.append(pl.BlockSpec((bm, bn), o_map))
    epi_args = []
    if epi is not None:
        if epi.scale:
            epi_args.append(scale)
        if epi.bias:
            epi_args.append(bias)
        if epi.residual:
            epi_args.append(residual)

    kernel = functools.partial(
        _binary_kernel, gk=gk, bkp=bkp, n_bits=n_bits,
        a_stripe=a_stripe, b_stripe=b_stripe, epi=epi,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(a_block, a_map),
            pl.BlockSpec(b_block, b_map),
            *epi_specs,
        ],
        out_specs=pl.BlockSpec((bm, bn), o_map),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a_packed, b_packed, *epi_args)


def binary_matmul(
    a_packed: jax.Array,
    b_packed: jax.Array,
    n_bits: int,
    bm: int = 128,
    bkp: int = 8,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Back-compat wrapper: the seed's fixed-tiling OS kernel, now routed
    through ``binary_mm_df``."""
    spec = DataflowSpec.basic(OS, block=(bm, bkp, bn))
    return binary_mm_df(a_packed, b_packed, n_bits, spec,
                        out_dtype=jnp.int32, interpret=interpret)
