"""Training step factory: grad accumulation, mixed precision, remat, clip.

``make_train_step`` builds a pure (params, opt_state, batch, step) ->
(params, opt_state, metrics) function suitable for jit/pjit; the dry-run
lowers exactly this function for every (arch x shape) cell.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.optim.adamw import AdamW


def make_loss_fn(cfg, dist=None, remat: str = "dots", unroll: int = 1):
    def loss(params, batch):
        return lm.loss_fn(params, batch, cfg, dist=dist, remat=remat,
                          unroll=unroll)
    return loss


def make_train_step(
    cfg,
    optimizer: AdamW,
    dist: Optional[lm.Dist] = None,
    remat: str = "dots",
    microbatches: int = 1,
    unroll: int = 1,
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  ``microbatches`` > 1 accumulates gradients over equal splits
    of the batch's leading dim (sequential lax.scan — the standard
    memory/throughput trade)."""
    loss_fn = make_loss_fn(cfg, dist=dist, remat=remat, unroll=unroll)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (l, metrics), grads = grad_fn(params, batch)
        return l, metrics, grads

    def accumulated(params, batch):
        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        micro = jax.tree.map(split, batch)
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

        def body(carry, mb):
            g_acc, l_acc = carry
            (l, metrics), g = grad_fn(params, mb)
            g_acc = jax.tree.map(
                lambda a, b_: a + b_.astype(jnp.float32), g_acc, g
            )
            return (g_acc, l_acc + l), metrics

        (g_acc, l_sum), metrics = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), micro
        )
        grads = jax.tree.map(lambda g: g / microbatches, g_acc)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return l_sum / microbatches, metrics, grads

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            l, metrics, grads = accumulated(params, batch)
        else:
            l, metrics, grads = single(params, batch)
        params, opt_state, opt_metrics = optimizer.update(
            grads, opt_state, params
        )
        out = {"loss": l, **metrics, **opt_metrics}
        return params, opt_state, out

    return train_step


def make_eval_step(cfg, dist=None) -> Callable:
    loss_fn = make_loss_fn(cfg, dist=dist, remat="none")

    def eval_step(params, batch):
        l, metrics = loss_fn(params, batch)
        return {"loss": l, **metrics}

    return eval_step
