from repro.data.pipeline import SyntheticLMDataset, make_global_batch
