"""Data pipeline: deterministic, stateless, shardable synthetic LM batches.

Batches are a pure function of (seed, step) — so restart-after-failure
resumes bit-exactly from the checkpointed step with no iterator state to
persist, and every host can materialize exactly its shard of the global
batch (``make_global_batch`` uses ``jax.make_array_from_callback``).

The token stream is a deterministic mixture (Zipf-ish unigram + short
copy motifs) so small models show a real, reproducible loss decrease in
the integration tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SyntheticLMDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    with_enc_frames: bool = False
    d_model: int = 0
    enc_seq_ratio: float = 1.0

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step])
        )

    def batch_np(self, step: int) -> Dict[str, np.ndarray]:
        """The full global batch for a step (pure function of step)."""
        rng = self._rng(step)
        b, s, v = self.global_batch, self.seq_len + 1, self.vocab_size
        # Zipf-ish unigram distribution
        ranks = np.arange(1, v + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(v, size=(b, s), p=probs).astype(np.int32)
        # inject copy motifs: second half repeats a window of the first
        motif = min(16, self.seq_len // 4)
        if motif >= 2:
            start = rng.integers(0, self.seq_len // 2 - motif, size=b)
            for i in range(b):
                src = toks[i, start[i] : start[i] + motif]
                dst = self.seq_len // 2 + start[i]
                toks[i, dst : dst + motif] = src
        out = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        if self.with_enc_frames:
            es = int(self.seq_len * self.enc_seq_ratio)
            out["enc_frames"] = rng.normal(
                size=(b, es, self.d_model)
            ).astype(np.float32)
        return out

    def batch(self, step: int, sharding=None) -> Dict[str, jax.Array]:
        np_batch = self.batch_np(step)
        if sharding is None:
            return {k: jnp.asarray(v) for k, v in np_batch.items()}
        return {
            k: make_global_batch(v, sharding[k] if isinstance(sharding, dict)
                                 else sharding)
            for k, v in np_batch.items()
        }

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_global_batch(array: np.ndarray, sharding) -> jax.Array:
    """Materialize only this host's shards of a globally-sharded batch."""
    def cb(index):
        return array[index]

    return jax.make_array_from_callback(array.shape, sharding, cb)
