"""Health monitoring: heartbeats, stragglers, fault injection, degradation.

On a real multi-host deployment each host runs a ``HealthMonitor``; the
coordinator aggregates heartbeats and triggers checkpoint-restart (via
runtime/driver.py) or elastic remesh (runtime/elastic.py) on dead hosts.
The serving engine (serve/engine.py) runs the same monitor per decode
loop, so stragglers, retries and kernel demotions surface in one ledger.

Fault injection is unified behind *named sites*: every place the stack
can plausibly fail — the serve loop, the autotune cache, each kernel
dispatch point, the train step — calls ``maybe_inject(site)``.  The
``REPRO_FAULT_PLAN`` env var arms faults declaratively::

    REPRO_FAULT_PLAN="<site>:<step>:<kind>[,<site>:<step>:<kind>...]"

where ``step`` is the 0-based hit count of that site at which the fault
fires (``*`` = every hit) and ``kind`` is one of

    raise         raise SimulatedFailure at the site
    nan           ask the caller to poison its output with NaNs
                  (``maybe_inject`` returns ``"nan"``; numeric sites
                  multiply their result by NaN, exercising the
                  non-finite sentinel downstream)
    hang-timeout  sleep ``REPRO_FAULT_HANG_S`` seconds (default 0.25)
                  before continuing — a straggler, not a crash
    kill          SIGKILL the whole process at the site — an
                  *unhandleable* crash (no finally blocks, no atexit,
                  no flushing).  The crash-drill CI job arms this at
                  journaled serve steps and asserts the restarted
                  engine replays bit-exactly (serve/journal.py)

Sites inside jit-traced code (the ``kernel.*`` and ``layers.*`` family)
fire at trace/lowering time — once per distinct compiled shape — which
is exactly where real lowering failures surface; host-side sites
(``serve.*``, ``autotune.*``, ``train.step``) fire on every call.
``REPRO_FAIL_AT_STEP`` is kept as sugar for ``train.step:<n>:raise``
keyed on the *training* step number (which survives checkpoint-restart,
unlike the per-process hit counter).
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Callable, Dict, List, Optional, Tuple


class SimulatedFailure(RuntimeError):
    """Raised by an armed ``raise``-kind injection site."""


FAULT_KINDS = ("raise", "nan", "hang-timeout", "kill")

# Canonical injection sites.  Modules owning additional dispatch points
# register theirs at import time via ``register_site`` — the CI fault
# drill iterates this set, so a site that is never registered is a site
# that is never drilled.
INJECTION_SITES: List[str] = [
    "serve.prefill",
    "serve.decode_step",
    "autotune.load",
    "autotune.save",
    "kernel.matmul",
    "kernel.conv2d",
    "kernel.binary_matmul",
    "kernel.attention",
    "layers.attention",
    "layers.mlp",
    "train.step",
    "pool.alloc",
    "pool.spill",
]


def register_site(site: str) -> str:
    """Idempotently add ``site`` to the drillable-site registry."""
    if site not in INJECTION_SITES:
        INJECTION_SITES.append(site)
    return site


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    site: str
    step: Optional[int]      # None = every hit ("*")
    kind: str                # raise | nan | hang-timeout


@dataclasses.dataclass
class FiredFault:
    site: str
    hit: int
    kind: str
    timestamp: float


def parse_fault_plan(plan: str) -> List[FaultSpec]:
    """Parse a ``site:step:kind[,...]`` spec; raises ValueError on a
    malformed entry so a typo'd drill fails loudly, not silently."""
    specs: List[FaultSpec] = []
    for part in plan.split(","):
        part = part.strip()
        if not part:
            continue
        fields = part.rsplit(":", 2)
        if len(fields) != 3:
            raise ValueError(f"fault plan entry {part!r} is not "
                             f"site:step:kind")
        site, step_s, kind = fields
        if kind == "hang":
            kind = "hang-timeout"
        if kind not in FAULT_KINDS:
            raise ValueError(
                f"fault kind {kind!r} not in {FAULT_KINDS}")
        step = None if step_s == "*" else int(step_s)
        specs.append(FaultSpec(site=site, step=step, kind=kind))
    return specs


_site_hits: Dict[str, int] = {}
_fired: List[FiredFault] = []


def reset_faults() -> None:
    """Zero the per-site hit counters and the fired-fault log."""
    _site_hits.clear()
    _fired.clear()


def fault_log() -> List[FiredFault]:
    """Every fault the plan has fired so far, in firing order."""
    return list(_fired)


def fault_hang_seconds() -> float:
    return float(os.environ.get("REPRO_FAULT_HANG_S", "0.25"))


def _active_plan() -> List[FaultSpec]:
    plan = os.environ.get("REPRO_FAULT_PLAN")
    return parse_fault_plan(plan) if plan else []


def maybe_inject(site: str, step: Optional[int] = None) -> Optional[str]:
    """Advance ``site``'s hit counter and fire any armed fault.

    ``step`` overrides the hit index used for matching (the train driver
    passes the real training step so ``REPRO_FAIL_AT_STEP`` semantics
    survive restarts); by default the per-process hit count is used.

    Returns the fired kind for faults the *caller* must realize
    (``"nan"``: poison your output; ``"hang-timeout"``: the sleep
    already happened), ``None`` when nothing fired.  ``raise``-kind
    faults raise ``SimulatedFailure``.
    """
    hit = _site_hits.get(site, 0)
    _site_hits[site] = hit + 1
    idx = hit if step is None else step
    if site == "train.step":
        at = os.environ.get("REPRO_FAIL_AT_STEP")
        if at is not None and idx == int(at):
            _fired.append(FiredFault(site, idx, "raise", time.time()))
            raise SimulatedFailure(f"injected failure at step {idx}")
    for spec in _active_plan():
        if spec.site != site:
            continue
        if spec.step is not None and spec.step != idx:
            continue
        _fired.append(FiredFault(site, idx, spec.kind, time.time()))
        if spec.kind == "raise":
            raise SimulatedFailure(
                f"injected failure at {site} (hit {idx})")
        if spec.kind == "kill":
            # A real crash: SIGKILL cannot be caught, so nothing below
            # this frame (journal fsyncs, checkpoint renames, atexit)
            # gets to run — exactly the window crash recovery must
            # survive.
            os.kill(os.getpid(), signal.SIGKILL)
        if spec.kind == "hang-timeout":
            time.sleep(fault_hang_seconds())
        return spec.kind
    return None


def maybe_inject_failure(step: int) -> None:
    """Legacy hook (REPRO_FAIL_AT_STEP): crash the training loop at a
    chosen step.  Now a thin wrapper over the ``train.step`` site, so a
    ``REPRO_FAULT_PLAN`` targeting ``train.step`` fires here too."""
    maybe_inject("train.step", step=step)


# ---------------------------------------------------------------------------
# Health ledger.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class StepRecord:
    step: int
    seconds: float
    timestamp: float


@dataclasses.dataclass
class HealthEvent:
    """One ledger row: what happened, where, at which step."""

    kind: str                    # demotion | retry | probe | straggler |
    #                              admission-reject | fault | evicted | ...
    site: str = ""
    step: Optional[int] = None
    detail: str = ""
    timestamp: float = dataclasses.field(default_factory=time.time)


class HealthMonitor:
    """Per-host step timing + straggler detection + event ledger.

    A step is flagged a straggler when it exceeds ``threshold`` x the
    rolling median of the last ``window`` steps.  At cluster scale the
    same statistic over per-host heartbeats identifies slow hosts; the
    mitigation hook is pluggable (default: record + warn — a production
    deployment plugs in hot-spare promotion or in-flight re-dispatch).

    Beyond timing, the monitor is the single *ledger* for the serving
    stack: kernel demotions, retries, Pallas re-probes, admission
    rejections and injected faults all land in ``events`` via ``note``,
    and ``report()`` rolls them up next to the straggler stats.
    """

    def __init__(self, window: int = 32, threshold: float = 3.0,
                 on_straggler: Optional[Callable[[StepRecord], None]] = None):
        self.window = window
        self.threshold = threshold
        self.records: List[StepRecord] = []
        self.stragglers: List[StepRecord] = []
        self.on_straggler = on_straggler
        self.events: List[HealthEvent] = []

    def record(self, step: int, seconds: float) -> bool:
        rec = StepRecord(step, seconds, time.time())
        recent = [r.seconds for r in self.records[-self.window:]]
        self.records.append(rec)
        if len(recent) >= 8:
            med = sorted(recent)[len(recent) // 2]
            if seconds > self.threshold * med:
                self.stragglers.append(rec)
                self.note("straggler", step=step,
                          detail=f"{seconds:.3f}s vs median {med:.3f}s")
                if self.on_straggler:
                    self.on_straggler(rec)
                return True
        return False

    def note(self, kind: str, site: str = "", step: Optional[int] = None,
             detail: str = "") -> HealthEvent:
        ev = HealthEvent(kind=kind, site=site, step=step, detail=detail)
        self.events.append(ev)
        return ev

    def events_of(self, kind: str) -> List[HealthEvent]:
        return [e for e in self.events if e.kind == kind]

    @property
    def median_step_seconds(self) -> float:
        if not self.records:
            return 0.0
        xs = sorted(r.seconds for r in self.records)
        return xs[len(xs) // 2]

    def report(self) -> Dict[str, object]:
        """One-stop health rollup: step timing, stragglers, and the
        event ledger grouped by kind."""
        by_kind: Dict[str, int] = {}
        for e in self.events:
            by_kind[e.kind] = by_kind.get(e.kind, 0) + 1
        return {
            "steps": len(self.records),
            "median_step_seconds": self.median_step_seconds,
            "stragglers": len(self.stragglers),
            "events": by_kind,
            "injected_faults": [
                (f.site, f.hit, f.kind) for f in fault_log()
            ],
        }


# ---------------------------------------------------------------------------
# Graceful kernel degradation.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class DegradationPolicy:
    """When and how the serving engine falls back to the XLA path.

    The engine asks ``backend_for(step)`` before every prefill/decode
    step: ``"primary"`` means the configured (Pallas-on-TPU) path,
    ``"degraded"`` means the ``backend="xla"`` escape hatch
    (``layers.forced_backend``).  ``on_failure`` demotes after a step
    failure (kernel lowering error, injected fault, non-finite logits);
    after ``cooldown_steps`` degraded steps the next step *re-probes*
    the primary path — a healthy probe promotes back, a failing one
    re-demotes for another cooldown.  ``max_retries``/``backoff_base_s``
    bound the per-step retry loop (exponential backoff) for transient
    failures that survive demotion.
    """

    max_retries: int = 2
    backoff_base_s: float = 0.02
    cooldown_steps: int = 4

    def __post_init__(self):
        self.demoted = False
        self.demoted_at: Optional[int] = None
        self.demotions: List[Tuple[str, int]] = []   # (site, step)
        self.probes = 0

    def backend_for(self, step: int,
                    monitor: Optional[HealthMonitor] = None) -> str:
        if not self.demoted:
            return "primary"
        if step - self.demoted_at >= self.cooldown_steps:
            self.probes += 1
            if monitor is not None:
                monitor.note("probe", step=step,
                             detail="re-probing primary kernel path "
                                    "after cooldown")
            self.demoted = False          # optimistic: re-demote on failure
            self.demoted_at = None
            return "primary"
        return "degraded"

    def on_failure(self, site: str, step: int, error: BaseException,
                   monitor: Optional[HealthMonitor] = None) -> None:
        self.demoted = True
        self.demoted_at = step
        self.demotions.append((site, step))
        if monitor is not None:
            monitor.note("demotion", site=site, step=step,
                         detail=f"{type(error).__name__}: {error}")

    def backoff_seconds(self, attempt: int) -> float:
        return self.backoff_base_s * (2 ** attempt)
