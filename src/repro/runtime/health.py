"""Health monitoring: heartbeats, straggler detection, failure injection.

On a real multi-host deployment each host runs a ``HealthMonitor``; the
coordinator aggregates heartbeats and triggers checkpoint-restart (via
runtime/driver.py) or elastic remesh (runtime/elastic.py) on dead hosts.
In this container the monitor is exercised by the failure-injection tests
(single-host), but the logic is host-count agnostic.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, List, Optional


class SimulatedFailure(RuntimeError):
    """Raised by the failure-injection hook (REPRO_FAIL_AT_STEP)."""


@dataclasses.dataclass
class StepRecord:
    step: int
    seconds: float
    timestamp: float


class HealthMonitor:
    """Per-host step timing + straggler detection.

    A step is flagged a straggler when it exceeds ``threshold`` x the
    rolling median of the last ``window`` steps.  At cluster scale the
    same statistic over per-host heartbeats identifies slow hosts; the
    mitigation hook is pluggable (default: record + warn — a production
    deployment plugs in hot-spare promotion or in-flight re-dispatch).
    """

    def __init__(self, window: int = 32, threshold: float = 3.0,
                 on_straggler: Optional[Callable[[StepRecord], None]] = None):
        self.window = window
        self.threshold = threshold
        self.records: List[StepRecord] = []
        self.stragglers: List[StepRecord] = []
        self.on_straggler = on_straggler

    def record(self, step: int, seconds: float) -> bool:
        rec = StepRecord(step, seconds, time.time())
        recent = [r.seconds for r in self.records[-self.window:]]
        self.records.append(rec)
        if len(recent) >= 8:
            med = sorted(recent)[len(recent) // 2]
            if seconds > self.threshold * med:
                self.stragglers.append(rec)
                if self.on_straggler:
                    self.on_straggler(rec)
                return True
        return False

    @property
    def median_step_seconds(self) -> float:
        if not self.records:
            return 0.0
        xs = sorted(r.seconds for r in self.records)
        return xs[len(xs) // 2]


def maybe_inject_failure(step: int) -> None:
    """Crash the training loop at a chosen step (tests / chaos drills)."""
    at = os.environ.get("REPRO_FAIL_AT_STEP")
    if at is not None and step == int(at):
        raise SimulatedFailure(f"injected failure at step {step}")
