"""Elastic scaling: recompute the mesh from survivors and reshard state.

When hosts die (or join), the coordinator:
  1. picks the largest (data x model) grid over the surviving devices
     subject to the arch's TP-divisibility constraints,
  2. rebuilds shardings from the same path rules (launch/sharding.py),
  3. reshards the live (or checkpoint-restored) state with device_put.

Because batches are a pure function of (seed, step) (data/pipeline.py)
and sharding rules are axis-name based, resuming on the new mesh is
bit-exact modulo reduction order.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

from repro.launch import sharding as shard_rules


def largest_grid(n_devices: int, max_model: int,
                 model_divisors: Sequence[int]) -> tuple:
    """(data, model) maximizing used devices (ties -> larger model).

    ``model_divisors``: candidate TP sizes, e.g. (16, 8, 4, 2, 1)
    filtered by the arch's dims.
    """
    if n_devices < 1:
        raise ValueError(
            f"cannot plan a mesh over {n_devices} surviving devices")
    best = (n_devices, 1)
    best_used = n_devices
    for model in sorted(set(model_divisors), reverse=True):
        if model > max_model or model > n_devices:
            continue
        data = n_devices // model
        used = data * model
        if used > best_used or (used == best_used and model > best[1]):
            best, best_used = (data, model), used
    return best


@dataclasses.dataclass
class ReshardPlan:
    new_mesh: Mesh
    param_shardings: Any
    opt_shardings: Any = None
    cache_shardings: Any = None


def plan_remesh(
    surviving_devices: List,
    params_shape,
    opt_shape=None,
    model_divisors: Sequence[int] = (16, 8, 4, 2, 1),
    max_model: int = 16,
    cache_shape=None,
) -> ReshardPlan:
    """Plan the survivors' mesh + shardings for every state family.

    ``opt_shape`` is optional so inference restarts (serve.Engine
    crash recovery) can plan without optimizer state; ``cache_shape``
    (a KV-cache shape pytree) additionally yields the shardings the
    Checkpointer needs to restore a snapshot's cache onto the new —
    possibly smaller — mesh.
    """
    data, model = largest_grid(len(surviving_devices), max_model,
                               model_divisors)
    n_used = data * model
    devs = np.asarray(surviving_devices[:n_used]).reshape(data, model)
    mesh = Mesh(devs, ("data", "model"))
    return ReshardPlan(
        new_mesh=mesh,
        param_shardings=shard_rules.param_shardings(params_shape, mesh),
        opt_shardings=(shard_rules.opt_state_shardings(opt_shape, mesh)
                       if opt_shape is not None else None),
        cache_shardings=(shard_rules.cache_shardings(cache_shape, mesh)
                         if cache_shape is not None else None),
    )


def reshard(state, shardings):
    """device_put every leaf onto its new sharding (cross-host in prod)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), state, shardings
    )
