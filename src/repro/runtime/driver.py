"""Fault-tolerant training driver: checkpoint-restart + health monitoring.

The driver owns the full loop: data (stateless, step-addressed), train
step (jit), periodic async checkpoints, heartbeat/straggler monitoring,
and the failure-injection hook.  ``run(resume=True)`` after a crash
restores the latest checkpoint and continues bit-exactly (the dataset is
a pure function of (seed, step), so no iterator state is persisted).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import Checkpointer
from repro.data.pipeline import SyntheticLMDataset
from repro.models import lm
from repro.optim import AdamW, schedules
from repro.runtime import health
from repro.train.step import make_train_step


@dataclasses.dataclass
class TrainJobConfig:
    arch: Any                      # ArchConfig
    steps: int = 50
    global_batch: int = 8
    seq_len: int = 128
    lr: float = 3e-4
    schedule: str = "cosine"       # cosine | wsd | const
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 20
    microbatches: int = 1
    remat: str = "none"
    seed: int = 0
    aux_weight: float = 0.01


@dataclasses.dataclass
class TrainState:
    step: int
    params: Any
    opt_state: Any
    last_loss: float = float("nan")


class TrainDriver:
    def __init__(self, job: TrainJobConfig,
                 dist: Optional[lm.Dist] = None):
        self.job = job
        cfg = job.arch
        if job.schedule == "cosine":
            lr_fn = lambda s: schedules.cosine(s, max(job.steps // 10, 1),
                                               job.steps, job.lr)
        elif job.schedule == "wsd":
            lr_fn = lambda s: schedules.wsd(
                s, max(job.steps // 10, 1),
                int(job.steps * 0.7), max(job.steps // 5, 1), job.lr)
        else:
            lr_fn = lambda s: jnp.asarray(job.lr)
        self.optimizer = AdamW(lr_fn=lr_fn)
        self.dataset = SyntheticLMDataset(
            vocab_size=cfg.vocab_size, seq_len=job.seq_len,
            global_batch=job.global_batch, seed=job.seed,
            with_enc_frames=cfg.is_encoder_decoder, d_model=cfg.d_model,
            enc_seq_ratio=cfg.enc_seq_ratio,
        )
        self.ckpt = Checkpointer(job.ckpt_dir)
        self.monitor = health.HealthMonitor()
        self._step_fn = jax.jit(make_train_step(
            cfg, self.optimizer, dist=dist, remat=job.remat,
            microbatches=job.microbatches,
        ))

    # ------------------------------------------------------------------
    def init_state(self) -> TrainState:
        params = lm.init_model(self.job.arch, jax.random.PRNGKey(
            self.job.seed))
        opt_state = self.optimizer.init(params)
        return TrainState(step=0, params=params, opt_state=opt_state)

    def run(self, resume: bool = False,
            state: Optional[TrainState] = None) -> TrainState:
        if state is None:
            if resume and self.ckpt.latest_step() is not None:
                state = self.restore()
                print(f"resumed from step {state.step}")
            else:
                state = self.init_state()

        while state.step < self.job.steps:
            step = state.step
            batch = self.dataset.batch(step)
            t0 = time.time()
            params, opt_state, metrics = self._step_fn(
                state.params, state.opt_state, batch
            )
            loss = float(metrics["loss"])
            dt = time.time() - t0
            state = TrainState(step + 1, params, opt_state, loss)
            if self.monitor.record(step, dt):
                print(f"straggler: step {step} took {dt:.2f}s "
                      f"(median {self.monitor.median_step_seconds:.2f}s)")
            if (step + 1) % self.job.ckpt_every == 0 \
                    or step + 1 == self.job.steps:
                self.save(state)
            try:
                health.maybe_inject_failure(step + 1)
            except health.SimulatedFailure as e:
                # Ledger the crash, then let it propagate: the whole
                # point of the drill is exercising checkpoint-restart
                # (run(resume=True) continues bit-exactly).
                self.monitor.note("fault", site="train.step",
                                  step=step + 1, detail=str(e))
                raise
        self.ckpt.wait()
        return state

    def health_report(self) -> Dict[str, object]:
        """Step timing + ledger rollup for this driver's monitor."""
        return self.monitor.report()

    # ------------------------------------------------------------------
    def save(self, state: TrainState, blocking: bool = False) -> None:
        self.ckpt.save(
            state.step,
            {"params": state.params, "opt": state.opt_state},
            extras={"last_loss": state.last_loss,
                    "dataset_seed": self.job.seed},
            blocking=blocking,
        )

    def restore(self, shardings: Optional[Dict] = None) -> TrainState:
        templates = {
            "params": jax.eval_shape(
                lambda: lm.init_model(self.job.arch,
                                      jax.random.PRNGKey(self.job.seed))),
        }
        templates["opt"] = jax.eval_shape(
            self.optimizer.init, templates["params"])
        step, state, extras = self.ckpt.restore(templates, shardings)
        return TrainState(step=step, params=state["params"],
                          opt_state=state["opt"],
                          last_loss=extras.get("last_loss", float("nan")))
