"""Sharded checkpointing: npz payloads + JSON manifest, async save,
resharding restore.

Layout:
  <dir>/step_<N>/manifest.json   — step, leaf paths/shapes/dtypes, extras
  <dir>/step_<N>/arrays.npz      — one entry per pytree leaf
  <dir>/LATEST                   — atomic pointer to the newest step

Save fetches arrays synchronously (cheap vs a train step) and writes the
file in a background thread; ``wait()`` joins before the next save so at
most one write is in flight.  Restore takes target shardings, so state
can be loaded onto a *different* mesh than it was saved from (elastic
restart — runtime/elastic.py).

Durability contract (drilled by the ``ckpt.write`` fault site and the
crash-drill CI job):

  * ``arrays.npz``, ``manifest.json`` and the step directory are all
    fsync'd *before* ``LATEST`` flips, so a pointed-at step is always
    complete even across a machine crash;
  * an existing step directory is swapped out with a side-rename
    (``step_N`` -> ``step_N.trash`` -> ``step_N.tmp`` -> ``step_N``)
    instead of the old ``rmtree`` + ``rename`` — there is no window in
    which the payload exists only as deleted inodes;
  * ``LATEST`` itself is written via fsync'd temp file + ``os.replace``;
  * a kill at *any* point leaves either the previous pointed-at step or
    the new one fully intact; stale ``.tmp``/``.trash`` residue is swept
    by the next save's ``_gc``.

Failure surfacing: the background writer never swallows exceptions —
an async save failure is captured and re-raised (as ``CheckpointError``)
on the next ``wait()`` or ``save()``, and counted in
``stats()['save_errors']`` (mirroring ``core.autotune.stats``).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime import health

health.register_site("ckpt.write")

# dtypes np.savez cannot store natively (ml_dtypes): widen to f32 on disk,
# narrow back on restore using the manifest's logical dtype (bit-exact for
# bf16 since bf16 -> f32 is a widening).
_WIDEN = {"bfloat16": np.float32, "float8_e4m3fn": np.float32}


class CheckpointError(RuntimeError):
    """A checkpoint write failed (possibly asynchronously)."""


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


def _fsync_path(path: str) -> None:
    """fsync a file by path (payload written via library APIs)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    """fsync a directory so its entries (renames, creates) are durable."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._save_error: Optional[BaseException] = None
        self._stats = {
            "saves": 0,          # _write completions
            "save_errors": 0,    # _write failures (sync or async)
            "restores": 0,
            "gc_removed": 0,     # step dirs + stale tmp/trash swept
        }
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any],
             extras: Optional[Dict] = None, blocking: bool = False) -> None:
        """state: dict of pytrees (e.g. {"params": ..., "opt": ...}).

        Raises ``CheckpointError`` here if the *previous* async save
        failed (the error would otherwise be invisible); a failure of
        this save is raised directly when ``blocking``, else surfaced
        on the next ``wait()``/``save()``.
        """
        self.wait()
        host_state = {
            name: {k: np.asarray(jax.device_get(v))
                   for k, v in _flatten(tree).items()}
            for name, tree in state.items()
        }

        def _write():
            d = os.path.join(self.dir, f"step_{step:08d}")
            tmp = d + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            arrays = {}
            manifest = {"step": step, "extras": extras or {}, "trees": {}}
            for name, leaves in host_state.items():
                manifest["trees"][name] = {
                    k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                    for k, v in leaves.items()
                }
                for k, v in leaves.items():
                    wide = _WIDEN.get(str(v.dtype))
                    arrays[f"{name}::{k}"] = v.astype(wide) if wide else v
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=2)
                f.flush()
                os.fsync(f.fileno())
            _fsync_path(os.path.join(tmp, "arrays.npz"))
            _fsync_dir(tmp)
            # mid-write drill point: payload durable under .tmp, not yet
            # published — a kill here must leave the previous step (and
            # LATEST) fully intact
            health.maybe_inject("ckpt.write")
            trash = None
            if os.path.exists(d):
                trash = d + ".trash"
                if os.path.exists(trash):
                    shutil.rmtree(trash)
                os.rename(d, trash)
            os.rename(tmp, d)
            _fsync_dir(self.dir)
            latest = os.path.join(self.dir, "LATEST")
            with open(latest + ".tmp", "w") as f:
                f.write(os.path.basename(d))
                f.flush()
                os.fsync(f.fileno())
            os.replace(latest + ".tmp", latest)
            _fsync_dir(self.dir)
            if trash is not None:
                shutil.rmtree(trash, ignore_errors=True)
            self._gc()
            self._stats["saves"] += 1

        if blocking:
            try:
                _write()
            except BaseException as e:
                self._stats["save_errors"] += 1
                raise CheckpointError(
                    f"checkpoint save at step {step} failed: "
                    f"{type(e).__name__}: {e}") from e
        else:
            def _guarded():
                try:
                    _write()
                except BaseException as e:   # surfaced on wait()/save()
                    self._stats["save_errors"] += 1
                    self._save_error = e

            self._thread = threading.Thread(target=_guarded, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        """Join the in-flight async save; raise its captured failure.

        The daemon writer thread cannot raise into the caller, so this
        is where an async ``save(blocking=False)`` failure becomes
        visible — silently losing checkpoints is the one thing a
        crash-safety layer may never do.
        """
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._save_error is not None:
            err, self._save_error = self._save_error, None
            raise CheckpointError(
                f"async checkpoint save failed: "
                f"{type(err).__name__}: {err}") from err

    def stats(self) -> Dict[str, int]:
        return dict(self._stats)

    def _gc(self) -> None:
        removed = 0
        entries = sorted(os.listdir(self.dir))
        live = [d for d in entries
                if d.startswith("step_") and not d.endswith((".tmp",
                                                             ".trash"))]
        for d in live[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)
            removed += 1
        for d in entries:
            # residue a kill left between publish and cleanup; at most
            # one write is ever in flight and it has already renamed
            # its own tmp away by the time _gc runs, so anything still
            # here is stale
            if d.startswith("step_") and d.endswith((".tmp", ".trash")):
                shutil.rmtree(os.path.join(self.dir, d),
                              ignore_errors=True)
                removed += 1
        self._stats["gc_removed"] += removed

    # -- restore --------------------------------------------------------------
    def steps(self) -> List[int]:
        """Complete steps on disk (manifest present), ascending."""
        out = []
        try:
            entries = os.listdir(self.dir)
        except OSError:
            return out
        for d in sorted(entries):
            if not d.startswith("step_") or d.endswith((".tmp", ".trash")):
                continue
            if os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                try:
                    out.append(int(d.split("_")[1]))
                except (IndexError, ValueError):
                    continue
        return out

    def latest_step(self) -> Optional[int]:
        latest = os.path.join(self.dir, "LATEST")
        if os.path.exists(latest):
            with open(latest) as f:
                name = f.read().strip()
            try:
                step = int(name.split("_")[1])
            except (IndexError, ValueError):
                step = None
            if step is not None and os.path.exists(
                    os.path.join(self.dir, name, "manifest.json")):
                return step
        # LATEST missing or dangling (kill inside the swap window):
        # fall back to the newest complete step on disk
        steps = self.steps()
        return steps[-1] if steps else None

    def manifest(self, step: Optional[int] = None) -> Dict[str, Any]:
        """The manifest of ``step`` (default latest) without payload I/O."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f)

    def restore(self, templates: Dict[str, Any],
                shardings: Optional[Dict[str, Any]] = None,
                step: Optional[int] = None):
        """Load state matching ``templates`` (pytrees of like-structure).

        ``shardings``: optional dict of sharding pytrees; leaves are
        device_put to them — this is where elastic resharding happens.
        Returns (step, state dict, extras).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        out = {}
        for name, tree in templates.items():
            flat_keys = list(_flatten(tree).keys())
            leaves = []
            shard_flat = (
                list(_flatten(shardings[name]).values())
                if shardings and name in shardings else [None] * len(flat_keys)
            )
            meta = manifest["trees"][name]
            for k, sh in zip(flat_keys, shard_flat):
                arr = data[f"{name}::{k}"]
                want = meta[k]["dtype"]
                if str(arr.dtype) != want:
                    arr = arr.astype(jnp.dtype(want))
                leaves.append(
                    jax.device_put(arr, sh) if sh is not None
                    else jax.numpy.asarray(arr)
                )
            treedef = jax.tree_util.tree_structure(tree)
            out[name] = jax.tree_util.tree_unflatten(treedef, leaves)
        self._stats["restores"] += 1
        return manifest["step"], out, manifest.get("extras", {})
