"""Sharded checkpointing: npz payloads + JSON manifest, async save,
resharding restore.

Layout:
  <dir>/step_<N>/manifest.json   — step, leaf paths/shapes/dtypes, extras
  <dir>/step_<N>/arrays.npz      — one entry per pytree leaf
  <dir>/LATEST                   — atomic pointer to the newest step

Save fetches arrays synchronously (cheap vs a train step) and writes the
file in a background thread; ``wait()`` joins before the next save so at
most one write is in flight.  Restore takes target shardings, so state
can be loaded onto a *different* mesh than it was saved from (elastic
restart — runtime/elastic.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

# dtypes np.savez cannot store natively (ml_dtypes): widen to f32 on disk,
# narrow back on restore using the manifest's logical dtype (bit-exact for
# bf16 since bf16 -> f32 is a widening).
_WIDEN = {"bfloat16": np.float32, "float8_e4m3fn": np.float32}


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any],
             extras: Optional[Dict] = None, blocking: bool = False) -> None:
        """state: dict of pytrees (e.g. {"params": ..., "opt": ...})."""
        self.wait()
        host_state = {
            name: {k: np.asarray(jax.device_get(v))
                   for k, v in _flatten(tree).items()}
            for name, tree in state.items()
        }
        treedefs = {
            name: jax.tree_util.tree_structure(tree)
            for name, tree in state.items()
        }

        def _write():
            d = os.path.join(self.dir, f"step_{step:08d}")
            tmp = d + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            arrays = {}
            manifest = {"step": step, "extras": extras or {}, "trees": {}}
            for name, leaves in host_state.items():
                manifest["trees"][name] = {
                    k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                    for k, v in leaves.items()
                }
                for k, v in leaves.items():
                    wide = _WIDEN.get(str(v.dtype))
                    arrays[f"{name}::{k}"] = v.astype(wide) if wide else v
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=2)
            if os.path.exists(d):
                shutil.rmtree(d)
            os.rename(tmp, d)
            latest = os.path.join(self.dir, "LATEST")
            with open(latest + ".tmp", "w") as f:
                f.write(os.path.basename(d))
            os.replace(latest + ".tmp", latest)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            d for d in os.listdir(self.dir) if d.startswith("step_")
        )
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        latest = os.path.join(self.dir, "LATEST")
        if not os.path.exists(latest):
            return None
        with open(latest) as f:
            name = f.read().strip()
        return int(name.split("_")[1])

    def restore(self, templates: Dict[str, Any],
                shardings: Optional[Dict[str, Any]] = None,
                step: Optional[int] = None):
        """Load state matching ``templates`` (pytrees of like-structure).

        ``shardings``: optional dict of sharding pytrees; leaves are
        device_put to them — this is where elastic resharding happens.
        Returns (step, state dict, extras).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        out = {}
        for name, tree in templates.items():
            flat_keys = list(_flatten(tree).keys())
            leaves = []
            shard_flat = (
                list(_flatten(shardings[name]).values())
                if shardings and name in shardings else [None] * len(flat_keys)
            )
            meta = manifest["trees"][name]
            for k, sh in zip(flat_keys, shard_flat):
                arr = data[f"{name}::{k}"]
                want = meta[k]["dtype"]
                if str(arr.dtype) != want:
                    arr = arr.astype(jnp.dtype(want))
                leaves.append(
                    jax.device_put(arr, sh) if sh is not None
                    else jax.numpy.asarray(arr)
                )
            treedef = jax.tree_util.tree_structure(tree)
            out[name] = jax.tree_util.tree_unflatten(treedef, leaves)
        return manifest["step"], out, manifest.get("extras", {})
