"""Serving engine: batched prefill + decode with KV/SSM caches.

``make_serve_step`` builds the one-token decode function the dry-run
lowers for the decode_32k / long_500k cells; ``Engine`` is the example
driver that batches requests, prefills, and streams tokens.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotune
from repro.models import lm


def make_serve_step(cfg, dist: Optional[lm.Dist] = None,
                    unroll: int = 1) -> Callable:
    """decode one token for the whole batch.

    serve_step(params, cache, tokens (B,1)) -> (logits (B,V), cache)
    """

    def serve_step(params, cache, tokens):
        return lm.decode_step(params, cache, tokens, cfg, dist=dist,
                              unroll=unroll)

    return serve_step


def make_prefill_fn(cfg, dist: Optional[lm.Dist] = None) -> Callable:
    def prefill_fn(params, tokens, enc_frames=None):
        return lm.prefill(params, tokens, cfg, max_len=None,
                          enc_frames=enc_frames, dist=dist)

    return prefill_fn


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)


class Engine:
    """Minimal batched serving loop (greedy decoding).

    Batches requests of equal prompt length (uniform-position cache),
    prefills once, then steps the decode function; used by
    examples/serve_batch.py.
    """

    def __init__(self, cfg, params, max_len: int = 2048,
                 dist: Optional[lm.Dist] = None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.dist = dist
        self._decode = jax.jit(make_serve_step(cfg, dist))
        self._prefill = jax.jit(
            lambda p, t: lm.prefill(p, t, cfg, max_len=max_len, dist=dist)
        )
        self._warmed = set()

    def _warm_autotune(self, batch: int, seq: int) -> None:
        """Populate the dataflow-spec cache for this request shape so the
        prefill and decode traces hit memoized specs instead of
        enumerating the explorer's candidate space.  Covers the hot GEMM
        shapes, the attention shapes the model actually serves — the
        prefill square, the ``sq=1``/``skv=max_len`` cached-decode step
        (traced valid length, keyed as the worst case), plus the
        windowed variants of both for sliding-window configs and int8
        KV-cache decode keys (``lm.hot_attention_problems``) — and, for
        configs with a conv frontend (audio family), the frontend's
        ``ConvProblem`` shapes — today the whisper frontend is stubbed
        (precomputed frame embeddings), so the conv warm-up is cheap
        forward-keying for when the real frontend lands on
        ``ops.conv2d_fused``.  ``binary_mlp`` configs additionally warm
        their prefill and decode ``BinaryProblem`` shapes.  Only runs
        when the model will actually take the Pallas kernel path."""
        if not (getattr(self.cfg, "use_pallas_kernels", False)
                and jax.default_backend() == "tpu"):
            return
        key = (batch, seq)
        if key in self._warmed:
            return
        self._warmed.add(key)
        autotune.warm(lm.hot_gemm_problems(self.cfg, batch, seq)
                      + lm.hot_gemm_problems(self.cfg, batch, 1)
                      + lm.hot_attention_problems(self.cfg, batch, seq,
                                                  self.max_len)
                      + lm.hot_conv_problems(self.cfg, batch, seq)
                      + lm.hot_binary_problems(self.cfg, batch, seq)
                      + lm.hot_binary_problems(self.cfg, batch, 1))

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 greedy: bool = True, seed: int = 0) -> np.ndarray:
        """prompts: (B, S) equal-length int32. Returns (B, new) tokens."""
        self._warm_autotune(prompts.shape[0], prompts.shape[1])
        logits, cache = self._prefill(self.params, jnp.asarray(prompts))
        outs = []
        key = jax.random.PRNGKey(seed)
        tok = None
        for i in range(max_new_tokens):
            if greedy:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits).astype(jnp.int32)
            outs.append(np.asarray(tok))
            logits, cache = self._decode(self.params, cache, tok[:, None])
        return np.stack(outs, axis=1)
