"""Serving engine: batched prefill + decode with a request lifecycle.

``make_serve_step`` builds the one-token decode function the dry-run
lowers for the decode_32k / long_500k cells; ``Engine`` batches
requests, prefills, and streams tokens — now behind a fault-tolerant
request lifecycle:

    QUEUED -> PREFILLING -> DECODING -> {DONE, FAILED, EVICTED}

``submit`` is the admission gate: it validates prompts (empty, over
``max_len``, non-integer dtype -> ``ValueError``) and rejects requests
whose decode-step attention footprint cannot fit the hardware's VMEM
under *any* dataflow the explorer can enumerate (``AdmissionError``).
``serve`` drives admitted requests through prefill and the decode loop;
every step runs under ``_execute``:

  * the ``serve.prefill`` / ``serve.decode_step`` fault-injection sites
    (``runtime.health.maybe_inject``) fire here, so drills exercise the
    exact retry path real failures take;
  * a non-finite sentinel checks the step's logits on the host — a NaN
    or Inf (bad kernel output, injected ``nan`` fault) counts as a step
    failure just like a raised lowering error;
  * on failure the ``DegradationPolicy`` demotes to the ``backend=
    "xla"`` escape hatch (``layers.forced_backend``) and the step is
    retried with exponential backoff against the *pre-step* cache —
    JAX's functional caches make commit-after-validate free, so a
    poisoned step never contaminates later tokens;
  * after ``cooldown_steps`` the policy re-probes the primary path.

Per-request deadlines evict slow requests (EVICTED) instead of stalling
the batch; ``max_new_tokens`` budgets are clamped to the cache capacity
(``max_len``).  ``stats()`` reports admission/backpressure counters next
to the ``HealthMonitor`` ledger, so demotions, retries, stragglers and
injected faults surface in one place.

Crash safety (PR 7) extends no-request-*fails* to no-request-is-*lost*:

  * every admission, emitted token and terminal transition is written
    ahead to a durable ``RequestJournal`` (serve/journal.py) when the
    engine is given a journal directory (``journal_dir=`` or
    ``REPRO_JOURNAL_DIR``);
  * ``snapshot()`` persists the full engine state — request table,
    emitted tokens, counters, health ledger, KV cache, last logits and
    params — through ``ckpt.Checkpointer``, on a decode-step cadence
    (``snapshot_every=`` / ``REPRO_SNAPSHOT_EVERY``);
  * after a kill, a fresh engine's ``restore()`` rebuilds the request
    table from the journal, loads the newest intact snapshot (falling
    back across corrupt ones, then to journal-only cold replay), and
    re-admits in-flight requests at their exact decode position; the
    next ``serve()`` call continues the decode loop from the restored
    pre-step cache.  Greedy decode is a pure function of params + the
    journaled prompts, so the recovered token streams are bit-identical
    to the uninterrupted run — the crash-drill CI job SIGKILLs the loop
    at journaled steps and asserts exactly that.  ``restore()`` accepts
    a ``devices=`` survivor list and reshards the snapshot through
    ``runtime.elastic.plan_remesh``, so recovery works onto a smaller
    mesh than the one that crashed.

Continuous batching (PR 8) lifts the equal-prompt-length restriction:

  * ``submit()`` now returns a ``RequestHandle`` — still a ``Request``
    (every existing call site keeps working) plus a ``tokens()``
    stream iterator and a blocking ``result()``, both of which drive
    the engine's continuous scheduler (``serve/scheduler.py``) one
    step at a time;
  * ``serve()`` on a mixed-prompt-length batch no longer raises — it
    routes through the scheduler: per-step admission into a fixed pool
    of cache slots, per-row banded decode (vector ``kv_len``), chunked
    prefill interleaved with decode, and prefix-page reuse on the
    shared ``PagedKVCache``.  Equal-length batches keep the original
    batch-synchronous loop (and its snapshot/warm-resume path)
    bit-for-bit;
  * ``step()`` / ``drain()`` expose the scheduler directly;
    ``generate()`` remains as a deprecated shim over submit + drain.
  * crash safety composes: continuous serving journals the same
    submit/serve/token/terminal records (``mode="continuous"``), and a
    cold ``restore()`` replays the ragged batch through a fresh
    scheduler — admission order, slot assignment and the fixed-shape
    ragged cache are all deterministic, so recovered greedy streams
    stay bit-identical (the ragged crash drill pins this).

Memory-pressure resilience (PR 10) makes the page pool the continuous
path's real decode datapath and makes it pressure-proof:

  * for paged-decode-capable configs the scheduler routes every decode
    step through ``ops.paged_attention`` off the block tables — no
    contiguous slot cache — so pool occupancy is the true capacity
    signal, and ``submit()`` additionally rejects requests whose KV
    reach cannot fit the pool at all (``AdmissionError``);
  * under pressure the scheduler runs an explicit ladder — watermark
    admission backpressure (queued-with-reason via
    ``Request.queue_reason``, never silent), host spill of the coldest
    request's pages (``PagedKVCache.spill``/``unspill``, shared prefix
    pages stay pinned), then preemption of the youngest request
    (fsync'd ``preempt`` journal record, deterministic
    recompute-on-resume verified by ``replay_divergence``);
  * ``stats()`` surfaces ``spills`` / ``spilled_pages`` / ``unspills``
    / ``preemptions`` / ``backpressure`` counters plus the scheduler's
    pool report (occupancy, watermark state), and the ``pool.alloc`` /
    ``pool.spill`` fault sites make the whole ladder drillable —
    including SIGKILL mid-spill, which recovers via the PR-7 journal
    with zero lost or duplicated requests.
"""
from __future__ import annotations

import dataclasses
import enum
import os
import time
import warnings
from typing import (Any, Callable, Dict, Iterator, List, Optional,
                    Sequence, Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import Checkpointer, CheckpointError
from repro.core import autotune, cost_model, explorer
from repro.models import layers, lm
from repro.runtime import elastic, health
from repro.serve import journal as journal_lib
from repro.serve.paged_cache import pages_for
from repro.serve.scheduler import (ContinuousScheduler, SamplingParams,
                                   SchedulerConfig, paged_decode_enabled,
                                   pool_capacity)

health.register_site("snapshot.save")
health.register_site("engine.restore")


def make_serve_step(cfg, dist: Optional[lm.Dist] = None,
                    unroll: int = 1) -> Callable:
    """decode one token for the whole batch.

    serve_step(params, cache, tokens (B,1)) -> (logits (B,V), cache)
    """

    def serve_step(params, cache, tokens):
        return lm.decode_step(params, cache, tokens, cfg, dist=dist,
                              unroll=unroll)

    return serve_step


def make_prefill_fn(cfg, dist: Optional[lm.Dist] = None) -> Callable:
    def prefill_fn(params, tokens, enc_frames=None):
        return lm.prefill(params, tokens, cfg, max_len=None,
                          enc_frames=enc_frames, dist=dist)

    return prefill_fn


class RequestState(str, enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    DONE = "done"
    FAILED = "failed"
    EVICTED = "evicted"


_TERMINAL = ("done", "failed", "evicted")


def _terminal(state: "RequestState") -> bool:
    return state.value in _TERMINAL


def to_state_safe(value) -> "RequestState":
    """RequestState from a journal/snapshot string; QUEUED on junk."""
    try:
        return RequestState(value)
    except ValueError:
        return RequestState.QUEUED


class AdmissionError(ValueError):
    """Request rejected at admission (resource infeasibility)."""


class StepFailed(RuntimeError):
    """A prefill/decode step failed on both kernel paths, retries
    exhausted — the requests it was serving transition to FAILED."""


class NonFiniteLogits(RuntimeError):
    """The post-step sentinel saw NaN/Inf logits."""


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int
    deadline_s: Optional[float] = None   # wall-clock budget from serve start
    rid: int = -1
    state: RequestState = RequestState.QUEUED
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    error: Optional[str] = None
    degraded_steps: int = 0       # decode steps served on the XLA path
    queue_reason: Optional[str] = None   # why a QUEUED request is waiting
    #                                      (watermark / pool backpressure)


@dataclasses.dataclass
class RequestHandle(Request):
    """What ``Engine.submit`` returns: a ``Request`` (so every existing
    consumer of the request table keeps working) bound to its engine,
    with a token-stream view over the continuous scheduler.

    ``tokens()`` yields generated token ids as they land, stepping the
    engine's scheduler whenever the stream runs dry; ``result()``
    drains the stream and returns the full output (raising
    ``StepFailed`` if the request ended FAILED).  Handles served
    through the batch-synchronous ``Engine.serve`` path work too —
    their tokens are already in ``out_tokens`` by the time the stream
    is read.
    """
    sampling: Optional[SamplingParams] = None
    engine: Optional["Engine"] = dataclasses.field(
        default=None, repr=False, compare=False)

    def tokens(self) -> Iterator[int]:
        i = 0
        while True:
            while i < len(self.out_tokens):
                yield self.out_tokens[i]
                i += 1
            if _terminal(self.state):
                return
            if self.engine is None:
                raise RuntimeError(
                    f"request {self.rid} is detached from its engine "
                    f"and not terminal; cannot stream")
            self.engine.step()

    def result(self) -> np.ndarray:
        """Block until terminal; the generated tokens as (n,) int32."""
        for _ in self.tokens():
            pass
        if self.state == RequestState.FAILED:
            raise StepFailed(
                f"request {self.rid} ended failed: {self.error}")
        return np.asarray(self.out_tokens, np.int32)


class Engine:
    """Batched serving loop with admission, degradation and retries.

    Equal-prompt-length batches run the original batch-synchronous
    loop (prefill once, decode until the last request finishes);
    mixed-length batches — and the ``step()``/``drain()``/handle
    streaming API — run the continuous scheduler: per-step admission
    into cache slots, per-row banded decode, chunked prefill and
    prefix-page reuse (``serve/scheduler.py``).  ``generate`` is kept
    as a deprecated prompts-in/tokens-out shim over submit + drain.

    ``hw`` is the admission-control hardware model (VMEM feasibility of
    the decode-step attention); tests pass a tiny ``HardwareSpec`` to
    force rejections.  ``policy``/``monitor`` own degradation state and
    the health ledger; callers may share one monitor across engines.
    """

    def __init__(self, cfg, params, max_len: int = 2048,
                 dist: Optional[lm.Dist] = None,
                 monitor: Optional[health.HealthMonitor] = None,
                 policy: Optional[health.DegradationPolicy] = None,
                 hw: cost_model.HardwareSpec = cost_model.V5E,
                 validate_outputs: bool = True,
                 journal_dir: Optional[str] = None,
                 snapshot_dir: Optional[str] = None,
                 snapshot_every: Optional[int] = None,
                 scheduler_config: Optional[SchedulerConfig] = None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.dist = dist
        self.hw = hw
        self.validate_outputs = validate_outputs
        self.monitor = monitor if monitor is not None else health.HealthMonitor()
        self.policy = policy if policy is not None else health.DegradationPolicy()
        jd = journal_dir or journal_lib.journal_dir()
        self.journal = journal_lib.RequestJournal(jd) if jd else None
        sd = snapshot_dir or (os.path.join(jd, "snapshots") if jd else None)
        self.snapshots = Checkpointer(sd) if sd else None
        if snapshot_every is None:
            snapshot_every = int(
                os.environ.get("REPRO_SNAPSHOT_EVERY", "0") or 0)
        self.snapshot_every = snapshot_every
        # live serve-loop state for snapshot(): (reqs, cache, logits,
        # step, greedy, seed) — valid between decode steps only
        self._live: Optional[Tuple] = None
        self._pending_resume: Optional[Dict[str, Any]] = None
        self._replay_expected: Dict[int, List[int]] = {}
        self._decode = jax.jit(make_serve_step(cfg, dist))
        self._prefill = jax.jit(
            lambda p, t: lm.prefill(p, t, cfg, max_len=max_len, dist=dist)
        )

        # Degraded twins: same computation forced through the XLA escape
        # hatch.  The context manager must be live while the function
        # body *traces*, so it wraps the body inside the jitted callee
        # rather than the jit() call.
        def _decode_xla(params, cache, tokens):
            with layers.forced_backend("xla"):
                return lm.decode_step(params, cache, tokens, cfg, dist=dist)

        def _prefill_xla(params, tokens):
            with layers.forced_backend("xla"):
                return lm.prefill(params, tokens, cfg, max_len=max_len,
                                  dist=dist)

        self._decode_degraded = jax.jit(_decode_xla)
        self._prefill_degraded = jax.jit(_prefill_xla)
        self._warmed = set()
        self._next_rid = 0
        self.scheduler_config = scheduler_config
        self._scheduler: Optional[ContinuousScheduler] = None
        self._backlog: List[RequestHandle] = []
        # (seq len, kv reach) -> feasible
        self._admission_cache: Dict[Tuple[int, int], bool] = {}
        self._counters: Dict[str, int] = {
            "submitted": 0, "admitted": 0, "rejected": 0,
            "completed": 0, "failed": 0, "evicted": 0,
            "retries": 0, "demotions": 0, "degraded_steps": 0,
            "budget_clamped": 0,
            "snapshots_saved": 0, "snapshot_errors": 0,
            "recovered": 0, "replayed_steps": 0,
            "replay_divergence": 0, "restore_fallbacks": 0,
            "spills": 0, "spilled_pages": 0, "unspills": 0,
            "preemptions": 0, "backpressure": 0,
        }

    # ------------------------------------------------------------------
    # Admission.
    # ------------------------------------------------------------------
    def _attention_feasible(self, seq: int,
                            cap: Optional[int] = None) -> bool:
        """Can every attention workload this request implies be realized
        under ``self.hw``'s VMEM by at least one explorer candidate?

        ``cap`` is the request's actual KV reach — ``prompt +
        max_new_tokens``, clamped to capacity.  Probing at ``max_len``
        regardless of the request's budget over-rejected short requests
        on small-VMEM parts (a 10-token request was billed for a
        2048-position decode it could never reach); the reach-aware
        probe admits everything the request can actually touch.
        """
        cap = int(cap if cap is not None else self.max_len)
        key = (seq, cap)
        if key in self._admission_cache:
            return self._admission_cache[key]
        ok = True
        for p in lm.hot_attention_problems(self.cfg, 1, max(seq, 1), cap):
            if not explorer.enumerate_attention_candidates(p, self.hw):
                ok = False
                break
        self._admission_cache[key] = ok
        return ok

    def _reject(self, reason: str, exc_type=ValueError) -> None:
        self._counters["rejected"] += 1
        self.monitor.note("admission-reject", site="serve.submit",
                          detail=reason)
        raise exc_type(reason)

    def submit(self, prompt, max_new_tokens: Optional[int] = None,
               deadline_s: Optional[float] = None,
               sampling: Optional[SamplingParams] = None
               ) -> RequestHandle:
        """Validate and admit one request (state QUEUED), or raise.

        Returns a ``RequestHandle``: stream its tokens with
        ``handle.tokens()`` / ``handle.result()``, or pass it (with
        others) to ``serve()`` / ``drain()``.  ``sampling`` bundles the
        per-request settings (``SamplingParams``); the explicit
        ``max_new_tokens`` / ``deadline_s`` arguments win over it.

        ``ValueError`` for malformed input (empty / over-``max_len`` /
        non-integer prompt, non-positive budget); ``AdmissionError``
        (a ``ValueError`` subclass) when the decode-step attention
        cannot fit the hardware's VMEM under any dataflow at the
        request's KV reach (``prompt + budget``, clamped to capacity).
        """
        self._counters["submitted"] += 1
        if max_new_tokens is None:
            max_new_tokens = (sampling.max_new_tokens if sampling
                              is not None else 16)
        if deadline_s is None and sampling is not None:
            deadline_s = sampling.deadline_s
        prompt = np.asarray(prompt)
        if prompt.ndim != 1:
            self._reject(f"prompt must be rank-1 (one request), got "
                         f"shape {prompt.shape}")
        if prompt.size == 0:
            self._reject("empty prompt: need at least one token")
        if not np.issubdtype(prompt.dtype, np.integer):
            self._reject(f"prompt dtype must be integer token ids, got "
                         f"{prompt.dtype}")
        plen = int(prompt.shape[0])
        if plen >= self.max_len:
            self._reject(
                f"prompt length {plen} leaves no decode room under "
                f"max_len={self.max_len}")
        if max_new_tokens < 1:
            self._reject(f"max_new_tokens must be >= 1, got "
                         f"{max_new_tokens}")
        reach = min(plen + max_new_tokens, self.max_len)
        if not self._attention_feasible(plen, reach):
            self._reject(
                f"no VMEM-feasible attention dataflow for prompt length "
                f"{plen} / kv reach {reach} (max_len={self.max_len}) on "
                f"{self.hw.name} ({self.hw.vmem_bytes} bytes VMEM)",
                AdmissionError)
        if paged_decode_enabled(self.cfg, self.scheduler_config,
                                self.max_len):
            sc = self.scheduler_config or SchedulerConfig()
            need = pages_for(reach, sc.page_size)
            cap = pool_capacity(sc, self.max_len)
            if need > cap:
                self._reject(
                    f"page pool cannot hold request: kv reach {reach} "
                    f"needs {need} pages of {sc.page_size}, pool "
                    f"capacity is {cap} pages", AdmissionError)
        budget = min(max_new_tokens, self.max_len - plen)
        if budget < max_new_tokens:
            self._counters["budget_clamped"] += 1
            self.monitor.note(
                "backpressure", site="serve.submit",
                detail=f"budget clamped {max_new_tokens} -> {budget} "
                       f"(cache capacity max_len={self.max_len})")
        self._counters["admitted"] += 1
        req = RequestHandle(prompt=np.asarray(prompt, np.int32),
                            max_new_tokens=budget, deadline_s=deadline_s,
                            rid=self._next_rid, sampling=sampling,
                            engine=self)
        self._next_rid += 1
        self._backlog.append(req)
        if self.journal is not None:
            # WAL contract: the caller is told "admitted" only after the
            # admission is durable, so a kill can never lose a request
            # the client believes is in flight
            self.journal.append(
                "submit", fsync=True, rid=req.rid,
                prompt=[int(t) for t in req.prompt],
                max_new_tokens=req.max_new_tokens,
                deadline_s=req.deadline_s)
        sched = self._scheduler
        if (sched is not None and sched.use_paged
                and sched.paged.above_high()):
            # backpressure is queued-with-reason, never a silent drop:
            # the request is admitted and durable, but the caller can
            # see it will wait for the pool to drain below the
            # watermark before it is scheduled
            req.queue_reason = (
                f"pool above high watermark (occupancy "
                f"{sched.paged.occupancy():.2f})")
            self._counters["backpressure"] += 1
            self.monitor.note("backpressure", site="serve.submit",
                              detail=f"rid {req.rid}: "
                                     f"{req.queue_reason}")
        return req

    # ------------------------------------------------------------------
    # Guarded step execution: inject -> run -> sentinel -> retry/demote.
    # ------------------------------------------------------------------
    def _execute(self, site: str, step: int, primary: Callable,
                 degraded: Callable) -> Tuple[Any, Any, str]:
        """Run one engine step fault-tolerantly.

        Picks the kernel path from the DegradationPolicy, fires the
        injection site, validates logits finiteness, and on any failure
        demotes + retries with backoff.  Returns (logits, cache, path).
        Raises ``StepFailed`` when retries are exhausted.
        """
        attempt = 0
        while True:
            path = self.policy.backend_for(step, self.monitor)
            fn = primary if path == "primary" else degraded
            try:
                fault = health.maybe_inject(site)
                logits, cache = fn()
                if fault == "nan":
                    logits = logits * jnp.asarray(jnp.nan, logits.dtype)
                if self.validate_outputs and not bool(
                        jnp.all(jnp.isfinite(logits))):
                    raise NonFiniteLogits(
                        f"non-finite logits from {site} step {step} "
                        f"({path} path)")
                return logits, cache, path
            except Exception as e:
                # SimulatedFailure, NonFiniteLogits, kernel lowering /
                # interpret errors — anything a bad step can surface.
                failure = e
            self.policy.on_failure(site, step, failure, self.monitor)
            self._counters["demotions"] += 1
            attempt += 1
            if attempt > self.policy.max_retries:
                raise StepFailed(
                    f"{site} step {step} failed after "
                    f"{self.policy.max_retries} retries: "
                    f"{type(failure).__name__}: {failure}") from failure
            self._counters["retries"] += 1
            self.monitor.note("retry", site=site, step=step,
                              detail=f"attempt {attempt} after "
                                     f"{type(failure).__name__}")
            time.sleep(self.policy.backoff_seconds(attempt - 1))

    # ------------------------------------------------------------------
    # Serving.
    # ------------------------------------------------------------------
    def _warm_autotune(self, batch: int, seq: int) -> None:
        """Populate the dataflow-spec cache for this request shape so the
        prefill and decode traces hit memoized specs instead of
        enumerating the explorer's candidate space.  Covers the hot GEMM
        shapes, the attention shapes the model actually serves — the
        prefill square, the ``sq=1``/``skv=max_len`` cached-decode step
        (traced valid length, keyed as the worst case), plus the
        windowed variants of both for sliding-window configs and int8
        KV-cache decode keys (``lm.hot_attention_problems``) — and, for
        configs with a conv frontend (audio family), the frontend's
        ``ConvProblem`` shapes — today the whisper frontend is stubbed
        (precomputed frame embeddings), so the conv warm-up is cheap
        forward-keying for when the real frontend lands on
        ``ops.conv2d_fused``.  ``binary_mlp`` configs additionally warm
        their prefill and decode ``BinaryProblem`` shapes.  Only runs
        when the model will actually take the Pallas kernel path."""
        if not (getattr(self.cfg, "use_pallas_kernels", False)
                and jax.default_backend() == "tpu"):
            return
        key = (batch, seq)
        if key in self._warmed:
            return
        self._warmed.add(key)
        autotune.warm(lm.hot_gemm_problems(self.cfg, batch, seq)
                      + lm.hot_gemm_problems(self.cfg, batch, 1)
                      + lm.hot_attention_problems(self.cfg, batch, seq,
                                                  self.max_len)
                      + lm.hot_conv_problems(self.cfg, batch, seq)
                      + lm.hot_binary_problems(self.cfg, batch, seq)
                      + lm.hot_binary_problems(self.cfg, batch, 1))

    def serve(self, requests: Sequence[Request], greedy: bool = True,
              seed: int = 0) -> List[Request]:
        """Drive a batch of QUEUED requests to a terminal state.

        Equal-prompt-length batches run the batch-synchronous loop
        (uniform-position cache, snapshot-resumable); mixed-length
        batches route through the continuous scheduler (per-row banded
        cache, per-step admission).  Terminal states: DONE (budget
        reached), EVICTED (deadline), FAILED (step failed beyond
        retries).  Returns the same request objects for convenience.

        After ``restore()``, serving requests that include a recovered
        in-flight batch continues that batch from its restored decode
        position — the snapshot's pre-step cache and logits when one
        was loaded, or a fresh prefill + deterministic re-decode (cold
        replay) otherwise.  The resumed loop uses the *journaled*
        greedy/seed, not this call's arguments, so replay cannot be
        skewed by a caller passing different sampling settings.
        """
        pending = self._take_resume(requests)
        mode = "batch"
        if pending is not None:
            greedy, seed = pending["greedy"], pending["seed"]
            mode = pending.get("mode", "batch")
            reqs = pending["reqs"]
            if pending["cache"] is not None:
                # warm restart: decode continues on the snapshot cache
                self._decode_loop(reqs, pending["cache"],
                                  pending["logits"], pending["step"],
                                  time.monotonic(), greedy, seed)
                self._check_replay(requests)
                return list(requests)
            # cold restart: re-prefill the journaled batch below
            reqs = [r for r in reqs if r.state == RequestState.QUEUED]
        else:
            reqs = [r for r in requests if r.state == RequestState.QUEUED]
        if not reqs:
            return list(requests)
        lens = {int(r.prompt.shape[0]) for r in reqs}
        if len(lens) != 1 or mode == "continuous":
            # mixed prompt lengths (or a continuous-mode cold replay):
            # the continuous scheduler owns the batch
            return self._serve_ragged(requests, reqs, greedy, seed)
        prompts = np.stack([r.prompt for r in reqs]).astype(np.int32)
        self._warm_autotune(prompts.shape[0], prompts.shape[1])
        t_start = time.monotonic()
        if self.journal is not None:
            # batch composition a later cold replay must reproduce
            self.journal.append(
                "serve", fsync=True, rids=[r.rid for r in reqs],
                seed=int(seed), greedy=bool(greedy),
                prompt_len=int(prompts.shape[1]))

        for r in reqs:
            r.state = RequestState.PREFILLING
        dev_prompts = jnp.asarray(prompts)
        try:
            logits, cache, path = self._execute(
                "serve.prefill", 0,
                lambda: self._prefill(self.params, dev_prompts),
                lambda: self._prefill_degraded(self.params, dev_prompts))
        except StepFailed as e:
            self._fail_batch(reqs, e)
            return list(requests)
        if path == "degraded":
            self._counters["degraded_steps"] += 1

        for r in reqs:
            r.state = RequestState.DECODING
        self._decode_loop(reqs, cache, logits, 0, t_start, greedy, seed)
        self._check_replay(requests)
        return list(requests)

    def _serve_ragged(self, requests: Sequence[Request],
                      reqs: List[Request], greedy: bool,
                      seed: int) -> List[Request]:
        """Drain a mixed-prompt-length batch through a dedicated
        continuous scheduler.

        A fresh scheduler per call: admission order (the given request
        order), slot assignment and the fixed-shape ragged cache are
        then pure functions of the batch, which is what lets a cold
        journal replay of the same rids regenerate bit-identical
        greedy streams (``_check_replay`` verifies)."""
        self._live = None        # no snapshot point inside a ragged drain
        if self.journal is not None:
            self.journal.append(
                "serve", fsync=True, rids=[r.rid for r in reqs],
                seed=int(seed), greedy=bool(greedy), mode="continuous",
                prompt_lens=[int(r.prompt.shape[0]) for r in reqs])
        sched = ContinuousScheduler(self, self.scheduler_config)
        for r in reqs:
            sched.enqueue(r)
        sched.drain(greedy=greedy, seed=seed)
        self._last_sched_report = sched.report()
        self._check_replay(requests)
        return list(requests)

    # ------------------------------------------------------------------
    # Continuous stepping (the handle/stream API).
    # ------------------------------------------------------------------
    def _ensure_scheduler(self) -> ContinuousScheduler:
        if self._scheduler is None:
            self._scheduler = ContinuousScheduler(self,
                                                  self.scheduler_config)
        return self._scheduler

    def _enqueue_backlog(self, sched: ContinuousScheduler) -> None:
        """Hand submitted-but-unserved handles to the scheduler, in rid
        (submission) order, journaling the in-flight set so a cold
        replay can re-enqueue the identical batch."""
        new = [r for r in self._backlog
               if r.state == RequestState.QUEUED]
        self._backlog = []
        if not new:
            return
        if self.journal is not None:
            live = {r.rid for r in new}
            live.update(r.rid for r in sched.inflight()
                        if not _terminal(r.state))
            self.journal.append(
                "serve", fsync=True, rids=sorted(live),
                seed=int(sched.seed), greedy=bool(sched.greedy),
                mode="continuous")
        for r in new:
            sched.enqueue(r)

    def step(self) -> bool:
        """One continuous-scheduler tick: admit at most one waiting
        request (or push one prefill chunk), then run one decode step
        over every occupied slot.  Returns True if any work was done.
        Newly submitted handles are picked up automatically."""
        sched = self._ensure_scheduler()
        self._enqueue_backlog(sched)
        self._live = None
        return sched.step()

    def drain(self, greedy: bool = True, seed: int = 0) -> None:
        """Step the continuous scheduler until every submitted request
        is terminal."""
        sched = self._ensure_scheduler()
        self._enqueue_backlog(sched)
        self._live = None
        sched.drain(greedy=greedy, seed=seed)

    def scheduler_report(self) -> Optional[Dict[str, Any]]:
        """Occupancy/paging counters: the persistent scheduler's if one
        is live, else the last ragged ``serve()`` drain's (None before
        any continuous serving)."""
        if self._scheduler is not None:
            return self._scheduler.report()
        return getattr(self, "_last_sched_report", None)

    def _decode_loop(self, reqs: List[Request], cache, logits, step: int,
                     t_start: float, greedy: bool, seed: int) -> None:
        """The decode loop, resumable at any ``step``.

        ``reqs`` is the batch in cache-row order (terminal members stay
        inert but keep their rows); ``logits`` predicts the *next*
        token, ``cache`` holds everything up to and including step
        ``step`` — the same pre-step-cache contract the PR-6 retry path
        relies on, which is what makes both snapshot resume and retry
        composable with each other.
        """
        key = jax.random.PRNGKey(seed)
        if not greedy:
            # fast-forward the PRNG stream to the resume position so
            # sampled replay of an unchanged batch is deterministic too
            for _ in range(step):
                key, _ = jax.random.split(key)
        self._live = (reqs, cache, logits, step, greedy, seed)
        while True:
            active = [r for r in reqs if r.state == RequestState.DECODING]
            if not active:
                break
            now = time.monotonic()
            for r in active:
                if (r.deadline_s is not None
                        and now - t_start > r.deadline_s):
                    r.state = RequestState.EVICTED
                    r.error = (f"deadline {r.deadline_s:.3f}s exceeded "
                               f"after {len(r.out_tokens)} tokens")
                    self._counters["evicted"] += 1
                    self.monitor.note("evicted", site="serve.decode_step",
                                      step=step, detail=r.error)
                    self._journal_terminal(r, step)
            active = [r for r in reqs if r.state == RequestState.DECODING]
            if not active:
                break

            if greedy:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits).astype(jnp.int32)
            tok_np = np.asarray(tok)
            for i, r in enumerate(reqs):
                if r.state == RequestState.DECODING:
                    t = int(tok_np[i])
                    r.out_tokens.append(t)
                    if self.journal is not None:
                        # position-addressed so a replayed step that
                        # re-emits an already-journaled token overwrites
                        # instead of duplicating on the next recovery
                        self.journal.append("token", rid=r.rid,
                                            step=len(r.out_tokens),
                                            token=t)
                    if len(r.out_tokens) >= r.max_new_tokens:
                        r.state = RequestState.DONE
                        self._counters["completed"] += 1
                        self._journal_terminal(r, step)
            if not any(r.state == RequestState.DECODING for r in reqs):
                break

            step += 1
            t0 = time.monotonic()
            try:
                logits, cache, path = self._execute(
                    "serve.decode_step", step,
                    lambda: self._decode(self.params, cache, tok[:, None]),
                    lambda: self._decode_degraded(self.params, cache,
                                                  tok[:, None]))
            except StepFailed as e:
                self._fail_batch(reqs, e, step)
                break
            if path == "degraded":
                self._counters["degraded_steps"] += 1
                for r in reqs:
                    if r.state == RequestState.DECODING:
                        r.degraded_steps += 1
            self.monitor.record(step, time.monotonic() - t0)
            self._live = (reqs, cache, logits, step, greedy, seed)
            if (self.snapshot_every and self.snapshots is not None
                    and step % self.snapshot_every == 0):
                self.snapshot()

    def _journal_terminal(self, r: Request,
                          step: Optional[int] = None) -> None:
        if self.journal is not None:
            self.journal.append(r.state.value, fsync=True, rid=r.rid,
                                step=step, error=r.error)

    def _fail_batch(self, reqs: List[Request], err: BaseException,
                    step: Optional[int] = None) -> None:
        for r in reqs:
            if r.state in (RequestState.PREFILLING, RequestState.DECODING):
                r.state = RequestState.FAILED
                r.error = str(err)
                self._counters["failed"] += 1
                self._journal_terminal(r, step)

    # ------------------------------------------------------------------
    # Crash safety: snapshot, restore, deterministic replay.
    # ------------------------------------------------------------------
    def snapshot(self) -> Optional[int]:
        """Persist the live serve-loop state through the Checkpointer.

        Saved: params, KV cache, last logits (the ``arrays.npz``
        payload) plus the request table, emitted tokens, counters and
        health ledger (the manifest extras).  Returns the snapshotted
        decode step, or None when there is nothing live to snapshot or
        the save failed — a snapshot failure (disk full, injected
        ``snapshot.save``/``ckpt.write`` fault) degrades the recovery
        point, it never takes down serving.
        """
        if self.snapshots is None or self._live is None:
            return None
        reqs, cache, logits, step, greedy, seed = self._live
        try:
            health.maybe_inject("snapshot.save")
            extras = {
                "step": step, "greedy": bool(greedy), "seed": int(seed),
                "rids": [r.rid for r in reqs],
                "requests": [{
                    "rid": r.rid, "state": r.state.value,
                    "prompt": [int(t) for t in r.prompt],
                    "max_new_tokens": r.max_new_tokens,
                    "deadline_s": r.deadline_s,
                    "out_tokens": list(r.out_tokens),
                    "error": r.error,
                } for r in reqs],
                "counters": dict(self._counters),
                "health_events": [[e.kind, e.site, e.step, e.detail]
                                  for e in self.monitor.events],
            }
            self.snapshots.save(
                step,
                {"params": self.params, "cache": cache,
                 "logits": {"arr": logits}},
                extras=extras, blocking=True)
        except (CheckpointError, OSError, health.SimulatedFailure) as e:
            self._counters["snapshot_errors"] += 1
            self.monitor.note("snapshot-error", site="snapshot.save",
                              step=step,
                              detail=f"{type(e).__name__}: {e}")
            return None
        self._counters["snapshots_saved"] += 1
        if self.journal is not None:
            self.journal.append("snapshot", fsync=True, step=step)
        return step

    def restore(self, devices: Optional[Sequence] = None) -> List[Request]:
        """Rebuild journaled requests after a crash; arm the resume.

        Returns every journaled request, in rid order: requests that
        reached a durable terminal state come back exactly as they
        ended (tokens included — nothing lost, nothing duplicated);
        in-flight requests come back re-admitted at their exact decode
        position, ready for the next ``serve()`` call to finish.

        Recovery sources, best to worst: the newest intact snapshot
        (corrupt or fault-injected ones fall back to older steps —
        ``stats()['restore_fallbacks']``), else journal-only cold
        replay (re-prefill + deterministic re-decode).  With
        ``devices`` given, snapshot state is restored through
        ``elastic.plan_remesh`` target shardings, so a restart that
        lost devices recovers onto the surviving mesh.
        """
        if self.journal is None:
            raise ValueError(
                "restore() needs a journal: construct the Engine with "
                "journal_dir= or set REPRO_JOURNAL_DIR")
        records = self.journal.scan()
        table = journal_lib.replay_table(records)
        to_state = {s.value: s for s in RequestState}
        reqs: Dict[int, Request] = {}
        for rid in sorted(table):
            row = table[rid]
            r = Request(prompt=np.asarray(row["prompt"], np.int32),
                        max_new_tokens=row["max_new_tokens"],
                        deadline_s=row["deadline_s"], rid=rid,
                        state=to_state[row["state"]])
            r.out_tokens = list(row["tokens"])
            r.error = row["error"]
            reqs[rid] = r
        if reqs:
            self._next_rid = max(self._next_rid, max(reqs) + 1)

        snap = None
        if self.snapshots is not None:
            for snap_step in reversed(self.snapshots.steps()):
                try:
                    health.maybe_inject("engine.restore")
                    snap = self._load_snapshot(snap_step, devices)
                    break
                except Exception as e:
                    # corrupt snapshot (torn npz/manifest) or injected
                    # fault: quarantine-in-place and fall back — first
                    # to an older snapshot, then to cold replay
                    self._counters["restore_fallbacks"] += 1
                    self.monitor.note(
                        "restore-fallback", site="engine.restore",
                        step=snap_step,
                        detail=f"{type(e).__name__}: {e}")
                    snap = None

        if snap is not None:
            self._arm_snapshot_resume(snap, reqs)
        else:
            self._arm_cold_resume(records, reqs)
        out = [reqs[rid] for rid in sorted(reqs)]
        recovered = [r for r in out if not _terminal(r.state)]
        self._counters["recovered"] += len(recovered)
        self.monitor.note(
            "restore", site="engine.restore",
            detail=f"{len(out)} journaled requests, "
                   f"{len(recovered)} in flight, "
                   f"{'warm' if snap is not None else 'cold'} resume")
        return out

    def _load_snapshot(self, step: int, devices: Optional[Sequence]):
        """Load one snapshot step; raises on any corruption."""
        man = self.snapshots.manifest(step)
        templates = {
            "params": jax.eval_shape(lambda: self.params),
            "cache": {k: 0 for k in man["trees"]["cache"]},
            "logits": {"arr": 0},
        }
        shardings = None
        if devices is not None:
            cache_shape = {
                k: jax.ShapeDtypeStruct(tuple(m["shape"]),
                                        jnp.dtype(m["dtype"]))
                for k, m in man["trees"]["cache"].items()
            }
            plan = elastic.plan_remesh(
                list(devices), templates["params"],
                cache_shape=cache_shape)
            shardings = {"params": plan.param_shardings,
                         "cache": plan.cache_shardings}
        _, state, extras = self.snapshots.restore(
            templates, shardings, step=step)
        return state, extras

    def _arm_snapshot_resume(self, snap, reqs: Dict[int, Request]) -> None:
        """Warm restart: requests re-admitted at the snapshot step."""
        state, extras = snap
        self.params = state["params"]
        step = int(extras["step"])
        snap_reqs = {sr["rid"]: sr for sr in extras.get("requests", [])}
        batch: List[Request] = []
        for rid in extras["rids"]:
            sr = snap_reqs.get(rid, {})
            r = reqs.get(rid)
            if r is None and sr:
                # journal lost the submit record (corruption) — the
                # snapshot's request table is the second source of truth
                r = Request(prompt=np.asarray(sr["prompt"], np.int32),
                            max_new_tokens=sr["max_new_tokens"],
                            deadline_s=sr.get("deadline_s"), rid=rid,
                            state=to_state_safe(sr.get("state")))
                r.out_tokens = list(sr.get("out_tokens", []))
                r.error = sr.get("error")
                reqs[rid] = r
            if r is None:
                raise CheckpointError(
                    f"snapshot step {step} names rid {rid} known to "
                    f"neither journal nor snapshot request table")
            snap_state = to_state_safe(sr.get("state")) if sr else None
            if _terminal(r.state):
                pass                     # journal terminal: authoritative
            elif snap_state is not None and _terminal(snap_state):
                # journal lost the terminal record but the snapshot has
                # it — adopt the snapshot's final word
                r.state = snap_state
                r.out_tokens = list(sr.get("out_tokens", r.out_tokens))
                r.error = sr.get("error", r.error)
            else:
                # journal may be ahead of the snapshot (tokens emitted
                # after the save): keep them as the replay expectation,
                # rewind the live position to the snapshot's
                if len(r.out_tokens) > step:
                    self._replay_expected[rid] = list(r.out_tokens)
                out = sr.get("out_tokens")
                r.out_tokens = (list(out) if out is not None
                                else r.out_tokens[:step])
                self._counters["replayed_steps"] += max(
                    0, len(self._replay_expected.get(rid, []))
                    - len(r.out_tokens))
                r.state = RequestState.DECODING
            batch.append(r)
        for k, v in extras.get("counters", {}).items():
            if k in self._counters:
                self._counters[k] = max(self._counters[k], int(v))
        for kind, site, estep, detail in extras.get("health_events", []):
            self.monitor.events.append(health.HealthEvent(
                kind=kind, site=site, step=estep, detail=detail))
        self._pending_resume = {
            "reqs": batch,
            "cache": state["cache"],
            "logits": state["logits"]["arr"],
            "step": step,
            "greedy": bool(extras["greedy"]),
            "seed": int(extras["seed"]),
        }

    def _arm_cold_resume(self, records: List[dict],
                         reqs: Dict[int, Request]) -> None:
        """No usable snapshot: replay in-flight requests from prefill.

        Greedy decode is a pure function of params + journaled prompt,
        so rewinding to QUEUED and re-serving reproduces the lost
        tokens bit-exactly; the journaled prefix is kept as the replay
        expectation and verified after the resumed serve.
        """
        serves = [rec for rec in records if rec.get("kind") == "serve"]
        if not serves:
            return                      # crash before any serve: QUEUED
        last = serves[-1]
        batch = []
        for rid in last.get("rids", []):
            r = reqs.get(rid)
            if r is None or _terminal(r.state):
                continue
            if r.out_tokens:
                self._replay_expected[rid] = list(r.out_tokens)
                self._counters["replayed_steps"] += len(r.out_tokens)
            r.out_tokens = []
            r.state = RequestState.QUEUED
            batch.append(r)
        if batch:
            self._pending_resume = {
                "reqs": batch, "cache": None, "logits": None, "step": 0,
                "greedy": bool(last.get("greedy", True)),
                "seed": int(last.get("seed", 0)),
                "mode": last.get("mode", "batch"),
            }

    def _take_resume(self, requests: Sequence[Request]):
        """Pop the armed resume iff its batch is inside ``requests``."""
        if self._pending_resume is None:
            return None
        given = {id(r) for r in requests}
        if all(id(r) in given for r in self._pending_resume["reqs"]):
            pending, self._pending_resume = self._pending_resume, None
            return pending
        return None

    def _check_replay(self, requests: Sequence[Request]) -> None:
        """Verify re-decoded tokens against the pre-crash journal.

        Determinism makes the replayed prefix bit-identical; a
        divergence means corrupted state (bad snapshot, bit-flipped
        journal record, changed params) and is ledgered loudly — the
        recomputed tokens win, since they came from the live model.
        """
        for r in requests:
            exp = self._replay_expected.pop(r.rid, None)
            if exp is None:
                continue
            n = min(len(exp), len(r.out_tokens))
            if r.out_tokens[:n] != exp[:n]:
                self._counters["replay_divergence"] += 1
                self.monitor.note(
                    "replay-divergence", site="engine.restore",
                    detail=f"rid {r.rid}: journaled {exp[:n]} vs "
                           f"replayed {r.out_tokens[:n]}")

    def stats(self) -> Dict[str, object]:
        """Admission/backpressure counters merged with the health
        ledger rollup (``HealthMonitor.report``) and, when configured,
        the journal/snapshot durability counters."""
        out: Dict[str, object] = dict(self._counters)
        out["demoted_now"] = self.policy.demoted
        out["probes"] = self.policy.probes
        out["health"] = self.monitor.report()
        sched = self.scheduler_report()
        if sched is not None:
            out["scheduler"] = sched
        if self.journal is not None:
            out["journal"] = self.journal.stats()
        if self.snapshots is not None:
            out["snapshots"] = self.snapshots.stats()
        return out

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 greedy: bool = True, seed: int = 0) -> np.ndarray:
        """prompts: (B, S) equal-length int32. Returns (B, new) tokens.

        .. deprecated:: PR 8
           ``generate`` is a back-compat shim over ``submit`` +
           ``drain``; use ``submit()`` and stream the returned
           ``RequestHandle`` (``handle.tokens()`` / ``handle.result()``)
           or batch with ``serve()``/``drain()`` directly.

        Raises ``StepFailed`` on any request that does not finish DONE.
        """
        warnings.warn(
            "Engine.generate() is deprecated; use Engine.submit() and "
            "stream the RequestHandle (tokens()/result()), or "
            "serve()/drain() for batches",
            DeprecationWarning, stacklevel=2)
        prompts = np.asarray(prompts)
        reqs = [self.submit(p, max_new_tokens) for p in prompts]
        self.drain(greedy=greedy, seed=seed)
        bad = [r for r in reqs if r.state != RequestState.DONE]
        if bad:
            r = bad[0]
            raise StepFailed(
                f"request {r.rid} ended {r.state.value}: {r.error}")
        return np.stack(
            [np.asarray(r.out_tokens, np.int32) for r in reqs])
