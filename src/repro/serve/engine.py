"""Serving engine: batched prefill + decode with a request lifecycle.

``make_serve_step`` builds the one-token decode function the dry-run
lowers for the decode_32k / long_500k cells; ``Engine`` batches
requests, prefills, and streams tokens — now behind a fault-tolerant
request lifecycle:

    QUEUED -> PREFILLING -> DECODING -> {DONE, FAILED, EVICTED}

``submit`` is the admission gate: it validates prompts (empty, over
``max_len``, non-integer dtype -> ``ValueError``) and rejects requests
whose decode-step attention footprint cannot fit the hardware's VMEM
under *any* dataflow the explorer can enumerate (``AdmissionError``).
``serve`` drives admitted requests through prefill and the decode loop;
every step runs under ``_execute``:

  * the ``serve.prefill`` / ``serve.decode_step`` fault-injection sites
    (``runtime.health.maybe_inject``) fire here, so drills exercise the
    exact retry path real failures take;
  * a non-finite sentinel checks the step's logits on the host — a NaN
    or Inf (bad kernel output, injected ``nan`` fault) counts as a step
    failure just like a raised lowering error;
  * on failure the ``DegradationPolicy`` demotes to the ``backend=
    "xla"`` escape hatch (``layers.forced_backend``) and the step is
    retried with exponential backoff against the *pre-step* cache —
    JAX's functional caches make commit-after-validate free, so a
    poisoned step never contaminates later tokens;
  * after ``cooldown_steps`` the policy re-probes the primary path.

Per-request deadlines evict slow requests (EVICTED) instead of stalling
the batch; ``max_new_tokens`` budgets are clamped to the cache capacity
(``max_len``).  ``stats()`` reports admission/backpressure counters next
to the ``HealthMonitor`` ledger, so demotions, retries, stragglers and
injected faults surface in one place.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotune, cost_model, explorer
from repro.models import layers, lm
from repro.runtime import health


def make_serve_step(cfg, dist: Optional[lm.Dist] = None,
                    unroll: int = 1) -> Callable:
    """decode one token for the whole batch.

    serve_step(params, cache, tokens (B,1)) -> (logits (B,V), cache)
    """

    def serve_step(params, cache, tokens):
        return lm.decode_step(params, cache, tokens, cfg, dist=dist,
                              unroll=unroll)

    return serve_step


def make_prefill_fn(cfg, dist: Optional[lm.Dist] = None) -> Callable:
    def prefill_fn(params, tokens, enc_frames=None):
        return lm.prefill(params, tokens, cfg, max_len=None,
                          enc_frames=enc_frames, dist=dist)

    return prefill_fn


class RequestState(str, enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    DONE = "done"
    FAILED = "failed"
    EVICTED = "evicted"


class AdmissionError(ValueError):
    """Request rejected at admission (resource infeasibility)."""


class StepFailed(RuntimeError):
    """A prefill/decode step failed on both kernel paths, retries
    exhausted — the requests it was serving transition to FAILED."""


class NonFiniteLogits(RuntimeError):
    """The post-step sentinel saw NaN/Inf logits."""


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int
    deadline_s: Optional[float] = None   # wall-clock budget from serve start
    rid: int = -1
    state: RequestState = RequestState.QUEUED
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    error: Optional[str] = None
    degraded_steps: int = 0       # decode steps served on the XLA path


class Engine:
    """Batched serving loop with admission, degradation and retries.

    Batches requests of equal prompt length (uniform-position cache),
    prefills once, then steps the decode function; used by
    examples/serve_batch.py.  ``generate`` keeps the original
    prompts-in/tokens-out contract on top of ``submit`` + ``serve``.

    ``hw`` is the admission-control hardware model (VMEM feasibility of
    the decode-step attention); tests pass a tiny ``HardwareSpec`` to
    force rejections.  ``policy``/``monitor`` own degradation state and
    the health ledger; callers may share one monitor across engines.
    """

    def __init__(self, cfg, params, max_len: int = 2048,
                 dist: Optional[lm.Dist] = None,
                 monitor: Optional[health.HealthMonitor] = None,
                 policy: Optional[health.DegradationPolicy] = None,
                 hw: cost_model.HardwareSpec = cost_model.V5E,
                 validate_outputs: bool = True):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.dist = dist
        self.hw = hw
        self.validate_outputs = validate_outputs
        self.monitor = monitor if monitor is not None else health.HealthMonitor()
        self.policy = policy if policy is not None else health.DegradationPolicy()
        self._decode = jax.jit(make_serve_step(cfg, dist))
        self._prefill = jax.jit(
            lambda p, t: lm.prefill(p, t, cfg, max_len=max_len, dist=dist)
        )

        # Degraded twins: same computation forced through the XLA escape
        # hatch.  The context manager must be live while the function
        # body *traces*, so it wraps the body inside the jitted callee
        # rather than the jit() call.
        def _decode_xla(params, cache, tokens):
            with layers.forced_backend("xla"):
                return lm.decode_step(params, cache, tokens, cfg, dist=dist)

        def _prefill_xla(params, tokens):
            with layers.forced_backend("xla"):
                return lm.prefill(params, tokens, cfg, max_len=max_len,
                                  dist=dist)

        self._decode_degraded = jax.jit(_decode_xla)
        self._prefill_degraded = jax.jit(_prefill_xla)
        self._warmed = set()
        self._next_rid = 0
        self._admission_cache: Dict[int, bool] = {}   # seq len -> feasible
        self._counters: Dict[str, int] = {
            "submitted": 0, "admitted": 0, "rejected": 0,
            "completed": 0, "failed": 0, "evicted": 0,
            "retries": 0, "demotions": 0, "degraded_steps": 0,
            "budget_clamped": 0,
        }

    # ------------------------------------------------------------------
    # Admission.
    # ------------------------------------------------------------------
    def _attention_feasible(self, seq: int) -> bool:
        """Can every attention workload this request implies be realized
        under ``self.hw``'s VMEM by at least one explorer candidate?"""
        if seq in self._admission_cache:
            return self._admission_cache[seq]
        ok = True
        for p in lm.hot_attention_problems(self.cfg, 1, max(seq, 1),
                                           self.max_len):
            if not explorer.enumerate_attention_candidates(p, self.hw):
                ok = False
                break
        self._admission_cache[seq] = ok
        return ok

    def _reject(self, reason: str, exc_type=ValueError) -> None:
        self._counters["rejected"] += 1
        self.monitor.note("admission-reject", site="serve.submit",
                          detail=reason)
        raise exc_type(reason)

    def submit(self, prompt, max_new_tokens: int,
               deadline_s: Optional[float] = None) -> Request:
        """Validate and admit one request (state QUEUED), or raise.

        ``ValueError`` for malformed input (empty / over-``max_len`` /
        non-integer prompt, non-positive budget); ``AdmissionError``
        (a ``ValueError`` subclass) when the decode-step attention
        cannot fit the hardware's VMEM under any dataflow.
        """
        self._counters["submitted"] += 1
        prompt = np.asarray(prompt)
        if prompt.ndim != 1:
            self._reject(f"prompt must be rank-1 (one request), got "
                         f"shape {prompt.shape}")
        if prompt.size == 0:
            self._reject("empty prompt: need at least one token")
        if not np.issubdtype(prompt.dtype, np.integer):
            self._reject(f"prompt dtype must be integer token ids, got "
                         f"{prompt.dtype}")
        plen = int(prompt.shape[0])
        if plen >= self.max_len:
            self._reject(
                f"prompt length {plen} leaves no decode room under "
                f"max_len={self.max_len}")
        if max_new_tokens < 1:
            self._reject(f"max_new_tokens must be >= 1, got "
                         f"{max_new_tokens}")
        if not self._attention_feasible(plen):
            self._reject(
                f"no VMEM-feasible attention dataflow for prompt length "
                f"{plen} / max_len={self.max_len} on {self.hw.name} "
                f"({self.hw.vmem_bytes} bytes VMEM)", AdmissionError)
        budget = min(max_new_tokens, self.max_len - plen)
        if budget < max_new_tokens:
            self._counters["budget_clamped"] += 1
            self.monitor.note(
                "backpressure", site="serve.submit",
                detail=f"budget clamped {max_new_tokens} -> {budget} "
                       f"(cache capacity max_len={self.max_len})")
        self._counters["admitted"] += 1
        req = Request(prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=budget, deadline_s=deadline_s,
                      rid=self._next_rid)
        self._next_rid += 1
        return req

    # ------------------------------------------------------------------
    # Guarded step execution: inject -> run -> sentinel -> retry/demote.
    # ------------------------------------------------------------------
    def _execute(self, site: str, step: int, primary: Callable,
                 degraded: Callable) -> Tuple[Any, Any, str]:
        """Run one engine step fault-tolerantly.

        Picks the kernel path from the DegradationPolicy, fires the
        injection site, validates logits finiteness, and on any failure
        demotes + retries with backoff.  Returns (logits, cache, path).
        Raises ``StepFailed`` when retries are exhausted.
        """
        attempt = 0
        while True:
            path = self.policy.backend_for(step, self.monitor)
            fn = primary if path == "primary" else degraded
            try:
                fault = health.maybe_inject(site)
                logits, cache = fn()
                if fault == "nan":
                    logits = logits * jnp.asarray(jnp.nan, logits.dtype)
                if self.validate_outputs and not bool(
                        jnp.all(jnp.isfinite(logits))):
                    raise NonFiniteLogits(
                        f"non-finite logits from {site} step {step} "
                        f"({path} path)")
                return logits, cache, path
            except Exception as e:
                # SimulatedFailure, NonFiniteLogits, kernel lowering /
                # interpret errors — anything a bad step can surface.
                failure = e
            self.policy.on_failure(site, step, failure, self.monitor)
            self._counters["demotions"] += 1
            attempt += 1
            if attempt > self.policy.max_retries:
                raise StepFailed(
                    f"{site} step {step} failed after "
                    f"{self.policy.max_retries} retries: "
                    f"{type(failure).__name__}: {failure}") from failure
            self._counters["retries"] += 1
            self.monitor.note("retry", site=site, step=step,
                              detail=f"attempt {attempt} after "
                                     f"{type(failure).__name__}")
            time.sleep(self.policy.backoff_seconds(attempt - 1))

    # ------------------------------------------------------------------
    # Serving.
    # ------------------------------------------------------------------
    def _warm_autotune(self, batch: int, seq: int) -> None:
        """Populate the dataflow-spec cache for this request shape so the
        prefill and decode traces hit memoized specs instead of
        enumerating the explorer's candidate space.  Covers the hot GEMM
        shapes, the attention shapes the model actually serves — the
        prefill square, the ``sq=1``/``skv=max_len`` cached-decode step
        (traced valid length, keyed as the worst case), plus the
        windowed variants of both for sliding-window configs and int8
        KV-cache decode keys (``lm.hot_attention_problems``) — and, for
        configs with a conv frontend (audio family), the frontend's
        ``ConvProblem`` shapes — today the whisper frontend is stubbed
        (precomputed frame embeddings), so the conv warm-up is cheap
        forward-keying for when the real frontend lands on
        ``ops.conv2d_fused``.  ``binary_mlp`` configs additionally warm
        their prefill and decode ``BinaryProblem`` shapes.  Only runs
        when the model will actually take the Pallas kernel path."""
        if not (getattr(self.cfg, "use_pallas_kernels", False)
                and jax.default_backend() == "tpu"):
            return
        key = (batch, seq)
        if key in self._warmed:
            return
        self._warmed.add(key)
        autotune.warm(lm.hot_gemm_problems(self.cfg, batch, seq)
                      + lm.hot_gemm_problems(self.cfg, batch, 1)
                      + lm.hot_attention_problems(self.cfg, batch, seq,
                                                  self.max_len)
                      + lm.hot_conv_problems(self.cfg, batch, seq)
                      + lm.hot_binary_problems(self.cfg, batch, seq)
                      + lm.hot_binary_problems(self.cfg, batch, 1))

    def serve(self, requests: Sequence[Request], greedy: bool = True,
              seed: int = 0) -> List[Request]:
        """Drive a batch of QUEUED requests to a terminal state.

        Requests must share one prompt length (uniform-position cache).
        Terminal states: DONE (budget reached), EVICTED (deadline),
        FAILED (step failed beyond retries).  Returns the same request
        objects for convenience.
        """
        reqs = [r for r in requests if r.state == RequestState.QUEUED]
        if not reqs:
            return list(requests)
        lens = {int(r.prompt.shape[0]) for r in reqs}
        if len(lens) != 1:
            raise ValueError(
                f"batch must share one prompt length, got {sorted(lens)}")
        prompts = np.stack([r.prompt for r in reqs]).astype(np.int32)
        self._warm_autotune(prompts.shape[0], prompts.shape[1])
        t_start = time.monotonic()

        for r in reqs:
            r.state = RequestState.PREFILLING
        dev_prompts = jnp.asarray(prompts)
        try:
            logits, cache, path = self._execute(
                "serve.prefill", 0,
                lambda: self._prefill(self.params, dev_prompts),
                lambda: self._prefill_degraded(self.params, dev_prompts))
        except StepFailed as e:
            self._fail_batch(reqs, e)
            return list(requests)
        if path == "degraded":
            self._counters["degraded_steps"] += 1

        for r in reqs:
            r.state = RequestState.DECODING
        key = jax.random.PRNGKey(seed)
        step = 0
        while True:
            active = [r for r in reqs if r.state == RequestState.DECODING]
            if not active:
                break
            now = time.monotonic()
            for r in active:
                if (r.deadline_s is not None
                        and now - t_start > r.deadline_s):
                    r.state = RequestState.EVICTED
                    r.error = (f"deadline {r.deadline_s:.3f}s exceeded "
                               f"after {len(r.out_tokens)} tokens")
                    self._counters["evicted"] += 1
                    self.monitor.note("evicted", site="serve.decode_step",
                                      step=step, detail=r.error)
            active = [r for r in reqs if r.state == RequestState.DECODING]
            if not active:
                break

            if greedy:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits).astype(jnp.int32)
            tok_np = np.asarray(tok)
            for i, r in enumerate(reqs):
                if r.state == RequestState.DECODING:
                    r.out_tokens.append(int(tok_np[i]))
                    if len(r.out_tokens) >= r.max_new_tokens:
                        r.state = RequestState.DONE
                        self._counters["completed"] += 1
            if not any(r.state == RequestState.DECODING for r in reqs):
                break

            step += 1
            t0 = time.monotonic()
            try:
                logits, cache, path = self._execute(
                    "serve.decode_step", step,
                    lambda: self._decode(self.params, cache, tok[:, None]),
                    lambda: self._decode_degraded(self.params, cache,
                                                  tok[:, None]))
            except StepFailed as e:
                self._fail_batch(reqs, e)
                break
            if path == "degraded":
                self._counters["degraded_steps"] += 1
                for r in reqs:
                    if r.state == RequestState.DECODING:
                        r.degraded_steps += 1
            self.monitor.record(step, time.monotonic() - t0)
        return list(requests)

    def _fail_batch(self, reqs: List[Request], err: BaseException) -> None:
        for r in reqs:
            if r.state in (RequestState.PREFILLING, RequestState.DECODING):
                r.state = RequestState.FAILED
                r.error = str(err)
                self._counters["failed"] += 1

    def stats(self) -> Dict[str, object]:
        """Admission/backpressure counters merged with the health
        ledger rollup (``HealthMonitor.report``)."""
        out: Dict[str, object] = dict(self._counters)
        out["demoted_now"] = self.policy.demoted
        out["probes"] = self.policy.probes
        out["health"] = self.monitor.report()
        return out

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 greedy: bool = True, seed: int = 0) -> np.ndarray:
        """prompts: (B, S) equal-length int32. Returns (B, new) tokens.

        Back-compat wrapper over submit/serve: raises on any request
        that does not finish DONE."""
        prompts = np.asarray(prompts)
        reqs = [self.submit(p, max_new_tokens) for p in prompts]
        self.serve(reqs, greedy=greedy, seed=seed)
        bad = [r for r in reqs if r.state != RequestState.DONE]
        if bad:
            r = bad[0]
            raise StepFailed(
                f"request {r.rid} ended {r.state.value}: {r.error}")
        return np.stack(
            [np.asarray(r.out_tokens, np.int32) for r in reqs])
