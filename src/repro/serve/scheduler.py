"""Continuous-batching scheduler: per-step admit / prefill / decode.

The batch-synchronous ``Engine.serve`` loop admits one equal-length
batch, prefills it once, and decodes until the *last* request finishes
— short requests ride along as dead rows and a new request waits for
the whole batch to drain.  ``ContinuousScheduler`` replaces that with
a slot machine over the ragged cache the PR-8 kernels understand:

  * each ``step()`` admits at most one waiting request into a free
    slot (whole-prompt prefill, or one chunk of a long prompt when
    ``prefill_chunk`` is set — chunked prefill interleaves with decode
    so running requests never stall behind a long prompt), then runs
    one vectorized decode step for every occupied slot;
  * requests finish (DONE / EVICTED / FAILED) individually: their slot
    frees immediately and the next waiting request takes it on the
    following step — no batch barrier;
  * for paged-decode-capable configs the page pool IS the decode
    datapath (PR-10 tentpole): each admitted prompt's KV is scattered
    into refcounted pages, full-page prefixes are shared across
    requests (``lookup_prefix``), and every decode step runs
    ``lm.paged_decode_step`` -> ``ops.paged_attention`` straight off
    the block tables — no contiguous slot cache exists, so pool
    occupancy is the true capacity signal.  Configs the paged step
    cannot express (SSM state, encoder-decoder, int8 KV, per-layer
    traced windows, ``max_len`` not page-aligned) keep the PR-8
    contiguous slot cache with best-effort page mirroring.

Memory pressure (the PR-10 tentpole) is handled by an explicit ladder,
coarse to fine:

  1. **watermark backpressure** — admission defers (the request stays
     QUEUED with ``queue_reason`` set, a ``backpressure`` counter and
     ledger event; never a silent fallback) while other requests hold
     pages and the pool is above ``high_watermark``, or when the
     prompt's pages cannot be allocated;
  2. **host spill** — when a decoding row cannot grow by one page, the
     coldest *other* active request (LRU by last decode step, ties to
     the youngest rid) is spilled: its private pages move to host
     numpy buffers (shared prefix pages stay pinned via the refcount),
     its slot frees, and it parks in ``paused``;
  3. **preemption** — if spilling cannot free a page, the youngest
     request holding pool memory is preempted: pages released, a
     fsync'd ``preempt`` record journaled, tokens stashed as replay
     expectations, and the request re-enqueued QUEUED.  Greedy (and
     position-keyed sampled) decode is deterministic, so the recompute
     regenerates bit-identical tokens — verified for free by the
     engine's ``replay_divergence`` check.

Spilled requests resume (``unspill`` round-trip, bit-exact) once a
slot is free and the pool is back below ``low_watermark`` (or idle);
they have priority over new admissions, and no request is ever
silently dropped from the paged path.

Determinism contract (what the ragged crash drill pins): admission
order is the enqueue order (rid order under ``Engine.drain``), slots
are assigned lowest-free-first, prefill uses the engine's own jitted
functions, and the ladder's victim choices are keyed on step counts
and rids only — so a cold journal replay that re-enqueues the same
rids walks the identical slot/batch/pressure evolution and
regenerates bit-identical greedy tokens.

Faults route through ``Engine._execute`` under the same
``serve.prefill`` / ``serve.decode_step`` injection sites as the
batch-synchronous loop, and the pool adds ``pool.alloc`` (simulated
OOM -> drives the ladder) and ``pool.spill`` (mid-spill crash drill),
so every registered drill exercises this loop unchanged.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers, lm
from repro.runtime import health
from repro.serve.paged_cache import PagedKVCache, pages_for


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling settings for the handle/stream API."""
    max_new_tokens: int = 16
    greedy: bool = True
    seed: int = 0
    deadline_s: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Continuous-batching knobs.

    ``max_batch``     decode slots (cache rows) — fixed, so the decode
                      trace never re-specializes as requests come/go.
    ``prefill_chunk`` 0 prefills whole prompts in one shot (and reuses
                      the engine's jitted prefill — bit-identical to
                      the batch-sync loop); >0 streams prompts longer
                      than the chunk through ``lm.prefill_chunk`` one
                      chunk per step, interleaved with decode.
    ``page_size`` / ``n_pages`` size the shared ``PagedKVCache``;
                      ``n_pages=0`` sizes it to hold ``max_batch`` full
                      ``max_len`` rows.  ``page_size=0`` disables
                      paging (slot cache only).
    ``prefix_reuse``  share full-page common prefixes across requests.
    ``high_watermark`` / ``low_watermark``
                      pool-occupancy hysteresis band: admission defers
                      above high, spilled requests resume below low.
    """
    max_batch: int = 4
    prefill_chunk: int = 0
    page_size: int = 16
    n_pages: int = 0
    prefix_reuse: bool = True
    high_watermark: float = 0.90
    low_watermark: float = 0.60


def paged_decode_enabled(cfg, sc: Optional[SchedulerConfig],
                         max_len: int) -> bool:
    """Would a scheduler built from ``sc`` route decode through the
    page pool for this config?  (Mirrors ``ContinuousScheduler``'s own
    gate; the engine uses it for admission-time capacity checks.)"""
    sc = sc or SchedulerConfig()
    return bool(
        sc.page_size
        and getattr(cfg, "has_attention", True)
        and getattr(cfg, "kv_cache_dtype", "auto") != "int8"
        and lm.supports_paged_decode(cfg)
        and max_len % sc.page_size == 0)


def pool_capacity(sc: Optional[SchedulerConfig], max_len: int) -> int:
    """Total pages the scheduler's pool will hold."""
    sc = sc or SchedulerConfig()
    return sc.n_pages or sc.max_batch * pages_for(max_len, sc.page_size)


class ContinuousScheduler:
    """Slot-based continuous batching over one ``Engine``.

    The scheduler borrows the engine's jitted prefill/decode functions,
    degradation policy, journal and counters; it owns the waiting
    queue, the slot table, the page pool (or the ragged slot cache for
    non-paged configs), and the spill/preempt pressure ladder.
    """

    def __init__(self, engine, config: Optional[SchedulerConfig] = None):
        from repro.serve import engine as engine_mod   # circular-safe
        self._E = engine_mod
        self.eng = engine
        self.cc = config or SchedulerConfig()
        if self.cc.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got "
                             f"{self.cc.max_batch}")
        self.waiting: deque = deque()
        self.slots: List[Optional[Any]] = [None] * self.cc.max_batch
        self.cache = None                      # ragged slot cache
        self.last_tok = np.zeros(self.cc.max_batch, np.int64)
        self.kv_lens = np.zeros(self.cc.max_batch, np.int64)
        self.step_count = 0
        self.greedy = True
        self.seed = 0
        self.t_start: Dict[int, float] = {}
        self.req_pages: Dict[int, List[int]] = {}
        self.last_step: Dict[int, int] = {}    # rid -> last decode step
        self.paused: List[int] = []            # spilled rids, spill order
        self.spilled: Dict[int, Tuple[Any, int, List[Tuple]]] = {}
        self.paged: Optional[PagedKVCache] = None
        self._pf: Optional[Tuple] = None       # chunked prefill in flight
        self._chunk_fns: Dict[int, Tuple] = {} # chunk len -> jitted pair
        self._paged_jit: Optional[Tuple] = None
        cfg = engine.cfg
        if self.cc.page_size and getattr(cfg, "has_attention", True) \
                and getattr(cfg, "kv_cache_dtype", "auto") != "int8":
            n_pages = self.cc.n_pages or (
                self.cc.max_batch
                * pages_for(engine.max_len, self.cc.page_size))
            self.paged = PagedKVCache(
                cfg, n_pages, self.cc.page_size, dtype=cfg.act_dtype,
                high_watermark=self.cc.high_watermark,
                low_watermark=self.cc.low_watermark)
        self.use_paged = bool(
            self.paged is not None and lm.supports_paged_decode(cfg)
            and engine.max_len % self.cc.page_size == 0)
        self.max_pages = (engine.max_len // self.cc.page_size
                          if self.use_paged else 0)

    # ------------------------------------------------------------------
    # Queue.
    # ------------------------------------------------------------------
    def enqueue(self, req) -> None:
        self.waiting.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self._pf is not None or self.paused
                    or any(r is not None for r in self.slots))

    def inflight(self) -> List[Any]:
        """Every request the scheduler currently owns (queued, mid-
        prefill, decoding, or spilled to host)."""
        out = [r for r in self.waiting]
        if self._pf is not None:
            out.append(self._pf[0])
        out.extend(r for r in self.slots if r is not None)
        out.extend(self.spilled[rid][0] for rid in self.paused)
        return out

    # ------------------------------------------------------------------
    # The step: admit (one prefill unit) then decode (all slots).
    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One scheduler tick; returns True if any work was done."""
        did = self._admit()
        did = self._decode() or did
        return did

    def drain(self, greedy: bool = True, seed: int = 0) -> None:
        """Step until every owned request is terminal.

        A tick that makes no progress while requests are still in
        flight is a scheduler stall — a bug, not a state.  It is
        ledgered as a ``scheduler.stall`` HealthEvent and every
        stranded request is FAILED with the stall as its error, so
        nothing is ever silently left QUEUED forever.
        """
        self.greedy, self.seed = bool(greedy), int(seed)
        try:
            while self.has_work:
                if not self.step():
                    self._stall()
                    break
        finally:
            self.greedy, self.seed = True, 0

    def _stall(self) -> None:
        """No-progress tick with work owned: fail the stranded requests
        loudly instead of dropping them (satellite of PR 10)."""
        stranded = [r for r in self.inflight()
                    if not self._E._terminal(r.state)]
        detail = (f"no forward progress with {len(stranded)} request(s) "
                  f"in flight: rids {sorted(r.rid for r in stranded)}")
        self.eng.monitor.note("scheduler.stall", site="serve.drain",
                              step=self.step_count, detail=detail)
        err = RuntimeError(f"scheduler stalled: {detail}")
        if self._pf is not None and self._pf[3] and self.paged is not None:
            self.paged.release(self._pf[3])    # chunked-prefill reserve
        self._pf = None
        for r in stranded:
            self._fail(r, err)
        self.waiting.clear()
        for rid in list(self.paused):
            _, _, entries = self.spilled.pop(rid)
            self.paged.release(
                [e[1] for e in entries if e[0] == "resident"])
        self.paused = []
        for i, r in enumerate(self.slots):
            if r is not None:
                self._free_slot(i)

    # -- admission ------------------------------------------------------
    def _admit(self) -> bool:
        if self._pf is not None:
            return self._advance_chunked()
        did = self._try_resume()
        if self.paused:
            # spilled requests resume before anyone new is admitted:
            # admitting into the pool they are waiting on would thrash
            return did
        while self.waiting:
            free = [i for i, r in enumerate(self.slots) if r is None]
            if not free:
                return did
            req = self.waiting[0]
            if req.state != self._E.RequestState.QUEUED:
                self.waiting.popleft()
                continue                   # served elsewhere / stale
            plen = int(req.prompt.shape[0])
            chunked = bool(self.cc.prefill_chunk
                           and plen > self.cc.prefill_chunk)
            pages: Optional[List[int]] = None
            reuse: List[int] = []
            covered = 0
            if self.use_paged:
                # a request whose full KV reach exceeds the pool can
                # never complete: admitting it would livelock the
                # ladder (grow -> fail -> preempt -> recompute -> grow)
                reach = min(plen + req.max_new_tokens, self.eng.max_len)
                need_reach = pages_for(reach, self.cc.page_size)
                if need_reach > self.paged.n_pages:
                    self.waiting.popleft()
                    self._fail(req, RuntimeError(
                        f"page pool cannot hold request: kv reach "
                        f"{reach} needs {need_reach} pages, pool holds "
                        f"{self.paged.n_pages}"))
                    return True
                holders = bool(self.req_pages) or bool(self.spilled)
                if holders and self.paged.above_high():
                    self._defer(req, f"pool above high watermark "
                                     f"(occupancy "
                                     f"{self.paged.occupancy():.2f} >= "
                                     f"{self.paged.high_watermark:.2f})")
                    return did
                if not chunked and self.cc.prefix_reuse:
                    reuse, covered = self.paged.lookup_prefix(
                        np.asarray(req.prompt, np.int32))
                need = pages_for(plen, self.cc.page_size) - len(reuse)
                new = self.paged.alloc(need)
                if new is None:
                    if reuse:
                        self.paged.release(reuse)
                    if holders:
                        self._defer(req, f"page pool exhausted ({need} "
                                         f"pages needed, "
                                         f"{self.paged.free_pages} free)")
                        return did
                    self.waiting.popleft()
                    self._fail(req, RuntimeError(
                        f"page pool cannot hold prompt: {need} pages "
                        f"needed, pool holds {self.paged.n_pages}"))
                    return True
                pages = list(reuse) + new
            self.waiting.popleft()
            req.queue_reason = None
            self._ensure_cache()
            self.t_start.setdefault(req.rid, time.monotonic())
            self.eng._warm_autotune(1, plen)
            if chunked:
                self._pf = (req, None, 0, pages)
                return self._advance_chunked()
            return self._prefill_whole(req, free[0], pages=pages,
                                       reuse=reuse, covered=covered)
        return did

    def _defer(self, req, reason: str) -> None:
        """Backpressure: leave ``req`` QUEUED with an explicit reason —
        the never-silent half of the admission contract."""
        if getattr(req, "queue_reason", None) != reason:
            req.queue_reason = reason
            self.eng._counters["backpressure"] += 1
            self.eng.monitor.note(
                "backpressure", site="serve.admit", step=self.step_count,
                detail=f"rid {req.rid}: {reason}")

    def _try_resume(self) -> bool:
        """Un-spill the oldest paused request once a slot is free and
        the pool is below the low watermark (or nothing is active)."""
        if not self.paused:
            return False
        free = [i for i, r in enumerate(self.slots) if r is None]
        if not free:
            return False
        if any(r is not None for r in self.slots) \
                and not self.paged.below_low():
            return False
        rid = self.paused[0]
        req, kv_len, entries = self.spilled[rid]
        while True:
            pages = self.paged.unspill(entries)
            if pages is not None:
                break
            if self._preempt_youngest(exclude_rid=rid):
                continue
            # cannot make room even with everyone else gone: recompute
            # this request instead of round-tripping its pages
            self.paused.pop(0)
            del self.spilled[rid]
            self.paged.release(
                [e[1] for e in entries if e[0] == "resident"])
            self._requeue(req)
            return True
        self.paused.pop(0)
        del self.spilled[rid]
        slot = free[0]
        req.state = self._E.RequestState.DECODING
        self.slots[slot] = req
        self.req_pages[rid] = pages
        self.kv_lens[slot] = kv_len
        self.last_tok[slot] = req.out_tokens[-1]
        self.last_step[rid] = self.step_count
        self.eng._counters["unspills"] += 1
        self.eng.monitor.note(
            "unspill", site="serve.admit", step=self.step_count,
            detail=f"rid {rid}: {len(pages)} pages back on device at "
                   f"kv_len {kv_len}")
        return True

    def _ensure_cache(self) -> None:
        if self.use_paged:
            return                         # the pool IS the datapath
        if self.cache is None:
            self.cache = lm.init_cache(
                self.eng.cfg, self.cc.max_batch, self.eng.max_len,
                dtype=self.eng.cfg.act_dtype)
            self.cache["index"] = jnp.zeros((self.cc.max_batch,),
                                            jnp.int32)

    def _prefill_whole(self, req, slot: int,
                       pages: Optional[List[int]] = None,
                       reuse: Optional[List[int]] = None,
                       covered: int = 0) -> bool:
        """Single-shot prefill through the engine's own jitted function
        (B=1), then install the row into ``slot``.

        On the paged datapath ``pages`` (and the ``reuse``/``covered``
        prefix share) were acquired by ``_admit`` before the request
        left the queue — allocation failure surfaces as backpressure
        there, never as a silent fallback here."""
        RequestState = self._E.RequestState
        prompt = np.asarray(req.prompt, np.int32)
        plen = len(prompt)
        if pages is None:
            reuse, covered = [], 0
            if self.paged is not None and self.cc.prefix_reuse:
                reuse, covered = self.paged.lookup_prefix(prompt)
        req.state = RequestState.PREFILLING
        dev = jnp.asarray(prompt[None])
        try:
            if covered:
                logits, rcache = self._prefill_from_pages(
                    prompt, reuse, covered)
            else:
                logits, rcache, path = self.eng._execute(
                    "serve.prefill", self.step_count,
                    lambda: self.eng._prefill(self.eng.params, dev),
                    lambda: self.eng._prefill_degraded(self.eng.params,
                                                       dev))
                if path == "degraded":
                    self.eng._counters["degraded_steps"] += 1
        except self._E.StepFailed as e:
            self._fail(req, e)
            if pages is not None:
                self.paged.release(pages)
            elif reuse:
                self.paged.release(reuse)
            return True
        self._store_pages(req, prompt, reuse, covered, rcache,
                          pages=pages)
        self._install(req, slot, rcache, plen, logits[0])
        return True

    def _prefill_from_pages(self, prompt, reuse: List[int],
                            covered: int):
        """Seed a fresh cache row from reused prefix pages, then prefill
        only the uncovered tail via ``lm.prefill_chunk``."""
        kp, vp = self.paged.gather(reuse)     # (L, n_kv, covered.., Dh)
        rcache = lm.init_cache(self.eng.cfg, 1, self.eng.max_len,
                               dtype=self.eng.cfg.act_dtype)
        rcache["k"] = rcache["k"].at[:, 0, :, :covered].set(
            kp[:, :, :covered].astype(rcache["k"].dtype))
        rcache["v"] = rcache["v"].at[:, 0, :, :covered].set(
            vp[:, :, :covered].astype(rcache["v"].dtype))
        rcache["index"] = jnp.asarray(covered, jnp.int32)
        tail = jnp.asarray(np.asarray(prompt[covered:], np.int32)[None])
        primary, degraded = self._chunk_fn(int(tail.shape[1]))
        start = jnp.asarray(covered, jnp.int32)
        logits, rcache, path = self.eng._execute(
            "serve.prefill", self.step_count,
            lambda: primary(self.eng.params, rcache, tail, start),
            lambda: degraded(self.eng.params, rcache, tail, start))
        if path == "degraded":
            self.eng._counters["degraded_steps"] += 1
        return logits, rcache

    def _advance_chunked(self) -> bool:
        """Push one chunk of the in-flight long prompt; on the final
        chunk, install the finished row into a free slot.

        Deadlines are checked at every chunk boundary (satellite of
        PR 10): a prompt that blows its deadline mid-prefill is evicted
        there instead of burning the remaining chunks first."""
        RequestState = self._E.RequestState
        req, rcache, pos, pages = self._pf
        prompt = np.asarray(req.prompt, np.int32)
        plen = len(prompt)
        dl = req.deadline_s
        if dl is not None \
                and time.monotonic() - self.t_start[req.rid] > dl:
            self._pf = None
            if pages:
                self.paged.release(pages)
            req.state = RequestState.EVICTED
            req.error = (f"deadline {dl:.3f}s exceeded during chunked "
                         f"prefill at position {pos}/{plen}")
            self.eng._counters["evicted"] += 1
            self.eng.monitor.note("evicted", site="serve.prefill",
                                  step=self.step_count, detail=req.error)
            self.eng._journal_terminal(req, self.step_count)
            return True
        end = min(pos + self.cc.prefill_chunk, plen)
        toks = jnp.asarray(prompt[None, pos:end])
        req.state = RequestState.PREFILLING
        try:
            if rcache is None:
                rcache = lm.init_cache(self.eng.cfg, 1, self.eng.max_len,
                                       dtype=self.eng.cfg.act_dtype)
            primary, degraded = self._chunk_fn(int(toks.shape[1]))
            start = jnp.asarray(pos, jnp.int32)
            logits, rcache, path = self.eng._execute(
                "serve.prefill", self.step_count,
                lambda: primary(self.eng.params, rcache, toks, start),
                lambda: degraded(self.eng.params, rcache, toks, start))
            if path == "degraded":
                self.eng._counters["degraded_steps"] += 1
        except self._E.StepFailed as e:
            self._pf = None
            self._fail(req, e)
            if pages:
                self.paged.release(pages)
            return True
        if end < plen:
            self._pf = (req, rcache, end, pages)
            return True
        self._pf = None
        free = [i for i, r in enumerate(self.slots) if r is None]
        self._store_pages(req, prompt, [], 0, rcache, pages=pages)
        self._install(req, free[0], rcache, plen, logits[0])
        return True

    def _chunk_fn(self, chunk_len: int) -> Tuple:
        """Jitted ``prefill_chunk`` (+ degraded XLA twin) per chunk
        length; ``start`` is traced so one trace serves every offset."""
        fns = self._chunk_fns.get(chunk_len)
        if fns is not None:
            return fns
        cfg = self.eng.cfg

        def _chunk(params, cache, toks, start):
            return lm.prefill_chunk(params, cache, toks, cfg, start)

        def _chunk_xla(params, cache, toks, start):
            with layers.forced_backend("xla"):
                return lm.prefill_chunk(params, cache, toks, cfg, start)

        fns = (jax.jit(_chunk), jax.jit(_chunk_xla))
        self._chunk_fns[chunk_len] = fns
        return fns

    def _paged_fns(self) -> Tuple:
        """Jitted ``paged_decode_step`` (+ degraded XLA twin)."""
        if self._paged_jit is None:
            cfg = self.eng.cfg

            def _step(params, kp, vp, toks, tables, kv, wpid, woff):
                return lm.paged_decode_step(params, kp, vp, toks, tables,
                                            kv, wpid, woff, cfg)

            def _step_xla(params, kp, vp, toks, tables, kv, wpid, woff):
                with layers.forced_backend("xla"):
                    return lm.paged_decode_step(params, kp, vp, toks,
                                                tables, kv, wpid, woff,
                                                cfg)

            self._paged_jit = (jax.jit(_step), jax.jit(_step_xla))
        return self._paged_jit

    def _store_pages(self, req, prompt, reuse: List[int], covered: int,
                     rcache, pages: Optional[List[int]] = None) -> None:
        """Scatter the prefilled row into the page pool.

        Paged datapath: ``pages`` were pre-acquired at admission —
        storing cannot fail.  Legacy slot-cache configs keep the
        best-effort behavior (pool exhaustion falls back to
        slot-cache-only, the pool is just a prefix-sharing mirror)."""
        if self.paged is None or "k" not in rcache:
            return
        plen = len(prompt)
        if pages is None:
            new = self.paged.alloc(
                pages_for(plen, self.cc.page_size) - len(reuse))
            if new is None:
                if reuse:
                    self.paged.release(reuse)
                return
            pages = list(reuse) + new
        self.paged.store(prompt, pages, covered,
                         rcache["k"][:, 0], rcache["v"][:, 0])
        self.req_pages[req.rid] = pages

    def _install(self, req, slot: int, rcache, plen: int,
                 first_logits) -> None:
        """Mark the row live (paged: set its kv length; slot-cache:
        copy the B=1 prefilled row in) and emit the prompt's first
        generated token."""
        if self.use_paged:
            self.kv_lens[slot] = plen
        else:
            for key, arr in self.cache.items():
                if key == "index":
                    continue
                self.cache[key] = arr.at[:, slot].set(
                    rcache[key][:, 0].astype(arr.dtype))
            self.cache["index"] = self.cache["index"].at[slot].set(plen)
        req.state = self._E.RequestState.DECODING
        self.slots[slot] = req
        self._emit(slot, first_logits)

    # -- the pressure ladder --------------------------------------------
    def _acquire_decode_page(self, slot: int) -> bool:
        """Attach one more page to ``slot``'s request, running the
        pressure ladder on allocation failure: spill the coldest other
        active request, then preempt the youngest other holder.
        Returns False only when the ladder is exhausted (the caller
        preempts the needy request itself)."""
        req = self.slots[slot]
        while True:
            new = self.paged.alloc(1)
            if new is not None:
                self.req_pages[req.rid].extend(new)
                return True
            if self._spill_coldest(exclude_slot=slot):
                continue
            if self._preempt_youngest(exclude_rid=req.rid):
                continue
            return False

    def _spill_coldest(self, exclude_slot: int) -> bool:
        """Spill the LRU active request (smallest last decode step,
        ties broken toward the youngest rid) other than
        ``exclude_slot``.  Returns True if a victim moved to host."""
        cands = [i for i, r in enumerate(self.slots)
                 if r is not None and i != exclude_slot]
        if not cands:
            return False
        victim = min(cands, key=lambda i: (
            self.last_step.get(self.slots[i].rid, 0),
            -self.slots[i].rid))
        return self._spill_slot(victim)

    def _spill_slot(self, slot: int) -> bool:
        """Move ``slot``'s request to the host spill tier and park it
        in ``paused``.  An injected ``pool.spill`` failure aborts the
        spill (the caller escalates to preemption)."""
        req = self.slots[slot]
        pages = self.req_pages[req.rid]
        try:
            entries = self.paged.spill(pages)
        except health.SimulatedFailure as e:
            self.eng.monitor.note(
                "spill-failed", site="pool.spill", step=self.step_count,
                detail=f"rid {req.rid}: {e}")
            return False
        del self.req_pages[req.rid]
        n_host = sum(1 for e in entries if e[0] == "host")
        self.spilled[req.rid] = (req, int(self.kv_lens[slot]), entries)
        self.paused.append(req.rid)
        self.slots[slot] = None
        self.last_tok[slot] = 0
        self.kv_lens[slot] = 0
        self.eng._counters["spills"] += 1
        self.eng._counters["spilled_pages"] += n_host
        self.eng.monitor.note(
            "spill", site="serve.decode_step", step=self.step_count,
            detail=f"rid {req.rid}: {n_host} page(s) to host "
                   f"({len(entries) - n_host} shared stay pinned)")
        return True

    def _preempt_youngest(self, exclude_rid: Optional[int] = None
                          ) -> bool:
        """Preempt the youngest (highest-rid) request holding pool
        pages — paused before active, so recompute cost lands on the
        request with the least standing work.  Returns True if one was
        preempted."""
        paused = [rid for rid in self.paused if rid != exclude_rid]
        if paused:
            rid = max(paused)
            req, _, entries = self.spilled.pop(rid)
            self.paused.remove(rid)
            self.paged.release(
                [e[1] for e in entries if e[0] == "resident"])
            self._requeue(req)
            return True
        cands = [i for i, r in enumerate(self.slots)
                 if r is not None and r.rid != exclude_rid]
        if not cands:
            return False
        slot = max(cands, key=lambda i: self.slots[i].rid)
        self._preempt_slot(slot)
        return True

    def _preempt_slot(self, slot: int) -> None:
        """Release ``slot``'s pages and re-queue its request."""
        req = self.slots[slot]
        self.paged.release(self.req_pages.pop(req.rid))
        self.slots[slot] = None
        self.last_tok[slot] = 0
        self.kv_lens[slot] = 0
        self.last_step.pop(req.rid, None)
        self._requeue(req)

    def _requeue(self, req) -> None:
        """The preemption tail: journal a fsync'd ``preempt`` record,
        stash the emitted tokens as replay expectations (the
        deterministic recompute must reproduce them bit-exactly —
        ``replay_divergence`` fires if it does not), and put the
        request back at the head of the queue."""
        if self.eng.journal is not None:
            self.eng.journal.append(
                "preempt", fsync=True, rid=req.rid, step=self.step_count,
                tokens_done=len(req.out_tokens))
        if req.out_tokens:
            exp = self.eng._replay_expected
            if len(req.out_tokens) > len(exp.get(req.rid, [])):
                exp[req.rid] = list(req.out_tokens)
        req.out_tokens = []
        req.state = self._E.RequestState.QUEUED
        self.waiting.appendleft(req)
        self.eng._counters["preemptions"] += 1
        self.eng.monitor.note(
            "preempt", site="serve.decode_step", step=self.step_count,
            detail=f"rid {req.rid} re-queued under memory pressure "
                   f"(will recompute deterministically)")

    # -- decode ---------------------------------------------------------
    def _sweep_deadlines(self) -> bool:
        """Evict every active or spilled request past its deadline."""
        now = time.monotonic()
        evicted = False
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            dl = r.deadline_s
            if dl is not None and now - self.t_start[r.rid] > dl:
                self._evict(r, i)
                evicted = True
        for rid in list(self.paused):
            req, _, entries = self.spilled[rid]
            dl = req.deadline_s
            if dl is not None and now - self.t_start.get(rid, now) > dl:
                self.paused.remove(rid)
                del self.spilled[rid]
                self.paged.release(
                    [e[1] for e in entries if e[0] == "resident"])
                self._evict(req, None)
                evicted = True
        return evicted

    def _evict(self, r, slot: Optional[int]) -> None:
        r.state = self._E.RequestState.EVICTED
        r.error = (f"deadline {r.deadline_s:.3f}s exceeded after "
                   f"{len(r.out_tokens)} tokens")
        self.eng._counters["evicted"] += 1
        self.eng.monitor.note("evicted", site="serve.decode_step",
                              step=self.step_count, detail=r.error)
        self.eng._journal_terminal(r, self.step_count)
        if slot is not None:
            self._free_slot(slot)
        else:
            self.t_start.pop(r.rid, None)
            self.last_step.pop(r.rid, None)

    def _decode(self) -> bool:
        if self.use_paged:
            return self._decode_paged()
        RequestState = self._E.RequestState
        evicted = self._sweep_deadlines()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return evicted
        self.step_count += 1
        toks = jnp.asarray(self.last_tok[:, None].astype(np.int32))
        cache = self.cache
        t0 = time.monotonic()
        try:
            logits, cache, path = self.eng._execute(
                "serve.decode_step", self.step_count,
                lambda: self.eng._decode(self.eng.params, cache, toks),
                lambda: self.eng._decode_degraded(self.eng.params, cache,
                                                  toks))
        except self._E.StepFailed as e:
            for i in active:
                self._fail(self.slots[i], e)
                self._free_slot(i)
            return True
        self.cache = cache
        if path == "degraded":
            self.eng._counters["degraded_steps"] += 1
            for i in active:
                self.slots[i].degraded_steps += 1
        self.eng.monitor.record(self.step_count, time.monotonic() - t0)
        logits_np = np.asarray(logits)
        for i in active:
            self._emit(i, logits_np[i])
        # park freed rows at index 0 so the cache state is a pure
        # function of the live requests (deterministic replay)
        occupied = np.asarray(
            [r is not None for r in self.slots], bool)
        self.cache["index"] = jnp.where(
            jnp.asarray(occupied), self.cache["index"], 0)
        return True

    def _decode_paged(self) -> bool:
        """One decode step straight off the page pool: grow rows at
        page boundaries (running the pressure ladder on failure), then
        dispatch ``lm.paged_decode_step`` over the block tables."""
        evicted = self._sweep_deadlines()
        if not any(r is not None for r in self.slots):
            return evicted
        ps = self.cc.page_size
        # page-boundary growth; the ladder may spill/preempt *other*
        # slots while satisfying row i, so re-check liveness as we go
        for i in range(self.cc.max_batch):
            req = self.slots[i]
            if req is None:
                continue
            if int(self.kv_lens[i]) // ps < len(self.req_pages[req.rid]):
                continue
            if not self._acquire_decode_page(i):
                # ladder exhausted with the needy request the only
                # holder left: recompute it later instead of wedging
                self._preempt_slot(i)
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return True                    # the ladder did the work
        self.step_count += 1
        mb = self.cc.max_batch
        tables = np.zeros((mb, self.max_pages), np.int32)
        wp = np.full(mb, self.paged.scratch, np.int32)
        wo = np.zeros(mb, np.int32)
        for i in active:
            pages = self.req_pages[self.slots[i].rid]
            tables[i, :len(pages)] = pages
            kv = int(self.kv_lens[i])
            wp[i] = pages[kv // ps]
            wo[i] = kv % ps
        toks = jnp.asarray(self.last_tok[:, None].astype(np.int32))
        tables_d = jnp.asarray(tables)
        kv_d = jnp.asarray(self.kv_lens.astype(np.int32))
        wp_d, wo_d = jnp.asarray(wp), jnp.asarray(wo)
        k_pool, v_pool = self.paged.k_pages, self.paged.v_pages
        primary, degraded = self._paged_fns()
        t0 = time.monotonic()
        try:
            logits, pools, path = self.eng._execute(
                "serve.decode_step", self.step_count,
                lambda: primary(self.eng.params, k_pool, v_pool, toks,
                                tables_d, kv_d, wp_d, wo_d),
                lambda: degraded(self.eng.params, k_pool, v_pool, toks,
                                 tables_d, kv_d, wp_d, wo_d))
        except self._E.StepFailed as e:
            for i in active:
                self._fail(self.slots[i], e)
                self._free_slot(i)
            return True
        # commit the pools only on step success — same pre-step-cache
        # retry contract as the slot path
        self.paged.k_pages, self.paged.v_pages = pools
        if path == "degraded":
            self.eng._counters["degraded_steps"] += 1
            for i in active:
                self.slots[i].degraded_steps += 1
        self.eng.monitor.record(self.step_count, time.monotonic() - t0)
        logits_np = np.asarray(logits)
        for i in active:
            self.kv_lens[i] += 1           # before _emit: it may free
            self._emit(i, logits_np[i])
        return True

    def _emit(self, slot: int, logits_row) -> None:
        """Sample one token for ``slot``, journal it, finish on budget."""
        RequestState = self._E.RequestState
        req = self.slots[slot]
        sp = getattr(req, "sampling", None)
        greedy = self.greedy if sp is None else sp.greedy
        if greedy:
            t = int(np.argmax(np.asarray(logits_row)))
        else:
            seed = self.seed if sp is None else sp.seed
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(seed), req.rid),
                len(req.out_tokens))
            t = int(jax.random.categorical(
                key, jnp.asarray(logits_row)))
        req.out_tokens.append(t)
        self.last_tok[slot] = t
        self.last_step[req.rid] = self.step_count
        if self.eng.journal is not None:
            self.eng.journal.append("token", rid=req.rid,
                                    step=len(req.out_tokens), token=t)
        if len(req.out_tokens) >= req.max_new_tokens:
            req.state = RequestState.DONE
            self.eng._counters["completed"] += 1
            self.eng._journal_terminal(req, self.step_count)
            self._free_slot(slot)

    # -- bookkeeping ----------------------------------------------------
    def _fail(self, req, err: BaseException) -> None:
        req.state = self._E.RequestState.FAILED
        req.error = str(err)
        self.eng._counters["failed"] += 1
        self.eng._journal_terminal(req, self.step_count)
        pages = self.req_pages.pop(req.rid, None)
        if pages is not None:
            self.paged.release(pages)

    def _free_slot(self, slot: int) -> None:
        req = self.slots[slot]
        self.slots[slot] = None
        self.last_tok[slot] = 0
        self.kv_lens[slot] = 0
        self.t_start.pop(req.rid, None)
        self.last_step.pop(req.rid, None)
        pages = self.req_pages.pop(req.rid, None)
        if pages is not None:
            self.paged.release(pages)

    def report(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "steps": self.step_count,
            "waiting": len(self.waiting),
            "active": sum(r is not None for r in self.slots),
            "paused": len(self.paused),
            "max_batch": self.cc.max_batch,
            "paged_decode": self.use_paged,
        }
        if self.paged is not None:
            out["pages"] = self.paged.report()
        return out
